"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness (assignment requirement)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (x64 on; models are dtype-explicit)
from repro.configs.registry import ARCHS, get_arch
from repro.models import (
    decode_step, forward_train, init_cache, init_params, loss_fn, prefill,
)

B, L = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, 2 * L, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad_step(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)

    logits, aux = forward_train(params, batch, cfg)
    assert logits.shape == (B, L, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros(()))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Teacher-forced consistency: step-by-step decode logits == prefill
    logits at the last position (validates every cache implementation)."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(7)
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, rng)
    max_len = L + 8

    logits_pre, cache = prefill(params, batch, cfg, max_len)
    assert logits_pre.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits_pre).all()), arch

    # decode-from-scratch over the same tokens must reproduce the prefill
    # last-position logits
    if cfg.enc_dec:
        cache2 = init_cache(cfg, B, max_len, enc_len=2 * L)
        # fill cross-attention memory from prefill's cache (encoder is
        # deterministic; reuse it)
        cache2 = {"dec": [
            {**c2, "xk": c1["xk"], "xv": c1["xv"]}
            for c1, c2 in zip(cache["dec"], cache2["dec"])]}
    else:
        cache2 = init_cache(cfg, B, max_len)
    logits_t = None
    for t in range(L):
        logits_t, cache2 = decode_step(
            params, cache2, batch["tokens"][:, t: t + 1], t, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_t, np.float32), np.asarray(logits_pre, np.float32),
        rtol=2e-2, atol=2e-2, err_msg=arch)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "recurrentgemma-2b"])
def test_windowed_decode_beyond_window(arch):
    """Ring-buffered caches stay correct past the window boundary."""
    cfg = get_arch(arch).reduced(window=8)
    rng = np.random.default_rng(8)
    params = init_params(cfg, jax.random.key(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 24)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    logits_pre, _ = prefill(params, batch, cfg, max_len=32)
    cache = init_cache(cfg, B, 32)
    logits_t = None
    for t in range(24):
        logits_t, cache = decode_step(params, cache, toks[:, t: t + 1], t,
                                      cfg)
    np.testing.assert_allclose(
        np.asarray(logits_t, np.float32), np.asarray(logits_pre, np.float32),
        rtol=2e-2, atol=2e-2, err_msg=arch)


def test_moe_routes_to_multiple_experts():
    cfg = get_arch("kimi-k2-1t-a32b").reduced()
    from repro.models.moe import init_moe, moe_block
    p = init_moe(jax.random.key(3), cfg)
    x = jax.random.normal(jax.random.key(4), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0.0
