"""Distribution layer tests.

Multi-device behaviours (sharded HE pipeline correctness, compressed-DP
all-reduce, sharding-rule placement) run through the shared
``run_in_8dev_subprocess`` harness (tests/conftest.py): a fresh
interpreter with XLA_FLAGS=--xla_force_host_platform_device_count=8 —
the flag must be set before jax initializes, and the main test process
has already done so.
"""


def test_he_pipeline_matches_core_on_mesh(run_in_8dev_subprocess):
    """Sharded HE Mul (batch→data, np→model) == core.heaan.he_mul, bitwise,
    on a (2, 4) mesh of 8 placeholder devices."""
    res = run_in_8dev_subprocess("""
        from repro.core import test_params
        from repro.core import heaan as H
        from repro.core.keys import keygen
        from repro.core.context import make_context
        from repro.dist import he_pipeline as hp
        from repro.dist.sharding import he_limb_sharding

        params = test_params(logN=5, beta_bits=32)
        sk, pk, evk = keygen(params, seed=0)
        rng = np.random.default_rng(1)
        B = 4
        cts = []
        for i in range(2 * B):
            z = rng.normal(size=8) + 1j * rng.normal(size=8)
            cts.append(H.encrypt_message(z, pk, params, seed=10 + i))
        ref = [H.he_mul(cts[2*i], cts[2*i+1], evk, params)
               for i in range(B)]

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        st = hp.he_static(params, params.logQ)
        step = jax.jit(hp.make_he_mul_step(st, mesh))
        ctx = make_context(params, params.logQ)
        t1, t2, ek = hp.runtime_tables(ctx, evk)
        stack = lambda xs: jnp.stack(xs)
        sh = he_limb_sharding(mesh)
        ax1 = jax.device_put(stack([cts[2*i].ax for i in range(B)]), sh)
        bx1 = jax.device_put(stack([cts[2*i].bx for i in range(B)]), sh)
        ax2 = jax.device_put(stack([cts[2*i+1].ax for i in range(B)]), sh)
        bx2 = jax.device_put(stack([cts[2*i+1].bx for i in range(B)]), sh)
        ax3, bx3 = jax.jit(step)(t1, t2, ek, ax1, bx1, ax2, bx2)
        ok = all(
            bool((np.asarray(ax3[i]) == np.asarray(ref[i].ax)).all()
                 and (np.asarray(bx3[i]) == np.asarray(ref[i].bx)).all())
            for i in range(B))
        print(json.dumps({"ok": ok, "devices": len(jax.devices())}))
    """)
    assert res["devices"] == 8
    assert res["ok"], "sharded HE Mul diverged from core he_mul"


def test_compressed_dp_grads_close_to_exact(run_in_8dev_subprocess):
    res = run_in_8dev_subprocess("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum_grads

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.normal(size=(8, 4, 333)).astype(np.float32))

        def local(g, key):
            out = compressed_psum_grads({"w": g[0]}, ("data",), key[0])
            return out["w"][None]

        fn = shard_map(local, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P("data"), check_rep=False)
        keys = jax.random.split(jax.random.key(0), 1)
        out = fn(g_all, keys)
        exact = np.asarray(g_all).mean(axis=0)
        approx = np.asarray(out)[0]
        # every replica holds the same result
        same = all(np.array_equal(np.asarray(out)[i], approx)
                   for i in range(8))
        scale = np.abs(np.asarray(g_all)).max() / 127.0
        err = np.abs(approx - exact).max()
        print(json.dumps({"same": bool(same), "err": float(err),
                          "tol": float(3 * scale)}))
    """)
    assert res["same"], "replicas diverged after compressed all-reduce"
    assert res["err"] <= res["tol"], (res["err"], res["tol"])


def test_param_sharding_rules_place_and_divide(run_in_8dev_subprocess):
    res = run_in_8dev_subprocess("""
        from repro.configs.registry import get_arch
        from repro.dist.sharding import param_sharding_rules
        from repro.models import init_params

        cfg = get_arch("llama3.2-1b").reduced(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
            vocab_size=512)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = init_params(cfg, jax.random.key(0))
        shardings = param_sharding_rules(params, mesh)
        placed = jax.device_put(params, shardings)
        leaves = jax.tree.leaves(placed)
        n_sharded = sum(
            1 for l in leaves
            if not l.sharding.is_fully_replicated)
        print(json.dumps({"n_leaves": len(leaves),
                          "n_sharded": int(n_sharded)}))
    """)
    assert res["n_sharded"] >= res["n_leaves"] // 2, res
