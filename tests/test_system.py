"""End-to-end behaviour tests for the whole system (deliverable c).

One pass through each public surface: the HE scheme (the paper's
contribution), an LM train/serve cycle, and the encrypted-inference
composition the examples ship.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.keys import keygen
from repro.configs.registry import ARCHS, get_arch, get_shapes, SHAPES
from repro.launch.train import TrainConfig, Trainer
from repro.launch.serve import generate


def test_registry_covers_assignment():
    assert len(ARCHS) == 10
    # 40 assigned cells = 10 archs × 4 shapes; skips documented per arch
    total = sum(len(SHAPES) for _ in ARCHS)
    assert total == 40
    runnable = sum(len(get_shapes(a)) for a in ARCHS)
    assert runnable == 33          # 7 long_500k full-attention skips
    for a in ("h2o-danube-1.8b", "recurrentgemma-2b", "falcon-mamba-7b"):
        assert "long_500k" in get_shapes(a), a


def test_he_scheme_end_to_end():
    params = small_params(logN=5, beta_bits=32)
    sk, pk, evk = keygen(params, seed=0)
    rng = np.random.default_rng(0)
    z1 = rng.normal(size=8) + 1j * rng.normal(size=8)
    z2 = rng.normal(size=8) + 1j * rng.normal(size=8)
    c1 = H.encrypt_message(z1, pk, params, seed=1)
    c2 = H.encrypt_message(z2, pk, params, seed=2)
    c3 = H.rescale(H.he_mul(c1, c2, evk, params), params)
    c4 = H.he_add(c3, H.he_mod_down(c1, params, c3.logq))
    out = H.decrypt_message(c4, sk, params)
    assert np.abs(out - (z1 * z2 + z1)).max() < 1e-2


@pytest.mark.slow
def test_plain_ops_compose_with_he_mul():
    """he_mul_plain ∘ he_mul chain (the encrypted-inference building block)."""
    params = small_params(logN=5, beta_bits=32, logQ=144, logp=24)
    sk, pk, evk = keygen(params, seed=3)
    rng = np.random.default_rng(4)
    z = rng.normal(size=8)
    ct = H.encrypt_message(z.astype(np.complex128), pk, params, seed=5)
    w = np.full(8, 0.5, np.complex128)
    scaled = H.rescale(
        H.he_mul_plain(ct, H.encode_plain(w, params, ct.logq), params),
        params)
    sq = H.rescale(H.he_mul(scaled, scaled, evk, params), params)
    out = H.decrypt_message(sq, sk, params).real
    np.testing.assert_allclose(out, (0.5 * z) ** 2, atol=1e-2)


@pytest.mark.slow
def test_train_then_serve_cycle(tmp_path):
    cfg = get_arch("llama3.2-1b").reduced(n_layers=2, d_model=64,
                                          n_heads=2, n_kv_heads=2,
                                          head_dim=32, d_ff=128,
                                          vocab_size=256)
    tr = Trainer(cfg, TrainConfig(batch=2, seq_len=16, steps=4,
                                  ckpt_every=2), ckpt_dir=str(tmp_path))
    tr.run()
    assert tr.step == 4
    toks = jnp.asarray(np.arange(16, dtype=np.int32)[None].repeat(2, 0))
    out = generate(tr.params, cfg, toks, gen_steps=4, max_len=24)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab_size
