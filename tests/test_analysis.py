"""repro.analysis tests: the shared dataflow engine, lint rules, noise
estimator, cost model, scheduler cost gate, session check=, and the
CLI — plus the noise UPPER-BOUND property on 100 seeded random traced
circuits (predicted worst-case decrypt error must dominate the
measured error, and must not be vacuously loose).
"""

import json

import numpy as np
import pytest

from repro.analysis import (CircuitError, CostModel, analyze_circuit,
                            analyze_handle, estimate_noise, op_units,
                            propagate, transfer, OPS, RULES)
from repro.analysis.__main__ import main as hslint_main
from repro.analysis.examples import EXAMPLES, build
from repro.core.params import test_params as small_params
from repro.hserve import CircuitOp, degree4_demo_circuit
from repro.hserve.circuit import validate_circuit
from repro.hserve.scheduler import CircuitScheduler

PARAMS = small_params()                    # logN=5, logQ=120, logp=24
TOP = (PARAMS.logQ, PARAMS.logp)


# ---------------------------------------------------------------- dataflow

def test_transfer_rules():
    lp = PARAMS.logp
    assert transfer("mul", [TOP, TOP], PARAMS) == (120, 48)
    assert transfer("add", [TOP, TOP], PARAMS) == TOP
    assert transfer("rescale", [(120, 48)], PARAMS, dlogp=lp) == (96, 24)
    assert transfer("mod_down", [TOP], PARAMS, logq2=72) == (72, 24)
    assert transfer("rotate", [TOP], PARAMS, r=3) == TOP
    # mul_plain with an un-scaled operand picks up log_delta
    assert transfer("mul_plain", [TOP], PARAMS, pt_logp=0) == \
        (120, 24 + PARAMS.log_delta)


def test_transfer_errors_cite_node_op_and_meta():
    with pytest.raises(CircuitError, match="exhausts the modulus"):
        transfer("rescale", [(24, 48)], PARAMS, dlogp=24, node=7)
    try:
        transfer("rescale", [(24, 48)], PARAMS, dlogp=24, node=7)
    except CircuitError as e:
        assert e.node == 7 and e.op == "rescale"
        assert e.logq == 24 and e.logp == 48
        assert "node 7 (rescale)" in str(e)
        assert "(logq=24, logp=48)" in str(e)
    with pytest.raises(CircuitError, match="levels differ"):
        transfer("add", [TOP, (96, 24)], PARAMS)
    with pytest.raises(CircuitError, match="scales differ"):
        transfer("add", [(120, 48), TOP], PARAMS)


def test_propagate_error_paths():
    with pytest.raises(CircuitError, match="unknown input"):
        propagate([CircuitOp("rotate", ("nope",), r=1)], {"x": TOP},
                  PARAMS)
    with pytest.raises(CircuitError, match="not an earlier node"):
        propagate([CircuitOp("add", (1, "x")),
                   CircuitOp("add", (0, "x"))], {"x": TOP}, PARAMS)


def test_validate_circuit_is_the_shared_engine():
    """hserve's validator and the analysis engine must be the SAME
    computation — metas agree node for node on the demo circuit."""
    ops, _ = degree4_demo_circuit(PARAMS)
    assert validate_circuit(ops, {"x": TOP}, PARAMS) == \
        propagate(ops, {"x": TOP}, PARAMS)


def test_compile_pass_uses_the_shared_engine():
    """A traced expression that exhausts the modulus must fail in the
    compiler with the engine's message (no second hand-rolled check)."""
    from repro.client import HESession
    p = small_params(logN=4, beta_bits=32)
    s = HESession(p, seed=0, batch=2)
    e = s.encrypt(np.ones(p.n_slots_max) + 0j)
    for _ in range(p.L):
        e = e * e
    with pytest.raises(ValueError, match="exhausts the modulus"):
        e.result()


# ------------------------------------------------------------------- noise

def test_noise_recurrences():
    ops = [CircuitOp("mul", ("x", "y")),
           CircuitOp("rescale", (0,), dlogp=PARAMS.logp),
           CircuitOp("mod_down", (1,), logq2=72)]
    nn = estimate_noise(ops, {"x": TOP, "y": TOP}, PARAMS)
    assert len(nn) == 3
    assert all(n.nu > 0 and np.isfinite(n.nu) for n in nn)
    # rescale shrinks noise (divides by 2^logp, adds only rounding)
    assert nn[1].nu < nn[0].nu
    # mod_down is exact: same nu, new logq
    assert nn[2].nu == nn[1].nu and nn[2].logq == 72
    assert nn[1].precision_bits == PARAMS.logp - np.log2(nn[1].nu)


# ------------------------------------------------------------------- rules

def _report(ops, input_meta=None, **kw):
    return analyze_circuit(ops, input_meta or {"x": TOP}, PARAMS, **kw)


def _ids(report):
    return [d.rule for d in report.diagnostics]


def test_hs001_dataflow_violation_is_an_error_diagnostic():
    # mul+rescale pairs: the (L+1)-th rescale has no modulus left
    ops = [CircuitOp("mul", ("x", "x")),
           CircuitOp("rescale", (0,), dlogp=PARAMS.logp)]
    for _ in range(PARAMS.L):
        ops += [CircuitOp("mul", (len(ops) - 1, len(ops) - 1)),
                CircuitOp("rescale", (len(ops),), dlogp=PARAMS.logp)]
    r = _report(ops)
    assert not r.ok
    assert [d.rule for d in r.errors] == ["HS001"]
    assert "exhausts the modulus" in r.errors[0].message


def test_hs007_names_the_bootstrappable_node_on_exhaustion():
    # the HS001 companion: the analyzer points at the node whose
    # level-exhausted OUTPUT a repro.boot pipeline would refresh — the
    # failing rescale's mul operand, where run(bootstrap="auto") would
    # splice the insertion
    ops = [CircuitOp("mul", ("x", "x")),
           CircuitOp("rescale", (0,), dlogp=PARAMS.logp)]
    for _ in range(PARAMS.L):
        ops += [CircuitOp("mul", (len(ops) - 1, len(ops) - 1)),
                CircuitOp("rescale", (len(ops),), dlogp=PARAMS.logp)]
    r = _report(ops)
    assert not r.ok
    hs7 = [d for d in r.diagnostics if d.rule == "HS007"]
    assert len(hs7) == 1 and hs7[0].severity == "info"
    # propagation dies at the FIRST exhausting rescale (the L-th pair,
    # at logq = logp); the suggested insertion point is its mul operand
    assert hs7[0].node == 2 * PARAMS.L - 2
    assert "bootstrappable" in hs7[0].message
    assert 'bootstrap="auto"' in hs7[0].message


def test_hs002_waterline():
    ops = [CircuitOp("add", ("x", "x"))]
    clean = _report(ops)
    assert "HS002" not in _ids(clean)
    low = _report(ops, waterline_bits=100.0)
    w = [d for d in low.diagnostics if d.rule == "HS002"]
    assert w and w[0].severity == "warning"
    assert "waterline" in w[0].message


def test_hs003_dead_node():
    ops = [CircuitOp("add", ("x", "x")),      # dead: nothing uses it
           CircuitOp("sub", ("x", "x")),
           CircuitOp("add", (1, "x"))]
    d = [x for x in _report(ops).diagnostics if x.rule == "HS003"]
    assert len(d) == 1 and d[0].node == 0
    assert "never consumed" in d[0].message


def test_hs004_rotations():
    n = PARAMS.n_slots_max
    noop = _report([CircuitOp("rotate", ("x",), r=n)])
    d = [x for x in noop.diagnostics if x.rule == "HS004"]
    assert d and d[0].severity == "warning" and "no-op" in d[0].message

    comp = [CircuitOp("rotate", ("x",), r=5)]
    info = [x for x in _report(comp).diagnostics if x.rule == "HS004"]
    assert info and info[0].severity == "info"       # keys unknown
    warn = [x for x in _report(
        comp, provisioned_rotations={1, 2, 4}).diagnostics
        if x.rule == "HS004"]
    assert warn and warn[0].severity == "warning"    # 5 missing, 1+4 held
    assert "1+4" in warn[0].message


def test_hs005_eager_rescale():
    eager = [CircuitOp("mul", ("x", "x")),
             CircuitOp("rescale", (0,), dlogp=PARAMS.logp)]
    assert "HS005" in _ids(_report(eager))
    lazy = eager + [CircuitOp("mod_down", ("x",), logq2=96),
                    CircuitOp("mul", (1, 2))]
    assert "HS005" not in _ids(_report(lazy))   # the rescale feeds a mul


def test_hs006_depth_headroom():
    shallow = [CircuitOp("add", ("x", "x"))]    # 4 spare levels at logQ
    d = [x for x in _report(shallow).diagnostics if x.rule == "HS006"]
    assert d and d[0].severity == "info" and "headroom" in d[0].message


def test_rules_registry_is_complete():
    # HS001-HS007 lint circuits; HS101-HS105 are shardlint's compiled-HLO
    # rules (emitted by repro.analysis.xla, registered here so the
    # catalog stays one table — see tests/test_shardlint.py)
    assert sorted(RULES) == [f"HS00{i}" for i in range(1, 8)] \
        + [f"HS10{i}" for i in range(1, 6)]
    assert RULES["HS001"].severity == "error"
    assert RULES["HS007"].severity == "info"


# -------------------------------------------------------------------- cost

def _bench_dict():
    return {"params": {"logN": PARAMS.logN, "logQ": PARAMS.logQ,
                       "logp": PARAMS.logp,
                       "beta_bits": PARAMS.beta_bits},
            "levels": [120, 96],
            "mul_per_s": 50.0, "rotate_per_s": 100.0,
            "plain": {"mul_plain_per_s": 200.0,
                      "add_plain_per_s": 5000.0}}


def test_cost_model_fit_and_ordering():
    cm = CostModel.from_bench(_bench_dict())
    assert set(cm.kappa) == {"mul", "rotate", "mul_plain", "add_plain"}
    # transforms dominate limb passes; deeper (higher logq) costs more
    assert cm.op_seconds("mul", 120) > cm.op_seconds("add", 120)
    assert cm.op_seconds("mul", 120) >= cm.op_seconds("mul", 48)
    # unmeasured ops fall back to rotate's key-switch kappa
    assert cm.op_seconds("conjugate", 120) == cm.op_seconds("rotate", 120)
    assert op_units("slot_sum", 120, PARAMS, n_slots=8) > \
        op_units("rotate", 120, PARAMS)


def test_cost_model_rejects_empty_bench():
    with pytest.raises(ValueError, match="no usable throughputs"):
        CostModel.from_bench({"params": _bench_dict()["params"],
                              "levels": [120]})


def test_cost_model_from_committed_bench_file():
    from pathlib import Path
    bench = Path(__file__).resolve().parent.parent / "BENCH_serve_he.json"
    cm = CostModel.from_bench(bench)
    assert cm.calibrated_from.endswith("BENCH_serve_he.json")
    ops, _ = degree4_demo_circuit(cm.params)
    total, per = cm.estimate_circuit(
        ops, {"x": (cm.params.logQ, cm.params.logp)})
    assert len(per) == len(ops) and total == pytest.approx(sum(per))
    assert total > 0


def test_analyze_circuit_reports_cost():
    cm = CostModel.from_bench(_bench_dict())
    r = _report([CircuitOp("mul", ("x", "x"))], cost_model=cm)
    assert r.cost_s and r.cost_s > 0
    assert r.calibrated_from == "<dict>"
    assert "est" in r.render("c")  # cost line surfaces in pretty output


# -------------------------------------------------- scheduler cost gate

def test_worth_deferring_gate():
    sch = CircuitScheduler()
    assert sch.cost_model is None
    # no model: legacy behavior — always worth deferring
    assert sch._worth_deferring(("mul", 120, None), 1, 4)

    big = CostModel({"mul": 1.0}, 1.0, PARAMS)      # ~seconds per op
    tiny = CostModel({"mul": 1e-15}, 1e-15, PARAMS)
    sch = CircuitScheduler(cost_model=big)
    assert sch._worth_deferring(("mul", 120, None), 1, 4)
    assert sch.cost_skips == 0
    sch.cost_model = tiny
    assert not sch._worth_deferring(("mul", 120, None), 1, 4)
    assert sch.cost_skips == 1
    # a full bucket has no padding to buy back — but the gate only ever
    # sees depth < batch (the drain flush checks that first)
    sch.reset_counters()
    assert sch.cost_skips == 0


def test_cost_gated_scheduling_is_bitwise_identical():
    """Drain two staggered degree-4 circuits with the deferral gate
    consulting a cost model vs not: results must match bit for bit
    (the gate may only change BATCHING, never values)."""
    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.core.rotate import conj_keygen
    from repro.hserve import HEServer

    p = small_params(logN=4, beta_bits=32)
    sk, pk, evk = keygen(p, seed=0)
    server = HEServer(p, evk, {}, conj_keygen(p, sk), batch=2,
                      schedule=True)
    ops, _ = degree4_demo_circuit(p)
    rng = np.random.default_rng(3)
    n = p.n_slots_max
    cts = [H.encrypt_message(rng.normal(size=n) + 0j, pk, p, seed=s)
           for s in (1, 2)]

    def staggered():
        res = {}
        c1 = server.submit_circuit(ops, {"x": cts[0]})
        res.update(dict(server.poll(flush=True)))
        c2 = server.submit_circuit(ops, {"x": cts[1]})
        res.update(server.drain())
        return res[c1], res[c2]

    outs_none = staggered()
    # at toy params EVERY bucket is below defer_min_s: the gate skips
    # every deferral (pure flush-now behavior) — maximally different
    # batching from the defer-always baseline, same bits
    server.scheduler.cost_model = CostModel.from_bench(_bench_dict())
    skips0 = server.scheduler.cost_skips
    outs_cost = staggered()
    assert server.scheduler.cost_skips > skips0
    assert server.scheduler.stats()["cost_model"] is True
    for a, b in zip(outs_none, outs_cost):
        assert (np.asarray(a.ax) == np.asarray(b.ax)).all()
        assert (np.asarray(a.bx) == np.asarray(b.bx)).all()


# --------------------------------------------------- session check= knob

@pytest.fixture(scope="module")
def session4():
    from repro.client import HESession
    p = small_params(logN=4, beta_bits=32)
    s = HESession(p, seed=0, batch=4)
    return p, s


def test_run_check_validates_its_argument(session4):
    _, s = session4
    x = s.encrypt(np.ones(s.params.n_slots_max) + 0j)
    with pytest.raises(ValueError, match="check must be"):
        s.run([x + x], check="loud")


def test_run_check_off_warn_error(session4):
    p, s = session4
    z = np.full(p.n_slots_max, 0.001 + 0j)
    x = s.encrypt(z)
    # big plaintext weights sink the predicted precision below the
    # waterline -> HS002 warning-severity finding
    bad = (x * 3000.0) * (x * 3000.0)

    with pytest.raises(ValueError,
                       match="static analysis rejected the run"):
        s.run([bad], check="error")

    with pytest.warns(UserWarning, match="HS002"):
        futs = s.run([bad], check="warn")
    s.drain()
    assert len(s.last_reports) == 1
    assert s.last_reports[0].warnings
    # still served under "warn" — and noisily, which is the point: the
    # flagged circuit's result carries visible error (the waterline
    # warning was RIGHT), so only a loose tolerance holds
    got = s.decrypt(futs[0].result())
    np.testing.assert_allclose(got, (z * 3000.0) ** 2, atol=0.5)

    clean = s.run([x + x], check="error")   # a clean circuit passes
    s.drain()
    assert s.last_reports[0].ok
    np.testing.assert_allclose(s.decrypt(clean[0].result()), 2 * z,
                               atol=1e-4)


def test_analyze_handle_bare_input(session4):
    p, s = session4
    x = s.encrypt(np.ones(p.n_slots_max) + 0j)
    r = analyze_handle(x, p)
    assert r.ok and r.n_ops == 0 and r.out_precision_bits is None


# ---------------------------------------------------------------- the CLI

def test_cli_json_over_all_examples(capsys):
    rc = hslint_main(["--json"])
    out = capsys.readouterr().out
    assert rc == 0
    reports = json.loads(out)
    assert set(reports) == set(EXAMPLES)
    for name, d in reports.items():
        assert d["ok"] is True, f"{name}: {d['diagnostics']}"
        assert d["n_ops"] > 0 and "note" in d
        assert d["out"]["precision_bits"] > 0


def test_cli_pretty_and_bench_calibration(capsys, tmp_path):
    bench = tmp_path / "b.json"
    bench.write_text(json.dumps(_bench_dict()))
    rc = hslint_main(["degree4", "--bench", str(bench)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "degree4" in out and "est" in out


def test_cli_unknown_example():
    with pytest.raises(ValueError, match="unknown example"):
        build("nope")


# -------------------------------------- typed errors (ex-asserts) sweep

def test_metrics_flush_cause_is_typed_error():
    from repro.hserve.metrics import ServeMetrics
    with pytest.raises(ValueError, match="unknown flush cause"):
        ServeMetrics().record_flush("panic")


def test_engine_addsub_step_is_typed_error():
    from repro.hserve.engine import make_addsub_step
    with pytest.raises(ValueError, match="addsub step takes op"):
        make_addsub_step(None, None, op="mul")


# ----------------------------------------- the noise upper-bound property

# documented slack contract (docs/ANALYSIS.md): the worst-case bound
# must HOLD on every circuit, and at test parameters (logN=4, depth<=4)
# stay within these many bits of the measured error — loose enough to
# be a sound worst case, tight enough to mean something
SLACK_MAX_BITS = 40.0
SLACK_MEDIAN_BITS = 20.0
N_CIRCUITS = 100


def test_noise_bound_on_100_random_traced_circuits(session4):
    p, s = session4
    rng = np.random.default_rng(42)
    n = p.n_slots_max
    leaves = []
    for i in range(2):
        z = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.5
        leaves.append((s.encrypt(z, seed=100 + i), z))
    in_bound = max(float(np.max(np.abs(z))) for _, z in leaves)

    from repro.client.testing import random_expr
    slacks = []
    for base in range(0, N_CIRCUITS, 20):     # chunked: one drain per 20
        exprs = []
        for k in range(base, base + 20):
            r = np.random.default_rng(1000 + k)
            h, shadow = random_expr(r, leaves, n_ops=3 + k % 3,
                                    max_depth=1 + k % 4)
            exprs.append((h, shadow))
        futs = s.run([h for h, _ in exprs])
        s.drain()
        for (h, shadow), f in zip(exprs, futs):
            measured = float(np.max(np.abs(s.decrypt(f.result())
                                           - shadow)))
            rep = analyze_handle(h, p, input_bounds=in_bound)
            predicted = 2.0 ** rep.noise[-1].error_bits
            assert measured <= predicted, (
                f"circuit {base + exprs.index((h, shadow))}: measured "
                f"error {measured:.3e} exceeds predicted bound "
                f"{predicted:.3e}")
            if measured > 0:
                slacks.append(float(np.log2(predicted / measured)))

    # non-vacuity: the bound tracks reality within the documented slack
    assert slacks, "every measured error was exactly zero?"
    assert float(np.median(slacks)) <= SLACK_MEDIAN_BITS
    assert max(slacks) <= SLACK_MAX_BITS, (
        f"bound is vacuous: max slack {max(slacks):.1f} bits")
