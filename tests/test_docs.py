"""Docs integrity as a tier-1 test: the same checks CI's docs step runs
(tools/check_docs.py) — relative links in README.md/docs/*.md resolve,
and the committed BENCH_serve_he.json matches the schema documented in
docs/SERVING.md."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_markdown_relative_links_resolve():
    assert check_docs.check_links(REPO) == []


def test_bench_serve_he_matches_documented_schema():
    assert check_docs.check_bench(REPO / "BENCH_serve_he.json") == []


def test_checker_flags_broken_links_and_bad_bench(tmp_path):
    """The checker itself must actually detect problems (a link-checker
    that passes everything keeps CI green while docs rot)."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[ok](docs/REAL.md) [broken](docs/NOPE.md) "
        "[ext](https://example.com) [anchor](#sec)\n")
    (tmp_path / "docs" / "REAL.md").write_text(
        "[back](../README.md)\n[gone](missing.md)\n")
    errs = check_docs.check_links(tmp_path)
    assert len(errs) == 2
    assert any("NOPE.md" in e for e in errs)
    assert any("missing.md" in e for e in errs)

    bench = tmp_path / "BENCH_serve_he.json"
    bench.write_text("{not json")
    assert any("invalid JSON" in e for e in check_docs.check_bench(bench))
    bench.write_text(
        '{"batch": "four", "trickle": {"requests": 1},'
        ' "scheduler": {"circuits": 2, "bitwise_identical": false,'
        '  "scheduled": {"drain_s": 0.1}}}')
    errs = check_docs.check_bench(bench)
    assert any("batch" in e and "expected int" in e for e in errs)
    assert any("missing key 'overlap'" in e for e in errs)
    assert any("missing key 'plain'" in e for e in errs)
    assert any("trickle: missing key 'p50_ms'" in e for e in errs)
    # the scheduler block is schema-checked too, including the per-phase
    # records and the bitwise guard (a false guard must FAIL the check)
    assert any("scheduler: missing key 'lookahead'" in e for e in errs)
    assert any("scheduler.scheduled: missing key 'batches'" in e
               for e in errs)
    assert any("changed a result bit" in e for e in errs)


def test_checker_analysis_block_failure_paths(tmp_path):
    """The three analysis-block failure modes must each produce their
    own distinct message: a missing block, a per-phase schema
    violation, and a false bitwise guard (which is a DIFFERENT message
    from the scheduler/client bitwise failures, so a red CI log says
    which A/B broke)."""
    bench = tmp_path / "BENCH_serve_he.json"

    # 1. block missing entirely
    bench.write_text('{"batch": 2}')
    errs = check_docs.check_bench(bench)
    assert any("missing key 'analysis'" in e for e in errs)

    # 2. block present but malformed: wrong type at the top level and a
    #    phase record missing its counters
    bench.write_text(
        '{"analysis": {"circuits": 2, "calibrated_from": 3,'
        ' "est_circuit_s": 0.01, "bitwise_identical": true,'
        ' "nocost": {"drain_s": 0.1}, "cost": {}}}')
    errs = check_docs.check_bench(bench)
    assert any("analysis.calibrated_from: expected str" in e for e in errs)
    assert any("analysis.nocost: missing key 'cost_skips'" in e
               for e in errs)
    assert any("analysis.cost: missing key 'drain_s'" in e for e in errs)
    assert not any("changed a result bit" in e for e in errs)

    # 3. bitwise guard false — the cost-model-specific message
    bench.write_text(
        '{"analysis": {"circuits": 2, "calibrated_from": "self",'
        ' "est_circuit_s": 0.01, "bitwise_identical": false,'
        ' "nocost": {"drain_s": 0.1, "batches": 1, "mul_pad_frac": 0.0,'
        '  "deferrals": 0, "cost_skips": 0},'
        ' "cost": {"drain_s": 0.1, "batches": 1, "mul_pad_frac": 0.0,'
        '  "deferrals": 0, "cost_skips": 0}}}')
    errs = check_docs.check_bench(bench)
    assert any("cost-model scheduling changed a result bit" in e
               for e in errs)
    assert not any("scheduler: scheduling changed" in e for e in errs)
    assert not any("traced frontend changed" in e for e in errs)


def test_ci_runs_the_docs_step():
    """The acceptance criterion says the link check runs in CI — pin the
    workflow wiring so a refactor can't silently drop it."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tools/check_docs.py" in wf


def test_ci_runs_lint_and_hslint_steps():
    """Same pinning for this PR's additions: the ruff+mypy lint job and
    the analyzer CLI pass over the example circuits."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "ruff check ." in wf
    assert "mypy src/repro/analysis" in wf
    assert "repro.analysis" in wf.split("fast-tier")[1]
