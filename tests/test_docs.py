"""Docs integrity as a tier-1 test: the same checks CI's docs step runs
(tools/check_docs.py) — relative links in README.md/docs/*.md resolve,
and the committed BENCH_serve_he.json matches the schema documented in
docs/SERVING.md."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_markdown_relative_links_resolve():
    assert check_docs.check_links(REPO) == []


def test_bench_serve_he_matches_documented_schema():
    assert check_docs.check_bench(REPO / "BENCH_serve_he.json") == []


def test_checker_flags_broken_links_and_bad_bench(tmp_path):
    """The checker itself must actually detect problems (a link-checker
    that passes everything keeps CI green while docs rot)."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[ok](docs/REAL.md) [broken](docs/NOPE.md) "
        "[ext](https://example.com) [anchor](#sec)\n")
    (tmp_path / "docs" / "REAL.md").write_text(
        "[back](../README.md)\n[gone](missing.md)\n")
    errs = check_docs.check_links(tmp_path)
    assert len(errs) == 2
    assert any("NOPE.md" in e for e in errs)
    assert any("missing.md" in e for e in errs)

    bench = tmp_path / "BENCH_serve_he.json"
    bench.write_text("{not json")
    assert any("invalid JSON" in e for e in check_docs.check_bench(bench))
    bench.write_text(
        '{"batch": "four", "trickle": {"requests": 1},'
        ' "scheduler": {"circuits": 2, "bitwise_identical": false,'
        '  "scheduled": {"drain_s": 0.1}}}')
    errs = check_docs.check_bench(bench)
    assert any("batch" in e and "expected int" in e for e in errs)
    assert any("missing key 'overlap'" in e for e in errs)
    assert any("missing key 'plain'" in e for e in errs)
    assert any("trickle: missing key 'p50_ms'" in e for e in errs)
    # the scheduler block is schema-checked too, including the per-phase
    # records and the bitwise guard (a false guard must FAIL the check)
    assert any("scheduler: missing key 'lookahead'" in e for e in errs)
    assert any("scheduler.scheduled: missing key 'batches'" in e
               for e in errs)
    assert any("changed a result bit" in e for e in errs)


def test_checker_analysis_block_failure_paths(tmp_path):
    """The three analysis-block failure modes must each produce their
    own distinct message: a missing block, a per-phase schema
    violation, and a false bitwise guard (which is a DIFFERENT message
    from the scheduler/client bitwise failures, so a red CI log says
    which A/B broke)."""
    bench = tmp_path / "BENCH_serve_he.json"

    # 1. block missing entirely
    bench.write_text('{"batch": 2}')
    errs = check_docs.check_bench(bench)
    assert any("missing key 'analysis'" in e for e in errs)

    # 2. block present but malformed: wrong type at the top level and a
    #    phase record missing its counters
    bench.write_text(
        '{"analysis": {"circuits": 2, "calibrated_from": 3,'
        ' "est_circuit_s": 0.01, "bitwise_identical": true,'
        ' "nocost": {"drain_s": 0.1}, "cost": {}}}')
    errs = check_docs.check_bench(bench)
    assert any("analysis.calibrated_from: expected str" in e for e in errs)
    assert any("analysis.nocost: missing key 'cost_skips'" in e
               for e in errs)
    assert any("analysis.cost: missing key 'drain_s'" in e for e in errs)
    assert not any("changed a result bit" in e for e in errs)

    # 3. bitwise guard false — the cost-model-specific message
    bench.write_text(
        '{"analysis": {"circuits": 2, "calibrated_from": "self",'
        ' "est_circuit_s": 0.01, "bitwise_identical": false,'
        ' "nocost": {"drain_s": 0.1, "batches": 1, "mul_pad_frac": 0.0,'
        '  "deferrals": 0, "cost_skips": 0},'
        ' "cost": {"drain_s": 0.1, "batches": 1, "mul_pad_frac": 0.0,'
        '  "deferrals": 0, "cost_skips": 0}}}')
    errs = check_docs.check_bench(bench)
    assert any("cost-model scheduling changed a result bit" in e
               for e in errs)
    assert not any("scheduler: scheduling changed" in e for e in errs)
    assert not any("traced frontend changed" in e for e in errs)


def test_committed_shard_manifest_passes_the_docs_gate():
    assert check_docs.check_shard_manifest(REPO) == []


def test_checker_flags_shard_manifest_problems(tmp_path):
    """check_shard_manifest's failure paths: a missing committed file, a
    schema violation, and committed-vs-fresh drift each produce their
    own message (and the checker stays stdlib — it loads manifest.py by
    file path, so the repo layout must be mirrored)."""
    import json as _json
    import shutil

    ana = tmp_path / "src" / "repro" / "analysis"
    ana.mkdir(parents=True)
    shutil.copy(REPO / "src" / "repro" / "analysis" / "manifest.py",
                ana / "manifest.py")

    # 1. committed manifest missing entirely
    errs = check_docs.check_shard_manifest(tmp_path)
    assert any("file missing" in e and "shardlint.py --write" in e
               for e in errs)

    # 2. schema violation in the committed file
    committed = _json.loads((REPO / "SHARD_MANIFEST.json").read_text())
    bad = _json.loads(_json.dumps(committed))
    del bad["hbm_budget_bytes"]
    (tmp_path / "SHARD_MANIFEST.json").write_text(_json.dumps(bad))
    errs = check_docs.check_shard_manifest(tmp_path)
    assert any("missing key 'hbm_budget_bytes'" in e for e in errs)

    # 3. drift: a fresh measurement whose mul collective schedule changed
    (tmp_path / "SHARD_MANIFEST.json").write_text(_json.dumps(committed))
    fresh = _json.loads(_json.dumps(committed))
    key = "mul/120/2x4"
    fresh["cells"][key]["collectives"]["counts"]["all-reduce"] += 1
    fresh_p = tmp_path / "fresh.json"
    fresh_p.write_text(_json.dumps(fresh))
    errs = check_docs.check_shard_manifest(tmp_path, fresh_p)
    assert len(errs) == 1
    assert "drift vs fresh.json" in errs[0] and key in errs[0]
    # identical fresh measurement -> clean
    fresh_p.write_text(_json.dumps(committed))
    assert check_docs.check_shard_manifest(tmp_path, fresh_p) == []
    # fresh path that does not exist is its own message
    errs = check_docs.check_shard_manifest(tmp_path,
                                           tmp_path / "nope.json")
    assert any("nope.json" in e and "file missing" in e for e in errs)


def test_ci_runs_the_docs_step():
    """The acceptance criterion says the link check runs in CI — pin the
    workflow wiring so a refactor can't silently drop it."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tools/check_docs.py" in wf


def test_ci_runs_lint_and_hslint_steps():
    """Same pinning for this PR's additions: the ruff+mypy lint job and
    the analyzer CLI pass over the example circuits."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "ruff check ." in wf
    assert "mypy src/repro/analysis" in wf
    assert "repro.analysis" in wf.split("fast-tier")[1]


def test_ci_runs_the_shardlint_gate_and_its_self_test():
    """The shardlint acceptance wiring: fast-tier must run the full
    grid, drift-diff it against the committed manifest, AND prove the
    gate can go red (the injected-regression step inverts the exit
    code, so shardlint succeeding there fails CI)."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tools/shardlint.py --out /tmp/shard_fresh.json" in wf
    assert "check_docs.py --shard-manifest /tmp/shard_fresh.json" in wf
    assert "--inject bogus-ct-sharding" in wf
