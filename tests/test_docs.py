"""Docs integrity as a tier-1 test: the same checks CI's docs step runs
(tools/check_docs.py) — relative links in README.md/docs/*.md resolve,
and the committed BENCH_serve_he.json matches the schema documented in
docs/SERVING.md."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_markdown_relative_links_resolve():
    assert check_docs.check_links(REPO) == []


def test_bench_serve_he_matches_documented_schema():
    assert check_docs.check_bench(REPO / "BENCH_serve_he.json") == []


def test_checker_flags_broken_links_and_bad_bench(tmp_path):
    """The checker itself must actually detect problems (a link-checker
    that passes everything keeps CI green while docs rot)."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[ok](docs/REAL.md) [broken](docs/NOPE.md) "
        "[ext](https://example.com) [anchor](#sec)\n")
    (tmp_path / "docs" / "REAL.md").write_text(
        "[back](../README.md)\n[gone](missing.md)\n")
    errs = check_docs.check_links(tmp_path)
    assert len(errs) == 2
    assert any("NOPE.md" in e for e in errs)
    assert any("missing.md" in e for e in errs)

    bench = tmp_path / "BENCH_serve_he.json"
    bench.write_text("{not json")
    assert any("invalid JSON" in e for e in check_docs.check_bench(bench))
    bench.write_text(
        '{"batch": "four", "trickle": {"requests": 1},'
        ' "scheduler": {"circuits": 2, "bitwise_identical": false,'
        '  "scheduled": {"drain_s": 0.1}}}')
    errs = check_docs.check_bench(bench)
    assert any("batch" in e and "expected int" in e for e in errs)
    assert any("missing key 'overlap'" in e for e in errs)
    assert any("missing key 'plain'" in e for e in errs)
    assert any("trickle: missing key 'p50_ms'" in e for e in errs)
    # the scheduler block is schema-checked too, including the per-phase
    # records and the bitwise guard (a false guard must FAIL the check)
    assert any("scheduler: missing key 'lookahead'" in e for e in errs)
    assert any("scheduler.scheduled: missing key 'batches'" in e
               for e in errs)
    assert any("changed a result bit" in e for e in errs)


def test_ci_runs_the_docs_step():
    """The acceptance criterion says the link check runs in CI — pin the
    workflow wiring so a refactor can't silently drop it."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tools/check_docs.py" in wf
