"""Optimizer, schedule, compression, and data-pipeline unit tests."""

import numpy as np

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.compress import compress_int8, decompress_int8
from repro.data import SyntheticLM
from repro.configs.registry import get_arch


def test_adamw_matches_reference_formula():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    p2, st2, m = adamw_update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                              weight_decay=wd, clip_norm=1e9)
    gn = np.linalg.norm(np.asarray(g["w"]))
    mu = (1 - b1) * np.asarray(g["w"])
    nu = (1 - b2) * np.asarray(g["w"]) ** 2
    mhat = mu / (1 - b1)
    vhat = nu / (1 - b2)
    expect = np.asarray(p["w"]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert abs(float(m["grad_norm"]) - gn) < 1e-4
    assert int(st2.step) == 1


def test_adamw_clip_scales_gradients():
    p = {"w": jnp.ones((2,), jnp.float32)}
    g = {"w": jnp.full((2,), 100.0, jnp.float32)}
    st = adamw_init(p)
    _, _, m = adamw_update(g, st, p, lr=0.0, clip_norm=1.0)
    assert float(m["clip_scale"]) < 0.01


def test_adamw_bf16_moments_shapes_and_dtype():
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    st = adamw_init(p, moments_dtype=jnp.bfloat16)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8,), 0.1, jnp.bfloat16)}
    p2, st2, _ = adamw_update(g, st, p, lr=1e-2)
    assert st2.mu["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == 0.0
    assert abs(float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                                   total_steps=100)) - 1.0) < 1e-6
    end = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                              total_steps=100))
    assert abs(end - 0.1) < 1e-6


def test_int8_compression_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 5)
    q8, scale, meta = compress_int8(x, jax.random.key(0))
    back = decompress_int8(q8, scale, meta)
    # per-block error bounded by the quantization step
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= float(scale.max()) * 1.01


def test_synthetic_data_counter_deterministic():
    cfg = get_arch("llama3.2-1b").reduced()
    d1 = SyntheticLM(cfg, 4, 32, seed=7)
    d2 = SyntheticLM(cfg, 4, 32, seed=7)
    b1, b2 = d1.batch_at(13), d2.batch_at(13)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch_at(14)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    # (tokens[t+1] == labels[t] wherever both derive from the same seq)
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_synthetic_shard_slice():
    cfg = get_arch("llama3.2-1b").reduced()
    d = SyntheticLM(cfg, 8, 16, seed=0)
    b = d.batch_at(0)
    s0 = d.shard_slice(b, 0, 4)
    s3 = d.shard_slice(b, 3, 4)
    assert s0["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(s3["tokens"]),
                                  np.asarray(b["tokens"][6:8]))
