"""Limb-array BigInt arithmetic vs python-int oracles (incl. hypothesis)."""

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401  (enables x64)
from repro.core import bigint as B
from repro.nt.residue import int_to_limbs, limbs_to_int

L = 7  # limbs under test


def _to(x, bits, limbs=L):
    return jnp.asarray(int_to_limbs(x % (1 << (bits * limbs)), limbs, bits))


@pytest.mark.parametrize("bits", [32, 64])
@given(a=st.integers(min_value=0), b=st.integers(min_value=0))
@settings(max_examples=60, deadline=None)
def test_add_sub_mod_2k(bits, a, b):
    W_ = 1 << (bits * L)
    a, b = a % W_, b % W_
    s = B.add(_to(a, bits)[None], _to(b, bits)[None])[0]
    d = B.sub(_to(a, bits)[None], _to(b, bits)[None])[0]
    assert limbs_to_int(np.asarray(s), bits) == (a + b) % W_
    assert limbs_to_int(np.asarray(d), bits) == (a - b) % W_


@pytest.mark.parametrize("bits", [32, 64])
@given(a=st.integers(min_value=0), k=st.integers(min_value=0, max_value=L * 64))
@settings(max_examples=60, deadline=None)
def test_mask_bits(bits, a, k):
    k = min(k, bits * L)
    W_ = 1 << (bits * L)
    a = a % W_
    m = B.mask_bits(_to(a, bits)[None], k)[0]
    assert limbs_to_int(np.asarray(m), bits) == a % (1 << k)


@pytest.mark.parametrize("bits", [32, 64])
@given(a=st.integers(min_value=0), b=st.integers(min_value=0))
@settings(max_examples=60, deadline=None)
def test_compare_ge(bits, a, b):
    W_ = 1 << (bits * L)
    a, b = a % W_, b % W_
    ge = B.compare_ge(_to(a, bits)[None], _to(b, bits)[None])[0]
    assert bool(ge) == (a >= b)


@pytest.mark.parametrize("bits", [32, 64])
@given(v=st.integers(min_value=-2**180, max_value=2**180),
       s=st.integers(min_value=1, max_value=150))
@settings(max_examples=80, deadline=None)
def test_shift_right_round_signed(bits, v, s):
    """round-half-up(v / 2^s) on two's complement matches python."""
    W_ = 1 << (bits * L)
    if abs(v) >= W_ // 4:
        v %= (W_ // 4)
    enc = v % W_
    out = B.shift_right_round(_to(enc, bits)[None], s)[0]
    got = limbs_to_int(np.asarray(out), bits)
    # interpret as signed
    if got >= W_ // 2:
        got -= W_
    expect = (v + (1 << (s - 1))) >> s   # floor((v+half)/2^s) = round-half-up
    assert got == expect, (v, s, got, expect)


@pytest.mark.parametrize("bits", [32, 64])
@given(a=st.integers(min_value=0), w=st.integers(min_value=0))
@settings(max_examples=60, deadline=None)
def test_mul_word(bits, a, w):
    W_ = 1 << (bits * L)
    a = a % W_
    w = w % (1 << bits)
    dt = jnp.uint32 if bits == 32 else jnp.uint64
    out = B.mul_word(_to(a, bits)[None], jnp.asarray([w], dt))[0]
    assert limbs_to_int(np.asarray(out), bits) == (a * w) % W_


@pytest.mark.parametrize("bits", [32, 64])
def test_neg_and_sign(bits):
    W_ = 1 << (bits * L)
    for v in [0, 1, 12345, W_ // 2 - 1, W_ // 2, W_ - 1]:
        n = B.neg(_to(v, bits)[None])[0]
        assert limbs_to_int(np.asarray(n), bits) == (-v) % W_
        assert bool(B.sign_bit(_to(v, bits)[None])[0]) == (v >= W_ // 2)


@pytest.mark.parametrize("bits", [32, 64])
@given(a=st.integers(min_value=0), s=st.integers(min_value=0, max_value=200))
@settings(max_examples=60, deadline=None)
def test_shift_left(bits, a, s):
    W_ = 1 << (bits * L)
    a = a % W_
    out = B.shift_left_bits(_to(a, bits)[None], s)[0]
    assert limbs_to_int(np.asarray(out), bits) == (a << s) % W_
