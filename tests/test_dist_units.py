"""Single-process unit tests for repro.dist (no 8-device subprocess).

The multi-device behaviours live in tests/test_dist.py; these catch
regressions in the table pytrees, spec builders, sharding rule engines,
pipeline numerics, and compressed collectives on whatever devices the
test process already has.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.context import make_context
from repro.core.keys import keygen
from repro.dist import he_pipeline as hp
from repro.dist.collectives import compressed_psum_grads
from repro.dist.sharding import (
    batch_spec, cache_sharding_rules, he_limb_sharding,
    param_sharding_rules, zero1_opt_sharding,
)

PARAMS = small_params(logN=4, beta_bits=32)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


# --------------------------------------------------------------------------
# table pytrees and abstract specs
# --------------------------------------------------------------------------

def test_region_tables_match_table_specs():
    """region_tables/evk_tables produce exactly the pytree he_table_specs
    promises — shapes, dtypes, and key sets (the dry-run lowers against
    the specs, the runtime feeds the tables; they must agree)."""
    st = hp.he_static(PARAMS, PARAMS.logQ)
    ctx = make_context(PARAMS, PARAMS.logQ)
    t1s, t2s, eks = hp.he_table_specs(st)
    for region, spec in ((1, t1s), (2, t2s)):
        tabs = hp.region_tables(ctx, region)
        assert set(tabs) == set(spec) == set(hp.REGION_TABLE_KEYS)
        for k in tabs:
            assert tabs[k].shape == spec[k].shape, (region, k)
            assert tabs[k].dtype == spec[k].dtype, (region, k)
    _, _, evk = keygen(PARAMS, seed=0)
    ek = hp.evk_tables(evk)
    assert set(ek) == set(eks) == set(hp.EVK_TABLE_KEYS)
    for k in ek:
        assert ek[k].shape == eks[k].shape
        assert ek[k].dtype == eks[k].dtype


def test_he_static_region_sizes():
    st = hp.he_static(PARAMS, PARAMS.logQ)
    # region 2 covers log q + 2 log Q bits vs region 1's 2 log q: more primes
    assert st.np2 > st.np1 >= 1
    assert st.np2_max == st.np2            # top level
    assert st.qlimbs == PARAMS.qlimbs(PARAMS.logQ)
    assert st.ks_limbs > st.qlimbs
    assert st.icrt1.np_count == st.np1
    assert st.icrt2.np_count == st.np2


def test_input_specs_shapes():
    st = hp.he_static(PARAMS, PARAMS.logQ)
    specs = hp.he_input_specs(st, batch=6)
    assert len(specs) == 4
    for s in specs:
        assert s.shape == (6, PARAMS.N, st.qlimbs)
        assert s.dtype == np.uint32


# --------------------------------------------------------------------------
# pipeline numerics on a trivial mesh
# --------------------------------------------------------------------------

def test_sharded_he_mul_bitwise_on_one_device():
    """make_he_mul_step == core.heaan.he_mul, bitwise, on a (1,1) mesh.

    The 8-device version lives in tests/test_dist.py; this in-process
    check catches numerics regressions without the subprocess harness.
    """
    params = small_params(logN=4, beta_bits=32)
    sk, pk, evk = keygen(params, seed=3)
    rng = np.random.default_rng(5)
    B = 2
    cts = []
    for i in range(2 * B):
        z = rng.normal(size=4) + 1j * rng.normal(size=4)
        cts.append(H.encrypt_message(z, pk, params, seed=20 + i))
    ref = [H.he_mul(cts[2 * i], cts[2 * i + 1], evk, params)
           for i in range(B)]

    mesh = _mesh11()
    st = hp.he_static(params, params.logQ)
    ctx = make_context(params, params.logQ)
    t1, t2, ek = hp.runtime_tables(ctx, evk)
    sh = he_limb_sharding(mesh, batch=B)
    args = [jax.device_put(jnp.stack(x), sh) for x in (
        [c.ax for c in cts[0::2]], [c.bx for c in cts[0::2]],
        [c.ax for c in cts[1::2]], [c.bx for c in cts[1::2]])]
    step = jax.jit(hp.make_he_mul_step(st, mesh))
    ax3, bx3 = step(t1, t2, ek, *args)
    for i in range(B):
        np.testing.assert_array_equal(np.asarray(ax3[i]),
                                      np.asarray(ref[i].ax))
        np.testing.assert_array_equal(np.asarray(bx3[i]),
                                      np.asarray(ref[i].bx))


# --------------------------------------------------------------------------
# compressed collectives on a 1-device mesh
# --------------------------------------------------------------------------

def test_compressed_psum_grads_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 130)).astype(np.float32))

    def local(g, key):
        return compressed_psum_grads({"w": g[0]}, ("data",),
                                     key[0])["w"][None]

    fn = shard_map(local, mesh=mesh, in_specs=(P("data"), P()),
                   out_specs=P("data"), check_rep=False)
    out = fn(g[None], jax.random.split(jax.random.key(0), 1))[0]
    scale = np.abs(np.asarray(g)).max() / 127.0
    # world of 1: the "mean" is just quantize→dequantize of g itself
    assert np.abs(np.asarray(out) - np.asarray(g)).max() <= 1.5 * scale


def test_trainer_compress_dp_runs_and_replays_bit_identical(tmp_path):
    """The --compress-dp Trainer path (shard_map over "data" with
    compressed_psum_grads, per-step fold_in quantization key) trains,
    and two runs from the same seed produce bit-identical params — the
    determinism the fault-tolerance replay contract needs."""
    from repro.configs.registry import get_arch
    from repro.launch.train import TrainConfig, Trainer

    cfg = get_arch("llama3.2-1b").reduced()
    tc = TrainConfig(batch=2, seq_len=16, steps=3, ckpt_every=1000)

    def run():
        tr = Trainer(cfg, tc, compress_dp=True)
        out = tr.run()
        return tr.params, out["history"]

    p1, h1 = run()
    p2, h2 = run()
    assert len(h1) == 3 and np.isfinite(h1[-1]["loss"])
    assert [h["loss"] for h in h1] == [h["loss"] for h in h2]
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_psum_preserves_structure_and_dtype():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"a": jnp.ones((3, 7), jnp.float32),
            "b": {"c": jnp.full((300,), 0.25, jnp.float32)}}

    def local(t, key):
        return compressed_psum_grads(t, ("data",), key)

    fn = shard_map(local, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    out = fn(tree, jax.random.key(1))
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype


# --------------------------------------------------------------------------
# sharding rule engines (placement logic only — no multi-device needed)
# --------------------------------------------------------------------------

def test_param_rules_orientation():
    from repro.configs.registry import get_arch
    from repro.models import init_params
    cfg = get_arch("llama3.2-1b").reduced(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512)
    mesh = _mesh11()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.key(0))
    sh = param_sharding_rules(params, mesh)
    flat = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): s.spec
            for path, s in jax.tree_util.tree_flatten_with_path(sh)[0]}
    # column-parallel: output dim on model; row-parallel: input dim
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq/w"))
    wo = next(v for k, v in flat.items() if k.endswith("attn/wo/w"))
    assert wq[-1] == "model" and wq[0] == "data"
    assert wo[0] == "model"
    emb = flat["tok_embed"]
    assert emb[0] == "model"
    # norms replicate
    ln = next(v for k, v in flat.items() if k.endswith("ln_f/scale"))
    assert all(a is None for a in ln)


def test_model_dim_orientation_helper():
    """Name-tagged orientation: column-parallel shards the output dim,
    row-parallel the input dim, embeddings the vocab dim; unknown ≥2-d
    leaves fall back to their largest dim; vectors are never sharded."""
    from repro.dist.sharding import _model_dim
    assert _model_dim(["layers", "attn", "wq", "w"], (64, 64)) == 1
    assert _model_dim(["layers", "attn", "wo", "w"], (64, 64)) == 0
    assert _model_dim(["tok_embed"], (512, 64)) == 0
    assert _model_dim(["moe", "wi"], (8, 64, 128)) == 2    # expert stacks
    assert _model_dim(["moe", "wo"], (8, 128, 64)) == 1
    assert _model_dim(["ssm", "A_log"], (128, 16)) == 0    # largest-dim
    assert _model_dim(["ln_f", "scale"], (64,)) is None


def test_cache_rules_batch_dim_offset():
    mesh = _mesh11()
    cache = {
        "stacked": {"k": jnp.zeros((2, 8, 16, 4, 32))},   # (L, B, S, H, hd)
        "list": [{"k": jnp.zeros((8, 16, 4, 32))}],       # (B, S, H, hd)
    }
    sh = cache_sharding_rules(cache, mesh)
    assert sh["stacked"]["k"].spec[1] in ("data", None)
    assert sh["stacked"]["k"].spec[0] is None              # layer axis local
    assert sh["list"][0]["k"].spec[0] in ("data", None)


def test_zero1_adds_data_axis():
    mesh = _mesh11()
    params = {"w": jnp.ones((4, 6))}
    p_sh = param_sharding_rules(params, mesh, fsdp_params=False)
    assert "data" not in p_sh["w"].spec          # params: model-parallel only
    m_sh = zero1_opt_sharding(p_sh, params, mesh)
    assert jax.tree.structure(m_sh) == jax.tree.structure(p_sh)
    spec = m_sh["w"].spec
    assert "data" in spec                        # moments gained the DP shard
    assert "model" in spec                       # and kept the param sharding


def test_batch_and_limb_specs():
    mesh = _mesh11()
    assert batch_spec(mesh).spec == P(("data",))
    assert he_limb_sharding(mesh).spec == P(("data",))
    # indivisible batch falls back to replicated
    sh = he_limb_sharding(mesh, batch=3)
    assert sh.spec == P(("data",)) or sh.is_fully_replicated


def test_he_limb_sharding_rejects_odd_batch_on_wide_mesh():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device to exercise the divisibility check")
    mesh = jax.make_mesh((2, len(devs) // 2), ("data", "model"))
    assert he_limb_sharding(mesh, batch=3).is_fully_replicated
