"""Dry-run analysis plumbing: HLO collective parser + roofline math.

Imports repro.launch.hlo_analysis (NOT dryrun, whose import sets XLA_FLAGS
for 512 placeholder devices — a side effect no test process wants).
"""

import repro.core  # noqa: F401
from repro.launch.hlo_analysis import collective_bytes_from_hlo
from benchmarks.roofline import analyze_record, model_flops


# modern HLO style: operands are SSA refs without inline shapes
HLO = """
  %all-reduce.5 = f32[512,1024]{1,0} all-reduce(%add.3), replica_groups={{0,1},{2,3}}
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups=[8,16]<=[128], dimensions={0}
  %rs.1 = f32[16]{0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %ar2 = f32[4]{0} all-reduce-start(%z), replica_groups={{0,1}}
  %ar2d = f32[4]{0} all-reduce-done(%ar2)
  %a2a = u32[2,2]{1,0} all-to-all(%v), replica_groups={{0,1,2,3}}
  %not = f32[9]{0} add(%a, %b)
"""


def test_collective_parser_counts_and_ring_bytes():
    r = collective_bytes_from_hlo(HLO)
    assert r["counts"] == {"all-reduce": 2, "all-gather": 1,
                           "reduce-scatter": 1, "all-to-all": 1,
                           "collective-permute": 1}
    S_ar = 512 * 1024 * 4
    assert r["bytes"]["all-reduce"] == 2 * S_ar * (2 - 1) / 2 + 2 * 16 * 0.5
    assert r["bytes"]["all-gather"] == 64 * 128 * 2 * 15 / 16
    assert r["bytes"]["reduce-scatter"] == 16 * 4 * 3    # S_out·(g-1)
    assert r["bytes"]["all-to-all"] == 2 * 2 * 4 * 3 / 4
    assert r["bytes"]["collective-permute"] == 8 * 8 * 4
    assert r["total_bytes"] == sum(r["bytes"].values())


def test_collective_parser_ignores_done_and_noncollectives():
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce-done(%y), replica_groups={{0,1}}")
    assert r["total_bytes"] == 0
    r = collective_bytes_from_hlo("%x = f32[4]{0} reduce(%y)")
    assert r["total_bytes"] == 0
    # group of 1 (degenerate) moves nothing
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce(%y), replica_groups={{0}}")
    assert r["total_bytes"] == 0


def test_roofline_terms_and_bottleneck():
    rec = {
        "cell": "llama3.2-1b/train_4k", "mesh": "pod16x16", "ok": True,
        "analysis": {
            "flops": 1.97e12,                 # exactly 10 ms of compute
            "bytes_accessed": 819e9 * 0.02,   # 20 ms of HBM
            "collectives": {"total_bytes": 50e9 * 0.001},
            "corrected": {},
        },
    }
    r = analyze_record(rec)
    assert abs(r["compute_s"] - 0.01) < 1e-9
    assert abs(r["memory_s"] - 0.02) < 1e-9
    assert abs(r["collective_s"] - 0.001) < 1e-9
    assert r["bottleneck"] == "memory"
    assert r["model_over_hlo"] is not None


def test_model_flops_formulas():
    # train: 6·N_active·tokens; decode: 2·N_active·tokens
    assert model_flops("llama3.2-1b", "train_4k") == \
        6.0 * 1.24e9 * 4096 * 256
    assert model_flops("kimi-k2-1t-a32b", "decode_32k") == \
        2.0 * 32.6e9 * 128
    assert model_flops("unknown-arch", "train_4k") is None
