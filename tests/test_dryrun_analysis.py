"""Dry-run analysis plumbing: HLO collective parser + roofline math.

Imports repro.launch.hlo_analysis (NOT dryrun, whose import sets XLA_FLAGS
for 512 placeholder devices — a side effect no test process wants).
"""

import pytest

import repro.core  # noqa: F401
from repro.launch.hlo_analysis import (collective_bytes_from_hlo,
                                       count_fusions, parse_replica_groups)
from benchmarks.roofline import analyze_record, model_flops


# modern HLO style: operands are SSA refs without inline shapes
HLO = """
  %all-reduce.5 = f32[512,1024]{1,0} all-reduce(%add.3), replica_groups={{0,1},{2,3}}
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups=[8,16]<=[128], dimensions={0}
  %rs.1 = f32[16]{0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %ar2 = f32[4]{0} all-reduce-start(%z), replica_groups={{0,1}}
  %ar2d = f32[4]{0} all-reduce-done(%ar2)
  %a2a = u32[2,2]{1,0} all-to-all(%v), replica_groups={{0,1,2,3}}
  %not = f32[9]{0} add(%a, %b)
"""


def test_collective_parser_counts_and_ring_bytes():
    r = collective_bytes_from_hlo(HLO)
    assert r["counts"] == {"all-reduce": 2, "all-gather": 1,
                           "reduce-scatter": 1, "all-to-all": 1,
                           "collective-permute": 1}
    S_ar = 512 * 1024 * 4
    assert r["bytes"]["all-reduce"] == 2 * S_ar * (2 - 1) / 2 + 2 * 16 * 0.5
    assert r["bytes"]["all-gather"] == 64 * 128 * 2 * 15 / 16
    assert r["bytes"]["reduce-scatter"] == 16 * 4 * 3    # S_out·(g-1)
    assert r["bytes"]["all-to-all"] == 2 * 2 * 4 * 3 / 4
    assert r["bytes"]["collective-permute"] == 8 * 8 * 4
    assert r["total_bytes"] == sum(r["bytes"].values())


def test_parse_replica_groups_literal_and_empty():
    g, s = parse_replica_groups("replica_groups={{0,1},{2,3}}")
    assert g == [(0, 1), (2, 3)] and s == 2
    g, s = parse_replica_groups("replica_groups={{0,1,2,3},{4,5,6,7}}")
    assert g == [(0, 1, 2, 3), (4, 5, 6, 7)] and s == 4
    # empty form = one group of every participant; size falls back to
    # the program's device count when the caller knows it
    g, s = parse_replica_groups("replica_groups={}")
    assert g is None and s == 1
    g, s = parse_replica_groups("replica_groups={}", default_group_size=8)
    assert g is None and s == 8
    # no replica_groups attribute at all (collective-permute lines)
    g, s = parse_replica_groups("source_target_pairs={{0,1}}")
    assert g is None and s == 1


def test_parse_replica_groups_iota_forms():
    # [G,S]<=[N]: iota(8) reshaped (2,4) — contiguous groups
    g, s = parse_replica_groups("replica_groups=[2,4]<=[8]")
    assert s == 4
    assert g == [(0, 1, 2, 3), (4, 5, 6, 7)]
    # transposed iota: groups are the COLUMNS of iota(8)->(2,4) — this
    # is what GSPMD emits for the model axis of a ("data","model") mesh
    g, s = parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    assert s == 2
    assert g == [(0, 4), (1, 5), (2, 6), (3, 7)]
    # identity transpose == plain iota
    g, s = parse_replica_groups("replica_groups=[2,4]<=[2,4]T(0,1)")
    assert g == [(0, 1, 2, 3), (4, 5, 6, 7)] and s == 4
    # inconsistent dims (product mismatch): size still parsed, no groups
    g, s = parse_replica_groups("replica_groups=[2,4]<=[4]")
    assert g is None and s == 4


def test_collective_parser_iota_group_wire_bytes():
    # ring bytes must use the iota group SIZE (4), not the device total
    r = collective_bytes_from_hlo(
        "%ar = f32[8,8]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8]")
    assert r["counts"]["all-reduce"] == 1
    assert r["bytes"]["all-reduce"] == 2 * (8 * 8 * 4) * 3 / 4
    (rec,) = r["ops"]
    assert rec["group_size"] == 4 and rec["n_groups"] == 2
    assert rec["groups"] == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_collective_parser_async_tuple_output_half():
    # an all-gather-start tuple is (operands..., outputs...): only the
    # output half is sized, and the -done line adds nothing
    hlo = """
      %ags = (f32[8,16]{1,0}, f32[16,16]{1,0}) all-gather-start(%p), replica_groups={{0,1}}
      %agd = f32[16,16]{1,0} all-gather-done(%ags)
    """
    r = collective_bytes_from_hlo(hlo)
    assert r["counts"] == {"all-reduce": 0, "all-gather": 1,
                           "reduce-scatter": 0, "all-to-all": 0,
                           "collective-permute": 0}
    (rec,) = r["ops"]
    assert rec["async"] and rec["size_bytes"] == 16 * 16 * 4
    assert r["bytes"]["all-gather"] == 16 * 16 * 4 * (2 - 1) / 2


def test_collective_parser_unknown_dtype_still_counted():
    r = collective_bytes_from_hlo(
        "%x = u4[64]{0} all-reduce(%y), replica_groups={{0,1}}")
    assert r["counts"]["all-reduce"] == 1       # schedule still visible
    assert r["total_bytes"] == 0.0              # but no sizing guess


def test_count_fusions():
    hlo = """
      %fused_computation { %p0 = f32[4]{0} parameter(0) }
      %f.1 = f32[4]{0} fusion(%a), kind=kLoop, calls=%fused_computation
      %f.2 = (f32[4]{0}, f32[4]{0}) fusion(%a, %b), kind=kOutput
      %add = f32[4]{0} add(%a, %b)
    """
    assert count_fusions(hlo) == 2
    assert count_fusions("%x = f32[4]{0} add(%a, %b)") == 0


def test_collective_parser_ignores_done_and_noncollectives():
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce-done(%y), replica_groups={{0,1}}")
    assert r["total_bytes"] == 0
    r = collective_bytes_from_hlo("%x = f32[4]{0} reduce(%y)")
    assert r["total_bytes"] == 0
    # group of 1 (degenerate) moves nothing
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce(%y), replica_groups={{0}}")
    assert r["total_bytes"] == 0


def test_roofline_terms_and_bottleneck():
    rec = {
        "cell": "llama3.2-1b/train_4k", "mesh": "pod16x16", "ok": True,
        "analysis": {
            "flops": 1.97e12,                 # exactly 10 ms of compute
            "bytes_accessed": 819e9 * 0.02,   # 20 ms of HBM
            "collectives": {"total_bytes": 50e9 * 0.001},
            "corrected": {},
        },
    }
    r = analyze_record(rec)
    assert abs(r["compute_s"] - 0.01) < 1e-9
    assert abs(r["memory_s"] - 0.02) < 1e-9
    assert abs(r["collective_s"] - 0.001) < 1e-9
    assert r["bottleneck"] == "memory"
    assert r["model_over_hlo"] is not None


def test_model_flops_formulas():
    # train: 6·N_active·tokens; decode: 2·N_active·tokens
    assert model_flops("llama3.2-1b", "train_4k") == \
        6.0 * 1.24e9 * 4096 * 256
    assert model_flops("kimi-k2-1t-a32b", "decode_32k") == \
        2.0 * 32.6e9 * 128
    assert model_flops("unknown-arch", "train_4k") is None


# --------------------------------------------------------------------------
# hserve serving steps: abstract-table lowering + collective analysis
# (the dryrun --he serving cells, exercised in-process at test params —
# launch.dryrun itself is never imported here, its import sets XLA_FLAGS)
# --------------------------------------------------------------------------

def _serving_lowered(op: str, batch: int = 2, logq=None):
    import jax

    from repro.core.params import test_params
    from repro.launch.cells import lower_he_serving_cell

    params = test_params(logN=4, beta_bits=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return lower_he_serving_cell(op, batch, mesh, logq=logq, params=params)


def _full_op_table():
    from repro.launch.cells import HE_SERVING_OPS
    return HE_SERVING_OPS


@pytest.mark.parametrize("op", _full_op_table())
def test_serving_steps_lower_with_abstract_tables(op):
    """EVERY op in the served table (`analysis.dataflow.OPS` — mul, add,
    sub, rotate, conjugate, slot_sum, rescale, mod_down, mul_plain,
    add_plain) lowers + compiles from he_table_specs alone and produces a
    full analysis record, so no served op can dodge dry-run/shardlint
    coverage."""
    from repro.launch.hlo_analysis import analyze_compiled

    lowered = _serving_lowered(op)
    rec = analyze_compiled(lowered, lowered.compile(), 0.0)
    assert set(rec) >= {"flops", "bytes_accessed", "collectives",
                        "memory", "fusions", "compile_seconds"}, op
    assert rec["collectives"]["counts"] is not None, op
    # single-device mesh: nothing should hit the wire
    assert rec["collectives"]["total_bytes"] == 0.0, op


def test_serving_op_table_matches_dataflow_and_levels_filter():
    """The lowering table is generated FROM the analysis dataflow op set
    (a newly served op cannot dodge coverage), and level filtering only
    trims the level-consuming ops at the chain bottom and the
    level-raising mod_raise at the chain top."""
    from repro.analysis.dataflow import OPS, PLAIN_OPS
    from repro.core.params import test_params
    from repro.launch.cells import HE_SERVING_OPS, serving_op_levels

    assert set(HE_SERVING_OPS) == set(OPS)
    assert set(PLAIN_OPS) <= set(HE_SERVING_OPS)
    params = test_params(logN=4, beta_bits=32)
    levels = (params.logQ, 3 * params.logp, params.logp)
    for op in HE_SERVING_OPS:
        got = serving_op_levels(op, levels, params)
        if op in ("rescale", "mod_down"):
            assert got == [lq for lq in levels if lq >= 2 * params.logp], op
        elif op == "mod_raise":
            assert got == [lq for lq in levels
                           if lq + params.logp <= params.logQ], op
        else:
            assert got == list(levels), op
    with pytest.raises(ValueError, match="unknown serving op"):
        _serving_lowered("bootstrap")


def test_plain_ops_have_no_keyswitch_collectives_and_cost_less():
    """The plaintext-operand ops' acceptance claim, checked on real HLO:
    neither carries ANY collective bytes (no region-2 key switch —
    rotate, by contrast, pays the full key-switch chain), add_plain is a
    bare limb add (orders of magnitude below the NTT ops), and
    mul_plain's region-1-only FLOPs stay well under rotate's region-2
    pipeline."""
    from repro.launch.hlo_analysis import (
        analyze_compiled, collective_bytes_from_hlo,
    )

    recs = {}
    for op in ("rotate", "mul_plain", "add_plain"):
        lowered = _serving_lowered(op)
        recs[op] = analyze_compiled(lowered, lowered.compile(), 0.0)
        # the parser on the pre-partitioning HLO text as well
        assert collective_bytes_from_hlo(
            lowered.as_text())["total_bytes"] == 0.0 \
            or op == "rotate", op
    for op in ("mul_plain", "add_plain"):
        assert recs[op]["collectives"]["total_bytes"] == 0.0, op
        assert not any(recs[op]["collectives"]["counts"].values()), op
    if recs["rotate"]["flops"] and recs["mul_plain"]["flops"]:
        assert recs["mul_plain"]["flops"] < recs["rotate"]["flops"]
    if recs["mul_plain"]["flops"] and recs["add_plain"]["flops"]:
        assert recs["add_plain"]["flops"] < recs["mul_plain"]["flops"] / 10


def test_rescale_step_has_no_collectives_and_fewer_flops():
    """Rescale is a pure limb shift — no NTT, no key switch: its HLO
    must contain zero collectives and cost far less than a rotate (the
    docs/ARCHITECTURE.md dataflow-table claim, checked on real HLO)."""
    from repro.launch.hlo_analysis import (
        analyze_compiled, collective_bytes_from_hlo,
    )

    rot = _serving_lowered("rotate")
    res = _serving_lowered("rescale")
    rec_rot = analyze_compiled(rot, rot.compile(), 0.0)
    rec_res = analyze_compiled(res, res.compile(), 0.0)
    # collective parser on the pre-partitioning HLO text as well
    assert collective_bytes_from_hlo(res.as_text())["total_bytes"] == 0.0
    if rec_rot["flops"] and rec_res["flops"]:
        assert rec_res["flops"] < rec_rot["flops"] / 10
