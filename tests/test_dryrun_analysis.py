"""Dry-run analysis plumbing: HLO collective parser + roofline math.

Imports repro.launch.hlo_analysis (NOT dryrun, whose import sets XLA_FLAGS
for 512 placeholder devices — a side effect no test process wants).
"""

import repro.core  # noqa: F401
from repro.launch.hlo_analysis import collective_bytes_from_hlo
from benchmarks.roofline import analyze_record, model_flops


# modern HLO style: operands are SSA refs without inline shapes
HLO = """
  %all-reduce.5 = f32[512,1024]{1,0} all-reduce(%add.3), replica_groups={{0,1},{2,3}}
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups=[8,16]<=[128], dimensions={0}
  %rs.1 = f32[16]{0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %ar2 = f32[4]{0} all-reduce-start(%z), replica_groups={{0,1}}
  %ar2d = f32[4]{0} all-reduce-done(%ar2)
  %a2a = u32[2,2]{1,0} all-to-all(%v), replica_groups={{0,1,2,3}}
  %not = f32[9]{0} add(%a, %b)
"""


def test_collective_parser_counts_and_ring_bytes():
    r = collective_bytes_from_hlo(HLO)
    assert r["counts"] == {"all-reduce": 2, "all-gather": 1,
                           "reduce-scatter": 1, "all-to-all": 1,
                           "collective-permute": 1}
    S_ar = 512 * 1024 * 4
    assert r["bytes"]["all-reduce"] == 2 * S_ar * (2 - 1) / 2 + 2 * 16 * 0.5
    assert r["bytes"]["all-gather"] == 64 * 128 * 2 * 15 / 16
    assert r["bytes"]["reduce-scatter"] == 16 * 4 * 3    # S_out·(g-1)
    assert r["bytes"]["all-to-all"] == 2 * 2 * 4 * 3 / 4
    assert r["bytes"]["collective-permute"] == 8 * 8 * 4
    assert r["total_bytes"] == sum(r["bytes"].values())


def test_collective_parser_ignores_done_and_noncollectives():
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce-done(%y), replica_groups={{0,1}}")
    assert r["total_bytes"] == 0
    r = collective_bytes_from_hlo("%x = f32[4]{0} reduce(%y)")
    assert r["total_bytes"] == 0
    # group of 1 (degenerate) moves nothing
    r = collective_bytes_from_hlo(
        "%x = f32[4]{0} all-reduce(%y), replica_groups={{0}}")
    assert r["total_bytes"] == 0


def test_roofline_terms_and_bottleneck():
    rec = {
        "cell": "llama3.2-1b/train_4k", "mesh": "pod16x16", "ok": True,
        "analysis": {
            "flops": 1.97e12,                 # exactly 10 ms of compute
            "bytes_accessed": 819e9 * 0.02,   # 20 ms of HBM
            "collectives": {"total_bytes": 50e9 * 0.001},
            "corrected": {},
        },
    }
    r = analyze_record(rec)
    assert abs(r["compute_s"] - 0.01) < 1e-9
    assert abs(r["memory_s"] - 0.02) < 1e-9
    assert abs(r["collective_s"] - 0.001) < 1e-9
    assert r["bottleneck"] == "memory"
    assert r["model_over_hlo"] is not None


def test_model_flops_formulas():
    # train: 6·N_active·tokens; decode: 2·N_active·tokens
    assert model_flops("llama3.2-1b", "train_4k") == \
        6.0 * 1.24e9 * 4096 * 256
    assert model_flops("kimi-k2-1t-a32b", "decode_32k") == \
        2.0 * 32.6e9 * 128
    assert model_flops("unknown-arch", "train_4k") is None


# --------------------------------------------------------------------------
# hserve serving steps: abstract-table lowering + collective analysis
# (the dryrun --he serving cells, exercised in-process at test params —
# launch.dryrun itself is never imported here, its import sets XLA_FLAGS)
# --------------------------------------------------------------------------

def _serving_lowered(op: str, batch: int = 2):
    import jax

    from repro.core.params import test_params
    from repro.core.rotate import rotation_k
    from repro.dist import he_pipeline as hp
    from repro.dist.sharding import he_limb_sharding
    from repro.hserve.engine import (
        make_add_plain_step, make_he_rotate_step, make_mul_plain_step,
        make_rescale_step, make_slot_sum_step, slot_sum_rotations,
    )

    params = test_params(logN=4, beta_bits=32)
    st = hp.he_static(params, params.logQ)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t1, t2, ek = hp.he_table_specs(st)        # abstract tables: no twiddle
    ct_sh = he_limb_sharding(mesh, batch=batch)     # build, pure specs
    ct = jax.ShapeDtypeStruct((batch, st.N, st.qlimbs), st.dtype,
                              sharding=ct_sh)
    if op == "rotate":
        step = make_he_rotate_step(st, mesh, rotation_k(params, 1))
        return jax.jit(step).lower(t2, ek, ct, ct)
    if op == "slot_sum":
        n = params.n_slots_max
        step = make_slot_sum_step(st, mesh, n)
        rks = tuple(ek for _ in slot_sum_rotations(n))
        return jax.jit(step).lower(t2, rks, ct, ct)
    if op == "rescale":
        step = make_rescale_step(st, mesh, params.logp)
        return jax.jit(step).lower(ct, ct)
    if op == "mul_plain":
        step = make_mul_plain_step(st, mesh)
        return jax.jit(step).lower(t1, ct, ct, ct)
    if op == "add_plain":
        step = make_add_plain_step(st, mesh)
        return jax.jit(step).lower(ct, ct, ct)
    raise ValueError(op)


def test_serving_steps_lower_with_abstract_tables():
    """rotate / slot_sum / rescale / mul_plain / add_plain lower +
    compile from he_table_specs alone and produce a full analysis record
    (the dryrun --he serving cells' contract)."""
    from repro.launch.hlo_analysis import analyze_compiled

    for op in ("rotate", "slot_sum", "rescale", "mul_plain", "add_plain"):
        lowered = _serving_lowered(op)
        rec = analyze_compiled(lowered, lowered.compile(), 0.0)
        assert set(rec) >= {"flops", "bytes_accessed", "collectives",
                            "memory", "compile_seconds"}, op
        assert rec["collectives"]["counts"] is not None, op
        # single-device mesh: nothing should hit the wire
        assert rec["collectives"]["total_bytes"] == 0.0, op


def test_plain_ops_have_no_keyswitch_collectives_and_cost_less():
    """The plaintext-operand ops' acceptance claim, checked on real HLO:
    neither carries ANY collective bytes (no region-2 key switch —
    rotate, by contrast, pays the full key-switch chain), add_plain is a
    bare limb add (orders of magnitude below the NTT ops), and
    mul_plain's region-1-only FLOPs stay well under rotate's region-2
    pipeline."""
    from repro.launch.hlo_analysis import (
        analyze_compiled, collective_bytes_from_hlo,
    )

    recs = {}
    for op in ("rotate", "mul_plain", "add_plain"):
        lowered = _serving_lowered(op)
        recs[op] = analyze_compiled(lowered, lowered.compile(), 0.0)
        # the parser on the pre-partitioning HLO text as well
        assert collective_bytes_from_hlo(
            lowered.as_text())["total_bytes"] == 0.0 \
            or op == "rotate", op
    for op in ("mul_plain", "add_plain"):
        assert recs[op]["collectives"]["total_bytes"] == 0.0, op
        assert not any(recs[op]["collectives"]["counts"].values()), op
    if recs["rotate"]["flops"] and recs["mul_plain"]["flops"]:
        assert recs["mul_plain"]["flops"] < recs["rotate"]["flops"]
    if recs["mul_plain"]["flops"] and recs["add_plain"]["flops"]:
        assert recs["add_plain"]["flops"] < recs["mul_plain"]["flops"] / 10


def test_rescale_step_has_no_collectives_and_fewer_flops():
    """Rescale is a pure limb shift — no NTT, no key switch: its HLO
    must contain zero collectives and cost far less than a rotate (the
    docs/ARCHITECTURE.md dataflow-table claim, checked on real HLO)."""
    from repro.launch.hlo_analysis import (
        analyze_compiled, collective_bytes_from_hlo,
    )

    rot = _serving_lowered("rotate")
    res = _serving_lowered("rescale")
    rec_rot = analyze_compiled(rot, rot.compile(), 0.0)
    rec_res = analyze_compiled(res, res.compile(), 0.0)
    # collective parser on the pre-partitioning HLO text as well
    assert collective_bytes_from_hlo(res.as_text())["total_bytes"] == 0.0
    if rec_rot["flops"] and rec_res["flops"]:
        assert rec_res["flops"] < rec_rot["flops"] / 10
