"""Shared pytest fixtures for the repro test suite.

The 8-device subprocess harness lives here: several suites
(test_dist, test_hserve, test_client, test_obs, test_multihost) verify
sharded serving on a forced (2, 4) CPU mesh, and XLA fixes its device
count at import time — once `jax` is imported in the pytest process,
no in-process test can change it. Each such test therefore runs its
body in a FRESH interpreter with
``--xla_force_host_platform_device_count=8`` set before the first jax
import, and reports results as one JSON line on stdout.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# imported before the test body, AFTER forcing the device count; the
# union of what every migrated suite's preamble used to import
_PREAMBLE = """
    import os
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro.core
"""


def run_in_8dev_subprocess(body: str, timeout: int = 900) -> dict:
    """Run `body` in a fresh python with 8 forced XLA host devices.

    The body must end by printing ONE json document (its last stdout
    line is parsed and returned). Raises via assert on a non-zero exit,
    with the subprocess stderr tail in the message.
    """
    code = textwrap.dedent(_PREAMBLE) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.fixture(name="run_in_8dev_subprocess")
def run_in_8dev_subprocess_fixture():
    """The harness as a fixture, so tests take it as an argument
    instead of importing from conftest (which shadows easily)."""
    return run_in_8dev_subprocess
