"""Fault tolerance: crash/restart bitwise-identity, straggler flags,
checkpoint atomicity + GC + elastic reshard."""

import os

import numpy as np

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.ckpt import CheckpointManager
from repro.configs.registry import get_arch
from repro.launch.train import TrainConfig, Trainer, run_with_restarts
from repro.runtime import FailureInjector, StepMonitor


def _cfg():
    return get_arch("llama3.2-1b").reduced(n_layers=2, d_model=64,
                                           n_heads=2, n_kv_heads=2,
                                           head_dim=32, d_ff=128,
                                           vocab_size=256)


def _tc(**kw):
    base = dict(batch=2, seq_len=16, steps=8, ckpt_every=2, warmup_steps=2)
    base.update(kw)
    return TrainConfig(**base)


def test_crash_restart_bitwise_identical(tmp_path):
    cfg = _cfg()
    # uninterrupted reference run
    ref = Trainer(cfg, _tc(), ckpt_dir=str(tmp_path / "ref"))
    ref.run()

    # crashing run: dies at steps 3 and 6, restarts from latest checkpoint
    ck = str(tmp_path / "crash")
    inj = FailureInjector(fail_at_steps=[3, 6])
    trainer, out, restarts = run_with_restarts(
        lambda: Trainer(cfg, _tc(), ckpt_dir=ck, injector=inj),
        total_steps=8)
    assert restarts == 2

    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref.params)[0],
            jax.tree_util.tree_flatten_with_path(trainer.params)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ka))


def test_straggler_monitor_flags_slow_steps():
    mon = StepMonitor(slack=2.0, warmup_steps=2)
    flags = [mon.record(i, 0.1) for i in range(6)]
    assert not any(flags)
    assert mon.record(6, 0.5) is True       # 5× EMA -> breach
    assert mon.record(7, 0.1) is False      # recovery


def test_straggler_injection_is_flagged(tmp_path):
    cfg = _cfg()
    inj = FailureInjector(straggle_at_steps=[6], straggle_seconds=1.5)
    tr = Trainer(cfg, _tc(), ckpt_dir=str(tmp_path / "s"), injector=inj)
    out = tr.run()
    assert any(h["straggler"] for h in out["history"]), \
        "injected straggler step was not flagged"


def test_checkpoint_atomicity_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        m.save(s, tree, block=True)
    assert m.all_steps() == [3, 4]          # keep-2 GC
    out = m.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16
    # no stray .tmp directories (atomicity)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_reshard_restore(tmp_path):
    """Restore under a different mesh: full-array ckpt + sharding_fn."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    m.save(1, tree, block=True)
    mesh = jax.make_mesh((1,), ("data",))

    def shard(key, arr):
        return jax.device_put(arr, NamedSharding(mesh, P("data")))

    out = m.restore(1, tree, sharding_fn=shard)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding.mesh.shape["data"] == 1


def test_loss_decreases_on_synthetic_data(tmp_path):
    cfg = _cfg()
    tr = Trainer(cfg, _tc(steps=60, batch=8, seq_len=32, ckpt_every=1000,
                          warmup_steps=5, peak_lr=3e-3), ckpt_dir=None)
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    head = sum(losses[:5]) / 5
    tail = sum(losses[-5:]) / 5
    assert tail < head * 0.8, (head, tail)
