"""Galois rotation / conjugation tests (slot semantics + slot-sum app)."""

import numpy as np
import pytest

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.keys import keygen
from repro.core.rotate import (
    conj_keygen, he_conjugate, he_rotate, rot_keygen,
)


@pytest.fixture(scope="module")
def setup():
    params = small_params(logN=5, beta_bits=32)
    sk, pk, evk = keygen(params, seed=0)
    return params, sk, pk, evk


def test_rotation_rolls_slots(setup):
    params, sk, pk, _ = setup
    n = 8
    rng = np.random.default_rng(0)
    z = rng.normal(size=n) + 1j * rng.normal(size=n)
    ct = H.encrypt_message(z, pk, params, seed=1)
    for r in (1, 3):
        rk = rot_keygen(params, sk, r)
        out = H.decrypt_message(he_rotate(ct, r, rk, params), sk, params)
        expect = np.roll(z, -r)
        assert np.abs(out - expect).max() < 1e-3, r


def test_conjugation(setup):
    params, sk, pk, _ = setup
    rng = np.random.default_rng(1)
    z = rng.normal(size=8) + 1j * rng.normal(size=8)
    ct = H.encrypt_message(z, pk, params, seed=2)
    ck = conj_keygen(params, sk)
    out = H.decrypt_message(he_conjugate(ct, ck, params), sk, params)
    assert np.abs(out - np.conj(z)).max() < 1e-3


def test_slot_sum_via_log_rotations(setup):
    """Σ over slots with log₂(n) rotations — the primitive encrypted
    dot-products need (paper's logistic-regression application class)."""
    params, sk, pk, _ = setup
    n = 8
    rng = np.random.default_rng(2)
    z = rng.normal(size=n)
    ct = H.encrypt_message(z.astype(np.complex128), pk, params, seed=3)
    acc = ct
    r = 1
    while r < n:
        rk = rot_keygen(params, sk, r)
        acc = H.he_add(acc, he_rotate(acc, r, rk, params))
        r *= 2
    out = H.decrypt_message(acc, sk, params)
    # every slot now holds the total sum
    np.testing.assert_allclose(out.real, np.full(n, z.sum()), atol=1e-2)


def test_rotation_composes_with_mul(setup):
    params, sk, pk, evk = setup
    rng = np.random.default_rng(3)
    z1 = rng.normal(size=8) + 1j * rng.normal(size=8)
    z2 = rng.normal(size=8) + 1j * rng.normal(size=8)
    c1 = H.encrypt_message(z1, pk, params, seed=4)
    c2 = H.encrypt_message(z2, pk, params, seed=5)
    prod = H.rescale(H.he_mul(c1, c2, evk, params), params)
    rk = rot_keygen(params, sk, 2)
    out = H.decrypt_message(he_rotate(prod, 2, rk, params), sk, params)
    assert np.abs(out - np.roll(z1 * z2, -2)).max() < 5e-3
