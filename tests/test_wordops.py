"""Word-level modular arithmetic vs python-int oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401  (enables x64)
from repro.core import wordops as W
from repro.nt.primes import find_ntt_primes, shoup_precompute

RNG = np.random.default_rng(0)


def _rand_words(n, bits, rng=RNG):
    if bits == 32:
        return rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
    return rng.integers(0, 1 << 63, size=n, dtype=np.uint64) * 2 + \
        rng.integers(0, 2, size=n, dtype=np.uint64)


@pytest.mark.parametrize("bits", [32, 64])
def test_mul_wide_exact(bits):
    a = _rand_words(512, bits)
    b = _rand_words(512, bits)
    hi, lo = W.mul_wide(jnp.asarray(a), jnp.asarray(b))
    for i in range(len(a)):
        prod = int(a[i]) * int(b[i])
        assert int(lo[i]) == prod % (1 << bits)
        assert int(hi[i]) == prod >> bits


@pytest.mark.parametrize("bits", [32, 64])
def test_mulhi_approx3_underestimates_by_at_most_2(bits):
    a = _rand_words(2048, bits)
    b = _rand_words(2048, bits)
    approx = np.asarray(W.mulhi_approx3(jnp.asarray(a), jnp.asarray(b)))
    for i in range(len(a)):
        true_hi = (int(a[i]) * int(b[i])) >> bits
        diff = true_hi - int(approx[i])
        assert 0 <= diff <= 2, (a[i], b[i], diff)


@pytest.mark.parametrize("bits,lo,hi", [(32, 28, 30), (64, 57, 60)])
def test_shoup_modmul(bits, lo, hi):
    primes = find_ntt_primes(64, 6, lo, hi)
    for p in primes:
        x = _rand_words(256, bits) % np.uint64(p) if bits == 64 else \
            (_rand_words(256, 32).astype(np.uint64) % np.uint64(p)).astype(np.uint32)
        y = int(_rand_words(1, bits)[0]) % p
        ysh = shoup_precompute(y, p, bits)
        dt = jnp.uint32 if bits == 32 else jnp.uint64
        r = W.shoup_modmul(jnp.asarray(x, dt), jnp.asarray(y, dt),
                           jnp.asarray(ysh, dt), jnp.asarray(p, dt))
        rm = W.shoup_modmul_modified(jnp.asarray(x, dt), jnp.asarray(y, dt),
                                     jnp.asarray(ysh, dt), jnp.asarray(p, dt))
        expect = (np.array([int(v) for v in x], dtype=object) * y) % p
        np.testing.assert_array_equal(
            np.array([int(v) for v in r], dtype=object), expect)
        np.testing.assert_array_equal(
            np.array([int(v) for v in rm], dtype=object), expect)


@pytest.mark.parametrize("bits,lo,hi", [(32, 28, 30), (64, 57, 60)])
def test_shoup_reduces_full_word_with_y1(bits, lo, hi):
    """Y=1 Shoup reduces an arbitrary β-bit word mod p (paper's accum fold)."""
    p = find_ntt_primes(64, 1, lo, hi)[0]
    x = _rand_words(4096, bits)
    dt = jnp.uint32 if bits == 32 else jnp.uint64
    ysh = shoup_precompute(1, p, bits)
    r = W.shoup_modmul(jnp.asarray(x, dt), jnp.asarray(1, dt),
                       jnp.asarray(ysh, dt), jnp.asarray(p, dt))
    expect = np.array([int(v) % p for v in x], dtype=object)
    np.testing.assert_array_equal(
        np.array([int(v) for v in r], dtype=object), expect)


@pytest.mark.parametrize("bits,lo,hi", [(32, 28, 30), (64, 57, 60)])
def test_montgomery_modmul(bits, lo, hi):
    primes = find_ntt_primes(64, 4, lo, hi)
    R = 1 << bits
    dt = jnp.uint32 if bits == 32 else jnp.uint64
    for p in primes:
        pprime = (-pow(p, -1, R)) % R
        r2 = (R * R) % p
        a = np.array([int(v) % p for v in _rand_words(256, bits)],
                     dtype=np.uint64)
        b = np.array([int(v) % p for v in _rand_words(256, bits)],
                     dtype=np.uint64)
        out = W.mont_modmul(jnp.asarray(a, dt), jnp.asarray(b, dt),
                            jnp.asarray(p, dt), jnp.asarray(pprime, dt),
                            jnp.asarray(r2, dt))
        expect = (a.astype(object) * b.astype(object)) % p
        np.testing.assert_array_equal(
            np.array([int(v) for v in out], dtype=object), expect)


@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1)),
                min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_acc3_chain_property(pairs):
    """3-word accumulator matches exact Σ a·b for any u32 sequence."""
    a2 = a1 = a0 = jnp.zeros((), jnp.uint32)
    total = 0
    for a, b in pairs:
        a2, a1, a0 = W.acc3_add_product(
            a2, a1, a0, jnp.asarray(a, jnp.uint32), jnp.asarray(b, jnp.uint32))
        total += a * b
    got = int(a0) + (int(a1) << 32) + (int(a2) << 64)
    assert got == total % (1 << 96)
    assert total < (1 << 96)  # 200 u32 products always fit 3 words


@pytest.mark.parametrize("bits", [32, 64])
def test_modadd_modsub(bits):
    p = find_ntt_primes(64, 1, 28 if bits == 32 else 57,
                        30 if bits == 32 else 60)[0]
    dt = jnp.uint32 if bits == 32 else jnp.uint64
    a = np.array([int(v) % p for v in _rand_words(512, bits)], dtype=np.uint64)
    b = np.array([int(v) % p for v in _rand_words(512, bits)], dtype=np.uint64)
    s = W.modadd(jnp.asarray(a, dt), jnp.asarray(b, dt), jnp.asarray(p, dt))
    d = W.modsub(jnp.asarray(a, dt), jnp.asarray(b, dt), jnp.asarray(p, dt))
    np.testing.assert_array_equal(np.asarray(s).astype(object),
                                  (a.astype(object) + b.astype(object)) % p)
    np.testing.assert_array_equal(np.asarray(d).astype(object),
                                  (a.astype(object) - b.astype(object)) % p)
