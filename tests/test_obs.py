"""repro.obs tests: bounded reservoirs, the metrics registry, tracer
span semantics under a fake clock, StageTimer attribution, StepMonitor
re-anchoring, the offline report, and the traced+profiled serving path
(bitwise vs plain serving, all eight lifecycle phases, schema-valid
trace events).

The 8-device lifecycle check runs through the shared
run_in_8dev_subprocess harness (tests/conftest.py): a fresh interpreter
with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.keys import keygen
from repro.core.rotate import rot_keygen
from repro.hserve import HEServer, ServeMetrics
from repro.obs import MetricsRegistry, Reservoir, StageTimer, Tracer
from repro.obs.report import analyze, format_report, load_events
from repro.obs.trace import _NULL_SPAN
from repro.runtime.monitor import Heartbeat, StepMonitor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PARAMS = small_params(logN=4, beta_bits=32)   # N=16, n_slots=8, L=5

EVENT_KEYS = ("pid", "tid", "ts", "dur", "name", "cat")
LIFECYCLE = {"submit", "enqueue", "bucket_wait", "flush",
             "batch_assemble", "dispatch", "device_wall", "complete"}


@pytest.fixture(scope="module")
def keys():
    sk, pk, evk = keygen(PARAMS, seed=0)
    return sk, pk, evk, {1: rot_keygen(PARAMS, sk, 1)}


def _enc(pk, seed, n=8):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=n) + 1j * rng.normal(size=n)
    return H.encrypt_message(z, pk, PARAMS, seed=seed)


def _bitwise(a, b):
    return bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
                and (np.asarray(a.bx) == np.asarray(b.bx)).all())


class _FakeClock:
    """Deterministic clock: advances by `tick` on every read."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        t, self.t = self.t, self.t + self.tick
        return t


# --------------------------------------------------------------------------
# Reservoir: bounded memory, exact moments, sampled quantiles
# --------------------------------------------------------------------------

def test_reservoir_bounded_with_exact_moments_and_close_quantiles():
    """50k lognormal samples through a 4096-slot reservoir: memory stays
    at capacity, count/total/min/max are EXACT, p50/p99 land within a
    few percent of the exact numpy percentiles."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=0.0, sigma=0.75, size=50_000)
    r = Reservoir(capacity=4096)
    r.extend(xs)
    assert r.sample_size == 4096                 # the memory ceiling
    assert r.count == 50_000
    assert r.min == xs.min() and r.max == xs.max()
    np.testing.assert_allclose(r.total, xs.sum())
    np.testing.assert_allclose(r.mean, xs.mean())
    assert abs(r.percentile(50) / np.percentile(xs, 50) - 1) < 0.05
    assert abs(r.percentile(99) / np.percentile(xs, 99) - 1) < 0.10
    s = r.summary()
    assert s["count"] == 50_000 and s["max"] == xs.max()


def test_reservoir_under_capacity_is_exact_and_deterministic():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    r = Reservoir(capacity=16)
    r.extend(xs)
    assert r.sample_size == 5
    assert r.percentile(50) == np.percentile(xs, 50)
    assert r.percentile(99) == np.percentile(xs, 99)
    # fixed seed: two identical streams summarize identically even past
    # capacity (telemetry must not jitter between identical runs)
    a, b = Reservoir(capacity=8), Reservoir(capacity=8)
    stream = list(np.random.default_rng(1).normal(size=1000))
    a.extend(stream)
    b.extend(stream)
    assert a.summary() == b.summary()
    with pytest.raises(ValueError):
        Reservoir(capacity=0)


def test_serve_metrics_memory_is_bounded():
    """Regression for the unbounded-list leak: ServeMetrics used to
    keep every latency and queue-depth sample forever. Stream far more
    than the reservoir capacity and pin the retained footprint."""
    m = ServeMetrics()
    lat = [0.001 * (i % 7 + 1) for i in range(8)]
    for i in range(3000):                        # 24k latency samples
        m.record_batch("mul", 240, 8, 0, 0.01, lat)
        m.record_depth(i % 50)
    for i in range(2000):
        m.record_depth(i)
    st = m._ops["mul"].latencies
    assert st.count == 24_000
    assert st.sample_size <= st.capacity == 4096
    assert m._depths.count == 5000
    assert m._depths.sample_size <= m._depths.capacity
    s = m.summary()
    assert s["per_op"]["mul"]["requests"] == 24_000
    # max latency is exact even though the sample is bounded
    assert s["per_op"]["mul"]["latency_ms"]["max"] == \
        pytest.approx(1e3 * max(lat))


# --------------------------------------------------------------------------
# StepMonitor: breach-streak re-anchoring (degrade then stabilize)
# --------------------------------------------------------------------------

def test_step_monitor_degrades_then_stabilizes():
    """A permanent 10× degradation: alerts fire, then after 8
    consecutive breaches the baseline re-anchors in CAPPED stages
    (4× per jump) until the new normal stops breaching — with every
    re-anchor logged for the launcher's escalation policy."""
    mon = StepMonitor(ema_alpha=0.1, slack=2.0, warmup_steps=3,
                      reanchor_after=8, reanchor_cap=4.0)
    step = 0
    for _ in range(3):                           # warmup → ema = 1.0
        step += 1
        assert not mon.record(step, 1.0)
    assert mon.ema == 1.0

    breaches = []
    for _ in range(20):                          # the pod now runs at 10×
        step += 1
        breaches.append(mon.record(step, 10.0))
    # first 8 breach → re-anchor capped at 4×·1.0 = 4.0 (not straight
    # to 10.0: one jump may never absorb an unbounded regression)
    assert mon.reanchors[0][1:] == (1.0, 4.0)
    # next 8 still breach (10 > 2·4) → second re-anchor reaches the
    # streak minimum, the true new normal
    assert mon.reanchors[1][1:] == (4.0, 10.0)
    assert len(mon.reanchors) == 2
    assert sum(breaches) == 16                   # then the alerts quiesce
    assert not breaches[-1]

    step += 1
    assert not mon.record(step, 10.0)            # stabilized at the new normal
    step += 1
    assert mon.record(step, 25.0)                # ...but still alerts on fresh
    assert len(mon.reanchors) == 2               # degradation, no re-anchor


def test_step_monitor_transient_breach_resets_streak():
    mon = StepMonitor(ema_alpha=0.1, slack=2.0, warmup_steps=3,
                      reanchor_after=8)
    for i in range(3):
        mon.record(i, 1.0)
    for i in range(5):                           # transient: under the streak
        assert mon.record(10 + i, 5.0)
    assert mon.record(20, 1.0) is False          # recovery resets the streak
    for i in range(7):
        assert mon.record(30 + i, 5.0)
    assert mon.reanchors == []                   # 5 + 7 but never 8 in a row
    assert mon.ema == pytest.approx(1.0)         # EMA froze during breaches


# --------------------------------------------------------------------------
# Tracer: span nesting, schema, disabled fast path
# --------------------------------------------------------------------------

def test_tracer_span_nesting_under_fake_clock():
    clk = _FakeClock(tick=1.0)                   # t0 = 0
    tr = Tracer(clock=clk)
    with tr.span("outer", cat="test", lane="a"):          # opens at t=1
        with tr.span("inner", cat="test", lane="a"):      # opens at t=2
            pass                                          # closes at t=3
    xs = [e for e in tr.events if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["inner", "outer"]  # inner closes first
    inner, outer = xs
    assert inner["ts"] == pytest.approx(2e6)     # µs relative to t0
    assert inner["dur"] == pytest.approx(1e6)
    assert outer["ts"] == pytest.approx(1e6)
    assert outer["dur"] == pytest.approx(3e6)    # envelops the inner span
    assert inner["tid"] == outer["tid"]          # one lane, one tid


def test_tracer_every_event_carries_the_full_key_set():
    """Schema contract: EVERY element of traceEvents — including "M"
    thread_name metadata — has pid/tid/ts/dur/name/cat."""
    tr = Tracer(clock=_FakeClock())
    tr.instant("i", cat="test", lane="a")
    with tr.span("s", cat="test", lane="b", args={"k": 1}):
        pass
    tr.event("e", cat="test", lane="a", ts=0.5, dur=0.25)
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 5          # 3 events + 2 lane metadata
    for e in doc["traceEvents"]:
        assert all(k in e for k in EVENT_KEYS), e
        assert e["ph"] in ("X", "M")
    # lanes intern to stable small-int tids with exactly one metadata
    # record each
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert sorted(m["args"]["name"] for m in metas) == ["a", "b"]
    assert {m["tid"] for m in metas} == {0, 1}


def test_disabled_tracer_allocates_nothing():
    """The no-trace serving default: span() hands back one shared
    singleton (no per-request Span objects) and records nothing."""
    tr = Tracer(enabled=False)
    spans = [tr.span(f"s{i}", cat="c", lane="l") for i in range(100)]
    assert all(s is _NULL_SPAN for s in spans)   # identity, not equality
    for s in spans:
        with s:
            pass
        s.end(extra=1)                           # no-op, no error
    tr.instant("i", cat="c", lane="l")
    tr.event("e", cat="c", lane="l", ts=0.0)
    assert len(tr) == 0 and tr.events == []


def test_tracer_caps_retained_events():
    tr = Tracer(clock=_FakeClock(), max_events=3)
    for i in range(5):
        tr.instant(f"e{i}", cat="c", lane="l")
    assert len(tr) == 3                          # 1 lane metadata + 2 events
    assert tr.dropped == 3
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0
    tr.instant("fresh", cat="c", lane="l")       # records again after clear
    assert len(tr) == 2


def test_obs_package_imports_without_jax():
    """Import contract: the frontend metrics path must be loadable on a
    jax-free host (jax only loads lazily inside StageTimer.timed)."""
    code = ("import sys; import repro.obs; "
            "print('jax' in sys.modules)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip() == "False"


# --------------------------------------------------------------------------
# StageTimer: attribution scoping, pausing, tracer coupling
# --------------------------------------------------------------------------

def test_stage_timer_attribution_and_regions():
    clk = _FakeClock(tick=0.5)
    tr = Tracer(clock=clk)
    st = StageTimer(tracer=tr, clock=clk)
    with st.op("mul"):
        assert st.timed("crt", lambda: 7) == 7   # returns the thunk's value
        st.timed("ntt", lambda: None)
        with st.region("region1"):
            st.timed("modmul", lambda: None)
    with st.op("rotate"):
        st.timed("ntt", lambda: None)
    s = st.summary()
    # every timed() call spans exactly two clock reads → 0.5 s each
    assert s["stages"]["mul"] == {"crt": 0.5, "ntt": 0.5,
                                  "modmul": 0.5, "icrt": 0.0}
    assert s["calls"]["mul"]["crt"] == 1
    assert s["stages"]["rotate"]["ntt"] == 0.5
    assert st.stage_total("mul") == pytest.approx(1.5)
    assert st.stage_total("absent") == 0.0
    # the region envelops its inner stage (region wall > stage wall)
    assert s["regions"]["mul"]["region1"] >= 0.5
    # stage spans landed on the tracer's "stage" lane, tagged by op
    stage_evs = [e for e in tr.events
                 if e["ph"] == "X" and e["cat"] == "stage"]
    assert {(e["name"], e["args"]["op"]) for e in stage_evs} == {
        ("crt", "mul"), ("ntt", "mul"), ("modmul", "mul"),
        ("region1", "mul"), ("ntt", "rotate")}
    with pytest.raises(ValueError):
        st.timed("keyswitch", lambda: None)
    st.reset()
    assert st.summary() == {"stages": {}, "calls": {}, "regions": {}}


def test_stage_timer_pause_suppresses_recording():
    st = StageTimer(clock=_FakeClock())
    with st.op("mul"), st.pause():               # warm-up runs book nothing
        assert st.timed("crt", lambda: 3) == 3
        with st.region("region1"):
            pass
    assert st.stage_total("mul") == 0.0
    assert st.summary()["regions"] == {}


# --------------------------------------------------------------------------
# MetricsRegistry + heartbeat embedding
# --------------------------------------------------------------------------

def test_registry_snapshot_instruments_and_sources():
    reg = MetricsRegistry(histogram_capacity=8)
    reg.counter("serve.polls").inc()
    reg.counter("serve.polls").inc(4)            # same name → same handle
    reg.gauge("serve.queue.depth").set(7)
    h = reg.histogram("serve.batch.wall_s")
    h.extend([0.1, 0.2, 0.3])
    reg.add_source("cache", lambda: {"hits": 3})
    snap = reg.snapshot()
    assert snap["counters"] == {"serve.polls": 5}
    assert snap["gauges"] == {"serve.queue.depth": 7.0}
    assert snap["histograms"]["serve.batch.wall_s"]["count"] == 3
    assert snap["cache"] == {"hits": 3}
    # replacement is deliberate (reset_metrics re-registers): last wins
    reg.add_source("cache", lambda: {"hits": 0})
    assert reg.snapshot()["cache"] == {"hits": 0}
    reg.remove_source("cache")
    assert "cache" not in reg.snapshot()


def test_registry_snapshot_captures_source_failures_inline():
    """A raising source must not poison the whole health snapshot."""
    reg = MetricsRegistry()

    def bad():
        raise RuntimeError("stats exploded")

    reg.add_source("bad", bad)
    reg.add_source("good", lambda: {"ok": True})
    snap = reg.snapshot()
    assert snap["good"] == {"ok": True}
    assert snap["bad"] == {"error": "RuntimeError: stats exploded"}


def test_heartbeat_embeds_registry_snapshot(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve.requests").inc(9)
    path = str(tmp_path / "hb.json")
    hb = Heartbeat(path, interval=0.0, metrics=reg)
    hb.beat(3, payload={"loss": 0.5})
    with open(path) as f:
        doc = json.load(f)
    assert doc["step"] == 3 and doc["loss"] == 0.5
    assert doc["metrics"]["counters"]["serve.requests"] == 9
    assert Heartbeat.is_alive(path, timeout=60.0)


# --------------------------------------------------------------------------
# offline report
# --------------------------------------------------------------------------

def test_report_aggregates_stage_and_lifecycle_events(tmp_path):
    def ev(name, cat, dur_s, **args):
        return {"pid": 1, "tid": 0, "ts": 0.0, "dur": dur_s * 1e6,
                "name": name, "cat": cat, "ph": "X", "args": args}

    doc = {"traceEvents": [
        {"pid": 1, "tid": 0, "ts": 0.0, "dur": 0.0, "name": "thread_name",
         "cat": "__metadata", "ph": "M", "args": {"name": "stage"}},
        ev("crt", "stage", 0.010, op="mul"),
        ev("ntt", "stage", 0.030, op="mul"),
        ev("ntt", "stage", 0.020, op="mul"),     # fwd + inverse both book
        ev("modmul", "stage", 0.015, op="mul"),
        ev("icrt", "stage", 0.005, op="mul"),
        ev("region2", "stage", 0.040, op="mul"),
        ev("bucket_wait", "lifecycle", 0.200, op="mul"),
        ev("device_wall", "lifecycle", 0.090, op="mul"),
        ev("complete", "lifecycle", 0.0, op="mul", latency_s=0.3),
        ev("complete", "lifecycle", 0.0, op="mul", latency_s=0.1),
    ], "displayTimeUnit": "ms"}
    path = str(tmp_path / "trace.json")
    with open(path, "w") as f:
        json.dump(doc, f)

    events = load_events(path)
    assert all(e["ph"] == "X" for e in events)   # metadata filtered out
    a = analyze(events)
    assert a["stages"]["mul"] == pytest.approx(
        {"crt": 0.010, "ntt": 0.050, "modmul": 0.015, "icrt": 0.005})
    assert a["regions"]["mul"]["region2"] == pytest.approx(0.040)
    assert a["queue_wait"]["mul"] == {
        "total_s": pytest.approx(0.2), "n": 1}
    assert a["device_wall"]["mul"]["batches"] == 1
    assert a["complete"]["mul"]["n"] == 2
    assert a["complete"]["mul"]["latency_total_s"] == pytest.approx(0.4)
    rep = format_report(a)
    assert "Fig. 3 stage attribution" in rep
    assert "queue wait vs device wall" in rep
    assert "mul" in rep


# --------------------------------------------------------------------------
# end to end: traced + stage-profiled serving
# --------------------------------------------------------------------------

def _drive(server, pk):
    cts = [_enc(pk, i) for i in range(1, 5)]
    rids = [server.submit_mul(cts[0], cts[1]),
            server.submit_mul(cts[2], cts[3]),
            server.submit_rotate(cts[0], 1)]
    res = server.drain()
    return [res[r] for r in rids]


def test_traced_profiled_serving_is_bitwise_with_full_lifecycle(keys):
    """`tracer + profile_stages` serving returns bit-identical
    ciphertexts to the plain fused path, records every lifecycle phase
    with schema-valid events, books Fig. 3 stage time for every staged
    op, and snapshots the whole stack through one registry."""
    _, pk, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tr = Tracer()
    srv = HEServer(PARAMS, evk, rks, mesh=mesh, batch=2,
                   tracer=tr, profile_stages=True)
    outs = _drive(srv, pk)
    plain = HEServer(PARAMS, evk, rks, mesh=mesh, batch=2)
    outs0 = _drive(plain, pk)
    assert all(_bitwise(a, b) for a, b in zip(outs, outs0))

    xs = [e for e in tr.events if e["ph"] == "X"]
    names = {e["name"] for e in xs}
    assert LIFECYCLE <= names                    # all eight phases
    assert all(all(k in e for k in EVENT_KEYS) for e in tr.events)

    st = srv.engine.stage_timer
    summ = st.summary()
    per_op = srv.metrics.summary()["per_op"]
    for op in ("mul", "rotate"):
        assert st.stage_total(op) > 0.0
        assert st.stage_total(op) <= per_op[op]["wall_s"]
    # mul books both Fig. 2 regions and all four Fig. 3 buckets
    assert set(summ["regions"]["mul"]) == {"region1", "region2"}
    assert all(v > 0.0 for v in summ["stages"]["mul"].values())
    # rotate has no ciphertext-product region and no region-1 modmul
    assert summ["stages"]["rotate"]["modmul"] > 0.0   # key switch only

    snap = srv.registry.snapshot()
    for key in ("counters", "gauges", "histograms", "serve", "cache",
                "scheduler", "engine"):
        assert key in snap, key
    assert snap["counters"]["serve.requests"] == 3
    assert snap["histograms"]["serve.batch.wall_s"]["count"] >= 2
    # the server's stats() surface carries the stage summary too
    assert srv.stats()["stages"]["stages"]["mul"]["ntt"] > 0.0


def test_trace_roundtrips_through_the_offline_report(tmp_path, keys):
    _, pk, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tr = Tracer()
    srv = HEServer(PARAMS, evk, rks, mesh=mesh, batch=2,
                   tracer=tr, profile_stages=True)
    _drive(srv, pk)
    path = str(tmp_path / "trace.json")
    n = tr.write(path)
    assert n == len(tr.events)
    a = analyze(load_events(path))
    assert a["stages"]["mul"]["ntt"] > 0.0
    assert a["complete"]["mul"]["n"] == 2
    assert a["device_wall"]["mul"]["batches"] >= 1
    assert a["queue_wait"]["mul"]["n"] == 2
    assert "mul" in format_report(a)


def test_session_publishes_client_counters(keys):
    from repro.client import HESession
    sk, pk, evk = keygen(PARAMS, seed=0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = HESession(PARAMS, sk, pk, evk, mesh=mesh, batch=2)
    x = s.encrypt(0.5 * np.ones(8), seed=3)
    f = s.run([x * x])[0]
    f.result()
    snap = s.server.registry.snapshot()
    assert snap["counters"]["client.runs"] == 1
    assert snap["counters"]["client.circuits"] == 1


# --------------------------------------------------------------------------
# 8-device mesh: full lifecycle under sharded serving
# --------------------------------------------------------------------------

def test_traced_serving_on_8_device_mesh_records_all_phases(
        run_in_8dev_subprocess):
    """Sharded (2, 4)-mesh serving with the tracer and stage profiler
    on: results stay bitwise vs the core references, every one of the
    eight lifecycle phases lands in the trace, every event carries the
    full key set, and mul books stage time."""
    res = run_in_8dev_subprocess("""
        from repro.core import heaan as H
        from repro.core import test_params
        from repro.core.keys import keygen
        from repro.core.rotate import he_rotate, rot_keygen
        from repro.hserve import HEServer
        from repro.obs import Tracer

        params = test_params(logN=5, beta_bits=32)
        sk, pk, evk = keygen(params, seed=0)
        rks = {1: rot_keygen(params, sk, 1)}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        tr = Tracer()
        server = HEServer(params, evk, rks, mesh=mesh, batch=2,
                          tracer=tr, profile_stages=True)

        rng = np.random.default_rng(7)
        def enc(seed):
            z = rng.normal(size=16) + 1j * rng.normal(size=16)
            return H.encrypt_message(z, pk, params, seed=seed)

        c1, c2, c3 = enc(1), enc(2), enc(3)
        rid_m = server.submit_mul(c1, c2)
        rid_r = server.submit_rotate(c3, 1)
        res = server.drain()
        ok_mul = res[rid_m]
        ok_rot = res[rid_r]
        ref_mul = H.he_mul(c1, c2, evk, params)
        ref_rot = he_rotate(c3, 1, rks[1], params)
        def bitwise(a, b):
            return bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
                        and (np.asarray(a.bx) == np.asarray(b.bx)).all())
        keys = ("pid", "tid", "ts", "dur", "name", "cat")
        st = server.engine.stage_timer
        print(json.dumps({
            "devices": jax.device_count(),
            "bitwise": bitwise(ok_mul, ref_mul) and bitwise(ok_rot,
                                                            ref_rot),
            "names": sorted({e["name"] for e in tr.events
                             if e["ph"] == "X"}),
            "bad_events": sum(1 for e in tr.events
                              if not all(k in e for k in keys)),
            "stage_mul_s": st.stage_total("mul"),
        }))
    """)
    assert res["devices"] == 8
    assert res["bitwise"] is True
    assert res["bad_events"] == 0
    assert LIFECYCLE <= set(res["names"])
    assert res["stage_mul_s"] > 0.0
