"""repro.hserve tests: queue invariants, level-slice table equality,
engine bitwise parity vs the single-device core references, metrics, and
the composed server loop.

The 8-device mesh parity check (sharded rotate/mul/slot-sum) runs
through the shared run_in_8dev_subprocess harness (tests/conftest.py):
a fresh interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.context import make_context
from repro.core.keys import keygen
from repro.core.rotate import conj_keygen, he_conjugate, he_rotate, \
    rot_keygen
from repro.dist import he_pipeline as hp
from repro.hserve import (
    BatchAssembler, CircuitOp, CircuitScheduler, HEServer, RequestQueue,
    ServeMetrics, TableCache, circuit_schedule, degree4_demo_circuit,
    slot_sum_rotations, validate_circuit,
)

PARAMS = small_params(logN=4, beta_bits=32)   # N=16, n_slots=8, L=5


@pytest.fixture(scope="module")
def keys():
    sk, pk, evk = keygen(PARAMS, seed=0)
    rks = {r: rot_keygen(PARAMS, sk, r) for r in (1, 2, 4)}
    return sk, pk, evk, rks


@pytest.fixture(scope="module")
def ck(keys):
    return conj_keygen(PARAMS, keys[0])


def _enc(pk, seed, n=8):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=n) + 1j * rng.normal(size=n)
    return z, H.encrypt_message(z, pk, PARAMS, seed=seed)


# --------------------------------------------------------------------------
# queue: bucketing and padding invariants
# --------------------------------------------------------------------------

def test_queue_buckets_by_op_level_and_r(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    low2 = H.he_mod_down(c2, PARAMS, PARAMS.logQ - PARAMS.logp)
    r0 = q.submit("mul", (c1, c2))
    r1 = q.submit("mul", (c1, c2))
    q.submit("mul", (low, low2))            # different level, new bucket
    q.submit("rotate", (c1,), r=1)
    q.submit("rotate", (c1,), r=2)          # different r, new bucket
    q.submit("slot_sum", (c1,))
    assert q.depth == 6
    assert len(q.bucket_depths()) == 5
    # oldest bucket with >= 2 requests is the top-level mul bucket
    key = q.ready_key(2)
    assert key == ("mul", PARAMS.logQ, None)
    got = q.pop_bucket(key, 2)
    assert [r.rid for r in got] == [r0, r1]   # FIFO within the bucket
    assert q.ready_key(2) is None             # no other bucket is full
    assert q.any_key() is not None            # but work remains for flush


def test_server_rejects_unserveable_requests_at_submit(keys):
    """A request the engine cannot serve must never enter the queue —
    otherwise it fails mid-drain after being popped, taking the rest of
    the queued work down with it."""
    _, pk, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, c1 = _enc(pk, 1)
    server = HEServer(PARAMS, evk, {1: rks[1]}, mesh=mesh, batch=2)
    with pytest.raises(KeyError):
        server.submit_rotate(c1, 3)           # no key for r=3
    with pytest.raises(KeyError):
        server.submit_slot_sum(c1)            # needs r=2,4 too
    no_evk = HEServer(PARAMS, rot_keys=rks, mesh=mesh, batch=2)
    with pytest.raises(ValueError):
        no_evk.submit_mul(c1, c1)             # no evaluation key
    assert no_evk.submit_slot_sum(c1) == 0    # rotations fully keyed
    assert server.queue.depth == 0


def test_queue_rejects_bad_requests(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    with pytest.raises(ValueError):
        q.submit("frobnicate", (c1,))
    with pytest.raises(ValueError):
        q.submit("mul", (c1,))                # arity
    with pytest.raises(ValueError):
        q.submit("mul", (c1, low))            # level mismatch
    with pytest.raises(ValueError):
        q.submit("rotate", (c1,), r=0)        # no rotation amount


def test_assembler_pads_to_fixed_shape(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    for _ in range(3):
        q.submit("mul", (c1, c2))
    asm = BatchAssembler(batch=4)
    b = asm.assemble(q.pop_bucket(("mul", PARAMS.logQ, None), 4))
    assert b.size == 4 and b.n_valid == 3 and b.n_pad == 1
    assert set(b.arrays) == {"ax1", "bx1", "ax2", "bx2"}
    for v in b.arrays.values():
        assert v.shape == (4, PARAMS.N, PARAMS.qlimbs(PARAMS.logQ))
        assert not np.asarray(v[3]).any()     # padded lane is zeros
    # valid lanes carry the submitted operands, in request order
    np.testing.assert_array_equal(np.asarray(b.arrays["ax1"][0]),
                                  np.asarray(c1.ax))
    np.testing.assert_array_equal(np.asarray(b.arrays["bx2"][2]),
                                  np.asarray(c2.bx))
    # rotate batches carry one operand only
    q.submit("rotate", (c1,), r=1)
    b = asm.assemble(q.pop_bucket(("rotate", PARAMS.logQ, 1), 4))
    assert set(b.arrays) == {"ax1", "bx1"}
    assert b.n_valid == 1 and b.n_pad == 3


def test_assembler_rejects_mixed_and_oversize(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    low2 = H.he_mod_down(c2, PARAMS, PARAMS.logQ - PARAMS.logp)
    q.submit("mul", (c1, c2))
    q.submit("mul", (low, low2))
    reqs = (q.pop_bucket(("mul", PARAMS.logQ, None), 4)
            + q.pop_bucket(("mul", PARAMS.logQ - PARAMS.logp, None), 4))
    asm = BatchAssembler(batch=4)
    with pytest.raises(ValueError):
        asm.assemble(reqs)                    # mixed buckets
    with pytest.raises(ValueError):
        BatchAssembler(batch=1).assemble(reqs[:1] * 2)  # oversize
    with pytest.raises(ValueError):
        asm.assemble([])


# --------------------------------------------------------------------------
# tables: level slices == freshly built per-level tables
# --------------------------------------------------------------------------

def test_table_cache_level_slices_match_fresh_tables(keys):
    """The resident-slice pytrees must be value-identical to
    region_tables built from a fresh per-level context at EVERY level —
    the whole bitwise-serving argument rests on this."""
    _, _, evk, _ = keys
    cache = TableCache(PARAMS, evk)
    for i in range(3):
        logq = PARAMS.logQ - i * PARAMS.logp
        t1, t2 = cache.level_tables(logq)
        ctx = make_context(PARAMS, logq)
        for region, cached in ((1, t1), (2, t2)):
            fresh = hp.region_tables(ctx, region)
            assert set(cached) == set(fresh) == set(hp.REGION_TABLE_KEYS)
            for k in fresh:
                np.testing.assert_array_equal(
                    np.asarray(cached[k]), np.asarray(jnp.asarray(fresh[k])),
                    err_msg=f"level {logq} region {region} table {k}")
    st = cache.stats()
    assert len(st["levels_materialized"]) == 3
    # second hit serves from cache
    before = cache.hits
    cache.level_tables(PARAMS.logQ)
    assert cache.hits == before + 1


def test_table_cache_keys_and_stats(keys):
    _, _, evk, rks = keys
    cache = TableCache(PARAMS, evk, {1: rks[1]})
    assert set(cache.evk()) == set(hp.EVK_TABLE_KEYS)
    assert set(cache.rot_key(1)) == set(hp.EVK_TABLE_KEYS)
    with pytest.raises(KeyError):
        cache.rot_key(2)
    cache.add_rot_key(2, rks[2])
    assert cache.rotation_amounts == [1, 2]
    assert cache.stats()["resident_mib"] > 0
    with pytest.raises(ValueError):
        TableCache(PARAMS).evk()


# --------------------------------------------------------------------------
# engine parity vs core, through the composed server (1-device mesh)
# --------------------------------------------------------------------------

def _server(keys, conj_key=None, **kw):
    _, _, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return HEServer(PARAMS, evk, rks, conj_key, mesh=mesh, batch=2, **kw)


def test_served_mul_bitwise_equals_core_at_two_levels(keys):
    sk, pk, evk, _ = keys
    server = _server(keys)
    cases = []
    for i, logq in enumerate((PARAMS.logQ, PARAMS.logQ - PARAMS.logp)):
        _, c1 = _enc(pk, 10 + 2 * i)
        _, c2 = _enc(pk, 11 + 2 * i)
        if logq < PARAMS.logQ:
            c1 = H.he_mod_down(c1, PARAMS, logq)
            c2 = H.he_mod_down(c2, PARAMS, logq)
        rid = server.submit_mul(c1, c2)
        cases.append((rid, H.he_mul(c1, c2, evk, PARAMS)))
    res = server.drain()
    for rid, ref in cases:
        out = res[rid]
        assert out.logq == ref.logq and out.logp == ref.logp
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_served_rotate_bitwise_equals_core(keys):
    sk, pk, _, rks = keys
    server = _server(keys)
    _, ct = _enc(pk, 42)
    low = H.he_mod_down(ct, PARAMS, PARAMS.logQ - PARAMS.logp)
    cases = [(server.submit_rotate(ct, 1),
              he_rotate(ct, 1, rks[1], PARAMS)),
             (server.submit_rotate(low, 2),
              he_rotate(low, 2, rks[2], PARAMS))]
    res = server.drain()
    for rid, ref in cases:
        out = res[rid]
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_served_slot_sum_bitwise_equals_core_composition(keys):
    sk, pk, _, rks = keys
    server = _server(keys)
    z, ct = _enc(pk, 77)
    rid = server.submit_slot_sum(ct)
    # reference: acc ← he_add(acc, he_rotate(acc, r)) for doubling r
    acc = ct
    for r in slot_sum_rotations(ct.n_slots):
        acc = H.he_add(acc, he_rotate(acc, r, rks[r], PARAMS))
    out = server.drain()[rid]
    np.testing.assert_array_equal(np.asarray(out.ax), np.asarray(acc.ax))
    np.testing.assert_array_equal(np.asarray(out.bx), np.asarray(acc.bx))
    got = H.decrypt_message(out, sk, PARAMS)
    np.testing.assert_allclose(got.real, np.full(8, z.real.sum()),
                               atol=1e-2)


def test_served_mul_with_kernels_bitwise(keys):
    """The Pallas-routed engine path (satellite: use_kernels through the
    batched stage wrappers) keeps the bitwise contract."""
    _, pk, evk, _ = keys
    server = _server(keys, use_kernels=True)
    _, c1 = _enc(pk, 91)
    _, c2 = _enc(pk, 92)
    rid = server.submit_mul(c1, c2)
    ref = H.he_mul(c1, c2, evk, PARAMS)
    out = server.drain()[rid]
    np.testing.assert_array_equal(np.asarray(out.ax), np.asarray(ref.ax))
    np.testing.assert_array_equal(np.asarray(out.bx), np.asarray(ref.bx))


# --------------------------------------------------------------------------
# level-management ops (this PR): bitwise parity vs core
# --------------------------------------------------------------------------

def test_queue_validates_level_management_ops(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    resc = H.rescale(c1, PARAMS)              # different logp than c1
    with pytest.raises(ValueError):
        q.submit("rescale", (c1,), dlogp=0)   # needs a positive dlogp
    with pytest.raises(ValueError):
        q.submit("rescale", (c1,), dlogp=PARAMS.logQ)   # exhausted
    with pytest.raises(ValueError):
        q.submit("mod_down", (c1,), logq2=0)
    with pytest.raises(ValueError):
        q.submit("mod_down", (c1,), logq2=PARAMS.logQ + 1)
    with pytest.raises(ValueError):
        q.submit("add", (low, resc))          # scale mismatch
    # distinct extras land in distinct buckets (trace signatures)
    q.submit("rescale", (c1,), dlogp=PARAMS.logp)
    q.submit("rescale", (c1,), dlogp=2 * PARAMS.logp)
    q.submit("mod_down", (c1,), logq2=PARAMS.logQ - PARAMS.logp)
    q.submit("conjugate", (c1,))
    q.submit("add", (c1, c1))
    q.submit("sub", (c1, c1))
    assert len(q.bucket_depths()) == 6


def test_served_level_ops_bitwise_equal_core(keys, ck):
    """conjugate / rescale / mod_down / add / sub through the server are
    bitwise identical to the single-device core references, with the
    right output (logq, logp) metadata."""
    _, pk, _, _ = keys
    server = _server(keys, ck)
    _, c1 = _enc(pk, 50)
    _, c2 = _enc(pk, 51)
    logq2 = PARAMS.logQ - PARAMS.logp
    cases = [
        (server.submit_conjugate(c1), he_conjugate(c1, ck, PARAMS)),
        (server.submit_rescale(c1), H.rescale(c1, PARAMS)),
        (server.submit_mod_down(c1, logq2),
         H.he_mod_down(c1, PARAMS, logq2)),
        (server.submit_add(c1, c2), H.he_add(c1, c2)),
        (server.submit_sub(c1, c2), H.he_sub(c1, c2)),
    ]
    res = server.drain()
    for rid, ref in cases:
        out = res[rid]
        assert out.logq == ref.logq and out.logp == ref.logp
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_conjugate_requires_key(keys):
    _, pk, _, _ = keys
    server = _server(keys)                    # no conjugation key
    _, c1 = _enc(pk, 1)
    with pytest.raises(ValueError):
        server.submit_conjugate(c1)
    assert server.queue.depth == 0


# --------------------------------------------------------------------------
# plaintext-operand ops (this PR): region-1-only mul_plain / add_plain
# --------------------------------------------------------------------------

def _plain(seed, logq, n=8):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=n) + 1j * rng.normal(size=n)
    return w, H.encode_plain(w, PARAMS, logq)


def test_served_plain_ops_bitwise_equal_core_at_every_level(keys):
    """mul_plain / add_plain through the server are bitwise identical to
    core.heaan.he_mul_plain / he_add_plain at every served level, with
    the right output (logq, logp) metadata — and they need NO keys."""
    sk, pk, _, _ = keys
    # a server with NO evk / rotation / conjugation keys at all: the
    # plaintext ops must still serve (no key switch is their point)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = HEServer(PARAMS, mesh=mesh, batch=2)
    cases = []
    for i in range(3):
        logq = PARAMS.logQ - i * PARAMS.logp
        z, ct = _enc(pk, 80 + i)
        if logq < PARAMS.logQ:
            ct = H.he_mod_down(ct, PARAMS, logq)
        w, pt = _plain(90 + i, logq)
        cases.append((server.submit_mul_plain(ct, pt),
                      H.he_mul_plain(ct, pt, PARAMS), ("mul", z * w)))
        cases.append((server.submit_add_plain(ct, pt),
                      H.he_add_plain(ct, pt, PARAMS), ("add", z + w)))
    res = server.drain()
    for rid, ref, (kind, want) in cases:
        out = res[rid]
        assert out.logq == ref.logq and out.logp == ref.logp
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))
        dec = H.rescale(out, PARAMS) if kind == "mul" else out
        got = H.decrypt_message(dec, sk, PARAMS)
        np.testing.assert_allclose(got, want, atol=1e-2)


def test_plain_ops_validation(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, pt = _plain(2, PARAMS.logQ)
    with pytest.raises(ValueError, match="plaintext"):
        q.submit("mul_plain", (c1,))              # no operand
    with pytest.raises(ValueError, match="pt_logp"):
        q.submit("mul_plain", (c1,), pt=pt)       # no scale
    with pytest.raises(ValueError, match="scales differ"):
        q.submit("add_plain", (c1,), pt=pt,
                 pt_logp=c1.logp + 1)             # scale mismatch
    with pytest.raises(ValueError, match="does not cover"):
        q.submit("mul_plain", (c1,), pt=np.asarray(pt)[:, :1],
                 pt_logp=PARAMS.log_delta)        # too few limbs
    q.submit("mul_plain", (c1,), pt=pt, pt_logp=PARAMS.log_delta)
    q.submit("add_plain", (c1,), pt=pt)           # pt_logp 0 → ct.logp
    assert len(q.bucket_depths()) == 2            # distinct buckets


def test_plain_ops_as_circuit_nodes_bitwise(keys):
    """An affine-layer-shaped circuit — mul_plain → rescale → add_plain
    — served via submit_circuit, bitwise equal to the composed core
    references (and the same under the circuit-aware scheduler)."""
    sk, pk, _, _ = keys
    _, x = _enc(pk, 70)
    w, pt = _plain(71, PARAMS.logQ)
    logq1 = PARAMS.logQ - PARAMS.logp
    _, pt2 = _plain(72, logq1)
    ops = [
        CircuitOp("mul_plain", ("x",), pt=pt),
        CircuitOp("rescale", (0,)),
        CircuitOp("add_plain", (1,), pt=pt2),
    ]
    ref = H.he_add_plain(
        H.rescale(H.he_mul_plain(x, pt, PARAMS), PARAMS), pt2, PARAMS)
    for schedule in (False, True):
        server = _server(keys, schedule=schedule)
        cid = server.submit_circuit(ops, {"x": x})
        out = server.drain()[cid]
        assert out.logq == ref.logq and out.logp == ref.logp
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_circuit_validates_plain_ops(keys):
    _, pk, _, _ = keys
    _, x = _enc(pk, 1)
    meta = {"x": (x.logq, x.logp)}
    _, pt = _plain(2, PARAMS.logQ)
    with pytest.raises(ValueError, match="plaintext"):
        validate_circuit([CircuitOp("mul_plain", ("x",))], meta, PARAMS)
    # a plaintext encoded at a LOWER level than the node's input must be
    # rejected up front — otherwise queue.submit raises mid-drain from
    # _submit_ready, stranding the circuit with siblings already served
    _, pt_low = _plain(3, PARAMS.logQ - 3 * PARAMS.logp)
    with pytest.raises(ValueError, match="does not cover"):
        validate_circuit([CircuitOp("mul", ("x", "x")),
                          CircuitOp("rescale", (0,)),
                          CircuitOp("mul_plain", (1,), pt=pt_low)],
                         meta, PARAMS)
    with pytest.raises(ValueError, match="scales differ"):
        validate_circuit([CircuitOp("add_plain", ("x",), pt=pt,
                                    pt_logp=x.logp + 1)], meta, PARAMS)
    # negative pt_logp must fail HERE, not from queue.submit mid-drain
    with pytest.raises(ValueError, match="negative mul_plain"):
        validate_circuit([CircuitOp("mul_plain", ("x",), pt=pt,
                                    pt_logp=-1)], meta, PARAMS)
    out = validate_circuit(
        [CircuitOp("mul_plain", ("x",), pt=pt),
         CircuitOp("rescale", (0,))], meta, PARAMS)
    # mul_plain doubles the scale (pt at log_delta), rescale drops one
    assert out[0] == (PARAMS.logQ, x.logp + PARAMS.log_delta)
    assert out[1] == (PARAMS.logQ - PARAMS.logp,
                      x.logp + PARAMS.log_delta - PARAMS.logp)


# --------------------------------------------------------------------------
# circuits: server-side op-DAG walk with level tracking
# --------------------------------------------------------------------------

def _degree4_reference(x, evk, ck):
    r0 = H.rescale(H.he_mul(x, x, evk, PARAMS), PARAMS)
    r1 = H.rescale(H.he_mul(r0, r0, evk, PARAMS), PARAMS)
    logq_md = PARAMS.logQ - 3 * PARAMS.logp
    r2 = he_conjugate(H.he_mod_down(r1, PARAMS, logq_md), ck, PARAMS)
    return H.he_add(r2, H.he_mod_down(x, PARAMS, logq_md))


def test_circuit_degree4_bitwise_equals_core(keys, ck):
    """The acceptance circuit: a degree-4 encrypted polynomial submitted
    ONCE via submit_circuit, evaluated wholly server-side, decrypting
    bitwise-identical to the composed single-device core reference."""
    sk, pk, evk, _ = keys
    server = _server(keys, ck)
    z, x = _enc(pk, 99)
    ops, _ = degree4_demo_circuit(PARAMS)
    cid = server.submit_circuit(ops, {"x": x})
    out = server.drain()[cid]
    ref = _degree4_reference(x, evk, ck)
    assert out.logq == ref.logq and out.logp == ref.logp
    np.testing.assert_array_equal(np.asarray(out.ax), np.asarray(ref.ax))
    np.testing.assert_array_equal(np.asarray(out.bx), np.asarray(ref.bx))
    got = H.decrypt_message(out, sk, PARAMS)
    np.testing.assert_allclose(got, np.conj(z ** 4) + z, atol=0.3)
    assert not server._circuits                # bookkeeping fully drained
    assert not server._node_of_rid


def test_concurrent_circuits_batch_together(keys, ck):
    """Two identical circuits submitted together share (op, level)
    signatures node-for-node, so their nodes batch pairwise (batch=2):
    no padded lanes anywhere."""
    _, pk, evk, _ = keys
    server = _server(keys, ck)
    _, x1 = _enc(pk, 60)
    _, x2 = _enc(pk, 61)
    ops, _ = degree4_demo_circuit(PARAMS)
    c1 = server.submit_circuit(ops, {"x": x1})
    c2 = server.submit_circuit(ops, {"x": x2})
    res = server.drain()
    for cid, x in ((c1, x1), (c2, x2)):
        ref = _degree4_reference(x, evk, ck)
        np.testing.assert_array_equal(np.asarray(res[cid].ax),
                                      np.asarray(ref.ax))
    for op, d in server.stats()["per_op"].items():
        assert d["pad_frac"] == 0.0, f"{op} padded despite lockstep"


def test_circuit_validation_rejects_before_enqueue(keys, ck):
    """Level tracking catches ill-formed circuits up front — nothing may
    enter the queue for a circuit that cannot complete."""
    _, pk, _, _ = keys
    server = _server(keys, ck)
    _, x = _enc(pk, 1)
    meta = {"x": (x.logq, x.logp)}
    # static validator: level/scale propagation
    with pytest.raises(ValueError, match="exhausts"):
        validate_circuit([CircuitOp("rescale", ("x",),
                                    dlogp=PARAMS.logQ)], meta, PARAMS)
    with pytest.raises(ValueError, match="levels differ"):
        validate_circuit([CircuitOp("mod_down", ("x",),
                                    logq2=PARAMS.logQ - PARAMS.logp),
                          CircuitOp("add", (0, "x"))], meta, PARAMS)
    with pytest.raises(ValueError, match="scales differ"):
        validate_circuit([CircuitOp("mul", ("x", "x")),
                          CircuitOp("add", (0, "x"))], meta, PARAMS)
    with pytest.raises(ValueError, match="not an earlier node"):
        validate_circuit([CircuitOp("conjugate", (1,)),
                          CircuitOp("conjugate", (0,))], meta, PARAMS)
    with pytest.raises(ValueError, match="unknown input"):
        validate_circuit([CircuitOp("conjugate", ("y",))], meta, PARAMS)
    with pytest.raises(ValueError, match="negative rescale"):
        validate_circuit([CircuitOp("rescale", ("x",), dlogp=-8)],
                         meta, PARAMS)
    # the server wires metadata + key checks into submit_circuit
    for bad in ([CircuitOp("mul", ("x", "x")),
                 CircuitOp("add", (0, "x"))],       # scale mismatch
                [CircuitOp("rotate", ("x",), r=3)]):  # no key for r=3
        with pytest.raises((ValueError, KeyError)):
            server.submit_circuit(bad, {"x": x})
    # slot_sum key availability is checked up front too — through node
    # references (n_slots propagates), and before ANY sibling enqueues
    no_keys = _server((keys[0], keys[1], keys[2], {}))  # evk, no rot keys
    with pytest.raises(KeyError, match="slot_sum"):
        no_keys.submit_circuit(
            [CircuitOp("mod_down", ("x",),
                       logq2=PARAMS.logQ - PARAMS.logp),
             CircuitOp("slot_sum", (0,))], {"x": x})
    assert server.queue.depth == 0
    assert no_keys.queue.depth == 0
    assert not no_keys._circuits


# --------------------------------------------------------------------------
# circuit-aware scheduler (this PR's tentpole): lookahead co-batching,
# prefetch, and the drain-vs-circuit deadlock regression
# --------------------------------------------------------------------------

def test_circuit_schedule_predicts_actual_bucket_keys(keys):
    """The schedule the scheduler looks ahead at must be EXACTLY the
    bucket keys the nodes' requests land in — key drift would defer
    buckets for siblings that never arrive."""
    _, pk, _, _ = keys
    _, x = _enc(pk, 1)
    _, pt = _plain(2, PARAMS.logQ)
    lq = PARAMS.logQ - 2 * PARAMS.logp
    ops = [
        CircuitOp("mul", ("x", "x")),
        CircuitOp("rescale", (0,)),
        CircuitOp("mul_plain", (1,), pt=np.asarray(pt)[
            :, :PARAMS.qlimbs(PARAMS.logQ - PARAMS.logp)],
            pt_logp=x.logp),
        CircuitOp("rescale", (2,)),
        CircuitOp("mod_down", ("x",), logq2=lq),
        CircuitOp("rotate", (4,), r=1),
        CircuitOp("slot_sum", (5,)),
        CircuitOp("conjugate", (6,)),
        CircuitOp("add", (3, 7)),
    ]
    meta = {"x": (x.logq, x.logp)}
    _, predicted, nslots = circuit_schedule(ops, meta, {"x": x.n_slots},
                                            PARAMS)
    assert nslots == [8] * 9
    # replay every node through a real queue as its operands would
    # resolve, and compare the actual bucket keys (metadata-faithful
    # zero ciphertexts stand in for node outputs)
    node_meta = validate_circuit(ops, meta, PARAMS)
    from repro.core.cipher import Ciphertext as CT
    values = {"x": x}
    q = RequestQueue()
    for i, node in enumerate(ops):
        cts = tuple(values[a] for a in node.args)
        dlogp = node.dlogp or (PARAMS.logp if node.op == "rescale" else 0)
        rid = q.submit(node.op, cts, r=node.r, dlogp=dlogp,
                       logq2=node.logq2, pt=node.pt,
                       pt_logp=node.pt_logp
                       or (PARAMS.log_delta
                           if node.op == "mul_plain" else 0))
        (key, reqs), = ((k, d) for k, d in q._buckets.items()
                        if any(r.rid == rid for r in d))
        assert key == predicted[i], (i, node.op, key, predicted[i])
        q.pop_bucket(key, 8)
        lq_i, lp_i = node_meta[i]
        k = PARAMS.qlimbs(lq_i)
        z = jnp.zeros((PARAMS.N, k), dtype=np.asarray(x.ax).dtype)
        values[i] = CT(ax=z, bx=z, logq=lq_i, logp=lp_i, n_slots=8)


def test_scheduler_lookahead_expectations():
    """Unit-level: expectations count pending same-key nodes within the
    horizon, shrink as nodes enqueue/complete, and vanish when the
    circuit finishes (dangling nodes must not defer buckets forever)."""
    s = CircuitScheduler(lookahead=2)
    K0, K1 = ("mul", 120, None), ("rescale", 120, 30)
    # chain: n0 -> n1 -> n2 (n0/n2 share K0), n3 dangling on n0
    s.register(7, [K0, K1, K0, K1], [(), (0,), (1,), (0,)])
    # n0 is 1 step away (source, not yet enqueued); n2 is 3 away (> 2)
    assert s.expected_within(K0) == 1
    s.on_enqueued(7, 0)
    assert s.expected_within(K0) == 1      # n2 is 2 batches away
    assert s.expected_within(K0, horizon=1) == 0
    assert s.expected_within(K1) == 2      # n1 (1 away) + n3 (1 away)
    s.on_completed(7, 0)
    s.on_enqueued(7, 1)
    assert s.expected_within(K0, horizon=1) == 1   # n2 now 1 away
    s.on_completed(7, 1)
    s.on_enqueued(7, 2)
    assert s.expected_within(K0) == 0
    s.on_completed(7, 2)
    s.on_finished(7)                        # n3 never ran (dangling)
    assert s.expected_within(K1) == 0
    assert s.stats()["circuits_tracked"] == 0


def test_drain_completes_2deep_samekey_circuit_regression(keys):
    """The drain-vs-circuit deadlock: in [mul(x,x), mul(0,0)] BOTH nodes
    share one bucket key, so the only non-empty bucket 'expects a
    sibling' whose parent is the bucket itself — a deferral policy
    without the progress guarantee never serves it and drain() spins.
    Submitted right before drain(), under the scheduler, it must
    complete (and stay bitwise): fails on the pre-PR server."""
    _, pk, evk, _ = keys
    for overlap in (False, True):
        server = _server(keys, schedule=True, overlap=overlap)
        _, x = _enc(pk, 31)
        cid = server.submit_circuit(
            [CircuitOp("mul", ("x", "x")), CircuitOp("mul", (0, 0))],
            {"x": x})
        res = server.drain()
        assert server._inflight is None and not server._circuits
        r0 = H.he_mul(x, x, evk, PARAMS)
        ref = H.he_mul(r0, r0, evk, PARAMS)
        np.testing.assert_array_equal(np.asarray(res[cid].ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(res[cid].bx),
                                      np.asarray(ref.bx))
        assert server.scheduler.deferrals >= 1   # it DID defer, once,
        # then the progress guarantee flushed the bucket anyway


def test_scheduler_cobatches_staggered_circuits_and_stays_bitwise(keys, ck):
    """Two degree-4 circuits submitted one engine batch out of phase:
    unscheduled they trail each other with padded batches; scheduled,
    the lookahead deferral re-syncs them (cross-circuit co-batch rate
    up, mul padding no worse) without changing a single bit."""
    _, pk, _, _ = keys
    ops, _ = degree4_demo_circuit(PARAMS)
    outs, cob, pads = {}, {}, {}
    for schedule in (False, True):
        server = _server(keys, ck, schedule=schedule)
        _, x1 = _enc(pk, 60)
        _, x2 = _enc(pk, 61)
        c1 = server.submit_circuit(ops, {"x": x1})
        server.poll(flush=True)               # desync the pair
        c2 = server.submit_circuit(ops, {"x": x2})
        res = server.drain()
        s = server.stats()
        outs[schedule] = (res[c1], res[c2])
        cob[schedule] = s["cobatch"]
        pads[schedule] = s["per_op"]["mul"]["pad_frac"]
    # scheduled == unscheduled == the composed single-device core refs
    refs = [_degree4_reference(_enc(pk, s)[1], keys[2], ck)
            for s in (60, 61)]
    for got in (outs[False], outs[True]):
        for out, ref in zip(got, refs):
            np.testing.assert_array_equal(np.asarray(out.ax),
                                          np.asarray(ref.ax))
            np.testing.assert_array_equal(np.asarray(out.bx),
                                          np.asarray(ref.bx))
    assert cob[True]["cross_circuit_batches"] > \
        cob[False]["cross_circuit_batches"]
    assert cob[True]["cross_circuit_rate"] > cob[False]["cross_circuit_rate"]
    assert pads[True] <= pads[False]


def test_scheduler_prefetches_next_levels(keys, ck):
    """Dispatching a level-dropping batch prefetches the successor
    levels' table slices while the batch is in flight — the cache rows
    exist BEFORE the successor node's step ever runs."""
    _, pk, _, _ = keys
    server = _server(keys, ck, schedule=True)
    _, x = _enc(pk, 62)
    lq = PARAMS.logQ - PARAMS.logp
    cid = server.submit_circuit(
        [CircuitOp("mul", ("x", "x")), CircuitOp("rescale", (0,)),
         CircuitOp("conjugate", (1,))], {"x": x})
    assert not server.cache.has_level(lq)
    server.poll(flush=True)                   # runs the mul; prefetches
    assert server.cache.has_level(lq)         # before rescale/conj run
    assert server.scheduler.prefetches >= 1
    assert lq in server.scheduler.prefetched_levels
    res = server.drain()
    assert cid in res


# --------------------------------------------------------------------------
# continuous batching: age-based flush under a trickle (fake clock)
# --------------------------------------------------------------------------

def test_poll_trickle_regression_without_age_policy(keys):
    """The PR-2 bug this PR's policy subsumes: with drain-only flushing,
    a sub-batch trickle sits in the queue forever under poll()."""
    _, pk, _, _ = keys
    server = _server(keys)                    # max_age_s=None
    _, c1 = _enc(pk, 5)
    _, c2 = _enc(pk, 6)
    server.submit_mul(c1, c2)
    for _ in range(5):
        assert server.poll() == []            # never served
    assert server.queue.depth == 1


def test_trickle_served_within_age_deadline_fake_clock(keys):
    """With max_age_s set, a lone request is flushed (padded) the moment
    its age crosses the deadline — deterministic via an injected clock."""
    _, pk, _, _ = keys
    now = [0.0]
    server = _server(keys, max_age_s=5.0, adaptive_target=False,
                     clock=lambda: now[0])
    _, c1 = _enc(pk, 5)
    _, c2 = _enc(pk, 6)
    rid = server.submit_mul(c1, c2)           # t_submit = 0.0
    assert server.poll() == []                # age 0 < 5: keep waiting
    now[0] = 4.9
    assert server.poll() == []                # still under the deadline
    now[0] = 5.0
    done = server.poll()                      # deadline hit: padded flush
    assert [r for r, _ in done] == [rid]
    s = server.stats()
    assert s["flushes"] == {"full": 0, "age": 1, "drain": 0}
    assert s["per_op"]["mul"]["pad_frac"] == 0.5
    # latency is measured on the same clock: submit 0.0 → complete 5.0
    assert s["per_op"]["mul"]["latency_ms"]["p50"] == pytest.approx(5000.0)


def test_queue_submit_stamps_with_injected_clock(keys):
    """Bugfix regression: RequestQueue.submit's default t_submit must
    come from the queue's (injected) clock, not a module-level time
    call — direct queue submits on a fake-clock server otherwise stamp
    wall-clock times and skew every age-based flush decision. Fails on
    the pre-PR code (t_submit was time.perf_counter())."""
    _, pk, _, _ = keys
    now = [123.0]
    server = _server(keys, clock=lambda: now[0])
    _, c1 = _enc(pk, 5)
    _, c2 = _enc(pk, 6)
    server.queue.submit("mul", (c1, c2))      # direct, no t_submit
    rid2 = server.submit_mul(c1, c2)          # via the server
    reqs = server.queue.pop_bucket(("mul", PARAMS.logQ, None), 4)
    assert [r.t_submit for r in reqs] == [123.0, 123.0]
    assert reqs[1].rid == rid2
    # a standalone queue with its own injected clock behaves the same
    q = RequestQueue(clock=lambda: 7.0)
    q.submit("mul", (c1, c2))
    assert q.pop_bucket(("mul", PARAMS.logQ, None), 1)[0].t_submit == 7.0


def test_arrival_rate_decays_after_idle_gap():
    """Bugfix regression (queue level): with `now` and a decay window,
    arrivals older than the window are dropped, so the estimate reflects
    current traffic; one in-window arrival reports the sparse floor."""
    q = RequestQueue()
    for i in range(64):
        q._arrivals.append(i * 0.5)           # 2/s burst ending at 31.5
    assert q.arrival_rate() == pytest.approx(2.0)
    # idle gap: at t=50 with a 16 s window the burst is stale
    assert q.arrival_rate(now=50.0, window_s=16.0) is None
    assert len(q._arrivals) == 0              # window physically decayed
    q._arrivals.append(50.0)
    assert q.arrival_rate(now=50.0, window_s=16.0) \
        == pytest.approx(1 / 16.0)            # sparse-traffic floor
    # two arrivals on one (coarse/fake) clock tick must still count —
    # span == 0 on the decayed path may not fall back to None, or the
    # target re-inflates to a full batch with MORE traffic evidence
    q._arrivals.append(50.0)
    assert q.arrival_rate(now=50.0, window_s=16.0) \
        == pytest.approx(2 / 16.0)
    q._arrivals.append(54.0)
    assert q.arrival_rate(now=54.0, window_s=16.0) == pytest.approx(0.5)


def test_post_idle_trickle_flushes_at_adapted_target(keys):
    """Bugfix regression (server level): after a burst and an idle gap,
    a trickle request must flush at the adapted target immediately —
    pre-PR the arrival window kept the burst forever, the target stayed
    inflated, and every post-idle trickle request waited the full
    max_age_s before the age deadline flushed it."""
    _, _, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    now = [0.0]
    server = HEServer(PARAMS, evk, rks, mesh=mesh, batch=4,
                      max_age_s=2.0, clock=lambda: now[0])
    _, c1 = _enc(keys[1], 5)
    _, c2 = _enc(keys[1], 6)
    # burst: 64 requests at 2/s (span 31.5 s), all drained
    for i in range(64):
        now[0] = i * 0.5
        server.submit_mul(c1, c2)
    server.drain()
    server.reset_metrics()
    # idle gap, then a lone trickle request at t=50: the decayed rate
    # puts the target at 1, so it flushes on the next poll as "full" —
    # NOT after the 2 s age deadline
    now[0] = 50.0
    rid = server.submit_mul(c1, c2)
    assert server._bucket_target() == 1
    done = server.poll()
    assert [r for r, _ in done] == [rid]
    s = server.stats()
    assert s["flushes"]["age"] == 0
    # latency: served at submit time, not submit + max_age_s
    assert s["per_op"]["mul"]["latency_ms"]["max"] < 2000.0


def test_adaptive_bucket_target_flushes_below_batch(keys):
    """At a low observed arrival rate the full-bucket target shrinks to
    rate × max_age_s, so a bucket that will never fill stops waiting."""
    _, _, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    now = [0.0]
    server = HEServer(PARAMS, evk, rks, mesh=mesh, batch=4,
                      max_age_s=2.0, clock=lambda: now[0])
    _, c1 = _enc(keys[1], 5)
    _, c2 = _enc(keys[1], 6)
    server.submit_mul(c1, c2)                 # t = 0
    now[0] = 1.0
    server.submit_mul(c1, c2)                 # t = 1 → rate 1/s
    # target = ceil(1/s × 2s) = 2 < batch=4: the 2-deep bucket is "full"
    assert server._bucket_target() == 2
    done = server.poll()
    assert len(done) == 2
    assert server.stats()["flushes"]["full"] == 1


# --------------------------------------------------------------------------
# double buffering: overlap mode stays bitwise and drains clean
# --------------------------------------------------------------------------

def test_overlap_drain_bitwise_and_clean(keys):
    """overlap=True returns results one poll late but drain() retires
    everything; outputs stay bitwise identical to core."""
    _, pk, evk, _ = keys
    server = _server(keys, overlap=True)
    cases = []
    for i in range(5):                        # 3 batches at batch=2 (pad 1)
        _, c1 = _enc(pk, 70 + 2 * i)
        _, c2 = _enc(pk, 71 + 2 * i)
        cases.append((server.submit_mul(c1, c2),
                      H.he_mul(c1, c2, evk, PARAMS)))
    res = server.drain()
    assert server._inflight is None
    assert len(res) == 5
    for rid, ref in cases:
        np.testing.assert_array_equal(np.asarray(res[rid].ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(res[rid].bx),
                                      np.asarray(ref.bx))


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_metrics_roundtrip():
    m = ServeMetrics()
    m.record_depth(3)
    m.record_depth(1)
    m.record_batch("mul", 120, n_valid=3, n_pad=1, wall_s=0.5,
                   latencies_s=[0.1, 0.2, 0.3])
    m.record_batch("mul", 96, n_valid=4, n_pad=0, wall_s=0.5,
                   latencies_s=[0.4] * 4)
    m.record_batch("rotate", 120, n_valid=1, n_pad=3, wall_s=0.25,
                   latencies_s=[0.9])
    s = m.summary()
    mul = s["per_op"]["mul"]
    assert mul["batches"] == 2 and mul["requests"] == 7
    assert mul["ops_per_s"] == pytest.approx(7.0)
    assert mul["pad_frac"] == pytest.approx(1 / 8)
    assert mul["latency_ms"]["p50"] == pytest.approx(400.0)
    assert mul["latency_ms"]["p99"] <= mul["latency_ms"]["max"] == 400.0
    assert s["per_op"]["rotate"]["pad_frac"] == pytest.approx(0.75)
    assert s["levels_served"] == [96, 120]
    assert s["queue_depth"]["max"] == 3
    assert s["queue_depth"]["samples"] == 2


def test_server_stats_shape(keys):
    _, pk, _, _ = keys
    server = _server(keys)
    _, c1 = _enc(pk, 5)
    _, c2 = _enc(pk, 6)
    server.submit_mul(c1, c2)
    assert server.poll() == []                # batch=2 not yet full
    server.submit_mul(c1, c2)
    done = server.poll()                      # full bucket runs
    assert len(done) == 2
    st = server.stats()
    assert st["submitted"] == 2
    assert st["engine"]["steps_compiled"] == 1
    assert st["per_op"]["mul"]["pad_frac"] == 0.0


# --------------------------------------------------------------------------
# 8-device mesh parity (subprocess harness, as tests/test_dist.py)
# --------------------------------------------------------------------------

def test_hserve_ops_bitwise_on_8_device_mesh(run_in_8dev_subprocess):
    """Sharded hserve mul + rotate + conjugate + slot_sum — and the
    whole degree-4 submit_circuit chain (mul → rescale → mod-down →
    conjugate → add) — on a (2, 4) mesh are bitwise identical to the
    core references across the served levels."""
    res = run_in_8dev_subprocess("""
        from repro.core import heaan as H
        from repro.core import test_params
        from repro.core.keys import keygen
        from repro.core.rotate import conj_keygen, he_conjugate, \
            he_rotate, rot_keygen
        from repro.hserve import HEServer, slot_sum_rotations

        params = test_params(logN=5, beta_bits=32)
        sk, pk, evk = keygen(params, seed=0)
        rks = {r: rot_keygen(params, sk, r) for r in (1, 2, 4, 8)}
        ckey = conj_keygen(params, sk)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        server = HEServer(params, evk, rks, ckey, mesh=mesh, batch=2)

        rng = np.random.default_rng(7)
        n = 16
        def enc(seed):
            z = rng.normal(size=n) + 1j * rng.normal(size=n)
            return H.encrypt_message(z, pk, params, seed=seed)

        logq2 = params.logQ - params.logp
        cases = []
        for i in range(2):                       # two mul levels
            c1, c2 = enc(10 + 2 * i), enc(11 + 2 * i)
            if i:
                c1 = H.he_mod_down(c1, params, logq2)
                c2 = H.he_mod_down(c2, params, logq2)
            cases.append((server.submit_mul(c1, c2),
                          H.he_mul(c1, c2, evk, params)))
        ct = enc(30)
        cases.append((server.submit_rotate(ct, 1),
                      he_rotate(ct, 1, rks[1], params)))
        low = H.he_mod_down(ct, params, logq2)
        cases.append((server.submit_rotate(low, 2),
                      he_rotate(low, 2, rks[2], params)))
        cases.append((server.submit_conjugate(ct),
                      he_conjugate(ct, ckey, params)))
        cs = enc(40)
        acc = cs
        for r in slot_sum_rotations(cs.n_slots):
            acc = H.he_add(acc, he_rotate(acc, r, rks[r], params))
        cases.append((server.submit_slot_sum(cs), acc))

        # plaintext-operand ops: region-1-only, sharded, bitwise
        zp = rng.normal(size=n) + 1j * rng.normal(size=n)
        pt = H.encode_plain(zp, params, params.logQ)
        cp = enc(45)
        cases.append((server.submit_mul_plain(cp, pt),
                      H.he_mul_plain(cp, pt, params)))
        cases.append((server.submit_add_plain(cp, pt),
                      H.he_add_plain(cp, pt, params)))

        # degree-4 polynomial circuit, wholly server-side on the mesh
        # (the same shared acceptance circuit serve --circuit runs)
        from repro.hserve import degree4_demo_circuit
        x = enc(50)
        ops, lq = degree4_demo_circuit(params)
        cid = server.submit_circuit(ops, inputs={"x": x})
        r0 = H.rescale(H.he_mul(x, x, evk, params), params)
        r1 = H.rescale(H.he_mul(r0, r0, evk, params), params)
        r2 = he_conjugate(H.he_mod_down(r1, params, lq), ckey, params)
        cases.append((cid, H.he_add(
            r2, H.he_mod_down(x, params, lq))))

        res = server.drain()

        # the SAME degree-4 circuit under the circuit-aware scheduler
        # (co-batch deferral + table prefetch) must be bitwise identical
        # to the unscheduled serve above — scheduling reorders flushes,
        # never results. Same warm server: no recompilation.
        server.schedule = True
        x2 = enc(51)
        cid2a = server.submit_circuit(ops, inputs={"x": x2})
        server.poll(flush=True)                  # desync the pair
        cid2b = server.submit_circuit(ops, inputs={"x": x2})
        res2 = server.drain()
        sr0 = H.rescale(H.he_mul(x2, x2, evk, params), params)
        sr1 = H.rescale(H.he_mul(sr0, sr0, evk, params), params)
        sr2 = he_conjugate(H.he_mod_down(sr1, params, lq), ckey, params)
        sref = H.he_add(sr2, H.he_mod_down(x2, params, lq))
        sched_ok = all(
            bool((np.asarray(res2[c].ax) == np.asarray(sref.ax)).all()
                 and (np.asarray(res2[c].bx) == np.asarray(sref.bx)).all())
            for c in (cid2a, cid2b))

        ok = all(
            bool((np.asarray(res[rid].ax) == np.asarray(ref.ax)).all()
                 and (np.asarray(res[rid].bx) == np.asarray(ref.bx)).all())
            for rid, ref in cases)
        st = server.stats()
        print(json.dumps({
            "ok": ok, "sched_ok": sched_ok,
            "cross_circuit": st["cobatch"]["cross_circuit_batches"],
            "devices": len(jax.devices()),
            "levels": st["levels_served"],
            "steps": st["engine"]["steps_compiled"]}))
    """)
    assert res["devices"] == 8
    assert res["steps"] >= 10
    assert len(res["levels"]) >= 3
    assert res["ok"], "sharded hserve op diverged from core reference"
    assert res["sched_ok"], \
        "scheduled circuit diverged from the unscheduled/core reference"
    assert res["cross_circuit"] > 0, \
        "staggered circuits never co-batched under the scheduler"
