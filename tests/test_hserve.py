"""repro.hserve tests: queue invariants, level-slice table equality,
engine bitwise parity vs the single-device core references, metrics, and
the composed server loop.

The 8-device mesh parity check (sharded rotate/mul/slot-sum) runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8, same
harness as tests/test_dist.py.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.context import make_context
from repro.core.keys import keygen
from repro.core.rotate import he_rotate, rot_keygen
from repro.dist import he_pipeline as hp
from repro.hserve import (
    BatchAssembler, HEServer, RequestQueue, ServeMetrics, TableCache,
    slot_sum_rotations,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PARAMS = small_params(logN=4, beta_bits=32)   # N=16, n_slots=8, L=5


@pytest.fixture(scope="module")
def keys():
    sk, pk, evk = keygen(PARAMS, seed=0)
    rks = {r: rot_keygen(PARAMS, sk, r) for r in (1, 2, 4)}
    return sk, pk, evk, rks


def _enc(pk, seed, n=8):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=n) + 1j * rng.normal(size=n)
    return z, H.encrypt_message(z, pk, PARAMS, seed=seed)


# --------------------------------------------------------------------------
# queue: bucketing and padding invariants
# --------------------------------------------------------------------------

def test_queue_buckets_by_op_level_and_r(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    low2 = H.he_mod_down(c2, PARAMS, PARAMS.logQ - PARAMS.logp)
    r0 = q.submit("mul", (c1, c2))
    r1 = q.submit("mul", (c1, c2))
    q.submit("mul", (low, low2))            # different level, new bucket
    q.submit("rotate", (c1,), r=1)
    q.submit("rotate", (c1,), r=2)          # different r, new bucket
    q.submit("slot_sum", (c1,))
    assert q.depth == 6
    assert len(q.bucket_depths()) == 5
    # oldest bucket with >= 2 requests is the top-level mul bucket
    key = q.ready_key(2)
    assert key == ("mul", PARAMS.logQ, None)
    got = q.pop_bucket(key, 2)
    assert [r.rid for r in got] == [r0, r1]   # FIFO within the bucket
    assert q.ready_key(2) is None             # no other bucket is full
    assert q.any_key() is not None            # but work remains for flush


def test_server_rejects_unserveable_requests_at_submit(keys):
    """A request the engine cannot serve must never enter the queue —
    otherwise it fails mid-drain after being popped, taking the rest of
    the queued work down with it."""
    _, pk, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, c1 = _enc(pk, 1)
    server = HEServer(PARAMS, evk, {1: rks[1]}, mesh=mesh, batch=2)
    with pytest.raises(KeyError):
        server.submit_rotate(c1, 3)           # no key for r=3
    with pytest.raises(KeyError):
        server.submit_slot_sum(c1)            # needs r=2,4 too
    no_evk = HEServer(PARAMS, rot_keys=rks, mesh=mesh, batch=2)
    with pytest.raises(ValueError):
        no_evk.submit_mul(c1, c1)             # no evaluation key
    assert no_evk.submit_slot_sum(c1) == 0    # rotations fully keyed
    assert server.queue.depth == 0


def test_queue_rejects_bad_requests(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    with pytest.raises(ValueError):
        q.submit("frobnicate", (c1,))
    with pytest.raises(ValueError):
        q.submit("mul", (c1,))                # arity
    with pytest.raises(ValueError):
        q.submit("mul", (c1, low))            # level mismatch
    with pytest.raises(ValueError):
        q.submit("rotate", (c1,), r=0)        # no rotation amount


def test_assembler_pads_to_fixed_shape(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    for _ in range(3):
        q.submit("mul", (c1, c2))
    asm = BatchAssembler(batch=4)
    b = asm.assemble(q.pop_bucket(("mul", PARAMS.logQ, None), 4))
    assert b.size == 4 and b.n_valid == 3 and b.n_pad == 1
    assert set(b.arrays) == {"ax1", "bx1", "ax2", "bx2"}
    for v in b.arrays.values():
        assert v.shape == (4, PARAMS.N, PARAMS.qlimbs(PARAMS.logQ))
        assert not np.asarray(v[3]).any()     # padded lane is zeros
    # valid lanes carry the submitted operands, in request order
    np.testing.assert_array_equal(np.asarray(b.arrays["ax1"][0]),
                                  np.asarray(c1.ax))
    np.testing.assert_array_equal(np.asarray(b.arrays["bx2"][2]),
                                  np.asarray(c2.bx))
    # rotate batches carry one operand only
    q.submit("rotate", (c1,), r=1)
    b = asm.assemble(q.pop_bucket(("rotate", PARAMS.logQ, 1), 4))
    assert set(b.arrays) == {"ax1", "bx1"}
    assert b.n_valid == 1 and b.n_pad == 3


def test_assembler_rejects_mixed_and_oversize(keys):
    _, pk, _, _ = keys
    q = RequestQueue()
    _, c1 = _enc(pk, 1)
    _, c2 = _enc(pk, 2)
    low = H.he_mod_down(c1, PARAMS, PARAMS.logQ - PARAMS.logp)
    low2 = H.he_mod_down(c2, PARAMS, PARAMS.logQ - PARAMS.logp)
    q.submit("mul", (c1, c2))
    q.submit("mul", (low, low2))
    reqs = (q.pop_bucket(("mul", PARAMS.logQ, None), 4)
            + q.pop_bucket(("mul", PARAMS.logQ - PARAMS.logp, None), 4))
    asm = BatchAssembler(batch=4)
    with pytest.raises(ValueError):
        asm.assemble(reqs)                    # mixed buckets
    with pytest.raises(ValueError):
        BatchAssembler(batch=1).assemble(reqs[:1] * 2)  # oversize
    with pytest.raises(ValueError):
        asm.assemble([])


# --------------------------------------------------------------------------
# tables: level slices == freshly built per-level tables
# --------------------------------------------------------------------------

def test_table_cache_level_slices_match_fresh_tables(keys):
    """The resident-slice pytrees must be value-identical to
    region_tables built from a fresh per-level context at EVERY level —
    the whole bitwise-serving argument rests on this."""
    _, _, evk, _ = keys
    cache = TableCache(PARAMS, evk)
    for i in range(3):
        logq = PARAMS.logQ - i * PARAMS.logp
        t1, t2 = cache.level_tables(logq)
        ctx = make_context(PARAMS, logq)
        for region, cached in ((1, t1), (2, t2)):
            fresh = hp.region_tables(ctx, region)
            assert set(cached) == set(fresh) == set(hp.REGION_TABLE_KEYS)
            for k in fresh:
                np.testing.assert_array_equal(
                    np.asarray(cached[k]), np.asarray(jnp.asarray(fresh[k])),
                    err_msg=f"level {logq} region {region} table {k}")
    st = cache.stats()
    assert len(st["levels_materialized"]) == 3
    # second hit serves from cache
    before = cache.hits
    cache.level_tables(PARAMS.logQ)
    assert cache.hits == before + 1


def test_table_cache_keys_and_stats(keys):
    _, _, evk, rks = keys
    cache = TableCache(PARAMS, evk, {1: rks[1]})
    assert set(cache.evk()) == set(hp.EVK_TABLE_KEYS)
    assert set(cache.rot_key(1)) == set(hp.EVK_TABLE_KEYS)
    with pytest.raises(KeyError):
        cache.rot_key(2)
    cache.add_rot_key(2, rks[2])
    assert cache.rotation_amounts == [1, 2]
    assert cache.stats()["resident_mib"] > 0
    with pytest.raises(ValueError):
        TableCache(PARAMS).evk()


# --------------------------------------------------------------------------
# engine parity vs core, through the composed server (1-device mesh)
# --------------------------------------------------------------------------

def _server(keys, **kw):
    _, _, evk, rks = keys
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return HEServer(PARAMS, evk, rks, mesh=mesh, batch=2, **kw)


def test_served_mul_bitwise_equals_core_at_two_levels(keys):
    sk, pk, evk, _ = keys
    server = _server(keys)
    cases = []
    for i, logq in enumerate((PARAMS.logQ, PARAMS.logQ - PARAMS.logp)):
        _, c1 = _enc(pk, 10 + 2 * i)
        _, c2 = _enc(pk, 11 + 2 * i)
        if logq < PARAMS.logQ:
            c1 = H.he_mod_down(c1, PARAMS, logq)
            c2 = H.he_mod_down(c2, PARAMS, logq)
        rid = server.submit_mul(c1, c2)
        cases.append((rid, H.he_mul(c1, c2, evk, PARAMS)))
    res = server.drain()
    for rid, ref in cases:
        out = res[rid]
        assert out.logq == ref.logq and out.logp == ref.logp
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_served_rotate_bitwise_equals_core(keys):
    sk, pk, _, rks = keys
    server = _server(keys)
    _, ct = _enc(pk, 42)
    low = H.he_mod_down(ct, PARAMS, PARAMS.logQ - PARAMS.logp)
    cases = [(server.submit_rotate(ct, 1),
              he_rotate(ct, 1, rks[1], PARAMS)),
             (server.submit_rotate(low, 2),
              he_rotate(low, 2, rks[2], PARAMS))]
    res = server.drain()
    for rid, ref in cases:
        out = res[rid]
        np.testing.assert_array_equal(np.asarray(out.ax),
                                      np.asarray(ref.ax))
        np.testing.assert_array_equal(np.asarray(out.bx),
                                      np.asarray(ref.bx))


def test_served_slot_sum_bitwise_equals_core_composition(keys):
    sk, pk, _, rks = keys
    server = _server(keys)
    z, ct = _enc(pk, 77)
    rid = server.submit_slot_sum(ct)
    # reference: acc ← he_add(acc, he_rotate(acc, r)) for doubling r
    acc = ct
    for r in slot_sum_rotations(ct.n_slots):
        acc = H.he_add(acc, he_rotate(acc, r, rks[r], PARAMS))
    out = server.drain()[rid]
    np.testing.assert_array_equal(np.asarray(out.ax), np.asarray(acc.ax))
    np.testing.assert_array_equal(np.asarray(out.bx), np.asarray(acc.bx))
    got = H.decrypt_message(out, sk, PARAMS)
    np.testing.assert_allclose(got.real, np.full(8, z.real.sum()),
                               atol=1e-2)


def test_served_mul_with_kernels_bitwise(keys):
    """The Pallas-routed engine path (satellite: use_kernels through the
    batched stage wrappers) keeps the bitwise contract."""
    _, pk, evk, _ = keys
    server = _server(keys, use_kernels=True)
    _, c1 = _enc(pk, 91)
    _, c2 = _enc(pk, 92)
    rid = server.submit_mul(c1, c2)
    ref = H.he_mul(c1, c2, evk, PARAMS)
    out = server.drain()[rid]
    np.testing.assert_array_equal(np.asarray(out.ax), np.asarray(ref.ax))
    np.testing.assert_array_equal(np.asarray(out.bx), np.asarray(ref.bx))


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_metrics_roundtrip():
    m = ServeMetrics()
    m.record_depth(3)
    m.record_depth(1)
    m.record_batch("mul", 120, n_valid=3, n_pad=1, wall_s=0.5,
                   latencies_s=[0.1, 0.2, 0.3])
    m.record_batch("mul", 96, n_valid=4, n_pad=0, wall_s=0.5,
                   latencies_s=[0.4] * 4)
    m.record_batch("rotate", 120, n_valid=1, n_pad=3, wall_s=0.25,
                   latencies_s=[0.9])
    s = m.summary()
    mul = s["per_op"]["mul"]
    assert mul["batches"] == 2 and mul["requests"] == 7
    assert mul["ops_per_s"] == pytest.approx(7.0)
    assert mul["pad_frac"] == pytest.approx(1 / 8)
    assert mul["latency_ms"]["p50"] == pytest.approx(400.0)
    assert mul["latency_ms"]["p99"] <= mul["latency_ms"]["max"] == 400.0
    assert s["per_op"]["rotate"]["pad_frac"] == pytest.approx(0.75)
    assert s["levels_served"] == [96, 120]
    assert s["queue_depth"]["max"] == 3
    assert s["queue_depth"]["samples"] == 2


def test_server_stats_shape(keys):
    _, pk, _, _ = keys
    server = _server(keys)
    _, c1 = _enc(pk, 5)
    _, c2 = _enc(pk, 6)
    server.submit_mul(c1, c2)
    assert server.poll() == []                # batch=2 not yet full
    server.submit_mul(c1, c2)
    done = server.poll()                      # full bucket runs
    assert len(done) == 2
    st = server.stats()
    assert st["submitted"] == 2
    assert st["engine"]["steps_compiled"] == 1
    assert st["per_op"]["mul"]["pad_frac"] == 0.0


# --------------------------------------------------------------------------
# 8-device mesh parity (subprocess harness, as tests/test_dist.py)
# --------------------------------------------------------------------------

def _run_subprocess(body: str) -> dict:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        import repro.core
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_hserve_ops_bitwise_on_8_device_mesh():
    """Sharded hserve mul + rotate + slot_sum on a (2, 4) mesh are
    bitwise identical to the core references at two served levels."""
    res = _run_subprocess("""
        from repro.core import heaan as H
        from repro.core import test_params
        from repro.core.keys import keygen
        from repro.core.rotate import he_rotate, rot_keygen
        from repro.hserve import HEServer, slot_sum_rotations

        params = test_params(logN=5, beta_bits=32)
        sk, pk, evk = keygen(params, seed=0)
        rks = {r: rot_keygen(params, sk, r) for r in (1, 2, 4, 8)}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        server = HEServer(params, evk, rks, mesh=mesh, batch=2)

        rng = np.random.default_rng(7)
        n = 16
        def enc(seed):
            z = rng.normal(size=n) + 1j * rng.normal(size=n)
            return H.encrypt_message(z, pk, params, seed=seed)

        logq2 = params.logQ - params.logp
        cases = []
        for i in range(2):                       # two mul levels
            c1, c2 = enc(10 + 2 * i), enc(11 + 2 * i)
            if i:
                c1 = H.he_mod_down(c1, params, logq2)
                c2 = H.he_mod_down(c2, params, logq2)
            cases.append((server.submit_mul(c1, c2),
                          H.he_mul(c1, c2, evk, params)))
        ct = enc(30)
        cases.append((server.submit_rotate(ct, 1),
                      he_rotate(ct, 1, rks[1], params)))
        low = H.he_mod_down(ct, params, logq2)
        cases.append((server.submit_rotate(low, 2),
                      he_rotate(low, 2, rks[2], params)))
        cs = enc(40)
        acc = cs
        for r in slot_sum_rotations(cs.n_slots):
            acc = H.he_add(acc, he_rotate(acc, r, rks[r], params))
        cases.append((server.submit_slot_sum(cs), acc))

        res = server.drain()
        ok = all(
            bool((np.asarray(res[rid].ax) == np.asarray(ref.ax)).all()
                 and (np.asarray(res[rid].bx) == np.asarray(ref.bx)).all())
            for rid, ref in cases)
        print(json.dumps({
            "ok": ok, "devices": len(jax.devices()),
            "levels": server.stats()["levels_served"],
            "steps": server.stats()["engine"]["steps_compiled"]}))
    """)
    assert res["devices"] == 8
    assert res["steps"] >= 5
    assert len(res["levels"]) == 2
    assert res["ok"], "sharded hserve op diverged from core reference"
