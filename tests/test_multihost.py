"""Multi-host serving tier: fault injection and telemetry merging.

The disaggregated frontend/worker split (repro.hserve.frontend /
worker / transport) must keep the serving contract under every failure
the tier is built for:

  - a worker killed MID-BATCH (computed but undelivered) requeues its
    in-flight requests and the stream re-serves bitwise identically;
  - a worker killed while holding the only warm table slices for a
    level re-routes to a cold worker (compile + slice load) — still
    bitwise;
  - a drain with every worker dead raises the typed
    ``NoLiveWorkersError`` instead of hanging;
  - heartbeat staleness (fake clock, no real sleeps) is a death signal
    equivalent to a broken transport;
  - per-worker telemetry (registry snapshots, step monitors, heartbeat
    payloads) never collides across publishers.

The worker-death requeue contract runs on BOTH the in-process 1-device
harness and the (2, 4) 8-device subprocess harness
(``run_in_8dev_subprocess``, tests/conftest.py).
"""

import numpy as np
import pytest

import jax

from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.keys import keygen
from repro.core.rotate import rot_keygen
from repro.hserve import (
    HEFrontend, HEServer, NoLiveWorkersError, WorkerDied,
)
from repro.obs import MetricsRegistry, merge_snapshots
from repro.runtime.failures import FailureInjector
from repro.runtime.monitor import Heartbeat, StepMonitor

PARAMS = small_params(logN=4, beta_bits=32)   # N=16, n_slots=8, L=5


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _bitwise(a, b) -> bool:
    return bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
                and (np.asarray(a.bx) == np.asarray(b.bx)).all())


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def keys():
    sk, pk, evk = keygen(PARAMS, seed=0)
    return sk, pk, evk


@pytest.fixture(scope="module")
def pool(keys):
    """Pre-encrypted operands at the top level and one level down."""
    _, pk, _ = keys
    rng = np.random.default_rng(0)
    n = PARAMS.n_slots_max
    top = [H.encrypt_message(rng.normal(size=n) + 1j * rng.normal(size=n),
                             pk, PARAMS, seed=i + 1) for i in range(4)]
    lo = [H.he_mod_down(c, PARAMS, PARAMS.logQ - PARAMS.logp)
          for c in top]
    return top, lo


def _submit_stream(srv, top, lo, n_each: int = 4):
    """The canonical two-level mul stream; returns the rid order."""
    rids = []
    for i in range(n_each):
        rids.append(srv.submit_mul(top[i % len(top)],
                                   top[(i + 1) % len(top)]))
        rids.append(srv.submit_mul(lo[i % len(lo)],
                                   lo[(i + 1) % len(lo)]))
    return rids


@pytest.fixture(scope="module")
def reference(keys, pool):
    """The monolithic HEServer's outputs for the canonical stream."""
    _, _, evk = keys
    top, lo = pool
    srv = HEServer(PARAMS, evk, mesh=_mesh(), batch=2)
    rids = _submit_stream(srv, top, lo)
    res = srv.drain()
    return [res[r] for r in rids]


# --------------------------------------------------------------------------
# fault injection (in-process, 1 device, fake clocks — no real sleeps)
# --------------------------------------------------------------------------

def test_worker_killed_mid_batch_requeues_and_reserves_bitwise(
        keys, pool, reference):
    """Worker 0 dies right after its first dispatch: the batch was
    computed but never delivered. The frontend must requeue the exact
    in-flight requests and the full stream must come back bitwise
    identical to the monolith."""
    _, _, evk = keys
    top, lo = pool
    fe = HEFrontend(PARAMS, evk, mesh=_mesh(), batch=2, workers=2,
                    injector=FailureInjector(kill_worker_at={0: 1}))
    rids = _submit_stream(fe, top, lo)
    res = fe.drain()
    assert all(_bitwise(res[r], ref) for r, ref in zip(rids, reference))
    fr = fe.stats()["frontend"]
    assert fr["deaths"] == 1
    assert fr["requeued_requests"] == 2     # one full batch
    assert fr["alive"] == 1
    fe.close()


def test_kill_worker_with_only_warm_slice_reroutes_cold_bitwise(
        keys, pool, reference):
    """After a warm-up that pins the low level's only warm slices on
    worker 0, killing it forces the re-route onto worker 1 — a cold
    compile + table-slice load — and results must stay bitwise."""
    _, _, evk = keys
    top, lo = pool
    fe = HEFrontend(PARAMS, evk, mesh=_mesh(), batch=2, workers=2)
    # warm exactly one batch at the low level -> only worker 0 warm
    fe.submit_mul(lo[0], lo[1])
    fe.submit_mul(lo[1], lo[2])
    fe.drain()
    warm = [w for w in fe.workers if w.keys_warm]
    assert [w.wid for w in warm] == [0]
    compiled_before = fe.workers[1].transport.worker.engine.n_compiled
    fe.workers[0].transport.kill()

    rids = _submit_stream(fe, top, lo)
    res = fe.drain()
    assert all(_bitwise(res[r], ref) for r, ref in zip(rids, reference))
    fr = fe.stats()["frontend"]
    assert fr["deaths"] == 1 and fr["alive"] == 1
    # worker 1 really did the cold work
    assert fe.workers[1].transport.worker.engine.n_compiled \
        > compiled_before
    assert all(k in fe.workers[1].keys_warm
               for k in fe.workers[0].keys_warm)
    fe.close()


def test_drain_with_all_workers_dead_raises_typed_error(keys, pool):
    """No live workers + queued work must be a typed, immediate error —
    never a hang waiting on replies that cannot come."""
    _, _, evk = keys
    top, lo = pool
    fe = HEFrontend(PARAMS, evk, mesh=_mesh(), batch=2, workers=2)
    for w in fe.workers:
        w.transport.kill()
    _submit_stream(fe, top, lo, n_each=1)
    with pytest.raises(NoLiveWorkersError) as ei:
        fe.drain()
    assert "no live workers" in str(ei.value)
    fe.close()


def test_heartbeat_timeout_declares_death_and_requeues(
        keys, pool, reference, tmp_path):
    """A worker whose heartbeat goes stale past the timeout is dead to
    the frontend: its in-flight batch requeues, and after the (test
    harness) revival the stream still serves bitwise. Pure fake clock —
    the test never sleeps."""
    _, _, evk = keys
    top, lo = pool
    clock = FakeClock()
    fe = HEFrontend(PARAMS, evk, mesh=_mesh(), batch=2, workers=2,
                    clock=clock, heartbeat_dir=str(tmp_path),
                    heartbeat_timeout=5.0)
    rids = _submit_stream(fe, top, lo)
    got = dict(fe.poll(flush=True))       # one batch lands on worker 0
    assert fe.workers[0].pending is not None

    clock.advance(6.0)                    # both beats now stale
    fe.check_workers()
    fr = fe.stats()["frontend"]
    assert fr["alive"] == 0 and fr["deaths"] == 2
    assert fr["requeued_requests"] == 2   # worker 0's in-flight batch

    # revive (in-process harness), re-beat on the advanced clock, and
    # the requeued stream must complete bitwise
    fe.revive_workers()
    for w in fe.workers:
        w.transport.worker._beat()
    res = fe.drain()
    res.update(got)
    assert all(_bitwise(res[r], ref) for r, ref in zip(rids, reference))
    fe.close()


def test_transport_kill_mid_batch_drops_computed_reply(keys, pool):
    """The in-process transport's kill() models death-after-compute:
    the reply exists, then vanishes — recv must raise WorkerDied."""
    _, _, evk = keys
    top, _ = pool
    fe = HEFrontend(PARAMS, evk, mesh=_mesh(), batch=2, workers=1)
    fe.submit_mul(top[0], top[1])
    fe.submit_mul(top[1], top[2])
    fe.poll(flush=True)                   # dispatch (reply buffered)
    w = fe.workers[0]
    assert w.pending is not None
    w.transport.kill()
    with pytest.raises(WorkerDied):
        w.transport.recv()
    fe.close()


# --------------------------------------------------------------------------
# subprocess transport (a real process boundary)
# --------------------------------------------------------------------------

def test_subprocess_workers_serve_bitwise(keys, pool, reference):
    """One spawned worker process, frames over stdin/stdout: the same
    stream (muls at two levels + a rotate through an init-shipped key)
    must serve bitwise identical to the monolith."""
    sk, _, evk = keys
    top, lo = pool
    rk = {1: rot_keygen(PARAMS, sk, 1)}
    ref_srv = HEServer(PARAMS, evk, rot_keys=rk, mesh=_mesh(), batch=2)
    fe = HEFrontend(PARAMS, evk, rot_keys=rk, transport="subprocess",
                    workers=1, batch=2)
    try:
        rids = _submit_stream(fe, top, lo, n_each=2)
        rot_rid = fe.submit_rotate(top[0], 1)
        res = fe.drain()

        ref_rids = _submit_stream(ref_srv, top, lo, n_each=2)
        ref_rot = ref_srv.submit_rotate(top[0], 1)
        ref_res = ref_srv.drain()
        assert all(_bitwise(res[r], ref_res[rr])
                   for r, rr in zip(rids, ref_rids))
        assert _bitwise(res[rot_rid], ref_res[ref_rot])
        assert fe.stats()["frontend"]["transport"] == "subprocess"
    finally:
        fe.close()


def test_subprocess_worker_respawn_restores_full_strength(keys, pool):
    """Worker restart/rejoin (the ROADMAP open item): a REAL subprocess
    worker is SIGKILLed mid-drain (after its first dispatch — the batch
    was computed but never delivered), the stream must complete on the
    survivor via requeue, and `revive_workers()` must respawn the dead
    process, replay the key/table init frame, and return the fleet to
    full strength — with the re-served stream bitwise identical and the
    respawned worker (blank interpreter, cold engine) really serving."""
    sk, _, evk = keys
    top, lo = pool
    rk = {1: rot_keygen(PARAMS, sk, 1)}

    ref_srv = HEServer(PARAMS, evk, rot_keys=rk, mesh=_mesh(), batch=2)
    ref_rids = _submit_stream(ref_srv, top, lo, n_each=2)
    ref_rot = ref_srv.submit_rotate(top[0], 1)
    ref_res = ref_srv.drain()

    fe = HEFrontend(PARAMS, evk, rot_keys=rk, transport="subprocess",
                    workers=2, batch=2,
                    injector=FailureInjector(kill_worker_at={0: 1}))
    try:
        dead_proc = fe.workers[0].transport.proc
        rids = _submit_stream(fe, top, lo, n_each=2)
        res = fe.drain()                     # worker 0 dies mid-drain
        assert dead_proc.poll() is not None, "process still alive"
        fr = fe.stats()["frontend"]
        assert fr["deaths"] == 1 and fr["alive"] == 1
        assert fr["requeued_requests"] > 0
        assert all(_bitwise(res[r], ref_res[rr])
                   for r, rr in zip(rids, ref_rids))

        fe.revive_workers()
        assert fe.stats()["frontend"]["alive"] == 2
        w0 = fe.workers[0]
        assert w0.transport.proc is not dead_proc     # a NEW process
        assert w0.transport.alive
        assert w0.keys_warm == set()         # blank interpreter again

        rids = _submit_stream(fe, top, lo, n_each=2)
        rot_rid = fe.submit_rotate(top[0], 1)   # init replay shipped rk
        res = fe.drain()
        assert all(_bitwise(res[r], ref_res[rr])
                   for r, rr in zip(rids, ref_rids))
        assert _bitwise(res[rot_rid], ref_res[ref_rot])
        # full strength means the respawned worker actually served
        assert w0.keys_warm, "respawned worker never took a batch"
    finally:
        fe.close()


# --------------------------------------------------------------------------
# 8-device mesh: worker-death requeue on a sharded (2, 4) fleet
# --------------------------------------------------------------------------

def test_worker_death_requeue_on_8_device_mesh(run_in_8dev_subprocess):
    """The mid-batch kill contract on the sharded harness: a (2, 4)
    mesh frontend with two workers, worker 0 killed after its first
    dispatch — requeued stream bitwise identical to the monolith on
    the same mesh."""
    res = run_in_8dev_subprocess("""
        from repro.core import heaan as H
        from repro.core import test_params
        from repro.core.keys import keygen
        from repro.hserve import HEFrontend, HEServer
        from repro.runtime.failures import FailureInjector

        params = test_params(logN=5, beta_bits=32)
        sk, pk, evk = keygen(params, seed=0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        n = params.n_slots_max
        pool = [H.encrypt_message(
            rng.normal(size=n) + 1j * rng.normal(size=n), pk, params,
            seed=i + 1) for i in range(4)]
        lo = [H.he_mod_down(c, params, params.logQ - params.logp)
              for c in pool]

        def stream(srv):
            rids = []
            for i in range(4):
                rids.append(srv.submit_mul(pool[i % 4],
                                           pool[(i + 1) % 4]))
                rids.append(srv.submit_mul(lo[i % 4], lo[(i + 1) % 4]))
            return rids

        ref_srv = HEServer(params, evk, mesh=mesh, batch=2)
        ref_rids = stream(ref_srv)
        ref_res = ref_srv.drain()

        fe = HEFrontend(params, evk, mesh=mesh, batch=2, workers=2,
                        injector=FailureInjector(kill_worker_at={0: 1}))
        rids = stream(fe)
        res = fe.drain()
        ok = all(
            bool((np.asarray(res[r].ax)
                  == np.asarray(ref_res[rr].ax)).all()
                 and (np.asarray(res[r].bx)
                      == np.asarray(ref_res[rr].bx)).all())
            for r, rr in zip(rids, ref_rids))
        fr = fe.stats()["frontend"]
        print(json.dumps({
            "ok": ok, "devices": len(jax.devices()),
            "deaths": fr["deaths"],
            "requeued": fr["requeued_requests"],
            "alive": fr["alive"]}))
    """)
    assert res["devices"] == 8
    assert res["ok"], "requeued stream diverged on the 8-device mesh"
    assert res["deaths"] == 1
    assert res["requeued"] == 2
    assert res["alive"] == 1


# --------------------------------------------------------------------------
# telemetry merging under multi-publisher collisions
# --------------------------------------------------------------------------

def test_merge_snapshots_namespaces_colliding_labels():
    """Two workers both counting worker.batches (and both sourcing an
    "engine" sub-doc) must survive a merge without either clobbering
    the other."""
    r0, r1 = MetricsRegistry(), MetricsRegistry()
    r0.counter("worker.batches").inc(3)
    r1.counter("worker.batches").inc(5)
    r0.gauge("depth").set(1.0)
    r1.gauge("depth").set(2.0)
    r0.histogram("wall_s").add(0.1)
    r0.add_source("engine", lambda: {"steps_compiled": 1})
    r1.add_source("engine", lambda: {"steps_compiled": 7})

    doc = merge_snapshots({"worker0": r0.snapshot(),
                           "worker1": r1.snapshot()})
    assert doc["counters"]["worker0.worker.batches"] == 3
    assert doc["counters"]["worker1.worker.batches"] == 5
    assert doc["gauges"]["worker0.depth"] == 1.0
    assert doc["gauges"]["worker1.depth"] == 2.0
    assert "worker0.wall_s" in doc["histograms"]
    assert doc["worker0.engine"]["steps_compiled"] == 1
    assert doc["worker1.engine"]["steps_compiled"] == 7
    # top-level shape matches a single registry's snapshot
    assert set(doc) >= {"counters", "gauges", "histograms"}


def test_step_monitor_per_worker_children_are_independent():
    """One shared StepMonitor fed by two workers must not mix their
    step-time distributions: a straggling worker 1 may never make
    worker 0's normal steps read as breaches (or vice versa)."""
    mon = StepMonitor(warmup_steps=1, slack=2.0)
    # worker 0 runs 10ms steps, worker 1 runs 1s steps — wildly
    # different baselines that would poison a shared EMA
    for step in range(8):
        assert not mon.record(step, 0.010, worker=0)
        assert not mon.record(step, 1.0, worker=1)
    assert mon.for_worker(0).ema == pytest.approx(0.010, rel=1e-6)
    assert mon.for_worker(1).ema == pytest.approx(1.0, rel=1e-6)
    # a real breach still fires per publisher
    assert mon.record(99, 0.1, worker=0)
    assert not mon.record(99, 1.1, worker=1)
    # the shared baseline saw nothing
    assert mon.ema is None and mon.count == 0


def test_heartbeat_merges_multi_publisher_metrics(tmp_path):
    """A Heartbeat handed {publisher: registry} must namespace the
    embedded snapshot per publisher (and always write its first beat,
    whatever the interval)."""
    r0, r1 = MetricsRegistry(), MetricsRegistry()
    r0.counter("worker.batches").inc(2)
    r1.counter("worker.batches").inc(9)
    clock = FakeClock(100.0)
    hb = Heartbeat(str(tmp_path / "hb.json"), interval=10.0,
                   metrics={"worker0": r0, "worker1": r1}, clock=clock)
    hb.beat(step=0)                       # first beat always fires
    assert Heartbeat.is_alive(hb.path, timeout=5.0, now=100.1)
    assert not Heartbeat.is_alive(hb.path, timeout=5.0, now=200.0)

    import json as _json
    with open(hb.path) as f:
        doc = _json.load(f)
    assert doc["metrics"]["counters"]["worker0.worker.batches"] == 2
    assert doc["metrics"]["counters"]["worker1.worker.batches"] == 9
    # interval gating holds on the same clock
    r0.counter("worker.batches").inc()
    clock.advance(1.0)
    hb.beat(step=1)
    with open(hb.path) as f:
        assert _json.load(f)["step"] == 0   # gated: too soon
    clock.advance(10.0)
    hb.beat(step=2)
    with open(hb.path) as f:
        assert _json.load(f)["step"] == 2


def test_requeue_preserves_rids_and_fifo_order(pool):
    """RequestQueue.requeue puts the EXACT request objects back on
    their bucket (rids, t_submit, bookkeeping untouched)."""
    from repro.hserve import RequestQueue
    top, _ = pool
    q = RequestQueue()
    rids = [q.submit("mul", (top[i % 2], top[(i + 1) % 2]))
            for i in range(3)]
    key = ("mul", PARAMS.logQ, None)
    popped = q.pop_bucket(key, 3)
    assert [r.rid for r in popped] == rids
    submitted_before = q.submitted
    q.requeue(popped)
    assert q.submitted == submitted_before    # not re-counted
    again = q.pop_bucket(key, 3)
    assert [r.rid for r in again] == rids
    assert again[0] is popped[0]              # same objects, not copies
