"""NTT/iNTT and CRT/iCRT vs exact python-int oracles (paper Algos 1,3,5,6)."""

import numpy as np
import pytest

import jax.numpy as jnp

import random

from repro.core import test_params as small_params
from repro.core import make_context
from repro.core import crt as C
from repro.core import ntt as T
from repro.core.wordops import mont_modmul
from repro.nt.residue import limbs_to_int, ints_to_limb_array


def _ctx(beta, logN=4, logQ=120, logp=24):
    p = small_params(logN=logN, beta_bits=beta, logQ=logQ, logp=logp)
    return p, make_context(p, p.logQ)


def _negacyclic_ref(a, b, q):
    """Exact negacyclic convolution of int lists mod q (python ints)."""
    n = len(a)
    out = [0] * n
    for i in range(n):
        for j in range(n):
            k = i + j
            if k < n:
                out[k] += a[i] * b[j]
            else:
                out[k - n] -= a[i] * b[j]
    return [v % q for v in out]


@pytest.mark.parametrize("beta", [32, 64])
def test_ntt_roundtrip(beta):
    p, ctx = _ctx(beta)
    g = ctx.tables
    npn = ctx.np1
    N = ctx.N
    rng = np.random.default_rng(1)
    primes = np.asarray(g.primes[:npn]).astype(np.uint64)
    x = (rng.integers(0, 1 << 62, size=(npn, N)).astype(np.uint64)
         % primes[:, None]).astype(g.primes.dtype)
    xj = jnp.asarray(x)
    fwd = T.ntt(xj, jnp.asarray(g.psi_rev[:npn]),
                jnp.asarray(g.psi_rev_shoup[:npn]),
                jnp.asarray(g.primes[:npn]))
    back = T.intt(fwd, jnp.asarray(g.ipsi_rev[:npn]),
                  jnp.asarray(g.ipsi_rev_shoup[:npn]),
                  jnp.asarray(g.n_inv[:npn]), jnp.asarray(g.n_inv_shoup[:npn]),
                  jnp.asarray(g.primes[:npn]))
    np.testing.assert_array_equal(np.asarray(back), x)


@pytest.mark.parametrize("beta", [32, 64])
@pytest.mark.parametrize("modified", [False, True])
def test_ntt_negacyclic_convolution(beta, modified):
    """pointwise-in-eval-domain == negacyclic convolution (the real check)."""
    p, ctx = _ctx(beta)
    g = ctx.tables
    npn, N = ctx.np1, ctx.N
    rng = np.random.default_rng(2)
    a = [int(v) for v in rng.integers(0, 1 << 20, size=N)]
    b = [int(v) for v in rng.integers(0, 1 << 20, size=N)]
    primes_py = [int(v) for v in np.asarray(g.primes[:npn])]

    ra = np.stack([[ai % pj for ai in a] for pj in primes_py]).astype(
        g.primes.dtype)
    rb = np.stack([[bi % pj for bi in b] for pj in primes_py]).astype(
        g.primes.dtype)

    def fwd(x):
        return T.ntt(jnp.asarray(x), jnp.asarray(g.psi_rev[:npn]),
                     jnp.asarray(g.psi_rev_shoup[:npn]),
                     jnp.asarray(g.primes[:npn]), modified=modified)

    ea, eb = fwd(ra), fwd(rb)
    prod = mont_modmul(ea, eb, jnp.asarray(g.primes[:npn])[:, None],
                       jnp.asarray(g.pprime[:npn])[:, None],
                       jnp.asarray(g.r2[:npn])[:, None])
    back = T.intt(prod, jnp.asarray(g.ipsi_rev[:npn]),
                  jnp.asarray(g.ipsi_rev_shoup[:npn]),
                  jnp.asarray(g.n_inv[:npn]), jnp.asarray(g.n_inv_shoup[:npn]),
                  jnp.asarray(g.primes[:npn]), modified=modified)
    back = np.asarray(back)
    for j, pj in enumerate(primes_py):
        expect = _negacyclic_ref(a, b, pj)
        np.testing.assert_array_equal(back[j], np.array(expect, dtype=np.uint64)
                                      .astype(back.dtype), err_msg=f"prime {j}")


@pytest.mark.parametrize("beta", [32, 64])
@pytest.mark.parametrize("strategy", ["matmul", "shoup", "mod2", "mod4", "acc3"])
def test_crt_strategies(beta, strategy):
    if beta == 64 and strategy in ("matmul", "mod2", "mod4"):
        pytest.skip("wide-accumulator strategies are β=2^32 only")
    p, ctx = _ctx(beta)
    g = ctx.tables
    npn = ctx.np2
    K = ctx.qlimbs
    N = ctx.N
    pr = random.Random(3)
    vals = [pr.getrandbits(p.logQ) for _ in range(N)]
    x = ints_to_limb_array(vals, K, beta)
    out = C.crt(jnp.asarray(x), jnp.asarray(g.crt_tb[:npn, :K]),
                jnp.asarray(g.crt_tb_shoup[:npn, :K]),
                jnp.asarray(g.primes[:npn]), strategy=strategy)
    out = np.asarray(out)
    primes_py = [int(v) for v in np.asarray(g.primes[:npn])]
    for j, pj in enumerate(primes_py):
        expect = np.array([v % pj for v in vals], dtype=np.uint64)
        np.testing.assert_array_equal(out[j].astype(np.uint64), expect,
                                      err_msg=f"prime {j} strategy {strategy}")


@pytest.mark.parametrize("beta", [32, 64])
@pytest.mark.parametrize("strategy", ["matmul", "acc3", "naive"])
def test_crt_icrt_roundtrip_centered(beta, strategy):
    """CRT → iCRT returns the centered value (two's complement truncation)."""
    if beta == 64 and strategy == "matmul":
        pytest.skip("matmul iCRT is β=2^32 only")
    p, ctx = _ctx(beta)
    g = ctx.tables
    npn = ctx.np1
    tabs = ctx.icrt1
    K = ctx.qlimbs
    N = ctx.N
    pr = random.Random(4)
    # signed values with magnitude < P/2 (and < 2^(K·β-1) for truncation)
    mag = min(tabs.P_int // 2, 1 << (K * beta - 2))
    vals = [pr.randrange(-mag, mag) for _ in range(N)]
    vals[:3] = [0, 1, -1]  # boundary cases near the float-quotient edge
    res = np.stack([
        np.array([v % pj for v in vals], dtype=np.uint64)
        for pj in [int(q) for q in np.asarray(g.primes[:npn])]
    ]).astype(g.primes.dtype)
    out = C.icrt(jnp.asarray(res), tabs,
                 jnp.asarray(g.primes[:npn]),
                 jnp.asarray(tabs.inv_P), jnp.asarray(tabs.inv_P_shoup),
                 jnp.asarray(tabs.pdivp), jnp.asarray(tabs.P_limbs),
                 jnp.asarray(tabs.P_half_limbs),
                 jnp.asarray(g.p_inv_f64[:npn]),
                 out_limbs=K, strategy=strategy)
    out = np.asarray(out)
    W_ = 1 << (K * beta)
    for n in range(N):
        got = limbs_to_int(out[n], beta)
        if got >= W_ // 2:
            got -= W_
        assert got == vals[n], (n, got, vals[n])
