"""repro.boot: the served CKKS bootstrapping pipeline.

Bootstrap is the repo's first APPROXIMATE served operation, so the
contract splits in two:

  - the pipeline itself is gated by an error bound
    (``BootstrapPlan.error_bound`` — documented in docs/BOOTSTRAP.md),
    property-tested over seeded random messages and plan shapes;
  - everything AROUND it stays bitwise: the mod_raise engine step pins
    against ``core.heaan.he_mod_raise`` (1-dev and the (2, 4) 8-dev
    mesh), and the refreshed ciphertext must run further muls bitwise
    identical to the core references at the raised level.

The served tests share one module-scoped server at the reference
small-param config (`boot_params`): the engine compile for the
pipeline's (op, level) cells is paid once, every drain after that is
steady state.
"""

import dataclasses
import math

import numpy as np
import pytest

import jax

import repro.core  # noqa: F401
from repro.analysis.dataflow import CircuitError
from repro.analysis.noise import estimate_noise
from repro.boot import (BOOT_STAGES, BootConfig, boot_params,
                        bootstrap_circuit, raise_target)
from repro.boot.modraise import interval_bound
from repro.boot.pipeline import _auto_r
from repro.core import heaan as H
from repro.core.keys import keygen
from repro.core.rotate import conj_keygen, rot_keygen
from repro.hserve import HEServer
from repro.obs import Tracer

PARAMS = boot_params()              # logN=4, logQ=336, logp=24, h=2


def _msg(rng, bound, n=None):
    n = n or PARAMS.n_slots_max
    z = rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)
    return z * (bound / np.max(np.abs(z)))


def _exhausted(z, pk, seed):
    """Encrypt z and walk it down to logq == logp — the level-exhausted
    position auto-insertion targets (q_s = 1)."""
    ct = H.encrypt_message(z, pk, PARAMS, seed=seed)
    return H.he_mod_down(ct, PARAMS, PARAMS.logp)


class BootEnv:
    def __init__(self):
        self.sk, self.pk, self.evk = keygen(PARAMS, seed=0)
        self.rot = {r: rot_keygen(PARAMS, self.sk, r)
                    for r in (1, 2, 3, 4)}
        self.conj = conj_keygen(PARAMS, self.sk)
        self.tracer = Tracer()
        self.server = HEServer(
            PARAMS, self.evk, self.rot, self.conj,
            mesh=jax.make_mesh((1, 1), ("data", "model")),
            batch=2, schedule=True, tracer=self.tracer)
        self.plan = bootstrap_circuit(
            PARAMS, logq_in=PARAMS.logp,
            plain_lookup=self.server.cache.has_plain)

        # ---- the canonical concurrent run: two seeded bootstraps in
        # one drain (compiles every pipeline cell; later tests reuse)
        rng = np.random.default_rng(7)
        self.msgs = [_msg(rng, self.plan.msg_bound) for _ in range(2)]
        cts = [_exhausted(z, self.pk, seed=11 + i)
               for i, z in enumerate(self.msgs)]
        cids = [self.server.submit_bootstrap(ct, plan=self.plan)
                for ct in cts]
        res = self.server.drain()
        self.refreshed = [res[c] for c in cids]
        self.stats = self.server.stats()

    def decrypt(self, ct):
        return H.decrypt_message(ct, self.sk, PARAMS)


@pytest.fixture(scope="module")
def env():
    return BootEnv()


# ------------------------------------------------------- plan structure

def test_plan_stages_levels_and_requirements():
    plan = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp)
    assert len(plan.ops) == len(plan.meta) == len(plan.stages)
    assert plan.ops[0].op == "mod_raise"
    assert plan.ops[0].logq2 == PARAMS.logQ
    assert tuple(dict.fromkeys(plan.stages)) == BOOT_STAGES
    # the refreshed ciphertext gains whole levels at the plan's scale
    assert plan.out_logp == PARAMS.logp
    assert plan.levels_gained >= 2
    assert plan.out_logq == PARAMS.logp \
        + plan.levels_gained * PARAMS.logp
    # Galois requirements: conjugation (Re/Im split) + the BSGS strides
    assert ("conj",) in plan.requires
    assert {t[1] for t in plan.requires if t[0] == "rot"} \
        == {1, 2, 3, 4}
    # the error contract is meaningful: bounded, and well above the
    # fixed-point floor
    b = plan.error_bound()
    assert 0 < b < 2.0 ** -6
    assert b >= 4.0 * PARAMS.N * 2.0 ** -PARAMS.logp


def test_auto_r_covers_interval_and_config_overrides():
    plan = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp)
    theta = 2 * math.pi * interval_bound(PARAMS, plan.msg_bound)
    assert plan.r == _auto_r(PARAMS, plan.msg_bound)
    assert theta / 2.0 ** plan.r <= 1.1
    deeper = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp,
                               config=BootConfig(r=plan.r + 1))
    assert deeper.r == plan.r + 1
    # one more squaring costs one more level
    assert deeper.out_logq == plan.out_logq - PARAMS.logp
    # the bound is monotone in the message contract
    assert plan.error_bound(2.0 ** -4) > plan.error_bound(2.0 ** -6)


def test_full_slots_required():
    with pytest.raises(ValueError, match="full slots"):
        bootstrap_circuit(PARAMS, logq_in=PARAMS.logp,
                          n_slots=PARAMS.n_slots_max // 2)


def test_chain_too_short_is_a_circuit_error():
    small = dataclasses.replace(PARAMS, logQ=8 * PARAMS.logp)
    with pytest.raises(CircuitError):
        bootstrap_circuit(small, logq_in=small.logp)


def test_raise_target_validates_range():
    with pytest.raises(ValueError, match="cannot mod-raise"):
        raise_target(PARAMS, PARAMS.logQ)


def test_resolved_ops_backfills_hash_only_diagonals():
    plan = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp)
    hashed = [n for n in plan.ops if n.pt_hash is not None]
    assert hashed, "no cached plaintext operands in the plan?"
    # cross-stage dedup ships repeats hash-only (pt=None)...
    assert any(n.pt is None for n in hashed)
    # ...and resolved_ops() materializes every one of them for the
    # cacheless reference path
    assert all(n.pt is not None for n in plan.resolved_ops()
               if n.pt_hash is not None)


def test_repeat_plan_against_cache_ships_fully_hash_only():
    plan = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp)
    regs = set(plan.plain_registers)
    again = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp,
                              plain_lookup=lambda h, lq: (h, lq) in regs)
    assert all(n.pt is None for n in again.ops if n.pt_hash is not None)


# ------------------------------------------- queue / scheduler plumbing

def test_queue_rejects_non_raising_mod_raise(env):
    ct = _exhausted(env.msgs[0], env.pk, seed=50)
    with pytest.raises(ValueError, match="must exceed"):
        env.server.submit_mod_raise(ct, ct.logq)


def test_scheduler_prefetch_walks_up_through_mod_raise():
    from repro.hserve.scheduler import CircuitScheduler
    lv = CircuitScheduler.levels_for_key(("mod_raise", PARAMS.logp,
                                          PARAMS.logQ))
    assert lv == {PARAMS.logp, PARAMS.logQ}
    # descending ops still walk down
    assert CircuitScheduler.levels_for_key(("rescale", 72, 24)) \
        == {72, 48}


# ----------------------------------- the served pipeline (module server)

def test_served_error_contract_and_raised_level(env):
    bound = env.plan.error_bound()
    for z, out in zip(env.msgs, env.refreshed):
        assert (out.logq, out.logp) \
            == (env.plan.out_logq, env.plan.out_logp)
        err = float(np.max(np.abs(env.decrypt(out) - z)))
        assert err <= bound, f"{err:.3e} > documented bound {bound:.3e}"


def test_concurrent_bootstraps_cobatch_across_circuits(env):
    cb = env.stats["cobatch"]
    assert cb["circuit_nodes"] >= 2 * len(env.plan.ops)
    assert cb["cross_circuit_batches"] > 0
    assert cb["cross_circuit_rate"] > 0.0


def test_scheduler_prefetched_the_raised_level_tail(env):
    # the bootstrap's post-raise nodes live ABOVE logq_in: without the
    # mod_raise-aware prefetch they would all cold-miss the TableCache
    warmed = env.server.scheduler.prefetched_levels
    assert any(lv > env.plan.logq_in for lv in warmed), warmed


def test_boot_spans_attribute_all_four_stages(env):
    ev = [e for e in env.tracer.events if e.get("cat") == "boot"]
    assert {e["name"] for e in ev} \
        == {f"boot.{s}" for s in BOOT_STAGES}
    assert all(e["args"]["nodes"] >= 1 for e in ev)


def test_served_mod_raise_is_bitwise_vs_core(env):
    ct = _exhausted(env.msgs[0], env.pk, seed=60)
    rid = env.server.submit_mod_raise(ct, PARAMS.logQ)
    got = env.server.drain()[rid]
    ref = H.he_mod_raise(ct, PARAMS, PARAMS.logQ)
    np.testing.assert_array_equal(np.asarray(got.ax), np.asarray(ref.ax))
    np.testing.assert_array_equal(np.asarray(got.bx), np.asarray(ref.bx))
    assert got.logq == PARAMS.logQ


def test_refreshed_ciphertext_runs_two_muls_bitwise_vs_core(env):
    """The error contract covers the bootstrap itself; AFTER it the
    refreshed ciphertext is an ordinary ciphertext — two further served
    muls (with rescales) must pin bitwise against the core references
    at the raised levels."""
    out = env.refreshed[0]
    srv = env.server
    r1 = srv.submit_mul(out, out)
    sq = srv.drain()[r1]
    ref_sq = H.he_mul(out, out, env.evk, PARAMS)
    np.testing.assert_array_equal(np.asarray(sq.ax),
                                  np.asarray(ref_sq.ax))
    r2 = srv.submit_rescale(sq)
    sq = srv.drain()[r2]
    ref_sq = H.rescale(ref_sq, PARAMS)
    np.testing.assert_array_equal(np.asarray(sq.bx),
                                  np.asarray(ref_sq.bx))
    r3 = srv.submit_mul(sq, sq)
    q4 = srv.drain()[r3]
    ref_q4 = H.he_mul(ref_sq, ref_sq, env.evk, PARAMS)
    np.testing.assert_array_equal(np.asarray(q4.ax),
                                  np.asarray(ref_q4.ax))
    np.testing.assert_array_equal(np.asarray(q4.bx),
                                  np.asarray(ref_q4.bx))
    # and the refreshed level really affords both muls
    assert ref_q4.logq - PARAMS.logp >= PARAMS.logp
    # the squared message is still the squared message
    z2 = env.msgs[0] ** 2
    err = float(np.max(np.abs(H.decrypt_message(
        H.rescale(q4, PARAMS), env.sk, PARAMS) - z2 * z2)))
    assert err < 1e-3


def test_session_auto_insertion_serves_past_native_depth(env):
    """run(bootstrap="auto"): a mul on a level-exhausted input compiles
    with the pipeline spliced in front and the served result is the
    product — depth beyond the native budget, within the bound."""
    from repro.client.session import HESession
    s = HESession(PARAMS, env.sk, env.pk, env.evk, server=env.server)
    rng = np.random.default_rng(21)
    z = _msg(rng, env.plan.msg_bound)
    x = s.input(_exhausted(z, env.pk, seed=70))

    with pytest.raises(CircuitError, match="needs bootstrapping"):
        s.compile(x * x)
    cc = s.compile(x * x, bootstrap="auto")
    assert len(cc.bootstraps) == 1
    assert any(n.op == "mod_raise" for n in cc.ops)

    fut = s.run([x * x], bootstrap="auto")[0]
    got = s.decrypt(fut)
    # one bootstrap (≤ bound on the message) then an exact mul: the
    # product error is ~2·|z|·bound at first order
    tol = 4.0 * env.plan.msg_bound * env.plan.error_bound()
    assert float(np.max(np.abs(got - z * z))) <= tol


def test_auto_insertion_bootstraps_shared_operand_once(env):
    from repro.client.session import HESession
    s = HESession(PARAMS, env.sk, env.pk, env.evk, server=env.server)
    rng = np.random.default_rng(22)
    x = s.input(_exhausted(_msg(rng, env.plan.msg_bound),
                           env.pk, seed=71))
    cc = s.compile((x * x) + (x * 0.5), bootstrap="auto")
    assert len(cc.bootstraps) == 1          # x refreshed once, shared
    assert sum(n.op == "mod_raise" for n in cc.ops) == 1


# ------------------------- the noise estimator's upper-bound contract

N_RANDOM_PLANS = 50
SERVED_EVERY = 10       # every 10th plan also runs served


def test_noise_upper_bound_contract_on_50_random_boot_circuits(env):
    """50 seeded random circuits containing a bootstrap (random message
    bound / squaring count → different plan DAGs: the squarings change
    the EvalMod chain and the level schedule). Statically, the
    analyzer's noise propagation must stay finite and the TOTAL
    documented contract — arithmetic noise bound + the plan's
    approximation bound — must promise usable precision. Every
    SERVED_EVERY-th plan is also served end to end, and the measured
    error must respect that total bound."""
    rng = np.random.default_rng(1234)
    served = []
    for k in range(N_RANDOM_PLANS):
        mb = 2.0 ** -int(rng.integers(5, 8))
        cfg = BootConfig(r=int(_auto_r(PARAMS, mb) + rng.integers(0, 2)))
        plan = bootstrap_circuit(PARAMS, logq_in=PARAMS.logp,
                                 msg_bound=mb, config=cfg,
                                 plain_lookup=env.server.cache.has_plain)
        noise = estimate_noise(
            plan.ops, {plan.in_name: (plan.logq_in, plan.logp)}, PARAMS,
            input_bounds=mb, pt_bounds=plan.pt_bounds,
            input_nslots={plan.in_name: plan.n_slots}, meta=plan.meta)
        assert all(np.isfinite(nn.nu) and nn.nu > 0 for nn in noise)
        total = 2.0 ** noise[-1].error_bits + plan.error_bound()
        assert total < 2.0 ** -6, (
            f"plan {k}: contract {total:.3e} promises no precision")
        if k % SERVED_EVERY == 0:
            z = _msg(rng, mb)
            ct = _exhausted(z, env.pk, seed=300 + k)
            cid = env.server.submit_bootstrap(ct, plan=plan)
            served.append((k, z, cid, total))
    res = env.server.drain()
    for k, z, cid, total in served:
        err = float(np.max(np.abs(env.decrypt(res[cid]) - z)))
        assert err <= total, (
            f"plan {k}: measured {err:.3e} > contract {total:.3e}")


# ------------------------------------------------- the (2, 4) 8-dev mesh

def test_bootstrap_cobatch_and_mod_raise_on_8_device_mesh(
        run_in_8dev_subprocess):
    """The acceptance gate's 8-dev half: on a (2, 4) mesh, two
    concurrent bootstraps must co-batch across circuits (cross-circuit
    rate > 0) and land within the error bound — and the mod_raise
    engine step must stay bitwise vs core on the sharded mesh."""
    res = run_in_8dev_subprocess("""
        from repro.boot import boot_params, bootstrap_circuit
        from repro.core import heaan as H
        from repro.core.keys import keygen
        from repro.core.rotate import conj_keygen, rot_keygen
        from repro.hserve import HEServer

        params = boot_params()
        sk, pk, evk = keygen(params, seed=0)
        rot = {r: rot_keygen(params, sk, r) for r in (1, 2, 3, 4)}
        conj = conj_keygen(params, sk)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        srv = HEServer(params, evk, rot, conj, mesh=mesh, batch=2,
                       schedule=True)
        plan = bootstrap_circuit(params, logq_in=params.logp,
                                 plain_lookup=srv.cache.has_plain)

        rng = np.random.default_rng(7)
        n = params.n_slots_max
        zs, cts = [], []
        for i in range(2):
            z = rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)
            z *= plan.msg_bound / np.max(np.abs(z))
            ct = H.encrypt_message(z, pk, params, seed=11 + i)
            zs.append(z)
            cts.append(H.he_mod_down(ct, params, params.logp))
        cids = [srv.submit_bootstrap(ct, plan=plan) for ct in cts]
        res = srv.drain()
        errs = [float(np.max(np.abs(
            H.decrypt_message(res[c], sk, params) - z)))
            for c, z in zip(cids, zs)]
        cb = srv.stats()["cobatch"]

        rid = srv.submit_mod_raise(cts[0], params.logQ)
        got = srv.drain()[rid]
        ref = H.he_mod_raise(cts[0], params, params.logQ)
        mr_bitwise = bool(
            (np.asarray(got.ax) == np.asarray(ref.ax)).all()
            and (np.asarray(got.bx) == np.asarray(ref.bx)).all())
        print(json.dumps({
            "devices": len(jax.devices()),
            "max_err": max(errs), "bound": plan.error_bound(),
            "out_logq": [res[c].logq for c in cids],
            "cross_rate": cb["cross_circuit_rate"],
            "cross_batches": cb["cross_circuit_batches"],
            "mr_bitwise": mr_bitwise}))
    """)
    assert res["devices"] == 8
    assert res["max_err"] <= res["bound"]
    assert all(lq > boot_params().logp for lq in res["out_logq"])
    assert res["cross_batches"] > 0 and res["cross_rate"] > 0.0
    assert res["mr_bitwise"], "sharded mod_raise diverged from core"
