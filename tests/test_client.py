"""repro.client tests: lazy tracing + constant folding, the compile
pass (auto level alignment, CSE, hand-written-circuit equivalence), the
server-side plaintext-operand cache, futures/co-batching, and the
(2, 4) 8-device mesh harness for the acceptance expression.

The acceptance contract (ISSUE 5): a traced expression using every op
(mul, mul_plain, add, rotate, conjugate, slot_sum) with NO explicit
rescale/mod_down compiles to a valid level-aligned circuit and decrypts
bitwise-identical to (1) the hand-written CircuitOp list and (2) the
composed core.heaan references, on the 1-device and 8-device harnesses.
"""

import numpy as np
import pytest

import jax

from repro.client import (
    CipherHandle, HESession, PlainHandle, compile_handle,
)
from repro.client.testing import random_expr
from repro.core import heaan as H
from repro.core import test_params as small_params
from repro.core.encoding import message_hash
from repro.core.rotate import conj_keygen, he_conjugate, he_rotate, \
    rot_keygen
from repro.hserve import CircuitOp, HEServer
from repro.hserve.circuit import execute_circuit_reference

# logp=24 over logQ=120 leaves L=5: depth-2 traces keep two spare levels
PARAMS = small_params(logN=4, beta_bits=32, logQ=120, logp=24)


@pytest.fixture(scope="module")
def session():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return HESession(PARAMS, seed=0, mesh=mesh, batch=2)


@pytest.fixture(scope="module")
def galois(session):
    """Reference-side Galois keys — rot_keygen/conj_keygen are
    deterministic in (sk, r), so these are bit-identical to the keys
    HESession.ensure_keys loads into the server."""
    rks = {r: rot_keygen(PARAMS, session.sk, r) for r in (1, 2, 4)}
    return rks, conj_keygen(PARAMS, session.sk)


def _msg(seed, n=8, scale=0.5):
    rng = np.random.default_rng(seed)
    return scale * (rng.normal(size=n) + 1j * rng.normal(size=n))


def _bitwise(a, b):
    return bool((np.asarray(a.ax) == np.asarray(b.ax)).all()
                and (np.asarray(a.bx) == np.asarray(b.bx)).all())


# --------------------------------------------------------------------------
# tracing: laziness, folding, trace-time validation
# --------------------------------------------------------------------------

def test_trace_is_lazy_and_plain_arithmetic_folds(session):
    x = session.encrypt(_msg(1), seed=1)
    y = ((x * x) + x).rotate(1).conj().slot_sum() - 0.25
    assert isinstance(y, CipherHandle)
    assert session.server.queue.submitted == 0   # nothing reached the
    assert not session.server._circuits          # server while tracing
    # plain-plain arithmetic never traces: it folds eagerly in numpy
    p = (session.plain(2.0) + 1.0) * session.plain([1j] * 8)
    assert isinstance(p, PlainHandle)
    np.testing.assert_allclose(p.z, np.full(8, 3j))
    q = session.plain(np.arange(8.0)).rotate(2).conj().slot_sum()
    np.testing.assert_allclose(q.z, np.full(8, 28.0))


def test_trace_time_validation():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s1 = HESession(PARAMS, seed=0, mesh=mesh, batch=2)
    s2 = HESession(PARAMS, seed=1, mesh=mesh, batch=2)
    x1, x2 = s1.encrypt(_msg(1), seed=1), s2.encrypt(_msg(2), seed=2)
    with pytest.raises(ValueError, match="different sessions"):
        x1 * x2
    with pytest.raises(ValueError, match="positive left-rotation"):
        x1.rotate(0)
    with pytest.raises(ValueError, match="slots"):
        x1 + np.ones(4)                    # 4 plain slots vs 8
    with pytest.raises(TypeError, match="plain - cipher"):
        1.0 - x1
    with pytest.raises(ValueError, match="only input handles"):
        (x1 * x1).ciphertext


# --------------------------------------------------------------------------
# the compile pass: hand-written-circuit equivalence, CSE, alignment
# --------------------------------------------------------------------------

def _every_op_expr(x, w):
    """The acceptance expression: every traced op, no explicit level
    management anywhere."""
    return ((x * x) * w + x).rotate(1).conj().slot_sum()


def _every_op_shadow(z, w):
    return np.full(len(z), np.conj(np.roll(z * z * w + z, -1)).sum())


def test_compile_matches_hand_written_circuit(session):
    """The compiler must emit EXACTLY the CircuitOp list an expert would
    hand-write for the acceptance expression — rescale after each mul,
    one mod_down aligning x into the add, same bucket-relevant params."""
    z, w = _msg(3), _msg(4)
    x = session.encrypt(z, seed=3)
    cc = compile_handle(_every_op_expr(x, w), PARAMS)   # no cache lookup
    lq1 = PARAMS.logQ - PARAMS.logp                     # 96
    lq2 = lq1 - PARAMS.logp                             # 72
    hand = [
        CircuitOp("mul", ("in0", "in0")),
        CircuitOp("rescale", (0,), dlogp=PARAMS.logp),
        CircuitOp("mul_plain", (1,), pt_logp=PARAMS.log_delta,
                  pt_hash=message_hash(w, PARAMS.log_delta)),
        CircuitOp("rescale", (2,), dlogp=PARAMS.logp),
        CircuitOp("mod_down", ("in0",), logq2=lq2),
        CircuitOp("add", (3, 4)),
        CircuitOp("rotate", (5,), r=1),
        CircuitOp("conjugate", (6,)),
        CircuitOp("slot_sum", (7,)),
    ]
    assert cc.ops == hand          # pt is compare=False; pt_hash compares
    assert cc.ops[2].pt is not None
    assert (cc.out_logq, cc.out_logp) == (lq2, PARAMS.logp)
    assert ("evk",) in cc.requires and ("conj",) in cc.requires
    assert {("rot", 1), ("rot", 2), ("rot", 4)} <= cc.requires


def test_traced_bitwise_equals_hand_circuit_and_core(session, galois):
    """Acceptance: traced path == hand-submitted CircuitOp list ==
    composed core references, bitwise, and ≈ the plaintext shadow."""
    rks, ck = galois
    z, w = _msg(5), _msg(6)
    x = session.encrypt(z, seed=5)
    y = _every_op_expr(x, w)
    cc = compile_handle(y, PARAMS)          # materialized pts for the
    ref = execute_circuit_reference(        # reference + hand paths
        cc.ops, cc.inputs, PARAMS, evk=session.evk, rot_keys=rks,
        conj_key=ck)
    session.ensure_keys(cc.requires)
    hand_cid = session.server.submit_circuit(cc.ops, cc.inputs)
    (fut,) = session.run([y])               # co-batches with the hand one
    hand = session.drain()[hand_cid]
    traced = fut.result()
    assert _bitwise(traced, ref)
    assert _bitwise(traced, hand)
    got = session.decrypt(traced)
    np.testing.assert_allclose(got, _every_op_shadow(z, w), atol=1e-4)


def test_cse_dedupes_identical_subexpressions(session):
    x = session.encrypt(_msg(9), seed=9)
    y = (x * x) + (x * x)                  # distinct handles, same term
    cc = session.compile(y)
    assert [o.op for o in cc.ops] == ["mul", "rescale", "add"]
    assert cc.ops[2].args == (1, 1)
    # symmetric ops canonicalize operand order: x*y CSEs with y*x
    x2 = session.encrypt(_msg(10), seed=10)
    cc2 = session.compile((x * x2) + (x2 * x))
    assert [o.op for o in cc2.ops] == ["mul", "rescale", "add"]


def test_auto_mod_down_alignment_for_uneven_depths(session):
    """(x*x)*x: the second mul's operands live at different levels, so
    the compiler must mod_down x — verified structurally and by value."""
    z = _msg(11)
    x = session.encrypt(z, seed=11)
    cc = session.compile((x * x) * x)
    assert [o.op for o in cc.ops] == \
        ["mul", "rescale", "mod_down", "mul", "rescale"]
    assert cc.ops[2].args == ("in0",)
    got = session.decrypt((x * x) * x)
    np.testing.assert_allclose(got, z ** 3, atol=1e-4)


def test_level_alignment_for_sub(session):
    """sub of a deeper term against a shallow one: the compiler aligns
    levels with one mod_down on the shallow side (scales already match —
    the rescale-after-mul discipline keeps every scale at Δ)."""
    x = session.encrypt(_msg(12), seed=12)
    cc = session.compile((x * x) - x)
    ops = [o.op for o in cc.ops]
    assert ops == ["mul", "rescale", "mod_down", "sub"]
    assert cc.ops[3].args == (1, 2)        # sub is NOT re-ordered


def test_compile_rejects_over_deep_traces(session):
    x = session.encrypt(_msg(13), seed=13)
    y = x
    for _ in range(PARAMS.L):
        y = y * y
    with pytest.raises(ValueError, match="exhausts the modulus"):
        session.compile(y)


def test_run_is_atomic_on_compile_errors(session):
    """A compile error on ANY handle must leave zero circuits enqueued —
    otherwise earlier handles' futures are orphaned and their results
    unrecoverable."""
    x = session.encrypt(_msg(15), seed=15)
    too_deep = x
    for _ in range(PARAMS.L):
        too_deep = too_deep * too_deep
    before = session.server.queue.submitted
    with pytest.raises(ValueError, match="exhausts the modulus"):
        session.run([x * x, too_deep])
    assert session.server.queue.submitted == before
    assert not session.server._circuits
    assert not session._futures


def test_default_encrypt_seeds_are_fresh(session):
    """Two default-seeded encryptions must never share encryption
    randomness (identical ax would leak the message difference)."""
    z = _msg(16)
    c1 = session.encrypt(z).ciphertext
    c2 = session.encrypt(z).ciphertext
    assert not (np.asarray(c1.ax) == np.asarray(c2.ax)).all()


def test_rejected_plain_operand_does_not_poison_cache():
    """A pt that fails queue validation must NOT be registered — a
    later hash-only circuit would resolve the bad resident and fail
    mid-drain."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = HEServer(PARAMS, mesh=mesh, batch=2)
    s = HESession(PARAMS, seed=0, server=server)
    ct = s.encrypt(_msg(17), seed=17).ciphertext
    bad = np.zeros((4, 1), dtype=np.uint32)        # wrong shape
    with pytest.raises(ValueError, match="does not cover"):
        server.submit_mul_plain(ct, bad, pt_hash="h17")
    assert not server.cache.has_plain("h17", ct.logq)
    with pytest.raises(ValueError, match="no cached plaintext"):
        server.submit_circuit(
            [CircuitOp("mul_plain", ("x",), pt_logp=PARAMS.log_delta,
                       pt_hash="h17")], {"x": ct})


def test_run_submit_failure_leaves_results_recoverable():
    """If a LATER handle's submit fails (missing Galois key, pk-only
    session), already-enqueued circuits must not vanish into
    unreachable futures — their results come back from drain()."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.core.keys import keygen
    sk, pk, evk = keygen(PARAMS, seed=0)
    server = HEServer(PARAMS, evk, mesh=mesh, batch=2)
    s = HESession(PARAMS, sk=None, pk=pk, evk=evk, server=server)
    z = _msg(18)
    x = s.input(H.encrypt_message(z, pk, PARAMS, seed=18))
    with pytest.raises(KeyError):           # no rotation key, no sk
        s.run([x * x, x.rotate(1)])
    assert not s._futures                   # nothing orphaned
    raw = s.drain()                         # the x*x circuit completed
    (out,) = raw.values()
    ref = H.rescale(H.he_mul(x.ciphertext, x.ciphertext, evk, PARAMS),
                    PARAMS)
    assert _bitwise(out, ref)


def test_duplicate_plain_operand_encodes_once_per_trace(session):
    """One weight vector applied to several ciphertexts in ONE trace
    carries exactly one materialized encoding; repeats ship hash-only
    (the lower-index node registers at submission, before later nodes
    resolve)."""
    w = _msg(19)
    x1 = session.encrypt(_msg(80), seed=80)
    x2 = session.encrypt(_msg(81), seed=81)
    cc = compile_handle((x1 * w) + (x2 * w), PARAMS)
    plains = [(i, o) for i, o in enumerate(cc.ops)
              if o.op == "mul_plain"]
    assert len(plains) == 2
    assert sum(o.pt is not None for _, o in plains) == 1
    assert plains[0][1].pt is not None      # lowest index materializes
    assert len({o.pt_hash for _, o in plains}) == 1
    # and it serves correctly end to end
    got = session.run([(x1 * w) + (x2 * w)])[0].result()
    assert got is not None


def test_plain_cache_lru_eviction():
    """The plaintext cache is LRU-bounded: one-shot operands age out,
    counters record evictions, and re-registering is legal."""
    from repro.hserve import TableCache
    entry_bytes = np.zeros(
        (PARAMS.N, PARAMS.qlimbs(PARAMS.logQ)), np.uint32).nbytes
    cache = TableCache(PARAMS,
                       plain_cache_mib=2.5 * entry_bytes / 2**20)
    pts = [np.full((PARAMS.N, PARAMS.qlimbs(PARAMS.logQ)), i,
                   np.uint32) for i in range(3)]
    for i, pt in enumerate(pts):
        cache.put_plain(f"h{i}", PARAMS.logQ, pt)
    st = cache.stats()
    assert st["plain_evictions"] == 1
    assert st["plain_entries"] == 2
    assert not cache.has_plain("h0", PARAMS.logQ)   # oldest evicted
    with pytest.raises(KeyError):
        cache.get_plain("h0", PARAMS.logQ)
    cache.put_plain("h0", PARAMS.logQ, pts[0])      # re-register OK
    assert cache.has_plain("h0", PARAMS.logQ)       # (evicting h1)
    assert not cache.has_plain("h1", PARAMS.logQ)
    # LRU, not FIFO: touching h2 makes h0 the next victim
    cache.get_plain("h2", PARAMS.logQ)
    cache.put_plain("h3", PARAMS.logQ, pts[0])
    assert cache.has_plain("h2", PARAMS.logQ)
    assert not cache.has_plain("h0", PARAMS.logQ)


def test_run_rematerializes_after_lru_eviction_race():
    """A sibling's registration inside one run() can evict the entry a
    later handle compiled hash-only against; run() must re-materialize
    and serve correctly instead of raising."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    entry_mib = np.zeros(
        (PARAMS.N, PARAMS.qlimbs(PARAMS.logQ)), np.uint32).nbytes / 2**20
    server = HEServer(PARAMS, mesh=mesh, batch=2,
                      plain_cache_mib=1.5 * entry_mib)
    s = HESession(PARAMS, seed=0, server=server)
    z, w1, w2 = _msg(82), _msg(83), _msg(84)
    x = s.encrypt(z, seed=82)
    s.run([x * w1])[0].result()             # w1 cached
    f2, f1 = s.run([x * w2, x * w1])        # w2's registration evicts w1
    got1 = f1.result()
    ref = H.rescale(H.he_mul_plain(
        x.ciphertext, np.asarray(H.encode_plain(w1, PARAMS,
                                                x.ciphertext.logq)),
        PARAMS), PARAMS)
    assert _bitwise(got1, ref)
    assert f2.done()


def test_bare_input_needs_no_round_trip(session):
    x = session.encrypt(_msg(14), seed=14)
    (fut,) = session.run([x])
    assert fut.done() and fut.result() is x.ciphertext
    assert session.server.queue.depth == 0


# --------------------------------------------------------------------------
# the server-side plaintext-operand cache
# --------------------------------------------------------------------------

def test_plain_cache_hits_across_requests():
    """Affine-layer contract: the same weights at the same level encode
    and ship ONCE — the second traced run compiles to hash-only nodes
    and the server serves the operand from its (hash, level) cache."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = HESession(PARAMS, seed=0, mesh=mesh, batch=2)
    w = _msg(20)
    for i, expected_pt in ((0, True), (1, False)):
        x = s.encrypt(_msg(21 + i), seed=21 + i)
        cc = s.compile(x * w)
        assert (cc.ops[0].pt is not None) == expected_pt
        s.run([x * w])
    s.drain()
    st = s.stats()["cache"]
    assert st["plain_entries"] == 1
    assert st["plain_misses"] == 1
    assert st["plain_hits"] >= 1


def test_plain_cache_standalone_submit_and_unknown_hash():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = HEServer(PARAMS, mesh=mesh, batch=2)   # keyless: plain ops only
    s = HESession(PARAMS, seed=0, server=server)
    ct = s.encrypt(_msg(30), seed=30).ciphertext
    w = _msg(31)
    pt = H.encode_plain(w, PARAMS, ct.logq)
    h = message_hash(w, PARAMS.log_delta)
    r1 = server.submit_mul_plain(ct, pt, pt_hash=h)      # registers
    r2 = server.submit_mul_plain(ct, pt_hash=h)          # hash-only hit
    res = server.drain()
    assert _bitwise(res[r1], res[r2])
    assert server.cache.stats()["plain_hits"] == 1
    with pytest.raises(KeyError, match="no cached plaintext"):
        server.submit_mul_plain(ct, pt_hash="deadbeef")
    # a circuit referencing an unknown hash rejects BEFORE enqueue
    with pytest.raises(ValueError, match="no cached plaintext"):
        server.submit_circuit(
            [CircuitOp("mul_plain", ("x",), pt_logp=PARAMS.log_delta,
                       pt_hash="deadbeef")], {"x": ct})
    assert server.queue.depth == 0
    # ... and the same hash at a DIFFERENT level is a different entry
    low = H.he_mod_down(ct, PARAMS, ct.logq - PARAMS.logp)
    with pytest.raises(ValueError, match="no cached plaintext"):
        server.submit_circuit(
            [CircuitOp("mul_plain", ("x",), pt_logp=PARAMS.log_delta,
                       pt_hash=h)], {"x": low})


def test_plain_cache_bitwise_vs_core():
    """A cache-served mul_plain is bitwise the core reference (the
    cached buffer IS the encoding the client would have sent)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = HESession(PARAMS, seed=0, mesh=mesh, batch=2)
    z, w = _msg(32), _msg(33)
    x = s.encrypt(z, seed=32)
    first = (x * w).result()                 # registers the operand
    second = (x * w).result()                # served from the cache
    pt = H.encode_plain(w, PARAMS, x.ciphertext.logq)
    ref = H.rescale(H.he_mul_plain(x.ciphertext, pt, PARAMS), PARAMS)
    assert _bitwise(first, ref) and _bitwise(second, ref)


# --------------------------------------------------------------------------
# futures and co-batching
# --------------------------------------------------------------------------

def test_futures_cobatch_in_one_drain(session):
    """run([...]) submits without draining: two same-shape circuits
    co-batch node-for-node (batch=2 → zero padded lanes for mul)."""
    session.server.reset_metrics()
    z1, z2, w = _msg(40), _msg(41), _msg(42)
    x1 = session.encrypt(z1, seed=40)
    x2 = session.encrypt(z2, seed=41)
    f1, f2 = session.run([_every_op_expr(x1, w), _every_op_expr(x2, w)])
    assert not f1.done() and not f2.done()
    r1 = f1.result()                        # one drain resolves both
    assert f2.done()
    np.testing.assert_allclose(session.decrypt(r1),
                               _every_op_shadow(z1, w), atol=1e-4)
    np.testing.assert_allclose(f2.decrypt(),
                               _every_op_shadow(z2, w), atol=1e-4)
    st = session.stats()
    assert st["per_op"]["mul"]["pad_frac"] == 0.0
    assert st["cobatch"]["cross_circuit_batches"] > 0


def test_future_triggered_drain_buffers_raw_results(session):
    """A fut.result() that drains internally must NOT lose raw
    server-submit results — they stay buffered for the next explicit
    session.drain()."""
    z1, z2 = _msg(70), _msg(71)
    c1 = session.encrypt(z1, seed=70).ciphertext
    c2 = session.encrypt(z2, seed=71).ciphertext
    rid = session.server.submit_mul(c1, c2)
    x = session.encrypt(z1, seed=72)
    (fut,) = session.run([x * x])
    out = fut.result()                      # drains; raw result buffered
    assert out is not None
    raw = session.drain()
    assert rid in raw
    assert _bitwise(raw[rid], H.he_mul(c1, c2, session.evk, PARAMS))


def test_explicit_server_loads_passed_galois_keys():
    """rot_keys/conj_key passed alongside server= must load into that
    server's cache (a pk-only session cannot regenerate them)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.core.keys import keygen
    sk, pk, evk = keygen(PARAMS, seed=0)
    server = HEServer(PARAMS, evk, mesh=mesh, batch=2)
    rk = rot_keygen(PARAMS, sk, 1)
    ck = conj_keygen(PARAMS, sk)
    s = HESession(PARAMS, sk=None, pk=pk, evk=evk,
                  rot_keys={1: rk}, conj_key=ck, server=server)
    assert server.cache.rotation_amounts == [1]
    assert server.cache.has_conj_key
    z = _msg(73)
    x = s.input(H.encrypt_message(z, pk, PARAMS, seed=73))
    got = x.rotate(1).conj().result()       # no sk: keys must be loaded
    ref = he_conjugate(he_rotate(x.ciphertext, 1, rk, PARAMS), ck, PARAMS)
    assert _bitwise(got, ref)


def test_plain_cache_resident_is_read_only_and_aliased():
    """Cache-resolved operands alias the read-only resident buffer (no
    per-request copy) while caller-provided arrays are still copied."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = HEServer(PARAMS, mesh=mesh, batch=2)
    s = HESession(PARAMS, seed=0, server=server)
    ct = s.encrypt(_msg(74), seed=74).ciphertext
    w = _msg(75)
    # np.array: a WRITEABLE caller buffer (np.asarray of a jax array is
    # read-only), so the anti-aliasing copy path is what's exercised
    pt = np.array(H.encode_plain(w, PARAMS, ct.logq))
    h = message_hash(w, PARAMS.log_delta)
    server.submit_mul_plain(ct, pt, pt_hash=h)
    resident = server.cache.get_plain(h, ct.logq)
    assert not resident.flags.writeable
    rid = server.submit_mul_plain(ct, pt_hash=h)
    req = next(r for d in server.queue._buckets.values() for r in d
               if r.rid == rid)
    assert not req.pt.flags.writeable       # aliased, not re-copied
    assert np.shares_memory(req.pt, resident)
    # mutating the original caller buffer must not reach queued requests
    pt[0, 0] += 1
    res = server.drain()
    ref = H.he_mul_plain(ct, np.asarray(
        H.encode_plain(w, PARAMS, ct.logq)), PARAMS)
    assert _bitwise(res[rid], ref)


def test_random_traced_exprs_bitwise_vs_reference(session, galois):
    """Seeded random-walk traces (every op kind reachable) through the
    REAL server: bitwise == the composed core references on the same
    compiled circuit, and ≈ the plaintext shadow."""
    rks, ck = galois
    for seed in (0, 1, 2):
        rng = np.random.default_rng(seed)
        z1, z2 = _msg(50 + seed), _msg(60 + seed)
        leaves = [(session.encrypt(z1, seed=50 + seed), z1),
                  (session.encrypt(z2, seed=60 + seed), z2)]
        y, shadow = random_expr(rng, leaves, n_ops=4, max_depth=2)
        cc = compile_handle(y, PARAMS)
        ref = execute_circuit_reference(
            cc.ops, cc.inputs, PARAMS, evk=session.evk, rot_keys=rks,
            conj_key=ck)
        got = session.run([y])[0].result()
        assert _bitwise(got, ref), f"seed {seed} diverged from core"
        tol = 1e-3 * max(1.0, float(np.abs(shadow).max()))
        np.testing.assert_allclose(session.decrypt(got), shadow,
                                   atol=tol)


# --------------------------------------------------------------------------
# 8-device mesh harness (subprocess, as tests/test_hserve.py)
# --------------------------------------------------------------------------

def test_traced_client_bitwise_on_8_device_mesh(run_in_8dev_subprocess):
    """The acceptance expression AND seeded random traces, served by an
    HESession on a (2, 4) mesh: bitwise == composed core references,
    ≈ shadows, with a plaintext-cache hit on the repeated run."""
    res = run_in_8dev_subprocess("""
        from repro.client import HESession, compile_handle
        from repro.client.testing import random_expr
        from repro.core import test_params
        from repro.core.rotate import conj_keygen, rot_keygen
        from repro.hserve.circuit import execute_circuit_reference

        params = test_params(logN=5, beta_bits=32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        session = HESession(params, seed=0, mesh=mesh, batch=2)
        rks = {r: rot_keygen(params, session.sk, r) for r in (1, 2, 4, 8)}
        ck = conj_keygen(params, session.sk)
        n = params.n_slots_max

        def msg(seed):
            r = np.random.default_rng(seed)
            return 0.4 * (r.normal(size=n) + 1j * r.normal(size=n))

        checks, errs = [], []
        def run_one(y, shadow):
            cc = compile_handle(y, params)
            ref = execute_circuit_reference(
                cc.ops, cc.inputs, params, evk=session.evk,
                rot_keys=rks, conj_key=ck)
            got = session.run([y])[0].result()
            checks.append(bool(
                (np.asarray(got.ax) == np.asarray(ref.ax)).all()
                and (np.asarray(got.bx) == np.asarray(ref.bx)).all()))
            errs.append(float(np.abs(session.decrypt(got)
                                     - shadow).max()))

        # acceptance: every op, no explicit level management — TWICE
        # with the same weights (second run hits the plaintext cache)
        z, w = msg(1), msg(2)
        for seed in (1, 3):
            x = session.encrypt(z, seed=seed)
            run_one(((x * x) * w + x).rotate(1).conj().slot_sum(),
                    np.full(n, np.conj(np.roll(z * z * w + z,
                                               -1)).sum()))

        for seed in (0, 1):
            rng = np.random.default_rng(seed)
            z1, z2 = msg(10 + seed), msg(20 + seed)
            leaves = [(session.encrypt(z1, seed=10 + seed), z1),
                      (session.encrypt(z2, seed=20 + seed), z2)]
            y, shadow = random_expr(rng, leaves, n_ops=3, max_depth=1)
            run_one(y, shadow)

        st = session.stats()
        print(json.dumps({
            "ok": all(checks), "max_err": max(errs),
            "devices": len(jax.devices()),
            "plain_hits": st["cache"]["plain_hits"],
            "levels": st["levels_served"]}))
    """)
    assert res["devices"] == 8
    assert res["ok"], "traced client diverged from core on the 8-dev mesh"
    assert res["max_err"] < 1e-2
    assert res["plain_hits"] >= 1, "repeated weights never hit the cache"
    assert len(res["levels"]) >= 2
