"""Pallas kernels vs pure-jnp oracles: exact equality, shape sweeps.

Integer kernels — no tolerance. All run in interpret mode on CPU (the
kernel bodies execute exactly as they would lower for TPU).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import test_params as small_params
from repro.core import make_context
from repro.kernels.crt.ops import crt_op
from repro.kernels.crt.ref import crt_ref
from repro.kernels.icrt.ops import icrt_op
from repro.kernels.icrt.ref import icrt_ref
from repro.kernels.modmul.ops import pointwise_mont_op
from repro.kernels.modmul.ref import pointwise_mont_ref
from repro.kernels.ntt.ops import intt_op, ntt_op
from repro.kernels.ntt.ref import intt_ref, ntt_ref
from repro.nt.residue import ints_to_limb_array

import random


def _ctx(logN=5, logQ=120, logp=24):
    p = small_params(logN=logN, beta_bits=32, logQ=logQ, logp=logp)
    return p, make_context(p, p.logQ)


def _rand_residues(g, npn, N, seed=0):
    rng = np.random.default_rng(seed)
    primes = np.asarray(g.primes[:npn]).astype(np.uint64)
    return (rng.integers(0, 1 << 62, size=(npn, N)).astype(np.uint64)
            % primes[:, None]).astype(np.uint32)


@pytest.mark.parametrize("logN", [4, 5, 7, 9])
@pytest.mark.parametrize("modified", [False, True])
def test_ntt_kernel_matches_ref(logN, modified):
    p, ctx = _ctx(logN=logN)
    g = ctx.tables
    npn, N = ctx.np1, ctx.N
    x = jnp.asarray(_rand_residues(g, npn, N, seed=logN))
    args = (jnp.asarray(g.psi_rev[:npn]), jnp.asarray(g.psi_rev_shoup[:npn]),
            jnp.asarray(g.primes[:npn]))
    np.testing.assert_array_equal(
        np.asarray(ntt_op(x, *args, modified=modified)),
        np.asarray(ntt_ref(x, *args, modified=modified)))


@pytest.mark.parametrize("logN", [4, 5, 7, 9])
def test_intt_kernel_matches_ref_and_roundtrip(logN):
    p, ctx = _ctx(logN=logN)
    g = ctx.tables
    npn, N = ctx.np2, ctx.N
    x = jnp.asarray(_rand_residues(g, npn, N, seed=10 + logN))
    fargs = (jnp.asarray(g.psi_rev[:npn]), jnp.asarray(g.psi_rev_shoup[:npn]),
             jnp.asarray(g.primes[:npn]))
    iargs = (jnp.asarray(g.ipsi_rev[:npn]),
             jnp.asarray(g.ipsi_rev_shoup[:npn]),
             jnp.asarray(g.n_inv[:npn]), jnp.asarray(g.n_inv_shoup[:npn]),
             jnp.asarray(g.primes[:npn]))
    ev = ntt_op(x, *fargs)
    np.testing.assert_array_equal(np.asarray(intt_op(ev, *iargs)),
                                  np.asarray(intt_ref(ev, *iargs)))
    np.testing.assert_array_equal(np.asarray(intt_op(ev, *iargs)),
                                  np.asarray(x))


@pytest.mark.parametrize("logN,logQ", [(4, 96), (5, 120), (6, 240)])
@pytest.mark.parametrize("strategy", ["acc3", "mod2", "mod4"])
def test_crt_kernel_matches_ref(logN, logQ, strategy):
    p, ctx = _ctx(logN=logN, logQ=logQ)
    g = ctx.tables
    npn, K, N = ctx.np2, ctx.qlimbs, ctx.N
    pr = random.Random(logN * 100 + logQ)
    vals = [pr.getrandbits(logQ) for _ in range(N)]
    x = jnp.asarray(ints_to_limb_array(vals, K, 32))
    args = (jnp.asarray(g.crt_tb[:npn, :K]),
            jnp.asarray(g.crt_tb_shoup[:npn, :K]),
            jnp.asarray(g.primes[:npn]))
    np.testing.assert_array_equal(
        np.asarray(crt_op(x, *args, strategy=strategy)),
        np.asarray(crt_ref(x, *args)))


@pytest.mark.parametrize("logN,logQ", [(4, 96), (5, 120), (6, 240)])
def test_icrt_kernel_matches_ref(logN, logQ):
    p, ctx = _ctx(logN=logN, logQ=logQ)
    g = ctx.tables
    npn, N = ctx.np1, ctx.N
    tabs = ctx.icrt1
    r = jnp.asarray(_rand_residues(g, npn, N, seed=20 + logN))
    out_limbs = ctx.qlimbs
    got = icrt_op(r, tabs, g, out_limbs)
    ref = icrt_ref(r, tabs, g, out_limbs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_icrt_kernel_boundary_values():
    """Residues of 0, ±1, ±(P-1)/2-ish — the quotient-trick edge cases."""
    p, ctx = _ctx(logN=4)
    g = ctx.tables
    npn, N = ctx.np1, ctx.N
    tabs = ctx.icrt1
    primes_py = [int(v) for v in np.asarray(g.primes[:npn])]
    vals = [0, 1, -1, 2, -2, tabs.P_int // 2 - 1, -(tabs.P_int // 2) + 1,
            123456789, -987654321] + [0] * (N - 9)
    res = np.stack([[v % pj for v in vals] for pj in primes_py]
                   ).astype(np.uint32)
    got = icrt_op(jnp.asarray(res), tabs, g, tabs.accum_limbs)
    ref = icrt_ref(jnp.asarray(res), tabs, g, tabs.accum_limbs,
                   strategy="acc3")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("npn,N", [(3, 64), (8, 128), (13, 512)])
def test_modmul_kernel_matches_ref(npn, N):
    p, ctx = _ctx(logN=5)
    g = ctx.tables
    npn = min(npn, ctx.np2)
    a = jnp.asarray(_rand_residues(g, npn, N, seed=30))
    b = jnp.asarray(_rand_residues(g, npn, N, seed=31))
    args = (jnp.asarray(g.primes[:npn]), jnp.asarray(g.pprime[:npn]),
            jnp.asarray(g.r2[:npn]))
    np.testing.assert_array_equal(
        np.asarray(pointwise_mont_op(a, b, *args)),
        np.asarray(pointwise_mont_ref(a, b, *args)))


def test_full_he_mul_through_kernels():
    """End-to-end HE Mul with every stage routed through Pallas kernels."""
    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.core.rns import PipelineConfig

    params = small_params(logN=4, beta_bits=32)
    sk, pk, evk = keygen(params, seed=3)
    rng = np.random.default_rng(40)
    z1 = rng.normal(size=4) + 1j * rng.normal(size=4)
    z2 = rng.normal(size=4) + 1j * rng.normal(size=4)
    c1 = H.encrypt_message(z1, pk, params, seed=41)
    c2 = H.encrypt_message(z2, pk, params, seed=42)
    base = H.he_mul(c1, c2, evk, params)
    kern = H.he_mul(c1, c2, evk, params,
                    cfg=PipelineConfig(use_kernels=True))
    np.testing.assert_array_equal(np.asarray(base.ax), np.asarray(kern.ax))
    np.testing.assert_array_equal(np.asarray(base.bx), np.asarray(kern.bx))
