"""End-to-end HEAAN scheme tests: the paper's claims, in miniature.

Small (insecure) parameters keep the CPU cost down; the algebra is the same
as the paper's (2^30, 40, 2^1200, 2^16) configuration.
"""

import numpy as np
import pytest

from repro.core import test_params as small_params
from repro.core import heaan as H
from repro.core.keys import keygen
from repro.core.rns import PipelineConfig


def _setup(beta, logN=5, logQ=120, logp=24, seed=7):
    params = small_params(logN=logN, beta_bits=beta, logQ=logQ, logp=logp)
    sk, pk, evk = keygen(params, seed=seed)
    return params, sk, pk, evk


# β=2^64 runs the u64 limb pipeline whose host-side table building is
# python-int exact (no numpy vectorization) — several× slower on CPU.
# Tier-1 default skips it: pytest -m "not slow" (ROADMAP).
BETAS = [32, pytest.param(64, marks=pytest.mark.slow)]


def _rand_msg(n, rng, scale=1.0):
    return scale * (rng.normal(size=n) + 1j * rng.normal(size=n))


@pytest.mark.parametrize("beta", BETAS)
def test_encrypt_decrypt_roundtrip(beta):
    params, sk, pk, evk = _setup(beta)
    rng = np.random.default_rng(0)
    z = _rand_msg(8, rng)
    ct = H.encrypt_message(z, pk, params, seed=11)
    out = H.decrypt_message(ct, sk, params)
    err = np.abs(out - z).max()
    assert err < 1e-4, err


@pytest.mark.parametrize("beta", BETAS)
def test_he_add_homomorphism(beta):
    params, sk, pk, evk = _setup(beta)
    rng = np.random.default_rng(1)
    z1, z2 = _rand_msg(16, rng), _rand_msg(16, rng)
    c1 = H.encrypt_message(z1, pk, params, seed=12)
    c2 = H.encrypt_message(z2, pk, params, seed=13)
    out = H.decrypt_message(H.he_add(c1, c2), sk, params)
    assert np.abs(out - (z1 + z2)).max() < 2e-4
    out = H.decrypt_message(H.he_sub(c1, c2), sk, params)
    assert np.abs(out - (z1 - z2)).max() < 2e-4


@pytest.mark.parametrize("beta", BETAS)
def test_he_mul_homomorphism(beta):
    params, sk, pk, evk = _setup(beta)
    rng = np.random.default_rng(2)
    z1, z2 = _rand_msg(8, rng), _rand_msg(8, rng)
    c1 = H.encrypt_message(z1, pk, params, seed=14)
    c2 = H.encrypt_message(z2, pk, params, seed=15)
    c3 = H.rescale(H.he_mul(c1, c2, evk, params), params)
    out = H.decrypt_message(c3, sk, params)
    err = np.abs(out - z1 * z2).max()
    assert err < 1e-3, err


@pytest.mark.slow
@pytest.mark.parametrize("beta", BETAS)
def test_he_mul_depth_chain(beta):
    """Multi-level chain: rescale after every mul (paper §III-A lifecycle)."""
    params, sk, pk, evk = _setup(beta)
    rng = np.random.default_rng(3)
    z = _rand_msg(4, rng, scale=0.9)
    zs = _rand_msg(4, rng, scale=0.9)
    ct = H.encrypt_message(z, pk, params, seed=16)
    cs_fresh = H.encrypt_message(zs, pk, params, seed=17)
    acc = z.copy()
    for level in range(3):
        cs = H.he_mod_down(cs_fresh, params, ct.logq)
        ct = H.rescale(H.he_mul(ct, cs, evk, params), params)
        acc = acc * zs
        out = H.decrypt_message(ct, sk, params)
        err = np.abs(out - acc).max()
        assert err < 1e-2 * (level + 1), (level, err)
    assert ct.logq == params.logQ - 3 * params.logp


@pytest.mark.parametrize("cfgkw", [
    pytest.param(dict(crt_strategy="shoup", icrt_strategy="acc3"),
                 marks=pytest.mark.slow),
    pytest.param(dict(crt_strategy="acc3", icrt_strategy="naive"),
                 marks=pytest.mark.slow),
    dict(crt_strategy="mod4", icrt_strategy="matmul"),
    dict(modified_shoup=True),
])
def test_he_mul_strategy_ladder_agree(cfgkw):
    """Every optimization-ladder configuration produces the same ciphertext."""
    params, sk, pk, evk = _setup(32, logN=4)
    rng = np.random.default_rng(4)
    z1, z2 = _rand_msg(4, rng), _rand_msg(4, rng)
    c1 = H.encrypt_message(z1, pk, params, seed=18)
    c2 = H.encrypt_message(z2, pk, params, seed=19)
    base = H.he_mul(c1, c2, evk, params)
    alt = H.he_mul(c1, c2, evk, params, cfg=PipelineConfig(**cfgkw))
    np.testing.assert_array_equal(np.asarray(base.ax), np.asarray(alt.ax))
    np.testing.assert_array_equal(np.asarray(base.bx), np.asarray(alt.bx))


@pytest.mark.parametrize("beta", BETAS)
def test_mul_then_add_mixed_circuit(beta):
    params, sk, pk, evk = _setup(beta)
    rng = np.random.default_rng(5)
    z1, z2, z3 = (_rand_msg(8, rng) for _ in range(3))
    c1 = H.encrypt_message(z1, pk, params, seed=20)
    c2 = H.encrypt_message(z2, pk, params, seed=21)
    c3 = H.encrypt_message(z3, pk, params, seed=22)
    prod = H.rescale(H.he_mul(c1, c2, evk, params), params)
    c3_l = H.he_mod_down(c3, params, prod.logq)   # level-align, same scale
    out = H.decrypt_message(H.he_add(prod, c3_l), sk, params)
    assert np.abs(out - (z1 * z2 + z3)).max() < 5e-3
