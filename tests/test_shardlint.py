"""shardlint (repro.analysis.xla + repro.analysis.manifest) tests.

`check_cell` is pure, so the HS1xx rule logic runs on hand-crafted cell
records without compiling anything; the manifest schema/drift layer is
stdlib and exercised against the committed SHARD_MANIFEST.json; one
in-process 1x1 compile checks measure_cell's record end-to-end; and the
two acceptance behaviors — exit 0 on a clean grid, exit 1 when a bogus
ciphertext sharding rule is injected (HS101 + HS103 fire) — run on the
(2, 4) mesh via the shared run_in_8dev_subprocess harness.
"""

from __future__ import annotations

import copy
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

import repro.core  # noqa: F401
from repro.analysis.manifest import (
    MANIFEST_NAME, cell_key, diff_manifests, load_manifest,
    validate_manifest,
)
from repro.analysis.rules import RULES
from repro.analysis.xla import DEFAULT_HBM_BUDGET, check_cell

REPO = Path(__file__).resolve().parents[1]


def _clean_cell():
    """A cell record matching its own analytic expectation — shaped like
    the committed mul/120/2x4 cell, with the per-instruction detail the
    in-memory record carries (the manifest strips it)."""
    return {
        "collectives": {
            "counts": {"all-reduce": 15},
            "bytes": {"all-reduce": 77568.0},
            "total_bytes": 77568.0,
            "ops": [],
        },
        "expected": {
            "counts": {"all-reduce": 15},
            "wire_bytes": 77568.0,
            "axis": "model",
            "allowed": {},
        },
        "group_axes": ["model"],
        "fusions": 273,
        "memory": {"argument_bytes": 42096, "output_bytes": 2064,
                   "temp_bytes": 81704, "peak_bytes": None},
        "flops": 546902.0,
    }


def _rules(diags):
    return sorted({d.rule for d in diags})


# --------------------------------------------------------------------------
# check_cell — the HS1xx rule logic, on hand-crafted records
# --------------------------------------------------------------------------

def test_check_cell_clean_cell_yields_no_findings():
    assert check_cell("mul/120/2x4", _clean_cell()) == []


def test_hs101_unexpected_collective_kind():
    cell = _clean_cell()
    cell["collectives"]["counts"]["all-gather"] = 2
    cell["collectives"]["ops"] = [
        {"op": "all-gather", "size_bytes": 4096, "group_size": 4}] * 2
    diags = check_cell("mul/120/2x4", cell)
    assert _rules(diags) == ["HS101"]
    assert all(d.severity == "error" for d in diags)
    assert "implicit resharding" in diags[0].message


def test_hs101_allowance_tolerates_bounded_evk_slice_permutes():
    cell = _clean_cell()
    cell["expected"]["allowed"] = {
        "collective-permute": {"max_count": 4, "max_bytes_each": 768}}
    cell["collectives"]["counts"]["collective-permute"] = 4
    cell["collectives"]["ops"] = [
        {"op": "collective-permute", "size_bytes": 768, "group_size": 1}] * 4
    assert check_cell("rotate/72/2x4", cell) == []
    # one permute too many -> HS101
    cell["collectives"]["counts"]["collective-permute"] = 5
    cell["collectives"]["ops"].append(
        {"op": "collective-permute", "size_bytes": 768, "group_size": 1})
    assert _rules(check_cell("rotate/72/2x4", cell)) == ["HS101"]
    # count back in bounds but one payload over the per-permute cap
    cell["collectives"]["counts"]["collective-permute"] = 4
    cell["collectives"]["ops"] = cell["collectives"]["ops"][:3] + [
        {"op": "collective-permute", "size_bytes": 769, "group_size": 1}]
    assert _rules(check_cell("rotate/72/2x4", cell)) == ["HS101"]


def test_hs102_all_reduce_bytes_drift():
    cell = _clean_cell()
    cell["collectives"]["bytes"]["all-reduce"] = 77568.0 * 1.05
    diags = check_cell("mul/120/2x4", cell)
    assert _rules(diags) == ["HS102"]
    assert "ring" in diags[0].message or "analytic" in diags[0].message
    # within the 1% tolerance -> clean
    cell["collectives"]["bytes"]["all-reduce"] = 77568.0 * 1.005
    assert check_cell("mul/120/2x4", cell) == []


def test_hs103_wrong_axis_and_count_mismatch():
    cell = _clean_cell()
    cell["group_axes"] = ["data", "model"]
    diags = check_cell("mul/120/2x4", cell)
    assert _rules(diags) == ["HS103"]
    assert "layout churn" in diags[0].message
    cell = _clean_cell()
    cell["collectives"]["counts"]["all-reduce"] = 12
    cell["collectives"]["bytes"]["all-reduce"] = 77568.0  # bytes kept equal
    diags = check_cell("mul/120/2x4", cell)
    assert _rules(diags) == ["HS103"]
    assert "exactly 15" in diags[0].message


def test_hs104_peak_memory_budget_and_cpu_fallback():
    cell = _clean_cell()
    # peak_bytes is None on CPU: the fallback sums argument+output+temp
    fallback = 42096 + 2064 + 81704
    diags = check_cell("mul/120/2x4", cell, hbm_budget=fallback - 1)
    assert _rules(diags) == ["HS104"]
    assert check_cell("mul/120/2x4", cell, hbm_budget=fallback) == []
    # an explicit backend peak wins over the fallback
    cell["memory"]["peak_bytes"] = 10 * fallback
    assert _rules(check_cell("m", cell,
                             hbm_budget=DEFAULT_HBM_BUDGET)) == []
    assert _rules(check_cell("m", cell, hbm_budget=fallback)) == ["HS104"]


def test_hs105_fusion_drift_is_a_warning():
    cell = _clean_cell()
    diags = check_cell("mul/120/2x4", cell, baseline_fusions=100)
    assert _rules(diags) == ["HS105"]
    assert diags[0].severity == "warning"
    # warnings don't gate: run_shardlint counts only errors
    assert check_cell("mul/120/2x4", cell, baseline_fusions=273) == []
    assert check_cell("mul/120/2x4", cell, baseline_fusions=250) == []


def test_hs1xx_rules_are_registered_in_the_catalog():
    for rid, sev in [("HS101", "error"), ("HS102", "error"),
                     ("HS103", "error"), ("HS104", "error"),
                     ("HS105", "warning")]:
        assert rid in RULES and RULES[rid].severity == sev
        assert RULES[rid].check is None     # emitted by the xla pass


# --------------------------------------------------------------------------
# manifest schema + drift diff (stdlib), against the committed file
# --------------------------------------------------------------------------

def test_committed_manifest_validates_and_selfdiffs_clean():
    obj = load_manifest(REPO / MANIFEST_NAME)
    assert validate_manifest(obj) == []
    assert diff_manifests(obj, copy.deepcopy(obj)) == []
    # both meshes, every level, and the full op table are covered
    assert obj["meshes"] == {"1x1": [1, 1], "2x4": [2, 4]}
    from repro.launch.cells import HE_SERVING_OPS
    for op in HE_SERVING_OPS:
        # mod_raise has no headroom at the top of the chain — its grid
        # starts one level down (serving_op_levels); check the bottom
        lq = obj["levels"][-1] if op == "mod_raise" else obj["levels"][0]
        assert cell_key(op, lq, "2x4") in obj["cells"], op


def test_validate_manifest_catches_schema_violations():
    obj = load_manifest(REPO / MANIFEST_NAME)
    bad = copy.deepcopy(obj)
    del bad["params"]["logN"]
    bad["batch"] = "two"
    key = next(iter(bad["cells"]))
    del bad["cells"][key]["fusions"]
    errs = "\n".join(validate_manifest(bad))
    assert "params: missing key 'logN'" in errs
    assert ".batch: expected int" in errs
    assert f"cells[{key}]: missing key 'fusions'" in errs
    empty = copy.deepcopy(obj)
    empty["cells"] = {}
    assert any("empty" in e for e in validate_manifest(empty))


def test_diff_manifests_flags_every_drift_class():
    old = load_manifest(REPO / MANIFEST_NAME)
    new = copy.deepcopy(old)
    k_mul = cell_key("mul", 120, "2x4")
    k_add = cell_key("add", 120, "1x1")
    new["cells"][k_mul]["collectives"]["counts"]["all-reduce"] += 3
    new["cells"][k_mul]["collectives"]["total_bytes"] *= 1.5
    new["cells"][k_mul]["fusions"] = 10
    new["cells"][k_add]["group_axes"] = ["data"]
    del new["cells"][cell_key("sub", 24, "1x1")]
    new["cells"]["bootstrap/120/2x4"] = new["cells"][k_add]
    errs = diff_manifests(old, new)
    text = "\n".join(errs)
    assert f"cells[{k_mul}]: all-reduce count" in text
    assert f"cells[{k_mul}]: wire bytes" in text
    assert f"cells[{k_mul}]: fused-kernel count" in text
    assert f"cells[{k_add}]: replica-group axes" in text
    assert "cells[sub/24/1x1]: in the committed manifest but not" in text
    assert "cells[bootstrap/120/2x4]: measured but not in" in text
    assert len(errs) == 6


def test_diff_manifests_tolerances_come_from_the_committed_side():
    old = load_manifest(REPO / MANIFEST_NAME)
    new = copy.deepcopy(old)
    k = cell_key("mul", 120, "2x4")
    new["cells"][k]["collectives"]["total_bytes"] *= 1.05
    assert diff_manifests(old, new)             # 5% > default 1%
    loose = copy.deepcopy(old)
    loose["tolerances"]["bytes_rtol"] = 0.10    # the reviewed contract
    assert diff_manifests(loose, new) == []


# --------------------------------------------------------------------------
# measure_cell in-process (1-dev mesh) + the CLI acceptance behaviors
# --------------------------------------------------------------------------

def test_measure_cell_single_device_record_and_clean_check():
    import jax

    from repro.analysis.xla import measure_cell
    from repro.core.params import test_params

    params = test_params(logN=4, beta_bits=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cell = measure_cell("mul", params.logQ, mesh, params, 2)
    # one device: nothing on the wire, predicted and measured alike
    assert cell["collectives"]["counts"] == {}
    assert cell["collectives"]["total_bytes"] == 0.0
    assert cell["expected"]["counts"] == {}
    assert cell["group_axes"] == []
    assert cell["fusions"] > 0
    assert check_cell("mul/120/1x1", cell) == []


def test_shardlint_wrapper_help_runs_without_jax():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "shardlint.py"), "--help"],
        capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "shardlint" in out.stdout and "--inject" in out.stdout


def test_shardlint_cli_clean_and_injected_on_8_device_mesh(
        run_in_8dev_subprocess):
    """The acceptance pair in one interpreter: a clean focused grid on
    the (2, 4) mesh exits 0 with the collective schedule matching the
    analytic prediction, and the same grid with the bogus ciphertext
    sharding injected exits 1 with HS101 (unpredicted collectives) and
    HS103 (replica groups on the wrong mesh axis) among the findings."""
    res = run_in_8dev_subprocess("""
        import contextlib, io
        from repro.analysis.xla import main as xla_main

        def run(extra):
            argv = ["--json", "--logn", "4", "--levels", "120",
                    "--meshes", "2x4", "--ops", "mul,rotate,add",
                    "--manifest", "/tmp/_no_such_manifest.json"] + extra
            buf = io.StringIO()
            with contextlib.redirect_stdout(buf):
                rc = xla_main(argv)
            return rc, json.loads(buf.getvalue().strip().splitlines()[-1])

        rc_ok, rep_ok = run([])
        rc_bad, rep_bad = run(["--inject", "bogus-ct-sharding"])
        mul = rep_ok["cells"]["mul/120/2x4"]
        print(json.dumps({
            "rc_ok": rc_ok, "errors_ok": rep_ok["errors"],
            "cells_ok": sorted(rep_ok["cells"]),
            "ar_mul": mul["collectives"]["counts"].get("all-reduce"),
            "bytes_match": mul["collectives"]["total_bytes"]
                == mul["expected"]["wire_bytes"],
            "rc_bad": rc_bad, "errors_bad": rep_bad["errors"],
            "rules_bad": sorted({d["rule"]
                                 for d in rep_bad["diagnostics"]}),
        }))
    """)
    assert res["rc_ok"] == 0 and res["errors_ok"] == 0
    assert res["cells_ok"] == ["add/120/2x4", "mul/120/2x4",
                               "rotate/120/2x4"]
    # mul at full depth: (3 + 2) iCRT reductions x 3 all-reduces each,
    # and the measured ring-model bytes equal the analytic prediction
    assert res["ar_mul"] == 15
    assert res["bytes_match"]
    assert res["rc_bad"] == 1 and res["errors_bad"] >= 2
    assert "HS101" in res["rules_bad"]
    assert "HS103" in res["rules_bad"]


def test_run_shardlint_rejects_unknown_op_and_injection():
    from repro.analysis.xla import run_shardlint
    with pytest.raises(ValueError, match="unknown serving op"):
        run_shardlint(ops=("bootstrap",), meshes={"1x1": (1, 1)})
    with pytest.raises(ValueError, match="unknown injection"):
        run_shardlint(inject="flip-bits", meshes={"1x1": (1, 1)})
