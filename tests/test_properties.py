"""Hypothesis property tests on system invariants (deliverable c)."""

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import test_params as small_params
from repro.core import make_context
from repro.core import rns
from repro.core.context import build_global_tables
from repro.nt.residue import limbs_to_int


PARAMS = small_params(logN=4, beta_bits=32)
CTX = make_context(PARAMS, PARAMS.logQ)
G = build_global_tables(PARAMS)


@given(st.lists(st.integers(min_value=-(2**100), max_value=2**100),
                min_size=16, max_size=16))
@settings(max_examples=20, deadline=None)
def test_to_eval_from_eval_roundtrip_is_centered_identity(vals):
    """from_eval(to_eval(x)) == x for any |x| < min(P/2, 2^(K·β-1))."""
    npn = CTX.np1
    K = CTX.qlimbs
    lim = min(CTX.icrt1.P_int // 2, 1 << (K * 32 - 2)) - 1
    vals = [max(-lim, min(lim, v)) for v in vals]
    enc = np.zeros((16, K), dtype=np.uint32)
    for i, v in enumerate(vals):
        vv = v % (1 << (K * 32))
        for k in range(K):
            enc[i, k] = (vv >> (32 * k)) & 0xFFFFFFFF
    ev = rns.to_eval(jnp.asarray(enc), npn, G)
    back = rns.from_eval(ev, PARAMS, K, G)
    W = 1 << (K * 32)
    for i, v in enumerate(vals):
        got = limbs_to_int(np.asarray(back[i]), 32)
        if got >= W // 2:
            got -= W
        assert got == v, (i, got, v)


@given(st.integers(min_value=0, max_value=2**120 - 1),
       st.integers(min_value=0, max_value=2**120 - 1))
@settings(max_examples=20, deadline=None)
def test_poly_mul_degree0_matches_int_mul(a, b):
    """Multiplying constant polynomials == BigInt multiplication mod q."""
    K = PARAMS.qlimbs(PARAMS.logQ)
    N = PARAMS.N

    def enc(v):
        out = np.zeros((N, K), dtype=np.uint32)
        for k in range(K):
            out[0, k] = (v >> (32 * k)) & 0xFFFFFFFF
        return jnp.asarray(out)

    prod = rns.poly_mul(enc(a), enc(b), 120, 120, PARAMS, G,
                        PARAMS.limbs_for_bits(242))
    got = limbs_to_int(np.asarray(prod[0]), 32)
    W = 1 << (PARAMS.limbs_for_bits(242) * 32)
    if got >= W // 2:
        got -= W
    assert got == a * b
    # every other coefficient must be exactly zero
    rest = np.asarray(prod[1:])
    assert (rest == 0).all()


@given(st.lists(st.integers(min_value=0, max_value=2**119), min_size=2,
                max_size=2))
@settings(max_examples=10, deadline=None)
def test_eval_domain_add_is_homomorphic(pair):
    """to_eval(x) ⊕ to_eval(y) == to_eval(x + y mod q) (RNS congruence)."""
    from repro.core import bigint
    a, b = pair
    K = CTX.qlimbs
    npn = CTX.np1

    def enc(v):
        out = np.zeros((PARAMS.N, K), dtype=np.uint32)
        rngv = v
        for k in range(K):
            out[0, k] = (rngv >> (32 * k)) & 0xFFFFFFFF
        return jnp.asarray(out)

    ea = rns.to_eval(enc(a), npn, G)
    eb = rns.to_eval(enc(b), npn, G)
    s_limbs = bigint.mask_bits(bigint.add(enc(a), enc(b)), PARAMS.logQ)
    lhs = rns.eval_add(ea, eb, G)
    rhs = rns.to_eval(s_limbs, npn, G)
    # additive homomorphism holds exactly when no q-overflow occurred
    if a + b < (1 << PARAMS.logQ):
        np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
