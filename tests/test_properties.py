"""Hypothesis property tests on system invariants (deliverable c)."""

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import test_params as small_params
from repro.core import make_context
from repro.core import rns
from repro.core.context import build_global_tables
from repro.nt.residue import limbs_to_int


PARAMS = small_params(logN=4, beta_bits=32)
# the traced-client property tests: logp=24 over logQ=120 leaves L=5,
# so depth-2 random traces keep two spare levels
TRACE_PARAMS = small_params(logN=4, beta_bits=32, logQ=120, logp=24)
CTX = make_context(PARAMS, PARAMS.logQ)
G = build_global_tables(PARAMS)


@given(st.lists(st.integers(min_value=-(2**100), max_value=2**100),
                min_size=16, max_size=16))
@settings(max_examples=20, deadline=None)
def test_to_eval_from_eval_roundtrip_is_centered_identity(vals):
    """from_eval(to_eval(x)) == x for any |x| < min(P/2, 2^(K·β-1))."""
    npn = CTX.np1
    K = CTX.qlimbs
    lim = min(CTX.icrt1.P_int // 2, 1 << (K * 32 - 2)) - 1
    vals = [max(-lim, min(lim, v)) for v in vals]
    enc = np.zeros((16, K), dtype=np.uint32)
    for i, v in enumerate(vals):
        vv = v % (1 << (K * 32))
        for k in range(K):
            enc[i, k] = (vv >> (32 * k)) & 0xFFFFFFFF
    ev = rns.to_eval(jnp.asarray(enc), npn, G)
    back = rns.from_eval(ev, PARAMS, K, G)
    W = 1 << (K * 32)
    for i, v in enumerate(vals):
        got = limbs_to_int(np.asarray(back[i]), 32)
        if got >= W // 2:
            got -= W
        assert got == v, (i, got, v)


@given(st.integers(min_value=0, max_value=2**120 - 1),
       st.integers(min_value=0, max_value=2**120 - 1))
@settings(max_examples=20, deadline=None)
def test_poly_mul_degree0_matches_int_mul(a, b):
    """Multiplying constant polynomials == BigInt multiplication mod q."""
    K = PARAMS.qlimbs(PARAMS.logQ)
    N = PARAMS.N

    def enc(v):
        out = np.zeros((N, K), dtype=np.uint32)
        for k in range(K):
            out[0, k] = (v >> (32 * k)) & 0xFFFFFFFF
        return jnp.asarray(out)

    prod = rns.poly_mul(enc(a), enc(b), 120, 120, PARAMS, G,
                        PARAMS.limbs_for_bits(242))
    got = limbs_to_int(np.asarray(prod[0]), 32)
    W = 1 << (PARAMS.limbs_for_bits(242) * 32)
    if got >= W // 2:
        got -= W
    assert got == a * b
    # every other coefficient must be exactly zero
    rest = np.asarray(prod[1:])
    assert (rest == 0).all()


@given(st.lists(st.integers(min_value=0, max_value=2**119), min_size=2,
                max_size=2))
@settings(max_examples=10, deadline=None)
def test_eval_domain_add_is_homomorphic(pair):
    """to_eval(x) ⊕ to_eval(y) == to_eval(x + y mod q) (RNS congruence)."""
    from repro.core import bigint
    a, b = pair
    K = CTX.qlimbs
    npn = CTX.np1

    def enc(v):
        out = np.zeros((PARAMS.N, K), dtype=np.uint32)
        rngv = v
        for k in range(K):
            out[0, k] = (rngv >> (32 * k)) & 0xFFFFFFFF
        return jnp.asarray(out)

    ea = rns.to_eval(enc(a), npn, G)
    eb = rns.to_eval(enc(b), npn, G)
    s_limbs = bigint.mask_bits(bigint.add(enc(a), enc(b)), PARAMS.logQ)
    lhs = rns.eval_add(ea, eb, G)
    rhs = rns.to_eval(s_limbs, npn, G)
    # additive homomorphism holds exactly when no q-overflow occurred
    if a + b < (1 << PARAMS.logQ):
        np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


# --------------------------------------------------------------------------
# circuit-aware scheduler invariants (repro.hserve): co-batching never
# merges bucket keys, and per-circuit execution order stays topological
# --------------------------------------------------------------------------

def _fake_hserver(schedule: bool, batch: int):
    """A real HEServer whose OpEngine is replaced by a metadata-faithful
    fake: outputs are zero ciphertexts with each op's (logq, logp) rules
    applied, so queue + scheduler + server logic runs EXACTLY as in
    production with no jit compiles. The fake asserts the co-batch
    invariant (one bucket key per dispatched batch) and logs execution
    order as (cid, node) tags."""
    import jax as _jax

    from repro.core.cipher import Ciphertext
    from repro.core.keys import keygen
    from repro.core.rotate import conj_keygen
    from repro.hserve import HEServer, Inflight

    if not hasattr(_fake_hserver, "_keys"):
        sk, pk, evk = keygen(PARAMS, seed=0)
        _fake_hserver._keys = (sk, pk, evk, conj_keygen(PARAMS, sk))
    sk, pk, evk, ck = _fake_hserver._keys
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    server = HEServer(PARAMS, evk, None, ck, mesh=mesh, batch=batch,
                      schedule=schedule, prefetch=False)

    class FakeEngine:
        n_compiled = 0
        compile_s = 0.0
        profile_stages = False

        def __init__(self):
            self.batches = []        # [(key, [tag-or-None, ...])]

        def dispatch(self, b):
            assert all(r.bucket_key == b.key for r in b.requests), \
                "co-batching merged requests with different bucket keys"
            return Inflight(batch=b, ax=None, bx=None, t0=0.0)

        def wait(self, inf):
            b = inf.batch
            # the rid->node map is popped in _complete, AFTER wait
            self.batches.append(
                (b.key, [server._node_of_rid.get(r.rid)
                         for r in b.requests]))
            outs = []
            for r in b.requests:
                c0 = r.cts[0]
                logq, logp = c0.logq, c0.logp
                if r.op == "mul":
                    logp += r.cts[1].logp
                elif r.op == "mul_plain":
                    logp += r.pt_logp
                elif r.op == "rescale":
                    logq, logp = logq - r.dlogp, logp - r.dlogp
                elif r.op == "mod_down":
                    logq = r.logq2
                z = jnp.zeros((PARAMS.N, PARAMS.qlimbs(logq)),
                              dtype=np.uint32)
                outs.append(Ciphertext(ax=z, bx=z, logq=logq, logp=logp,
                                       n_slots=c0.n_slots))
            return outs, 0.0

    server.engine = FakeEngine()
    return server, pk


_CHAIN_OPS = st.lists(st.sampled_from(["mul", "rescale", "mod_down",
                                       "conjugate", "mul_plain"]),
                      min_size=1, max_size=6)


def _build_chain(chain, z, pt_top):
    """Lower a random op-kind chain to a level-legal CircuitOp list
    (level-changing ops degrade to conjugate at the modulus floor;
    plaintext operands are encoded once per level into `pt_top`)."""
    from repro.core import heaan as H
    from repro.hserve import CircuitOp

    ops, logq = [], PARAMS.logQ
    for kind in chain:
        prev = len(ops) - 1 if ops else "x"
        if kind == "rescale" and logq - PARAMS.logp <= 0:
            kind = "conjugate"
        if kind == "mod_down" and logq - PARAMS.logp <= 0:
            kind = "conjugate"
        if kind == "mul":
            ops.append(CircuitOp("mul", (prev, prev)))
        elif kind == "mul_plain":
            if logq not in pt_top:
                pt_top[logq] = H.encode_plain(z, PARAMS, logq)
            ops.append(CircuitOp("mul_plain", (prev,),
                                 pt=pt_top[logq]))
        elif kind == "rescale":
            ops.append(CircuitOp("rescale", (prev,)))
            logq -= PARAMS.logp
        elif kind == "mod_down":
            ops.append(CircuitOp("mod_down", (prev,),
                                 logq2=logq - PARAMS.logp))
            logq -= PARAMS.logp
        else:
            ops.append(CircuitOp("conjugate", (prev,)))
    return ops


@given(chains=st.lists(_CHAIN_OPS, min_size=2, max_size=4),
       staggers=st.lists(st.integers(min_value=0, max_value=2),
                         min_size=2, max_size=4),
       batch=st.integers(min_value=2, max_value=4),
       schedule=st.booleans())
@settings(max_examples=20, deadline=None)
def test_scheduler_never_merges_keys_and_preserves_topo_order(
        chains, staggers, batch, schedule):
    """For random circuit chains submitted with random stagger, under
    both flush policies: (a) every dispatched batch holds ONE bucket
    key, (b) each circuit's nodes execute in topological order, and
    (c) drain() terminates with every circuit completed (the scheduler's
    progress guarantee — a deferral policy without it deadlocks on
    same-key parent/child chains)."""
    from repro.core import heaan as H

    server, pk = _fake_hserver(schedule, batch)
    rng = np.random.default_rng(0)
    z = rng.normal(size=8) + 1j * rng.normal(size=8)
    x = H.encrypt_message(z, pk, PARAMS, seed=1)
    pt_top = {}

    cids, results, built = [], {}, {}
    for chain, stagger in zip(chains, staggers):
        ops = _build_chain(chain, z, pt_top)
        cid = server.submit_circuit(ops, {"x": x})
        cids.append(cid)
        built[cid] = ops
        for _ in range(stagger):
            results.update(dict(server.poll(flush=True)))
    # bounded drain: a deadlock shows as exhaustion, not a hang
    for _ in range(300):
        if not (server.queue.depth or server._inflight is not None
                or server._circuits):
            break
        results.update(dict(server.poll(flush=True)))
    assert not server._circuits, "drain did not complete every circuit"
    assert server.queue.depth == 0
    assert all(cid in results for cid in cids)
    # per-circuit topological order over the logged execution tags
    done = [t for _key, tags in server.engine.batches
            for t in tags if t is not None]
    pos = {t: i for i, t in enumerate(done)}
    for cid, ops in built.items():
        for i, node in enumerate(ops):
            if (cid, i) not in pos:
                continue                  # padded-out / never-needed
            for a in node.args:
                if isinstance(a, int):
                    assert (cid, a) in pos, \
                        f"node ({cid},{i}) ran but its arg {a} never did"
                    assert pos[(cid, a)] < pos[(cid, i)], \
                        f"node ({cid},{i}) ran before its arg {a}"


# --------------------------------------------------------------------------
# repro.client compile pass (ISSUE 5): a RANDOM traced expression — every
# op kind reachable, no explicit rescale/mod_down anywhere — compiles to a
# level-aligned circuit that (a) the real server serves bitwise-identical
# to the composed core.heaan references run over the same CircuitOp list,
# and (b) decrypts to the plaintext shadow of the traced arithmetic
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trace_session():
    """One warm HESession + reference-side Galois keys (deterministic in
    sk, so bit-identical to what auto_keys loads into the server)."""
    import jax

    from repro.client import HESession
    from repro.core.rotate import conj_keygen, rot_keygen

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = HESession(TRACE_PARAMS, seed=0, mesh=mesh, batch=2)
    rks = {r: rot_keygen(TRACE_PARAMS, s.sk, r) for r in (1, 2, 4)}
    return s, rks, conj_keygen(TRACE_PARAMS, s.sk)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n_ops=st.integers(min_value=1, max_value=4))
@settings(max_examples=8, deadline=None)
def test_random_traced_expr_bitwise_vs_core_and_shadow(
        trace_session, seed, n_ops):
    from repro.client import compile_handle
    from repro.client.testing import random_expr
    from repro.hserve.circuit import execute_circuit_reference

    session, rks, ck = trace_session
    rng = np.random.default_rng(seed)
    n = TRACE_PARAMS.n_slots_max
    zs = [0.5 * (rng.normal(size=n) + 1j * rng.normal(size=n))
          for _ in range(2)]
    leaves = [(session.encrypt(z, seed=1000 + seed + i), z)
              for i, z in enumerate(zs)]
    y, shadow = random_expr(rng, leaves, n_ops=n_ops, max_depth=2)
    cc = compile_handle(y, TRACE_PARAMS)      # materialized operands
    ref = execute_circuit_reference(
        cc.ops, cc.inputs, TRACE_PARAMS, evk=session.evk, rot_keys=rks,
        conj_key=ck)
    got = session.run([y])[0].result()
    assert bool((np.asarray(got.ax) == np.asarray(ref.ax)).all()
                and (np.asarray(got.bx) == np.asarray(ref.bx)).all()), \
        "traced serving diverged from the composed core reference"
    tol = 1e-3 * max(1.0, float(np.abs(shadow).max()))
    np.testing.assert_allclose(session.decrypt(got), shadow, atol=tol)


# --------------------------------------------------------------------------
# multi-host frontend (ISSUE 8): random circuits through an HEFrontend
# with K in [1, 4] metadata-faithful fake workers under random
# worker-death schedules — co-batching stays key-pure on every worker,
# each node is DELIVERED exactly once (re-executions match the requeue
# counter exactly), per-circuit topological order holds across the whole
# fleet, and the bounded drain terminates
# --------------------------------------------------------------------------

def _fake_frontend(workers, batch, schedule, injector, log):
    """A real HEFrontend over in-process workers whose OpEngines are
    replaced by the same metadata-faithful fake as `_fake_hserver` —
    queue, scheduler, routing, transport framing, death/requeue, and
    request rebuild on the worker side all run EXACTLY as in
    production, with no jit. Executions append (wid, key, [rid]) to
    `log`."""
    import jax as _jax

    from repro.core.cipher import Ciphertext
    from repro.core.keys import keygen
    from repro.core.rotate import conj_keygen
    from repro.hserve.frontend import HEFrontend

    if not hasattr(_fake_hserver, "_keys"):
        sk, pk, evk = keygen(PARAMS, seed=0)
        _fake_hserver._keys = (sk, pk, evk, conj_keygen(PARAMS, sk))
    sk, pk, evk, ck = _fake_hserver._keys
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    fe = HEFrontend(PARAMS, evk, None, ck, mesh=mesh, batch=batch,
                    workers=workers, schedule=schedule,
                    injector=injector)

    class FakeWorkerEngine:
        n_compiled = 0
        compile_s = 0.0

        def __init__(self, wid):
            self.wid = wid

        def dispatch(self, b):
            assert all(r.bucket_key == b.key for r in b.requests), \
                "co-batching merged requests with different bucket keys"
            return b

        def wait(self, b):
            log.append((self.wid, b.key,
                        [r.rid for r in b.requests]))
            outs = []
            for r in b.requests:
                c0 = r.cts[0]
                logq, logp = c0.logq, c0.logp
                if r.op == "mul":
                    logp += r.cts[1].logp
                elif r.op == "mul_plain":
                    logp += r.pt_logp
                elif r.op == "rescale":
                    logq, logp = logq - r.dlogp, logp - r.dlogp
                elif r.op == "mod_down":
                    logq = r.logq2
                z = np.zeros((PARAMS.N, PARAMS.qlimbs(logq)),
                             dtype=np.uint32)
                outs.append(Ciphertext(ax=z, bx=z, logq=logq, logp=logp,
                                       n_slots=c0.n_slots))
            return outs, 0.0

    for w in fe.workers:
        w.transport.worker.engine = FakeWorkerEngine(w.wid)
    return fe, pk


@given(chains=st.lists(_CHAIN_OPS, min_size=2, max_size=4),
       workers=st.integers(min_value=1, max_value=4),
       batch=st.integers(min_value=2, max_value=3),
       schedule=st.booleans(),
       kills=st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                                st.integers(min_value=1, max_value=3)),
                      max_size=2))
@settings(max_examples=15, deadline=None)
def test_multihost_serves_every_node_once_in_topo_order_under_deaths(
        chains, workers, batch, schedule, kills):
    """Random circuits through the multi-host frontend with a random
    worker count and a random kill schedule (always leaving >= 1
    survivor): (a) every dispatched batch reaching ANY worker holds one
    bucket key, (b) each circuit node is delivered exactly once — the
    only re-executions are the requeued in-flight requests of dead
    workers, counted exactly by the requeue counter, (c) first-execution
    order respects every circuit's topology even when nodes of one
    circuit land on different workers, and (d) the bounded drain
    completes every circuit."""
    from repro.core import heaan as H
    from repro.runtime.failures import FailureInjector

    # at most workers-1 distinct victims, so routing always has a
    # survivor (all-dead drain is a separate typed-error test)
    sched = {}
    for wid_raw, after in kills:
        wid = wid_raw % workers
        if wid not in sched and len(sched) < workers - 1:
            sched[wid] = after
    injector = FailureInjector(kill_worker_at=sched) if sched else None

    log = []
    fe, pk = _fake_frontend(workers, batch, schedule, injector, log)
    rng = np.random.default_rng(0)
    z = rng.normal(size=8) + 1j * rng.normal(size=8)
    x = H.encrypt_message(z, pk, PARAMS, seed=1)
    pt_top = {}
    cids, built, results, tags = [], {}, {}, {}
    for chain in chains:
        ops = _build_chain(chain, z, pt_top)
        cid = fe.submit_circuit(ops, {"x": x})
        cids.append(cid)
        built[cid] = ops
    # bounded drain, snapshotting the rid->node map BEFORE each poll
    # (the server pops it at completion; children enqueued during a
    # poll cannot be dispatched before the next one)
    for _ in range(400):
        if not (fe.queue.depth or fe._work_pending() or fe._circuits):
            break
        tags.update(fe._node_of_rid)
        results.update(dict(fe.poll(flush=True)))
    assert not fe._circuits, "drain did not complete every circuit"
    assert fe.queue.depth == 0
    assert all(cid in results for cid in cids)

    # every node executed; re-executions == requeued requests exactly
    served = [rid for _wid, _key, rids in log for rid in rids]
    fr = fe.stats()["frontend"]
    assert len(served) - len(set(served)) == fr["requeued_requests"], \
        "a request was re-served without a matching worker-death requeue"
    if injector is not None:
        assert fr["deaths"] == len(injector.killed_workers)
    pos = {}
    for _wid, _key, rids in log:
        for rid in rids:
            t = tags.get(rid)
            if t is not None and t not in pos:
                pos[t] = len(pos)
    want = {(cid, i) for cid, ops in built.items()
            for i in range(len(ops))}
    assert set(pos) == want, "a circuit node was never served"
    for cid, ops in built.items():
        for i, node in enumerate(ops):
            for a in node.args:
                if isinstance(a, int):
                    assert pos[(cid, a)] < pos[(cid, i)], \
                        f"node ({cid},{i}) ran before its arg {a}"


# --------------------------------------------------------------------------
# multi-host REAL serving (ISSUE 8): the traced-client property of the
# previous section, re-run through an HEFrontend with two real workers
# and a randomized single-worker death mid-stream — requeue + re-route
# must keep the served result bitwise identical to the composed core
# reference (ops are deterministic integer arithmetic)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mh_trace_session():
    """One warm frontend-backed HESession (two in-process workers) +
    reference-side Galois keys, reused across hypothesis examples —
    workers are revived and the kill schedule reset per example."""
    import jax

    from repro.client import HESession
    from repro.core.keys import keygen
    from repro.core.rotate import conj_keygen, rot_keygen
    from repro.hserve.frontend import HEFrontend

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sk, pk, evk = keygen(TRACE_PARAMS, seed=0)
    fe = HEFrontend(TRACE_PARAMS, evk, mesh=mesh, batch=2, workers=2)
    s = HESession(TRACE_PARAMS, sk=sk, pk=pk, evk=evk, server=fe)
    rks = {r: rot_keygen(TRACE_PARAMS, sk, r) for r in (1, 2, 4)}
    return s, fe, rks, conj_keygen(TRACE_PARAMS, sk)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n_ops=st.integers(min_value=1, max_value=3),
       kill=st.sampled_from([-1, 0, 1]),
       kill_after=st.integers(min_value=1, max_value=2))
@settings(max_examples=6, deadline=None)
def test_random_traced_expr_multihost_bitwise_under_worker_death(
        mh_trace_session, seed, n_ops, kill, kill_after):
    """A random traced expression served by the two-worker frontend —
    with worker `kill` scheduled to die `kill_after` dispatches into
    the example (kill=-1: no death) — is bitwise identical to the
    composed core.heaan reference over the compiled CircuitOp list."""
    from repro.client import compile_handle
    from repro.client.testing import random_expr
    from repro.hserve.circuit import execute_circuit_reference
    from repro.runtime.failures import FailureInjector

    session, fe, rks, ck = mh_trace_session
    fe.revive_workers()
    if kill >= 0:
        fe.injector = FailureInjector(kill_worker_at={
            kill: fe.workers[kill].batches + kill_after})
    try:
        rng = np.random.default_rng(seed)
        n = TRACE_PARAMS.n_slots_max
        zs = [0.5 * (rng.normal(size=n) + 1j * rng.normal(size=n))
              for _ in range(2)]
        leaves = [(session.encrypt(z, seed=2000 + seed + i), z)
                  for i, z in enumerate(zs)]
        y, _shadow = random_expr(rng, leaves, n_ops=n_ops, max_depth=2)
        cc = compile_handle(y, TRACE_PARAMS)
        ref = execute_circuit_reference(
            cc.ops, cc.inputs, TRACE_PARAMS, evk=session.evk,
            rot_keys=rks, conj_key=ck)
        got = session.run([y])[0].result()
    finally:
        fe.injector = None
        fe.revive_workers()
    assert bool((np.asarray(got.ax) == np.asarray(ref.ax)).all()
                and (np.asarray(got.bx) == np.asarray(ref.bx)).all()), \
        "multi-host serving diverged from the composed core reference"
