#!/usr/bin/env python3
"""shardlint CLI wrapper: static HLO/collective analysis of the
compiled serving engines on BOTH the 1-dev and (2,4) meshes.

XLA fixes its device count at the first jax import, so the 8-host-device
flag must be in the environment before anything imports jax — this
wrapper guarantees that, then delegates to `repro.analysis.xla` (which
is also runnable directly as `python -m repro.analysis.xla` in an
already-configured process). Run from the repo root:

    python tools/shardlint.py --json            # analyze + check
    python tools/shardlint.py --write           # regenerate the manifest
    python tools/shardlint.py --json --out /tmp/fresh.json
    python tools/check_docs.py --shard-manifest /tmp/fresh.json

Exit 1 = error-severity HS1xx findings (see docs/ANALYSIS.md).
"""

import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"


def _force_devices() -> None:
    assert "jax" not in sys.modules, \
        "tools/shardlint.py must run before any jax import"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FLAG}".strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> int:
    _force_devices()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.analysis.xla import main as xla_main
    return xla_main(sys.argv[1:])


if __name__ == "__main__":
    sys.exit(main())
