#!/usr/bin/env python3
"""Docs CI check: relative-link integrity + BENCH_serve_he.json schema.

Two checks, no dependencies beyond the stdlib (CI runs this before the
test install finishes, and the driver repo bans new deps):

  1. Every relative markdown link in README.md and docs/*.md must point
     at an existing file (anchors and absolute http(s)/mailto links are
     skipped; intra-file `#fragment` links are skipped).
  2. BENCH_serve_he.json must match the schema documented in
     docs/SERVING.md — required keys with the right JSON types, including
     the `trickle` and `overlap` blocks this PR's benchmark emits. The
     `obs` block additionally GATES: tracing overhead ≤ 2% and
     bitwise-identical results (always-on tracing must be free).
  3. SHARD_MANIFEST.json (shardlint's measured collective/fusion/memory
     record per served (op, level, mesh) cell) must match its schema;
     with `--shard-manifest FRESH.json` a freshly measured manifest
     (tools/shardlint.py --json --out FRESH.json) is DIFFED against the
     committed one — any collective count / wire bytes / fusion /
     group-axis drift fails CI until the manifest is regenerated
     (tools/shardlint.py --write) and the diff explained in review.

The shard-manifest schema/diff logic lives in
src/repro/analysis/manifest.py (stdlib-only) and is loaded here by file
path, bypassing the repro.analysis package __init__ (which imports
numpy — unavailable in the docs CI job).

With `--trace` / `--metrics`, the repro.obs artifacts a serve run wrote
are validated instead: every Chrome trace event carries the full
pid/tid/ts/dur/name/cat key set and all eight request-lifecycle phases
appear; the metrics snapshot has the registry's documented shape
(docs/OBSERVABILITY.md).

Exit code 0 = clean; 1 = problems (each printed on its own line).

    python tools/check_docs.py [--repo PATH]
    python tools/check_docs.py --trace trace.json --metrics metrics.json
    python tools/check_docs.py --shard-manifest /tmp/shard_fresh.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# [text](target) — excluding images' leading ! is unnecessary (same rule)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

NUM = (int, float)

# BENCH_serve_he.json required keys → expected JSON types
# (documented in docs/SERVING.md; keep the two in sync)
BENCH_SCHEMA = {
    "params": dict,
    "batch": int,
    "levels": list,
    "use_kernels": bool,
    "mesh": dict,
    "requests": dict,
    "mul_per_s": NUM,
    "rotate_per_s": NUM,
    "latency_ms": dict,
    "pad_frac": dict,
    "queue_depth": dict,
    "cache": dict,
    "compile_s": NUM,
    "steps_compiled": int,
    "setup_s": dict,
    "drain_wall_s": NUM,
    "trickle": dict,
    "overlap": dict,
    "plain": dict,
    "scheduler": dict,
    "client": dict,
    "analysis": dict,
    "obs": dict,
    "boot": dict,
    "multihost": dict,
}
PARAMS_KEYS = ("logN", "logQ", "logp", "beta_bits")
TRICKLE_SCHEMA = {"requests": int, "max_age_s": NUM, "p50_ms": NUM,
                  "p99_ms": NUM, "age_flushes": int}
OVERLAP_SCHEMA = {"muls": int, "off_drain_s": NUM, "on_drain_s": NUM,
                  "speedup": NUM}
PLAIN_SCHEMA = {"requests": int, "mul_plain_per_s": NUM,
                "add_plain_per_s": NUM, "mul_plain_vs_mul": NUM}
SCHEDULER_SCHEMA = {"circuits": int, "lookahead": int,
                    "unscheduled": dict, "scheduled": dict,
                    "bitwise_identical": bool}
# per-phase record inside scheduler.{unscheduled,scheduled}
SCHED_PHASE_SCHEMA = {"drain_s": NUM, "batches": int, "mul_pad_frac": NUM,
                      "cross_circuit_batches": int,
                      "cross_circuit_rate": NUM, "deferrals": int,
                      "prefetches": int}
# the repro.client traced-session vs hand-built-circuit A/B
CLIENT_SCHEMA = {"circuits": int, "hand_drain_s": NUM,
                 "traced_drain_s": NUM, "hand_mul_pad_frac": NUM,
                 "traced_mul_pad_frac": NUM, "cross_circuit_rate": NUM,
                 "plain_cache_hits": int, "plain_cache_hit_rate": NUM,
                 "bitwise_identical": bool}
# the repro.analysis cost-model scheduler A/B (hslint calibration loop)
ANALYSIS_SCHEMA = {"circuits": int, "calibrated_from": str,
                   "est_circuit_s": NUM, "nocost": dict, "cost": dict,
                   "bitwise_identical": bool}
# per-phase record inside analysis.{nocost,cost}
ANALYSIS_PHASE_SCHEMA = {"drain_s": NUM, "batches": int,
                         "mul_pad_frac": NUM, "deferrals": int,
                         "cost_skips": int}
# the repro.obs tracing-overhead A/B; overhead_frac is GATED ≤ this
OBS_SCHEMA = {"muls": int, "off_drain_s": NUM, "on_drain_s": NUM,
              "overhead_frac": NUM, "trace_events": int,
              "bitwise_identical": bool}
OBS_MAX_OVERHEAD = 0.02
# the repro.boot batched-bootstrapping A/B. Two GATES: max_err must
# stay within the documented error_bound (bootstrap is approximate —
# the bound IS its correctness contract), and cross_circuit_batches
# must be > 0 (concurrent bootstraps that never co-batch mean the
# scheduler lost the batched-bootstrapping payoff entirely)
BOOT_SCHEMA = {"params": dict, "concurrent": int, "pipeline_ops": int,
               "logq_in": int, "out_logq": int, "levels_gained": int,
               "compile_s": NUM, "solo_latency_s": NUM,
               "concurrent_drain_s": NUM, "latency_s_per_bootstrap": NUM,
               "cobatch_speedup": NUM, "cross_circuit_batches": int,
               "cross_circuit_rate": NUM, "max_err": NUM,
               "error_bound": NUM, "precision_bits_in": NUM,
               "precision_bits_out": NUM}
# the multi-host frontend/worker scaling A/B (virtual-time makespan
# over W in-process workers) + the worker-death requeue check.
# scaling_efficiency_at_4 is GATED ≥ MULTIHOST_MIN_EFF4: the load-first
# router must spread a hot bucket over the fleet, not pin-and-serialize
MULTIHOST_SCHEMA = {"muls": int, "batch": int, "transport": str,
                    "workers_swept": list, "per_workers": dict,
                    "scaling_efficiency_at_4": NUM, "requeue": dict,
                    "bitwise_identical": bool}
# per-W record inside multihost.per_workers
MULTIHOST_W_SCHEMA = {"busy_s": NUM, "makespan_s": NUM, "mul_per_s": NUM}
MULTIHOST_REQUEUE_SCHEMA = {"worker_deaths": int, "requeued_requests": int,
                            "bitwise_identical": bool}
MULTIHOST_MIN_EFF4 = 0.7
# every complete ("X") trace event must carry the full key set or the
# Chrome/Perfetto importers mis-render the lane
TRACE_EVENT_KEYS = ("pid", "tid", "ts", "dur", "name", "cat")
LIFECYCLE_PHASES = ("submit", "enqueue", "bucket_wait", "flush",
                    "batch_assemble", "dispatch", "device_wall",
                    "complete")


def check_links(repo: Path) -> list:
    errors = []
    md_files = [repo / "README.md"] + sorted((repo / "docs").glob("*.md"))
    for md in md_files:
        if not md.exists():
            errors.append(f"{md.relative_to(repo)}: file missing")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in _LINK_RE.findall(line):
                if re.match(r"^[a-z]+:", target) or target.startswith("#"):
                    continue                   # external / in-page anchor
                path = target.split("#", 1)[0]
                if not (md.parent / path).exists():
                    errors.append(
                        f"{md.relative_to(repo)}:{lineno}: broken relative "
                        f"link -> {target}")
    return errors


def _check_block(obj: dict, schema: dict, where: str) -> list:
    errors = []
    for key, typ in schema.items():
        if key not in obj:
            errors.append(f"{where}: missing key {key!r}")
        elif not isinstance(obj[key], typ) or (
                typ is not bool and isinstance(obj[key], bool)):
            errors.append(
                f"{where}.{key}: expected "
                f"{getattr(typ, '__name__', typ)}, got "
                f"{type(obj[key]).__name__}")
    return errors


def check_bench(bench: Path) -> list:
    if not bench.exists():
        return [f"{bench.name}: file missing"]
    try:
        obj = json.loads(bench.read_text())
    except json.JSONDecodeError as e:
        return [f"{bench.name}: invalid JSON ({e})"]
    errors = _check_block(obj, BENCH_SCHEMA, bench.name)
    if isinstance(obj.get("params"), dict):
        for k in PARAMS_KEYS:
            if k not in obj["params"]:
                errors.append(f"{bench.name}.params: missing key {k!r}")
    if isinstance(obj.get("trickle"), dict):
        errors += _check_block(obj["trickle"], TRICKLE_SCHEMA,
                               f"{bench.name}.trickle")
    if isinstance(obj.get("overlap"), dict):
        errors += _check_block(obj["overlap"], OVERLAP_SCHEMA,
                               f"{bench.name}.overlap")
    if isinstance(obj.get("plain"), dict):
        errors += _check_block(obj["plain"], PLAIN_SCHEMA,
                               f"{bench.name}.plain")
    if isinstance(obj.get("scheduler"), dict):
        sch = obj["scheduler"]
        errors += _check_block(sch, SCHEDULER_SCHEMA,
                               f"{bench.name}.scheduler")
        for phase in ("unscheduled", "scheduled"):
            if isinstance(sch.get(phase), dict):
                errors += _check_block(
                    sch[phase], SCHED_PHASE_SCHEMA,
                    f"{bench.name}.scheduler.{phase}")
        if sch.get("bitwise_identical") is False:
            errors.append(f"{bench.name}.scheduler: scheduling changed "
                          "a result bit (bitwise_identical false)")
    if isinstance(obj.get("client"), dict):
        cl = obj["client"]
        errors += _check_block(cl, CLIENT_SCHEMA, f"{bench.name}.client")
        if cl.get("bitwise_identical") is False:
            errors.append(f"{bench.name}.client: the traced frontend "
                          "changed a result bit (bitwise_identical "
                          "false)")
        if cl.get("plain_cache_hits") == 0:
            errors.append(f"{bench.name}.client: traced circuits never "
                          "hit the plaintext-operand cache")
    if isinstance(obj.get("analysis"), dict):
        an = obj["analysis"]
        errors += _check_block(an, ANALYSIS_SCHEMA, f"{bench.name}.analysis")
        for phase in ("nocost", "cost"):
            if isinstance(an.get(phase), dict):
                errors += _check_block(
                    an[phase], ANALYSIS_PHASE_SCHEMA,
                    f"{bench.name}.analysis.{phase}")
        if an.get("bitwise_identical") is False:
            errors.append(f"{bench.name}.analysis: cost-model scheduling "
                          "changed a result bit (bitwise_identical false)")
    if isinstance(obj.get("obs"), dict):
        ob = obj["obs"]
        errors += _check_block(ob, OBS_SCHEMA, f"{bench.name}.obs")
        if ob.get("bitwise_identical") is False:
            errors.append(f"{bench.name}.obs: tracing changed a result "
                          "bit (bitwise_identical false)")
        frac = ob.get("overhead_frac")
        if isinstance(frac, NUM) and not isinstance(frac, bool) \
                and frac > OBS_MAX_OVERHEAD:
            errors.append(
                f"{bench.name}.obs: tracing overhead {frac:.1%} exceeds "
                f"the {OBS_MAX_OVERHEAD:.0%} gate — the lifecycle "
                "tracer must stay cheap enough to leave on")
    if isinstance(obj.get("boot"), dict):
        bo = obj["boot"]
        errors += _check_block(bo, BOOT_SCHEMA, f"{bench.name}.boot")
        err, bound = bo.get("max_err"), bo.get("error_bound")
        if isinstance(err, NUM) and isinstance(bound, NUM) \
                and not isinstance(err, bool) and err > bound:
            errors.append(
                f"{bench.name}.boot: measured bootstrap error {err:.3e} "
                f"breaches the documented bound {bound:.3e} — the error "
                "contract is the approximate pipeline's correctness "
                "gate")
        cxb = bo.get("cross_circuit_batches")
        if isinstance(cxb, int) and not isinstance(cxb, bool) and cxb == 0:
            errors.append(
                f"{bench.name}.boot: zero cross-request co-batching — "
                "concurrent bootstraps must share batches through the "
                "circuit scheduler (the batched-bootstrapping payoff)")
        lg = bo.get("levels_gained")
        if isinstance(lg, int) and not isinstance(lg, bool) and lg < 1:
            errors.append(
                f"{bench.name}.boot: bootstrap gained {lg} levels — the "
                "refreshed ciphertext must land strictly above its "
                "input level")
    if isinstance(obj.get("multihost"), dict):
        mh = obj["multihost"]
        errors += _check_block(mh, MULTIHOST_SCHEMA,
                               f"{bench.name}.multihost")
        if isinstance(mh.get("per_workers"), dict):
            for wkey, rec in sorted(mh["per_workers"].items()):
                if isinstance(rec, dict):
                    errors += _check_block(
                        rec, MULTIHOST_W_SCHEMA,
                        f"{bench.name}.multihost.per_workers[{wkey}]")
        if isinstance(mh.get("requeue"), dict):
            rq = mh["requeue"]
            errors += _check_block(rq, MULTIHOST_REQUEUE_SCHEMA,
                                   f"{bench.name}.multihost.requeue")
            if rq.get("bitwise_identical") is False:
                errors.append(
                    f"{bench.name}.multihost.requeue: worker-death "
                    "requeue changed a result bit (bitwise_identical "
                    "false)")
        if mh.get("bitwise_identical") is False:
            errors.append(f"{bench.name}.multihost: multi-host serving "
                          "changed a result bit (bitwise_identical "
                          "false)")
        eff = mh.get("scaling_efficiency_at_4")
        if isinstance(eff, NUM) and not isinstance(eff, bool) \
                and eff < MULTIHOST_MIN_EFF4:
            errors.append(
                f"{bench.name}.multihost: scaling efficiency {eff} at "
                f"4 workers is below the {MULTIHOST_MIN_EFF4} gate — "
                "the load-first router must spread hot buckets over "
                "the fleet instead of pinning them to one worker")
    return errors


def _manifest_mod(repo: Path):
    """Load src/repro/analysis/manifest.py by file path (stdlib-only by
    contract) without importing the repro.analysis package."""
    import importlib.util
    p = repo / "src" / "repro" / "analysis" / "manifest.py"
    spec = importlib.util.spec_from_file_location("_shard_manifest", p)
    if spec is None or spec.loader is None:
        raise ImportError(f"cannot load {p}")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def check_shard_manifest(repo: Path, fresh: Path | None = None) -> list:
    """Schema-check the committed SHARD_MANIFEST.json; with `fresh`, also
    drift-diff a freshly measured manifest against it (the CI gate that
    makes collective-schedule changes reviewable)."""
    try:
        mod = _manifest_mod(repo)
    except Exception as e:
        return [f"manifest module: {type(e).__name__}: {e}"]
    committed_path = repo / mod.MANIFEST_NAME
    if not committed_path.exists():
        return [f"{mod.MANIFEST_NAME}: file missing (regenerate with "
                "tools/shardlint.py --write)"]
    try:
        committed = mod.load_manifest(committed_path)
    except ValueError as e:
        return [f"{mod.MANIFEST_NAME}: {e}"]
    except json.JSONDecodeError as e:
        return [f"{mod.MANIFEST_NAME}: invalid JSON ({e})"]
    errors = mod.validate_manifest(committed)
    if fresh is not None:
        if not fresh.exists():
            return errors + [f"{fresh}: file missing"]
        try:
            fresh_obj = mod.load_manifest(fresh)
        except (ValueError, json.JSONDecodeError) as e:
            return errors + [f"{fresh.name}: {e}"]
        errors += mod.validate_manifest(fresh_obj, fresh.name)
        errors += [f"{mod.MANIFEST_NAME} drift vs {fresh.name}: {d}"
                   for d in mod.diff_manifests(committed, fresh_obj)]
    return errors


def check_trace(path: Path) -> list:
    """Validate a Chrome trace-event JSON written by `serve --he
    --trace`: well-formed, full key set on every complete event, and
    every request-lifecycle phase represented."""
    if not path.exists():
        return [f"{path.name}: file missing"]
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path.name}: invalid JSON ({e})"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        return [f"{path.name}: no traceEvents array"]
    errors = []
    names = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errors.append(f"{path.name}[{i}]: event is not an object")
            continue
        if e.get("ph") not in ("X", "M"):
            errors.append(f"{path.name}[{i}]: unexpected phase "
                          f"{e.get('ph')!r} (emitter writes only "
                          "complete 'X' and metadata 'M' events)")
        missing = [k for k in TRACE_EVENT_KEYS if k not in e]
        if missing:
            errors.append(f"{path.name}[{i}] ({e.get('name')!r}): "
                          f"missing {missing}")
        if e.get("ph") == "X":
            names.add(e.get("name"))
    absent = [p for p in LIFECYCLE_PHASES if p not in names]
    if absent:
        errors.append(f"{path.name}: lifecycle phases never recorded: "
                      f"{absent} (found {sorted(names)})")
    return errors


def check_metrics(path: Path) -> list:
    """Validate a MetricsRegistry snapshot written by `serve --he
    --metrics`: instrument sections plus the serve source, and no
    source captured an exception."""
    if not path.exists():
        return [f"{path.name}: file missing"]
    try:
        obj = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path.name}: invalid JSON ({e})"]
    errors = _check_block(obj, {"counters": dict, "gauges": dict,
                                "histograms": dict, "serve": dict},
                          path.name)
    for name, sub in obj.items():
        if isinstance(sub, dict) and "error" in sub \
                and set(sub) == {"error"}:
            errors.append(f"{path.name}.{name}: source raised at "
                          f"snapshot time: {sub['error']}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--repo", default=Path(__file__).resolve().parent.parent,
                    type=Path, help="repo root (default: this file's ../)")
    ap.add_argument("--bench", default=None, type=Path,
                    help="validate THIS bench JSON instead of the "
                         "committed BENCH_serve_he.json (and skip the "
                         "link check) — CI schema-drift gate for freshly "
                         "emitted files")
    ap.add_argument("--trace", default=None, type=Path,
                    help="validate a Chrome trace-event JSON written by "
                         "`serve --he --trace` (full event key set + "
                         "all lifecycle phases); skips the link/bench "
                         "checks")
    ap.add_argument("--metrics", default=None, type=Path,
                    help="validate a MetricsRegistry snapshot written "
                         "by `serve --he --metrics`; skips the "
                         "link/bench checks")
    ap.add_argument("--shard-manifest", default=None, type=Path,
                    help="drift-diff THIS freshly measured shardlint "
                         "manifest (tools/shardlint.py --out) against "
                         "the committed SHARD_MANIFEST.json; skips the "
                         "link/bench checks")
    args = ap.parse_args(argv)
    if args.trace is not None or args.metrics is not None:
        errors = []
        if args.trace is not None:
            errors += check_trace(args.trace)
        if args.metrics is not None:
            errors += check_metrics(args.metrics)
    elif args.shard_manifest is not None:
        errors = check_shard_manifest(args.repo, args.shard_manifest)
    elif args.bench is not None:
        errors = check_bench(args.bench)
    else:
        errors = check_links(args.repo) \
            + check_bench(args.repo / "BENCH_serve_he.json") \
            + check_shard_manifest(args.repo)
    for e in errors:
        print(e)
    if not errors:
        print("docs OK: checked artifacts match the documented schema")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
