#!/usr/bin/env python3
"""hslint — the repro.analysis CLI, runnable straight from a checkout.

Thin wrapper so CI and humans can `python tools/hslint.py` without
setting PYTHONPATH; all behavior (and --help) lives in
`repro.analysis.__main__`.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
