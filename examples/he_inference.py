"""End-to-end encrypted inference (the paper's application class, §I/[39]):
logistic-regression scoring on ENCRYPTED features, batched in CKKS slots,
written on the `repro.client` session API — the traced-handle frontend
that compiles straight to served circuits.

    PYTHONPATH=src python examples/he_inference.py

Pipeline:
  1. train a logistic-regression probe on synthetic data (plaintext numpy);
  2. client encrypts each request batch FEATURE-MAJOR: ciphertext j holds
     feature j of every example in its slots (no rotations needed);
  3. the model is ONE traced function over handles —
         score = Σ_j w_j · ct_j + b                     (affine)
         σ(x) ≈ 0.5 + 0.197·x − 0.004·x³                (degree-3 sigmoid)
     with NO rescale/mod_down anywhere: the compile pass inserts all
     level management and hash-registers every weight, so the SECOND
     request batch ships hash-only plaintext operands and the server
     serves them from its (hash, level) cache;
  4. both requests run as futures through one drain (they co-batch
     node-for-node), then the client decrypts and we compare against
     plaintext inference.
"""

import time

import numpy as np

from repro.client import HESession
from repro.core import test_params

# --- plaintext training ------------------------------------------------------
rng = np.random.default_rng(0)
n_examples, n_features = 64, 8
w_true = rng.normal(size=n_features)


def make_batch(seed):
    r = np.random.default_rng(seed)
    X = r.normal(size=(n_examples, n_features))
    y = (X @ w_true + 0.3 * r.normal(size=n_examples) > 0)
    return X, y.astype(np.float64)


X, y = make_batch(1)
w = np.zeros(n_features)
b = 0.0
for _ in range(400):
    p = 1 / (1 + np.exp(-(X @ w + b)))
    g = X.T @ (p - y) / n_examples + 0.08 * w   # L2 keeps scores in the
    w -= 0.5 * g                                # poly-sigmoid's range
    b -= 0.5 * float(np.mean(p - y))
acc_plain = float(((1 / (1 + np.exp(-(X @ w + b))) > 0.5) == y).mean())
print(f"plaintext probe accuracy: {acc_plain:.3f} "
      f"(score range ±{np.abs(X @ w + b).max():.1f})")

# --- the session: keys + server (L=6 covers the depth-4 trace) ---------------
params = test_params(logN=7, beta_bits=32, logQ=144, logp=24)
session = HESession(params, seed=0, batch=2)

# degree-3 sigmoid (Kim et al. / iDASH coefficients, valid on ~[-6, 6])
c1, c3 = 0.197, 0.004


def traced_probs(cts):
    """The whole encrypted model as handle arithmetic. The x² and x·x²
    steps are real HE Muls — the operation this framework accelerates;
    every rescale/mod_down is the compiler's problem."""
    score = cts[0] * w[0]
    for j in range(1, n_features):
        score = score + cts[j] * w[j]
    score = score + b
    x2 = score * score                           # HE Mul #1
    x3 = x2 * score                              # HE Mul #2 (auto align)
    return score * c1 - x3 * c3 + 0.5


# --- two request batches through one traced model ----------------------------
X2, y2 = make_batch(2)
t0 = time.time()
handles = []
for i, Xi in enumerate((X, X2)):
    cts = [session.encrypt(Xi[:, j], seed=100 * i + j)
           for j in range(n_features)]
    handles.append(traced_probs(cts))
print(f"encrypted 2 × {n_features} feature ciphertexts "
      f"({n_examples} examples/slots each): {time.time()-t0:.1f}s")

t0 = time.time()
futs = session.run(handles)          # compile + submit; NO drain yet
probs_he = [f.decrypt().real for f in futs]   # one drain serves both
cache = session.stats()["cache"]
print(f"served both traced circuits (2 HE Muls + affine each): "
      f"{time.time()-t0:.1f}s; plaintext-operand cache: "
      f"{cache['plain_hits']} hits / {cache['plain_misses']} misses "
      f"({cache['plain_entries']} entries)")

# --- client decrypt + verify -------------------------------------------------
err, accs = 0.0, []
for (Xi, yi), probs in zip(((X, y), (X2, y2)), probs_he):
    scores = Xi @ w + b
    probs_pt = 0.5 + c1 * scores - c3 * scores ** 3
    err = max(err, float(np.abs(probs - probs_pt).max()))
    acc_he = float(((probs > 0.5) == yi).mean())
    acc_poly = float(((probs_pt > 0.5) == yi).mean())
    accs.append((acc_he, acc_poly))
    if acc_he != acc_poly:
        raise AssertionError(
            "HE must match plaintext poly-sigmoid decisions")
print(f"max |HE - plaintext poly-sigmoid| = {err:.2e}")
print("accuracy per batch (encrypted == plaintext poly-sigmoid): "
      + ", ".join(f"{a:.3f}" for a, _ in accs))
if err >= 1e-2:
    raise AssertionError("HE diverged from the computation it mirrors")
if cache["plain_hits"] < 1:
    raise AssertionError(
        "second request batch never hit the plaintext-operand cache")
if accs[0][0] < acc_plain - 0.1:
    raise AssertionError("poly-sigmoid approximation degraded")
print("OK")
