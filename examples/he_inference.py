"""End-to-end encrypted inference (the paper's application class, §I/[39]):
logistic-regression scoring on ENCRYPTED features, batched in CKKS slots.

    PYTHONPATH=src python examples/he_inference.py

Pipeline:
  1. train a logistic-regression probe on synthetic data (plaintext numpy);
  2. client encrypts the feature matrix FEATURE-MAJOR: ciphertext j holds
     feature j of every example in its slots (no rotations needed);
  3. server computes   score = Σ_j w_j ⊙ ct_j + b        (he_mul_plain)
     and then a degree-3 sigmoid approximation
         σ(x) ≈ 0.5 + 0.15·x − 0.0015·x³
     HOMOMORPHICALLY — the x² and x·x² steps are real HE Muls, the
     operation this whole framework accelerates;
  4. client decrypts probabilities; we compare against plaintext inference.
"""

import time

import numpy as np

from repro.core import heaan as H
from repro.core import test_params
from repro.core.keys import keygen

# --- plaintext training ------------------------------------------------------
rng = np.random.default_rng(0)
n_examples, n_features = 64, 8
w_true = rng.normal(size=n_features)
X = rng.normal(size=(n_examples, n_features))
y = (X @ w_true + 0.3 * rng.normal(size=n_examples) > 0).astype(np.float64)

w = np.zeros(n_features)
b = 0.0
for _ in range(400):
    p = 1 / (1 + np.exp(-(X @ w + b)))
    g = X.T @ (p - y) / n_examples + 0.08 * w   # L2 keeps scores in the
    w -= 0.5 * g                                # poly-sigmoid's range
    b -= 0.5 * float(np.mean(p - y))
acc_plain = float(((1 / (1 + np.exp(-(X @ w + b))) > 0.5) == y).mean())
print(f"plaintext probe accuracy: {acc_plain:.3f} "
      f"(score range ±{np.abs(X @ w + b).max():.1f})")

# --- encrypt features (feature-major) ---------------------------------------
params = test_params(logN=8, beta_bits=32, logQ=144, logp=24)
sk, pk, evk = keygen(params, seed=0)
t0 = time.time()
cts = [H.encrypt_message(X[:, j].astype(np.complex128), pk, params,
                         seed=10 + j) for j in range(n_features)]
print(f"encrypted {n_features} feature ciphertexts "
      f"({n_examples} examples/slots each): {time.time()-t0:.1f}s")

# --- server-side encrypted scoring ------------------------------------------
t0 = time.time()
acc = None
for j in range(n_features):
    term = H.he_mul_plain(
        cts[j], H.encode_plain(np.full(n_examples, w[j], np.complex128),
                               params, cts[j].logq), params)
    acc = term if acc is None else H.he_add(acc, term)
score = H.rescale(acc, params)                       # scale back to Δ
score = H.he_add_plain(
    score, H.encode_plain(np.full(n_examples, b, np.complex128), params,
                          score.logq), params)

# degree-3 sigmoid (Kim et al. / iDASH coefficients, valid on ~[-6, 6]):
#   σ(x) ≈ 0.5 + 0.197·x − 0.004·x³      (x³ via two real HE Muls)
c1, c3 = 0.197, 0.004
x2 = H.rescale(H.he_mul(score, score, evk, params), params)      # HE Mul #1
sc_down = H.he_mod_down(score, params, x2.logq)
x3 = H.rescale(H.he_mul(x2, sc_down, evk, params), params)       # HE Mul #2
lin = H.rescale(H.he_mul_plain(
    H.he_mod_down(score, params, x3.logq),
    H.encode_plain(np.full(n_examples, c1, np.complex128), params,
                   x3.logq), params), params)
cub = H.rescale(H.he_mul_plain(
    x3, H.encode_plain(np.full(n_examples, -c3, np.complex128), params,
                       x3.logq), params), params)
lin = H.he_mod_down(lin, params, cub.logq)
poly = H.he_add(lin, cub)
half = H.encode_plain(np.full(n_examples, 0.5, np.complex128), params,
                      poly.logq, log_delta=poly.logp)
prob_ct = H.he_add_plain(poly, half, params)
print(f"encrypted scoring + homomorphic sigmoid "
      f"(2 HE Muls, 2 plain muls): {time.time()-t0:.1f}s; "
      f"final logq={prob_ct.logq}/{params.logQ}")

# --- client decrypt + verify -------------------------------------------------
probs_he = H.decrypt_message(prob_ct, sk, params).real
scores_pt = X @ w + b
probs_pt = 0.5 + c1 * scores_pt - c3 * scores_pt ** 3
err = np.abs(probs_he - probs_pt).max()
acc_he = float(((probs_he > 0.5) == y).mean())
acc_poly = float(((probs_pt > 0.5) == y).mean())
print(f"max |HE - plaintext poly-sigmoid| = {err:.2e}")
print(f"accuracy: encrypted {acc_he:.3f} | plaintext poly-sigmoid "
      f"{acc_poly:.3f} | plaintext true sigmoid {acc_plain:.3f}")
assert err < 1e-2, "HE diverged from the plaintext computation it mirrors"
assert acc_he == acc_poly, "HE must match plaintext poly-sigmoid decisions"
assert acc_he >= acc_plain - 0.1, "poly-sigmoid approximation degraded"
print("OK")
