"""Train a ~100M-parameter LM for a few hundred steps (deliverable b).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny   # quick

Uses the full production path: synthetic counter-based data pipeline,
AdamW + warmup-cosine, checkpoint/restart (kill it mid-run and rerun — it
resumes bit-identically), straggler monitoring.
"""

import argparse

from repro.configs.registry import get_arch
from repro.launch.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    full = get_arch("llama3.2-1b")
    if args.tiny:
        cfg = full.reduced()
        tc = TrainConfig(batch=8, seq_len=64, steps=args.steps,
                         peak_lr=3e-3, warmup_steps=10, ckpt_every=50)
    else:
        # ~100M params: 8L × d768 × ff2048, 32k vocab
        cfg = full.reduced(n_layers=8, d_model=768, n_heads=12,
                           n_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab_size=32000, scan_layers=True)
        tc = TrainConfig(batch=8, seq_len=256, steps=args.steps,
                         peak_lr=1e-3, warmup_steps=20, ckpt_every=50)

    trainer = Trainer(cfg, tc, ckpt_dir=args.ckpt_dir)
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")
    out = trainer.run()
    hist = out["history"]
    print(f"steps {hist[0]['step']}..{hist[-1]['step']}  "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}  "
          f"({sum(h['sec'] for h in hist):.0f}s, "
          f"{len(out['breaches'])} straggler flags)")


if __name__ == "__main__":
    main()
