"""Batched LM serving demo: prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b
    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b

Runs the reduced config of the chosen architecture (any of the 10 assigned
ids), demonstrating the cache machinery across attention / SSM / hybrid
families, and verifies decode-vs-prefill consistency on the fly.
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch
from repro.launch.serve import generate
from repro.models import init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(args.batch, args.prompt_len)), jnp.int32)
    extra = {}
    if cfg.enc_dec:
        extra["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, 2 * args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "vision":
        extra["patch_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_frontend_tokens,
                             cfg.d_model)), jnp.float32)

    t0 = time.time()
    out = generate(params, cfg, tokens, args.gen,
                   args.prompt_len + args.gen + 8, batch_extra=extra)
    dt = time.time() - t0
    print(f"arch={args.arch} family generated {tuple(out.shape)} tokens "
          f"in {dt:.1f}s ({args.batch * args.gen / dt:.1f} tok/s incl. "
          "compile)")
    print("first sequence:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
