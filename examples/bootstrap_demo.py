"""Past the native depth limit: served CKKS bootstrapping end to end.

    PYTHONPATH=src python examples/bootstrap_demo.py

Leveled HEAAN dies of modulus exhaustion (the paper's §III-A): every
mul + rescale burns logp bits of logq, and at logq == logp no further
mul can rescale. This demo walks one traced expression PAST that
limit on the `repro.client` session API:

  1. encrypt a full-slot message at the reference bootstrap config
     (`repro.boot.boot_params()`: logN=4, logQ=336, logp=24, h=2 —
     NOT secure; a pipeline-correctness parameter set);
  2. exhaust the ciphertext down to logq = logp, so even ONE more
     mul is impossible natively — `session.run([x * x])` raises
     "needs bootstrapping" at compile;
  3. re-run with `bootstrap="auto"`: the compile pass splices the
     served four-stage refresh (mod-raise → CoeffToSlot → EvalMod →
     SlotToCoeff, docs/BOOTSTRAP.md) in front of the exhausted
     operand and the square executes at the refreshed level;
  4. explicitly refresh a second exhausted ciphertext with
     `session.bootstrap(ct)` — the plan is cached per input shape and
     its CoeffToSlot/SlotToCoeff diagonals now ship hash-only;
  5. decrypt and check both results against the plan's DOCUMENTED
     error bound — bootstrap is approximate by construction; the
     bound is its correctness contract.
"""

import numpy as np

from repro.boot import boot_params, bootstrap_circuit
from repro.client import HESession
from repro.core import heaan

params = boot_params()
session = HESession(params, seed=0, batch=2, schedule=True)
n = params.n_slots_max                       # bootstrap needs FULL slots

rng = np.random.default_rng(7)
msg_bound = 2.0 ** -5                        # the per-slot |z| contract
z = (rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)) * msg_bound

# --- exhaust the modulus chain: mod-down to the last level -------------------
ct = heaan.encrypt_message(z, session.pk, params, seed=11)
ct = heaan.he_mod_down(ct, params, params.logp)
print(f"exhausted ciphertext: logq={ct.logq} (= logp={params.logp}; "
      f"no mul can rescale)")

# --- natively impossible: one more mul needs a level we don't have ----------
x = session.input(ct)
try:
    session.run([x * x])
except Exception as e:
    print(f"without bootstrap: {type(e).__name__}: {e}")

# --- auto-insertion: the compile pass splices the served refresh ------------
cc = session.compile(x * x, bootstrap="auto")
plan = bootstrap_circuit(params, logq_in=ct.logq)   # same shape → same plan
print(f"bootstrap='auto': {len(cc.bootstraps)} pipeline spliced "
      f"({len(plan.ops)} of the circuit's {len(cc.ops)} nodes), "
      f"logq {plan.logq_in} -> {plan.out_logq} "
      f"(+{plan.levels_gained} levels)")

fut, = session.run([x * x], bootstrap="auto")
got = session.decrypt(fut.result())
err = float(np.max(np.abs(got - z * z)))
# the square doubles the refreshed operand's error, and |z| ≤ mb keeps
# the product's own magnitude inside the contract
budget = 4.0 * msg_bound * plan.error_bound()
print(f"served x*x past the depth limit: |err| {err:.3e} "
      f"(budget {budget:.3e})")
assert err <= budget

# --- explicit refresh: the cached plan ships diagonals hash-only ------------
z2 = (rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)) * msg_bound
ct2 = heaan.he_mod_down(
    heaan.encrypt_message(z2, session.pk, params, seed=12),
    params, params.logp)
hits0 = session.server.stats()["cache"]["plain_hits"]
refreshed = session.bootstrap(ct2).result()
err2 = float(np.max(np.abs(session.decrypt(refreshed) - z2)))
hits = session.server.stats()["cache"]["plain_hits"] - hits0
print(f"explicit bootstrap: logq {ct2.logq} -> {refreshed.logq}, "
      f"|err| {err2:.3e} (bound {plan.error_bound():.3e}), "
      f"{hits} hash-only diagonal cache hits")
assert err2 <= plan.error_bound()
assert hits > 0, "repeat bootstrap should serve diagonals from cache"
print("ok: served past the native depth limit within the error bound")
