"""Quickstart: HEAAN basics through the public API.

    PYTHONPATH=src python examples/quickstart.py

Encodes two complex vectors, encrypts them, multiplies the ciphertexts
(the paper's HE Mul: CRT → NTT → pointwise → iNTT → iCRT, regions 1+2),
rescales, adds, decrypts — and checks the arithmetic came out right.
First with explicit core calls (the reference pipeline this repo is
built on), then the SAME computation through the `repro.client` session
API, where the compiler inserts the rescale/mod-down bookkeeping —
bitwise-identically.
"""

import time

import numpy as np

from repro.core import heaan as H
from repro.core import test_params
from repro.core.keys import keygen
from repro.core.rns import PipelineConfig

params = test_params(logN=8, beta_bits=32, logQ=120, logp=24)
print(f"params: N=2^{params.logN}, logQ={params.logQ}, logp={params.logp}, "
      f"β=2^{params.beta_bits}, depth L={params.L}")
print(f"RNS primes: region1 np={params.np_region1(params.logQ)}, "
      f"region2 np={params.np_region2(params.logQ)}")

t0 = time.time()
sk, pk, evk = keygen(params, seed=0)
print(f"keygen: {time.time()-t0:.2f}s")

rng = np.random.default_rng(0)
n = 64
z1 = rng.normal(size=n) + 1j * rng.normal(size=n)
z2 = rng.normal(size=n) + 1j * rng.normal(size=n)

c1 = H.encrypt_message(z1, pk, params, seed=1)
c2 = H.encrypt_message(z2, pk, params, seed=2)
print(f"encrypted {n} complex slots at logq={c1.logq}")

t0 = time.time()
c3 = H.he_mul(c1, c2, evk, params)        # the paper's Fig. 2 pipeline
c3 = H.rescale(c3, params)
print(f"HE Mul + rescale: {time.time()-t0:.2f}s  (logq: "
      f"{c1.logq} -> {c3.logq})")

c4 = H.he_add(c3, H.he_mod_down(c1, params, c3.logq))

out = H.decrypt_message(c4, sk, params)
expect = z1 * z2 + z1
err = np.abs(out - expect).max()
print(f"decrypt(c1*c2 + c1): max error = {err:.2e}")
assert err < 1e-2, "HE arithmetic diverged!"

# --- the same computation on the session API (the canonical frontend) --------
# x1 * x2 + x1 traces lazily; the compile pass inserts the rescale and
# the mod-down level alignment written by hand above — bitwise identical
from repro.client import HESession

session = HESession(params, sk=sk, pk=pk, evk=evk, batch=2)
x1, x2 = session.input(c1), session.input(c2)
ct = (x1 * x2 + x1).result()           # compile → batched serve → 1 ct
assert bool((np.asarray(ct.ax) == np.asarray(c4.ax)).all()
            and (np.asarray(ct.bx) == np.asarray(c4.bx)).all()), \
    "session API diverged from the hand-composed core pipeline"
print("session API (repro.client): x1 * x2 + x1 bitwise == hand-composed")

# the optimization ladder (paper §V) is a config choice:
fast = PipelineConfig(crt_strategy="matmul", icrt_strategy="matmul")
ref = PipelineConfig(crt_strategy="shoup", icrt_strategy="naive")
t0 = time.time(); H.he_mul(c1, c2, evk, params, cfg=fast)
t_fast = time.time() - t0
t0 = time.time(); H.he_mul(c1, c2, evk, params, cfg=ref)
t_ref = time.time() - t0
print(f"reference-structure HE Mul: {t_ref:.2f}s; "
      f"loop-reordered (paper §V-A): {t_fast:.2f}s")
print("OK")
