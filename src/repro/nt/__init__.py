"""Number-theory substrate: primes, roots of unity, python-int oracles."""

from repro.nt.primes import (
    is_prime,
    find_ntt_primes,
    primitive_2nth_root,
    bit_reverse_indices,
)
from repro.nt.residue import (
    int_to_limbs,
    limbs_to_int,
    ints_to_limb_array,
    limb_array_to_ints,
)

__all__ = [
    "is_prime",
    "find_ntt_primes",
    "primitive_2nth_root",
    "bit_reverse_indices",
    "int_to_limbs",
    "limbs_to_int",
    "ints_to_limb_array",
    "limb_array_to_ints",
]
