"""Python-int ↔ limb-array conversions (exact oracles for tests & I/O).

BigInts are stored little-endian as fixed-width limb arrays. These helpers
are host-side (numpy) and exact; the JAX/Pallas code paths are validated
against them.
"""

from __future__ import annotations

from typing import Iterable, List, Sequence

import numpy as np


def _dtype_for_beta(beta_bits: int):
    if beta_bits == 32:
        return np.uint32
    if beta_bits == 64:
        return np.uint64
    raise ValueError(f"unsupported beta_bits={beta_bits}")


def int_to_limbs(x: int, n_limbs: int, beta_bits: int) -> np.ndarray:
    """Non-negative python int -> little-endian limb vector (n_limbs,)."""
    assert x >= 0, "use centered/two's-complement encoding upstream"
    mask = (1 << beta_bits) - 1
    out = np.zeros(n_limbs, dtype=_dtype_for_beta(beta_bits))
    for k in range(n_limbs):
        out[k] = x & mask
        x >>= beta_bits
    if x != 0:
        raise OverflowError("value does not fit in n_limbs")
    return out


def limbs_to_int(limbs: Sequence[int] | np.ndarray, beta_bits: int) -> int:
    """Little-endian limb vector -> python int."""
    x = 0
    for k in range(len(limbs) - 1, -1, -1):
        x = (x << beta_bits) | int(limbs[k])
    return x


def ints_to_limb_array(
    xs: Iterable[int], n_limbs: int, beta_bits: int
) -> np.ndarray:
    """List of non-negative ints -> (len(xs), n_limbs) limb matrix."""
    xs = list(xs)
    out = np.zeros((len(xs), n_limbs), dtype=_dtype_for_beta(beta_bits))
    for i, x in enumerate(xs):
        out[i] = int_to_limbs(x, n_limbs, beta_bits)
    return out


def limb_array_to_ints(arr: np.ndarray, beta_bits: int) -> List[int]:
    """(M, n_limbs) limb matrix -> list of python ints."""
    return [limbs_to_int(row, beta_bits) for row in np.asarray(arr)]


def signed_to_mod_q(x: int, q: int) -> int:
    """Center-lift inverse: signed int -> representative in [0, q)."""
    return x % q


def mod_q_to_signed(x: int, q: int) -> int:
    """Representative in [0, q) -> centered signed value in [-q/2, q/2)."""
    return x - q if x >= q // 2 else x
