"""NTT-friendly prime generation and roots of unity.

All functions here run host-side with Python ints (exact arithmetic); they
feed the precomputed tables in :mod:`repro.core.context`. The paper requires
primes p ≡ 1 (mod 2N) so that a primitive 2N-th root of unity ψ exists,
enabling the negacyclic NTT over Z_p[X]/(X^N + 1).
"""

from __future__ import annotations

import random
from functools import lru_cache
from typing import List

# Deterministic Miller-Rabin witness sets (Jaeschke / Sorenson-Webster):
# valid for all n < 3.3e24, which covers every word size we use (< 2^64).
_MR_WITNESSES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)

_SMALL_PRIMES = (
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67,
    71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113, 127, 131, 137, 139,
)


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24."""
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n == p:
            return True
        if n % p == 0:
            return False
    d = n - 1
    r = 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_WITNESSES:
        if a >= n:
            continue
        x = pow(a, d, n)
        if x == 1 or x == n - 1:
            continue
        for _ in range(r - 1):
            x = (x * x) % n
            if x == n - 1:
                break
        else:
            return False
    return True


@lru_cache(maxsize=None)
def find_ntt_primes(
    n_poly: int,
    count: int,
    lo_bits: int,
    hi_bits: int,
    descending: bool = True,
) -> tuple:
    """Find `count` primes p with 2^lo_bits < p < 2^hi_bits and p ≡ 1 (mod 2N).

    Scans candidates k·2N + 1 from the top of the range downward (as HEAAN
    does — the largest primes give the most headroom for delayed-modulo
    accumulation). Deterministic for reproducibility.
    """
    two_n = 2 * n_poly
    hi = (1 << hi_bits) - 1
    lo = 1 << lo_bits
    # Largest k with k*2N + 1 <= hi.
    k = (hi - 1) // two_n
    primes: List[int] = []
    while len(primes) < count and k > 0:
        cand = k * two_n + 1
        if cand < lo:
            break
        if is_prime(cand):
            primes.append(cand)
        k -= 1
    if len(primes) < count:
        raise ValueError(
            f"only found {len(primes)}/{count} primes ≡1 mod {two_n} "
            f"in (2^{lo_bits}, 2^{hi_bits})"
        )
    if not descending:
        primes.reverse()
    return tuple(primes)


def primitive_2nth_root(p: int, n_poly: int, seed: int = 0) -> int:
    """Find ψ of multiplicative order exactly 2N modulo prime p.

    Requires p ≡ 1 (mod 2N). ψ = x^((p-1)/2N) has order dividing 2N; the
    order is exactly 2N iff ψ^N ≡ -1 (mod p).
    """
    two_n = 2 * n_poly
    assert (p - 1) % two_n == 0, "p must be ≡ 1 (mod 2N)"
    exp = (p - 1) // two_n
    rng = random.Random(seed ^ p)
    while True:
        x = rng.randrange(2, p - 1)
        psi = pow(x, exp, p)
        if psi in (0, 1):
            continue
        if pow(psi, n_poly, p) == p - 1:
            return psi


def bit_reverse_indices(n: int) -> List[int]:
    """Bit-reversal permutation of range(n); n must be a power of two."""
    bits = n.bit_length() - 1
    assert 1 << bits == n, "n must be a power of two"
    out = [0] * n
    for i in range(n):
        r = 0
        x = i
        for _ in range(bits):
            r = (r << 1) | (x & 1)
            x >>= 1
        out[i] = r
    return out


def shoup_precompute(y: int, p: int, beta_bits: int) -> int:
    """Shoup constant floor(y·β / p) for Shoup modular multiplication."""
    return (y << beta_bits) // p
