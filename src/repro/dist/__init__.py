"""Distribution layer: sharding rules, the mesh-sharded HE-Mul pipeline,
and explicit compressed collectives.

The paper's residue-level parallelism (§V: one prime per thread, transposed
layouts) maps directly onto a JAX device mesh: the `np` CRT primes of HE Mul
live on the "model" axis (HEAX's per-modulus hardware lanes, as mesh shards)
while batches of ciphertexts / training examples live on the "data" axis.

Modules:
  - sharding:    NamedSharding rule engines for HE limb tensors, LM params,
                 KV caches, batches, and ZeRO-1 optimizer state.
  - he_pipeline: the paper's Fig. 2 two-region HE Mul as a single jit-able,
                 mesh-sharded step, bitwise identical to core.heaan.he_mul;
                 its batched stages are factored as make_stage_fns /
                 make_keyswitch_step (reused by repro.hserve's rotate and
                 slot-sum engine) and route through the repro.kernels
                 Pallas paths with use_kernels=True.
  - collectives: int8 compress -> all-gather -> decompress gradient
                 reduction (composes with optim.compress).
"""

from repro.dist import collectives, he_pipeline, sharding  # noqa: F401

__all__ = ["sharding", "he_pipeline", "collectives"]
