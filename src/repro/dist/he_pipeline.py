"""The paper's Fig. 2 two-region HE Mul as one jit-able, mesh-sharded step.

This is `core.heaan.he_mul` restructured for a device mesh:

  - a BATCH of ciphertext pairs (the unit a privacy-preserving serving
    system schedules) rides the "data" mesh axis;
  - the np CRT primes ride the "model" axis — the paper's §V-A pinning of
    primes to threads (and HEAX's per-modulus hardware lanes) expressed as
    GSPMD sharding, so CRT/NTT/pointwise/iNTT stages are embarrassingly
    parallel and only iCRT's cross-prime accumulation communicates;
  - every table is passed as a pytree argument (not baked as constants),
    so the whole step traces ONCE and re-runs for any batch with the same
    static shape.

Bitwise contract: the step reuses the exact `core` stage functions (crt,
ntt, mont pointwise, intt, icrt, BigInt combine) in the same order as
`core.heaan.he_mul`, and sharding is expressed only through placement
constraints — integer limb arithmetic partitions exactly, and iCRT's f64
quotient estimate is followed by exact ±1 corrections — so the sharded
output equals the single-device reference bit for bit (tests/test_dist.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bigint
from repro.core.cipher import EvalKey
from repro.core.context import (
    HEContext, IcrtTables, build_icrt_tables,
)
from repro.core.crt import crt, icrt
from repro.core.ntt import intt, ntt, pointwise_shoup_scale
from repro.core.params import HEParams
from repro.core.wordops import modadd, modsub, mont_modmul
from repro.dist.sharding import data_axes, he_eval_sharding

__all__ = [
    "HEStatic", "he_static", "region_tables", "evk_tables",
    "runtime_tables", "he_table_specs", "he_input_specs",
    "make_he_mul_step",
]

# Keys of a region-table pytree, in the order region_tables emits them.
REGION_TABLE_KEYS = (
    "primes", "psi_rev", "psi_rev_shoup", "ipsi_rev", "ipsi_rev_shoup",
    "n_inv", "n_inv_shoup", "pprime", "r2", "crt_tb", "crt_tb_shoup",
    "inv_P", "inv_P_shoup", "pdivp", "P_limbs", "P_half_limbs", "p_inv_f64",
)

EVK_TABLE_KEYS = ("ax_ev", "ax_ev_shoup", "bx_ev", "bx_ev_shoup")


@dataclasses.dataclass(frozen=True)
class HEStatic:
    """Everything shape-static about one HE-Mul level: prime counts, limb
    widths, and the iCRT accumulator tables' static metadata. Cheap to
    build (no NTT twiddles) — dry-run lowering needs only this."""

    params: HEParams
    logq: int
    qlimbs: int
    np1: int
    np2: int
    np2_max: int          # rows of the stored evk (region 2 at logQ)
    ks_limbs: int         # key-switch product width before ÷Q
    icrt1: IcrtTables
    icrt2: IcrtTables

    @property
    def N(self) -> int:
        return self.params.N

    @property
    def dtype(self):
        return np.uint32 if self.params.beta_bits == 32 else np.uint64


def he_static(params: HEParams, logq: int) -> HEStatic:
    """Static shape/table metadata for an HE Mul at modulus 2^logq."""
    np1 = params.np_region1(logq)
    np2 = params.np_region2(logq)
    return HEStatic(
        params=params,
        logq=logq,
        qlimbs=params.qlimbs(logq),
        np1=np1,
        np2=np2,
        np2_max=params.np_region2(params.logQ),
        ks_limbs=params.limbs_for_bits(logq + params.logQ) + 1,
        icrt1=build_icrt_tables(params, np1),
        icrt2=build_icrt_tables(params, np2),
    )


# --------------------------------------------------------------------------
# table pytrees
# --------------------------------------------------------------------------

def region_tables(ctx: HEContext, region: int) -> Dict[str, np.ndarray]:
    """All tables one region's CRT→NTT→iNTT→iCRT chain consumes, as a flat
    dict of host arrays (callers jnp.asarray / device_put them; the step
    takes them as arguments so nothing is baked into the jaxpr)."""
    assert region in (1, 2)
    g = ctx.tables
    npn = ctx.np1 if region == 1 else ctx.np2
    tabs = ctx.icrt1 if region == 1 else ctx.icrt2
    K = ctx.qlimbs
    return {
        "primes": g.primes[:npn],
        "psi_rev": g.psi_rev[:npn],
        "psi_rev_shoup": g.psi_rev_shoup[:npn],
        "ipsi_rev": g.ipsi_rev[:npn],
        "ipsi_rev_shoup": g.ipsi_rev_shoup[:npn],
        "n_inv": g.n_inv[:npn],
        "n_inv_shoup": g.n_inv_shoup[:npn],
        "pprime": g.pprime[:npn],
        "r2": g.r2[:npn],
        "crt_tb": g.crt_tb[:npn, :K],
        "crt_tb_shoup": g.crt_tb_shoup[:npn, :K],
        "inv_P": tabs.inv_P,
        "inv_P_shoup": tabs.inv_P_shoup,
        "pdivp": tabs.pdivp,
        "P_limbs": tabs.P_limbs,
        "P_half_limbs": tabs.P_half_limbs,
        "p_inv_f64": g.p_inv_f64[:npn],
    }


def evk_tables(evk: EvalKey) -> Dict[str, jnp.ndarray]:
    """The evaluation key as a flat pytree (already eval-domain + Shoup;
    the step slices rows [:np2] for the current level)."""
    return {
        "ax_ev": evk.ax_ev,
        "ax_ev_shoup": evk.ax_ev_shoup,
        "bx_ev": evk.bx_ev,
        "bx_ev_shoup": evk.bx_ev_shoup,
    }


def runtime_tables(ctx: HEContext, evk: EvalKey) -> Tuple[Dict, Dict, Dict]:
    """Device-ready (t1, t2, ek) pytrees for running the step (the runtime
    counterpart of he_table_specs; tables replicate across the mesh)."""
    t1 = {k: jnp.asarray(v) for k, v in region_tables(ctx, 1).items()}
    t2 = {k: jnp.asarray(v) for k, v in region_tables(ctx, 2).items()}
    ek = {k: jnp.asarray(v) for k, v in evk_tables(evk).items()}
    return t1, t2, ek


def _region_spec(st: HEStatic, npn: int, tabs: IcrtTables) -> Dict:
    dt = st.dtype
    N = st.N
    sds = jax.ShapeDtypeStruct
    return {
        "primes": sds((npn,), dt),
        "psi_rev": sds((npn, N), dt),
        "psi_rev_shoup": sds((npn, N), dt),
        "ipsi_rev": sds((npn, N), dt),
        "ipsi_rev_shoup": sds((npn, N), dt),
        "n_inv": sds((npn,), dt),
        "n_inv_shoup": sds((npn,), dt),
        "pprime": sds((npn,), dt),
        "r2": sds((npn,), dt),
        "crt_tb": sds((npn, st.qlimbs), dt),
        "crt_tb_shoup": sds((npn, st.qlimbs), dt),
        "inv_P": sds((npn,), dt),
        "inv_P_shoup": sds((npn,), dt),
        "pdivp": sds((npn, tabs.plimbs), dt),
        "P_limbs": sds((tabs.accum_limbs,), dt),
        "P_half_limbs": sds((tabs.accum_limbs,), dt),
        "p_inv_f64": sds((npn,), np.float64),
    }


def he_table_specs(st: HEStatic) -> Tuple[Dict, Dict, Dict]:
    """Abstract (t1, t2, ek) pytrees for lowering without building the
    multi-second NTT twiddle tables (the dry-run path)."""
    t1 = _region_spec(st, st.np1, st.icrt1)
    t2 = _region_spec(st, st.np2, st.icrt2)
    sds = jax.ShapeDtypeStruct
    ek = {k: sds((st.np2_max, st.N), st.dtype) for k in EVK_TABLE_KEYS}
    return t1, t2, ek


def he_input_specs(st: HEStatic, batch: int) -> Tuple:
    """Abstract (ax1, bx1, ax2, bx2) ciphertext-batch operands."""
    sds = jax.ShapeDtypeStruct((batch, st.N, st.qlimbs), st.dtype)
    return (sds, sds, sds, sds)


# --------------------------------------------------------------------------
# batched stage wrappers (value-identical to the per-item core stages)
# --------------------------------------------------------------------------

def _crt_b(x: jnp.ndarray, t: Dict, strategy: str) -> jnp.ndarray:
    """(B, N, K) limbs -> (B, np, N) residues. CRT rows are independent
    per coefficient, so batching folds into the row dimension exactly."""
    B, N, K = x.shape
    res = crt(x.reshape(B * N, K), t["crt_tb"], t["crt_tb_shoup"],
              t["primes"], strategy=strategy)
    return jnp.moveaxis(res.reshape(res.shape[0], B, N), 0, 1)


def _ntt_b(r: jnp.ndarray, t: Dict, modified: bool) -> jnp.ndarray:
    return jax.vmap(lambda rr: ntt(
        rr, t["psi_rev"], t["psi_rev_shoup"], t["primes"],
        modified=modified))(r)


def _intt_b(r: jnp.ndarray, t: Dict, modified: bool) -> jnp.ndarray:
    return jax.vmap(lambda rr: intt(
        rr, t["ipsi_rev"], t["ipsi_rev_shoup"], t["n_inv"],
        t["n_inv_shoup"], t["primes"], modified=modified))(r)


def _icrt_b(r: jnp.ndarray, t: Dict, tabs: IcrtTables, out_limbs: int,
            strategy: str) -> jnp.ndarray:
    return jax.vmap(lambda rr: icrt(
        rr, tabs, t["primes"], t["inv_P"], t["inv_P_shoup"], t["pdivp"],
        t["P_limbs"], t["P_half_limbs"], t["p_inv_f64"],
        out_limbs=out_limbs, strategy=strategy))(r)


def _mont_mul_b(a: jnp.ndarray, b: jnp.ndarray, t: Dict) -> jnp.ndarray:
    return mont_modmul(a, b, t["primes"][:, None], t["pprime"][:, None],
                       t["r2"][:, None])


# --------------------------------------------------------------------------
# the step
# --------------------------------------------------------------------------

def make_he_mul_step(st: HEStatic, mesh: Mesh, *,
                     crt_strategy: str = "matmul",
                     icrt_strategy: str = "matmul",
                     modified_shoup: bool = False,
                     reduce_scatter_icrt: bool = False):
    """Build step(t1, t2, ek, ax1, bx1, ax2, bx2) -> (ax3, bx3).

    Operands are (B, N, qlimbs) limb batches; outputs likewise. Strategy
    knobs select the paper's optimization ladder per stage (benchmarks/
    hillclimb.py sweeps them); `reduce_scatter_icrt` additionally shards
    the post-iCRT limb axis on "model" so the partitioner can lower the
    cross-prime reduction as reduce-scatter instead of all-reduce.
    """
    params, logq, qlimbs = st.params, st.logq, st.qlimbs
    np2, ks_limbs = st.np2, st.ks_limbs
    batch_axes = data_axes(mesh)
    b_ax = batch_axes if batch_axes else None
    ev_sh = he_eval_sharding(mesh)
    model = "model" if "model" in mesh.axis_names else None
    limb_sh = NamedSharding(
        mesh, P(b_ax, None, model if reduce_scatter_icrt else None))
    out_sh = NamedSharding(mesh, P(b_ax))

    def ev(x):
        return jax.lax.with_sharding_constraint(x, ev_sh)

    def limbs(x):
        return jax.lax.with_sharding_constraint(x, limb_sh)

    def to_eval(x, t):
        return ev(_ntt_b(ev(_crt_b(x, t, crt_strategy)), t, modified_shoup))

    def from_eval(e, t, tabs, out_limbs):
        res = _intt_b(e, t, modified_shoup)
        return limbs(_icrt_b(ev(res), t, tabs, out_limbs, icrt_strategy))

    def step(t1, t2, ek, ax1, bx1, ax2, bx2):
        p1 = t1["primes"][:, None]
        # ---- region 1: 4×(CRT→NTT), 3 pointwise, 3×(iNTT→iCRT) ----------
        ea1 = to_eval(ax1, t1)
        eb1 = to_eval(bx1, t1)
        ea2 = to_eval(ax2, t1)
        eb2 = to_eval(bx2, t1)

        d0_ev = _mont_mul_b(eb1, eb2, t1)
        d2_ev = _mont_mul_b(ea1, ea2, t1)
        d1_ev = _mont_mul_b(modadd(ea1, eb1, p1), modadd(ea2, eb2, p1), t1)
        d1_ev = modsub(modsub(d1_ev, d0_ev, p1), d2_ev, p1)

        d0 = from_eval(d0_ev, t1, st.icrt1, qlimbs)
        d1 = from_eval(d1_ev, t1, st.icrt1, qlimbs)
        d2 = bigint.mask_bits(from_eval(d2_ev, t1, st.icrt1, qlimbs), logq)

        # ---- region 2: key switching against the evk --------------------
        e2 = to_eval(d2, t2)
        p2 = t2["primes"]
        ks_ax = from_eval(
            pointwise_shoup_scale(e2, ek["ax_ev"][:np2],
                                  ek["ax_ev_shoup"][:np2], p2,
                                  modified=modified_shoup),
            t2, st.icrt2, ks_limbs)
        ks_bx = from_eval(
            pointwise_shoup_scale(e2, ek["bx_ev"][:np2],
                                  ek["bx_ev_shoup"][:np2], p2,
                                  modified=modified_shoup),
            t2, st.icrt2, ks_limbs)
        ks_ax = bigint.shift_right_round(ks_ax, params.logQ,
                                         out_limbs=qlimbs)
        ks_bx = bigint.shift_right_round(ks_bx, params.logQ,
                                         out_limbs=qlimbs)

        # ---- combine ----------------------------------------------------
        ax3 = bigint.mask_bits(bigint.add(d1, ks_ax), logq)
        bx3 = bigint.mask_bits(bigint.add(d0, ks_bx), logq)
        return (jax.lax.with_sharding_constraint(ax3, out_sh),
                jax.lax.with_sharding_constraint(bx3, out_sh))

    return step
