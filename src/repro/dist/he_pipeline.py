"""The paper's Fig. 2 two-region HE Mul as one jit-able, mesh-sharded step.

This is `core.heaan.he_mul` restructured for a device mesh:

  - a BATCH of ciphertext pairs (the unit a privacy-preserving serving
    system schedules) rides the "data" mesh axis;
  - the np CRT primes ride the "model" axis — the paper's §V-A pinning of
    primes to threads (and HEAX's per-modulus hardware lanes) expressed as
    GSPMD sharding, so CRT/NTT/pointwise/iNTT stages are embarrassingly
    parallel and only iCRT's cross-prime accumulation communicates;
  - every table is passed as a pytree argument (not baked as constants),
    so the whole step traces ONCE and re-runs for any batch with the same
    static shape.

Bitwise contract: the step reuses the exact `core` stage functions (crt,
ntt, mont pointwise, intt, icrt, BigInt combine) in the same order as
`core.heaan.he_mul`, and sharding is expressed only through placement
constraints — integer limb arithmetic partitions exactly, and iCRT's f64
quotient estimate is followed by exact ±1 corrections — so the sharded
output equals the single-device reference bit for bit (tests/test_dist.py).

The batched stage wrappers are factored into a :class:`StageFns` bundle
(``make_stage_fns``) plus a region-2 key-switch factory
(``make_keyswitch_step``) so `repro.hserve.engine` can lift Galois
rotations, conjugations, and slot-sum reductions onto the same table
pytrees — every ciphertext op that key-switches shares Fig. 2's region 2
verbatim — and every stage can route through the repro.kernels Pallas
paths (``use_kernels``; the kernels are exact integer drop-ins, so the
bitwise contract holds on either path).

Table pytree note: ``quot_fix`` (in REGION_TABLE_KEYS since the Pallas
routing landed) is ⌊β²/p_j⌋ as two β-bit limbs per prime — the
fixed-point reciprocal the TPU iCRT kernel uses for its quotient
estimate in place of the reference path's f64 multiply (TPUs have no
f64). It is built by ``build_icrt_tables`` but depends only on the
prime, so `repro.hserve.tables.TableCache` row-slices it from one
resident copy like the prime-pool tables, not per-np like the other
iCRT entries. See ``IcrtTables.quot_fix`` in `core/context.py` and
`kernels/icrt/icrt.py`.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bigint
from repro.core.cipher import EvalKey
from repro.core.context import (
    HEContext, IcrtTables, build_icrt_tables,
)
from repro.core.crt import crt, icrt
from repro.core.ntt import intt, ntt, pointwise_shoup_scale
from repro.core.params import HEParams
from repro.core.wordops import modadd, modsub, mont_modmul
from repro.dist.sharding import data_axes, he_eval_sharding

__all__ = [
    "HEStatic", "he_static", "region_tables", "evk_tables",
    "runtime_tables", "he_table_specs", "he_input_specs",
    "StageFns", "make_stage_fns", "make_keyswitch_step",
    "make_he_mul_step",
]

# Keys of a region-table pytree, in the order region_tables emits them.
REGION_TABLE_KEYS = (
    "primes", "psi_rev", "psi_rev_shoup", "ipsi_rev", "ipsi_rev_shoup",
    "n_inv", "n_inv_shoup", "pprime", "r2", "crt_tb", "crt_tb_shoup",
    "inv_P", "inv_P_shoup", "pdivp", "P_limbs", "P_half_limbs", "p_inv_f64",
    "quot_fix",
)

EVK_TABLE_KEYS = ("ax_ev", "ax_ev_shoup", "bx_ev", "bx_ev_shoup")


@dataclasses.dataclass(frozen=True)
class HEStatic:
    """Everything shape-static about one HE-Mul level: prime counts, limb
    widths, and the iCRT accumulator tables' static metadata. Cheap to
    build (no NTT twiddles) — dry-run lowering needs only this."""

    params: HEParams
    logq: int
    qlimbs: int
    np1: int
    np2: int
    np2_max: int          # rows of the stored evk (region 2 at logQ)
    ks_limbs: int         # key-switch product width before ÷Q
    icrt1: IcrtTables
    icrt2: IcrtTables

    @property
    def N(self) -> int:
        return self.params.N

    @property
    def dtype(self):
        return np.uint32 if self.params.beta_bits == 32 else np.uint64


def he_static(params: HEParams, logq: int) -> HEStatic:
    """Static shape/table metadata for an HE Mul at modulus 2^logq."""
    np1 = params.np_region1(logq)
    np2 = params.np_region2(logq)
    return HEStatic(
        params=params,
        logq=logq,
        qlimbs=params.qlimbs(logq),
        np1=np1,
        np2=np2,
        np2_max=params.np_region2(params.logQ),
        ks_limbs=params.limbs_for_bits(logq + params.logQ) + 1,
        icrt1=build_icrt_tables(params, np1),
        icrt2=build_icrt_tables(params, np2),
    )


# --------------------------------------------------------------------------
# table pytrees
# --------------------------------------------------------------------------

def region_tables(ctx: HEContext, region: int) -> Dict[str, np.ndarray]:
    """All tables one region's CRT→NTT→iNTT→iCRT chain consumes, as a flat
    dict of host arrays (callers jnp.asarray / device_put them; the step
    takes them as arguments so nothing is baked into the jaxpr)."""
    assert region in (1, 2)
    g = ctx.tables
    npn = ctx.np1 if region == 1 else ctx.np2
    tabs = ctx.icrt1 if region == 1 else ctx.icrt2
    K = ctx.qlimbs
    return {
        "primes": g.primes[:npn],
        "psi_rev": g.psi_rev[:npn],
        "psi_rev_shoup": g.psi_rev_shoup[:npn],
        "ipsi_rev": g.ipsi_rev[:npn],
        "ipsi_rev_shoup": g.ipsi_rev_shoup[:npn],
        "n_inv": g.n_inv[:npn],
        "n_inv_shoup": g.n_inv_shoup[:npn],
        "pprime": g.pprime[:npn],
        "r2": g.r2[:npn],
        "crt_tb": g.crt_tb[:npn, :K],
        "crt_tb_shoup": g.crt_tb_shoup[:npn, :K],
        "inv_P": tabs.inv_P,
        "inv_P_shoup": tabs.inv_P_shoup,
        "pdivp": tabs.pdivp,
        "P_limbs": tabs.P_limbs,
        "P_half_limbs": tabs.P_half_limbs,
        "p_inv_f64": g.p_inv_f64[:npn],
        # ⌊β²/p_j⌋, the TPU kernel's fixed-point quotient reciprocal (the
        # no-f64 stand-in for p_inv_f64); per-prime, not per-P — see the
        # module docstring
        "quot_fix": tabs.quot_fix,
    }


def evk_tables(evk: EvalKey) -> Dict[str, jnp.ndarray]:
    """The evaluation key as a flat pytree (already eval-domain + Shoup;
    the step slices rows [:np2] for the current level)."""
    return {
        "ax_ev": evk.ax_ev,
        "ax_ev_shoup": evk.ax_ev_shoup,
        "bx_ev": evk.bx_ev,
        "bx_ev_shoup": evk.bx_ev_shoup,
    }


def runtime_tables(ctx: HEContext, evk: EvalKey) -> Tuple[Dict, Dict, Dict]:
    """Device-ready (t1, t2, ek) pytrees for running the step (the runtime
    counterpart of he_table_specs; tables replicate across the mesh)."""
    t1 = {k: jnp.asarray(v) for k, v in region_tables(ctx, 1).items()}
    t2 = {k: jnp.asarray(v) for k, v in region_tables(ctx, 2).items()}
    ek = {k: jnp.asarray(v) for k, v in evk_tables(evk).items()}
    return t1, t2, ek


def _region_spec(st: HEStatic, npn: int, tabs: IcrtTables) -> Dict:
    dt = st.dtype
    N = st.N
    sds = jax.ShapeDtypeStruct
    return {
        "primes": sds((npn,), dt),
        "psi_rev": sds((npn, N), dt),
        "psi_rev_shoup": sds((npn, N), dt),
        "ipsi_rev": sds((npn, N), dt),
        "ipsi_rev_shoup": sds((npn, N), dt),
        "n_inv": sds((npn,), dt),
        "n_inv_shoup": sds((npn,), dt),
        "pprime": sds((npn,), dt),
        "r2": sds((npn,), dt),
        "crt_tb": sds((npn, st.qlimbs), dt),
        "crt_tb_shoup": sds((npn, st.qlimbs), dt),
        "inv_P": sds((npn,), dt),
        "inv_P_shoup": sds((npn,), dt),
        "pdivp": sds((npn, tabs.plimbs), dt),
        "P_limbs": sds((tabs.accum_limbs,), dt),
        "P_half_limbs": sds((tabs.accum_limbs,), dt),
        "p_inv_f64": sds((npn,), np.float64),
        "quot_fix": sds((npn, 2), dt),
    }


def he_table_specs(st: HEStatic) -> Tuple[Dict, Dict, Dict]:
    """Abstract (t1, t2, ek) pytrees for lowering without building the
    multi-second NTT twiddle tables (the dry-run path)."""
    t1 = _region_spec(st, st.np1, st.icrt1)
    t2 = _region_spec(st, st.np2, st.icrt2)
    sds = jax.ShapeDtypeStruct
    ek = {k: sds((st.np2_max, st.N), st.dtype) for k in EVK_TABLE_KEYS}
    return t1, t2, ek


def he_input_specs(st: HEStatic, batch: int) -> Tuple:
    """Abstract (ax1, bx1, ax2, bx2) ciphertext-batch operands."""
    sds = jax.ShapeDtypeStruct((batch, st.N, st.qlimbs), st.dtype)
    return (sds, sds, sds, sds)


# --------------------------------------------------------------------------
# batched stage wrappers (value-identical to the per-item core stages)
# --------------------------------------------------------------------------
#
# Pallas routing folds the batch into whichever axis the kernel treats as
# independent rows: CRT/iCRT/pointwise are per-coefficient (batch folds
# into N), NTT/iNTT butterflies mix across N but rows are per-prime (batch
# tiles the row axis, twiddles riding along). All kernels are exact
# integer drop-ins (tests/test_kernels.py), so either path is bitwise
# identical to the core stages.

def _fold_np(x: jnp.ndarray) -> jnp.ndarray:
    """(B, np, N) -> (np, B·N): concatenate the batch into the coefficient
    axis (legal wherever the op is per-coefficient)."""
    B, npn, N = x.shape
    return jnp.moveaxis(x, 1, 0).reshape(npn, B * N)


def _unfold_np(x: jnp.ndarray, B: int) -> jnp.ndarray:
    npn = x.shape[0]
    return jnp.moveaxis(x.reshape(npn, B, -1), 0, 1)


def _crt_b(x: jnp.ndarray, t: Dict, strategy: str,
           use_kernels: bool = False) -> jnp.ndarray:
    """(B, N, K) limbs -> (B, np, N) residues. CRT rows are independent
    per coefficient, so batching folds into the row dimension exactly."""
    B, N, K = x.shape
    if use_kernels:
        from repro.kernels.crt.ops import crt_op
        kstrat = strategy if strategy in ("acc3", "mod2", "mod4") else "acc3"
        res = crt_op(x.reshape(B * N, K), t["crt_tb"], t["crt_tb_shoup"],
                     t["primes"], strategy=kstrat)
    else:
        res = crt(x.reshape(B * N, K), t["crt_tb"], t["crt_tb_shoup"],
                  t["primes"], strategy=strategy)
    return jnp.moveaxis(res.reshape(res.shape[0], B, N), 0, 1)


def _ntt_b(r: jnp.ndarray, t: Dict, modified: bool,
           use_kernels: bool = False) -> jnp.ndarray:
    if use_kernels:
        from repro.kernels.ntt.ops import ntt_op
        B, npn, N = r.shape
        return ntt_op(r.reshape(B * npn, N),
                      jnp.tile(t["psi_rev"], (B, 1)),
                      jnp.tile(t["psi_rev_shoup"], (B, 1)),
                      jnp.tile(t["primes"], B),
                      modified=modified).reshape(B, npn, N)
    return jax.vmap(lambda rr: ntt(
        rr, t["psi_rev"], t["psi_rev_shoup"], t["primes"],
        modified=modified))(r)


def _intt_b(r: jnp.ndarray, t: Dict, modified: bool,
            use_kernels: bool = False) -> jnp.ndarray:
    if use_kernels:
        from repro.kernels.ntt.ops import intt_op
        B, npn, N = r.shape
        return intt_op(r.reshape(B * npn, N),
                       jnp.tile(t["ipsi_rev"], (B, 1)),
                       jnp.tile(t["ipsi_rev_shoup"], (B, 1)),
                       jnp.tile(t["n_inv"], B),
                       jnp.tile(t["n_inv_shoup"], B),
                       jnp.tile(t["primes"], B),
                       modified=modified).reshape(B, npn, N)
    return jax.vmap(lambda rr: intt(
        rr, t["ipsi_rev"], t["ipsi_rev_shoup"], t["n_inv"],
        t["n_inv_shoup"], t["primes"], modified=modified))(r)


def _icrt_b(r: jnp.ndarray, t: Dict, tabs: IcrtTables, out_limbs: int,
            strategy: str, use_kernels: bool = False) -> jnp.ndarray:
    if use_kernels:
        from repro.core.crt import finalize_accum
        from repro.kernels.icrt.icrt import icrt_accum_pallas
        B = r.shape[0]
        accum, s = icrt_accum_pallas(
            _fold_np(r), t["inv_P"], t["inv_P_shoup"], t["pdivp"],
            t["quot_fix"], t["primes"], accum_limbs=tabs.accum_limbs)
        out = finalize_accum(accum, s, t["P_limbs"], t["P_half_limbs"],
                             out_limbs)
        return out.reshape(B, -1, out_limbs)
    return jax.vmap(lambda rr: icrt(
        rr, tabs, t["primes"], t["inv_P"], t["inv_P_shoup"], t["pdivp"],
        t["P_limbs"], t["P_half_limbs"], t["p_inv_f64"],
        out_limbs=out_limbs, strategy=strategy))(r)


def _mont_mul_b(a: jnp.ndarray, b: jnp.ndarray, t: Dict,
                use_kernels: bool = False) -> jnp.ndarray:
    if use_kernels:
        from repro.kernels.modmul.ops import pointwise_mont_op
        B = a.shape[0]
        return _unfold_np(pointwise_mont_op(
            _fold_np(a), _fold_np(b), t["primes"], t["pprime"], t["r2"]), B)
    return mont_modmul(a, b, t["primes"][:, None], t["pprime"][:, None],
                       t["r2"][:, None])


# --------------------------------------------------------------------------
# stage bundles and the steps built from them
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageFns:
    """Mesh-constrained batched stage bundle for one parameter level.

    `to_eval`/`from_eval` are the paper's CRT→NTT and iNTT→iCRT chains
    over (B, ·, ·) batches with placement constraints applied between
    stages; `mont_mul` is the region-1 pointwise product. Shared by
    make_he_mul_step and the repro.hserve rotate/slot-sum engine.
    """

    to_eval: Callable[[jnp.ndarray, Dict], jnp.ndarray]
    from_eval: Callable[[jnp.ndarray, Dict, IcrtTables, int], jnp.ndarray]
    mont_mul: Callable[[jnp.ndarray, jnp.ndarray, Dict], jnp.ndarray]
    shoup_mul: Callable[..., jnp.ndarray]          # region-2 key product
    ev: Callable[[jnp.ndarray], jnp.ndarray]       # eval-domain placement
    out: Callable[[jnp.ndarray], jnp.ndarray]      # output placement
    modified_shoup: bool
    # Fig. 3 attribution hook (repro.obs.StageTimer); None on the fused
    # jit path — timers cannot run under tracing, so the engine only
    # passes one when steps execute eagerly (--profile-stages).
    timer: Optional[object] = None


def make_stage_fns(st: HEStatic, mesh: Mesh, *,
                   crt_strategy: str = "matmul",
                   icrt_strategy: str = "matmul",
                   modified_shoup: bool = False,
                   reduce_scatter_icrt: bool = False,
                   use_kernels: bool = False,
                   stage_timer=None) -> StageFns:
    """Bind strategy knobs + mesh placements into a reusable stage bundle.

    `use_kernels` routes CRT/NTT/iNTT/iCRT/pointwise through the
    repro.kernels Pallas paths (β = 2^32 only; interpret mode off-TPU).

    `stage_timer` (a `repro.obs.StageTimer`) fences and clocks every
    stage call in the paper's Fig. 3 taxonomy — crt, ntt (fwd + inv),
    modmul (Montgomery and Shoup pointwise), icrt. Only legal on steps
    that are NOT jitted as a whole: the fence is a host-side
    block_until_ready, meaningless (and rejected by jax) under tracing.
    To keep the attribution honest, profiling jits each stage as its
    own BLOCK (compiled once per shape, fenced after each call) — fully
    eager execution would bury the real stage compute under
    per-primitive dispatch overhead that belongs to no stage. The math
    is identical either way, so the timed path stays
    bitwise-identical.
    """
    if use_kernels:
        assert st.params.beta_bits == 32, \
            "Pallas kernels are β=2^32 (TPU-native)"
    batch_axes = data_axes(mesh)
    b_ax = batch_axes if batch_axes else None
    ev_sh = he_eval_sharding(mesh)
    model = "model" if "model" in mesh.axis_names else None
    limb_sh = NamedSharding(
        mesh, P(b_ax, None, model if reduce_scatter_icrt else None))
    out_sh = NamedSharding(mesh, P(b_ax))

    def ev(x):
        return jax.lax.with_sharding_constraint(x, ev_sh)

    def limbs(x):
        return jax.lax.with_sharding_constraint(x, limb_sh)

    def out(x):
        return jax.lax.with_sharding_constraint(x, out_sh)

    if stage_timer is None:
        def timed(stage, thunk):
            return thunk()

        def crt_f(x, t):
            return _crt_b(x, t, crt_strategy, use_kernels)

        def ntt_f(r, t):
            return _ntt_b(r, t, modified_shoup, use_kernels)

        def intt_f(r, t):
            return _intt_b(r, t, modified_shoup, use_kernels)

        def mont_f(a, b, t):
            return _mont_mul_b(a, b, t, use_kernels)

        def shoup_f(e, w, ws, p):
            return pointwise_shoup_scale(e, w, ws, p,
                                         modified=modified_shoup)

        def icrt_f(r, t, tabs, out_limbs):
            return _icrt_b(r, t, tabs, out_limbs, icrt_strategy,
                           use_kernels)
    else:
        # profiling: each stage compiles as its own block, so a timed
        # call measures the stage's fused compute, not uncompiled
        # per-primitive dispatch. One jit per stage per shape signature.
        # The inter-stage mesh placements fold INTO the neighbouring
        # stage's block (they are free data-layout hints under jit, but
        # standalone eager dispatches that would inflate the un-bucketed
        # remainder and erode the coverage gate if left outside).
        timed = stage_timer.timed
        crt_f = jax.jit(
            lambda x, t: _crt_b(x, t, crt_strategy, use_kernels))
        ntt_f = jax.jit(
            lambda r, t: ev(_ntt_b(ev(r), t, modified_shoup,
                                   use_kernels)))
        intt_f = jax.jit(
            lambda r, t: _intt_b(r, t, modified_shoup, use_kernels))
        mont_f = jax.jit(
            lambda a, b, t: _mont_mul_b(a, b, t, use_kernels))
        shoup_f = jax.jit(
            lambda e, w, ws, p: pointwise_shoup_scale(
                e, w, ws, p, modified=modified_shoup))
        _icrt_jits: Dict[Tuple[int, int], Callable] = {}

        def icrt_f(r, t, tabs, out_limbs):
            # tabs is host-side static table metadata (baked into the
            # trace exactly as the fused path bakes it via closure)
            key = (id(tabs), out_limbs)
            if key not in _icrt_jits:
                _icrt_jits[key] = jax.jit(lambda rr, tt: limbs(_icrt_b(
                    ev(rr), tt, tabs, out_limbs, icrt_strategy,
                    use_kernels)))
            return _icrt_jits[key](r, t)

    if stage_timer is None:
        def to_eval(x, t):
            r = timed("crt", lambda: crt_f(x, t))
            return ev(timed("ntt", lambda: ntt_f(ev(r), t)))

        def from_eval(e, t, tabs, out_limbs):
            # iNTT books under "ntt": Fig. 3 plots one transform bucket.
            res = timed("ntt", lambda: intt_f(e, t))
            return limbs(timed("icrt", lambda: icrt_f(ev(res), t, tabs,
                                                      out_limbs)))
    else:
        # placements already live inside the jitted stage blocks
        def to_eval(x, t):
            r = timed("crt", lambda: crt_f(x, t))
            return timed("ntt", lambda: ntt_f(r, t))

        def from_eval(e, t, tabs, out_limbs):
            res = timed("ntt", lambda: intt_f(e, t))
            return timed("icrt", lambda: icrt_f(res, t, tabs, out_limbs))

    def mont_mul(a, b, t):
        return timed("modmul", lambda: mont_f(a, b, t))

    def shoup_mul(e, w, w_shoup, primes):
        return timed("modmul", lambda: shoup_f(e, w, w_shoup, primes))

    if stage_timer is not None:
        # output placement too — the last eager dispatch on the path
        out = jax.jit(out)

    return StageFns(to_eval=to_eval, from_eval=from_eval,
                    mont_mul=mont_mul, shoup_mul=shoup_mul, ev=ev, out=out,
                    modified_shoup=modified_shoup, timer=stage_timer)


def _region(sf: StageFns, name: str):
    """Fig. 2 region scope when the bundle carries a StageTimer; free
    (nullcontext) on the fused path."""
    return sf.timer.region(name) if sf.timer is not None \
        else contextlib.nullcontext()


def _glue_jit(sf: StageFns):
    """jax.jit for the un-bucketed glue (BigInt shifts/adds, masks,
    automorphism permutes) when profiling — uncompiled glue would
    dominate the device wall with dispatch overhead that belongs to no
    Fig. 3 stage and sink the stage-coverage contract. Identity on the
    fused path (the enclosing step jit owns everything)."""
    return jax.jit if sf.timer is not None else (lambda f: f)


def make_keyswitch_step(st: HEStatic, sf: StageFns):
    """Region-2 key switch: ks(t2, ek, d) -> (ks_ax, ks_bx) at qlimbs.

    The shared tail of HE Mul (d = d2) and every Galois operation
    (d = σ_k(ax)) — paper Fig. 2's region 2: CRT→NTT at np₂ primes,
    two Shoup pointwise products against the (rotation/evaluation) key,
    iNTT→iCRT at ks_limbs, then the ÷Q rounding shift.
    """
    np2, ks_limbs = st.np2, st.ks_limbs
    logQ, qlimbs = st.params.logQ, st.qlimbs
    shift_f = _glue_jit(sf)(
        lambda x: bigint.shift_right_round(x, logQ, out_limbs=qlimbs))

    def ks(t2, ek, d):
        with _region(sf, "region2"):
            e2 = sf.to_eval(d, t2)
            p2 = t2["primes"]
            ks_ax = sf.from_eval(
                sf.shoup_mul(e2, ek["ax_ev"][:np2],
                             ek["ax_ev_shoup"][:np2], p2),
                t2, st.icrt2, ks_limbs)
            ks_bx = sf.from_eval(
                sf.shoup_mul(e2, ek["bx_ev"][:np2],
                             ek["bx_ev_shoup"][:np2], p2),
                t2, st.icrt2, ks_limbs)
            ks_ax = shift_f(ks_ax)
            ks_bx = shift_f(ks_bx)
        return ks_ax, ks_bx

    return ks


def make_he_mul_step(st: HEStatic, mesh: Mesh, *,
                     crt_strategy: str = "matmul",
                     icrt_strategy: str = "matmul",
                     modified_shoup: bool = False,
                     reduce_scatter_icrt: bool = False,
                     use_kernels: bool = False,
                     stage_timer=None):
    """Build step(t1, t2, ek, ax1, bx1, ax2, bx2) -> (ax3, bx3).

    Operands are (B, N, qlimbs) limb batches; outputs likewise. Strategy
    knobs select the paper's optimization ladder per stage (benchmarks/
    hillclimb.py sweeps them); `reduce_scatter_icrt` additionally shards
    the post-iCRT limb axis on "model" so the partitioner can lower the
    cross-prime reduction as reduce-scatter instead of all-reduce;
    `use_kernels` routes every stage through the repro.kernels Pallas
    paths (β = 2^32), keeping the bitwise contract.
    """
    logq, qlimbs = st.logq, st.qlimbs
    sf = make_stage_fns(st, mesh, crt_strategy=crt_strategy,
                        icrt_strategy=icrt_strategy,
                        modified_shoup=modified_shoup,
                        reduce_scatter_icrt=reduce_scatter_icrt,
                        use_kernels=use_kernels,
                        stage_timer=stage_timer)
    keyswitch = make_keyswitch_step(st, sf)
    gj = _glue_jit(sf)
    add_f = gj(lambda a, b, p: modadd(a, b, p))
    d1fix_f = gj(lambda d1, d0, d2, p: modsub(modsub(d1, d0, p), d2, p))
    mask_f = gj(lambda x: bigint.mask_bits(x, logq))
    comb_f = gj(lambda d, ks: bigint.mask_bits(bigint.add(d, ks), logq))

    def step(t1, t2, ek, ax1, bx1, ax2, bx2):
        p1 = t1["primes"][:, None]
        # ---- region 1: 4×(CRT→NTT), 3 pointwise, 3×(iNTT→iCRT) ----------
        with _region(sf, "region1"):
            ea1 = sf.to_eval(ax1, t1)
            eb1 = sf.to_eval(bx1, t1)
            ea2 = sf.to_eval(ax2, t1)
            eb2 = sf.to_eval(bx2, t1)

            d0_ev = sf.mont_mul(eb1, eb2, t1)
            d2_ev = sf.mont_mul(ea1, ea2, t1)
            d1_ev = sf.mont_mul(add_f(ea1, eb1, p1),
                                add_f(ea2, eb2, p1), t1)
            d1_ev = d1fix_f(d1_ev, d0_ev, d2_ev, p1)

            d0 = sf.from_eval(d0_ev, t1, st.icrt1, qlimbs)
            d1 = sf.from_eval(d1_ev, t1, st.icrt1, qlimbs)
            d2 = mask_f(sf.from_eval(d2_ev, t1, st.icrt1, qlimbs))

        # ---- region 2: key switching against the evk --------------------
        ks_ax, ks_bx = keyswitch(t2, ek, d2)

        # ---- combine ----------------------------------------------------
        ax3 = comb_f(d1, ks_ax)
        bx3 = comb_f(d0, ks_bx)
        return sf.out(ax3), sf.out(bx3)

    return step
