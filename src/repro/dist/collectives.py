"""Explicit compressed collectives for the shard_map DP training path.

`compressed_psum_grads` is the wire protocol `optim/compress.py` documents:
each device int8-block-quantizes its local gradient shard (stochastic
rounding, per-256-block f32 scales), the int8 payloads + scales are
all-gathered (4× less traffic than an f32 ring all-reduce), and every
device dequantizes per source and averages. Because each replica averages
the same gathered data in the same order, all replicas hold bit-identical
results — the property tests/test_dist.py asserts.
"""

from __future__ import annotations

from typing import Any, Tuple, Union

import jax
import jax.numpy as jnp

from repro.optim.compress import compress_int8, decompress_int8

__all__ = ["compressed_psum_grads"]

AxisNames = Union[str, Tuple[str, ...]]


def _axis_size(axis_names: AxisNames) -> jnp.ndarray:
    return jax.lax.psum(jnp.ones((), jnp.int32), axis_names)


def compressed_psum_grads(grads: Any, axis_names: AxisNames, key) -> Any:
    """Mean-reduce a gradient pytree across `axis_names` in int8.

    Must be called inside shard_map (or pmap) with `axis_names` bound.
    Returns the dequantized mean with the original shapes/dtypes; every
    participant returns the same values. Error per element is bounded by
    one quantization step (≤ max|g| / 127 of the worst shard).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, max(len(leaves), 1))

    out = []
    for i, g in enumerate(leaves):
        q8, scale, meta = compress_int8(g, keys[i])
        # all-gather the compressed payload — the only wire traffic
        q_all = jax.lax.all_gather(q8, axis_names, tiled=False)
        s_all = jax.lax.all_gather(scale, axis_names, tiled=False)
        # multi-axis all_gather stacks one dim per axis; flatten to (W, ...)
        q_all = q_all.reshape((-1,) + q8.shape)
        s_all = s_all.reshape((-1,) + scale.shape)
        deq = jax.vmap(lambda q, s: decompress_int8(q, s, meta))(
            q_all, s_all)
        mean = deq.sum(axis=0) / _axis_size(axis_names).astype(jnp.float32)
        out.append(mean.astype(g.dtype))
    return jax.tree.unflatten(treedef, out)
