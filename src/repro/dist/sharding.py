"""NamedSharding rule engines for every distributed surface of the repo.

All rules are *placement hints*: they never change values, only where XLA
puts them, so every sharded computation stays bitwise identical to its
single-device reference (integer limb arithmetic partitions exactly; the
one f64 quotient estimate in iCRT is followed by exact ±1 corrections).

Axis convention (DESIGN.md §5, mirrors the paper's §V thread mapping):
  - "data":  batches — ciphertext pairs per HE-Mul step, LM examples.
  - "model": the np CRT primes of the HE pipeline (HEAX's per-modulus
             lanes), and tensor-parallel dims of LM weights.
  - "pod":   optional outer data axis on multi-pod meshes.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch axes of a mesh: ("pod", "data") on multi-pod, else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


# --------------------------------------------------------------------------
# HE pipeline placements
# --------------------------------------------------------------------------

def he_limb_sharding(mesh: Mesh, batch: Optional[int] = None
                     ) -> NamedSharding:
    """Placement for batched ciphertext limb arrays (B, N, qlimbs).

    The batch goes on the data axes; N and the limb axis stay local — the
    pipeline re-shards its eval-domain intermediates (B, np, N) with np on
    "model" internally. When `batch` is given and does not divide across
    the data axes, falls back to replicated (correct, just not scaled).
    """
    axes = data_axes(mesh)
    if not axes:
        return NamedSharding(mesh, P())
    if batch is not None and batch % _axis_size(mesh, axes) != 0:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(axes))


def he_eval_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for eval-domain residue tensors (B, np, N): batch on the
    data axes, the CRT primes on "model" (the paper's prime-per-thread
    pinning, §V-A)."""
    axes = data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return NamedSharding(mesh, P(axes if axes else None, model))


def batch_spec(mesh: Mesh) -> NamedSharding:
    """LM batch placement: leading (batch) dim over the data axes."""
    axes = data_axes(mesh)
    return NamedSharding(mesh, P(axes if axes else None))


# --------------------------------------------------------------------------
# LM parameter / cache / optimizer placements
# --------------------------------------------------------------------------

# Leaf or parent names whose weights are column-parallel (output dim on
# "model") vs row-parallel (input dim on "model", megatron-style so the
# matmul pair needs one collective, not two).
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg", "in_proj", "in_x", "in_y", "x_proj",
    "dt_proj", "gate_a", "gate_x", "router", "lm_head",
})
_ROW_PARALLEL = frozenset({"wo", "out_proj", "out"})
_EMBED = frozenset({"tok_embed"})


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _model_dim(names: list, shape: Tuple[int, ...]) -> Optional[int]:
    """Which dim of this leaf carries the tensor-parallel "model" axis."""
    if len(shape) < 2:
        return None
    tagged = [n for n in names if n in _COL_PARALLEL | _ROW_PARALLEL
              | _EMBED]
    if tagged:
        tag = tagged[-1]
        if tag in _ROW_PARALLEL:
            return len(shape) - 2
        if tag in _EMBED:
            return len(shape) - 2      # vocab dim of (V, D)
        return len(shape) - 1          # column-parallel: output dim
    # Unknown ≥2-d leaf (conv filters, SSM A_log, ...): largest dim.
    return max(range(len(shape)), key=lambda d: shape[d])


def param_sharding_rules(params: Any, mesh: Mesh, *,
                         fsdp_params: bool = True) -> Any:
    """Pytree of NamedShardings for model params.

    Tensor-parallel dim (by name orientation, falling back to largest-dim)
    goes on "model"; with `fsdp_params`, the largest remaining divisible
    dim goes on "data" (FSDP). Scalars, vectors, and non-divisible dims
    stay replicated — placement never fails, it only degrades.
    """
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def rule(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2:
            md = _model_dim(_path_names(path), shape)
            if md is not None and shape[md] % msize == 0 \
                    and shape[md] >= msize and shape[md] > 1:
                spec[md] = "model"
            if fsdp_params:
                free = [d for d in range(len(shape)) if spec[d] is None
                        and shape[d] % dsize == 0 and shape[d] >= dsize
                        and shape[d] > 1]
                if free:
                    spec[max(free, key=lambda d: shape[d])] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_sharding_rules(cache: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings for KV / recurrent decode caches.

    The batch dim (0, or 1 under a stacked/scanned layer axis) goes on
    "data"; of the remaining dims, prefer the head dim (-2) and otherwise
    the largest divisible dim for "model".
    """
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def rule(path, leaf):
        shape = leaf.shape
        names = _path_names(path)
        spec: list = [None] * len(shape)
        bdim = 1 if names and names[0] in ("stacked", "groups") else 0
        if len(shape) > bdim and shape[bdim] % dsize == 0 \
                and shape[bdim] >= dsize and shape[bdim] > 1:
            spec[bdim] = "data"
        cands = [d for d in range(bdim + 1, len(shape))
                 if spec[d] is None and shape[d] % msize == 0
                 and shape[d] >= msize and shape[d] > 1]
        if cands:
            head = len(shape) - 2
            spec[head if head in cands else
                 max(cands, key=lambda d: shape[d])] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache)


def zero1_opt_sharding(p_sh: Any, params: Any, mesh: Mesh) -> Any:
    """ZeRO-1 moment placement: params' sharding plus the "data" axis on
    the largest still-unsharded divisible dim (optimizer state is never
    needed unsharded, so moments can always be FSDP'd even when params
    are kept gathered for compute)."""
    dsize = mesh.shape.get("data", 1)

    def rule(sh, leaf):
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        used = {a for s in spec if s is not None
                for a in ((s,) if isinstance(s, str) else s)}
        if "data" not in used:
            free = [d for d in range(leaf.ndim) if spec[d] is None
                    and leaf.shape[d] % dsize == 0 and leaf.shape[d] >= dsize
                    and leaf.shape[d] > 1]
            if free:
                spec[max(free, key=lambda d: leaf.shape[d])] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(rule, p_sh, params)
