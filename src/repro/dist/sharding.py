"""NamedSharding rule engines for every distributed surface of the repo.

All rules are *placement hints*: they never change values, only where XLA
puts them, so every sharded computation stays bitwise identical to its
single-device reference (integer limb arithmetic partitions exactly; the
one f64 quotient estimate in iCRT is followed by exact ±1 corrections).

Axis convention (DESIGN.md §5, mirrors the paper's §V thread mapping):
  - "data":  batches — ciphertext pairs per HE-Mul step, LM examples.
  - "model": the np CRT primes of the HE pipeline (HEAX's per-modulus
             lanes), and tensor-parallel dims of LM weights.
  - "pod":   optional outer data axis on multi-pod meshes.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch axes of a mesh: ("pod", "data") on multi-pod, else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


# --------------------------------------------------------------------------
# HE pipeline placements
# --------------------------------------------------------------------------

def he_limb_sharding(mesh: Mesh, batch: Optional[int] = None
                     ) -> NamedSharding:
    """Placement for batched ciphertext limb arrays (B, N, qlimbs).

    The batch goes on the data axes; N and the limb axis stay local — the
    pipeline re-shards its eval-domain intermediates (B, np, N) with np on
    "model" internally. When `batch` is given and does not divide across
    the data axes, falls back to replicated (correct, just not scaled).
    """
    axes = data_axes(mesh)
    if not axes:
        return NamedSharding(mesh, P())
    if batch is not None and batch % _axis_size(mesh, axes) != 0:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(axes))


def he_eval_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for eval-domain residue tensors (B, np, N): batch on the
    data axes, the CRT primes on "model" (the paper's prime-per-thread
    pinning, §V-A)."""
    axes = data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return NamedSharding(mesh, P(axes if axes else None, model))


# --------------------------------------------------------------------------
# HE collective predictions (what the placements above IMPLY on the wire)
# --------------------------------------------------------------------------

# iCRT cross-prime reductions per served op, split by Fig. 2 region:
# (region-1 reductions at np1 primes, region-2 reductions at np2 primes).
# mul: from_eval for d0/d1/d2 in region 1 + the key switch's ks_ax/ks_bx
# in region 2; rotate/conjugate: the key switch only; slot_sum: one key
# switch (2 outputs) per doubling round; mul_plain: region 1 only (da,
# db); the limb-linear ops never leave the coefficient domain.
_HE_ICRT_REDUCTIONS = {
    "mul": (3, 2),
    "rotate": (0, 2),
    "conjugate": (0, 2),
    "mul_plain": (2, 0),
}


def _slot_sum_rounds(n_slots: int) -> int:
    """Doubling rounds of the slot_sum ladder (1, 2, 4, … < n_slots)."""
    rounds, r = 0, 1
    while r < n_slots:
        rounds += 1
        r *= 2
    return rounds


def mesh_collective_groups(mesh: Mesh) -> dict:
    """Device-id replica groups a collective over each named mesh axis
    would use — the oracle shardlint classifies measured HLO replica
    groups against (a group set matching no axis = layout churn)."""
    import numpy as np
    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    out = {}
    for i, name in enumerate(mesh.axis_names):
        moved = np.moveaxis(ids, i, -1).reshape(-1, ids.shape[i])
        out[str(name)] = sorted(tuple(int(x) for x in row)
                                for row in moved)
    return out


def he_expected_collectives(op: str, mesh: Mesh, params, logq: int, *,
                            batch: int, n_slots: Optional[int] = None
                            ) -> dict:
    """Predicted collective schedule of one served (op, level) cell under
    the placements above, with the default "matmul" iCRT strategy.

    Only iCRT's cross-prime accumulation communicates: every residue
    tensor is (B, np, N) with np on "model", every stage before iCRT is
    prime-pointwise, and the batch axes make every op batch-pointwise —
    so each iCRT reduction lowers to EXACTLY three all-reduces over the
    model-axis groups:

      2 × u64[B_local, N, plimbs]   the partial-product accumulator
                                    halves of the Σ_j x_j·(P/p_j) matmul
                                    (plimbs = limb width of P/p_j, from
                                    `core.context.build_icrt_tables`);
      1 × f64[B_local, N]           the quotient estimate Σ x_j/p_j that
                                    picks the exact ±1-corrected k·P.

    Wire bytes use the same ring model as `launch.hlo_analysis`
    (all-reduce = 2·S·(g−1)/g per device); B_local is the per-data-shard
    batch (the full batch when it doesn't divide — `he_limb_sharding`
    falls back to replicated). With model-axis size 1 the partitioner
    elides every reduction: zero collectives of any kind.

    One tolerated side channel: below logQ, key-switch ops slice the
    stored (np2_max, N) evk/Galois tables to [:np2] rows, and GSPMD
    rebalances the model-sharded row axis with small collective-permutes
    — exactly 4 per consumed key table (ax/bx × value/shoup), each
    moving at most one destination shard of rows (⌈np2/g⌉·N limbs). The
    returned "allowed" block bounds them so shardlint can wave them
    through without opening the door to real resharding regressions.
    """
    from repro.core.context import build_icrt_tables
    g = mesh.shape.get("model", 1)
    dsize = _axis_size(mesh, data_axes(mesh))
    b_local = batch // dsize if dsize and batch % dsize == 0 else batch
    rounds = _slot_sum_rounds(n_slots if n_slots else params.n_slots_max)
    if op == "slot_sum":
        red = (0, 2 * rounds)
    else:
        red = _HE_ICRT_REDUCTIONS.get(op, (0, 0))
    n_red = sum(red)
    n_keys = {"mul": 1, "rotate": 1, "conjugate": 1,
              "slot_sum": rounds}.get(op, 0)
    np2, np2_max = params.np_region2(logq), params.np_region2(params.logQ)
    allowed = {}
    if n_keys and g > 1 and np2 < np2_max:
        limb_bytes = 4 if params.beta_bits <= 32 else 8
        allowed["collective-permute"] = {
            "max_count": 4 * n_keys,
            "max_bytes_each": -(-np2 // g) * params.N * limb_bytes,
        }
    if g <= 1 or n_red == 0:
        return {"kinds": [], "counts": {}, "wire_bytes": 0.0,
                "n_reductions": n_red, "axis": "model", "group_size": g,
                "allowed": {}}

    def ring(size: float) -> float:
        return 2.0 * size * (g - 1) / g

    per_region = []
    total = 0.0
    for n_r, npn in zip(red, (params.np_region1(logq),
                              params.np_region2(logq))):
        if not n_r:
            continue
        plimbs = build_icrt_tables(params, npn).plimbs
        one = 2 * ring(b_local * params.N * plimbs * 8) \
            + ring(b_local * params.N * 8)
        per_region.append({"reductions": n_r, "np": npn,
                           "plimbs": plimbs, "bytes_per_reduction": one})
        total += n_r * one
    return {"kinds": ["all-reduce"], "counts": {"all-reduce": 3 * n_red},
            "wire_bytes": total, "n_reductions": n_red, "axis": "model",
            "group_size": g, "per_region": per_region, "allowed": allowed}


def batch_spec(mesh: Mesh) -> NamedSharding:
    """LM batch placement: leading (batch) dim over the data axes."""
    axes = data_axes(mesh)
    return NamedSharding(mesh, P(axes if axes else None))


# --------------------------------------------------------------------------
# LM parameter / cache / optimizer placements
# --------------------------------------------------------------------------

# Leaf or parent names whose weights are column-parallel (output dim on
# "model") vs row-parallel (input dim on "model", megatron-style so the
# matmul pair needs one collective, not two).
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg", "in_proj", "in_x", "in_y", "x_proj",
    "dt_proj", "gate_a", "gate_x", "router", "lm_head",
})
_ROW_PARALLEL = frozenset({"wo", "out_proj", "out"})
_EMBED = frozenset({"tok_embed"})


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _model_dim(names: list, shape: Tuple[int, ...]) -> Optional[int]:
    """Which dim of this leaf carries the tensor-parallel "model" axis."""
    if len(shape) < 2:
        return None
    tagged = [n for n in names if n in _COL_PARALLEL | _ROW_PARALLEL
              | _EMBED]
    if tagged:
        tag = tagged[-1]
        if tag in _ROW_PARALLEL:
            return len(shape) - 2
        if tag in _EMBED:
            return len(shape) - 2      # vocab dim of (V, D)
        return len(shape) - 1          # column-parallel: output dim
    # Unknown ≥2-d leaf (conv filters, SSM A_log, ...): largest dim.
    return max(range(len(shape)), key=lambda d: shape[d])


def param_sharding_rules(params: Any, mesh: Mesh, *,
                         fsdp_params: bool = True) -> Any:
    """Pytree of NamedShardings for model params.

    Tensor-parallel dim (by name orientation, falling back to largest-dim)
    goes on "model"; with `fsdp_params`, the largest remaining divisible
    dim goes on "data" (FSDP). Scalars, vectors, and non-divisible dims
    stay replicated — placement never fails, it only degrades.
    """
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def rule(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2:
            md = _model_dim(_path_names(path), shape)
            if md is not None and shape[md] % msize == 0 \
                    and shape[md] >= msize and shape[md] > 1:
                spec[md] = "model"
            if fsdp_params:
                free = [d for d in range(len(shape)) if spec[d] is None
                        and shape[d] % dsize == 0 and shape[d] >= dsize
                        and shape[d] > 1]
                if free:
                    spec[max(free, key=lambda d: shape[d])] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_sharding_rules(cache: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings for KV / recurrent decode caches.

    The batch dim (0, or 1 under a stacked/scanned layer axis) goes on
    "data"; of the remaining dims, prefer the head dim (-2) and otherwise
    the largest divisible dim for "model".
    """
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)

    def rule(path, leaf):
        shape = leaf.shape
        names = _path_names(path)
        spec: list = [None] * len(shape)
        bdim = 1 if names and names[0] in ("stacked", "groups") else 0
        if len(shape) > bdim and shape[bdim] % dsize == 0 \
                and shape[bdim] >= dsize and shape[bdim] > 1:
            spec[bdim] = "data"
        cands = [d for d in range(bdim + 1, len(shape))
                 if spec[d] is None and shape[d] % msize == 0
                 and shape[d] >= msize and shape[d] > 1]
        if cands:
            head = len(shape) - 2
            spec[head if head in cands else
                 max(cands, key=lambda d: shape[d])] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache)


def zero1_opt_sharding(p_sh: Any, params: Any, mesh: Mesh) -> Any:
    """ZeRO-1 moment placement: params' sharding plus the "data" axis on
    the largest still-unsharded divisible dim (optimizer state is never
    needed unsharded, so moments can always be FSDP'd even when params
    are kept gathered for compute)."""
    dsize = mesh.shape.get("data", 1)

    def rule(sh, leaf):
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        used = {a for s in spec if s is not None
                for a in ((s,) if isinstance(s, str) else s)}
        if "data" not in used:
            free = [d for d in range(leaf.ndim) if spec[d] is None
                    and leaf.shape[d] % dsize == 0 and leaf.shape[d] >= dsize
                    and leaf.shape[d] > 1]
            if free:
                spec[max(free, key=lambda d: leaf.shape[d])] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(rule, p_sh, params)
