"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    attention="full",
    rope_theta=500000.0,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

# O(L²) attention: long_500k is architecturally unsupported (DESIGN.md §6).
SKIP_SHAPES = ("long_500k",)
