"""Assigned architecture configs (--arch <id>) + the paper's HE workload.

Each module exposes CONFIG (full size, dry-run only) and the shared shape
set; repro.configs.registry resolves ids.
"""

from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shapes

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_shapes"]
