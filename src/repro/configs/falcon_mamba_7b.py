"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — Mamba-1 architecture. [arXiv:2410.05355; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,                  # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                     # no FFN — Mamba mixer only
    vocab_size=65024,
    attention="none",
    ssm=True,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

# Constant-size recurrent state: long_500k runs.
SKIP_SHAPES = ()
