"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

NOTE (DESIGN.md §6): at full size this exceeds 16 GiB/chip HBM even fully
sharded over 512 v5e chips; the dry-run compiles and reports the honest
bytes/device (EXPERIMENTS.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,                 # per-expert FFN width
    vocab_size=163840,
    attention="full",
    n_experts=384,
    top_k=8,
    capacity_factor=1.25,
    rope_theta=50000.0,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

SKIP_SHAPES = ("long_500k",)
