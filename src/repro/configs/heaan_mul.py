"""The paper's own workload as a config: batched HEAAN HE Mul serving.

Full parameters (Table III/VI): (p, L, Q, N) = (2^30, 40, 2^1200, 2^16),
β = 2^32 (TPU-native), np ≈ 81/122. A batch of ciphertext pairs is
multiplied per step — the unit a privacy-preserving serving system
schedules. Distribution: batch → data axis, primes → model axis
(DESIGN.md §5).
"""

from repro.core.params import HEParams, paper_params, test_params

CONFIG: HEParams = paper_params(beta_bits=32)
SMOKE: HEParams = test_params(logN=5, beta_bits=32)

# HE shapes: ciphertext-pair batches per HE Mul step.
HE_SHAPES = {
    "he_mul_b16": dict(batch=16),
    "he_mul_b64": dict(batch=64),
}
