"""Architecture / shape registry: --arch <id> resolution.

SHAPES are the assignment's per-arch input-shape set. ``decode_*`` /
``long_*`` lower serve_step (one token against a seq_len KV cache);
``train_*`` / ``prefill_*`` lower train_step / prefill. Skips are per-arch
(SKIP_SHAPES), documented in DESIGN.md §6.
"""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

_MODULES = {
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "arctic-480b": "repro.configs.arctic_480b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-base": "repro.configs.whisper_base",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
}

ARCHS = tuple(_MODULES)

# assignment shape set: (kind, seq_len, global_batch)
SHAPES: Dict[str, Tuple[str, int, int]] = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def get_arch(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def get_skips(arch_id: str) -> Tuple[str, ...]:
    mod = importlib.import_module(_MODULES[arch_id])
    return getattr(mod, "SKIP_SHAPES", ())


def get_shapes(arch_id: str) -> Dict[str, Tuple[str, int, int]]:
    skips = set(get_skips(arch_id))
    return {k: v for k, v in SHAPES.items() if k not in skips}


def cells():
    """All (arch, shape) baseline cells, skips excluded."""
    for a in ARCHS:
        for s in get_shapes(a):
            yield a, s
