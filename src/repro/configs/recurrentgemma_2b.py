"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attention : 2 recurrent.
[arXiv:2402.19427; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention="full",            # attention layers in the pattern are local
    window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    rglru_width=2560,
    rope_theta=10000.0,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

# RG-LRU recurrence + bounded local window: sub-quadratic, long_500k runs.
SKIP_SHAPES = ()
