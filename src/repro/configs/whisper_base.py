"""whisper-base [audio] — 6L (enc+dec) d_model=512 8H d_ff=2048 vocab=51865
— encoder-decoder; conv frontend is a STUB (input_specs supplies precomputed
frame embeddings). [arXiv:2212.04356; unverified]

Shape semantics (DESIGN.md §6): prefill_32k = encoder over 32,768 stub
frames + decoder prefill; decode = one decoder step cross-attending to the
32,768-frame memory. long_500k skipped (full bidirectional encoder
attention is O(L²)).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    n_layers=6,                  # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    attention="full",
    enc_dec=True,
    frontend="audio",
    norm="layernorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

SKIP_SHAPES = ("long_500k",)
