"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — transformer BACKBONE only; anyres patch tiling is a STUB
(input_specs supplies precomputed patch embeddings prepended to tokens).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention="full",
    frontend="vision",
    n_frontend_tokens=576,       # one anyres tile of 24×24 patches
    rope_theta=1000000.0,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

SKIP_SHAPES = ("long_500k",)
