"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,                 # per-expert FFN width
    vocab_size=32000,
    attention="full",
    n_experts=128,
    top_k=2,
    capacity_factor=1.25,
    moe_dense_residual=True,
    dense_d_ff=4864,
    rope_theta=10000.0,
    norm="rmsnorm",
    param_dtype="bfloat16",
    activation_dtype="bfloat16",
)

SKIP_SHAPES = ("long_500k",)
