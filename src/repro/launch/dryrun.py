import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile EVERY
(architecture × input-shape × mesh) cell on the production meshes and
record memory/cost/collective analysis for the roofline (deliverable g).

The two lines above MUST run before any jax import — jax locks the device
count at first init. 512 placeholder CPU devices back the (16,16) and
(2,16,16) meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --multipod
    PYTHONPATH=src python -m repro.launch.dryrun --he             # HE cells
    ... --out results.jsonl

Each cell appends a JSON record: per-device HLO FLOPs / bytes accessed /
collective-operand bytes (parsed from the optimized HLO), peak/argument
memory where the backend reports it, and wall compile time.
"""

import argparse
import contextlib
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (x64 for the HE cells)
from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shapes
from repro.data import make_batch_specs
from repro.dist.sharding import (
    batch_spec, cache_sharding_rules, param_sharding_rules,
)
# re-exports: the parsers live in hlo_analysis (no import side effects);
# hillclimb and older callers still reach them through this module.
from repro.launch.hlo_analysis import (  # noqa: F401
    analyze_compiled as _analyze, collective_bytes_from_hlo,
)
from repro.launch.mesh import make_production_mesh
from repro.models import (
    decode_step, forward_train, init_cache, init_params, loss_fn, prefill,
)
from repro.optim import adamw_init, adamw_update, warmup_cosine


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------

def make_train_step(cfg):
    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        lr = warmup_cosine(opt.step, peak_lr=3e-4, warmup_steps=100,
                           total_steps=10000)
        params, opt, _ = adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss
    return train_step


def _abstract_params(cfg):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def lower_lm_cell(arch: str, shape_name: str, mesh, *,
                  cost_correct: bool = True, overrides: dict | None = None,
                  opt_dtype=None, sharding_mode: str = "fsdp") -> dict:
    """Compile the full (scanned) cell; correct HLO costs for scan-body
    once-counting via the layer-delta method (see EXPERIMENTS.md §Roofline
    methodology): C(L) = C(u) + (L-u)/u · (C(2u) - C(u)) with u = one
    pattern unit, computed from 1- and 2-unit unrolled variants.

    overrides/opt_dtype: §Perf hillclimb knobs (model-config fields /
    optimizer moments dtype)."""
    import dataclasses as _dc
    cfg = get_arch(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    out = _lower_lm_variant(cfg, shape_name, mesh, opt_dtype=opt_dtype,
                            sharding_mode=sharding_mode)
    if not cost_correct or cfg.enc_dec or not cfg.scan_layers:
        out["corrected"] = {k: out.get(k) for k in
                            ("flops", "bytes_accessed")}
        out["corrected"]["collective_bytes"] = \
            out["collectives"]["total_bytes"]
        out["correction"] = "none (stack already unrolled)"
        return out
    u = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    v1 = _lower_lm_variant(
        _dc.replace(cfg, n_layers=u, scan_layers=False), shape_name, mesh,
        opt_dtype=opt_dtype, sharding_mode=sharding_mode)
    v2 = _lower_lm_variant(
        _dc.replace(cfg, n_layers=2 * u, scan_layers=False), shape_name,
        mesh, opt_dtype=opt_dtype, sharding_mode=sharding_mode)
    L = cfg.n_layers
    scale = (L - u) / u

    def corr(a, b):
        if a is None or b is None:
            return None
        return a + scale * (b - a)

    out["corrected"] = {
        "flops": corr(v1["flops"], v2["flops"]),
        "bytes_accessed": corr(v1["bytes_accessed"], v2["bytes_accessed"]),
        "collective_bytes": corr(v1["collectives"]["total_bytes"],
                                 v2["collectives"]["total_bytes"]),
    }
    out["correction"] = (f"layer-delta: unit={u}, C1={v1['flops']}, "
                         f"C2={v2['flops']}")
    return out


@contextlib.contextmanager
def _x64_disabled():
    """LM cells lower with 32-bit index types.

    repro.core enables x64 globally for the HE limb pipeline (f64 iCRT
    quotients, u64 limbs), but s64 scan indices trip an XLA SPMD
    partitioner bug (s64/s32 compare in the scan-transpose
    dynamic-update-slice) when the scanned params are sharded. The LM
    model code is dtype-explicit, so 32-bit tracing is value-identical.
    """
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


def _lower_lm_variant(cfg, shape_name: str, mesh, opt_dtype=None,
                      sharding_mode: str = "fsdp") -> dict:
    with _x64_disabled():
        return _lower_lm_variant_inner(cfg, shape_name, mesh,
                                       opt_dtype=opt_dtype,
                                       sharding_mode=sharding_mode)


def _lower_lm_variant_inner(cfg, shape_name: str, mesh, opt_dtype=None,
                            sharding_mode: str = "fsdp") -> dict:
    kind, seq_len, global_batch = SHAPES[shape_name]
    params_abs = _abstract_params(cfg)
    p_sh = param_sharding_rules(params_abs, mesh,
                                fsdp_params=sharding_mode == "fsdp")
    b_sh = batch_spec(mesh)

    def sds(tree, shardings=None):
        if shardings is None:
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        return jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            tree, shardings)

    enc_len = seq_len if cfg.enc_dec else None
    t0 = time.time()
    if kind == "train":
        import functools as _ft
        init_opt = _ft.partial(adamw_init, moments_dtype=opt_dtype) \
            if opt_dtype is not None else adamw_init
        opt_abs = jax.eval_shape(init_opt, params_abs)
        # moments shard like params (fsdp) or data-upgraded (zero1)
        from repro.dist.sharding import zero1_opt_sharding
        from repro.optim.adamw import OptState
        from jax.sharding import NamedSharding, PartitionSpec as P
        m_sh = zero1_opt_sharding(p_sh, params_abs, mesh) \
            if sharding_mode == "zero1" else p_sh
        opt_sh = OptState(step=NamedSharding(mesh, P()),
                          mu=m_sh, nu=m_sh)
        batch_specs = make_batch_specs(cfg, global_batch, seq_len,
                                       enc_len=enc_len)
        batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=b_sh)
                     for k, v in batch_specs.items()}
        fn = jax.jit(make_train_step(cfg),
                     in_shardings=(p_sh, opt_sh, None),
                     out_shardings=(p_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(sds(params_abs, p_sh), sds(opt_abs, opt_sh),
                           batch_abs)
    elif kind == "prefill":
        batch_specs = make_batch_specs(cfg, global_batch, seq_len,
                                       enc_len=enc_len)
        batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=b_sh)
                     for k, v in batch_specs.items()}
        fn = jax.jit(lambda p, b: prefill(p, b, cfg, seq_len),
                     in_shardings=(p_sh, None))
        lowered = fn.lower(sds(params_abs, p_sh), batch_abs)
    elif kind == "decode":
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, global_batch, seq_len,
                               enc_len=seq_len if cfg.enc_dec else 0))
        c_sh = cache_sharding_rules(cache_abs, mesh)
        tok = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
        t_spec = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            lambda p, c, tk, t: decode_step(p, c, tk, t, cfg),
            in_shardings=(p_sh, c_sh, None, None),
            donate_argnums=(1,))
        lowered = fn.lower(sds(params_abs, p_sh), sds(cache_abs, c_sh),
                           tok, t_spec)
    else:
        raise ValueError(kind)

    compiled = lowered.compile()
    return _analyze(lowered, compiled, time.time() - t0)


# --------------------------------------------------------------------------
# HE cells (the paper's workload)
# --------------------------------------------------------------------------

def lower_he_cell(batch: int, mesh, *, logq=None) -> dict:
    from repro.configs.heaan_mul import CONFIG as HEP
    from repro.dist import he_pipeline as hp
    from repro.dist.sharding import he_limb_sharding
    logq = HEP.logQ if logq is None else logq
    st = hp.he_static(HEP, logq)
    step = hp.make_he_mul_step(st, mesh)
    t1, t2, ek = hp.he_table_specs(st)
    cts = hp.he_input_specs(st, batch)
    ct_sh = he_limb_sharding(mesh, batch=batch)
    cts = tuple(jax.ShapeDtypeStruct(c.shape, c.dtype, sharding=ct_sh)
                for c in cts)
    t0 = time.time()
    fn = jax.jit(step)
    lowered = fn.lower(t1, t2, ek, *cts)
    compiled = lowered.compile()
    return _analyze(lowered, compiled, time.time() - t0)


# the FULL served op table (analysis.dataflow.OPS — mul, add, sub,
# rotate, conjugate, slot_sum, rescale, mod_down, mul_plain, add_plain);
# the lowering itself lives in launch.cells (no import side effects, so
# tests and repro.analysis.xla use it in-process) and is re-exported
# here for the dry-run drivers and older callers.
from repro.launch.cells import (  # noqa: F401, E402
    HE_SERVING_OPS, serving_op_levels,
    lower_he_serving_cell as _lower_serving,
)


def lower_he_serving_cell(op: str, batch: int, mesh, *, logq=None,
                          params=None) -> dict:
    """Lower + compile one hserve engine step with abstract tables and
    return its analysis record (`launch.cells.lower_he_serving_cell`
    does the lowering; see its docstring for the per-op contracts)."""
    t0 = time.time()
    lowered = _lower_serving(op, batch, mesh, logq=logq, params=params)
    compiled = lowered.compile()
    return _analyze(lowered, compiled, time.time() - t0)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def run_cells(archs, shapes, *, multipod: bool, he: bool, he_batches,
              out_path: str):
    mesh = make_production_mesh(multi_pod=multipod)
    mesh_name = "pod2x16x16" if multipod else "pod16x16"
    results = []
    with open(out_path, "a") as f:
        def emit(rec):
            results.append(rec)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            status = "OK" if rec.get("ok") else "FAIL"
            print(f"[{status}] {rec['cell']} ({mesh_name}) "
                  f"flops={rec.get('analysis', {}).get('flops')} "
                  f"coll={rec.get('analysis', {}).get('collectives', {}).get('total_bytes')} "
                  f"t={rec.get('analysis', {}).get('compile_seconds')}s",
                  flush=True)

        if he:
            for b in he_batches:
                rec = {"cell": f"heaan_mul/he_mul_b{b}", "mesh": mesh_name}
                try:
                    rec["analysis"] = lower_he_cell(b, mesh)
                    rec["ok"] = True
                except Exception as e:
                    rec["ok"] = False
                    rec["error"] = f"{type(e).__name__}: {e}"
                    rec["traceback"] = traceback.format_exc()[-2000:]
                emit(rec)
            # the serving engine's op set (one batch size is enough for
            # the collective matrix; slot_sum is log2(N/2) key switches)
            for op in HE_SERVING_OPS:
                rec = {"cell": f"heaan_mul/he_{op}_b{he_batches[0]}",
                       "mesh": mesh_name}
                try:
                    rec["analysis"] = lower_he_serving_cell(
                        op, he_batches[0], mesh)
                    rec["ok"] = True
                except Exception as e:
                    rec["ok"] = False
                    rec["error"] = f"{type(e).__name__}: {e}"
                    rec["traceback"] = traceback.format_exc()[-2000:]
                emit(rec)
        for arch in archs:
            valid = get_shapes(arch)
            for shape in shapes:
                if shape not in SHAPES:
                    continue
                if shape not in valid:
                    emit({"cell": f"{arch}/{shape}", "mesh": mesh_name,
                          "ok": True, "skipped": True,
                          "reason": "architecturally unsupported "
                                    "(DESIGN.md §6)"})
                    continue
                rec = {"cell": f"{arch}/{shape}", "mesh": mesh_name}
                try:
                    # roofline cost-correction variants: single-pod only
                    # (the roofline table is single-pod per the assignment)
                    rec["analysis"] = lower_lm_cell(
                        arch, shape, mesh, cost_correct=not multipod)
                    rec["ok"] = True
                except Exception as e:
                    rec["ok"] = False
                    rec["error"] = f"{type(e).__name__}: {e}"
                    rec["traceback"] = traceback.format_exc()[-2000:]
                emit(rec)
    n_fail = sum(1 for r in results if not r.get("ok"))
    print(f"\n{mesh_name}: {len(results) - n_fail}/{len(results)} cells OK")
    return n_fail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--he", action="store_true",
                    help="include the HEAAN HE-Mul cells")
    ap.add_argument("--he-only", action="store_true")
    ap.add_argument("--he-batches", default="16,64")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.he_only:
        archs, shapes = [], []
    he_batches = [int(b) for b in args.he_batches.split(",")]
    include_he = args.he or args.he_only

    fails = run_cells(archs, shapes, multipod=args.multipod,
                      he=include_he, he_batches=he_batches,
                      out_path=args.out)
    if args.both_meshes:
        fails += run_cells(archs, shapes, multipod=not args.multipod,
                           he=include_he, he_batches=he_batches,
                           out_path=args.out)
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
