"""Training driver: checkpoint/restart, heartbeats, straggler monitoring.

Library use (tests, examples) and CLI:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --preset smoke --steps 50 --ckpt-dir /tmp/run1 [--compress-dp]

Fault-tolerance contract (DESIGN.md §9): batches are a pure function of
(seed, step); AdamW is deterministic; so crash → restore-latest → replay
yields bit-identical training (tests/test_fault_tolerance.py asserts it).

`--compress-dp` swaps the implicit f32 gradient all-reduce for the
explicit int8 wire protocol (`dist.collectives.compressed_psum_grads`)
inside a shard_map over the mesh's data axis — 4× less DP traffic; every
replica still holds bit-identical gradients (the tests/test_dist.py
contract), and the per-step quantization key is a pure function of
(seed, step) so the fault-tolerance replay contract survives.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Optional

import jax

from repro.ckpt import CheckpointManager
from repro.data import SyntheticLM
from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.runtime import FailureInjector, Heartbeat, StepMonitor


@dataclasses.dataclass
class TrainConfig:
    batch: int = 8
    seq_len: int = 64
    steps: int = 20
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    ckpt_every: int = 5
    keep: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 ckpt_dir: Optional[str] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 injector: Optional[FailureInjector] = None,
                 compress_dp: bool = False):
        self.cfg = cfg
        self.tc = tc
        if compress_dp and mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.mesh = mesh
        self.compress_dp = compress_dp
        self.injector = injector
        self.data = SyntheticLM(cfg, tc.batch, tc.seq_len, seed=tc.seed)
        self.monitor = StepMonitor()
        self.heartbeat = None
        self.ckpt = CheckpointManager(ckpt_dir, keep=tc.keep) \
            if ckpt_dir else None
        if ckpt_dir:
            self.heartbeat = Heartbeat(os.path.join(ckpt_dir, "heartbeat"),
                                       interval=0.0)

        params = init_params(cfg, jax.random.key(tc.seed))
        opt = adamw_init(params)
        self.step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            s = self.ckpt.latest_step()
            state = self.ckpt.restore(s, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            self.step = s
        self.params, self.opt = params, opt

        tcfg = self.tc

        if compress_dp:
            # Explicit-DP path: per-shard grads, then the int8
            # compress→all-gather→decompress mean over the "data" axis.
            # Every replica averages the same gathered payloads in the
            # same order, so gradients stay bit-identical across replicas
            # (tests/test_dist.py's contract for compressed_psum_grads).
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.dist.collectives import compressed_psum_grads

            ndata = self.mesh.shape["data"]
            assert tc.batch % ndata == 0, (
                f"the data axis ({ndata}) must divide batch={tc.batch} "
                f"(each shard needs an integral per-device batch)")

            def local_grads(params, batch, key):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch, cfg)
                grads = compressed_psum_grads(grads, ("data",), key)
                metrics = jax.tree.map(
                    lambda m: jax.lax.pmean(m, ("data",)), metrics)
                return grads, metrics

            grads_fn = shard_map(
                local_grads, mesh=self.mesh,
                in_specs=(P(), P("data"), P()), out_specs=(P(), P()),
                check_rep=False)
        else:
            def grads_fn(params, batch, key):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch, cfg)
                return grads, metrics

        @jax.jit
        def train_step(params, opt, batch, key):
            grads, metrics = grads_fn(params, batch, key)
            lr = warmup_cosine(opt.step, peak_lr=tcfg.peak_lr,
                               warmup_steps=tcfg.warmup_steps,
                               total_steps=tcfg.steps)
            params, opt, od = adamw_update(grads, opt, params, lr=lr)
            return params, opt, {"loss": metrics["loss"], **od}

        self._train_step = train_step

    def run(self, steps: Optional[int] = None) -> dict:
        steps = steps if steps is not None else self.tc.steps
        history = []
        while self.step < steps:
            t0 = time.time()
            if self.injector:
                # inside the timed region: stragglers must show up in the
                # step wall-time the monitor sees (hard failures raise
                # before any state mutation, so restart-from-ckpt is clean)
                self.injector.maybe_fail(self.step)
            batch = self.data.batch_at(self.step)
            # quantization key: pure function of (seed, step), so replay
            # after restart reproduces the exact same stochastic rounding
            key = jax.random.fold_in(
                jax.random.key(self.tc.seed), self.step)
            self.params, self.opt, m = self._train_step(
                self.params, self.opt, batch, key)
            jax.block_until_ready(self.params)
            dt = time.time() - t0
            self.step += 1
            breach = self.monitor.record(self.step, dt)
            history.append({"step": self.step,
                            "loss": float(m["loss"]),
                            "sec": dt, "straggler": breach})
            if self.heartbeat:
                self.heartbeat.beat(self.step, {"loss": float(m["loss"])})
            if self.ckpt and self.step % self.tc.ckpt_every == 0:
                self.save()
        if self.ckpt:
            self.save(block=True)
        return {"history": history,
                "breaches": list(self.monitor.breaches)}

    def save(self, block: bool = False) -> None:
        self.ckpt.save(self.step, {"params": self.params, "opt": self.opt},
                       block=block)
        self.ckpt.wait() if block else None


def run_with_restarts(make_trainer, total_steps: int, max_restarts: int = 3):
    """Supervisor loop: restart-from-latest on (simulated) node failure."""
    from repro.runtime.failures import SimulatedFailure
    restarts = 0
    trainer = make_trainer()
    while True:
        try:
            out = trainer.run(total_steps)
            return trainer, out, restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            trainer = make_trainer()   # restores from latest checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-dp", action="store_true",
                    help="int8-compressed gradient all-reduce over the "
                         "data axis (dist.collectives; 4× less DP "
                         "traffic, replicas stay bit-identical)")
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    full = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = full.reduced()
    elif args.preset == "100m":
        cfg = full.reduced(n_layers=8, d_model=768, n_heads=12,
                           n_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab_size=32000, scan_layers=True)
    else:
        cfg = full
    tc = TrainConfig(batch=args.batch, seq_len=args.seq, steps=args.steps)
    trainer = Trainer(cfg, tc, ckpt_dir=args.ckpt_dir,
                      compress_dp=args.compress_dp)
    out = trainer.run()
    first, last = out["history"][0], out["history"][-1]
    print(f"arch={args.arch} preset={args.preset} "
          f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"({len(out['history'])} steps)")


if __name__ == "__main__":
    main()
