"""Side-effect-free HLO analysis helpers (no jax import, no env mutation).

`launch.dryrun` sets XLA_FLAGS for 512 placeholder devices at module
import, which poisons any process that merely wants the HLO parsers —
so those parsers live here and dryrun re-exports them. Import this
module from tests and benchmarks, never dryrun.
"""

from __future__ import annotations

import re

__all__ = ["collective_bytes_from_hlo", "analyze_compiled"]

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _base_collective(op: str):
    for suf in ("-start", "-done"):
        if op.endswith(suf):
            return op[: -len(suf)], suf
    return op, ""


def _group_size(line: str) -> int:
    """Participants per replica group (ring size) for a collective line."""
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device ICI wire bytes of every collective in the partitioned HLO.

    Modern HLO text omits operand shapes, so bytes derive from the OUTPUT
    shape + replica-group size g with the standard ring model:
      all-reduce       2·S·(g-1)/g        (reduce-scatter + all-gather)
      all-gather       S_out·(g-1)/g
      reduce-scatter   S_out·(g-1)        (input = S_out·g)
      all-to-all       S·(g-1)/g
      collective-permute S
    This refines the assignment's "sum operand sizes" into the actual
    per-device traffic each op puts on the links.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+([a-z0-9\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        base, suf = _base_collective(op)
        if base not in _COLLECTIVES or suf == "-done":
            continue
        shapes = _SHAPE_RE.findall(m.group(1))      # output shape(s)
        size = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        g = _group_size(stripped)
        if base == "collective-permute":             # point-to-point
            wire = float(size)
        elif g <= 1:
            wire = 0.0
        elif base == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif base == "all-gather":
            wire = size * (g - 1) / g
        elif base == "reduce-scatter":
            wire = float(size) * (g - 1)
        elif base == "all-to-all":
            wire = size * (g - 1) / g
        else:
            wire = float(size)
        counts[base] += 1
        out[base] += wire
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def analyze_compiled(lowered, compiled, seconds: float) -> dict:
    """Cost/memory/collective record for one compiled cell."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):    # some jax versions: one per program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
        "memory": mem_d,
        "collectives": coll,
        "compile_seconds": round(seconds, 2),
    }
