"""Side-effect-free HLO analysis helpers (no jax import, no env mutation).

`launch.dryrun` sets XLA_FLAGS for 512 placeholder devices at module
import, which poisons any process that merely wants the HLO parsers —
so those parsers live here and dryrun re-exports them. Import this
module from tests and benchmarks, never dryrun.

Replica-group grammar (all forms newer XLA emits are handled):

  replica_groups={{0,1,2,3},{4,5,6,7}}     literal multi-group lists
  replica_groups=[2,4]<=[8]                iota form: 2 groups of 4,
                                           iota(8) reshaped to (2,4)
  replica_groups=[2,4]<=[4,2]T(1,0)        iota + transpose: groups are
                                           the COLUMNS of iota(8)->(4,2)
  replica_groups={}                        one group of every participant
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["collective_bytes_from_hlo", "analyze_compiled",
           "parse_replica_groups", "count_fusions"]

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([a-z0-9\-]+)\(")
_GROUPS_LITERAL_RE = re.compile(
    r"replica_groups=\{(\{[0-9, ]*\}(?:,\s*\{[0-9, ]*\})*)\}")
_GROUPS_EMPTY_RE = re.compile(r"replica_groups=\{\s*\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
    r"(?:T\(([0-9,]+)\))?")
_FUSION_KIND_RE = re.compile(r"kind=k(\w+)")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _base_collective(op: str) -> Tuple[str, str]:
    for suf in ("-start", "-done"):
        if op.endswith(suf):
            return op[: -len(suf)], suf
    return op, ""


def _expand_iota(n_groups: int, group_size: int, dims: List[int],
                 perm: Optional[List[int]]) -> Optional[List[Tuple[int, ...]]]:
    """Materialize `[G,S]<=[d0,d1,...]T(perm)` into explicit id groups:
    iota(prod dims) reshaped to dims, transposed by perm, reshaped (G,S)."""
    total = 1
    for d in dims:
        total *= d
    if total != n_groups * group_size or total == 0:
        return None
    if perm is None:
        perm = list(range(len(dims)))
    if sorted(perm) != list(range(len(dims))):
        return None
    strides = [1] * len(dims)                      # row-major source strides
    for i in range(len(dims) - 2, -1, -1):
        strides[i] = strides[i + 1] * dims[i + 1]
    tdims = [dims[p] for p in perm]
    vals = []
    for flat in range(total):
        rem, idx = flat, [0] * len(tdims)
        for j in range(len(tdims) - 1, -1, -1):    # flat -> transposed index
            idx[j] = rem % tdims[j]
            rem //= tdims[j]
        vals.append(sum(idx[j] * strides[p] for j, p in enumerate(perm)))
    return [tuple(vals[i * group_size:(i + 1) * group_size])
            for i in range(n_groups)]


def parse_replica_groups(line: str, *, default_group_size: Optional[int] = None
                         ) -> Tuple[Optional[List[Tuple[int, ...]]], int]:
    """(explicit groups or None, participants per group) for one HLO line.

    Handles literal multi-group lists, both iota forms (with and without
    a transpose suffix — the transposed form's groups are materialized so
    callers can check WHICH mesh axis a collective runs over, not just
    how many devices it spans), and the empty `replica_groups={}` (all
    participants, one group — group size falls back to
    `default_group_size`, or 1 when unknown).
    """
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        groups = [tuple(int(x) for x in g.split(",") if x.strip())
                  for g in re.findall(r"\{([0-9, ]*)\}", m.group(1))]
        size = max((len(g) for g in groups), default=1)
        return groups, size
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",") if x.strip()]
        perm = ([int(x) for x in m.group(4).split(",") if x.strip()]
                if m.group(4) else None)
        return _expand_iota(n_groups, size, dims, perm), size
    if _GROUPS_EMPTY_RE.search(line):
        return None, default_group_size if default_group_size else 1
    return None, 1


def _group_size(line: str, default: Optional[int] = None) -> int:
    """Participants per replica group (ring size) for a collective line."""
    return parse_replica_groups(line, default_group_size=default)[1]


def count_fusions(hlo_text: str) -> int:
    """Fused-kernel count of an optimized HLO module: `fusion(...)`
    instructions in the entry (and nested) computations. A drop against
    a baseline means XLA broke a fusion — more kernel launches and HBM
    round trips for the same math."""
    n = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line.strip())
        if m and m.group(2) == "fusion":
            n += 1
    return n


def collective_bytes_from_hlo(hlo_text: str, *,
                              default_group_size: Optional[int] = None
                              ) -> dict:
    """Per-device ICI wire bytes of every collective in the partitioned HLO.

    Modern HLO text omits operand shapes, so bytes derive from the OUTPUT
    shape + replica-group size g with the standard ring model:
      all-reduce       2·S·(g-1)/g        (reduce-scatter + all-gather)
      all-gather       S_out·(g-1)/g
      reduce-scatter   S_out·(g-1)        (input = S_out·g)
      all-to-all       S·(g-1)/g
      collective-permute S
    This refines the assignment's "sum operand sizes" into the actual
    per-device traffic each op puts on the links.

    Returns {"bytes": per-kind wire bytes, "counts": per-kind counts,
    "total_bytes", "ops": [one record per collective instruction with
    its kind, payload size, group size/shape, and wire bytes]}. Async
    pairs count once: `-start` carries the cost, `-done` is skipped; an
    `all-gather-start`/`collective-permute-start` tuple holds
    (operands..., outputs...) so only the output half is sized.
    `default_group_size` backs the empty `replica_groups={}` form (all
    participants — pass the device count of the program).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    ops: List[dict] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        op = m.group(2)
        base, suf = _base_collective(op)
        if base not in _COLLECTIVES or suf == "-done":
            continue
        shapes = _SHAPE_RE.findall(m.group(1))      # output shape(s)
        if (suf == "-start" and base in ("all-gather", "collective-permute")
                and len(shapes) >= 2 and len(shapes) % 2 == 0):
            shapes = shapes[len(shapes) // 2:]      # (operands..., outputs...)
        size = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue                            # unknown dtype: 0 bytes,
            n = 1                                   # op still counted
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        groups, g = parse_replica_groups(
            stripped, default_group_size=default_group_size)
        if base == "collective-permute":             # point-to-point
            wire = float(size)
        elif g <= 1:
            wire = 0.0
        elif base == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif base == "all-gather":
            wire = size * (g - 1) / g
        elif base == "reduce-scatter":
            wire = float(size) * (g - 1)
        elif base == "all-to-all":
            wire = size * (g - 1) / g
        else:
            wire = float(size)
        counts[base] += 1
        out[base] += wire
        ops.append({"op": base, "async": suf == "-start",
                    "size_bytes": size, "group_size": g,
                    "n_groups": len(groups) if groups is not None else None,
                    "groups": ([list(t) for t in groups]
                               if groups is not None else None),
                    "wire_bytes": wire})
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values()), "ops": ops}


def analyze_compiled(lowered, compiled, seconds: float) -> dict:
    """Cost/memory/collective/fusion record for one compiled cell."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):    # some jax versions: one per program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    text = compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    return {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
        "memory": mem_d,
        "collectives": coll,
        "fusions": count_fusions(text),
        "compile_seconds": round(seconds, 2),
    }
