"""Launchers: mesh construction, training/serving drivers, multi-pod dry-run."""
