"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""

from __future__ import annotations

import jax

XLA_LHS_FLAGS = (
    # collective/compute overlap knobs for real-TPU runs (documented here,
    # consumed by launch scripts; harmless on CPU):
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 chips per pod; 2 pods multi-pod (assignment contract)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1) -> jax.sharding.Mesh:
    """Whatever this host offers (tests / examples on CPU)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"model={model} must divide the host device count ({n})")
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
