"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --preset smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.config import ModelConfig


def generate(params, cfg: ModelConfig, tokens, gen_steps: int,
             max_len: int, batch_extra=None):
    """Greedy generation. tokens: (B, L) prompt. Returns (B, gen_steps)."""
    B, L = tokens.shape
    batch = {"tokens": tokens, **(batch_extra or {})}
    logits, cache = prefill(params, batch, cfg, max_len)
    step_fn = jax.jit(
        lambda p, c, t, i: decode_step(p, c, t, i, cfg),
        static_argnames=())
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_steps):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok, L + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    extra = {}
    if cfg.enc_dec:
        extra["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, 2 * args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "vision":
        extra["patch_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    t0 = time.time()
    out = generate(params, cfg, tokens,
                   args.gen, args.prompt_len + args.gen + 8,
                   batch_extra=extra)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s, incl. compile)")


if __name__ == "__main__":
    main()
