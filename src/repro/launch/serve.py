"""Serving drivers: batched LM prefill + greedy decode, and the paper's
own workload — batched HE Mul — over the mesh-sharded pipeline.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --preset smoke --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --he --batch 8

Both paths place their state with repro.dist.sharding rules on the host
mesh (whatever devices this process has), so the same driver scales from
1 CPU device to a pod slice unchanged.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.config import ModelConfig


def generate(params, cfg: ModelConfig, tokens, gen_steps: int,
             max_len: int, batch_extra=None):
    """Greedy generation. tokens: (B, L) prompt. Returns (B, gen_steps)."""
    B, L = tokens.shape
    batch = {"tokens": tokens, **(batch_extra or {})}
    logits, cache = prefill(params, batch, cfg, max_len)
    step_fn = jax.jit(
        lambda p, c, t, i: decode_step(p, c, t, i, cfg),
        static_argnames=())
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_steps):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok, L + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def serve_he(batch: int, steps: int = 3, model_shards: int = 1) -> dict:
    """Batched HE-Mul serving over the mesh-sharded pipeline.

    Encrypts `batch` ciphertext pairs, places them with he_limb_sharding
    on the host mesh, runs the jit'd make_he_mul_step, and checks the
    decrypted products. Returns a stats dict (printed by main).
    """
    from repro.configs.heaan_mul import SMOKE
    from repro.core import heaan as H
    from repro.core.context import make_context
    from repro.core.keys import keygen
    from repro.dist import he_pipeline as hp
    from repro.dist.sharding import he_limb_sharding
    from repro.launch.mesh import make_host_mesh

    params = SMOKE
    sk, pk, evk = keygen(params, seed=0)
    mesh = make_host_mesh(model=model_shards)   # validates divisibility
    rng = np.random.default_rng(0)
    n = params.n_slots_max
    zs = [(rng.normal(size=n) + 1j * rng.normal(size=n),
           rng.normal(size=n) + 1j * rng.normal(size=n))
          for _ in range(batch)]
    cts = [(H.encrypt_message(z1, pk, params, seed=2 * i + 1),
            H.encrypt_message(z2, pk, params, seed=2 * i + 2))
           for i, (z1, z2) in enumerate(zs)]

    st = hp.he_static(params, params.logQ)
    ctx = make_context(params, params.logQ)
    t1, t2, ek = hp.runtime_tables(ctx, evk)
    sh = he_limb_sharding(mesh, batch=batch)
    ax1, bx1, ax2, bx2 = (
        jax.device_put(jnp.stack([getattr(c[j], a) for c in cts]), sh)
        for j, a in ((0, "ax"), (0, "bx"), (1, "ax"), (1, "bx")))
    step = jax.jit(hp.make_he_mul_step(st, mesh))

    t0 = time.time()
    ax3, bx3 = jax.block_until_ready(step(t1, t2, ek, ax1, bx1, ax2, bx2))
    compile_s = time.time() - t0
    t0 = time.time()
    for _ in range(steps):
        ax3, bx3 = jax.block_until_ready(
            step(t1, t2, ek, ax1, bx1, ax2, bx2))
    steady_s = (time.time() - t0) / max(steps, 1)

    from repro.core.cipher import Ciphertext
    errs = []
    for i, (z1, z2) in enumerate(zs):
        ct3 = Ciphertext(ax=ax3[i], bx=bx3[i], logq=params.logQ,
                         logp=2 * params.log_delta, n_slots=n)
        out = H.decrypt_message(H.rescale(ct3, params), sk, params)
        errs.append(float(np.abs(out - z1 * z2).max()))
    return {"batch": batch, "devices": len(jax.devices()),
            "mesh": dict(mesh.shape), "compile_s": round(compile_s, 3),
            "steady_s_per_step": round(steady_s, 4),
            "mul_per_s": round(batch / max(steady_s, 1e-9), 1),
            "max_err": max(errs)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--he", action="store_true",
                    help="serve batched HE Mul instead of an LM")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="size of the model axis of the host mesh")
    args = ap.parse_args()

    if args.he:
        stats = serve_he(args.batch, model_shards=args.model_shards)
        print(f"he_mul batch={stats['batch']} on {stats['devices']} "
              f"device(s) {stats['mesh']}: {stats['mul_per_s']} mul/s "
              f"(compile {stats['compile_s']}s, "
              f"step {stats['steady_s_per_step']}s, "
              f"max_err {stats['max_err']:.2e})")
        assert stats["max_err"] < 1e-2, "HE serving pipeline diverged"
        return

    from repro.configs.registry import get_arch
    from repro.dist.sharding import batch_spec, param_sharding_rules
    from repro.launch.mesh import make_host_mesh
    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    mesh = make_host_mesh(model=args.model_shards)  # validates divisibility
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    # tensor-parallel only: FSDP-sharded weights would re-gather on every
    # decode step, and serving has no gradients to shard for
    params = jax.device_put(
        params, param_sharding_rules(params, mesh, fsdp_params=False))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    if args.batch % mesh.shape["data"] == 0:
        tokens = jax.device_put(tokens, batch_spec(mesh))
    extra = {}
    if cfg.enc_dec:
        extra["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, 2 * args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "vision":
        extra["patch_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    t0 = time.time()
    out = generate(params, cfg, tokens,
                   args.gen, args.prompt_len + args.gen + 8,
                   batch_extra=extra)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s, incl. compile)")


if __name__ == "__main__":
    main()
