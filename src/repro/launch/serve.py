"""Serving drivers: batched LM prefill + greedy decode, and the paper's
own workload — a batched multi-level HE request stream — over the
repro.hserve runtime (queue → level-aware table cache → sharded engine).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --preset smoke --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --he --batch 8 \
        --requests 24 --levels 3 --rotations 4 --conjugations 2 \
        [--plain-frac 0.5] [--circuit] [--schedule] [--max-age-s 0.05] \
        [--overlap] [--kernels]

Both paths place their state with repro.dist.sharding rules on the host
mesh (whatever devices this process has), so the same driver scales from
1 CPU device to a pod slice unchanged.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_params, prefill
from repro.models.config import ModelConfig


def generate(params, cfg: ModelConfig, tokens, gen_steps: int,
             max_len: int, batch_extra=None):
    """Greedy generation. tokens: (B, L) prompt. Returns (B, gen_steps)."""
    B, L = tokens.shape
    batch = {"tokens": tokens, **(batch_extra or {})}
    logits, cache = prefill(params, batch, cfg, max_len)
    step_fn = jax.jit(
        lambda p, c, t, i: decode_step(p, c, t, i, cfg),
        static_argnames=())
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_steps):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok, L + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def serve_he(batch: int, requests: int = 0, levels: int = 1,
             rotations: int = 0, conjugations: int = 0,
             plain_frac: float = 0.0, model_shards: int = 1,
             use_kernels: bool = False, max_age_s: float | None = None,
             overlap: bool = False, circuit: bool = False,
             schedule: bool = False, traced: int = 0,
             check: str = "off", seed: int = 0,
             trace: str | None = None, profile_stages: bool = False,
             metrics: str | None = None, workers: int = 0,
             bootstrap: int = 0) -> dict:
    """Batched multi-level HE serving, driven through a `repro.client`
    HESession (the session owns keygen, encrypt/decrypt, and the
    HEServer; the raw per-op stream rides `session.server`).

    Submits a mixed stream of HE-Mul / rotate / conjugate requests
    spread over `levels` moduli — `plain_frac` of the mul share served
    as the key-switch-free mul_plain/add_plain plaintext-operand ops —
    plus, with `circuit`, a whole degree-4 encrypted polynomial circuit
    via submit_circuit (TWO staggered copies under `schedule`,
    exercising the circuit-aware scheduler's cross-circuit co-batching
    and table prefetch), plus, with `traced` > 0, that many TRACED
    client expressions (every handle op, no explicit level management —
    the compile pass inserts it) sharing one weight vector so every
    expression after the first ships hash-only plaintext operands and
    hits the server's (hash, level) cache. Drains the queue with padded
    batching and verifies every decrypted result. Returns the server
    stats dict plus a max_err field (printed by main).

    Observability (repro.obs): `trace` writes a Chrome trace-event JSON
    of the request lifecycle + engine spans to that path (load it in
    Perfetto, or run `python -m repro.obs report PATH`);
    `profile_stages` swaps stage-chain steps to the block-jitted eager
    path (bitwise identical, slower) and prints the paper's Fig. 3
    CRT/NTT/modmul/iCRT attribution; `metrics` dumps the registry
    snapshot (serving telemetry plane) as JSON to that path.

    `workers` > 0 serves the same stream through the multi-host tier:
    an :class:`repro.hserve.HEFrontend` routing batches to that many
    in-process worker engines (docs/SERVING.md "Multi-host serving").
    Bitwise identical to the single-server path.

    `bootstrap` > 0 additionally serves that many CONCURRENT bootstrap
    pipelines (`repro.boot`, docs/BOOTSTRAP.md) over level-exhausted
    ciphertexts — the whole run switches to the reference bootstrap
    params (`boot_params()`: logQ=336, h=2) so the pipeline fits the
    modulus chain. Bootstrap results verify against the plan's
    documented error bound (approximate, not bitwise); the returned
    stats gain a "bootstrap" block with the measured error, the bound,
    and the cross-circuit co-batch rate the concurrent pipelines hit.
    """
    from repro.client import HESession
    from repro.configs.heaan_mul import SMOKE
    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.hserve import HEFrontend, degree4_demo_circuit
    from repro.launch.mesh import make_host_mesh
    from repro.obs import Tracer

    if bootstrap:
        from repro.boot import boot_params
        params = boot_params()
    else:
        params = SMOKE
    requests = requests or 2 * batch + 1   # force >1 batch and padding
    # the lowest level logq = logp is excluded: mul results there cannot
    # rescale (ciphertext exhausted), and verification rescales every mul
    if not 1 <= levels <= params.L - 1:
        raise ValueError(f"--levels must be in [1, {params.L - 1}]")
    if not 0.0 <= plain_frac <= 1.0:
        raise ValueError("--plain-frac must be in [0, 1]")
    tracer = Tracer() if trace else None
    if workers > 0:
        if profile_stages or overlap:
            raise ValueError(
                "--profile-stages/--overlap are single-server knobs; "
                "the multi-host frontend pipelines across workers "
                "instead of double-buffering one engine")
        sk, pk, evk = keygen(params, seed=0)
        frontend = HEFrontend(
            params, evk, mesh=make_host_mesh(model=model_shards),
            batch=batch, workers=workers, use_kernels=use_kernels,
            max_age_s=max_age_s, schedule=schedule, tracer=tracer)
        session = HESession(params, sk=sk, pk=pk, evk=evk,
                            server=frontend)
    else:
        session = HESession(params, seed=0,
                            mesh=make_host_mesh(model=model_shards),
                            batch=batch, use_kernels=use_kernels,
                            max_age_s=max_age_s, overlap=overlap,
                            schedule=schedule, tracer=tracer,
                            profile_stages=profile_stages)
    server = session.server
    if rotations:
        session.ensure_rotation_keys([1])
    if conjugations or circuit:
        session.ensure_conj_key()

    rng = np.random.default_rng(seed)
    n = params.n_slots_max
    logqs = [params.logQ - i * params.logp for i in range(levels)]
    expect = {}   # rid -> (op, expected slots)
    n_mul = requests - rotations - conjugations
    if n_mul < 0:
        raise ValueError(
            "--rotations + --conjugations cannot exceed --requests")
    n_plain = int(round(plain_frac * n_mul))
    for i in range(requests):
        logq = logqs[i % levels]
        z = rng.normal(size=n) + 1j * rng.normal(size=n)
        ct = session.encrypt(z, seed=2 * i + 1).ciphertext
        if logq < params.logQ:
            ct = H.he_mod_down(ct, params, logq)
        if i < n_plain:
            # plaintext-operand ops: encode-only operand, region-1
            # product / bx add — no key switch, no key material
            w = rng.normal(size=n) + 1j * rng.normal(size=n)
            pt = H.encode_plain(w, params, logq)
            if i % 2 == 0:
                expect[server.submit_mul_plain(ct, pt)] = \
                    ("mul_plain", z * w)
            else:
                expect[server.submit_add_plain(ct, pt)] = \
                    ("add_plain", z + w)
        elif i < n_mul:
            z2 = rng.normal(size=n) + 1j * rng.normal(size=n)
            c2 = session.encrypt(z2, seed=2 * i + 2).ciphertext
            if logq < params.logQ:
                c2 = H.he_mod_down(c2, params, logq)
            expect[server.submit_mul(ct, c2)] = ("mul", z * z2)
        elif i < n_mul + rotations:
            expect[server.submit_rotate(ct, 1)] = ("rotate", np.roll(z, -1))
        else:
            expect[server.submit_conjugate(ct)] = ("conjugate", np.conj(z))

    if circuit:
        # a degree-4 encrypted polynomial, evaluated WHOLLY server-side:
        # conj(x⁴) + x — muls, rescales, a mod-down alignment, conjugate,
        # and an add, all through one submit_circuit round trip. Under
        # --schedule a second, STAGGERED copy rides along so the
        # scheduler's cross-circuit co-batching is exercised end-to-end.
        ops, _ = degree4_demo_circuit(params)
        if check != "off":
            # hslint the hand-built circuit before submitting it (the
            # traced path runs the same analyzer inside session.run)
            from repro.analysis import analyze_circuit
            report = analyze_circuit(
                ops, {"x": (params.logQ, params.logp)}, params,
                input_nslots={"x": n})
            print(report.render("degree4 circuit"))
            if check == "error" and not report.ok:
                raise ValueError("static analysis rejected the demo "
                                 "circuit: " + "; ".join(
                                     d.format() for d in report.errors))
        n_circ = 2 if schedule else 1
        results = {}
        for j in range(n_circ):
            zc = rng.normal(size=n) + 1j * rng.normal(size=n)
            x = session.encrypt(zc, seed=7777 + j).ciphertext
            cid = server.submit_circuit(ops, inputs={"x": x})
            expect[cid] = ("circuit", np.conj(zc ** 4) + zc)
            if schedule and j == 0:       # desync the two circuits (the
                results.update(           # poll may complete plain reqs)
                    dict(server.poll(flush=True)))
    else:
        results = {}

    tfuts = []
    if traced:
        # the session API end to end: every traced op, NO explicit
        # rescale/mod_down (the compile pass inserts level management),
        # one shared weight vector — every expression after the first
        # compiles to hash-only plain operands (server-cache hits)
        wz = 0.5 * (rng.normal(size=n) + 1j * rng.normal(size=n))
        for j in range(traced):
            zt = 0.5 * (rng.normal(size=n) + 1j * rng.normal(size=n))
            x = session.encrypt(zt, seed=5555 + j)
            tfuts.append(
                (session.run([((x * x) * wz + x)
                              .rotate(1).conj().slot_sum()],
                             check=check)[0],
                 np.full(n, np.conj(np.roll(zt * zt * wz + zt,
                                            -1)).sum())))

    bfuts = []
    if bootstrap:
        # N concurrent bootstrap pipelines over level-exhausted inputs:
        # their aligned stage nodes co-batch ACROSS circuits (and with
        # the plain request stream) through the same queue
        for j in range(bootstrap):
            zb = rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)
            zb *= 2.0 ** -5 / np.max(np.abs(zb))
            ct = session.encrypt(zb, seed=8888 + j).ciphertext
            ct = H.he_mod_down(ct, params, params.logp)  # exhausted
            bfuts.append((session.bootstrap(ct), zb))

    # session.drain (not server.drain) so traced futures resolve while
    # the raw per-op/circuit results come back as {rid: ct}
    results.update(session.drain())
    errs = []
    for rid, (op, want) in expect.items():
        out = results[rid]
        if op in ("mul", "mul_plain"):
            out = H.rescale(out, params)
        got = session.decrypt(out)
        errs.append(float(np.abs(got - want).max()))
    for fut, want in tfuts:
        errs.append(float(np.abs(session.decrypt(fut.result())
                                 - want).max()))
    stats = server.stats()
    stats["devices"] = len(jax.devices())
    stats["max_err"] = max(errs)
    if bootstrap:
        # approximate-op contract: error-BOUND gate, not bitwise
        plan = next(iter(session._boot_plans.values()))
        berrs = []
        for fut, want in bfuts:
            out = fut.result()
            assert out.logq == plan.out_logq, (out.logq, plan.out_logq)
            berrs.append(
                float(np.abs(session.decrypt(out) - want).max()))
        bound = plan.error_bound()
        if max(berrs) > bound:
            raise AssertionError(
                f"bootstrap error {max(berrs):.3e} exceeds the "
                f"documented bound {bound:.3e}")
        if schedule and bootstrap >= 2 \
                and stats["cobatch"]["cross_circuit_batches"] == 0:
            raise AssertionError(
                "concurrent bootstraps never co-batched across "
                "circuits — the scheduler lost the batched-"
                "bootstrapping payoff")
        stats["bootstrap"] = {
            "n": bootstrap,
            "max_err": max(berrs),
            "error_bound": bound,
            "logq_in": plan.logq_in,
            "out_logq": plan.out_logq,
            "cross_circuit_rate":
                stats["cobatch"]["cross_circuit_rate"],
        }
    if trace:
        stats["trace_events"] = tracer.write(trace)
    if metrics:
        import json
        with open(metrics, "w") as f:
            json.dump(server.registry.snapshot(), f, indent=2)
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--he", action="store_true",
                    help="serve a batched multi-level HE request stream "
                         "(queue → level-aware table cache → sharded "
                         "mul/rotate engine) instead of an LM")
    ap.add_argument("--requests", type=int, default=0,
                    help="HE requests to stream (default 2·batch+1, which "
                         "exercises multi-batch assembly and padding)")
    ap.add_argument("--levels", type=int, default=1,
                    help="number of moduli to spread HE requests over "
                         "(level i serves logq = logQ − i·logp from the "
                         "resident table cache)")
    ap.add_argument("--rotations", type=int, default=0,
                    help="how many of the HE requests are rotate(r=1) "
                         "instead of mul")
    ap.add_argument("--conjugations", type=int, default=0,
                    help="how many of the HE requests are conjugate "
                         "(σ₋₁ through the same key-switch machinery)")
    ap.add_argument("--plain-frac", type=float, default=0.0,
                    help="serve this fraction of the mul share as "
                         "plaintext-operand ops (mul_plain/add_plain: "
                         "encode-only operand, NO key switch — the "
                         "encrypted-inference affine-layer fast path)")
    ap.add_argument("--circuit", action="store_true",
                    help="also submit a degree-4 encrypted polynomial "
                         "circuit (mul → rescale → mod-down → conjugate "
                         "→ add) via submit_circuit and verify it "
                         "(two staggered copies under --schedule)")
    ap.add_argument("--schedule", action="store_true",
                    help="circuit-aware scheduling: co-batch same-"
                         "(op, level) nodes across circuits via "
                         "lookahead deferral and prefetch next-level "
                         "table slices behind the in-flight batch")
    ap.add_argument("--traced", type=int, default=0,
                    help="also run this many TRACED repro.client "
                         "expressions (every handle op, auto level "
                         "management) through the session; they share "
                         "one weight vector, so runs after the first "
                         "hit the server's plaintext-operand cache")
    ap.add_argument("--check", default="off",
                    choices=["off", "warn", "error"],
                    help="static-analyze circuits before submission "
                         "(repro.analysis): 'warn' prints findings, "
                         "'error' refuses to submit on errors/warnings")
    ap.add_argument("--max-age-s", type=float, default=None,
                    help="continuous-batching SLO: flush a bucket once "
                         "its oldest request has waited this long "
                         "(default: drain-only flushing)")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer batch assembly + device_put "
                         "against the in-flight engine step")
    ap.add_argument("--kernels", action="store_true",
                    help="route HE stages through the repro.kernels "
                         "Pallas paths (interpret mode off-TPU)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="size of the model axis of the host mesh")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the request "
                         "lifecycle (submit → enqueue → bucket-wait → "
                         "flush → assemble → dispatch → device-wall → "
                         "complete) + engine spans; open in Perfetto or "
                         "run `python -m repro.obs report PATH`")
    ap.add_argument("--profile-stages", action="store_true",
                    help="attribute mul/rotate wall time to the paper's "
                         "Fig. 3 stages (CRT/NTT/modmul/iCRT): stage-"
                         "chain steps run as fenced block-jitted stages "
                         "(bitwise identical, slower) and the per-stage "
                         "split prints after the drain")
    ap.add_argument("--workers", type=int, default=0,
                    help="serve through the multi-host tier: an "
                         "HEFrontend routing batches by (op, level) "
                         "affinity to this many in-process worker "
                         "engines, with heartbeat health and worker-"
                         "death requeue (0 = single HEServer)")
    ap.add_argument("--bootstrap", type=int, nargs="?", const=2,
                    default=0, metavar="N",
                    help="also serve N concurrent CKKS bootstrap "
                         "pipelines (repro.boot) over level-exhausted "
                         "ciphertexts; bare --bootstrap means N=2 so "
                         "cross-circuit co-batching is exercised. "
                         "Switches the run to the reference bootstrap "
                         "params (logQ=336, h=2); results verify "
                         "against the documented error bound")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="dump the unified MetricsRegistry snapshot "
                         "(serve/cache/scheduler/engine/client planes) "
                         "as JSON after the drain")
    args = ap.parse_args()

    if args.he:
        stats = serve_he(args.batch, requests=args.requests,
                         levels=args.levels, rotations=args.rotations,
                         conjugations=args.conjugations,
                         plain_frac=args.plain_frac,
                         model_shards=args.model_shards,
                         use_kernels=args.kernels,
                         max_age_s=args.max_age_s, overlap=args.overlap,
                         circuit=args.circuit, schedule=args.schedule,
                         traced=args.traced, check=args.check,
                         trace=args.trace,
                         profile_stages=args.profile_stages,
                         metrics=args.metrics, workers=args.workers,
                         bootstrap=args.bootstrap)
        ops = ", ".join(
            f"{op}: {d['requests']} reqs @ {d['ops_per_s']}/s "
            f"(p50 {d['latency_ms']['p50']}ms, "
            f"p99 {d['latency_ms']['p99']}ms, pad {d['pad_frac']})"
            for op, d in stats["per_op"].items())
        print(f"hserve batch={stats['batch']} on {stats['devices']} "
              f"device(s) {stats['mesh']} levels={stats['levels_served']} "
              f"steps_compiled={stats['engine']['steps_compiled']} "
              f"(compile {stats['engine']['compile_s']}s)")
        print(f"  {ops}")
        if args.workers:
            fr = stats["frontend"]
            print(f"  frontend: {fr['workers']} {fr['transport']} "
                  f"worker(s), {fr['alive']} alive, "
                  f"{fr['deaths']} death(s), "
                  f"{fr['requeued_requests']} requeued")
        if args.schedule:
            sch, cb = stats["scheduler"], stats["cobatch"]
            print(f"  scheduler: lookahead={sch['lookahead']} "
                  f"deferrals={sch['deferrals']} "
                  f"prefetched_levels={sch['prefetched_levels']} "
                  f"cross_circuit_rate={cb['cross_circuit_rate']}")
        if args.traced:
            c = stats["cache"]
            print(f"  plaintext cache: {c['plain_hits']} hits / "
                  f"{c['plain_misses']} misses "
                  f"({c['plain_entries']} entries)")
        if args.profile_stages:
            for op, row in sorted(stats["stages"]["stages"].items()):
                tot = sum(row.values())
                wall = stats["per_op"].get(op, {}).get("wall_s", 0.0)
                split = " ".join(
                    f"{s} {1e3 * v:.1f}ms ({v / tot:.0%})"
                    for s, v in row.items()) if tot else "—"
                cov = f" coverage {tot / wall:.0%} of wall" if wall else ""
                print(f"  fig3[{op}]: {split}{cov}")
        if args.bootstrap:
            bs = stats["bootstrap"]
            print(f"  bootstrap: {bs['n']} concurrent pipeline(s) "
                  f"logq {bs['logq_in']} -> {bs['out_logq']}, "
                  f"max_err {bs['max_err']:.2e} "
                  f"(bound {bs['error_bound']:.2e}), "
                  f"cross_circuit_rate {bs['cross_circuit_rate']}")
        if args.trace:
            print(f"  trace: {stats['trace_events']} events -> "
                  f"{args.trace}")
        if args.metrics:
            print(f"  metrics snapshot -> {args.metrics}")
        print(f"  max_err {stats['max_err']:.2e}")
        assert stats["max_err"] < 1e-2, "HE serving pipeline diverged"
        return

    from repro.configs.registry import get_arch
    from repro.dist.sharding import batch_spec, param_sharding_rules
    from repro.launch.mesh import make_host_mesh
    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    mesh = make_host_mesh(model=args.model_shards)  # validates divisibility
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    # tensor-parallel only: FSDP-sharded weights would re-gather on every
    # decode step, and serving has no gradients to shard for
    params = jax.device_put(
        params, param_sharding_rules(params, mesh, fsdp_params=False))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    if args.batch % mesh.shape["data"] == 0:
        tokens = jax.device_put(tokens, batch_spec(mesh))
    extra = {}
    if cfg.enc_dec:
        extra["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, 2 * args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "vision":
        extra["patch_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    t0 = time.time()
    out = generate(params, cfg, tokens,
                   args.gen, args.prompt_len + args.gen + 8,
                   batch_extra=extra)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s, incl. compile)")


if __name__ == "__main__":
    main()
