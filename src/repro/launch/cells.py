"""Abstract-table lowering of every served HE engine step (no side
effects: unlike `launch.dryrun`, importing this module sets no XLA
flags, so tests and `repro.analysis.xla` can use it in-process).

One function, `lower_he_serving_cell`, covers the FULL served op table
(`analysis.dataflow.OPS` + `PLAIN_OPS`): it builds the exact step the
hserve engine would jit for that op — same factory, same table pytrees —
but lowers it from `he_table_specs` ShapeDtypeStructs alone, so a cell
compiles in milliseconds with no twiddle-table build. `launch.dryrun`
re-exports it for the multi-pod dry-run; `repro.analysis.xla`
(shardlint) lowers every (op, level, mesh) cell through it and checks
the optimized HLO against the `dist.sharding` collective predictions.

`ct_sharding` deliberately accepts a WRONG placement: shardlint's
injected-regression path lowers a cell with a bogus rule (e.g. the N
axis on "model") to prove the analyzer catches the resulting implicit
resharding (HS101/HS103).
"""

from __future__ import annotations

import jax

from repro.analysis.dataflow import OPS, PLAIN_OPS

__all__ = ["HE_SERVING_OPS", "lower_he_serving_cell", "serving_op_levels"]

# every op the engine serves — the analysis table is the source of truth
# so a newly served op cannot dodge lowering analysis
HE_SERVING_OPS = tuple(OPS)
assert set(PLAIN_OPS) <= set(HE_SERVING_OPS)


def serving_op_levels(op: str, levels, params) -> list:
    """The subset of `levels` at which `op` is actually servable.

    rescale and mod_down consume a level: at the bottom of the modulus
    chain (logq < 2·logp) there is no level left to drop, and the
    serving dataflow would never schedule them there. mod_raise is the
    mirror image: at the top of the chain (logq + logp > logQ) there is
    no headroom left to raise into.
    """
    if op in ("rescale", "mod_down"):
        return [lq for lq in levels if lq >= 2 * params.logp]
    if op == "mod_raise":
        return [lq for lq in levels if lq + params.logp <= params.logQ]
    return list(levels)


def lower_he_serving_cell(op: str, batch: int, mesh, *, logq=None,
                          params=None, n_slots=None, ct_sharding=None):
    """Lower ONE hserve engine step with abstract tables -> jax Lowered.

    `rotate`/`conjugate`/`slot_sum` consume the region-2 table spec plus
    evk-shaped Galois key specs (rotation keys have exactly the evk
    pytree shape); `mul` takes both region tables + the evk; `rescale`/
    `mod_down`/`add`/`sub` consume nothing but ciphertext batches — pure
    limb arithmetic, which is the point the analysis record makes: zero
    collective bytes at any mesh size. The plaintext-operand ops make
    the complementary point: `mul_plain` is region 1 alone (its HLO
    carries NO key-switch collectives, only the CRT/iCRT reduction
    traffic) and `add_plain` is a bare limb add with nothing on the
    wire at all.

    `ct_sharding` overrides the ciphertext placement rule
    (`dist.sharding.he_limb_sharding`) — pass a deliberately wrong
    NamedSharding to reproduce an implicit-resharding regression.
    """
    from repro.core.rotate import conjugation_k, rotation_k
    from repro.dist import he_pipeline as hp
    from repro.dist.sharding import he_limb_sharding
    from repro.hserve.engine import (
        make_add_plain_step, make_addsub_step, make_he_rotate_step,
        make_mod_down_step, make_mod_raise_step, make_mul_plain_step,
        make_rescale_step, make_slot_sum_step, slot_sum_rotations,
    )
    if params is None:
        from repro.configs.heaan_mul import CONFIG as params
    if logq is None:
        # mod_raise is unservable at the very top of the chain (nothing
        # to raise into) — its default cell sits one level down
        logq = params.logQ - (params.logp if op == "mod_raise" else 0)
    st = hp.he_static(params, logq)
    t1, t2, ek = hp.he_table_specs(st)
    ct_sh = he_limb_sharding(mesh, batch=batch) if ct_sharding is None \
        else ct_sharding
    ct = jax.ShapeDtypeStruct((batch, st.N, st.qlimbs), st.dtype,
                              sharding=ct_sh)
    if op == "mul":
        step = hp.make_he_mul_step(st, mesh)
        return jax.jit(step).lower(t1, t2, ek, ct, ct, ct, ct)
    if op in ("rotate", "conjugate"):
        k = rotation_k(params, 1) if op == "rotate" \
            else conjugation_k(params)
        step = make_he_rotate_step(st, mesh, k)
        return jax.jit(step).lower(t2, ek, ct, ct)
    if op == "slot_sum":
        n = n_slots if n_slots else params.n_slots_max
        step = make_slot_sum_step(st, mesh, n)
        rks = tuple(ek for _ in slot_sum_rotations(n))
        return jax.jit(step).lower(t2, rks, ct, ct)
    if op == "rescale":
        step = make_rescale_step(st, mesh, params.logp)
        return jax.jit(step).lower(ct, ct)
    if op == "mod_down":
        step = make_mod_down_step(st, mesh, max(params.logp,
                                                logq - params.logp))
        return jax.jit(step).lower(ct, ct)
    if op == "mod_raise":
        step = make_mod_raise_step(st, mesh,
                                   min(params.logQ, logq + params.logp))
        return jax.jit(step).lower(ct, ct)
    if op in ("add", "sub"):
        step = make_addsub_step(st, mesh, op)
        return jax.jit(step).lower(ct, ct, ct, ct)
    if op == "mul_plain":
        step = make_mul_plain_step(st, mesh)
        return jax.jit(step).lower(t1, ct, ct, ct)   # pt: same spec
    if op == "add_plain":
        step = make_add_plain_step(st, mesh)
        return jax.jit(step).lower(ct, ct, ct)
    raise ValueError(f"unknown serving op {op!r}; one of {HE_SERVING_OPS}")
