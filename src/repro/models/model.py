"""Model top level: init / train forward / prefill / decode for all families.

Decoder-only (dense, MoE, SSM, hybrid, VLM-backbone) and encoder-decoder
(whisper) assemblies. Uniform layer stacks run under lax.scan with stacked
params (compile-time sanity at 64 layers); hybrid patterns scan over whole
pattern groups with an unrolled tail.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.attention import attention_block, decode_attention, \
    init_attn, kv_to_ring_cache
from repro.models.blocks import (
    apply_layer, apply_layer_decode, apply_layer_prefill, init_layer,
    init_layer_cache,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense, init_linear, init_norm, norm_apply, sinusoidal_positions,
)
from repro.models.mlp import gelu_mlp, init_gelu_mlp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "tok_embed": (jax.random.normal(
            ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.pdt),
        "ln_f": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
        "lm_head": init_linear(ks[1], cfg.d_model, cfg.vocab_size, cfg.pdt),
    }
    kinds = cfg.layer_kinds
    if cfg.enc_dec:
        p["enc"] = _init_encoder(ks[2], cfg)
        p["dec"] = _init_dec_layers(ks[3], cfg)
        return p
    if cfg.uniform_layers and cfg.scan_layers:
        keys = jax.random.split(ks[2], cfg.n_layers)
        p["layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, kinds[0]))(keys)
    elif cfg.layer_pattern and cfg.scan_layers:
        g = len(cfg.layer_pattern)
        n_groups, tail = divmod(cfg.n_layers, g)
        gkeys = jax.random.split(ks[2], n_groups)

        def init_group(k):
            lk = jax.random.split(k, g)
            return {f"sub{i}": init_layer(lk[i], cfg, cfg.layer_pattern[i])
                    for i in range(g)}

        p["groups"] = jax.vmap(init_group)(gkeys)
        tkeys = jax.random.split(ks[3], max(tail, 1))
        p["tail"] = [init_layer(tkeys[i], cfg, kinds[n_groups * g + i])
                     for i in range(tail)]
    else:
        lkeys = jax.random.split(ks[2], cfg.n_layers)
        p["layers_list"] = [init_layer(lkeys[i], cfg, kinds[i])
                            for i in range(cfg.n_layers)]
    return p


def _init_encoder(key, cfg):
    ks = jax.random.split(key, cfg.n_enc_layers + 1)

    def enc_layer(k):
        kk = jax.random.split(k, 2)
        return {
            "ln1": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
            "attn": init_attn(kk[0], cfg),
            "ln2": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
            "mlp": init_gelu_mlp(kk[1], cfg.d_model, cfg.d_ff, cfg.pdt),
        }

    return {
        "layers": [enc_layer(ks[i]) for i in range(cfg.n_enc_layers)],
        "ln_post": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
    }


def _init_dec_layers(key, cfg):
    ks = jax.random.split(key, cfg.n_layers)

    def dec_layer(k):
        kk = jax.random.split(k, 3)
        return {
            "ln1": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
            "self_attn": init_attn(kk[0], cfg),
            "ln_x": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
            "cross_attn": init_attn(kk[1], cfg, cross=True),
            "ln2": init_norm(cfg.d_model, cfg.pdt, cfg.norm),
            "mlp": init_gelu_mlp(kk[2], cfg.d_model, cfg.d_ff, cfg.pdt),
        }

    return {"layers": [dec_layer(ks[i]) for i in range(cfg.n_layers)]}


# --------------------------------------------------------------------------
# stacks (train/prefill)
# --------------------------------------------------------------------------

def _remat_wrap(fn, cfg):
    """Apply the configured remat policy (§Perf lever)."""
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _run_stack(p, x, cfg, positions=None):
    """Returns (x, total_aux)."""
    kinds = cfg.layer_kinds
    aux_total = jnp.zeros((), jnp.float32)
    if "layers" in p:
        def body(x, layer_p):
            fn = _remat_wrap(
                functools.partial(apply_layer, cfg=cfg, kind=kinds[0],
                                  positions=positions), cfg)
            x, aux = fn(layer_p, x)
            return x, aux
        x, auxes = jax.lax.scan(body, x, p["layers"])
        return x, aux_total + auxes.sum()
    if "groups" in p:
        g = len(cfg.layer_pattern)

        def gbody(x, group_p):
            aux = jnp.zeros((), jnp.float32)
            for i in range(g):
                fn = _remat_wrap(
                    functools.partial(apply_layer, cfg=cfg,
                                      kind=cfg.layer_pattern[i],
                                      positions=positions), cfg)
                x, a = fn(group_p[f"sub{i}"], x)
                aux = aux + a
            return x, aux
        x, auxes = jax.lax.scan(gbody, x, p["groups"])
        aux_total = aux_total + auxes.sum()
        n_groups = cfg.n_layers // g
        for i, lp in enumerate(p["tail"]):
            x, a = apply_layer(lp, x, cfg, kinds[n_groups * g + i],
                               positions=positions)
            aux_total = aux_total + a
        return x, aux_total
    for i, lp in enumerate(p["layers_list"]):
        fn = _remat_wrap(
            functools.partial(apply_layer, cfg=cfg, kind=kinds[i],
                              positions=positions), cfg)
        x, a = fn(lp, x)
        aux_total = aux_total + a
    return x, aux_total


def _encode_frames(p, frames, cfg):
    """Whisper encoder over stub frame embeddings (B, S, D)."""
    x = frames.astype(cfg.adt)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cfg.adt)[None]
    for lp in p["enc"]["layers"]:
        h = norm_apply(cfg.norm, lp["ln1"], x)
        x = x + attention_block(lp["attn"], h, cfg, causal=False,
                                use_rope=False)
        h2 = norm_apply(cfg.norm, lp["ln2"], x)
        x = x + gelu_mlp(lp["mlp"], h2)
    return norm_apply(cfg.norm, p["enc"]["ln_post"], x)


def _decoder_stack_encdec(p, x, memory, cfg):
    for lp in p["dec"]["layers"]:
        h = norm_apply(cfg.norm, lp["ln1"], x)
        x = x + attention_block(lp["self_attn"], h, cfg, causal=True,
                                use_rope=False)
        hx = norm_apply(cfg.norm, lp["ln_x"], x)
        x = x + attention_block(lp["cross_attn"], hx, cfg, kv_x=memory,
                                use_rope=False)
        h2 = norm_apply(cfg.norm, lp["ln2"], x)
        x = x + gelu_mlp(lp["mlp"], h2)
    return x


# --------------------------------------------------------------------------
# train forward / loss
# --------------------------------------------------------------------------

def forward_train(params: Params, batch: Dict[str, jnp.ndarray],
                  cfg: ModelConfig):
    """Returns (logits (B, L, V), aux_loss)."""
    tokens = batch["tokens"]
    B, L = tokens.shape
    x = params["tok_embed"].astype(cfg.adt)[tokens]

    if cfg.enc_dec:
        memory = _encode_frames(params, batch["frames"], cfg)
        x = x + sinusoidal_positions(L, cfg.d_model, cfg.adt)[None]
        x = _decoder_stack_encdec(params, x, memory, cfg)
        aux = jnp.zeros((), jnp.float32)
    else:
        positions = jnp.arange(L)[None, :]
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(cfg.adt)
            x = jnp.concatenate([pe, x], axis=1)
            positions = jnp.arange(x.shape[1])[None, :]
        x, aux = _run_stack(params, x, cfg, positions=positions)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            x = x[:, -L:]
    x = norm_apply(cfg.norm, params["ln_f"], x)
    logits = dense(params["lm_head"], x).astype(jnp.float32)
    return logits, aux


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    logits, aux = forward_train(params, batch, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None],
                               axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": mask.sum()}


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0):
    dtype = cfg.adt
    if cfg.enc_dec:
        shp = (batch, max_len, cfg.n_kv_heads, cfg.hd)
        xshp = (batch, enc_len, cfg.n_kv_heads, cfg.hd)
        return {
            "dec": [{"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
                     "xk": jnp.zeros(xshp, dtype),
                     "xv": jnp.zeros(xshp, dtype)}
                    for _ in range(cfg.n_layers)],
        }
    kinds = cfg.layer_kinds
    if cfg.uniform_layers and cfg.scan_layers:
        one = init_layer_cache(cfg, kinds[0], batch, max_len, dtype)
        return {"stacked": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)}
    if cfg.layer_pattern and cfg.scan_layers:
        g = len(cfg.layer_pattern)
        n_groups, tail = divmod(cfg.n_layers, g)
        group = {f"sub{i}": init_layer_cache(cfg, cfg.layer_pattern[i],
                                             batch, max_len, dtype)
                 for i in range(g)}
        return {
            "groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (n_groups,) + a.shape), group),
            "tail": [init_layer_cache(cfg, kinds[n_groups * g + i], batch,
                                      max_len, dtype)
                     for i in range(tail)],
        }
    return {"list": [init_layer_cache(cfg, kinds[i], batch, max_len, dtype)
                     for i in range(cfg.n_layers)]}


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            max_len: int):
    """Run the prompt, build the cache. Returns (last-token logits, cache)."""
    tokens = batch["tokens"]
    B, L = tokens.shape
    x = params["tok_embed"].astype(cfg.adt)[tokens]
    kinds = cfg.layer_kinds

    if cfg.enc_dec:
        memory = _encode_frames(params, batch["frames"], cfg)
        x = x + sinusoidal_positions(L, cfg.d_model, cfg.adt)[None]
        caches = []
        for lp in params["dec"]["layers"]:
            h = norm_apply(cfg.norm, lp["ln1"], x)
            att, k, v = attention_block(lp["self_attn"], h, cfg, causal=True,
                                        use_rope=False, return_kv=True)
            ck, cv = kv_to_ring_cache(k, v, max_len)
            x = x + att
            hx = norm_apply(cfg.norm, lp["ln_x"], x)
            xatt, xk, xv = attention_block(lp["cross_attn"], hx, cfg,
                                           kv_x=memory, use_rope=False,
                                           return_kv=True)
            x = x + xatt
            h2 = norm_apply(cfg.norm, lp["ln2"], x)
            x = x + gelu_mlp(lp["mlp"], h2)
            caches.append({"k": ck, "v": cv, "xk": xk, "xv": xv})
        x = norm_apply(cfg.norm, params["ln_f"], x)
        logits = dense(params["lm_head"], x[:, -1:]).astype(jnp.float32)
        return logits, {"dec": caches}

    positions = jnp.arange(L)[None, :]
    if "layers" in params:
        def body(x, layer_p):
            x, cache = apply_layer_prefill(layer_p, x, cfg, kinds[0],
                                           max_len, positions=positions)
            return x, cache
        x, stacked = jax.lax.scan(body, x, params["layers"])
        cache = {"stacked": stacked}
    elif "groups" in params:
        g = len(cfg.layer_pattern)
        n_groups = cfg.n_layers // g

        def gbody(x, group_p):
            caches = {}
            for i in range(g):
                x, c = apply_layer_prefill(group_p[f"sub{i}"], x, cfg,
                                           cfg.layer_pattern[i], max_len,
                                           positions=positions)
                caches[f"sub{i}"] = c
            return x, caches
        x, gcaches = jax.lax.scan(gbody, x, params["groups"])
        tails = []
        for i, lp in enumerate(params["tail"]):
            x, c = apply_layer_prefill(lp, x, cfg, kinds[n_groups * g + i],
                                       max_len, positions=positions)
            tails.append(c)
        cache = {"groups": gcaches, "tail": tails}
    else:
        caches = []
        for i, lp in enumerate(params["layers_list"]):
            x, c = apply_layer_prefill(lp, x, cfg, kinds[i], max_len,
                                       positions=positions)
            caches.append(c)
        cache = {"list": caches}
    x = norm_apply(cfg.norm, params["ln_f"], x)
    logits = dense(params["lm_head"], x[:, -1:]).astype(jnp.float32)
    return logits, cache


def decode_step(params: Params, cache, token_t: jnp.ndarray, t,
                cfg: ModelConfig):
    """One decode step. token_t: (B, 1) int32; t: current position (scalar).

    Returns (logits (B, 1, V), new_cache).
    """
    x = params["tok_embed"].astype(cfg.adt)[token_t]
    kinds = cfg.layer_kinds

    if cfg.enc_dec:
        from repro.models.layers import sinusoidal_position_at
        pos = sinusoidal_position_at(jnp.asarray(t), cfg.d_model,
                                     cfg.adt)[None, None]
        x = x + pos
        new = []
        for lp, c in zip(params["dec"]["layers"], cache["dec"]):
            h = norm_apply(cfg.norm, lp["ln1"], x)
            att, ck, cv = decode_attention(lp["self_attn"], h, c["k"],
                                           c["v"], t, cfg, use_rope=False)
            x = x + att
            hx = norm_apply(cfg.norm, lp["ln_x"], x)
            # cross attention: static memory, no causal mask
            xout = _cross_decode(lp["cross_attn"], hx, c["xk"], c["xv"], cfg)
            x = x + xout
            h2 = norm_apply(cfg.norm, lp["ln2"], x)
            x = x + gelu_mlp(lp["mlp"], h2)
            new.append({"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]})
        x = norm_apply(cfg.norm, params["ln_f"], x)
        return dense(params["lm_head"], x).astype(jnp.float32), {"dec": new}

    if "layers" in params:
        def body(x, scanned):
            layer_p, c = scanned
            x, c2 = apply_layer_decode(layer_p, x, c, t, cfg, kinds[0])
            return x, c2
        x, new_stacked = jax.lax.scan(body, x,
                                      (params["layers"], cache["stacked"]))
        new_cache = {"stacked": new_stacked}
    elif "groups" in params:
        g = len(cfg.layer_pattern)
        n_groups = cfg.n_layers // g

        def gbody(x, scanned):
            group_p, gc = scanned
            out_c = {}
            for i in range(g):
                x, c2 = apply_layer_decode(group_p[f"sub{i}"], x,
                                           gc[f"sub{i}"], t, cfg,
                                           cfg.layer_pattern[i])
                out_c[f"sub{i}"] = c2
            return x, out_c
        x, new_g = jax.lax.scan(gbody, x,
                                (params["groups"], cache["groups"]))
        new_tail = []
        for i, (lp, c) in enumerate(zip(params["tail"], cache["tail"])):
            x, c2 = apply_layer_decode(lp, x, c, t, cfg,
                                       kinds[n_groups * g + i])
            new_tail.append(c2)
        new_cache = {"groups": new_g, "tail": new_tail}
    else:
        new_list = []
        for i, (lp, c) in enumerate(zip(params["layers_list"],
                                        cache["list"])):
            x, c2 = apply_layer_decode(lp, x, c, t, cfg, kinds[i])
            new_list.append(c2)
        new_cache = {"list": new_list}
    x = norm_apply(cfg.norm, params["ln_f"], x)
    return dense(params["lm_head"], x).astype(jnp.float32), new_cache


def _cross_decode(p, x_t, xk, xv, cfg):
    """Decode-time cross attention against static encoder memory."""
    import jax.numpy as jnp
    from repro.models.attention import _split_heads
    B = x_t.shape[0]
    hd = cfg.hd
    q = _split_heads(dense(p["wq"], x_t), cfg.n_heads, hd)
    Hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, xk.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, xv.astype(jnp.float32))
    o = o.astype(x_t.dtype).reshape(B, 1, cfg.n_heads * hd)
    return dense(p["wo"], o)
