"""LM model substrate: every assigned architecture family in functional JAX.

Families: dense decoder (GQA/SWA/RoPE/SwiGLU), MoE (top-k, optional dense
residual), SSM (Mamba-1), hybrid (RG-LRU + local attention), encoder-decoder
(whisper, stub audio frontend), VLM (stub patch frontend + decoder backbone).

Params are nested dicts of jnp arrays; sharding rules live in repro.dist.
"""

from repro.models.config import ModelConfig
from repro.models.model import (
    init_params, forward_train, loss_fn, prefill, decode_step, init_cache,
)

__all__ = [
    "ModelConfig", "init_params", "forward_train", "loss_fn",
    "prefill", "decode_step", "init_cache",
]
