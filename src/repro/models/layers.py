"""Shared layers: norms, RoPE, embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm", "layernorm", "rope", "init_linear", "init_norm",
           "dense", "norm_apply", "sinusoidal_positions"]


def init_linear(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_norm(d, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm_apply(kind, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def rope(x, positions, theta):
    """Rotary embedding. x: (..., L, H, hd); positions: (..., L)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., L, half)
    cos = jnp.cos(ang)[..., :, None, :]                        # (...,L,1,half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n, d, dtype):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return pe.astype(dtype)


def sinusoidal_position_at(t, d, dtype):
    """Single-position embedding with a traced position t. Returns (d,)."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = t.astype(jnp.float32) / (10000.0 ** (dim / d))
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang[: (d - d // 2)]))
    return pe.astype(dtype)
