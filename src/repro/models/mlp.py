"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_linear

__all__ = ["init_swiglu", "swiglu", "init_gelu_mlp", "gelu_mlp"]


def init_swiglu(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wi": init_linear(ks[0], d_model, d_ff, dtype),
        "wg": init_linear(ks[1], d_model, d_ff, dtype),
        "wo": init_linear(ks[2], d_ff, d_model, dtype,
                          scale=d_ff ** -0.5),
    }


def swiglu(p, x):
    h = jax.nn.silu(dense(p["wg"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h * dense(p["wi"], x))


def init_gelu_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 2)
    return {
        "wi": init_linear(ks[0], d_model, d_ff, dtype, bias=True),
        "wo": init_linear(ks[1], d_ff, d_model, dtype, bias=True,
                          scale=d_ff ** -0.5),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu(dense(p["wi"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h)
