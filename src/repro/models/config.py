"""Unified model configuration covering all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # core transformer dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None          # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    # attention
    attention: str = "full"                  # full | swa | none
    window: int = 4096                       # SWA / local-attn window
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    # norm / activation
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False         # arctic: dense FFN in parallel
    dense_d_ff: int = 0                      # width of the dense residual FFN
    # SSM (mamba-1)
    ssm: bool = False
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid pattern: tuple like ("rglru", "rglru", "attn"); empty = uniform
    layer_pattern: Tuple[str, ...] = ()
    rglru_width: Optional[int] = None        # default d_model
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    n_frontend_tokens: int = 0               # patches prepended (vision)
    # dtypes
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    # training
    remat: bool = True
    remat_policy: str = "full"               # full | dots | none (§Perf)
    scan_layers: bool = True                 # stack layers under lax.scan
    ssm_chunk: int = 128                     # recurrence chunk (§Perf)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adt(self):
        return jnp.dtype(self.activation_dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, (self.d_model + 15) // 16)

    @property
    def lru_width(self) -> int:
        return self.rglru_width if self.rglru_width else self.d_model

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind, resolved from the pattern (cycled) or uniform."""
        if self.layer_pattern:
            pat = self.layer_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.ssm:
            return tuple("ssm" for _ in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    @property
    def uniform_layers(self) -> bool:
        kinds = self.layer_kinds
        return all(k == kinds[0] for k in kinds)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized copy of the same family (assignment: per-arch
        smoke tests instantiate a REDUCED config of the same family)."""
        base = dict(
            n_layers=min(self.n_layers, 2 + (2 if self.layer_pattern else 0)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4
                                  // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            capacity_factor=4.0,   # dropless at smoke scale: C reaches T
            dense_d_ff=128 if self.moe_dense_residual else 0,
            rglru_width=128 if self.rglru_width else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            window=64,
            param_dtype="float32",
            activation_dtype="float32",
            scan_layers=False,
        )
        if self.layer_pattern:
            base["n_layers"] = len(self.layer_pattern)
        base.update(overrides)
        return dataclasses.replace(self, **base)
