"""Per-layer blocks (attn / ssm / rglru, dense or MoE FFN) + stacking."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_block, decode_attention, init_attn,
)
from repro.models.layers import init_norm, norm_apply
from repro.models.mlp import init_swiglu, swiglu
from repro.models.moe import init_moe, moe_block
from repro.models.rglru import (
    init_rglru, init_rglru_state, rglru_block, rglru_decode_step,
)
from repro.models.ssm import (
    init_ssm, init_ssm_state, ssm_block, ssm_decode_step,
)

__all__ = ["init_layer", "apply_layer", "apply_layer_decode",
           "apply_layer_prefill", "init_layer_cache"]


def _ffn_init(key, cfg):
    if cfg.n_experts:
        return {"moe": init_moe(key, cfg)}
    return {"mlp": init_swiglu(key, cfg.d_model, cfg.d_ff, cfg.pdt)}


def _ffn_apply(p, x, cfg):
    if "moe" in p:
        return moe_block(p["moe"], x, cfg)
    return swiglu(p["mlp"], x), jnp.zeros((), jnp.float32)


def init_layer(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    nk = cfg.norm
    if kind == "attn":
        return {
            "ln1": init_norm(cfg.d_model, cfg.pdt, nk),
            "attn": init_attn(ks[0], cfg),
            "ln2": init_norm(cfg.d_model, cfg.pdt, nk),
            **_ffn_init(ks[1], cfg),
        }
    if kind == "swa":
        return init_layer(key, cfg, "attn")
    if kind == "ssm":
        return {
            "ln1": init_norm(cfg.d_model, cfg.pdt, nk),
            "ssm": init_ssm(ks[0], cfg),
        }
    if kind == "rglru":
        return {
            "ln1": init_norm(cfg.d_model, cfg.pdt, nk),
            "rglru": init_rglru(ks[0], cfg),
            "ln2": init_norm(cfg.d_model, cfg.pdt, nk),
            **_ffn_init(ks[1], cfg),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def _window_for(kind: str, cfg) -> int:
    if kind == "swa" or (kind == "attn" and cfg.attention == "swa"):
        return cfg.window
    if kind == "attn" and cfg.layer_pattern:
        return cfg.window          # hybrid archs use local attention
    return 0


def apply_layer(p, x, cfg, kind: str, positions=None):
    """Training/prefill path. Returns (x, aux_loss, kv) — kv for prefill."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if kind in ("attn", "swa"):
        h = norm_apply(cfg.norm, p["ln1"], x)
        att = attention_block(p["attn"], h, cfg, positions=positions,
                              causal=True, window=_window_for(kind, cfg))
        x = x + att
        h2 = norm_apply(cfg.norm, p["ln2"], x)
        f, aux = _ffn_apply(p, h2, cfg)
        x = x + f
    elif kind == "ssm":
        x = x + ssm_block(p["ssm"], norm_apply(cfg.norm, p["ln1"], x), cfg)
    elif kind == "rglru":
        x = x + rglru_block(p["rglru"],
                            norm_apply(cfg.norm, p["ln1"], x), cfg)
        h2 = norm_apply(cfg.norm, p["ln2"], x)
        f, aux = _ffn_apply(p, h2, cfg)
        x = x + f
    else:
        raise ValueError(kind)
    return x, aux


def apply_layer_prefill(p, x, cfg, kind: str, max_len: int, positions=None):
    """Prefill path: like apply_layer but also builds this layer's cache."""
    from repro.models.attention import kv_to_ring_cache
    if kind in ("attn", "swa"):
        h = norm_apply(cfg.norm, p["ln1"], x)
        w = _window_for(kind, cfg)
        att, k, v = attention_block(
            p["attn"], h, cfg, positions=positions, causal=True,
            window=w, return_kv=True)
        S = min(max_len, w) if w else max_len
        ck, cv = kv_to_ring_cache(k, v, S)
        x = x + att
        h2 = norm_apply(cfg.norm, p["ln2"], x)
        f, _ = _ffn_apply(p, h2, cfg)
        return x + f, {"k": ck, "v": cv}
    if kind == "ssm":
        from repro.models.ssm import _ssm_inner
        out, tail, hs = _ssm_inner(
            p["ssm"], norm_apply(cfg.norm, p["ln1"], x), cfg)
        return x + out, {"h": hs, "conv_tail": tail}
    if kind == "rglru":
        from repro.models.rglru import _rglru_inner
        out, tail, hs = _rglru_inner(
            p["rglru"], norm_apply(cfg.norm, p["ln1"], x), cfg)
        x = x + out
        h2 = norm_apply(cfg.norm, p["ln2"], x)
        f, _ = _ffn_apply(p, h2, cfg)
        return x + f, {"hr": hs, "conv_tail": tail}
    raise ValueError(kind)


def init_layer_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "swa"):
        w = _window_for(kind, cfg)
        S = min(max_len, w) if w else max_len
        shp = (batch, S, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "ssm":
        return init_ssm_state(cfg, batch, dtype)
    if kind == "rglru":
        return init_rglru_state(cfg, batch, dtype)
    raise ValueError(kind)


def apply_layer_decode(p, x_t, cache, t, cfg, kind: str):
    """Single-token decode. Returns (x_t, new_cache)."""
    if kind in ("attn", "swa"):
        h = norm_apply(cfg.norm, p["ln1"], x_t)
        w = _window_for(kind, cfg)
        att, ck, cv = decode_attention(p["attn"], h, cache["k"], cache["v"],
                                       t, cfg, window=w)
        x_t = x_t + att
        h2 = norm_apply(cfg.norm, p["ln2"], x_t)
        f, _ = _ffn_apply(p, h2, cfg)
        return x_t + f, {"k": ck, "v": cv}
    if kind == "ssm":
        out, st = ssm_decode_step(
            p["ssm"], norm_apply(cfg.norm, p["ln1"], x_t), cache, cfg)
        return x_t + out, st
    if kind == "rglru":
        out, st = rglru_decode_step(
            p["rglru"], norm_apply(cfg.norm, p["ln1"], x_t), cache, cfg)
        x_t = x_t + out
        h2 = norm_apply(cfg.norm, p["ln2"], x_t)
        f, _ = _ffn_apply(p, h2, cfg)
        return x_t + f, st
    raise ValueError(kind)
