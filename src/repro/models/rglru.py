"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Block: x -> [linear branch with GELU gate] ∥ [linear -> causal conv1d ->
RG-LRU] -> multiply -> out linear.

RG-LRU (diagonal gated linear recurrence):
    r_t = σ(W_a x_t + b_a)                  (recurrence gate)
    i_t = σ(W_x x_t + b_x)                  (input gate)
    a_t = a^(c·r_t)  with  a = σ(Λ), c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Scan structure mirrors ssm.py (chunked + remat). Decode carries (h, conv
tail).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_linear
from repro.models.ssm import _conv1d_causal

__all__ = ["init_rglru", "rglru_block", "rglru_decode_step",
           "init_rglru_state"]

C_CONST = 8.0
CHUNK = 128


def init_rglru(key, cfg):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    dt = cfg.pdt
    # Λ init so a = σ(Λ) ∈ (0.9, 0.999) (paper's stable range)
    u = jax.random.uniform(ks[4], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / C_CONST) / (1 - u ** (1.0 / C_CONST)))
    return {
        "in_x": init_linear(ks[0], D, W, dt),
        "in_y": init_linear(ks[1], D, W, dt),
        "conv_w": (jax.random.normal(ks[2], (4, W), jnp.float32)
                   * (4 * W) ** -0.5).astype(dt),
        "conv_b": jnp.zeros((W,), dt),
        "gate_a": init_linear(ks[3], W, W, jnp.float32, bias=True),
        "gate_x": init_linear(ks[5], W, W, jnp.float32, bias=True),
        "lambda": lam,
        "out": init_linear(jax.random.fold_in(key, 9), W, D, dt,
                           scale=W ** -0.5),
    }


def _rglru_scan(p, xs, h0):
    """xs: (B, L, W) f32. Returns (y (B, L, W) f32, h_final)."""
    B, L, W = xs.shape
    r = jax.nn.sigmoid(xs @ p["gate_a"]["w"] + p["gate_a"]["b"])
    i = jax.nn.sigmoid(xs @ p["gate_x"]["w"] + p["gate_x"]["b"])
    log_a = -C_CONST * jax.nn.softplus(p["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xs)

    n_chunks = max(1, L // CHUNK)
    while L % n_chunks:
        n_chunks -= 1
    ch = L // n_chunks

    def tm(x):
        return jnp.moveaxis(x, 1, 0).reshape(n_chunks, ch, B, W)

    def chunk_step(h, inp):
        ac, gc = inp

        def step(h, t_in):
            at, gt = t_in
            h = at * h + gt
            return h, h

        return jax.lax.scan(step, h, (ac, gc))

    h, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (tm(a), tm(gated)))
    return jnp.moveaxis(ys.reshape(L, B, W), 0, 1), h


def _rglru_inner(p, x, cfg, conv_tail=None, h0=None):
    B, L, _ = x.shape
    W = cfg.lru_width
    y_branch = jax.nn.gelu(dense(p["in_y"], x).astype(jnp.float32))
    xs = dense(p["in_x"], x)
    xs, new_tail = _conv1d_causal(p["conv_w"], p["conv_b"], xs, conv_tail)
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    h_seq, h = _rglru_scan(p, xs.astype(jnp.float32), h0)
    out = (h_seq * y_branch).astype(x.dtype)
    return dense(p["out"], out), new_tail, h


def rglru_block(p, x, cfg):
    out, _, _ = _rglru_inner(p, x, cfg)
    return out


def init_rglru_state(cfg, batch, dtype):
    return {
        "hr": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv_tail": jnp.zeros((batch, 3, cfg.lru_width), dtype),
    }


def rglru_decode_step(p, x_t, state, cfg):
    out, tail, h = _rglru_inner(p, x_t, cfg, conv_tail=state["conv_tail"],
                                h0=state["hr"])
    return out, {"hr": h, "conv_tail": tail}
