"""Mamba-1 selective state-space block (falcon-mamba architecture).

x -> in_proj -> [x, z]; x -> causal depthwise conv1d -> SiLU ->
selective scan (input-dependent Δ, B, C; diagonal A) -> ·SiLU(z) -> out_proj.

The scan runs as an outer lax.scan over fixed-size chunks (each chunk
rematerialized) with an inner sequential scan carrying (B, d_inner, d_state)
— memory stays O(B·d_inner·d_state·n_chunks) during training. Decode carries
the recurrent state and a (conv-1)-deep input tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_linear

__all__ = ["init_ssm", "ssm_block", "ssm_decode_step", "init_ssm_state"]

CHUNK = 128


def init_ssm(key, cfg):
    D, DI, R, S = cfg.d_model, cfg.d_inner, cfg.dt_rank, cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = cfg.pdt
    A = jnp.tile(jnp.arange(1, S + 1, dtype=jnp.float32)[None, :], (DI, 1))
    return {
        "in_proj": init_linear(ks[0], D, 2 * DI, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, DI), jnp.float32)
                   * (cfg.ssm_conv * DI) ** -0.5).astype(dt),
        "conv_b": jnp.zeros((DI,), dt),
        "x_proj": init_linear(ks[2], DI, R + 2 * S, dt),
        "dt_proj": init_linear(ks[3], R, DI, dt, bias=True),
        "A_log": jnp.log(A),                       # f32 (stability)
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": init_linear(ks[4], DI, D, dt, scale=DI ** -0.5),
    }


def _conv1d_causal(w, b, x, tail=None):
    """Depthwise causal conv. x: (B, L, DI); w: (K, DI); tail: (B, K-1, DI)."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(K))
    return out + b.astype(x.dtype), xp[:, -(K - 1):]


def _selective_scan(u, delta, Bc, Cc, A, D, h0, chunk=CHUNK, remat=True):
    """u: (B, L, DI); delta: (B, L, DI); Bc/Cc: (B, L, S); A: (DI, S).

    h_t = exp(Δ_t A)·h_{t-1} + Δ_t·B_t·u_t ;  y_t = C_t·h_t + D·u_t.
    Returns (y (B, L, DI) f32, h_final (B, DI, S) f32).
    """
    L = u.shape[1]
    n_chunks = max(1, L // chunk)
    while L % n_chunks:
        n_chunks -= 1

    def chunk_step(h, inputs):
        uc, dc, bc, cc = inputs          # (CH, B, ...) time-major

        def step(h, t_in):
            ut, dt_, bt, ct = t_in       # (B, DI), (B, DI), (B, S), (B, S)
            dA = jnp.exp(dt_[..., None] * (-A)[None])       # (B, DI, S)
            dBu = dt_[..., None] * bt[:, None, :] * ut[..., None]
            h = dA * h + dBu
            y = jnp.einsum("bds,bs->bd", h, ct)
            return h, y

        h, ys = jax.lax.scan(step, h, (uc, dc, bc, cc))
        return h, ys

    def tm(x):  # (B, L, ...) -> (n_chunks, CH, B, ...)
        ch = L // n_chunks
        return jnp.moveaxis(x, 1, 0).reshape(n_chunks, ch, *x.shape[:1],
                                             *x.shape[2:])

    chunked = (tm(u), tm(delta), tm(Bc), tm(Cc))
    step_fn = jax.checkpoint(chunk_step) if remat else chunk_step
    h, ys = jax.lax.scan(step_fn, h0, chunked)
    y = jnp.moveaxis(ys.reshape(L, u.shape[0], -1), 0, 1)
    return y + u * D[None, None, :], h


def _ssm_inner(p, x, cfg, conv_tail=None, h0=None):
    B, L, _ = x.shape
    DI, R, S = cfg.d_inner, cfg.dt_rank, cfg.ssm_state
    xz = dense(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_tail = _conv1d_causal(p["conv_w"], p["conv_b"], xs, conv_tail)
    xs = jax.nn.silu(xs.astype(jnp.float32))
    proj = dense(p["x_proj"], xs.astype(x.dtype)).astype(jnp.float32)
    dt_in, Bc, Cc = jnp.split(proj, [R, R + S], axis=-1)
    delta = jax.nn.softplus(
        dt_in @ p["dt_proj"]["w"].astype(jnp.float32)
        + p["dt_proj"]["b"].astype(jnp.float32))
    A = jnp.exp(p["A_log"])
    if h0 is None:
        h0 = jnp.zeros((B, DI, S), jnp.float32)
    y, h = _selective_scan(xs, delta, Bc, Cc, A, p["D"], h0,
                           chunk=cfg.ssm_chunk,
                           remat=cfg.remat_policy != "none")
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return dense(p["out_proj"], y), new_tail, h


def ssm_block(p, x, cfg):
    out, _, _ = _ssm_inner(p, x, cfg)
    return out


def init_ssm_state(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv_tail": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                               dtype),
    }


def ssm_decode_step(p, x_t, state, cfg):
    """x_t: (B, 1, D). Returns (out (B, 1, D), new state)."""
    out, tail, h = _ssm_inner(p, x_t, cfg, conv_tail=state["conv_tail"],
                              h0=state["h"])
    return out, {"h": h, "conv_tail": tail}
