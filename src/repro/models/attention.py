"""GQA attention: flash-style chunked training path + cached decode path.

- Training/prefill: blockwise softmax (running max / normalizer) scanned
  over KV blocks — O(L·Kb) live memory instead of O(L²). Causal, sliding-
  window (SWA / local), and bidirectional (encoder, cross) masks.
- Decode: one query position against a (possibly ring-buffered) KV cache.

Shapes: q (B, L, H, hd); k/v (B, S, Hkv, hd); GQA groups H into Hkv bands.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rope

NEG_INF = -1e30


def init_attn(key, cfg, d_model=None, cross=False):
    from repro.models.layers import init_linear
    d = d_model or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, cfg.pdt,
                          bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.pdt,
                          bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.pdt,
                          bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, cfg.pdt,
                          scale=(cfg.n_heads * hd) ** -0.5),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _block_mask(q_pos, k_pos, causal, window):
    """(Qb, Kb) additive mask."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, m)
    if window and window > 0:
        m = jnp.where(k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, m)
    return m


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=512, block_k=512):
    """Blockwise-softmax attention.

    q: (B, Lq, H, hd); k, v: (B, Lk, Hkv, hd). Returns (B, Lq, H, hd).
    """
    B, Lq, H, hd = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(block_q, Lq)
    while Lq % bq:
        bq -= 1
    bk = min(block_k, Lk)
    while Lk % bk:
        bk -= 1
    nq, nk = Lq // bq, Lk // bk

    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, nq, bq, Hkv, g, hd)
    kf = k.astype(jnp.float32).reshape(B, nk, bk, Hkv, hd)
    vf = v.astype(jnp.float32).reshape(B, nk, bk, Hkv, hd)

    def per_qblock(qi, qblk):
        # qblk: (B, bq, Hkv, g, hd)
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kf, ki, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vf, ki, 1, keepdims=False)
            k_pos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            s = s + _block_mask(q_pos, k_pos, causal, window)[None, None,
                                                             None, :, :]
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, bq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out                                     # (B, Hkv, g, bq, hd)

    outs = jax.lax.map(lambda args: per_qblock(*args),
                       (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    # outs: (nq, B, Hkv, g, bq, hd) -> (B, Lq, H, hd)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    return out.reshape(B, Hkv * g, nq * bq, hd).transpose(0, 2, 1, 3) \
        .astype(q.dtype)


def attention_block(p, x, cfg, *, positions=None, causal=True, window=0,
                    kv_x=None, use_rope=True, return_kv=False):
    """Full attention sub-layer (projections + flash core).

    kv_x: encoder memory for cross-attention (bidirectional, no rope).
    return_kv: also return the (rotated) k/v for prefill cache building.
    """
    B, L, _ = x.shape
    hd = cfg.hd
    src = kv_x if kv_x is not None else x
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["wk"], src), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["wv"], src), cfg.n_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(L)[None, :]
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal and kv_x is None,
                        window=window)
    out = dense(p["wo"], o.reshape(B, L, cfg.n_heads * hd))
    if return_kv:
        return out, k, v
    return out


def kv_to_ring_cache(k, v, S: int):
    """Pack the last S positions of prefill k/v into the decode ring layout.

    decode_attention writes position t at slot t % S; after prefilling L
    tokens, position L-S+i must sit at slot (L-S+i) % S — a roll by L % S.
    """
    L = k.shape[1]
    if L <= S:
        pad = [(0, 0), (0, S - L), (0, 0), (0, 0)]
        return jnp.pad(k, pad), jnp.pad(v, pad)
    kw, vw = k[:, L - S:], v[:, L - S:]
    return (jnp.roll(kw, L % S, axis=1), jnp.roll(vw, L % S, axis=1))


# ---- decode path -----------------------------------------------------------

def decode_attention(p, x_t, cache_k, cache_v, t, cfg, *, window=0,
                     use_rope=True):
    """One-token attention against the KV cache.

    x_t: (B, 1, D); cache_k/v: (B, S, Hkv, hd) (S = max context or window,
    ring-buffered when windowed); t: current absolute position (scalar).
    Returns (out (B, 1, D), new_cache_k, new_cache_v).
    """
    B = x_t.shape[0]
    hd = cfg.hd
    S = cache_k.shape[1]
    q = _split_heads(dense(p["wq"], x_t), cfg.n_heads, hd)
    k = _split_heads(dense(p["wk"], x_t), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x_t), cfg.n_kv_heads, hd)
    pos = jnp.full((B, 1), t)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    slot = t % S if window else jnp.minimum(t, S - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)

    Hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Hkv, g, hd)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    # valid slots: absolute position of slot i is i (linear cache) or within
    # the last `window` writes (ring cache)
    idx = jnp.arange(S)
    if window:
        age = (t % S - idx) % S            # steps since written
        valid = (age < jnp.minimum(t + 1, S))
    else:
        valid = idx <= t
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, vf).astype(x_t.dtype)
    o = o.reshape(B, 1, cfg.n_heads * hd)
    return dense(p["wo"], o), cache_k, cache_v
