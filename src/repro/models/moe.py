"""Mixture-of-Experts: top-k routing with capacity-bounded sort dispatch.

Expert-parallel friendly: expert weight tensors carry E as their leading
axis (sharded over the `model` mesh axis); dispatch is sort-based (no
(T, E, C) one-hot blowup): assignments are argsorted by expert, positions
within each expert computed by searchsorted, tokens over capacity dropped.

Aux load-balancing loss (Switch-style) is returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear

__all__ = ["init_moe", "moe_block"]


def init_moe(key, cfg):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = cfg.pdt

    def expert_stack(k, d_in, d_out, scale):
        return jax.random.normal(k, (E, d_in, d_out), jnp.float32) \
            .astype(dt) * scale

    p = {
        "router": init_linear(ks[0], D, E, jnp.float32),
        "wi": expert_stack(ks[1], D, F, D ** -0.5),
        "wg": expert_stack(ks[2], D, F, D ** -0.5),
        "wo": expert_stack(ks[3], F, D, F ** -0.5),
    }
    if cfg.moe_dense_residual:
        from repro.models.mlp import init_swiglu
        p["dense"] = init_swiglu(ks[4], D, cfg.dense_d_ff or cfg.d_ff, dt)
    return p


def moe_block(p, x, cfg):
    """x: (B, L, D) -> (y (B, L, D), aux_loss scalar)."""
    B, L, D = x.shape
    T = B * L
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                           # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E · Σ_e f_e · P_e
    f = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    P = probs.mean(0)
    aux = E * jnp.sum(f * P)

    C = max(1, int(cfg.capacity_factor * T * k / E))

    flat_e = idx.reshape(-1)                                      # (T·k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert segment
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(T * k) - first
    keep = pos < C
    tok = order // k                                              # token id
    slot_e = jnp.where(keep, sorted_e, E - 1)
    slot_c = jnp.where(keep, pos, C)                              # overflow->C

    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[slot_e, slot_c].set(xt[tok] * keep[:, None].astype(x.dtype))
    buf = buf[:, :C]                                              # (E, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))

    # combine back: each kept assignment gathers its expert output × gate
    y_assign = y_buf[slot_e, jnp.minimum(slot_c, C - 1)]          # (T·k, D)
    w_assign = (gate.reshape(-1)[order] * keep).astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok].add(y_assign * w_assign[:, None])

    if "dense" in p:
        from repro.models.mlp import swiglu
        y = y + swiglu(p["dense"], xt)
    return y.reshape(B, L, D), aux
