"""Word-level modular arithmetic, synthesized from half-word multiplies.

This is the paper's §V-B "emulating arithmetic operations" story adapted to
TPU: the TPU VPU (like AVX-512 in the paper) has no widening multiply and no
carry flags, so a β-bit mulhi is synthesized from four (or three, in the
paper's *modified Shoup*) half-word multiplies. Everything here is pure jnp
on unsigned ints and is shared verbatim by:

  - the pure-JAX HEAAN pipeline (β = 2^64 on CPU, β = 2^32 anywhere), and
  - the Pallas kernel bodies (β = 2^32, TPU-native).

All functions are shape-polymorphic (elementwise) and exact; they are tested
against python-int oracles in tests/test_wordops.py.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "mul_wide", "mulhi", "mullo", "mulhi_approx3",
    "modadd", "modsub", "cond_reduce",
    "shoup_modmul", "shoup_modmul_modified",
    "mont_redc", "mont_modmul",
    "add_wide", "acc3_add_product",
    "barrett_modmul_ref",
]


def _half_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 4


def _full_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def mul_wide(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full β×β→2β product via four half-word multiplies. Returns (hi, lo).

    The partial-product recombination never overflows β bits:
    (2^h-1)^2 + (2^h-1) < 2^(2h).
    """
    dt = a.dtype
    h = _half_bits(dt)
    mask = jnp.array((1 << h) - 1, dt)
    al, ah = a & mask, a >> h
    bl, bh = b & mask, b >> h
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    mid = lh + (ll >> h)            # no overflow (see docstring)
    mid2 = hl + (mid & mask)        # no overflow
    lo = (mid2 << h) | (ll & mask)
    hi = hh + (mid >> h) + (mid2 >> h)
    return hi, lo


def mulhi(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return mul_wide(a, b)[0]


def mullo(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Low β bits of the product — native wrap-around multiply."""
    return a * b


def mulhi_approx3(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Approximate mulhi with THREE half-word muls (paper's modified Shoup).

    Drops the lo·lo partial product (used only for its carry). The result
    underestimates the true mulhi by at most 2, so a Shoup quotient from it
    yields a remainder in [0, 4p) (paper §V-B) — fixed by two conditional
    subtractions downstream.
    """
    dt = a.dtype
    h = _half_bits(dt)
    mask = jnp.array((1 << h) - 1, dt)
    al, ah = a & mask, a >> h
    bl, bh = b & mask, b >> h
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    mid2 = hl + (lh & mask)
    return hh + (lh >> h) + (mid2 >> h)


# ---- modular add/sub -------------------------------------------------------

def modadd(a, b, p):
    """(a + b) mod p for a, b in [0, p). p < β/2 so no wrap."""
    s = a + b
    return jnp.where(s >= p, s - p, s)


def modsub(a, b, p):
    """(a - b) mod p for a, b in [0, p)."""
    d = a + p - b
    return jnp.where(d >= p, d - p, d)


def cond_reduce(x, p, kmax: int):
    """Reduce x < kmax·p to [0, p) by conditional power-of-two subtractions.

    Requires kmax·p < β (caller guarantees via prime-size headroom).
    """
    k = 1
    while k < kmax:
        k *= 2
    k //= 2
    while k >= 1:
        kp = p * jnp.asarray(k, x.dtype)
        x = jnp.where(x >= kp, x - kp, x)
        k //= 2
    return x


# ---- Shoup modular multiplication (paper Algo 2) --------------------------

def shoup_modmul(x, y, y_shoup, p):
    """mod(x·y, p) with precomputed y_shoup = floor(y·β/p). Requires p < β/4.

    3 multiplies total: one synthesized mulhi (4 half-muls) + two native
    wrap-around mullos. Result is exact in [0, p).
    """
    qu = mulhi(x, y_shoup)
    r = x * y - qu * p          # wraps mod β; true value < 2p
    return jnp.where(r >= p, r - p, r)


def shoup_modmul_modified(x, y, y_shoup, p):
    """Paper's modified Shoup: approximate mulhi (3 half-muls), r in [0,4p)."""
    qu = mulhi_approx3(x, y_shoup)
    r = x * y - qu * p          # wraps mod β; true value < 4p
    two_p = p + p
    r = jnp.where(r >= two_p, r - two_p, r)
    return jnp.where(r >= p, r - p, r)


# ---- Montgomery (for unknown×unknown pointwise products) -------------------

def mont_redc(t_hi, t_lo, p, pprime):
    """REDC: (t_hi·β + t_lo)·β⁻¹ mod p, for t < p·β. pprime = -p⁻¹ mod β."""
    m = t_lo * pprime                       # mod β
    mp_hi, _ = mul_wide(m, p)               # m·p ≡ -t_lo (mod β)
    carry = (t_lo != 0).astype(t_lo.dtype)  # (t_lo + mp_lo) carries iff t_lo≠0
    t = t_hi + mp_hi + carry                # < 2p
    return jnp.where(t >= p, t - p, t)


def mont_modmul(a, b, p, pprime, r2):
    """mod(a·b, p) via two REDCs (r2 = β² mod p). Domain-free."""
    hi, lo = mul_wide(a, b)
    t = mont_redc(hi, lo, p, pprime)        # a·b·β⁻¹ mod p
    hi2, lo2 = mul_wide(t, r2)
    return mont_redc(hi2, lo2, p, pprime)   # a·b mod p


# ---- wide accumulation (paper's ADC / GPU-C strategy) ----------------------

def add_wide(acc_hi, acc_lo, hi, lo):
    """(acc_hi, acc_lo) += (hi, lo) with synthesized carry. 2-word accum."""
    new_lo = acc_lo + lo
    carry = (new_lo < lo).astype(acc_lo.dtype)
    new_hi = acc_hi + hi + carry
    return new_hi, new_lo


def acc3_add_product(acc2, acc1, acc0, a, b):
    """3-word accumulator += a·b (paper's GPU-C: ADC chains, no modulo)."""
    hi, lo = mul_wide(a, b)
    new0 = acc0 + lo
    c0 = (new0 < lo).astype(acc0.dtype)
    new1 = acc1 + hi
    c1 = (new1 < hi).astype(acc1.dtype)
    new1b = new1 + c0
    c1b = (new1b < c0).astype(acc1.dtype)
    new2 = acc2 + c1 + c1b
    return new2, new1b, new0


# ---- reference (division-based) -------------------------------------------

def barrett_modmul_ref(a, b, p):
    """Division-based reference modmul for β=2^32 (widens to u64 + rem).

    Exact oracle on CPU; never used in the optimized paths. For β=2^64 use
    the python-int oracles in tests (no 128-bit hardware type exists).
    """
    if a.dtype != jnp.uint32:
        raise NotImplementedError("u64 reference lives in python-int oracles")
    wide = jnp.uint64
    return (a.astype(wide) * b.astype(wide) % p.astype(wide)).astype(a.dtype)
