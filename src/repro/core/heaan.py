"""HEAAN scheme operations: encrypt / decrypt / HE Add / HE Mul / rescale.

HE Mul is the paper's Fig. 2 pipeline:

  region 1 (np₁ primes, P₁ > 2N·q²):
      4× (CRT → NTT)  for ax1, bx1, ax2, bx2
      3× pointwise    d0 = b̂1⊙b̂2,  d2 = â1⊙â2,
                      d1 = (â1+b̂1)⊙(â2+b̂2) − d0 − d2     (eval-domain adds)
      3× (iNTT → iCRT)
  region 2 (np₂ primes, P₂ > 2N·q·Q², key switching):
      1× (CRT → NTT)  for d2
      2× pointwise    against evk (precomputed in eval domain, Shoup)
      2× (iNTT → iCRT), then ÷Q with rounding (bit shift; Q = 2^1200)
  combine:  c3.ax = d1 + (d2·evk.ax)/Q,  c3.bx = d0 + (d2·evk.bx)/Q  (mod q)

Because q and Q are powers of two (faithful HEAAN), mod-q is masking and
÷Q / rescale are rounding bit-shifts — all BigInt division lives in iCRT.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import bigint
from repro.core.cipher import Ciphertext, EvalKey, PublicKey, SecretKey
from repro.core.context import build_global_tables, make_context
from repro.core.encoding import decode, encode
from repro.core.keys import sample_gauss, sample_zo
from repro.core.params import HEParams
from repro.core import rns
from repro.core.rns import DEFAULT, PipelineConfig

__all__ = [
    "encrypt_coeffs", "encrypt_message", "decrypt_coeffs", "decrypt_message",
    "he_add", "he_sub", "he_neg", "he_mul", "rescale", "rescale_poly",
    "he_mod_down", "mod_down_poly", "he_mod_raise", "mod_raise_poly",
    "he_mul_plain", "he_add_plain", "encode_plain",
]


# --------------------------------------------------------------------------
# encryption / decryption
# --------------------------------------------------------------------------

def encrypt_coeffs(pt_limbs: jnp.ndarray, pk: PublicKey, params: HEParams,
                   n_slots: int, seed: int = 1,
                   cfg: PipelineConfig = DEFAULT) -> Ciphertext:
    """Encrypt plaintext coefficients (N, QLimbs) at the top level logQ.

    c.ax = u·pk.ax + e1,  c.bx = u·pk.bx + e0 + t   (mod Q)
    """
    rng = np.random.default_rng(seed)
    g = build_global_tables(params)
    N, beta = params.N, params.beta_bits
    logQ = params.logQ
    qlimbs = params.qlimbs(logQ)
    u = jnp.asarray(sample_zo(rng, N))
    np_enc = params.np_for_bits(params.primes, logQ + params.logN + 3)
    u_ev = rns.to_eval_small(u, np_enc, g, cfg)

    def mul_u(poly_limbs):
        prod = rns.eval_mul(rns.to_eval(poly_limbs, np_enc, g, cfg),
                            u_ev, g, cfg)
        return rns.from_eval(prod, params, qlimbs, g, cfg)

    e1 = rns.small_ints_to_limbs(sample_gauss(rng, N, params.sigma),
                                 qlimbs, beta)
    e0 = rns.small_ints_to_limbs(sample_gauss(rng, N, params.sigma),
                                 qlimbs, beta)
    ax = bigint.mask_bits(bigint.add(mul_u(pk.ax), e1), logQ)
    bx = bigint.mask_bits(
        bigint.add(bigint.add(mul_u(pk.bx), e0), pt_limbs), logQ)
    return Ciphertext(ax=ax, bx=bx, logq=logQ, logp=params.log_delta,
                      n_slots=n_slots)


def encrypt_message(z: np.ndarray, pk: PublicKey, params: HEParams,
                    seed: int = 1, cfg: PipelineConfig = DEFAULT
                    ) -> Ciphertext:
    """Encode a complex message and encrypt it."""
    coeffs = encode(z, params)
    q = 1 << params.logQ
    from repro.nt.residue import ints_to_limb_array
    enc = ints_to_limb_array([int(c) % q for c in coeffs],
                             params.qlimbs(params.logQ), params.beta_bits)
    return encrypt_coeffs(jnp.asarray(enc), pk, params, len(z), seed, cfg)


def decrypt_coeffs(ct: Ciphertext, sk: SecretKey, params: HEParams,
                   cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """t ≈ bx + ax·s (mod q), returned as (N, qlimbs) mod-q limbs."""
    g = build_global_tables(params)
    qlimbs = params.qlimbs(ct.logq)
    np_dec = params.np_for_bits(params.primes, ct.logq + params.logN + 3)
    ax = ct.ax[:, :qlimbs] if ct.ax.shape[1] >= qlimbs else ct.ax
    prod = rns.from_eval(
        rns.eval_mul(rns.to_eval(ax, np_dec, g, cfg),
                     rns.to_eval_small(sk.s, np_dec, g, cfg), g, cfg),
        params, qlimbs, g, cfg)
    return bigint.mask_bits(bigint.add(ct.bx[:, :qlimbs], prod), ct.logq)


def decrypt_message(ct: Ciphertext, sk: SecretKey, params: HEParams,
                    cfg: PipelineConfig = DEFAULT) -> np.ndarray:
    """Decrypt and decode to complex slots (scale 2^ct.logp assumed)."""
    t = decrypt_coeffs(ct, sk, params, cfg)
    ints = rns.limbs_to_centered_ints(np.asarray(t), params.beta_bits,
                                      ct.logq)
    return decode(np.array(ints, dtype=object), ct.n_slots, params,
                  log_delta=ct.logp)


# --------------------------------------------------------------------------
# HE Add / Sub / Neg (paper §III-B: limb adds + mask — q is a power of two)
# --------------------------------------------------------------------------

def he_add(c1: Ciphertext, c2: Ciphertext) -> Ciphertext:
    assert c1.logq == c2.logq and c1.logp == c2.logp
    return Ciphertext(
        ax=bigint.mask_bits(bigint.add(c1.ax, c2.ax), c1.logq),
        bx=bigint.mask_bits(bigint.add(c1.bx, c2.bx), c1.logq),
        logq=c1.logq, logp=c1.logp, n_slots=c1.n_slots)


def he_sub(c1: Ciphertext, c2: Ciphertext) -> Ciphertext:
    assert c1.logq == c2.logq and c1.logp == c2.logp
    return Ciphertext(
        ax=bigint.mask_bits(bigint.sub(c1.ax, c2.ax), c1.logq),
        bx=bigint.mask_bits(bigint.sub(c1.bx, c2.bx), c1.logq),
        logq=c1.logq, logp=c1.logp, n_slots=c1.n_slots)


def he_neg(c: Ciphertext) -> Ciphertext:
    return Ciphertext(ax=bigint.mask_bits(bigint.neg(c.ax), c.logq),
                      bx=bigint.mask_bits(bigint.neg(c.bx), c.logq),
                      logq=c.logq, logp=c.logp, n_slots=c.n_slots)


# --------------------------------------------------------------------------
# HE Mul (paper Fig. 2) and rescale
# --------------------------------------------------------------------------

def he_mul(c1: Ciphertext, c2: Ciphertext, evk: EvalKey, params: HEParams,
           cfg: PipelineConfig = DEFAULT) -> Ciphertext:
    assert c1.logq == c2.logq, "operands must share a modulus (paper §III-B)"
    logq = c1.logq
    ctx = make_context(params, logq)
    g = ctx.tables
    qlimbs = ctx.qlimbs
    np1, np2 = ctx.np1, ctx.np2

    ax1, bx1 = c1.ax[:, :qlimbs], c1.bx[:, :qlimbs]
    ax2, bx2 = c2.ax[:, :qlimbs], c2.bx[:, :qlimbs]

    # ---- region 1 ----------------------------------------------------------
    ea1 = rns.to_eval(ax1, np1, g, cfg)
    eb1 = rns.to_eval(bx1, np1, g, cfg)
    ea2 = rns.to_eval(ax2, np1, g, cfg)
    eb2 = rns.to_eval(bx2, np1, g, cfg)

    d0_ev = rns.eval_mul(eb1, eb2, g, cfg)
    d2_ev = rns.eval_mul(ea1, ea2, g, cfg)
    d1_ev = rns.eval_mul(rns.eval_add(ea1, eb1, g),
                         rns.eval_add(ea2, eb2, g), g, cfg)
    d1_ev = rns.eval_sub(rns.eval_sub(d1_ev, d0_ev, g), d2_ev, g)

    d0 = rns.from_eval(d0_ev, params, qlimbs, g, cfg)
    d1 = rns.from_eval(d1_ev, params, qlimbs, g, cfg)
    d2 = bigint.mask_bits(rns.from_eval(d2_ev, params, qlimbs, g, cfg), logq)

    # ---- region 2 (key switching) ------------------------------------------
    ks_limbs = params.limbs_for_bits(logq + params.logQ) + 1
    e2 = rns.to_eval(d2, np2, g, cfg)
    ks_ax = rns.from_eval(
        rns.eval_mul_shoup(e2, evk.ax_ev[:np2], evk.ax_ev_shoup[:np2],
                           g, cfg), params, ks_limbs, g, cfg)
    ks_bx = rns.from_eval(
        rns.eval_mul_shoup(e2, evk.bx_ev[:np2], evk.bx_ev_shoup[:np2],
                           g, cfg), params, ks_limbs, g, cfg)
    ks_ax = bigint.shift_right_round(ks_ax, params.logQ, out_limbs=qlimbs)
    ks_bx = bigint.shift_right_round(ks_bx, params.logQ, out_limbs=qlimbs)

    # ---- combine ------------------------------------------------------------
    ax3 = bigint.mask_bits(bigint.add(d1, ks_ax), logq)
    bx3 = bigint.mask_bits(bigint.add(d0, ks_bx), logq)
    return Ciphertext(ax=ax3, bx=bx3, logq=logq,
                      logp=c1.logp + c2.logp, n_slots=c1.n_slots)


def encode_plain(z: np.ndarray, params: HEParams, logq: int,
                 log_delta: int | None = None) -> jnp.ndarray:
    """Encode a message into mod-q plaintext limbs (for plain-ct ops)."""
    from repro.nt.residue import ints_to_limb_array
    coeffs = encode(z, params, log_delta=log_delta)
    q = 1 << logq
    return jnp.asarray(ints_to_limb_array(
        [int(c) % q for c in coeffs], params.qlimbs(logq),
        params.beta_bits))


def he_mul_plain(ct: Ciphertext, pt_limbs: jnp.ndarray, params: HEParams,
                 pt_logp: int | None = None,
                 cfg: PipelineConfig = DEFAULT) -> Ciphertext:
    """Ciphertext × plaintext (no key switching — cheap, paper Fig. 2's
    region 1 only). pt is an encoded polynomial at scale 2^pt_logp."""
    g = build_global_tables(params)
    logq = ct.logq
    qlimbs = params.qlimbs(logq)
    pt_logp = params.log_delta if pt_logp is None else pt_logp
    npn = params.np_for_bits(params.primes, 2 * logq + params.logN + 2)
    pt_ev = rns.to_eval(pt_limbs[:, :qlimbs], npn, g, cfg)

    def mul_poly(poly):
        prod = rns.eval_mul(rns.to_eval(poly[:, :qlimbs], npn, g, cfg),
                            pt_ev, g, cfg)
        return bigint.mask_bits(
            rns.from_eval(prod, params, qlimbs, g, cfg), logq)

    return Ciphertext(ax=mul_poly(ct.ax), bx=mul_poly(ct.bx), logq=logq,
                      logp=ct.logp + pt_logp, n_slots=ct.n_slots)


def he_add_plain(ct: Ciphertext, pt_limbs: jnp.ndarray, params: HEParams
                 ) -> Ciphertext:
    """Ciphertext + plaintext (added to bx; scales must match)."""
    qlimbs = params.qlimbs(ct.logq)
    return Ciphertext(
        ax=ct.ax,
        bx=bigint.mask_bits(
            bigint.add(ct.bx[:, :qlimbs], pt_limbs[:, :qlimbs]), ct.logq),
        logq=ct.logq, logp=ct.logp, n_slots=ct.n_slots)


def mod_down_poly(poly: jnp.ndarray, params: HEParams, logq2: int
                  ) -> jnp.ndarray:
    """Mask a mod-q limb polynomial down to modulus 2^logq2 and drop the
    now-zero high limbs. Batch-agnostic ((..., L) leading axes pass
    through), so `repro.hserve.engine` serves it as a batched step."""
    return bigint.mask_bits(poly, logq2)[..., :params.qlimbs(logq2)]


def he_mod_down(ct: Ciphertext, params: HEParams, logq2: int) -> Ciphertext:
    """Switch to a smaller modulus q' | q without touching the scale.

    q and q' are powers of two, so this is pure masking (level alignment
    before HE Add/Mul between ciphertexts of different depths).
    """
    assert 0 < logq2 <= ct.logq
    return Ciphertext(
        ax=mod_down_poly(ct.ax, params, logq2),
        bx=mod_down_poly(ct.bx, params, logq2),
        logq=logq2, logp=ct.logp, n_slots=ct.n_slots)


def mod_raise_poly(poly: jnp.ndarray, params: HEParams, logq: int,
                   logq2: int) -> jnp.ndarray:
    """Lift a mod-q limb polynomial into the larger modulus 2^logq2.

    The coefficient is centered (sign-extended above bit logq−1 from its
    mod-q lift) and re-masked at logq2 — the bootstrap mod-raise: the
    decrypted value becomes t + q·I(X) for small I, which EvalMod later
    removes. Like :func:`rescale_poly`, all indexing is on the trailing
    limb axis so leading batch axes pass through unchanged and the
    batched `repro.hserve.engine` step shares this implementation.
    """
    assert 0 < logq < logq2 <= params.logQ
    beta = params.beta_bits
    L2 = params.qlimbs(logq2)
    pad = L2 - poly.shape[-1]
    if pad > 0:
        poly = jnp.concatenate(
            [poly, jnp.zeros(poly.shape[:-1] + (pad,), poly.dtype)],
            axis=-1)
    else:
        poly = poly[..., :L2]
    sign = (poly[..., (logq - 1) // beta] >> ((logq - 1) % beta)) & 1
    high_fill = jnp.where(sign[..., None].astype(bool),
                          jnp.asarray(~jnp.zeros((), poly.dtype)),
                          jnp.zeros((), poly.dtype))
    idx = jnp.arange(L2)
    w, r = divmod(logq, beta)
    limb_sel = idx >= (w + (1 if r else 0))
    lifted = jnp.where(limb_sel, high_fill, poly)
    if r:
        part = poly[..., w] | jnp.where(
            sign.astype(bool),
            jnp.asarray(((1 << beta) - (1 << r)) & ((1 << beta) - 1),
                        poly.dtype),
            jnp.zeros((), poly.dtype))
        lifted = lifted.at[..., w].set(part)
    return bigint.mask_bits(lifted, logq2)


def he_mod_raise(ct: Ciphertext, params: HEParams, logq2: int
                 ) -> Ciphertext:
    """Raise to a larger modulus q' = 2^logq2 > q (bootstrap step 1).

    The scale is untouched; the underlying plaintext gains a q·I(X)
    error term (|I| small for a fresh-ish ciphertext) that the EvalMod
    stage of the bootstrap pipeline removes homomorphically.
    """
    assert ct.logq < logq2 <= params.logQ
    return Ciphertext(
        ax=mod_raise_poly(ct.ax, params, ct.logq, logq2),
        bx=mod_raise_poly(ct.bx, params, ct.logq, logq2),
        logq=logq2, logp=ct.logp, n_slots=ct.n_slots)


def rescale_poly(poly: jnp.ndarray, params: HEParams, logq: int,
                 dlogp: int) -> jnp.ndarray:
    """Rounding-divide a mod-q limb polynomial by 2^dlogp (paper §III-A).

    The coefficient is centered (sign-extended above bit logq−1 from its
    mod-q lift), rounding-shifted right by dlogp, and re-masked at
    logq' = logq − dlogp. All indexing is on the trailing limb axis, so
    any leading batch axes pass through unchanged — `core.rescale` and
    the batched `repro.hserve.engine` rescale step share this one
    implementation (the bitwise contract between them is by construction).
    """
    logq2 = logq - dlogp
    assert logq2 > 0, "ciphertext exhausted (needs bootstrapping)"
    qlimbs2 = params.qlimbs(logq2)
    beta = params.beta_bits
    L = poly.shape[-1]
    sign = (poly[..., (logq - 1) // beta] >> ((logq - 1) % beta)) & 1
    high_fill = jnp.where(sign[..., None].astype(bool),
                          jnp.asarray(~jnp.zeros((), poly.dtype)),
                          jnp.zeros((), poly.dtype))
    idx = jnp.arange(L)
    w, r = divmod(logq, beta)
    limb_sel = idx >= (w + (1 if r else 0))
    lifted = jnp.where(limb_sel, high_fill, poly)
    if r:
        part = poly[..., w] | jnp.where(
            sign.astype(bool),
            jnp.asarray(((1 << beta) - (1 << r)) & ((1 << beta) - 1),
                        poly.dtype),
            jnp.zeros((), poly.dtype))
        lifted = lifted.at[..., w].set(part)
    out = bigint.shift_right_round(lifted, dlogp)
    return bigint.mask_bits(out, logq2)[..., :max(qlimbs2, 1)]


def rescale(ct: Ciphertext, params: HEParams, dlogp: int | None = None
            ) -> Ciphertext:
    """Divide by the rescaling factor p = 2^logp (paper §III-A).

    Coefficients are centered (mod-q lift), rounding-shifted, and re-masked
    at logq' = logq − dlogp (see :func:`rescale_poly`).
    """
    dlogp = params.logp if dlogp is None else dlogp
    return Ciphertext(
        ax=rescale_poly(ct.ax, params, ct.logq, dlogp),
        bx=rescale_poly(ct.bx, params, ct.logq, dlogp),
        logq=ct.logq - dlogp, logp=ct.logp - dlogp, n_slots=ct.n_slots)
