"""HEAAN parameter sets (paper Table III / Table VI).

Two word-size modes, mirroring the paper's §V:
  - ``beta_bits=64``: the paper's CPU (AVX-512) configuration — qLimbs=19,
    primes in (2^57, 2^60), np≈42/63 at log Q = 1200.
  - ``beta_bits=32``: the paper's GPU configuration, which is also the
    TPU-native choice (no 64-bit widening multiply on TPU VPUs) — qLimbs=38,
    primes in (2^27, 2^30), np≈90/134.

q is a power of two (q = 2^logq, faithful to HEAAN), so mod-q is limb
masking and rescaling is a bit shift. All modular heavy lifting happens on
the RNS primes.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Tuple

from repro.nt.primes import find_ntt_primes


@dataclasses.dataclass(frozen=True)
class HEParams:
    """Static HEAAN parameters. Everything derives from these."""

    logN: int = 16
    logQ: int = 1200
    logp: int = 30          # rescaling factor (paper: 2^30)
    log_delta: int = 30     # encoding scale Δ (paper: 2^30)
    beta_bits: int = 32     # word size β: 32 (TPU/GPU) or 64 (paper CPU)
    sigma: float = 3.2      # error stddev
    h: int = 64             # secret-key Hamming weight (HEAAN default)

    def __post_init__(self):
        assert self.beta_bits in (32, 64)
        assert self.logQ % self.logp == 0, "L = logQ/logp must be integral"

    # ---- sizes -----------------------------------------------------------
    @property
    def N(self) -> int:
        return 1 << self.logN

    @property
    def n_slots_max(self) -> int:
        return self.N // 2

    @property
    def L(self) -> int:
        """Multiplicative depth."""
        return self.logQ // self.logp

    @property
    def Q(self) -> int:
        return 1 << self.logQ

    @property
    def qlimbs_max(self) -> int:
        return self.limbs_for_bits(self.logQ)

    def limbs_for_bits(self, bits: int) -> int:
        return max(1, math.ceil(bits / self.beta_bits))

    def qlimbs(self, logq: int) -> int:
        return self.limbs_for_bits(logq)

    # ---- prime ranges (paper Table VI) ----------------------------------
    @property
    def prime_lo_bits(self) -> int:
        # β=2^32: 2^27 < p < 2^30 (paper GPU; lower bound raised to 2^28 to
        # keep np down — footnote 2 of the paper discusses this trade-off).
        # β=2^64: 2^57 < p < 2^60 (paper CPU/AVX-512 uses 2^57 lower bound).
        return 28 if self.beta_bits == 32 else 57

    @property
    def prime_hi_bits(self) -> int:
        return 30 if self.beta_bits == 32 else 60

    # ---- np derivation (paper §III-B / Table VI) --------------------------
    def region1_target_bits(self, logq: int) -> int:
        """Product of region-1 primes must exceed 2·N·q² (signed conv bound)."""
        return 2 * logq + self.logN + 2

    def region2_target_bits(self, logq: int) -> int:
        """Region 2 multiplies a log q-bit poly with a log Q²-bit evk."""
        return logq + 2 * self.logQ + self.logN + 2

    def np_for_bits(self, primes: Tuple[int, ...], target_bits: int) -> int:
        acc = 0.0
        for j, p in enumerate(primes):
            acc += math.log2(p)
            if acc >= target_bits:
                return j + 1
        raise ValueError(
            f"prime pool too small: {len(primes)} primes cover "
            f"{acc:.0f} bits < {target_bits}"
        )

    @property
    def max_np(self) -> int:
        """Primes needed for region 2 at the top level (logq = logQ)."""
        return self._np_cached(self.region2_target_bits(self.logQ))

    def np_region1(self, logq: int) -> int:
        return self._np_cached(self.region1_target_bits(logq))

    def np_region2(self, logq: int) -> int:
        return self._np_cached(self.region2_target_bits(logq))

    def _np_cached(self, target_bits: int) -> int:
        return self.np_for_bits(self.primes, target_bits)

    # ---- the prime pool ---------------------------------------------------
    @property
    def primes(self) -> Tuple[int, ...]:
        return _prime_pool(
            self.N, self.prime_lo_bits, self.prime_hi_bits, self.beta_bits,
            self.logQ, self.logN,
        )


@lru_cache(maxsize=None)
def _prime_pool(
    n_poly: int, lo_bits: int, hi_bits: int, beta_bits: int,
    logQ: int, logN: int,
) -> Tuple[int, ...]:
    """Largest-first pool of NTT primes, big enough for region 2 at logQ."""
    # Worst case bits needed: region2 target at top level.
    target = 3 * logQ + logN + 2
    # Conservative count using the lower bound on prime size.
    count = math.ceil(target / lo_bits) + 2
    return find_ntt_primes(n_poly, count, lo_bits, hi_bits)


# Canonical parameter presets ------------------------------------------------

def paper_params(beta_bits: int = 32) -> HEParams:
    """The paper's representative parameters (Table III/VI)."""
    return HEParams(logN=16, logQ=1200, logp=30, log_delta=30,
                    beta_bits=beta_bits)


def test_params(logN: int = 5, beta_bits: int = 32, logQ: int = 120,
                logp: int = 24) -> HEParams:
    """Small parameters for fast CPU tests (NOT secure)."""
    return HEParams(logN=logN, logQ=logQ, logp=logp, log_delta=logp,
                    beta_bits=beta_bits, h=min(64, (1 << logN) // 2))
