"""Galois rotations and conjugation (HEAAN leftRotate / conjugate).

Slot rotation by r steps is the ring automorphism σ_k : t(X) → t(X^k),
k = 5^r mod 2N (conjugation: k = 2N−1). On coefficients, index i maps to
i·k mod 2N with a sign flip when the image lands in [N, 2N) — a static
permutation + negation, precomputed host-side per k.

A rotated ciphertext decrypts under σ_k(s), so a key-switch with the
rotation key rk_k = (a, −a·s + e + Q·σ_k(s)) mod Q² follows — the SAME
region-2 machinery as HE Mul (paper Fig. 2); rotations therefore ride the
exact pipeline this framework accelerates.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

import jax.numpy as jnp

from repro.core import bigint, rns
from repro.core.cipher import Ciphertext, EvalKey, SecretKey
from repro.core.context import build_global_tables, make_context, _shoup_vec
from repro.core.params import HEParams
from repro.core.rns import DEFAULT, PipelineConfig

__all__ = ["rot_keygen", "conj_keygen", "he_rotate", "he_conjugate",
           "automorphism_poly", "automorphism_maps", "rotation_k",
           "conjugation_k"]


def rotation_k(params: HEParams, r: int) -> int:
    """Galois element for a left-rotation by r slots."""
    return pow(5, r, 2 * params.N)


def conjugation_k(params: HEParams) -> int:
    """Galois element σ₋₁ for slot-wise complex conjugation (k = 2N−1)."""
    return 2 * params.N - 1


@lru_cache(maxsize=None)
def _auto_maps(N: int, k: int):
    """(dest index, negate?) for coefficient i -> i·k mod 2N."""
    idx = (np.arange(N, dtype=np.int64) * k) % (2 * N)
    neg = idx >= N
    return idx % N, neg


def automorphism_maps(N: int, k: int):
    """Host-side σ_k coefficient maps: (dest indices, negate mask).

    Public so batched engines (repro.hserve) can bake the permutation
    into a traced step; each rotation key-switches with the SAME region-2
    machinery as HE Mul, so the maps are the only rotate-specific state.
    """
    return _auto_maps(N, k)


def automorphism_poly(poly: jnp.ndarray, params: HEParams, k: int,
                      logq: int) -> jnp.ndarray:
    """Apply σ_k to a mod-q limb polynomial (N, L)."""
    dest, neg = _auto_maps(params.N, k)
    out = jnp.zeros_like(poly)
    negated = bigint.mask_bits(bigint.neg(poly), logq)
    src = jnp.where(jnp.asarray(neg)[:, None], negated, poly)
    return out.at[jnp.asarray(dest)].set(src)


def _galois_key(params: HEParams, s: np.ndarray, k: int, seed: int,
                cfg: PipelineConfig) -> EvalKey:
    """Key-switching key from σ_k(s) to s over Q² (same shape as evk)."""
    from repro.core.keys import sample_gauss, sample_uniform_limbs
    g = build_global_tables(params)
    N, beta, logQ = params.N, params.beta_bits, params.logQ
    q2limbs = params.limbs_for_bits(2 * logQ)
    rng = np.random.default_rng(seed)

    # σ_k(s) on the small-int secret (sign tracked directly)
    dest, neg = _auto_maps(N, k)
    s_rot = np.zeros_like(s)
    s_rot[dest] = np.where(neg, -s.astype(np.int64), s.astype(np.int64))

    ax = sample_uniform_limbs(rng, N, 2 * logQ, q2limbs, beta)
    np_kk = params.np_for_bits(params.primes, 2 * logQ + params.logN + 3)
    as_prod = rns.from_eval(
        rns.eval_mul(rns.to_eval(ax, np_kk, g, cfg),
                     rns.to_eval_small(jnp.asarray(s), np_kk, g, cfg),
                     g, cfg), params, q2limbs, g, cfg)
    e = rns.small_ints_to_limbs(sample_gauss(rng, N, params.sigma),
                                q2limbs, beta)
    srot_limbs = rns.small_ints_to_limbs(s_rot, q2limbs, beta)
    q_srot = bigint.shift_left_bits(srot_limbs, logQ)
    bx = bigint.mask_bits(
        bigint.add(bigint.add(bigint.neg(as_prod), e), q_srot), 2 * logQ)

    np2_max = params.np_region2(logQ)
    ax_ev = rns.to_eval(ax, np2_max, g, cfg)
    bx_ev = rns.to_eval(bx, np2_max, g, cfg)
    primes_np = np.asarray(g.primes[:np2_max])
    return EvalKey(
        ax_ev=ax_ev,
        ax_ev_shoup=jnp.asarray(_shoup_vec(np.asarray(ax_ev), primes_np,
                                           beta)),
        bx_ev=bx_ev,
        bx_ev_shoup=jnp.asarray(_shoup_vec(np.asarray(bx_ev), primes_np,
                                           beta)))


def rot_keygen(params: HEParams, sk: SecretKey, r: int, seed: int = 100,
               cfg: PipelineConfig = DEFAULT) -> EvalKey:
    """Rotation key for a left-rotation by r slots."""
    return _galois_key(params, np.asarray(sk.s), rotation_k(params, r),
                       seed + r, cfg)


def conj_keygen(params: HEParams, sk: SecretKey, seed: int = 200,
                cfg: PipelineConfig = DEFAULT) -> EvalKey:
    return _galois_key(params, np.asarray(sk.s), 2 * params.N - 1, seed,
                       cfg)


def _apply_galois(ct: Ciphertext, k: int, key: EvalKey, params: HEParams,
                  cfg: PipelineConfig) -> Ciphertext:
    logq = ct.logq
    ctx = make_context(params, logq)
    g = ctx.tables
    qlimbs = ctx.qlimbs
    np2 = ctx.np2
    ks_limbs = params.limbs_for_bits(logq + params.logQ) + 1

    ax_r = automorphism_poly(ct.ax[:, :qlimbs], params, k, logq)
    bx_r = automorphism_poly(ct.bx[:, :qlimbs], params, k, logq)

    e2 = rns.to_eval(ax_r, np2, g, cfg)
    ks_ax = rns.from_eval(
        rns.eval_mul_shoup(e2, key.ax_ev[:np2], key.ax_ev_shoup[:np2],
                           g, cfg), params, ks_limbs, g, cfg)
    ks_bx = rns.from_eval(
        rns.eval_mul_shoup(e2, key.bx_ev[:np2], key.bx_ev_shoup[:np2],
                           g, cfg), params, ks_limbs, g, cfg)
    ks_ax = bigint.shift_right_round(ks_ax, params.logQ, out_limbs=qlimbs)
    ks_bx = bigint.shift_right_round(ks_bx, params.logQ, out_limbs=qlimbs)

    return Ciphertext(
        ax=bigint.mask_bits(ks_ax, logq),
        bx=bigint.mask_bits(bigint.add(bx_r, ks_bx), logq),
        logq=logq, logp=ct.logp, n_slots=ct.n_slots)


def he_rotate(ct: Ciphertext, r: int, rk: EvalKey, params: HEParams,
              cfg: PipelineConfig = DEFAULT) -> Ciphertext:
    """Rotate message slots left by r (rk must be keyed for the same r)."""
    return _apply_galois(ct, rotation_k(params, r), rk, params, cfg)


def he_conjugate(ct: Ciphertext, ck: EvalKey, params: HEParams,
                 cfg: PipelineConfig = DEFAULT) -> Ciphertext:
    """Complex-conjugate every slot."""
    return _apply_galois(ct, 2 * params.N - 1, ck, params, cfg)
