"""Fixed-width BigInt arithmetic on little-endian limb arrays.

A BigInt is a (..., L) array of β-bit unsigned limbs, value = Σ a_k·β^k,
interpreted either as unsigned or as two's complement at width β·L (the
iCRT center-lift and the region-2 rounding shift need signed semantics).
Because HEAAN's q is a power of two, mod-q is :func:`mask_bits` and
rescaling is :func:`shift_right_round` — no BigInt division anywhere.

Carry/borrow propagation uses lax.scan over the limb axis (L ≤ ~130).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.wordops import mul_wide

__all__ = [
    "add", "sub", "neg", "mask_bits", "compare_ge",
    "shift_right_round", "shift_left_bits", "mul_word",
    "sign_bit", "select",
]


def _scan_limbs(f, a, b, init):
    """Scan f over the last (limb) axis of a and b with a carry."""
    a_t = jnp.moveaxis(a, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)
    carry, out = jax.lax.scan(f, init, (a_t, b_t))
    return jnp.moveaxis(out, 0, -1)


def add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a + b) mod β^L, limb-wise with carry."""
    zero = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]),
                     dtype=a.dtype)

    def step(carry, ab):
        x, y = ab
        s = x + y
        c1 = (s < x).astype(a.dtype)
        s2 = s + carry
        c2 = (s2 < carry).astype(a.dtype)
        return c1 | c2, s2

    return _scan_limbs(step, a, jnp.broadcast_to(b, a.shape), zero)


def sub(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a - b) mod β^L (two's complement on underflow)."""
    zero = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]),
                     dtype=a.dtype)

    def step(borrow, ab):
        x, y = ab
        d = x - y
        b1 = (x < y).astype(a.dtype)
        d2 = d - borrow
        b2 = (d < borrow).astype(a.dtype)
        return b1 | b2, d2

    return _scan_limbs(step, a, jnp.broadcast_to(b, a.shape), zero)


def neg(a: jnp.ndarray) -> jnp.ndarray:
    """Two's complement negation mod β^L."""
    return add(~a, jnp.zeros_like(a).at[..., 0].set(1))


def sign_bit(a: jnp.ndarray) -> jnp.ndarray:
    """Top bit of the top limb (two's complement sign)."""
    bits = jnp.dtype(a.dtype).itemsize * 8
    return (a[..., -1] >> (bits - 1)).astype(jnp.bool_)


def mask_bits(a: jnp.ndarray, bits: int) -> jnp.ndarray:
    """a mod 2^bits (zero limbs/bits above). Keeps the limb width."""
    beta = jnp.dtype(a.dtype).itemsize * 8
    L = a.shape[-1]
    w, r = divmod(bits, beta)
    if w >= L:
        return a
    idx = jnp.arange(L)
    full = idx < w
    partial = idx == w
    part_mask = jnp.asarray((1 << r) - 1 if r else 0, a.dtype)
    limb_mask = jnp.where(full, jnp.asarray(~jnp.zeros((), a.dtype)),
                          jnp.where(partial, part_mask,
                                    jnp.zeros((), a.dtype)))
    return a & limb_mask


def compare_ge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Unsigned a >= b, comparing from the most significant limb."""
    b = jnp.broadcast_to(b, a.shape)

    def step(state, ab):
        x, y = ab
        decided, ge = state
        new_ge = jnp.where(decided, ge, x > y)
        new_decided = decided | (x != y)
        return (new_decided, new_ge), 0

    init = (jnp.zeros(a.shape[:-1], jnp.bool_),
            jnp.ones(a.shape[:-1], jnp.bool_))   # equal -> ge
    a_t = jnp.flip(jnp.moveaxis(a, -1, 0), 0)
    b_t = jnp.flip(jnp.moveaxis(b, -1, 0), 0)
    (decided, ge), _ = jax.lax.scan(step, init, (a_t, b_t))
    return jnp.where(decided, ge, True)


def shift_left_bits(a: jnp.ndarray, s: int) -> jnp.ndarray:
    """(a << s) mod β^L; s is a static python int."""
    beta = jnp.dtype(a.dtype).itemsize * 8
    w, r = divmod(s, beta)
    L = a.shape[-1]
    if w:
        pad = jnp.zeros(a.shape[:-1] + (w,), a.dtype)
        a = jnp.concatenate([pad, a[..., : L - w]], axis=-1)
    if r:
        lo = a << r
        hi_in = jnp.concatenate(
            [jnp.zeros(a.shape[:-1] + (1,), a.dtype), a[..., :-1]], axis=-1)
        a = lo | (hi_in >> (beta - r))
    return a


def shift_right_round(a: jnp.ndarray, s: int, *, arithmetic: bool = True,
                      out_limbs: int | None = None) -> jnp.ndarray:
    """round(a / 2^s) with round-half-up; a is two's complement at width β·L.

    Used for the region-2 key-switch shift (÷Q, paper Fig. 2) and for
    rescaling (÷p). s is static. Result width is out_limbs (default L).
    """
    beta = jnp.dtype(a.dtype).itemsize * 8
    L = a.shape[-1]
    # +2^(s-1) for rounding (two's complement safe).
    if s > 0:
        half = jnp.zeros_like(a)
        w_h, r_h = divmod(s - 1, beta)
        if w_h < L:
            half = half.at[..., w_h].set(jnp.asarray(1 << r_h, a.dtype))
        a = add(a, half)
    w, r = divmod(s, beta)
    sign = sign_bit(a)
    ext = jnp.where(sign[..., None], jnp.asarray(~jnp.zeros((), a.dtype)),
                    jnp.zeros((), a.dtype)) if arithmetic else jnp.zeros(
        a.shape[:-1] + (1,), a.dtype)
    ext = jnp.broadcast_to(ext, a.shape[:-1] + (max(w, 1) + 1,))
    a_ext = jnp.concatenate([a, ext.astype(a.dtype)], axis=-1)
    shifted = a_ext[..., w: w + L]
    if r:
        hi_next = a_ext[..., w + 1: w + 1 + L]
        shifted = (shifted >> r) | (hi_next << (beta - r))
    if out_limbs is not None and out_limbs != L:
        if out_limbs < L:
            shifted = shifted[..., :out_limbs]
        else:
            sign2 = sign_bit(shifted)
            pad = jnp.where(
                sign2[..., None], jnp.asarray(~jnp.zeros((), a.dtype)),
                jnp.zeros((), a.dtype))
            pad = jnp.broadcast_to(pad, shifted.shape[:-1]
                                   + (out_limbs - L,)).astype(a.dtype)
            shifted = jnp.concatenate([shifted, pad], axis=-1)
    return shifted


def mul_word(a: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """(a · s) mod β^L for a word-sized scalar s (broadcast over batch)."""
    s = jnp.asarray(s, a.dtype)
    s_b = jnp.broadcast_to(s[..., None], a.shape)

    def step(carry, ab):
        x, y = ab
        hi, lo = mul_wide(x, y)
        out = lo + carry
        c = (out < lo).astype(a.dtype)
        return hi + c, out             # hi ≤ β-2, so hi + c cannot wrap

    return _scan_limbs(step, a, s_b, jnp.zeros(a.shape[:-1], a.dtype))


def select(cond: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise limb select: cond is (...,) bool, a/b are (..., L)."""
    return jnp.where(cond[..., None], a, b)
