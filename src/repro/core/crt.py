"""CRT (paper Algo 1) and iCRT (Algo 5 → reordered Algo 6).

CRT strategies (paper Table VIII ladder, all selectable):
  - "shoup"  : per-term Shoup modmul, modulo every iteration (≈ GPU-Mod1,
               but division-free).
  - "mod2"/"mod4" : raw wide products accumulated, hardware remainder every
               2/4 iterations (GPU-Mod2/GPU-Mod4; β=2³² only — the wide
               accumulator is u64).
  - "acc3"   : three-word accumulation with synthesized ADC, single fold at
               the end through Shoup multiplies by β^k mod p (GPU-C; the
               paper's CPU path does the same with accum spanning ≤3 limbs).
  - "matmul" : the whole stage-1 sum as two integer matrix-matrix multiplies
               on 16-bit input halves (β=2³² only). This is the loop-
               reordering insight of §V-A applied to CRT itself — XLA gets a
               dense integer GEMM instead of a scan. Beyond-paper.

iCRT strategies:
  - "naive"  : Algo 5 — scalar×BigInt accumulation per coefficient
               (N-degree parallelism only). Kept as the measurable baseline.
  - "acc3"   : Algo 6 loop-reordered with 3-word accumulators.
  - "matmul" : Algo 6 realized as integer GEMMs on 16-bit table halves
               (β=2³² only) — N·PLimbs parallelism handed to the MXU/BLAS.

All paths are exact; tests cross-check every strategy against python-int
oracles and against each other.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bigint
from repro.core.context import IcrtTables
from repro.core.wordops import (
    acc3_add_product, cond_reduce, modadd, mul_wide, shoup_modmul,
)

__all__ = ["crt", "icrt", "finalize_accum"]


# --------------------------------------------------------------------------
# CRT: (N, K) BigInt limbs -> (np, N) residues
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("strategy",))
def crt(x: jnp.ndarray, tb: jnp.ndarray, tb_shoup: jnp.ndarray,
        primes: jnp.ndarray, *, strategy: str = "matmul") -> jnp.ndarray:
    """mod(Σ_k x[n,k]·β^k, p_j) for every coefficient n and prime j.

    x: (N, K) limbs; tb/tb_shoup: (np, K) = β^k mod p_j; primes: (np,).
    Returns (np, N).
    """
    if x.dtype == jnp.uint64 and strategy in ("matmul", "mod2", "mod4"):
        strategy = "acc3"   # wide accumulators unavailable at β=2^64
    npn, K = tb.shape
    N = x.shape[0]
    assert x.shape[1] == K

    if strategy == "matmul":
        mask16 = jnp.uint64(0xFFFF)
        xl = (x.astype(jnp.uint64) & mask16)
        xh = (x.astype(jnp.uint64) >> jnp.uint64(16))
        tbT = tb.astype(jnp.uint64).T                      # (K, np)
        s_lo = xl @ tbT                                    # < K·2^46 exact
        s_hi = xh @ tbT
        p64 = primes.astype(jnp.uint64)[None, :]
        v = (s_lo + ((s_hi % p64) << jnp.uint64(16))) % p64
        return v.astype(x.dtype).T

    if strategy == "shoup":
        def step(acc, k):
            xk = jax.lax.dynamic_index_in_dim(x, k, 1, keepdims=False)
            term = shoup_modmul(xk[None, :], tb[:, k, None],
                                tb_shoup[:, k, None], primes[:, None])
            return modadd(acc, term, primes[:, None]), None
        acc0 = jnp.zeros((npn, N), x.dtype)
        acc, _ = jax.lax.scan(step, acc0, jnp.arange(K))
        return acc

    if strategy in ("mod2", "mod4"):
        every = int(strategy[3:])
        p64 = primes.astype(jnp.uint64)[:, None]
        acc = jnp.zeros((npn, N), jnp.uint64)
        for k in range(K):                      # K ≤ ~76: unrolled in trace
            prod = tb.astype(jnp.uint64)[:, k, None] * \
                x.astype(jnp.uint64)[None, :, k]
            acc = acc + prod
            if (k + 1) % every == 0:
                acc = acc % p64
        return (acc % p64).astype(x.dtype)

    if strategy == "acc3":
        zeros = jnp.zeros((npn, N), x.dtype)

        def step(carry, k):
            a2, a1, a0 = carry
            xk = jax.lax.dynamic_index_in_dim(x, k, 1, keepdims=False)
            a2, a1, a0 = acc3_add_product(
                a2, a1, a0, jnp.broadcast_to(xk[None, :], (npn, N)),
                jnp.broadcast_to(tb[:, k, None], (npn, N)))
            return (a2, a1, a0), None

        (a2, a1, a0), _ = jax.lax.scan(
            step, (zeros, zeros, zeros), jnp.arange(K))
        return _fold3(a0, a1, a2, tb, tb_shoup, primes)

    raise ValueError(f"unknown CRT strategy {strategy!r}")


def _fold3(a0, a1, a2, tb, tb_shoup, primes):
    """Reduce a 3-word accumulator via Shoup multiplies by β^k mod p.

    This is the paper's 'Shoup's ModMul on accum spanning up to 3 limbs,
    using precomputed Y_shoup on Y = {1, β, β²}' (§IV).
    """
    p = primes[:, None]
    # Y = 1 (= β^0 mod p): Shoup reduces an arbitrary word mod p in one shot.
    r0 = shoup_modmul(a0, tb[:, 0, None], tb_shoup[:, 0, None], p)
    r1 = shoup_modmul(a1, tb[:, 1, None], tb_shoup[:, 1, None], p)
    r2 = shoup_modmul(a2, tb[:, 2, None], tb_shoup[:, 2, None], p)
    return cond_reduce(r0 + r1 + r2, p, 4)


# --------------------------------------------------------------------------
# iCRT: (np, N) residues -> (N, out_limbs) two's-complement centered BigInt
# --------------------------------------------------------------------------

def icrt(r: jnp.ndarray, tabs: IcrtTables, primes: jnp.ndarray,
         inv_P: jnp.ndarray, inv_P_shoup: jnp.ndarray,
         pdivp: jnp.ndarray, P_limbs: jnp.ndarray, P_half: jnp.ndarray,
         p_inv_f64: jnp.ndarray, out_limbs: int,
         *, strategy: str = "matmul") -> jnp.ndarray:
    """Reconstruct centered BigInts from RNS residues (paper Algo 5/6).

    r: (np, N). Returns (N, out_limbs) two's-complement (low limbs of the
    centered value — callers mask to mod-q or shift for key-switching).
    """
    if r.dtype == jnp.uint64 and strategy == "matmul":
        strategy = "acc3"
    return _icrt_jit(r, primes, inv_P, inv_P_shoup, pdivp, P_limbs, P_half,
                     p_inv_f64, out_limbs=out_limbs,
                     accum_limbs=tabs.accum_limbs, strategy=strategy)


@partial(jax.jit,
         static_argnames=("out_limbs", "accum_limbs", "strategy"))
def _icrt_jit(r, primes, inv_P, inv_P_shoup, pdivp, P_limbs, P_half,
              p_inv_f64, *, out_limbs: int, accum_limbs: int, strategy: str):
    npn, N = r.shape
    dt = r.dtype
    beta = jnp.dtype(dt).itemsize * 8

    # (1) Hadamard: temp[j,n] = mod(r[j,n]·(P/p_j)⁻¹, p_j)   [Shoup]
    temp = shoup_modmul(r, inv_P[:, None], inv_P_shoup[:, None],
                        primes[:, None])

    # (2) accum[n] = Σ_j temp[j,n]·(P/p_j)  — strategy-dependent
    if strategy == "matmul":
        accum = _accum_matmul_u32(temp, pdivp, accum_limbs)
    elif strategy == "acc3":
        accum = _accum_acc3(temp, pdivp, accum_limbs)
    elif strategy == "naive":
        accum = _accum_naive(temp, pdivp, accum_limbs)
    else:
        raise ValueError(f"unknown iCRT strategy {strategy!r}")

    # (3) mod P via the float-quotient trick: accum/P = Σ_j temp_j/p_j
    # exactly; f64 error ≪ 1, so ±1 conditional corrections make it exact.
    s_f = temp.astype(jnp.float64).T @ p_inv_f64     # (N,)
    s = jnp.floor(s_f).astype(dt)
    return finalize_accum(accum, s, P_limbs, P_half, out_limbs)


def finalize_accum(accum, s, P_limbs, P_half, out_limbs: int):
    """accum − s·P with ±1 quotient corrections, center-lift, truncate.

    Shared by the pure-JAX iCRT and the Pallas iCRT tail. `s` may come from
    the f64 quotient (CPU) or the fixed-point integer quotient (TPU kernel);
    both are exact after the correction ladder.
    """
    N, accum_limbs = accum.shape
    sp = bigint.mul_word(jnp.broadcast_to(P_limbs, (N, accum_limbs)), s)
    red = bigint.sub(accum, sp)
    for _ in range(2):   # s may be off by one in either direction
        neg = bigint.sign_bit(red)
        red = bigint.select(neg, bigint.add(red, P_limbs), red)
        too_big = bigint.compare_ge(red, P_limbs) & ~neg
        red = bigint.select(too_big, bigint.sub(red, P_limbs), red)

    # center-lift: v >= P/2  ⇒  v -= P  (two's complement wrap is fine)
    high = bigint.compare_ge(red, P_half)
    red = bigint.select(high, bigint.sub(red, P_limbs), red)

    return red[:, :out_limbs] if out_limbs <= accum_limbs else _sext(
        red, out_limbs)


def _sext(a, out_limbs):
    sign = bigint.sign_bit(a)
    pad = jnp.where(sign[..., None], jnp.asarray(~jnp.zeros((), a.dtype)),
                    jnp.zeros((), a.dtype))
    pad = jnp.broadcast_to(pad, a.shape[:-1] + (out_limbs - a.shape[-1],))
    return jnp.concatenate([a, pad.astype(a.dtype)], axis=-1)


def _accum_matmul_u32(temp, pdivp, accum_limbs):
    """Loop-reordered Algo 6 as two u64 GEMMs on 16-bit table halves."""
    npn, N = temp.shape
    PL = pdivp.shape[1]
    mask16 = jnp.uint64(0xFFFF)
    t64 = temp.astype(jnp.uint64).T                       # (N, np)
    pl = pdivp.astype(jnp.uint64) & mask16                # (np, PL)
    ph = pdivp.astype(jnp.uint64) >> jnp.uint64(16)
    s_lo = t64 @ pl                                       # (N, PL) < 2^54
    s_hi = t64 @ ph
    # value_k = s_lo + s_hi·2^16 contributes to limbs k and k+1.
    m32 = jnp.uint64(0xFFFFFFFF)
    lo_part = (s_lo & m32) + ((s_hi << jnp.uint64(16)) & m32)   # < 2^33
    hi_part = (s_lo >> jnp.uint64(32)) + (s_hi >> jnp.uint64(16))
    acc = jnp.zeros((N, accum_limbs), jnp.uint64)
    acc = acc.at[:, :PL].add(lo_part)
    acc = acc.at[:, 1: PL + 1].add(hi_part)

    def carry_step(carry, col):
        v = col + carry
        return v >> jnp.uint64(32), (v & m32).astype(jnp.uint32)

    _, limbs = jax.lax.scan(carry_step, jnp.zeros((N,), jnp.uint64),
                            jnp.moveaxis(acc, -1, 0))
    return jnp.moveaxis(limbs, 0, -1)


def _accum_acc3(temp, pdivp, accum_limbs):
    """Algo 6 with per-(n,k) 3-word accumulators (paper's GPU-C flavour)."""
    npn, N = temp.shape
    PL = pdivp.shape[1]
    dt = temp.dtype
    zeros = jnp.zeros((N, PL), dt)

    def step(carry, j):
        a2, a1, a0 = carry
        tj = jax.lax.dynamic_index_in_dim(temp, j, 0, keepdims=False)
        pj = jax.lax.dynamic_index_in_dim(pdivp, j, 0, keepdims=False)
        a2, a1, a0 = acc3_add_product(
            a2, a1, a0,
            jnp.broadcast_to(tj[:, None], (N, PL)),
            jnp.broadcast_to(pj[None, :], (N, PL)))
        return (a2, a1, a0), None

    (a2, a1, a0), _ = jax.lax.scan(step, (zeros, zeros, zeros),
                                   jnp.arange(npn))
    # assemble Σ_k (a0 + a1β + a2β²)_k · β^k with three shifted adds
    acc = jnp.zeros((N, accum_limbs), dt)
    acc = bigint.add(acc, _placed(a0, 0, accum_limbs))
    acc = bigint.add(acc, _placed(a1, 1, accum_limbs))
    acc = bigint.add(acc, _placed(a2, 2, accum_limbs))
    return acc


def _accum_naive(temp, pdivp, accum_limbs):
    """Paper Algo 5: scan over primes, BigInt accumulate (N-parallel only).

    Deliberately the slow baseline: each step is a word×BigInt multiply and
    a full-width BigInt add per coefficient.
    """
    npn, N = temp.shape
    PL = pdivp.shape[1]
    dt = temp.dtype

    def step(acc, j):
        tj = jax.lax.dynamic_index_in_dim(temp, j, 0, keepdims=False)
        pj = jax.lax.dynamic_index_in_dim(pdivp, j, 0, keepdims=False)
        row = _placed(jnp.zeros((N, PL), dt) + pj[None, :], 0, accum_limbs)
        prod = bigint.mul_word(row, tj)
        return bigint.add(acc, prod), None

    acc0 = jnp.zeros((N, accum_limbs), dt)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(npn))
    return acc


def _placed(words, offset, accum_limbs):
    """(N, PL) words -> (N, accum_limbs) BigInt shifted by `offset` limbs.

    Words beyond the accumulator width are provably zero (each non-negative
    component is bounded by the total Σ < β^accum_limbs) and are dropped.
    """
    N, PL = words.shape
    keep = min(PL, accum_limbs - offset)
    out = jnp.zeros((N, accum_limbs), words.dtype)
    return out.at[:, offset: offset + keep].set(words[:, :keep])
