"""Key generation and randomness sampling (HEAAN distributions, §III-A).

Sampling is host-side numpy (client-side operations, deterministic per
seed); the polynomial products inside keygen run through the same JAX RNS
pipeline used for HE Mul (dogfooding the paper's machinery).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import bigint
from repro.core.cipher import EvalKey, PublicKey, SecretKey
from repro.core.context import build_global_tables
from repro.core.params import HEParams
from repro.core import rns
from repro.core.rns import PipelineConfig, DEFAULT

__all__ = [
    "sample_hwt", "sample_zo", "sample_gauss", "sample_uniform_limbs",
    "keygen",
]


def sample_hwt(rng: np.random.Generator, N: int, h: int) -> np.ndarray:
    """Ternary secret with exactly h nonzeros (HEAAN HWT distribution)."""
    s = np.zeros(N, dtype=np.int8)
    idx = rng.choice(N, size=h, replace=False)
    s[idx] = rng.choice(np.array([-1, 1], dtype=np.int8), size=h)
    return s


def sample_zo(rng: np.random.Generator, N: int, prob: float = 0.5
              ) -> np.ndarray:
    """ZO(prob): ±1 each with prob/2, else 0 (paper: u's distribution)."""
    r = rng.random(N)
    return (np.where(r < prob / 2, -1,
                     np.where(r < prob, 1, 0))).astype(np.int8)


def sample_gauss(rng: np.random.Generator, N: int, sigma: float
                 ) -> np.ndarray:
    """Rounded discrete Gaussian, σ = 3.2 (paper §III-A)."""
    return np.round(rng.normal(0.0, sigma, size=N)).astype(np.int64)


def sample_uniform_limbs(rng: np.random.Generator, N: int, bits: int,
                         n_limbs: int, beta_bits: int) -> jnp.ndarray:
    """Uniform in [0, 2^bits): random limbs + mask (q is a power of two)."""
    if beta_bits == 32:
        raw = rng.integers(0, 1 << 32, size=(N, n_limbs), dtype=np.uint64)
        arr = jnp.asarray(raw.astype(np.uint32))
    else:
        raw = (rng.integers(0, 1 << 62, size=(N, n_limbs), dtype=np.uint64)
               << np.uint64(2)) | rng.integers(
                   0, 4, size=(N, n_limbs), dtype=np.uint64)
        arr = jnp.asarray(raw)
    return bigint.mask_bits(arr, bits)


def keygen(params: HEParams, seed: int = 0,
           cfg: PipelineConfig = DEFAULT
           ) -> tuple[SecretKey, PublicKey, EvalKey]:
    """Generate (sk, pk, evk).

    pk:  ax ~ U(R_Q),  bx = -ax·s + e  (mod Q)
    evk: ax ~ U(R_Q²), bx = -ax·s + e + Q·s²  (mod Q²)
    """
    rng = np.random.default_rng(seed)
    g = build_global_tables(params)
    N = params.N
    beta = params.beta_bits
    logQ = params.logQ
    qlimbs = params.qlimbs(logQ)
    q2limbs = params.limbs_for_bits(2 * logQ)

    s = sample_hwt(rng, N, params.h)
    s_j = jnp.asarray(s)

    # ---- public key over Q -------------------------------------------------
    pk_ax = sample_uniform_limbs(rng, N, logQ, qlimbs, beta)
    np_pk = params.np_for_bits(params.primes, logQ + params.logN + 3)
    as_prod = rns.from_eval(
        rns.eval_mul(rns.to_eval(pk_ax, np_pk, g, cfg),
                     rns.to_eval_small(s_j, np_pk, g, cfg), g, cfg),
        params, qlimbs, g, cfg)                      # centered a·s
    e = rns.small_ints_to_limbs(sample_gauss(rng, N, params.sigma),
                                qlimbs, beta)
    pk_bx = bigint.mask_bits(bigint.add(bigint.neg(as_prod), e), logQ)

    # ---- evaluation key over Q² --------------------------------------------
    evk_ax = sample_uniform_limbs(rng, N, 2 * logQ, q2limbs, beta)
    np_evk = params.np_for_bits(params.primes, 2 * logQ + params.logN + 3)
    as2 = rns.from_eval(
        rns.eval_mul(rns.to_eval(evk_ax, np_evk, g, cfg),
                     rns.to_eval_small(s_j, np_evk, g, cfg), g, cfg),
        params, q2limbs, g, cfg)                     # centered evk_ax·s
    # s² via a tiny exact product (coeffs bounded by N)
    np_ss = params.np_for_bits(params.primes, 2 + params.logN + 3)
    ss = rns.from_eval(
        rns.eval_mul(rns.to_eval_small(s_j, np_ss, g, cfg),
                     rns.to_eval_small(s_j, np_ss, g, cfg), g, cfg),
        params, q2limbs, g, cfg)
    q_ss = bigint.shift_left_bits(ss, logQ)          # Q·s²
    e2 = rns.small_ints_to_limbs(sample_gauss(rng, N, params.sigma),
                                 q2limbs, beta)
    evk_bx = bigint.mask_bits(
        bigint.add(bigint.add(bigint.neg(as2), e2), q_ss), 2 * logQ)

    # ---- evk into the eval domain (region-2 primes, max np2) ---------------
    np2_max = params.np_region2(logQ)
    from repro.core.context import _shoup_vec  # host-side exact
    ax_ev = rns.to_eval(evk_ax, np2_max, g, cfg)
    bx_ev = rns.to_eval(bigint.mask_bits(evk_bx, 2 * logQ), np2_max, g, cfg)
    primes_np = np.asarray(g.primes[:np2_max])
    ax_sh = _shoup_vec(np.asarray(ax_ev), primes_np, beta)
    bx_sh = _shoup_vec(np.asarray(bx_ev), primes_np, beta)

    return (SecretKey(s=s_j),
            PublicKey(ax=pk_ax, bx=pk_bx),
            EvalKey(ax_ev=ax_ev, ax_ev_shoup=jnp.asarray(ax_sh),
                    bx_ev=bx_ev, bx_ev_shoup=jnp.asarray(bx_sh)))
