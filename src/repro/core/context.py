"""Precomputed tables for the HE Mul pipeline (paper Table V).

The paper's functions consume precomputed data:
  - CRT:  TB_CRT[j,k] = β^k mod p_j, plus Shoup companions.
  - NTT:  TB_W = powers of the 2N-th root ψ in bit-reversed order (+Shoup).
  - iNTT: inverse-ψ powers (+Shoup) and N⁻¹ mod p.
  - iCRT: (P/p_j)⁻¹ mod p_j (+Shoup), limbs of P/p_j, and P itself.

Tables are built host-side with exact python-int arithmetic, vectorized with
numpy where the word size allows, and cached:

  - :class:`GlobalTables` — everything that depends only on the prime pool
    (built once per parameter set; sliced per level).
  - :class:`IcrtTables` — everything that depends on P = ∏ first-np primes
    (cached per np, shared between regions/levels that use the same np).
  - :class:`HEContext` — a cheap per-(params, logq) view bundling both
    regions' slices.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

from repro.core.params import HEParams
from repro.nt.primes import bit_reverse_indices, primitive_2nth_root
from repro.nt.residue import int_to_limbs


def _np_dtype(beta_bits: int):
    return np.uint32 if beta_bits == 32 else np.uint64


def _pow_table_vec(bases: np.ndarray, primes: np.ndarray, n: int,
                   beta_bits: int) -> np.ndarray:
    """powers[j, k] = bases[j]^k mod primes[j], k in [0, n). Exact."""
    npn = len(primes)
    out = np.empty((npn, n), dtype=object)
    if beta_bits == 32:
        # vectorized: products < 2^60 fit u64
        b = bases.astype(np.uint64)
        p = primes.astype(np.uint64)
        col = np.ones(npn, dtype=np.uint64)
        res = np.empty((npn, n), dtype=np.uint64)
        for k in range(n):
            res[:, k] = col
            col = (col * b) % p
        return res.astype(np.uint32)
    # u64 primes: python-int per prime (exact, one-time)
    res = np.empty((npn, n), dtype=np.uint64)
    for j in range(npn):
        pj = int(primes[j])
        bj = int(bases[j])
        c = 1
        for k in range(n):
            res[j, k] = c
            c = (c * bj) % pj
    return res


def _shoup_vec(vals: np.ndarray, primes: np.ndarray, beta_bits: int
               ) -> np.ndarray:
    """floor(vals·β / p); vals is (np,) or (np, K), primes is (np,). Exact."""
    p_b = primes.reshape(-1, *([1] * (vals.ndim - 1)))
    if beta_bits == 32:
        return ((vals.astype(np.uint64) << np.uint64(32))
                // p_b.astype(np.uint64)).astype(np.uint32)
    out = np.empty_like(vals, dtype=np.uint64)
    flat_v = vals.reshape(-1)
    flat_p = np.broadcast_to(p_b, vals.shape).reshape(-1)
    flat_o = out.reshape(-1)
    for i in range(flat_v.size):
        flat_o[i] = (int(flat_v[i]) << 64) // int(flat_p[i])
    return out


@dataclasses.dataclass(frozen=True)
class GlobalTables:
    """Prime-pool-wide tables; slice rows [:np] for a given level/region."""

    params: HEParams
    primes: np.ndarray            # (np_max,)
    psi_rev: np.ndarray           # (np_max, N)   ψ^brv(k)
    psi_rev_shoup: np.ndarray
    ipsi_rev: np.ndarray          # (np_max, N)   ψ^-brv(k)
    ipsi_rev_shoup: np.ndarray
    n_inv: np.ndarray             # (np_max,)     N⁻¹ mod p
    n_inv_shoup: np.ndarray
    pprime: np.ndarray            # (np_max,)     -p⁻¹ mod β  (Montgomery)
    r2: np.ndarray                # (np_max,)     β² mod p    (Montgomery)
    crt_tb: np.ndarray            # (np_max, max_in_limbs)  β^k mod p
    crt_tb_shoup: np.ndarray
    betak: np.ndarray             # (np_max, 3)   β^k mod p, k<3 (accum fold)
    betak_shoup: np.ndarray
    p_inv_f64: np.ndarray         # (np_max,)     1/p as float64

    @property
    def max_in_limbs(self) -> int:
        return self.crt_tb.shape[1]


@lru_cache(maxsize=8)
def build_global_tables(params: HEParams) -> GlobalTables:
    beta = params.beta_bits
    dt = _np_dtype(beta)
    N = params.N
    np_max = params.max_np
    primes_py = params.primes[:np_max]
    primes = np.array(primes_py, dtype=dt)

    # --- NTT twiddles ------------------------------------------------------
    psis = np.array(
        [primitive_2nth_root(p, N) for p in primes_py], dtype=dt)
    ipsis = np.array(
        [pow(int(w), int(p) - 2, int(p)) for w, p in zip(psis, primes_py)],
        dtype=dt)
    pow_psi = _pow_table_vec(psis, primes, N, beta)      # ψ^k natural order
    pow_ipsi = _pow_table_vec(ipsis, primes, N, beta)
    brv = np.array(bit_reverse_indices(N), dtype=np.int64)
    psi_rev = np.ascontiguousarray(pow_psi[:, brv])
    ipsi_rev = np.ascontiguousarray(pow_ipsi[:, brv])
    n_inv = np.array(
        [pow(N, int(p) - 2, int(p)) for p in primes_py], dtype=dt)

    # --- Montgomery constants ---------------------------------------------
    R = 1 << beta
    pprime = np.array([(-pow(p, -1, R)) % R for p in primes_py], dtype=dt)
    r2 = np.array([(R * R) % p for p in primes_py], dtype=dt)

    # --- CRT table: β^k mod p ---------------------------------------------
    max_in_limbs = params.limbs_for_bits(2 * params.logQ) + 1
    beta_mod = np.array([R % p for p in primes_py], dtype=dt)
    crt_tb = _pow_table_vec(beta_mod, primes, max_in_limbs, beta)
    betak = crt_tb[:, :3].copy()

    return GlobalTables(
        params=params,
        primes=primes,
        psi_rev=psi_rev,
        psi_rev_shoup=_shoup_vec(psi_rev, primes, beta),
        ipsi_rev=ipsi_rev,
        ipsi_rev_shoup=_shoup_vec(ipsi_rev, primes, beta),
        n_inv=n_inv,
        n_inv_shoup=_shoup_vec(n_inv, primes, beta),
        pprime=pprime,
        r2=r2,
        crt_tb=crt_tb,
        crt_tb_shoup=_shoup_vec(crt_tb, primes, beta),
        betak=betak,
        betak_shoup=_shoup_vec(betak, primes, beta),
        p_inv_f64=1.0 / primes.astype(np.float64),
    )


@dataclasses.dataclass(frozen=True)
class IcrtTables:
    """Tables depending on P = ∏_{j<np} p_j (paper Algo 5/6 inputs)."""

    np_count: int
    P_int: int                    # exact P (host-side)
    P_bits: int
    plimbs: int                   # limbs of the largest P/p_j
    accum_limbs: int              # limbs covering np·P (the accumulator)
    inv_P: np.ndarray             # (np,)  (P/p_j)⁻¹ mod p_j
    inv_P_shoup: np.ndarray
    pdivp: np.ndarray             # (np, plimbs)  limbs of P/p_j
    P_limbs: np.ndarray           # (accum_limbs,)
    P_half_limbs: np.ndarray      # (accum_limbs,)  floor(P/2)
    quot_fix: np.ndarray          # (np, 2)  floor(β²/p_j) — the TPU kernel's
    #                               fixed-point quotient (no f64 on TPU)


@lru_cache(maxsize=None)
def build_icrt_tables(params: HEParams, np_count: int) -> IcrtTables:
    beta = params.beta_bits
    dt = _np_dtype(beta)
    primes_py = params.primes[:np_count]
    P = 1
    for p in primes_py:
        P *= p
    P_bits = P.bit_length()
    plimbs = params.limbs_for_bits((P // min(primes_py)).bit_length())
    # +2 limbs of assembly headroom: the 3-word accumulators are placed at
    # limb offsets 0..2 before the final carry propagation.
    accum_limbs = params.limbs_for_bits(
        P_bits + math.ceil(math.log2(np_count)) + 1) + 2

    inv_P = np.array(
        [pow(P // p, -1, p) for p in primes_py], dtype=dt)
    primes = np.array(primes_py, dtype=dt)
    pdivp = np.zeros((np_count, plimbs), dtype=dt)
    for j, p in enumerate(primes_py):
        pdivp[j] = int_to_limbs(P // p, plimbs, beta)
    quot_fix = np.zeros((np_count, 2), dtype=dt)
    for j, p in enumerate(primes_py):
        quot_fix[j] = int_to_limbs((1 << (2 * beta)) // p, 2, beta)

    return IcrtTables(
        np_count=np_count,
        P_int=P,
        P_bits=P_bits,
        plimbs=plimbs,
        accum_limbs=accum_limbs,
        inv_P=inv_P,
        inv_P_shoup=_shoup_vec(inv_P, primes, beta),
        pdivp=pdivp,
        P_limbs=int_to_limbs(P, accum_limbs, beta),
        P_half_limbs=int_to_limbs(P // 2, accum_limbs, beta),
        quot_fix=quot_fix,
    )


@dataclasses.dataclass(frozen=True)
class HEContext:
    """Per-(params, logq) bundle: region-1 and region-2 table views.

    Region 1 multiplies two log q-bit polys (P₁ > 2N·q²); region 2 multiplies
    a log q-bit poly with the log Q²-bit evk (P₂ > 2N·q·Q²). Paper Fig. 2.
    """

    params: HEParams
    logq: int
    tables: GlobalTables
    np1: int
    np2: int
    icrt1: IcrtTables
    icrt2: IcrtTables

    @property
    def qlimbs(self) -> int:
        return self.params.qlimbs(self.logq)

    @property
    def N(self) -> int:
        return self.params.N


@lru_cache(maxsize=None)
def make_context(params: HEParams, logq: int) -> HEContext:
    tables = build_global_tables(params)
    np1 = params.np_region1(logq)
    np2 = params.np_region2(logq)
    return HEContext(
        params=params,
        logq=logq,
        tables=tables,
        np1=np1,
        np2=np2,
        icrt1=build_icrt_tables(params, np1),
        icrt2=build_icrt_tables(params, np2),
    )
