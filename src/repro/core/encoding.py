"""CKKS canonical-embedding encode/decode (HEAAN's "special FFT").

This is the client-side boundary (paper §III-A): a message of n ≤ N/2
complex numbers becomes a degree-(N-1) integer polynomial via the inverse
canonical embedding, scaled by Δ and rounded. The paper does not accelerate
this step (it is not part of HE Mul), so it lives host-side in numpy,
implemented as HEAAN's rot-group butterfly network in O(n log n).

Conventions follow the reference HEAAN (Ring::EMB / EMBInv, Scheme::encode):
  - rotGroup[j] = 5^j mod 2N indexes the evaluation points,
  - real parts land at coefficients i·gap, imaginary parts at N/2 + i·gap,
    gap = (N/2)/n.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core.params import HEParams

__all__ = ["encode", "decode", "emb", "emb_inv", "message_hash"]


def message_hash(z: np.ndarray, log_delta: int) -> str:
    """Content hash of a slot message at an encoding scale.

    Two messages share a hash exactly when :func:`encode` would produce
    the same plaintext polynomial for them (same slot values, same
    scale 2^log_delta), so ``(message_hash(z, Δ), logq)`` is a sound key
    for caching the ENCODED operand of mul_plain/add_plain server-side —
    the `repro.hserve` plaintext-operand cache and `repro.client`'s
    `PlainHandle` both key on it. Modulus and parameter set are NOT part
    of the hash; callers key those separately (one cache per server).
    """
    z = np.ascontiguousarray(np.asarray(z, dtype=np.complex128))
    h = hashlib.sha256()
    h.update(f"{z.shape}|{int(log_delta)}|".encode())
    h.update(z.tobytes())
    return h.hexdigest()[:20]


def _bit_reverse_inplace(vals: np.ndarray) -> np.ndarray:
    n = len(vals)
    j = 0
    for i in range(1, n):
        bit = n >> 1
        while j >= bit:
            j -= bit
            bit >>= 1
        j += bit
        if i < j:
            vals[i], vals[j] = vals[j], vals[i]
    return vals


def _ksi_pows(M: int) -> np.ndarray:
    return np.exp(2j * np.pi * np.arange(M + 1) / M)


def _rot_group(Nh: int, M: int) -> np.ndarray:
    out = np.empty(Nh, dtype=np.int64)
    five = 1
    for i in range(Nh):
        out[i] = five
        five = (five * 5) % M
    return out


def emb(vals: np.ndarray, M: int) -> np.ndarray:
    """HEAAN Ring::EMB — slot evaluation (decode direction), in place."""
    vals = np.array(vals, dtype=np.complex128)
    n = len(vals)
    rot = _rot_group(max(n, 1), M)
    ksi = _ksi_pows(M)
    _bit_reverse_inplace(vals)
    length = 2
    while length <= n:
        lenh = length >> 1
        lenq = length << 2
        gap = M // lenq
        for i in range(0, n, length):
            idx_all = (rot[:lenh] % lenq) * gap
            u = vals[i: i + lenh].copy()
            v = vals[i + lenh: i + length] * ksi[idx_all]
            vals[i: i + lenh] = u + v
            vals[i + lenh: i + length] = u - v
        length <<= 1
    return vals


def emb_inv(vals: np.ndarray, M: int) -> np.ndarray:
    """HEAAN Ring::EMBInv — inverse embedding (encode direction)."""
    vals = np.array(vals, dtype=np.complex128)
    n = len(vals)
    rot = _rot_group(max(n, 1), M)
    ksi = _ksi_pows(M)
    length = n
    while length >= 1:
        if length == 1:
            break
        lenh = length >> 1
        lenq = length << 2
        gap = M // lenq
        for i in range(0, n, length):
            idx_all = lenq - (rot[:lenh] % lenq)
            idx_all = idx_all * gap
            u = vals[i: i + lenh] + vals[i + lenh: i + length]
            v = (vals[i: i + lenh] - vals[i + lenh: i + length]) * ksi[idx_all]
            vals[i: i + lenh] = u
            vals[i + lenh: i + length] = v
        length >>= 1
    _bit_reverse_inplace(vals)
    return vals / n


def encode(z: np.ndarray, params: HEParams, log_delta: int | None = None
           ) -> np.ndarray:
    """Complex message (n,) -> integer coefficient vector (N,) (python ints).

    n must be a power of two, n ≤ N/2. Negative coefficients are returned
    as signed python ints (callers map to mod-q two's complement).
    """
    z = np.asarray(z, dtype=np.complex128)
    n = len(z)
    N = params.N
    Nh = N // 2
    assert n <= Nh and (n & (n - 1)) == 0, "slots must be a power of two ≤ N/2"
    ld = params.log_delta if log_delta is None else log_delta
    delta = float(1 << ld)
    u = emb_inv(z, 2 * N)
    gap = Nh // n
    coeffs = np.zeros(N, dtype=object)
    for i in range(n):
        coeffs[i * gap] = int(np.round(u[i].real * delta))
        coeffs[Nh + i * gap] = int(np.round(u[i].imag * delta))
    return coeffs


def decode(coeffs: np.ndarray, n: int, params: HEParams,
           log_delta: int | None = None) -> np.ndarray:
    """Signed integer coefficients (N,) -> complex message (n,)."""
    N = params.N
    Nh = N // 2
    gap = Nh // n
    ld = params.log_delta if log_delta is None else log_delta
    delta = float(1 << ld)
    u = np.empty(n, dtype=np.complex128)
    for i in range(n):
        u[i] = (float(coeffs[i * gap]) + 1j * float(coeffs[Nh + i * gap])) \
            / delta
    return emb(u, 2 * N)
