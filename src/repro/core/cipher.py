"""Ciphertext / plaintext / key containers (JAX pytrees)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Ciphertext:
    """HEAAN ciphertext: a pair of mod-q polynomials (paper §III-A).

    ax/bx: (N, qlimbs) little-endian limb arrays, coefficients in [0, q).
    logq/logp/n_slots are static metadata.
    """
    ax: jnp.ndarray
    bx: jnp.ndarray
    logq: int = dataclasses.field(metadata=dict(static=True))
    logp: int = dataclasses.field(metadata=dict(static=True))
    n_slots: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PublicKey:
    """pk = (bx, ax) with bx = -ax·s + e mod Q."""
    ax: jnp.ndarray   # (N, QLimbs)
    bx: jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EvalKey:
    """evk over Q², stored CRT'd + NTT'd at the maximal region-2 prime set
    (HEAAN 2.1 'faster multiplication'), with Shoup companions.

    ax_ev/bx_ev: (np2_max, N); *_shoup alongside.
    """
    ax_ev: jnp.ndarray
    ax_ev_shoup: jnp.ndarray
    bx_ev: jnp.ndarray
    bx_ev_shoup: jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SecretKey:
    """Ternary secret with Hamming weight h (host-visible for tests only)."""
    s: jnp.ndarray    # (N,) int8 in {-1, 0, 1}
