"""RNS pipeline composition: the paper's Fig. 2 stages as reusable pieces.

    limbs --CRT--> residues --NTT--> eval domain
    eval  --iNTT--> residues --iCRT--> centered limbs

Strategy flags select the paper's optimization ladder (see core.crt/ntt).
The HEAAN scheme (core.heaan) and the benchmarks compose these; the Pallas
kernels provide drop-in replacements for each stage (repro.kernels).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.context import GlobalTables, build_icrt_tables
from repro.core.crt import crt, icrt
from repro.core.ntt import intt, ntt, pointwise_shoup_scale
from repro.core.params import HEParams
from repro.core.wordops import modadd, modsub, mont_modmul

__all__ = ["PipelineConfig", "to_eval", "to_eval_small", "from_eval",
           "eval_mul", "eval_add", "eval_sub", "eval_mul_shoup",
           "poly_mul", "small_ints_to_limbs", "limbs_to_centered_ints"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Paper optimization toggles (§V). Defaults = fastest pure-JAX path."""
    crt_strategy: str = "matmul"      # matmul | shoup | mod2 | mod4 | acc3
    icrt_strategy: str = "matmul"     # matmul | acc3 | naive
    modified_shoup: bool = False      # paper's 3-half-mul Shoup variant
    use_kernels: bool = False         # route stages through Pallas kernels


DEFAULT = PipelineConfig()


def to_eval(x: jnp.ndarray, npn: int, g: GlobalTables,
            cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """(N, K) limbs -> (npn, N) eval-domain residues (CRT then NTT)."""
    K = x.shape[1]
    if cfg.use_kernels:
        from repro.kernels.crt.ops import crt_op
        from repro.kernels.ntt.ops import ntt_op
        res = crt_op(x, jnp.asarray(g.crt_tb[:npn, :K]),
                     jnp.asarray(g.crt_tb_shoup[:npn, :K]),
                     jnp.asarray(g.primes[:npn]))
        return ntt_op(res, jnp.asarray(g.psi_rev[:npn]),
                      jnp.asarray(g.psi_rev_shoup[:npn]),
                      jnp.asarray(g.primes[:npn]))
    res = crt(x, jnp.asarray(g.crt_tb[:npn, :K]),
              jnp.asarray(g.crt_tb_shoup[:npn, :K]),
              jnp.asarray(g.primes[:npn]), strategy=cfg.crt_strategy)
    return ntt(res, jnp.asarray(g.psi_rev[:npn]),
               jnp.asarray(g.psi_rev_shoup[:npn]),
               jnp.asarray(g.primes[:npn]), modified=cfg.modified_shoup)


def to_eval_small(s: jnp.ndarray, npn: int, g: GlobalTables,
                  cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """Small signed ints (N,) (e.g. ternary secrets) -> eval domain."""
    primes = jnp.asarray(g.primes[:npn])
    s64 = jnp.asarray(s, jnp.int64)
    res = jnp.where(s64[None, :] >= 0,
                    s64[None, :].astype(primes.dtype) %
                    primes[:, None],
                    primes[:, None]
                    - ((-s64[None, :]).astype(primes.dtype)
                       % primes[:, None]))
    res = jnp.where(res == primes[:, None], 0, res).astype(primes.dtype)
    if cfg.use_kernels:
        from repro.kernels.ntt.ops import ntt_op
        return ntt_op(res, jnp.asarray(g.psi_rev[:npn]),
                      jnp.asarray(g.psi_rev_shoup[:npn]), primes)
    return ntt(res, jnp.asarray(g.psi_rev[:npn]),
               jnp.asarray(g.psi_rev_shoup[:npn]), primes,
               modified=cfg.modified_shoup)


def from_eval(ev: jnp.ndarray, params: HEParams, out_limbs: int,
              g: GlobalTables, cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """(npn, N) eval residues -> (N, out_limbs) centered two's complement."""
    npn = ev.shape[0]
    tabs = build_icrt_tables(params, npn)
    primes = jnp.asarray(g.primes[:npn])
    if cfg.use_kernels:
        from repro.kernels.ntt.ops import intt_op
        from repro.kernels.icrt.ops import icrt_op
        res = intt_op(ev, jnp.asarray(g.ipsi_rev[:npn]),
                      jnp.asarray(g.ipsi_rev_shoup[:npn]),
                      jnp.asarray(g.n_inv[:npn]),
                      jnp.asarray(g.n_inv_shoup[:npn]), primes)
        return icrt_op(res, tabs, g, out_limbs)
    res = intt(ev, jnp.asarray(g.ipsi_rev[:npn]),
               jnp.asarray(g.ipsi_rev_shoup[:npn]),
               jnp.asarray(g.n_inv[:npn]), jnp.asarray(g.n_inv_shoup[:npn]),
               primes, modified=cfg.modified_shoup)
    return icrt(res, tabs, primes,
                jnp.asarray(tabs.inv_P), jnp.asarray(tabs.inv_P_shoup),
                jnp.asarray(tabs.pdivp), jnp.asarray(tabs.P_limbs),
                jnp.asarray(tabs.P_half_limbs),
                jnp.asarray(g.p_inv_f64[:npn]),
                out_limbs=out_limbs, strategy=cfg.icrt_strategy)


def eval_mul(a: jnp.ndarray, b: jnp.ndarray, g: GlobalTables,
             cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """Pointwise a⊙b mod p (unknown×unknown → Montgomery)."""
    npn = a.shape[0]
    if cfg.use_kernels:
        from repro.kernels.modmul.ops import pointwise_mont_op
        return pointwise_mont_op(a, b, jnp.asarray(g.primes[:npn]),
                                 jnp.asarray(g.pprime[:npn]),
                                 jnp.asarray(g.r2[:npn]))
    return mont_modmul(a, b, jnp.asarray(g.primes[:npn])[:, None],
                       jnp.asarray(g.pprime[:npn])[:, None],
                       jnp.asarray(g.r2[:npn])[:, None])


def eval_mul_shoup(a: jnp.ndarray, b: jnp.ndarray, b_shoup: jnp.ndarray,
                   g: GlobalTables, cfg: PipelineConfig = DEFAULT
                   ) -> jnp.ndarray:
    """Pointwise a⊙b mod p where b has precomputed Shoup companions (evk)."""
    npn = a.shape[0]
    return pointwise_shoup_scale(a, b, b_shoup,
                                 jnp.asarray(g.primes[:npn]),
                                 modified=cfg.modified_shoup)


def eval_add(a, b, g: GlobalTables):
    return modadd(a, b, jnp.asarray(g.primes[: a.shape[0]])[:, None])


def eval_sub(a, b, g: GlobalTables):
    return modsub(a, b, jnp.asarray(g.primes[: a.shape[0]])[:, None])


def poly_mul(x: jnp.ndarray, y: jnp.ndarray, x_bits: int, y_bits: int,
             params: HEParams, g: GlobalTables, out_limbs: int,
             cfg: PipelineConfig = DEFAULT) -> jnp.ndarray:
    """General negacyclic poly product of two canonical limb polys.

    Chooses np from the exact coefficient bound |c| < N·2^(x_bits+y_bits).
    Returns centered two's complement at out_limbs.
    """
    npn = params.np_for_bits(
        params.primes, x_bits + y_bits + params.logN + 2)
    ex = to_eval(x, npn, g, cfg)
    ey = to_eval(y, npn, g, cfg)
    return from_eval(eval_mul(ex, ey, g, cfg), params, out_limbs, g, cfg)


# ---- host/limb conversions -------------------------------------------------

def small_ints_to_limbs(v: np.ndarray, n_limbs: int, beta_bits: int
                        ) -> jnp.ndarray:
    """Signed small ints (N,) -> (N, L) two's complement limb arrays."""
    dt = jnp.uint32 if beta_bits == 32 else jnp.uint64
    v64 = jnp.asarray(np.asarray(v, dtype=np.int64))
    out = []
    x = v64.astype(jnp.int64)
    for k in range(n_limbs):
        if beta_bits == 32:
            out.append((x & 0xFFFFFFFF).astype(dt))
            x = x >> 32
        else:
            out.append(x.astype(jnp.uint64))
            x = x >> 63 >> 1   # arithmetic sign fill
    return jnp.stack(out, axis=-1)


def limbs_to_centered_ints(a: np.ndarray, beta_bits: int, logq: int
                           ) -> list:
    """(N, L) mod-q limbs -> centered python ints in [-q/2, q/2)."""
    from repro.nt.residue import limbs_to_int
    q = 1 << logq
    out = []
    for row in np.asarray(a):
        v = limbs_to_int(row, beta_bits) % q
        out.append(v - q if v >= q // 2 else v)
    return out
