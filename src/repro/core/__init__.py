# The paper's primary contribution — the HEAAN HE-Mul pipeline
# (CRT → NTT → pointwise → iNTT → iCRT, regions 1+2) — implemented in JAX.
#
# β = 2^64 limb arithmetic requires uint64; enable x64 before any tracing.
# Model code (repro.models) is dtype-explicit, so this is safe globally.
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.params import HEParams, paper_params, test_params  # noqa: E402
from repro.core.context import HEContext, make_context  # noqa: E402

__all__ = [
    "HEParams",
    "paper_params",
    "test_params",
    "HEContext",
    "make_context",
]
