"""Negacyclic NTT / iNTT over the RNS primes (paper Algo 3/4).

Forward: merged-ψ Cooley-Tukey (natural order in, bit-reversed out), exactly
the paper's Algo 3 with TB_W[m+j] = ψ^brv(m+j). Inverse: Gentleman-Sande
with ψ⁻¹ twiddles (bit-reversed in, natural out) and a final N⁻¹ scale —
the paper notes iNTT's extra elementwise division by N (§IV).

Pointwise ciphertext products stay in the bit-reversed eval domain, so the
permutation never materializes. All modmuls are Shoup (paper Algo 2); the
modified-Shoup variant (3 half-muls, §V-B) is selectable.

Data layout is (np, N) with N minor — on TPU this puts butterflies on the
128-lane axis (the paper's "matrix transposed for SIMD locality" point).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.wordops import (
    modadd, modsub, shoup_modmul, shoup_modmul_modified,
)

__all__ = ["ntt", "intt", "pointwise_shoup_scale"]


def _modmul(modified: bool):
    return shoup_modmul_modified if modified else shoup_modmul


@partial(jax.jit, static_argnames=("modified",))
def ntt(x: jnp.ndarray, psi_rev: jnp.ndarray, psi_rev_shoup: jnp.ndarray,
        primes: jnp.ndarray, *, modified: bool = False) -> jnp.ndarray:
    """Forward negacyclic NTT.

    x: (np, N) residues in natural order  ->  (np, N) bit-reversed eval.
    psi_rev[j, k] = ψ_j^brv(k); primes: (np,).
    """
    npn, N = x.shape
    mm = _modmul(modified)
    p = primes[:, None, None]
    t = N
    m = 1
    while m < N:
        t //= 2
        # groups: (np, m, 2, t); twiddle S = psi_rev[:, m + i] per group i.
        xr = x.reshape(npn, m, 2, t)
        u = xr[:, :, 0, :]
        v = xr[:, :, 1, :]
        s = psi_rev[:, m: 2 * m, None]
        s_sh = psi_rev_shoup[:, m: 2 * m, None]
        vv = mm(v, s, s_sh, p)
        x = jnp.stack([modadd(u, vv, p), modsub(u, vv, p)],
                      axis=2).reshape(npn, N)
        m *= 2
    return x


@partial(jax.jit, static_argnames=("modified",))
def intt(x: jnp.ndarray, ipsi_rev: jnp.ndarray, ipsi_rev_shoup: jnp.ndarray,
         n_inv: jnp.ndarray, n_inv_shoup: jnp.ndarray,
         primes: jnp.ndarray, *, modified: bool = False) -> jnp.ndarray:
    """Inverse negacyclic NTT (Gentleman-Sande).

    x: (np, N) bit-reversed eval  ->  (np, N) natural-order residues.
    """
    npn, N = x.shape
    mm = _modmul(modified)
    p = primes[:, None, None]
    t = 1
    m = N
    while m > 1:
        h = m // 2
        xr = x.reshape(npn, h, 2, t)
        u = xr[:, :, 0, :]
        v = xr[:, :, 1, :]
        s = ipsi_rev[:, h: 2 * h, None]
        s_sh = ipsi_rev_shoup[:, h: 2 * h, None]
        lo = modadd(u, v, p)
        hi = mm(modsub(u, v, p), s, s_sh, p)
        x = jnp.stack([lo, hi], axis=2).reshape(npn, N)
        t *= 2
        m = h
    # final elementwise ·N⁻¹ (paper §IV: iNTT's extra division by N)
    return _modmul(modified)(x, n_inv[:, None], n_inv_shoup[:, None],
                             primes[:, None])


def pointwise_shoup_scale(x: jnp.ndarray, y: jnp.ndarray, y_shoup: jnp.ndarray,
                          primes: jnp.ndarray, *, modified: bool = False
                          ) -> jnp.ndarray:
    """Elementwise x·y mod p where y has precomputed Shoup companions.

    Used for evk products (evk is precomputed in the eval domain, so its
    Shoup companions are too) and for the iCRT Hadamard step.
    """
    return _modmul(modified)(x, y, y_shoup, primes[:, None])
