"""repro.hserve — batched HE serving runtime over the sharded pipeline.

The paper's architectural claim (§V) is that HE-Mul *throughput* under
thread-pinned batching — not single-op latency — is what makes HEAAN
serviceable; HEAX's per-modulus lanes and Medha's resident-on-chip
key/table placement both say the winning serving design keeps ONE table
set resident and streams work through it. `repro.hserve` is that design
in JAX/GSPMD, layered on `repro.dist.he_pipeline`:

  - :mod:`repro.hserve.queue`   — request queue + batch assembler:
    buckets by (op, level), pads to one fixed trace shape per bucket.
  - :mod:`repro.hserve.tables`  — level-aware resident table cache:
    tables materialize once at logQ; every level logq < logQ is served
    as row-slices of the one resident pytree.
  - :mod:`repro.hserve.engine`  — jit-once op engine: mesh-sharded
    `he_mul`, `he_rotate`, and slot-sum steps, bitwise identical to the
    single-device `core` references.
  - :mod:`repro.hserve.metrics` — steady-state throughput / latency /
    queue-depth accounting.
  - :mod:`repro.hserve.server`  — :class:`HEServer`, the composed loop.

Usage — serve a mixed multi-level stream on the host mesh::

    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.core.rotate import rot_keygen
    from repro.core.params import test_params
    from repro.hserve import HEServer

    params = test_params(logN=5, beta_bits=32)
    sk, pk, evk = keygen(params, seed=0)
    server = HEServer(params, evk,
                      rot_keys={1: rot_keygen(params, sk, 1)}, batch=4)

    c1 = H.encrypt_message(z1, pk, params, seed=1)
    c2 = H.encrypt_message(z2, pk, params, seed=2)
    rid_mul = server.submit_mul(c1, c2)           # level logQ
    low = H.he_mod_down(c1, params, params.logQ - params.logp)
    rid_rot = server.submit_rotate(low, r=1)      # a lower level

    results = server.drain()                      # {rid: Ciphertext}
    print(server.stats()["per_op"]["mul"]["ops_per_s"])

Or drive it from the CLI::

    PYTHONPATH=src python -m repro.launch.serve --he --batch 8 \\
        --requests 24 --levels 3 --rotations 4 [--kernels]
"""

from repro.hserve import engine, metrics, queue, tables  # noqa: F401
from repro.hserve.engine import OpEngine, slot_sum_rotations  # noqa: F401
from repro.hserve.metrics import ServeMetrics  # noqa: F401
from repro.hserve.queue import (  # noqa: F401
    Batch, BatchAssembler, Request, RequestQueue,
)
from repro.hserve.server import HEServer  # noqa: F401
from repro.hserve.tables import TableCache  # noqa: F401

__all__ = [
    "HEServer", "OpEngine", "TableCache", "ServeMetrics",
    "Request", "Batch", "RequestQueue", "BatchAssembler",
    "slot_sum_rotations",
]
