"""repro.hserve — the encrypted-circuit serving runtime.

The paper's architectural claim (§V) is that HE-Mul *throughput* under
thread-pinned batching — not single-op latency — is what makes HEAAN
serviceable; HEAX's per-modulus lanes and Medha's resident-on-chip
key/table placement both say the winning serving design keeps ONE table
set resident and streams work through it — and that the accelerator only
pays off when the FULL ciphertext op set lives on the device, because
real workloads chain mul → rescale → mod-down → rotate at descending
levels (§III-A). `repro.hserve` is that design in JAX/GSPMD, layered on
`repro.dist.he_pipeline`:

  - :mod:`repro.hserve.queue`   — request queue + batch assembler:
    buckets by (op, level, extra), pads to one fixed trace shape per
    bucket, and tracks request ages / arrival rate for the flush policy.
  - :mod:`repro.hserve.tables`  — level-aware resident table cache:
    tables materialize once at logQ; every level logq < logQ is served
    as row-slices of the one resident pytree. Holds evk, rotation, and
    conjugation keys.
  - :mod:`repro.hserve.engine`  — jit-once op engine: mesh-sharded
    mul / add / sub / rotate / conjugate / slot-sum / rescale / mod-down
    steps, bitwise identical to the single-device `core` references,
    with async dispatch/wait for double buffering.
  - :mod:`repro.hserve.circuit` — encrypted-circuit op-DAG (CircuitOp)
    + the (logq, logp) level-tracking validator and the per-node bucket
    key schedule (`circuit_schedule`).
  - :mod:`repro.hserve.scheduler` — circuit-aware scheduler: looks
    ahead at registered circuits' level schedules to co-batch
    same-(op, level) nodes ACROSS circuits (deferring under-full drain
    flushes for siblings within a lookahead horizon, with a progress
    guarantee) and to prefetch the next levels' table slices behind the
    in-flight batch.
  - :mod:`repro.hserve.metrics` — steady-state throughput / latency /
    queue-depth / flush-cause accounting.
  - :mod:`repro.hserve.server`  — :class:`HEServer`, the composed loop:
    age-based continuous batching (`max_age_s`), adaptive bucket
    targets, double-buffered pipelining (`overlap`), and
    `submit_circuit` for whole-circuit server-side evaluation.
  - :mod:`repro.hserve.frontend` / :mod:`repro.hserve.worker` /
    :mod:`repro.hserve.transport` — the multi-host disaggregated tier:
    :class:`HEFrontend` keeps the queue/scheduler/plain-cache half and
    routes batches by (op, level) affinity over pickle-free frames to N
    :class:`WorkerEngine` processes (each with its own mesh, TableCache,
    and compiled steps), with heartbeat health, worker-death requeue,
    and bitwise identity to single-server serving (docs/SERVING.md).

Usage — serve a degree-4 encrypted polynomial in one round trip::

    from repro.core import heaan as H
    from repro.core.keys import keygen
    from repro.core.params import test_params
    from repro.core.rotate import conj_keygen, rot_keygen
    from repro.hserve import CircuitOp, HEServer

    params = test_params(logN=5, beta_bits=32)
    sk, pk, evk = keygen(params, seed=0)
    server = HEServer(params, evk,
                      rot_keys={1: rot_keygen(params, sk, 1)},
                      conj_key=conj_keygen(params, sk),
                      batch=4, max_age_s=0.05)

    x = H.encrypt_message(z, pk, params, seed=1)
    cid = server.submit_circuit([
        CircuitOp("mul", ("x", "x")),          # x²  (logp doubles)
        CircuitOp("rescale", (0,)),            # ÷2^logp, one level down
        CircuitOp("mul", (1, 1)),              # x⁴
        CircuitOp("rescale", (2,)),
        CircuitOp("conjugate", (3,)),          # conj(x⁴)
    ], inputs={"x": x})
    ct_out = server.drain()[cid]               # ONE ciphertext back

Plain per-op serving and the CLI driver still work::

    rid = server.submit_mul(c1, c2)
    results = server.drain()                   # {rid: Ciphertext}

    PYTHONPATH=src python -m repro.launch.serve --he --batch 8 \\
        --requests 24 --levels 3 --rotations 4 [--kernels] [--overlap]

Most users should not write CircuitOp lists by hand: `repro.client`'s
HESession/CipherHandle frontend traces plain arithmetic and compiles it
to these circuits (auto level alignment, CSE, plaintext-operand
caching) — see docs/API.md. This module is the serving substrate.

See docs/SERVING.md for the lifecycle and every knob.
"""

from repro.hserve import (  # noqa: F401
    circuit, engine, metrics, queue, scheduler, tables,
)
from repro.hserve.circuit import (  # noqa: F401
    CircuitOp, circuit_schedule, degree4_demo_circuit, validate_circuit,
)
from repro.hserve.engine import (  # noqa: F401
    Inflight, OpEngine, slot_sum_rotations,
)
from repro.hserve.metrics import ServeMetrics  # noqa: F401
from repro.hserve.queue import (  # noqa: F401
    Batch, BatchAssembler, Request, RequestQueue,
)
from repro.hserve.frontend import (  # noqa: F401
    FrontendCatalog, HEFrontend, NoLiveWorkersError,
)
from repro.hserve.scheduler import CircuitScheduler  # noqa: F401
from repro.hserve.server import HEServer  # noqa: F401
from repro.hserve.tables import PlainCache, TableCache  # noqa: F401
from repro.hserve.transport import (  # noqa: F401
    InProcTransport, SubprocessTransport, WorkerDied,
)
from repro.hserve.worker import WorkerEngine  # noqa: F401

__all__ = [
    "HEServer", "OpEngine", "TableCache", "PlainCache", "ServeMetrics",
    "Request", "Batch", "RequestQueue", "BatchAssembler",
    "CircuitOp", "validate_circuit", "circuit_schedule",
    "degree4_demo_circuit", "Inflight", "CircuitScheduler",
    "slot_sum_rotations",
    "HEFrontend", "FrontendCatalog", "NoLiveWorkersError",
    "WorkerEngine", "InProcTransport", "SubprocessTransport",
    "WorkerDied",
]
