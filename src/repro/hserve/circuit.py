"""Encrypted-circuit representation for server-side evaluation.

The paper's workloads never run one HE Mul in isolation: a real encrypted
computation is a small DAG of mul → rescale → mod-down → rotate/conjugate
ops at DESCENDING levels (§III-A's level-management discipline). A
serving runtime that round-trips the ciphertext to the client between
levels throws away the batching and table-residency wins of §IV–V — so
`HEServer.submit_circuit` accepts the whole DAG and walks it server-side,
one queue submission per node, with every node's output level tracked.

A circuit is a topologically-ordered list of :class:`CircuitOp` nodes.
Each node's ``args`` reference either a named client input (str) or the
output of an earlier node (int index). The LAST node is the circuit's
output; its ciphertext is what the client gets back.

:func:`validate_circuit` is the level tracker: it propagates
(logq, logp) through the DAG from the input ciphertexts' metadata and
raises — BEFORE anything is enqueued — on the errors that would
otherwise surface mid-drain: level mismatches between operands, scale
mismatches on add/sub, rescaling past exhaustion, mod-down to an
out-of-range modulus, forward references, or unknown ops.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.analysis.dataflow import propagate
from repro.core.params import HEParams

__all__ = ["CircuitOp", "validate_circuit", "circuit_schedule",
           "degree4_demo_circuit", "execute_circuit_reference"]

NodeRef = Union[int, str]


def degree4_demo_circuit(params: HEParams):
    """The repo's acceptance/demo circuit over one input "x":
    conj(x⁴) + x — mul → rescale → mul → rescale → mod-down → conjugate,
    plus the mod-down alignment of x and the final add, exercising every
    level-management op. Returns (ops, logq_md), where logq_md is the
    aligned modulus (logQ − 3·logp). Shared by `launch.serve --circuit`
    and the bitwise acceptance tests so all of them verify the SAME
    circuit; decrypts to conj(z⁴) + z."""
    logq_md = params.logQ - 3 * params.logp
    if logq_md <= 0:                    # not assert: gone under python -O
        raise ValueError(
            f"degree-4 demo circuit needs depth L >= 4 "
            f"(logQ={params.logQ}, logp={params.logp} gives only "
            f"L={params.L})")
    return [
        CircuitOp("mul", ("x", "x")),
        CircuitOp("rescale", (0,)),
        CircuitOp("mul", (1, 1)),
        CircuitOp("rescale", (2,)),
        CircuitOp("mod_down", (3,), logq2=logq_md),
        CircuitOp("conjugate", (4,)),
        CircuitOp("mod_down", ("x",), logq2=logq_md),
        CircuitOp("add", (5, 6)),
    ], logq_md


@dataclasses.dataclass(frozen=True)
class CircuitOp:
    """One node of an encrypted circuit.

    op:    any served op ("mul", "add", "sub", "rotate", "conjugate",
           "slot_sum", "rescale", "mod_down", "mul_plain", "add_plain").
    args:  operand references — a str names a client input, an int the
           output of an earlier node (0-based index into the op list).
    r:     left-rotation amount ("rotate" only).
    dlogp: scale drop for "rescale" (0 → params.logp).
    logq2: target modulus for "mod_down".
    pt:    encoded plaintext operand for "mul_plain"/"add_plain" —
           (N, qlimbs) mod-q limbs at the node's input level
           (core.heaan.encode_plain); excluded from equality/repr. May
           be None when `pt_hash` names an operand the server already
           holds in its (hash, level) plaintext cache.
    pt_logp: the plaintext's scale (mul_plain: 0 → params.log_delta;
           add_plain: must match the ciphertext's logp, 0 → assumed to).
    pt_hash: content hash of the plaintext MESSAGE at its encoding scale
           (core.encoding.message_hash). With `pt` set it registers the
           operand in the server's plaintext cache; alone it references
           a previously registered operand — affine-layer weights encode
           and ship once, not per request.
    """

    op: str
    args: Tuple[NodeRef, ...]
    r: int = 0
    dlogp: int = 0
    logq2: int = 0
    pt: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False, repr=False)
    pt_logp: int = 0
    pt_hash: Optional[str] = None


def validate_circuit(ops: List[CircuitOp],
                     input_meta: Dict[str, Tuple[int, int]],
                     params: HEParams) -> List[Tuple[int, int]]:
    """Propagate (logq, logp) through the DAG; raise on any ill-formed
    node. Returns the per-node output (logq, logp) list — the level
    schedule the server will serve.

    input_meta maps input names to their ciphertexts' (logq, logp).

    Delegates to the shared dataflow engine
    (:func:`repro.analysis.dataflow.propagate`) — the same transfer
    functions the client compile pass and the noise estimator use, so
    admission and compilation can never disagree. Errors are
    `repro.analysis.dataflow.CircuitError` (a `ValueError`) citing the
    node index, op, and computed (logq, logp).
    """
    return propagate(ops, input_meta, params)


def circuit_schedule(ops: List[CircuitOp],
                     input_meta: Dict[str, Tuple[int, int]],
                     input_nslots: Dict[str, int],
                     params: HEParams):
    """The circuit's full level schedule, computed BEFORE execution.

    Validates the DAG (see :func:`validate_circuit`) and returns
    ``(meta, keys, nslots)``: per-node output (logq, logp), per-node
    queue BUCKET KEY — the exact ``Request.bucket_key`` each node's
    request will land in — and per-node slot count (every op preserves
    its first operand's n_slots). This is what the circuit-aware
    scheduler looks ahead at: knowing every future node's bucket key
    lets it co-batch same-key nodes across circuits before they are
    ready and prefetch the next level's table slices (Medha's
    look-ahead-at-the-instruction-schedule idea).
    """
    meta = validate_circuit(ops, input_meta, params)
    keys: List[Tuple] = []
    nslots: List[int] = []
    for i, node in enumerate(ops):
        a = node.args[0]
        in_logq = input_meta[a][0] if isinstance(a, str) else meta[a][0]
        nslots.append(input_nslots[a] if isinstance(a, str) else nslots[a])
        if node.op == "rotate":
            keys.append((node.op, in_logq, node.r))
        elif node.op == "slot_sum":
            keys.append((node.op, in_logq, nslots[-1]))
        elif node.op == "rescale":
            keys.append((node.op, in_logq, node.dlogp or params.logp))
        elif node.op in ("mod_down", "mod_raise"):
            keys.append((node.op, in_logq, node.logq2))
        else:
            keys.append((node.op, in_logq, None))
    return meta, keys, nslots


def execute_circuit_reference(ops: List[CircuitOp],
                              inputs: Dict[str, "object"],
                              params: HEParams, *, evk=None,
                              rot_keys: Optional[Dict[int, object]] = None,
                              conj_key=None):
    """Run a circuit through the composed single-device `core` references.

    This is the bitwise ORACLE the served path is tested against: every
    node maps to exactly the core.heaan / core.rotate call the engine's
    batched step reproduces (slot_sum as the doubling rotate+add ladder).
    Plaintext nodes must carry a materialized `pt` (there is no cache on
    this path — resolve hashes first). Returns the LAST node's
    Ciphertext, like ``HEServer.submit_circuit``'s result.
    """
    from repro.core import heaan as H
    from repro.core.rotate import he_conjugate, he_rotate
    from repro.hserve.engine import slot_sum_rotations

    validate_circuit(
        ops, {n: (c.logq, c.logp) for n, c in inputs.items()}, params)
    rot_keys = rot_keys or {}
    values: Dict[NodeRef, object] = dict(inputs)
    for i, node in enumerate(ops):
        cts = [values[a] for a in node.args]
        if node.op == "mul":
            if evk is None:
                raise ValueError(f"node {i}: mul needs an evaluation key")
            out = H.he_mul(cts[0], cts[1], evk, params)
        elif node.op == "add":
            out = H.he_add(cts[0], cts[1])
        elif node.op == "sub":
            out = H.he_sub(cts[0], cts[1])
        elif node.op == "rotate":
            out = he_rotate(cts[0], node.r, rot_keys[node.r], params)
        elif node.op == "conjugate":
            if conj_key is None:
                raise ValueError(
                    f"node {i}: conjugate needs a conjugation key")
            out = he_conjugate(cts[0], conj_key, params)
        elif node.op == "slot_sum":
            out = cts[0]
            for r in slot_sum_rotations(out.n_slots):
                out = H.he_add(out, he_rotate(out, r, rot_keys[r], params))
        elif node.op == "rescale":
            out = H.rescale(cts[0], params, dlogp=node.dlogp or None)
        elif node.op == "mod_down":
            out = H.he_mod_down(cts[0], params, node.logq2)
        elif node.op == "mod_raise":
            out = H.he_mod_raise(cts[0], params, node.logq2)
        elif node.op == "mul_plain":
            if node.pt is None:
                raise ValueError(
                    f"node {i}: reference execution needs a materialized "
                    f"pt (no plaintext cache on this path)")
            out = H.he_mul_plain(cts[0], node.pt, params,
                                 pt_logp=node.pt_logp or None)
        elif node.op == "add_plain":
            if node.pt is None:
                raise ValueError(
                    f"node {i}: reference execution needs a materialized "
                    f"pt (no plaintext cache on this path)")
            out = H.he_add_plain(cts[0], node.pt, params)
        else:                             # unreachable post-validation
            raise ValueError(f"node {i}: unknown op {node.op!r}")
        values[i] = out
    return values[len(ops) - 1]
