"""Frontend <-> worker transports with pickle-free array framing.

The multi-host tier (docs/SERVING.md) splits ``HEServer`` into a
frontend that owns the queue/scheduler and N worker engines that own
device meshes.  Everything that crosses the cut goes through one wire
format so the in-process and subprocess deployments exercise the SAME
serialization path:

    frame := b"HSW1" | u32 header_len | header_json | payload*

The JSON header carries the message dict plus an ``arrays`` manifest
(name/dtype/shape per array); payloads are the raw C-contiguous bytes
concatenated in manifest order.  No pickle anywhere — a worker can only
ever receive ndarrays and JSON scalars, and the frame is portable
across interpreter versions.

Two transports share the interface (``send`` / ``recv`` / ``kill`` /
``alive`` / ``close``):

- ``InProcTransport`` drives a ``WorkerEngine`` in this process.  Every
  batch still round-trips the byte framing (encode -> decode -> handle
  -> encode -> decode), so frame bugs surface in fast unit tests, and
  ``kill()`` drops undelivered replies — the "worker died mid-batch"
  fault the requeue tests inject.
- ``SubprocessTransport`` spawns ``python -m repro.hserve.worker`` and
  speaks frames over its stdin/stdout pipes — a real process boundary
  with its own XLA host devices.
"""

from __future__ import annotations

import json
import os
import struct
import subprocess
import sys
from collections import deque
from typing import Any, Dict, Mapping, Tuple

import numpy as np

MAGIC = b"HSW1"
_LEN = struct.Struct("<I")

__all__ = [
    "WorkerDied",
    "encode_frame",
    "decode_frame",
    "read_frame",
    "InProcTransport",
    "SubprocessTransport",
]


class WorkerDied(RuntimeError):
    """The worker on the other end of a transport is gone.

    Raised by ``send``/``recv`` on broken pipes, EOF mid-frame, or a
    killed in-process worker.  The frontend catches this, marks the
    worker dead, and requeues its in-flight batch.
    """


def encode_frame(head: Dict[str, Any],
                 arrays: Mapping[str, np.ndarray] | None = None) -> bytes:
    """Serialize a message dict + named ndarrays into one frame."""
    arrays = arrays or {}
    manifest = []
    payloads = []
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        manifest.append({"name": name, "dtype": str(a.dtype),
                         "shape": list(a.shape)})
        payloads.append(a.tobytes())
    header = dict(head)
    header["arrays"] = manifest
    hj = json.dumps(header, separators=(",", ":")).encode()
    return b"".join([MAGIC, _LEN.pack(len(hj)), hj, *payloads])


def decode_frame(buf: bytes) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """Inverse of :func:`encode_frame` over a complete in-memory frame."""
    if buf[:4] != MAGIC:
        raise WorkerDied(f"bad frame magic {buf[:4]!r}")
    (hlen,) = _LEN.unpack(buf[4:8])
    head = json.loads(buf[8:8 + hlen].decode())
    off = 8 + hlen
    arrays: Dict[str, np.ndarray] = {}
    for m in head.pop("arrays", []):
        dt = np.dtype(m["dtype"])
        n = int(np.prod(m["shape"], dtype=np.int64)) * dt.itemsize
        arrays[m["name"]] = np.frombuffer(
            buf[off:off + n], dtype=dt).reshape(m["shape"])
        off += n
    return head, arrays


def _read_exact(stream: Any, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        c = stream.read(n - got)
        if not c:
            raise WorkerDied("worker stream closed mid-frame")
        chunks.append(c)
        got += len(c)
    return b"".join(chunks)


def read_frame(stream: Any) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """Read one frame from a binary stream (worker stdout / stdin)."""
    magic = stream.read(4)
    if not magic:
        raise WorkerDied("worker stream closed (EOF)")
    if magic != MAGIC:
        raise WorkerDied(f"bad frame magic {magic!r}")
    (hlen,) = _LEN.unpack(_read_exact(stream, 4))
    head = json.loads(_read_exact(stream, hlen).decode())
    arrays: Dict[str, np.ndarray] = {}
    for m in head.pop("arrays", []):
        dt = np.dtype(m["dtype"])
        n = int(np.prod(m["shape"], dtype=np.int64)) * dt.itemsize
        arrays[m["name"]] = np.frombuffer(
            _read_exact(stream, n), dtype=dt).reshape(m["shape"])
    return head, arrays


class InProcTransport:
    """Drive a ``WorkerEngine`` in-process, through the byte framing.

    ``send`` is synchronous: the worker computes the reply inside the
    call and the reply frame is buffered until ``recv``.  ``kill()``
    between the two models a worker that finished computing but died
    before delivering — exactly the in-flight window the frontend must
    requeue.
    """

    kind = "inproc"

    def __init__(self, worker: Any) -> None:
        self.worker = worker
        self._replies: deque = deque()
        self._dead = False

    @property
    def alive(self) -> bool:
        return not self._dead

    def send(self, head: Dict[str, Any],
             arrays: Mapping[str, np.ndarray] | None = None) -> None:
        if self._dead:
            raise WorkerDied(f"worker {self.worker.wid} is dead")
        h, a = decode_frame(encode_frame(head, arrays))
        reply = self.worker.handle(h, a)
        if reply is not None:
            rhead, rarrays = reply
            self._replies.append(encode_frame(rhead, rarrays))

    def recv(self) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        if self._dead:
            raise WorkerDied(f"worker {self.worker.wid} is dead")
        if not self._replies:
            raise WorkerDied(
                f"worker {self.worker.wid}: no reply pending")
        return decode_frame(self._replies.popleft())

    def kill(self) -> None:
        """Simulate worker death: drop any undelivered replies."""
        self._dead = True
        self._replies.clear()

    def revive(self) -> None:
        """Bring a killed in-process worker back (test harness only)."""
        self._dead = False
        self._replies.clear()

    def close(self) -> None:
        self._dead = True
        self._replies.clear()


class SubprocessTransport:
    """Frames over the stdin/stdout pipes of a spawned worker process."""

    kind = "subprocess"

    def __init__(self, *, devices: int = 1, env: Mapping[str, str] | None = None,
                 ) -> None:
        # spawn args are kept so :meth:`respawn` can relaunch an
        # identical process after a crash
        self._devices = devices
        self._env = dict(env) if env else None
        self.proc = self._spawn()

    def _spawn(self) -> subprocess.Popen:
        import repro
        # repro may be a namespace package (__file__ is None) — resolve
        # the src dir from its search path instead
        src_dir = os.path.dirname(
            os.path.abspath(list(repro.__path__)[0]))
        penv = dict(os.environ)
        penv.update(self._env or {})
        pp = penv.get("PYTHONPATH", "")
        penv["PYTHONPATH"] = src_dir + (os.pathsep + pp if pp else "")
        penv["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={self._devices}")
        penv.setdefault("JAX_PLATFORMS", "cpu")
        # -c instead of -m: the package __init__ imports the worker
        # module, so `-m` would re-execute it as __main__ (runpy warns)
        return subprocess.Popen(
            [sys.executable, "-c",
             "from repro.hserve.worker import main; main()"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            env=penv)

    def respawn(self) -> None:
        """Relaunch the worker process with the original spawn args.

        The new process is a BLANK interpreter: it has no params, keys,
        tables, or compiled steps — the owner must replay the init
        frame (and await its ack) before routing work to it.
        `HEFrontend.revive_workers` does exactly that.
        """
        if self.alive:
            self.proc.kill()
        self.proc.wait(timeout=30)
        self.proc = self._spawn()

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def send(self, head: Dict[str, Any],
             arrays: Mapping[str, np.ndarray] | None = None) -> None:
        if not self.alive:
            raise WorkerDied("worker process exited "
                             f"(rc={self.proc.returncode})")
        try:
            assert self.proc.stdin is not None
            self.proc.stdin.write(encode_frame(head, arrays))
            self.proc.stdin.flush()
        except (BrokenPipeError, OSError) as e:
            raise WorkerDied(f"worker pipe broke: {e}") from e

    def recv(self) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        assert self.proc.stdout is not None
        return read_frame(self.proc.stdout)

    def kill(self) -> None:
        self.proc.kill()
        self.proc.wait(timeout=30)

    def close(self) -> None:
        if self.alive:
            try:
                self.send({"type": "shutdown"})
                self.proc.wait(timeout=30)
            except (WorkerDied, subprocess.TimeoutExpired):
                self.proc.kill()
                self.proc.wait(timeout=30)
