"""Circuit-aware scheduler: lookahead co-batching + table prefetch.

`HEServer.submit_circuit` drops each READY node into the generic FIFO
queue, so two circuits one stage out of phase never share a batch: the
drain policy pads circuit A's lone (op, level) bucket while circuit B's
identical node is one parent-completion away from joining it. That
throws away exactly the win the paper's batching argument (§V) and
Medha's microcoded instruction scheduling are about — the level schedule
of a validated circuit is KNOWN ahead of execution, so the server can
look at it.

:class:`CircuitScheduler` walks every submitted circuit's validated
(logq, logp) schedule (`hserve.circuit.circuit_schedule`) and keeps, per
queue bucket key, the set of nodes that are *going to* arrive:

  - **Lookahead co-batching** — `expected_within(key, horizon)` counts
    not-yet-ready nodes whose bucket key matches and whose chain of
    unfinished ancestors is at most `horizon` engine batches deep. The
    server's drain flush defers an under-full bucket with expected
    siblings in favor of one with none, so the sibling lands in the same
    batch instead of a padded straggler pair (cross-circuit co-batch
    rate and pad_frac are reported in BENCH_serve_he.json's `scheduler`
    block).
  - **Progress guarantee** — deferral alone DEADLOCKS: in a 2-deep
    circuit [mul(x,x), mul(0,0)] both nodes share one bucket key, so the
    only non-empty bucket "expects a sibling" whose parent is the bucket
    itself, and a drain that keeps deferring never serves anything.
    `drain_key` therefore always returns SOME non-empty bucket — when
    every candidate is deferred, the oldest flushes anyway (the expected
    sibling's parent is necessarily queued or in flight, so flushing it
    is the only way the sibling ever arrives). tests/test_hserve.py pins
    this with exactly that 2-deep circuit submitted right before
    drain().
  - **Table prefetch** — `prefetch_levels(...)` materializes the NEXT
    levels' TableCache row/column slices (and their per-np iCRT entries,
    the only host-side build) while the current batch is in flight,
    riding the same `OpEngine.dispatch`/`wait` double buffer the overlap
    path uses. Successor levels come from the registered schedules; the
    batch op's own output level (rescale/mod-down) is prefetched too.

The scheduler NEVER changes results — it only reorders drain flushes
and warms caches — so scheduled vs. unscheduled serving is bitwise
identical (asserted on the 1-device and 8-device mesh harnesses).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.hserve.queue import BucketKey

__all__ = ["CircuitScheduler"]


class _SchedCircuit:
    """Per-circuit lookahead state: the static schedule + progress."""

    __slots__ = ("keys", "int_args", "succ", "enqueued", "completed")

    def __init__(self, keys: List[BucketKey],
                 int_args: List[Tuple[int, ...]]):
        self.keys = keys
        self.int_args = int_args            # per node: earlier-node refs
        self.succ: List[Tuple[int, ...]] = [() for _ in keys]
        for i, args in enumerate(int_args):
            for a in set(args):
                self.succ[a] += (i,)
        self.enqueued: Set[int] = set()
        self.completed: Set[int] = set()

    def steps_to_ready(self, i: int, memo: Dict[int, int]) -> int:
        """Engine batches that must complete before node i can enter the
        queue: 0 if already enqueued (or done), else one more than its
        deepest unfinished ancestor chain."""
        if i in self.enqueued or i in self.completed:
            return 0
        if i in memo:
            return memo[i]
        memo[i] = d = 1 + max(
            (self.steps_to_ready(a, memo)
             for a in self.int_args[i] if a not in self.completed),
            default=0)
        return d


class CircuitScheduler:
    """Cross-circuit lookahead over validated level schedules.

    lookahead: horizon (in engine batches) within which a pending node
        counts as an expected sibling for its bucket; 0 disables
        deferral, larger values wait for deeper-chained siblings.
    cost_model: optional `repro.analysis.cost.CostModel` consulted by
        the deferral decision: deferring a bucket is only worth a drain
        round trip when the padded batch it avoids actually costs
        device time. Limb-cheap buckets (add/rescale/mod_down at µs
        scale) flush immediately even with siblings coming — waiting
        saves padding on an op whose whole batch is cheaper than the
        bookkeeping. None (the default) keeps the pure
        expected_within policy, bit-for-bit.
    defer_min_s: the device-seconds a padded batch must waste before
        deferral is worth it (only read when cost_model is set).
    """

    def __init__(self, lookahead: int = 2, *, cost_model=None,
                 defer_min_s: float = 1e-3):
        if lookahead < 0:               # not assert: gone under python -O
            raise ValueError(f"lookahead must be >= 0, got {lookahead}")
        self.lookahead = lookahead
        self.cost_model = cost_model
        self.defer_min_s = defer_min_s
        self._circ: Dict[int, _SchedCircuit] = {}
        # pending (registered, not yet enqueued) nodes per bucket key
        self._expected: Dict[BucketKey, Set[Tuple[int, int]]] = {}
        self.deferrals = 0
        self.cost_skips = 0             # deferrals skipped as too cheap
        self.prefetches = 0
        self.prefetched_levels: Set[int] = set()

    # ---- circuit lifecycle (driven by HEServer) --------------------------

    def register(self, cid: int, keys: Sequence[BucketKey],
                 int_args: Sequence[Tuple[int, ...]]) -> None:
        """Adopt one validated circuit's schedule: per-node bucket keys
        and earlier-node argument references (str inputs excluded)."""
        sc = _SchedCircuit(list(keys), list(int_args))
        self._circ[cid] = sc
        for i, k in enumerate(sc.keys):
            self._expected.setdefault(k, set()).add((cid, i))

    def on_enqueued(self, cid: int, i: int) -> None:
        """Node i's request entered the queue: it is no longer expected —
        the queue itself now advertises it."""
        sc = self._circ.get(cid)
        if sc is None:
            return
        sc.enqueued.add(i)
        self._drop_expected(sc.keys[i], cid, i)

    def on_completed(self, cid: int, i: int) -> None:
        sc = self._circ.get(cid)
        if sc is None:
            return
        sc.enqueued.discard(i)
        sc.completed.add(i)

    def on_finished(self, cid: int) -> None:
        """Circuit done (its last node completed): purge every leftover
        expectation — dangling unsubmitted nodes will never arrive, and a
        stale expectation would defer their bucket forever."""
        sc = self._circ.pop(cid, None)
        if sc is None:
            return
        for i, k in enumerate(sc.keys):
            if i not in sc.enqueued and i not in sc.completed:
                self._drop_expected(k, cid, i)

    def _drop_expected(self, key: BucketKey, cid: int, i: int) -> None:
        s = self._expected.get(key)
        if s is not None:
            s.discard((cid, i))
            if not s:
                del self._expected[key]

    # ---- the flush-policy hooks ------------------------------------------

    def expected_within(self, key: BucketKey,
                        horizon: Optional[int] = None) -> int:
        """Pending same-key nodes at most `horizon` engine batches away
        (default: the configured lookahead)."""
        horizon = self.lookahead if horizon is None else horizon
        pend = self._expected.get(key)
        if not pend:
            return 0
        n = 0
        memos: Dict[int, Dict[int, int]] = {}
        for cid, i in pend:
            sc = self._circ[cid]
            if sc.steps_to_ready(i, memos.setdefault(cid, {})) <= horizon:
                n += 1
        return n

    def _worth_deferring(self, key: BucketKey, depth: int,
                         batch: int) -> bool:
        """Cost-model gate on deferral: is the padding this bucket
        would waste worth a drain round trip? Without a cost model,
        always yes (the pre-cost-model policy, bit-for-bit). With one,
        the padded lanes' estimated device-seconds must reach
        defer_min_s — an under-full add bucket at 2 limbs pads
        microseconds and should just flush."""
        if self.cost_model is None:
            return True
        op, logq = key[0], key[1]
        n_slots = key[2] if op == "slot_sum" else None
        pad_s = (batch - depth) * self.cost_model.op_seconds(
            op, logq, n_slots=n_slots)
        if pad_s >= self.defer_min_s:
            return True
        self.cost_skips += 1
        return False

    def drain_key(self, queue, batch: int) -> Optional[BucketKey]:
        """The drain flush's bucket choice: oldest non-empty bucket with
        no expected siblings within the lookahead horizon; under-full
        buckets with siblings coming are deferred (counted) — IF the
        cost model (when configured) says the avoided padding is worth
        device time (see :meth:`_worth_deferring`). PROGRESS
        GUARANTEE: if every non-empty bucket is deferred, the oldest
        flushes anyway — the sibling's parents sit in the queue or in
        flight, and deferring everything would stall drain() forever
        (the drain-vs-circuit deadlock this module's docstring walks
        through)."""
        depths = queue.bucket_depths()
        fallback = None
        for k, depth in depths.items():
            if fallback is None:
                fallback = k
            if depth < batch and self.expected_within(k) \
                    and self._worth_deferring(k, depth, batch):
                self.deferrals += 1
                continue
            return k
        return fallback

    # ---- prefetch ---------------------------------------------------------

    @staticmethod
    def levels_for_key(key: BucketKey) -> Set[int]:
        """Levels (logq) a request with this bucket key touches: its
        input level, plus — for the level-CHANGING ops, whose target is
        encoded in the key's extra — the level it produces. The single
        home of the op → output-level mapping (used both for successor
        keys and for the in-flight batch's own key). mod_raise walks
        UP the chain (a bootstrap circuit's raised-level tail): without
        it, prefetch only ever warms descending levels and every
        post-mod-raise node cold-misses the TableCache."""
        op, logq, extra = key
        out = {logq}
        if op == "rescale":
            out.add(logq - extra)
        elif op in ("mod_down", "mod_raise"):
            out.add(extra)
        return out

    def next_levels(self, tags: Iterable[Tuple[int, int]]) -> Set[int]:
        """Levels the successor nodes of the given (cid, node) tags will
        touch — inputs and (for level-dropping successors) outputs, so
        the slice exists before the grandchild's step ever asks for
        it."""
        out: Set[int] = set()
        for cid, i in tags:
            sc = self._circ.get(cid)
            if sc is None:
                continue
            for j in sc.succ[i]:
                if j not in sc.completed:
                    out |= self.levels_for_key(sc.keys[j])
        return out

    def prefetch_levels(self, cache, levels: Iterable[int]) -> int:
        """Materialize table slices for `levels` that the cache has not
        served yet (row/column views of the resident set + the per-np
        iCRT entries — the latter are the host-side build this hides
        behind the in-flight batch). Returns how many were cold."""
        n = 0
        for logq in levels:
            if cache.has_level(logq):
                continue
            cache.level_tables(logq)
            self.prefetches += 1
            self.prefetched_levels.add(logq)
            n += 1
        return n

    # ---- accounting -------------------------------------------------------

    def reset_counters(self) -> None:
        """Zero the deferral/prefetch counters (a fresh measurement
        window — HEServer.reset_metrics calls this); registered circuit
        schedules are kept."""
        self.deferrals = 0
        self.cost_skips = 0
        self.prefetches = 0
        self.prefetched_levels = set()

    def stats(self) -> dict:
        return {
            "lookahead": self.lookahead,
            "cost_model": self.cost_model is not None,
            "circuits_tracked": len(self._circ),
            "deferrals": self.deferrals,
            "cost_skips": self.cost_skips,
            "prefetches": self.prefetches,
            "prefetched_levels": sorted(self.prefetched_levels),
        }
