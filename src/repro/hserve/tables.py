"""Level-aware resident table cache for the HE serving runtime.

A multi-level circuit touches many moduli logq < logQ, and a naive server
rebuilds + re-uploads `region_tables` per level. But almost everything in
a region-table pytree is prime-pool state (twiddles, Montgomery/Shoup
constants, CRT rows): at level logq those arrays are STRICT row/column
slices of the top level's — the table set Medha keeps resident on chip.
So this cache:

  - materializes the prime-pool tables ONCE on device, at full
    (max_np, ·) shapes (the `resident` pytree), and serves every level's
    region-1/2 tables as row slices ``[:np]`` (plus a column slice
    ``[:qlimbs]`` for the CRT rows);
  - caches the few genuinely per-np entries (the iCRT tables, which
    depend on P = ∏ first-np primes) keyed by np — shared across every
    level and region that lands on the same prime count;
  - holds the evaluation key, any rotation keys, and the conjugation key
    as device pytrees in `dist.he_pipeline.evk_tables` form (the engine
    slices key rows ``[:np2]`` per level inside the step). This is
    Medha's resident-key design: every Galois key is just another
    evk-shaped pytree riding the same region-2 machinery.

The sliced pytrees are value-identical to a freshly built
``runtime_tables(make_context(params, logq), evk)`` at every level
(tests/test_hserve.py asserts array equality), so serving from the cache
cannot change a single output bit.

A note on ``quot_fix`` (present in the region tables since the Pallas
kernel routing landed): it is the table of ⌊β²/p_j⌋ as two β-bit limbs,
one row per prime — the fixed-point reciprocal the TPU iCRT kernel uses
to estimate the accumulator quotient where the reference path uses an
f64 multiply (TPUs have no f64; see `kernels/icrt/icrt.py` and
`IcrtTables.quot_fix` in `core/context.py`). Although it is built by
``build_icrt_tables``, it depends only on the prime — not on
P = ∏ primes — so unlike the other iCRT entries it row-slices from the
resident set exactly like the prime-pool tables (``_ROW_KEYS`` below),
and one resident copy serves every level and region.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.cipher import EvalKey
from repro.core.context import build_global_tables, build_icrt_tables
from repro.core.params import HEParams
from repro.dist.he_pipeline import evk_tables

__all__ = ["PlainCache", "TableCache"]


class PlainCache:
    """LRU cache of encoded plaintext operands keyed by (hash, logq).

    Extracted from TableCache so the multi-host frontend — which owns
    the plain-operand cache but NO device tables (those live in the
    workers) — can hold one without materializing a table set. The
    ROADMAP "plaintext operand caching" story: affine-layer weights
    encode once, every later request references the hash.
    LRU-bounded (cap_mib; None = unbounded): a server fed per-request
    one-shot operands must not grow without limit.
    """

    def __init__(self, cap_mib: Optional[float] = 256.0):
        self._plain: "OrderedDict[Tuple[str, int], np.ndarray]" = \
            OrderedDict()
        self._cap = None if cap_mib is None else int(cap_mib * 2**20)
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def put(self, h: str, logq: int, pt) -> np.ndarray:
        """Cache an encoded operand under (hash, logq); returns the
        resident copy. An existing entry wins (and counts a hit — the
        client re-sent an operand the server already held). The resident
        array is marked read-only, so the request queue can alias it
        instead of re-copying the (N, qlimbs) buffer on every submit
        that resolves from the cache."""
        key = (h, int(logq))
        if key in self._plain:
            self.hits += 1
            self._plain.move_to_end(key)
        else:
            self.misses += 1
            if isinstance(pt, np.ndarray) and not pt.flags.writeable \
                    and pt.base is None:
                arr = pt       # adopt an owned immutable buffer as-is
            else:              # (base check: a read-only VIEW can have
                arr = np.array(pt)            # a writeable base)
                arr.setflags(write=False)
            self._plain[key] = arr
            self._bytes += arr.nbytes
            # LRU eviction (never the entry just inserted). In-flight
            # circuits resolved their arrays at submit and keep their
            # own references, so eviction cannot break queued work —
            # only a LATER hash-only reference to an evicted key fails
            # (and re-registering it is always legal).
            while self._cap is not None and len(self._plain) > 1 \
                    and self._bytes > self._cap:
                _, old = self._plain.popitem(last=False)
                self._bytes -= old.nbytes
                self.evictions += 1
        return self._plain[key]

    def get(self, h: str, logq: int) -> np.ndarray:
        """The cached encoded operand for (hash, logq); KeyError (before
        anything is enqueued) when the client references a hash the
        server never saw at this level."""
        key = (h, int(logq))
        if key not in self._plain:
            raise KeyError(
                f"no cached plaintext for hash {h!r} at logq={logq}; "
                f"send the encoded operand once (pt=..., pt_hash=...) "
                f"before referencing it by hash alone")
        self.hits += 1
        self._plain.move_to_end(key)
        return self._plain[key]

    def has(self, h: str, logq: int) -> bool:
        return (h, int(logq)) in self._plain

    def __len__(self) -> int:
        return len(self._plain)

    @property
    def nbytes(self) -> int:
        return self._bytes

# Resident (prime-pool) entries: rows slice by np; crt rows also slice
# their limb column by the level's qlimbs.
_ROW_KEYS = ("primes", "psi_rev", "psi_rev_shoup", "ipsi_rev",
             "ipsi_rev_shoup", "n_inv", "n_inv_shoup", "pprime", "r2",
             "p_inv_f64", "quot_fix")
_ROWCOL_KEYS = ("crt_tb", "crt_tb_shoup")
# Per-np entries (depend on P = ∏ first-np primes; cached by np).
_ICRT_KEYS = ("inv_P", "inv_P_shoup", "pdivp", "P_limbs", "P_half_limbs")


class TableCache:
    """One resident device table set; per-level views by slicing."""

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None,
                 conj_key: Optional[EvalKey] = None,
                 plain_cache_mib: Optional[float] = 256.0):
        self.params = params
        g = build_global_tables(params)
        top = build_icrt_tables(params, params.max_np)
        self._resident: Dict[str, jnp.ndarray] = {
            "primes": jnp.asarray(g.primes),
            "psi_rev": jnp.asarray(g.psi_rev),
            "psi_rev_shoup": jnp.asarray(g.psi_rev_shoup),
            "ipsi_rev": jnp.asarray(g.ipsi_rev),
            "ipsi_rev_shoup": jnp.asarray(g.ipsi_rev_shoup),
            "n_inv": jnp.asarray(g.n_inv),
            "n_inv_shoup": jnp.asarray(g.n_inv_shoup),
            "pprime": jnp.asarray(g.pprime),
            "r2": jnp.asarray(g.r2),
            "crt_tb": jnp.asarray(g.crt_tb),
            "crt_tb_shoup": jnp.asarray(g.crt_tb_shoup),
            "p_inv_f64": jnp.asarray(g.p_inv_f64),
            # ⌊β²/p⌋ depends only on the prime, so despite living in
            # IcrtTables it row-slices like the pool tables do
            "quot_fix": jnp.asarray(top.quot_fix),
        }
        self._icrt_dev: Dict[int, Dict[str, jnp.ndarray]] = {}
        self._levels: Dict[int, Tuple[Dict, Dict]] = {}
        self._ek = {k: jnp.asarray(v) for k, v in evk_tables(evk).items()} \
            if evk is not None else None
        self._rot = {
            int(r): {k: jnp.asarray(v) for k, v in evk_tables(rk).items()}
            for r, rk in (rot_keys or {}).items()}
        self._conj = {k: jnp.asarray(v)
                      for k, v in evk_tables(conj_key).items()} \
            if conj_key is not None else None
        self.hits = 0
        self.misses = 0
        # repro.obs.Tracer (optional): cold level_tables misses emit
        # "tables.level_slice" engine spans — the host-side build the
        # scheduler's prefetch hides behind the in-flight batch.
        self.tracer = None
        # encoded plaintext operands keyed by (message hash, logq) —
        # see PlainCache (extracted so the multi-host frontend can own
        # one without any device tables)
        self.plain = PlainCache(cap_mib=plain_cache_mib)

    # ---- per-level region tables ----------------------------------------

    def level_tables(self, logq: int) -> Tuple[Dict, Dict]:
        """(t1, t2) region-table pytrees for modulus 2^logq, as slices of
        the resident set. Cached per level; cheap on miss (no host
        rebuild, no re-upload of pool tables)."""
        if logq in self._levels:
            self.hits += 1
            return self._levels[logq]
        self.misses += 1
        span = self.tracer.span("tables.level_slice", cat="engine",
                                lane="engine", args={"logq": logq}) \
            if self.tracer is not None else None
        p = self.params
        K = p.qlimbs(logq)
        t1 = self._region_view(p.np_region1(logq), K)
        t2 = self._region_view(p.np_region2(logq), K)
        self._levels[logq] = (t1, t2)
        if span is not None:
            span.end()
        return t1, t2

    def has_level(self, logq: int) -> bool:
        """Whether 2^logq's slice views are already materialized — the
        circuit-aware scheduler's prefetch asks before warming a level
        behind the in-flight batch (`CircuitScheduler.prefetch_levels`)."""
        return logq in self._levels

    def _region_view(self, npn: int, K: int) -> Dict[str, jnp.ndarray]:
        t = {k: self._resident[k][:npn] for k in _ROW_KEYS}
        t.update({k: self._resident[k][:npn, :K] for k in _ROWCOL_KEYS})
        t.update(self._icrt(npn))
        return t

    def _icrt(self, npn: int) -> Dict[str, jnp.ndarray]:
        if npn not in self._icrt_dev:
            tabs = build_icrt_tables(self.params, npn)
            self._icrt_dev[npn] = {
                k: jnp.asarray(getattr(tabs, k)) for k in _ICRT_KEYS}
        return self._icrt_dev[npn]

    # ---- plaintext operands ----------------------------------------------

    def put_plain(self, h: str, logq: int, pt) -> np.ndarray:
        """Cache an encoded plaintext operand under (hash, logq); see
        :meth:`PlainCache.put`."""
        return self.plain.put(h, logq, pt)

    def get_plain(self, h: str, logq: int) -> np.ndarray:
        """The cached encoded operand for (hash, logq); see
        :meth:`PlainCache.get`."""
        return self.plain.get(h, logq)

    def has_plain(self, h: str, logq: int) -> bool:
        """Whether (hash, logq) is cached — `repro.client`'s compile pass
        asks this to skip the client-side encode entirely on reuse."""
        return self.plain.has(h, logq)

    @property
    def plain_hits(self) -> int:
        return self.plain.hits

    @property
    def plain_misses(self) -> int:
        return self.plain.misses

    @property
    def plain_evictions(self) -> int:
        return self.plain.evictions

    # ---- keys ------------------------------------------------------------

    def evk(self) -> Dict[str, jnp.ndarray]:
        if self._ek is None:
            raise ValueError("no evaluation key loaded (mul unavailable)")
        return self._ek

    def rot_key(self, r: int) -> Dict[str, jnp.ndarray]:
        try:
            return self._rot[int(r)]
        except KeyError:
            raise KeyError(
                f"no rotation key for r={r}; loaded: "
                f"{sorted(self._rot)}") from None

    def add_rot_key(self, r: int, rk: EvalKey) -> None:
        self._rot[int(r)] = {
            k: jnp.asarray(v) for k, v in evk_tables(rk).items()}

    def conj_key(self) -> Dict[str, jnp.ndarray]:
        if self._conj is None:
            raise ValueError(
                "no conjugation key loaded (conjugate unavailable)")
        return self._conj

    def add_conj_key(self, ck: EvalKey) -> None:
        self._conj = {k: jnp.asarray(v) for k, v in evk_tables(ck).items()}

    @property
    def has_conj_key(self) -> bool:
        return self._conj is not None

    @property
    def rotation_amounts(self):
        return sorted(self._rot)

    # ---- accounting ------------------------------------------------------

    def stats(self) -> dict:
        res_b = sum(int(v.size) * v.dtype.itemsize
                    for v in self._resident.values())
        icrt_b = sum(int(v.size) * v.dtype.itemsize
                     for d in self._icrt_dev.values() for v in d.values())
        key_b = sum(int(v.size) * v.dtype.itemsize
                    for d in ([self._ek] if self._ek else [])
                    + ([self._conj] if self._conj else [])
                    + list(self._rot.values()) for v in d.values())
        return {
            "levels_materialized": sorted(self._levels),
            "np_sets": sorted(self._icrt_dev),
            "rot_keys": self.rotation_amounts,
            "conj_key": self.has_conj_key,
            "hits": self.hits,
            "misses": self.misses,
            "plain_entries": len(self.plain),
            "plain_hits": self.plain.hits,
            "plain_misses": self.plain.misses,
            "plain_evictions": self.plain.evictions,
            "resident_mib": round(res_b / 2**20, 3),
            "icrt_mib": round(icrt_b / 2**20, 3),
            "keys_mib": round(key_b / 2**20, 3),
            "plain_mib": round(self.plain.nbytes / 2**20, 3),
        }
