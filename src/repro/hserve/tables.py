"""Level-aware resident table cache for the HE serving runtime.

A multi-level circuit touches many moduli logq < logQ, and a naive server
rebuilds + re-uploads `region_tables` per level. But almost everything in
a region-table pytree is prime-pool state (twiddles, Montgomery/Shoup
constants, CRT rows): at level logq those arrays are STRICT row/column
slices of the top level's — the table set Medha keeps resident on chip.
So this cache:

  - materializes the prime-pool tables ONCE on device, at full
    (max_np, ·) shapes (the `resident` pytree), and serves every level's
    region-1/2 tables as row slices ``[:np]`` (plus a column slice
    ``[:qlimbs]`` for the CRT rows);
  - caches the few genuinely per-np entries (the iCRT tables, which
    depend on P = ∏ first-np primes) keyed by np — shared across every
    level and region that lands on the same prime count;
  - holds the evaluation key and any rotation keys as device pytrees in
    `dist.he_pipeline.evk_tables` form (the engine slices key rows
    ``[:np2]`` per level inside the step).

The sliced pytrees are value-identical to a freshly built
``runtime_tables(make_context(params, logq), evk)`` at every level
(tests/test_hserve.py asserts array equality), so serving from the cache
cannot change a single output bit.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.cipher import EvalKey
from repro.core.context import build_global_tables, build_icrt_tables
from repro.core.params import HEParams
from repro.dist.he_pipeline import evk_tables

__all__ = ["TableCache"]

# Resident (prime-pool) entries: rows slice by np; crt rows also slice
# their limb column by the level's qlimbs.
_ROW_KEYS = ("primes", "psi_rev", "psi_rev_shoup", "ipsi_rev",
             "ipsi_rev_shoup", "n_inv", "n_inv_shoup", "pprime", "r2",
             "p_inv_f64", "quot_fix")
_ROWCOL_KEYS = ("crt_tb", "crt_tb_shoup")
# Per-np entries (depend on P = ∏ first-np primes; cached by np).
_ICRT_KEYS = ("inv_P", "inv_P_shoup", "pdivp", "P_limbs", "P_half_limbs")


class TableCache:
    """One resident device table set; per-level views by slicing."""

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None):
        self.params = params
        g = build_global_tables(params)
        top = build_icrt_tables(params, params.max_np)
        self._resident: Dict[str, jnp.ndarray] = {
            "primes": jnp.asarray(g.primes),
            "psi_rev": jnp.asarray(g.psi_rev),
            "psi_rev_shoup": jnp.asarray(g.psi_rev_shoup),
            "ipsi_rev": jnp.asarray(g.ipsi_rev),
            "ipsi_rev_shoup": jnp.asarray(g.ipsi_rev_shoup),
            "n_inv": jnp.asarray(g.n_inv),
            "n_inv_shoup": jnp.asarray(g.n_inv_shoup),
            "pprime": jnp.asarray(g.pprime),
            "r2": jnp.asarray(g.r2),
            "crt_tb": jnp.asarray(g.crt_tb),
            "crt_tb_shoup": jnp.asarray(g.crt_tb_shoup),
            "p_inv_f64": jnp.asarray(g.p_inv_f64),
            # ⌊β²/p⌋ depends only on the prime, so despite living in
            # IcrtTables it row-slices like the pool tables do
            "quot_fix": jnp.asarray(top.quot_fix),
        }
        self._icrt_dev: Dict[int, Dict[str, jnp.ndarray]] = {}
        self._levels: Dict[int, Tuple[Dict, Dict]] = {}
        self._ek = {k: jnp.asarray(v) for k, v in evk_tables(evk).items()} \
            if evk is not None else None
        self._rot = {
            int(r): {k: jnp.asarray(v) for k, v in evk_tables(rk).items()}
            for r, rk in (rot_keys or {}).items()}
        self.hits = 0
        self.misses = 0

    # ---- per-level region tables ----------------------------------------

    def level_tables(self, logq: int) -> Tuple[Dict, Dict]:
        """(t1, t2) region-table pytrees for modulus 2^logq, as slices of
        the resident set. Cached per level; cheap on miss (no host
        rebuild, no re-upload of pool tables)."""
        if logq in self._levels:
            self.hits += 1
            return self._levels[logq]
        self.misses += 1
        p = self.params
        K = p.qlimbs(logq)
        t1 = self._region_view(p.np_region1(logq), K)
        t2 = self._region_view(p.np_region2(logq), K)
        self._levels[logq] = (t1, t2)
        return t1, t2

    def _region_view(self, npn: int, K: int) -> Dict[str, jnp.ndarray]:
        t = {k: self._resident[k][:npn] for k in _ROW_KEYS}
        t.update({k: self._resident[k][:npn, :K] for k in _ROWCOL_KEYS})
        t.update(self._icrt(npn))
        return t

    def _icrt(self, npn: int) -> Dict[str, jnp.ndarray]:
        if npn not in self._icrt_dev:
            tabs = build_icrt_tables(self.params, npn)
            self._icrt_dev[npn] = {
                k: jnp.asarray(getattr(tabs, k)) for k in _ICRT_KEYS}
        return self._icrt_dev[npn]

    # ---- keys ------------------------------------------------------------

    def evk(self) -> Dict[str, jnp.ndarray]:
        if self._ek is None:
            raise ValueError("no evaluation key loaded (mul unavailable)")
        return self._ek

    def rot_key(self, r: int) -> Dict[str, jnp.ndarray]:
        try:
            return self._rot[int(r)]
        except KeyError:
            raise KeyError(
                f"no rotation key for r={r}; loaded: "
                f"{sorted(self._rot)}") from None

    def add_rot_key(self, r: int, rk: EvalKey) -> None:
        self._rot[int(r)] = {
            k: jnp.asarray(v) for k, v in evk_tables(rk).items()}

    @property
    def rotation_amounts(self):
        return sorted(self._rot)

    # ---- accounting ------------------------------------------------------

    def stats(self) -> dict:
        res_b = sum(int(v.size) * v.dtype.itemsize
                    for v in self._resident.values())
        icrt_b = sum(int(v.size) * v.dtype.itemsize
                     for d in self._icrt_dev.values() for v in d.values())
        key_b = sum(int(v.size) * v.dtype.itemsize
                    for d in ([self._ek] if self._ek else [])
                    + list(self._rot.values()) for v in d.values())
        return {
            "levels_materialized": sorted(self._levels),
            "np_sets": sorted(self._icrt_dev),
            "rot_keys": self.rotation_amounts,
            "hits": self.hits,
            "misses": self.misses,
            "resident_mib": round(res_b / 2**20, 3),
            "icrt_mib": round(icrt_b / 2**20, 3),
            "keys_mib": round(key_b / 2**20, 3),
        }
