"""HEFrontend: the multi-host disaggregated serving tier.

The monolithic ``HEServer`` owns both halves of serving: the
queue/scheduler/plain-cache frontend AND the mesh/tables/engine
backend. This module splits them. :class:`HEFrontend` keeps the
engine-free serving core (it subclasses HEServer and reuses
``_init_core`` / ``_choose_flush`` / ``_pop_assemble`` / ``_complete``
verbatim — submit, circuits, metrics, scheduling are all inherited) and
routes assembled batches to N :class:`~repro.hserve.worker.WorkerEngine`
processes over :mod:`~repro.hserve.transport` frames. Each worker owns
its own device mesh, resident TableCache, and jit-once OpEngine steps —
the per-host state that cannot be shared across processes.

Routing is (op, level)-bucket affinity with load-first tiebreak:
an idle worker always beats a busy one (a single hot bucket must spill
across hosts or scaling is zero), and among equally-loaded workers the
one whose compiled-step/table cache is already warm for the bucket
wins — so in steady state hot levels stay pinned to the worker holding
their table slices, and a spill warms exactly one new worker.

Health and death: workers publish ``runtime.monitor.Heartbeat`` files
(registry snapshots embedded); the frontend marks a worker dead on a
transport error OR a stale heartbeat (``check_workers``), requeues the
dead worker's in-flight batch at the original rids — circuit routing
and FIFO order survive — and re-routes on the next poll. Ops are
deterministic integer arithmetic, so a re-served batch is bitwise
identical to the first attempt. With every worker dead and work still
queued, :class:`NoLiveWorkersError` is raised (drain propagates it
instead of spinning).

``runtime.failures.FailureInjector(kill_worker_at={wid: n})`` drives
worker death deterministically for the fault tests and the bench's
requeue block.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cipher import Ciphertext, EvalKey
from repro.core.params import HEParams
from repro.hserve.queue import Batch
from repro.hserve.server import HEServer
from repro.hserve.tables import PlainCache
from repro.hserve.transport import (
    InProcTransport, SubprocessTransport, WorkerDied,
)
from repro.hserve.worker import WorkerEngine
from repro.runtime.monitor import Heartbeat

__all__ = ["NoLiveWorkersError", "FrontendCatalog", "WorkerHandle",
           "HEFrontend"]


class NoLiveWorkersError(RuntimeError):
    """Work is queued (or in flight) but every worker is dead — the
    typed drain-instead-of-hang contract of the fault tests."""


class FrontendCatalog:
    """The frontend's key/plain-operand catalog — TableCache's submit-
    time surface with NO device state.

    The frontend must answer "can this op be served?" at submit (the
    same raise-before-enqueue contract TableCache gives HEServer) and
    resolve plaintext operands, but the device pytrees live in the
    workers. So this holds raw EvalKeys + a PlainCache, mirrors
    TableCache's query API (evk/rot_key/conj_key/rotation_amounts/
    has_conj_key/put_plain/get_plain/has_plain), and forwards key
    additions to every live worker via the frontend's broadcast hook.
    """

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None,
                 conj_key: Optional[EvalKey] = None,
                 plain_cache_mib: Optional[float] = 256.0):
        self.params = params
        self._ek = evk
        self._rot: Dict[int, EvalKey] = {
            int(r): rk for r, rk in (rot_keys or {}).items()}
        self._conj = conj_key
        self.plain = PlainCache(cap_mib=plain_cache_mib)
        self.tracer = None
        # set by HEFrontend: broadcast(kind, r, key) ships a key to
        # every live worker before it can be referenced by a batch
        self._broadcast: Optional[Callable] = None

    # ---- submit-time key checks (same messages as TableCache) ---------

    def evk(self) -> EvalKey:
        if self._ek is None:
            raise ValueError("no evaluation key loaded (mul unavailable)")
        return self._ek

    def rot_key(self, r: int) -> EvalKey:
        try:
            return self._rot[int(r)]
        except KeyError:
            raise KeyError(
                f"no rotation key for r={r}; loaded: "
                f"{sorted(self._rot)}") from None

    def conj_key(self) -> EvalKey:
        if self._conj is None:
            raise ValueError(
                "no conjugation key loaded (conjugate unavailable)")
        return self._conj

    def add_rot_key(self, r: int, rk: EvalKey) -> None:
        r = int(r)
        new = r not in self._rot
        self._rot[r] = rk
        if new and self._broadcast is not None:
            self._broadcast("rot", r, rk)

    def add_conj_key(self, ck: EvalKey) -> None:
        new = self._conj is None
        self._conj = ck
        if new and self._broadcast is not None:
            self._broadcast("conj", 0, ck)

    @property
    def has_conj_key(self) -> bool:
        return self._conj is not None

    @property
    def rotation_amounts(self):
        return sorted(self._rot)

    # ---- plaintext operands (delegated; HEServer.submit's surface) ----

    def put_plain(self, h: str, logq: int, pt) -> np.ndarray:
        return self.plain.put(h, logq, pt)

    def get_plain(self, h: str, logq: int) -> np.ndarray:
        return self.plain.get(h, logq)

    def has_plain(self, h: str, logq: int) -> bool:
        return self.plain.has(h, logq)

    def stats(self) -> dict:
        return {
            "rot_keys": self.rotation_amounts,
            "conj_key": self.has_conj_key,
            "plain_entries": len(self.plain),
            "plain_hits": self.plain.hits,
            "plain_misses": self.plain.misses,
            "plain_evictions": self.plain.evictions,
            "plain_mib": round(self.plain.nbytes / 2**20, 3),
        }


class _Pending:
    """One dispatched-but-unretired batch on a worker."""

    __slots__ = ("batch", "seq", "t0")

    def __init__(self, batch: Batch, seq: int, t0: float):
        self.batch = batch
        self.seq = seq
        self.t0 = t0


class WorkerHandle:
    """Frontend-side view of one worker: transport + routing state."""

    def __init__(self, wid: int, transport, heartbeat_path=None):
        self.wid = wid
        self.transport = transport
        self.heartbeat_path = heartbeat_path
        self.alive = True
        self.pending: Optional[_Pending] = None
        # routing state: buckets this worker has served (its compiled
        # steps + table slices are warm for these), and busy seconds
        self.keys_warm: set = set()
        self.busy_s = 0.0
        self.batches = 0             # lifetime dispatches (injector key)
        self.served_requests = 0

    def stats(self) -> dict:
        return {"wid": self.wid, "alive": self.alive,
                "transport": self.transport.kind,
                "batches": self.batches,
                "served_requests": self.served_requests,
                "busy_s": round(self.busy_s, 6),
                "keys_warm": sorted(str(k) for k in self.keys_warm),
                "pending": self.pending is not None}


def _key_frames(evk: Optional[EvalKey], rot: Dict[int, EvalKey],
                conj: Optional[EvalKey]) -> Dict[str, np.ndarray]:
    """Flatten key material into init-frame array names."""
    out: Dict[str, np.ndarray] = {}

    def put(prefix: str, ek: EvalKey) -> None:
        for f in ("ax_ev", "ax_ev_shoup", "bx_ev", "bx_ev_shoup"):
            out[f"{prefix}.{f}"] = np.asarray(getattr(ek, f))

    if evk is not None:
        put("evk", evk)
    for r, rk in rot.items():
        put(f"rot.{r}", rk)
    if conj is not None:
        put("conj", conj)
    return out


class HEFrontend(HEServer):
    """The frontend process of the disaggregated serving tier.

    Inherits the whole intake/scheduling surface from HEServer (submit,
    submit_circuit, drain, metrics, the flush policy) and replaces the
    local engine with routed dispatch to `workers` worker engines.

    transport: "inproc" (worker engines in this process, framed — the
        default; simulated multi-host, shares this process's devices) or
        "subprocess" (real `python -m repro.hserve.worker` processes,
        each with its own XLA host devices).
    worker_devices: host device count per subprocess worker.
    injector: optional `runtime.failures.FailureInjector` whose
        `kill_worker_at` schedule this frontend consults after every
        dispatch (deterministic worker death for tests/benches).
    heartbeat_dir / heartbeat_timeout / heartbeat_interval: worker
        health files; `check_workers()` marks a worker dead when its
        file goes stale past the timeout. In-process workers beat on
        the frontend's (injectable) clock; subprocess workers beat on
        wall time.

    Unsupported vs the monolith: `overlap` (the per-worker pipeline IS
    the overlap — every worker holds one in-flight batch while the
    frontend assembles the next) and `profile_stages` (a worker-local
    measurement mode; run it on a single HEServer).
    """

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None,
                 conj_key: Optional[EvalKey] = None, *,
                 workers: int = 2, transport: str = "inproc",
                 mesh=None, batch: int = 8, use_kernels: bool = False,
                 max_age_s: Optional[float] = None,
                 adaptive_target: bool = True,
                 schedule: bool = False, lookahead: int = 2,
                 cost_model=None,
                 plain_cache_mib: Optional[float] = 256.0,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer=None, registry=None, injector=None,
                 heartbeat_dir: Optional[str] = None,
                 heartbeat_timeout: float = 30.0,
                 heartbeat_interval: float = 0.0,
                 worker_devices: int = 1,
                 **engine_knobs):
        if workers < 1:
            raise ValueError(f"need at least one worker, got {workers}")
        if transport not in ("inproc", "subprocess"):
            raise ValueError(f"unknown transport {transport!r} "
                             "(inproc | subprocess)")
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.cache = FrontendCatalog(params, evk, rot_keys, conj_key,
                                     plain_cache_mib=plain_cache_mib)
        self.engine = None           # no local engine — workers own them
        self._init_core(params, mesh=mesh, batch=batch,
                        max_age_s=max_age_s,
                        adaptive_target=adaptive_target, overlap=False,
                        schedule=schedule, lookahead=lookahead,
                        cost_model=cost_model, prefetch=False,
                        clock=clock, tracer=tracer, registry=registry)
        self.injector = injector
        self.transport_kind = transport
        self.heartbeat_timeout = heartbeat_timeout
        # spawn-time worker config, kept so revive_workers() can replay
        # a full init frame into a respawned subprocess worker
        self.worker_devices = worker_devices
        self.heartbeat_interval = heartbeat_interval
        self.use_kernels = use_kernels
        self.engine_knobs = dict(engine_knobs)
        self._seq = 0
        # results completed out-of-poll (quiesce before a key
        # broadcast, eager retires) buffer here until the next poll
        self._ready: List[Tuple[int, Ciphertext]] = []
        self.workers: List[WorkerHandle] = []
        rot = {int(r): rk for r, rk in (rot_keys or {}).items()}
        for wid in range(workers):
            hb_path = None
            if heartbeat_dir is not None:
                import os
                hb_path = os.path.join(heartbeat_dir,
                                       f"worker{wid}.heartbeat.json")
            if transport == "inproc":
                eng = WorkerEngine(
                    params, evk, dict(rot) or None, conj_key,
                    mesh=mesh, wid=wid, clock=clock,
                    heartbeat_path=hb_path,
                    heartbeat_interval=heartbeat_interval,
                    heartbeat_clock=clock, use_kernels=use_kernels,
                    **engine_knobs)
                tp = InProcTransport(eng)
            else:
                tp = SubprocessTransport(devices=worker_devices)
                self._send_worker_init(tp, wid, hb_path)
            self.workers.append(WorkerHandle(wid, tp,
                                             heartbeat_path=hb_path))
        if transport == "subprocess":
            # collect each worker's init ack (keys loaded, mesh up)
            for w in self.workers:
                head, _ = w.transport.recv()
                if head.get("type") != "ok":
                    raise WorkerDied(
                        f"worker {w.wid} failed init: {head}")
        self.cache._broadcast = self._broadcast_key
        self._c_deaths = self.registry.counter("worker.deaths")
        self._c_requeued = self.registry.counter(
            "worker.requeued_requests")
        self._g_alive = self.registry.gauge("worker.alive")
        self._g_alive.set(len(self.workers))
        for w in self.workers:
            self.registry.add_source(f"worker{w.wid}", w.stats)

    # ---- worker lifecycle ------------------------------------------------

    def _send_worker_init(self, tp, wid: int,
                          hb_path: Optional[str]) -> None:
        """Ship the init frame (params/mesh/knobs + ALL current key
        material) to a fresh subprocess worker. Reads keys from the
        catalog, not the constructor args, so a respawned worker also
        receives keys that were added (auto-provisioned rotations,
        bootstrap key sets) after the fleet first came up. The caller
        awaits the "ok" ack."""
        import dataclasses
        cat = self.cache
        init = {"type": "init",
                "params": dataclasses.asdict(self.params),
                "mesh": [1, self.worker_devices],
                "wid": wid,
                "has_evk": cat._ek is not None,
                "rot_rs": sorted(cat._rot),
                "has_conj": cat._conj is not None,
                "heartbeat": {"path": hb_path,
                              "interval": self.heartbeat_interval}
                if hb_path else None,
                "knobs": {"use_kernels": self.use_kernels,
                          **self.engine_knobs}}
        tp.send(init, _key_frames(cat._ek, cat._rot, cat._conj))

    def _alive_workers(self) -> List[WorkerHandle]:
        return [w for w in self.workers if w.alive]

    def _on_death(self, w: WorkerHandle, cause: str) -> None:
        """Mark a worker dead and requeue its in-flight batch (original
        rids — circuit routing and metrics bookkeeping survive)."""
        if not w.alive:
            return
        w.alive = False
        try:
            w.transport.kill()
        except Exception:                     # noqa: BLE001 — best effort
            pass
        self._c_deaths.inc()
        self._g_alive.set(len(self._alive_workers()))
        if w.pending is not None:
            reqs = w.pending.batch.requests[:w.pending.batch.n_valid]
            self.queue.requeue(reqs)
            self._c_requeued.inc(len(reqs))
            w.pending = None
        if self._tracer is not None:
            self._tracer.event(
                "worker_death", cat="worker", lane=f"worker{w.wid}",
                ts=self._clock(), args={"wid": w.wid, "cause": cause})

    def check_workers(self, now: Optional[float] = None) -> None:
        """Heartbeat sweep: a live worker whose heartbeat file has gone
        stale past `heartbeat_timeout` is declared dead (its in-flight
        batch requeues). In-process workers beat on the frontend's
        injected clock, so pass the same clock's reading via `now`
        (default: this frontend's clock for inproc, wall time for
        subprocess workers)."""
        for w in self._alive_workers():
            if w.heartbeat_path is None:
                continue
            t = now
            if t is None and w.transport.kind == "inproc":
                t = self._clock()
            if not Heartbeat.is_alive(w.heartbeat_path,
                                      self.heartbeat_timeout, now=t):
                self._on_death(w, "heartbeat_timeout")

    def revive_workers(self) -> None:
        """Bring every killed worker back online and restore the fleet
        to full strength.

        In-process workers are un-killed in place — their engines kept
        their compiled steps. Subprocess workers are RESPAWNED: a new
        interpreter comes up, the init frame is replayed with the
        catalog's CURRENT key material (including keys broadcast after
        the original spawn), and the "ok" ack is awaited before the
        worker is routable. The fresh process has no compiled steps or
        table slices, so its warm-bucket routing state resets; anything
        it was serving when it died was already requeued at death, and
        re-served batches are bitwise identical (deterministic integer
        ops)."""
        respawned: List[WorkerHandle] = []
        for w in self.workers:
            if w.alive:
                continue
            if w.transport.kind == "inproc":
                w.transport.revive()     # engine kept its compiled steps
            else:
                w.transport.respawn()
                self._send_worker_init(w.transport, w.wid,
                                       w.heartbeat_path)
                w.keys_warm = set()      # blank interpreter: nothing warm
                respawned.append(w)
            w.alive = True
            w.pending = None
        for w in respawned:
            head, _ = w.transport.recv()
            if head.get("type") != "ok":
                w.alive = False
                raise WorkerDied(
                    f"worker {w.wid} failed respawn init: {head}")
        self._g_alive.set(len(self._alive_workers()))

    # ---- key broadcast ---------------------------------------------------

    def _broadcast_key(self, kind: str, r: int, ek: EvalKey) -> None:
        """Ship a late-added key to every live worker. Each worker is
        quiesced first (its pending batch retired into the ready
        buffer) so the strict request-reply protocol stays in step."""
        arrays = {f: np.asarray(getattr(ek, f))
                  for f in ("ax_ev", "ax_ev_shoup", "bx_ev",
                            "bx_ev_shoup")}
        for w in self._alive_workers():
            if w.pending is not None:
                self._retire_worker(w)
                if not w.alive:
                    continue
            try:
                w.transport.send({"type": "add_key", "kind": kind,
                                  "r": r}, arrays)
                head, _ = w.transport.recv()
                if head.get("type") != "ok":
                    raise WorkerDied(f"add_key nacked: {head}")
            except WorkerDied:
                self._on_death(w, "transport")

    # ---- routed dispatch (replaces the local engine) ---------------------

    def _route(self, b: Batch) -> WorkerHandle:
        """Pick a worker: load first, bucket affinity second.

        Affinity-first would pin a single hot bucket onto one worker
        and serialize the whole stream (zero scaling); load-first lets
        a hot bucket spill to idle and less-busy workers — each spill
        warms exactly one more worker, converging to a balanced pinning
        — while the affinity tiebreak keeps multi-bucket streams from
        bouncing warm levels between equally loaded workers. Idle
        workers rank warmth before accumulated busy_s (their past load
        is sunk; reusing compiled steps + resident slices is free);
        busy workers rank busy_s before warmth (a warm-but-backlogged
        worker must NOT beat an idle-ish one — that is the pinning
        failure mode). wid breaks remaining ties deterministically
        (routing must be replayable: the bench re-runs the same stream
        and compares bitwise).
        """
        alive = self._alive_workers()
        if not alive:
            raise NoLiveWorkersError(
                f"no live workers ({len(self.workers)} configured, all "
                f"dead) with {self.queue.depth} queued request(s)")

        def score(w: WorkerHandle):
            warm = 0 if b.key in w.keys_warm else 1
            if w.pending is None:
                return (0, warm, w.busy_s, w.wid)
            return (1, w.busy_s, warm, w.wid)

        return min(alive, key=score)

    def _dispatch_to(self, w: WorkerHandle, b: Batch) -> bool:
        """Frame + send one batch; False when the send killed the
        worker (caller re-routes)."""
        self._seq += 1
        seq = self._seq
        head = {"type": "batch", "seq": seq,
                "key": list(b.key), "n_valid": b.n_valid,
                "reqs": [{"rid": r.rid, "r": r.r, "dlogp": r.dlogp,
                          "logq2": r.logq2, "pt_logp": r.pt_logp,
                          "n_slots": r.cts[0].n_slots,
                          "logps": [c.logp for c in r.cts]}
                         for r in b.requests[:b.n_valid]]}
        tr = self._tracer
        try:
            if tr is not None:
                with tr.span("dispatch", cat="lifecycle", lane="server",
                             args={"op": b.op, "batch": b.size,
                                   "worker": w.wid}):
                    w.transport.send(head, b.arrays)
            else:
                w.transport.send(head, b.arrays)
        except WorkerDied:
            self._on_death(w, "transport")
            return False
        w.pending = _Pending(b, seq, self._clock())
        w.batches += 1
        w.keys_warm.add(b.key)
        if self.injector is not None and \
                self.injector.maybe_kill_worker(w.wid, w.batches):
            # die AFTER the send: the batch is in flight on a worker
            # that will never answer — the mid-batch death window
            w.transport.kill()
        return True

    def _retire_worker(self, w: WorkerHandle) -> None:
        """Collect one worker's pending result into the ready buffer
        (or requeue it if the worker died under us)."""
        p = w.pending
        if p is None:
            return
        try:
            head, arrays = w.transport.recv()
            if head.get("type") != "result" or head.get("seq") != p.seq:
                raise WorkerDied(
                    f"protocol skew from worker {w.wid}: {head}")
        except WorkerDied:
            self._on_death(w, "transport")
            return
        w.pending = None
        wall = float(head["wall"])
        w.busy_s += wall
        w.served_requests += p.batch.n_valid
        if self._tracer is not None:
            self._tracer.event(
                "device_wall", cat="lifecycle", lane=f"worker{w.wid}",
                ts=p.t0, dur=wall,
                args={"op": p.batch.op, "logq": p.batch.logq,
                      "worker": w.wid, "n_valid": p.batch.n_valid})
        outs = [Ciphertext(ax=arrays["ax"][i], bx=arrays["bx"][i],
                           logq=int(m["logq"]), logp=int(m["logp"]),
                           n_slots=int(m["n_slots"]))
                for i, m in enumerate(head["outs"])]
        self._ready.extend(self._complete(p.batch, outs, wall))

    def _retire_oldest(self) -> None:
        pend = [w for w in self._alive_workers() if w.pending is not None]
        if pend:
            self._retire_worker(min(pend, key=lambda w: w.pending.t0))

    def _take_ready(self) -> List[Tuple[int, Ciphertext]]:
        out, self._ready = self._ready, []
        return out

    def _work_pending(self) -> bool:
        return bool(self._ready) or any(
            w.pending is not None for w in self._alive_workers())

    # ---- the serving loop (routed) ---------------------------------------

    def poll(self, flush: bool = False) -> List[Tuple[int, Ciphertext]]:
        """One frontend scheduling step: health-check workers, release
        at most one batch per the inherited flush policy, route it, and
        return whatever results have completed. Workers run one-deep
        pipelines — a routed batch is NOT awaited here; it retires when
        its worker is next needed (or at drain), so W workers hold W
        batches in flight while the frontend keeps assembling."""
        self._c_polls.inc()
        self._g_depth.set(self.queue.depth)
        self.metrics.record_depth(self.queue.depth)
        now = self._clock()
        self.check_workers()
        key, cause = self._choose_flush(flush, now)
        if key is None:
            # nothing to release — retire the oldest pipelined batch
            # instead (the monolith retires its in-flight step here)
            self._retire_oldest()
            return self._take_ready()
        b = self._pop_assemble(key, cause)
        while True:
            w = self._route(b)
            if w.pending is not None:
                self._retire_worker(w)        # free its pipeline slot
                if not w.alive:
                    continue                  # died on retire: re-route
            if self._dispatch_to(w, b):
                break
        return self._take_ready()

    def drain(self) -> Dict[int, Ciphertext]:
        results = super().drain()
        # retire any stragglers still pipelined on the workers
        for w in self._alive_workers():
            self._retire_worker(w)
        for rid, ct in self._take_ready():
            results[rid] = ct
        return results

    # ---- accounting ------------------------------------------------------

    def reset_metrics(self) -> None:
        super().reset_metrics()
        for w in self.workers:
            w.busy_s = 0.0
            w.served_requests = 0
            # NOT w.batches: the injector's kill schedule counts
            # lifetime dispatches

    def stats(self) -> dict:
        eng = {"steps_compiled": 0, "compile_s": 0.0}
        for w in self.workers:
            if w.transport.kind == "inproc":
                e = w.transport.worker.engine
                eng["steps_compiled"] += e.n_compiled
                eng["compile_s"] += e.compile_s
        eng["compile_s"] = round(eng["compile_s"], 3)
        return {
            **self.metrics.summary(),
            "cache": self.cache.stats(),
            "engine": eng,
            "mesh": dict(self.mesh.shape),
            "batch": self.batch,
            "flush_policy": {
                "max_age_s": self.max_age_s,
                "adaptive_target": self.adaptive_target,
                "bucket_target": self._bucket_target(),
                "overlap": False,
            },
            "scheduler": {"enabled": self.schedule,
                          "prefetch_tables": self.prefetch,
                          **self.scheduler.stats()},
            "submitted": self.queue.submitted,
            "frontend": {
                "transport": self.transport_kind,
                "workers": len(self.workers),
                "alive": len(self._alive_workers()),
                "deaths": self._c_deaths.value,
                "requeued_requests": self._c_requeued.value,
            },
            "workers": [w.stats() for w in self.workers],
        }

    def close(self) -> None:
        """Shut every worker down (subprocess transports exit their
        frame loops; in-process ones just drop)."""
        for w in self.workers:
            try:
                w.transport.close()
            except Exception:                 # noqa: BLE001 — best effort
                pass
            w.alive = False
