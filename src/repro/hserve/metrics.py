"""Steady-state serving accounting: throughput, latency, queue depth.

The paper's premise (§V) is that HE Mul THROUGHPUT under batching — not
single-op latency — is what makes HEAAN serviceable; this module gives
the serving runtime the numbers to prove it per op kind:

  - per-(op) throughput: valid (non-padding) ops per second of engine
    wall time, compile excluded (steady state);
  - request latency: submit → batch-complete, p50/p99;
  - batch efficiency: padding fraction per op;
  - queue depth samples over the run;
  - flush causes: how many batches ran because a bucket was full, hit
    its age deadline (the continuous-batching SLO path), or was drained —
    the knob-tuning signal for `HEServer(max_age_s=...)`;
  - co-batching: of the batches that carried circuit nodes, how many
    mixed nodes from TWO OR MORE circuits — the cross-circuit co-batch
    rate the circuit-aware scheduler exists to raise (`HEServer(
    schedule=True)`), plus its deferral and table-prefetch counts.

Everything is plain host-side accumulation — no jax dependency — so the
metrics can run on a frontend host next to the RequestQueue.

Memory contract: latency and queue-depth streams accumulate into
BOUNDED reservoirs (`repro.obs.stats.Reservoir`), not lists — a
week-old server at production request counts holds a fixed few thousand
samples per op, with count/mean/max exact and p50/p99 sampled (within
tolerance; pinned by tests/test_obs.py against exact percentiles).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

from repro.obs.stats import Reservoir

__all__ = ["ServeMetrics"]


@dataclasses.dataclass
class _OpStats:
    batches: int = 0
    valid: int = 0
    padded: int = 0
    wall_s: float = 0.0
    latencies: Reservoir = dataclasses.field(default_factory=Reservoir)


class ServeMetrics:
    """Accumulate per-batch records; summarize steady-state rates."""

    FLUSH_CAUSES = ("full", "age", "drain")

    def __init__(self):
        self._ops: Dict[str, _OpStats] = defaultdict(_OpStats)
        self._depths = Reservoir()
        self._levels: set = set()
        self._flushes: Dict[str, int] = {c: 0 for c in self.FLUSH_CAUSES}
        self._circuit_batches = 0
        self._cross_circuit_batches = 0
        self._circuit_nodes = 0

    def record_batch(self, op: str, logq: int, n_valid: int, n_pad: int,
                     wall_s: float, latencies_s: List[float]) -> None:
        s = self._ops[op]
        s.batches += 1
        s.valid += n_valid
        s.padded += n_pad
        s.wall_s += wall_s
        s.latencies.extend(latencies_s)
        self._levels.add(logq)

    def record_depth(self, depth: int) -> None:
        self._depths.add(depth)

    def record_flush(self, cause: str) -> None:
        """Count why a batch was released: "full" (bucket reached the
        target), "age" (oldest request hit the deadline), "drain"."""
        if cause not in self.FLUSH_CAUSES:   # not assert: gone under -O
            raise ValueError(f"unknown flush cause {cause!r}; one of "
                             f"{self.FLUSH_CAUSES}")
        self._flushes[cause] += 1

    def record_circuit_batch(self, n_circuits: int, n_nodes: int) -> None:
        """One served batch carried `n_nodes` circuit nodes from
        `n_circuits` distinct circuits (co-batching accounting)."""
        if n_nodes <= 0:
            return
        self._circuit_batches += 1
        self._circuit_nodes += n_nodes
        if n_circuits >= 2:
            self._cross_circuit_batches += 1

    def summary(self) -> dict:
        per_op = {}
        for op, s in sorted(self._ops.items()):
            served = s.valid + s.padded
            lat = s.latencies
            per_op[op] = {
                "batches": s.batches,
                "requests": s.valid,
                "ops_per_s": round(s.valid / s.wall_s, 3)
                if s.wall_s > 0 else 0.0,
                "wall_s": round(s.wall_s, 4),
                "pad_frac": round(s.padded / served, 4) if served else 0.0,
                "latency_ms": {
                    "p50": round(1e3 * lat.percentile(50), 3),
                    "p99": round(1e3 * lat.percentile(99), 3),
                    # max is exact — reservoirs track extremes outside
                    # the sample
                    "max": round(1e3 * lat.max, 3) if lat else 0.0,
                },
            }
        return {
            "per_op": per_op,
            "levels_served": sorted(self._levels),
            "flushes": dict(self._flushes),
            "cobatch": {
                "circuit_batches": self._circuit_batches,
                "circuit_nodes": self._circuit_nodes,
                "cross_circuit_batches": self._cross_circuit_batches,
                "cross_circuit_rate": round(
                    self._cross_circuit_batches / self._circuit_batches, 4)
                if self._circuit_batches else 0.0,
            },
            "queue_depth": {
                "mean": round(self._depths.mean, 2) if self._depths
                else 0.0,
                "max": int(self._depths.max) if self._depths else 0,
                "samples": len(self._depths),
            },
        }
