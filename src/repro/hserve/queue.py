"""Request queue and batch assembler for the HE serving runtime.

The unit of work a privacy-preserving serving system schedules is a
ciphertext-op request: (op, operand ciphertexts[, op parameters]). The
engine jit-compiles ONE step per trace signature, so requests must reach
it in fixed-shape batches of like kind. This module does that shaping:

  - :class:`RequestQueue` buckets incoming requests by
    ``(op, logq[, op-specific extra])`` — every member of a bucket shares
    a trace signature — and preserves FIFO order within each bucket. It
    also keeps the age/arrival-rate bookkeeping the server's continuous-
    batching flush policy reads (``expired_key`` / ``arrival_rate``).
  - :class:`BatchAssembler` stacks a bucket's ciphertext limb arrays into
    ``(B, N, qlimbs)`` operands, zero-padding up to the fixed batch size
    (zero polynomials are valid ciphertext material; padded lanes are
    computed and discarded), and records ``n_valid`` so the engine can
    slice real results back out.

The served op set covers the whole ciphertext-level circuit vocabulary
the paper's workloads chain (§III-A/B: mul → rescale → mod-down →
rotate/conjugate at descending levels) — HEAX and Medha both argue the
accelerator only pays off when ALL of these stay on the device, not just
HE Mul:

  ==========  ========  =============================================
  op          operands  extra in the bucket key
  ==========  ========  =============================================
  mul         2         — (region-1 product + region-2 key switch)
  add / sub   2         — (limb add/sub + mask; paper §III-B)
  rotate      1         r, the left-rotation amount (σ_{5^r})
  conjugate   1         — (σ₋₁, k = 2N−1; same key-switch machinery)
  slot_sum    1         n_slots (log₂ n fused rotate+add rounds)
  rescale     1         dlogp, the scale drop (÷2^dlogp; §III-A)
  mod_down    1         logq2, the target modulus
  ==========  ========  =============================================

Placement onto the mesh's "data" axis happens in the engine (the
assembler stays device-free so it can run on a frontend host).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cipher import Ciphertext

__all__ = ["Request", "Batch", "RequestQueue", "BatchAssembler", "OPS"]

# op -> number of ciphertext operands
OPS = {"mul": 2, "add": 2, "sub": 2, "rotate": 1, "conjugate": 1,
       "slot_sum": 1, "rescale": 1, "mod_down": 1}

BucketKey = Tuple  # (op, logq, extra): extra = r | n_slots | dlogp | logq2 | None


@dataclasses.dataclass
class Request:
    """One ciphertext-op request.

    cts: operand ciphertexts (2 for "mul"/"add"/"sub", 1 otherwise), all
    at the same modulus 2^logq. Op parameters: `r` is the left-rotation
    amount for "rotate", `dlogp` the scale drop for "rescale", `logq2`
    the target modulus for "mod_down".
    """

    rid: int
    op: str
    cts: Tuple[Ciphertext, ...]
    r: int = 0
    dlogp: int = 0
    logq2: int = 0
    t_submit: float = 0.0

    @property
    def logq(self) -> int:
        return self.cts[0].logq

    @property
    def bucket_key(self) -> BucketKey:
        if self.op == "rotate":
            return (self.op, self.logq, self.r)
        if self.op == "slot_sum":
            return (self.op, self.logq, self.cts[0].n_slots)
        if self.op == "rescale":
            return (self.op, self.logq, self.dlogp)
        if self.op == "mod_down":
            return (self.op, self.logq, self.logq2)
        return (self.op, self.logq, None)     # mul / add / sub / conjugate


@dataclasses.dataclass
class Batch:
    """A fixed-shape, assembly-complete unit of engine work.

    arrays: stacked host (B, N, qlimbs) operands — "ax1"/"bx1" always,
    "ax2"/"bx2" for two-operand ops. Rows past n_valid are zero padding.
    The engine's `_place` is the single host→device transfer.
    """

    key: BucketKey
    requests: List[Request]
    arrays: Dict[str, np.ndarray]
    n_valid: int

    @property
    def op(self) -> str:
        return self.key[0]

    @property
    def logq(self) -> int:
        return self.key[1]

    @property
    def size(self) -> int:
        return next(iter(self.arrays.values())).shape[0]

    @property
    def n_pad(self) -> int:
        return self.size - self.n_valid


class RequestQueue:
    """FIFO-within-bucket request queue keyed by trace signature.

    Besides bucketing, the queue is the flush policy's sensor: it knows
    how long each bucket's head request has waited (`expired_key`) and
    the recent arrival rate (`arrival_rate`), which the server uses to
    size its adaptive bucket target (ROADMAP: continuous batching).
    """

    # window of recent submit timestamps used for the arrival-rate
    # estimate; big enough to smooth bursts, small enough to track drift
    _RATE_WINDOW = 64

    def __init__(self):
        self._buckets: "OrderedDict[BucketKey, Deque[Request]]" = \
            OrderedDict()
        self._next_rid = 0
        self._submitted = 0
        self._arrivals: Deque[float] = deque(maxlen=self._RATE_WINDOW)

    def reserve_rid(self) -> int:
        """Allocate a request id without enqueuing anything (used by
        HEServer.submit_circuit so circuit handles share the rid space
        and can never collide with per-op request ids)."""
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def submit(self, op: str, cts: Tuple[Ciphertext, ...], r: int = 0,
               dlogp: int = 0, logq2: int = 0,
               t_submit: Optional[float] = None) -> int:
        """Enqueue a request; returns its request id."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; serve one of {set(OPS)}")
        cts = tuple(cts) if isinstance(cts, (tuple, list)) else (cts,)
        if len(cts) != OPS[op]:
            raise ValueError(
                f"op {op!r} takes {OPS[op]} ciphertext(s), got {len(cts)}")
        if any(c.logq != cts[0].logq for c in cts):
            raise ValueError("operands must share a modulus (paper §III-B)")
        if op in ("add", "sub") and cts[0].logp != cts[1].logp:
            raise ValueError(
                f"{op} operands must share a scale: "
                f"logp {cts[0].logp} != {cts[1].logp} (rescale first)")
        if op == "rotate" and r <= 0:
            raise ValueError("rotate needs a positive rotation amount r")
        if op == "rescale":
            if dlogp <= 0:
                raise ValueError("rescale needs a positive dlogp")
            if cts[0].logq - dlogp <= 0:
                raise ValueError(
                    f"rescale by {dlogp} exhausts the ciphertext "
                    f"(logq {cts[0].logq}; needs bootstrapping)")
        if op == "mod_down" and not 0 < logq2 <= cts[0].logq:
            raise ValueError(
                f"mod_down target logq2={logq2} outside (0, "
                f"{cts[0].logq}]")
        req = Request(rid=self._next_rid, op=op, cts=cts, r=r, dlogp=dlogp,
                      logq2=logq2,
                      t_submit=time.perf_counter()
                      if t_submit is None else t_submit)
        self._next_rid += 1
        self._submitted += 1
        self._arrivals.append(req.t_submit)
        self._buckets.setdefault(req.bucket_key, deque()).append(req)
        return req.rid

    @property
    def depth(self) -> int:
        return sum(len(d) for d in self._buckets.values())

    @property
    def submitted(self) -> int:
        return self._submitted

    def bucket_depths(self) -> Dict[BucketKey, int]:
        return {k: len(d) for k, d in self._buckets.items() if d}

    def ready_key(self, batch: int) -> Optional[BucketKey]:
        """Oldest bucket holding at least a full batch, else None."""
        for k, d in self._buckets.items():
            if len(d) >= batch:
                return k
        return None

    def any_key(self) -> Optional[BucketKey]:
        """Oldest non-empty bucket (for flush/drain with padding)."""
        for k, d in self._buckets.items():
            if d:
                return k
        return None

    def expired_key(self, max_age_s: float, now: float
                    ) -> Optional[BucketKey]:
        """The bucket whose HEAD request has waited longest past the age
        deadline (None when nothing has expired). The head is always the
        bucket's oldest request (FIFO), so this is exactly the per-bucket
        oldest-request deadline of the continuous-batching policy."""
        best, best_t = None, None
        for k, d in self._buckets.items():
            if d and now - d[0].t_submit >= max_age_s:
                if best_t is None or d[0].t_submit < best_t:
                    best, best_t = k, d[0].t_submit
        return best

    def arrival_rate(self) -> Optional[float]:
        """Requests/second over the recent submit window (None until two
        arrivals with distinct timestamps exist)."""
        if len(self._arrivals) < 2:
            return None
        span = self._arrivals[-1] - self._arrivals[0]
        if span <= 0:
            return None
        return (len(self._arrivals) - 1) / span

    def pop_bucket(self, key: BucketKey, max_n: int) -> List[Request]:
        """Dequeue up to max_n requests from one bucket, FIFO."""
        d = self._buckets.get(key)
        if not d:
            return []
        out = [d.popleft() for _ in range(min(max_n, len(d)))]
        if not d:
            del self._buckets[key]
        return out


class BatchAssembler:
    """Stack + zero-pad a same-bucket request list to the fixed shape."""

    def __init__(self, batch: int):
        assert batch >= 1
        self.batch = batch

    def assemble(self, requests: List[Request]) -> Batch:
        if not requests:
            raise ValueError("cannot assemble an empty batch")
        if len(requests) > self.batch:
            raise ValueError(
                f"{len(requests)} requests exceed batch size {self.batch}")
        key = requests[0].bucket_key
        if any(r.bucket_key != key for r in requests):
            raise ValueError("mixed buckets in one batch: "
                             f"{ {r.bucket_key for r in requests} }")
        n_valid = len(requests)
        pad = self.batch - n_valid

        def stack(attr: str, operand: int) -> np.ndarray:
            rows = [np.asarray(getattr(r.cts[operand], attr))
                    for r in requests]
            if pad:
                z = np.zeros_like(rows[0])
                rows = rows + [z] * pad
            return np.stack(rows)

        arrays = {"ax1": stack("ax", 0), "bx1": stack("bx", 0)}
        if OPS[key[0]] == 2:
            arrays["ax2"] = stack("ax", 1)
            arrays["bx2"] = stack("bx", 1)
        return Batch(key=key, requests=list(requests), arrays=arrays,
                     n_valid=n_valid)
