"""Request queue and batch assembler for the HE serving runtime.

The unit of work a privacy-preserving serving system schedules is a
ciphertext-op request: (op, operand ciphertexts[, op parameters]). The
engine jit-compiles ONE step per trace signature, so requests must reach
it in fixed-shape batches of like kind. This module does that shaping:

  - :class:`RequestQueue` buckets incoming requests by
    ``(op, logq[, op-specific extra])`` — every member of a bucket shares
    a trace signature — and preserves FIFO order within each bucket. It
    also keeps the age/arrival-rate bookkeeping the server's continuous-
    batching flush policy reads (``expired_key`` / ``arrival_rate``).
  - :class:`BatchAssembler` stacks a bucket's ciphertext limb arrays into
    ``(B, N, qlimbs)`` operands, zero-padding up to the fixed batch size
    (zero polynomials are valid ciphertext material; padded lanes are
    computed and discarded), and records ``n_valid`` so the engine can
    slice real results back out.

The served op set covers the whole ciphertext-level circuit vocabulary
the paper's workloads chain (§III-A/B: mul → rescale → mod-down →
rotate/conjugate at descending levels) — HEAX and Medha both argue the
accelerator only pays off when ALL of these stay on the device, not just
HE Mul:

  ==========  ========  =============================================
  op          operands  extra in the bucket key
  ==========  ========  =============================================
  mul         2         — (region-1 product + region-2 key switch)
  add / sub   2         — (limb add/sub + mask; paper §III-B)
  rotate      1         r, the left-rotation amount (σ_{5^r})
  conjugate   1         — (σ₋₁, k = 2N−1; same key-switch machinery)
  slot_sum    1         n_slots (log₂ n fused rotate+add rounds)
  rescale     1         dlogp, the scale drop (÷2^dlogp; §III-A)
  mod_down    1         logq2, the target modulus
  mul_plain   1         — (encoded-operand product: region 1 ONLY —
                           no key switch, the affine-layer fast path)
  add_plain   1         — (plaintext added to bx; no key material)
  ==========  ========  =============================================

The plaintext-operand ops carry their encoded operand (a host
(N, qlimbs) mod-q limb array) on the request itself; it is stacked into
the batch as the "pt" array — batch DATA, not trace signature, so every
same-level mul_plain shares one compiled step regardless of operand.

Placement onto the mesh's "data" axis happens in the engine (the
assembler stays device-free so it can run on a frontend host).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.dataflow import OPS, PLAIN_OPS
from repro.core.cipher import Ciphertext

__all__ = ["Request", "Batch", "RequestQueue", "BatchAssembler", "OPS",
           "PLAIN_OPS"]

BucketKey = Tuple  # (op, logq, extra): extra = r | n_slots | dlogp | logq2 | None


@dataclasses.dataclass
class Request:
    """One ciphertext-op request.

    cts: operand ciphertexts (2 for "mul"/"add"/"sub", 1 otherwise), all
    at the same modulus 2^logq. Op parameters: `r` is the left-rotation
    amount for "rotate", `dlogp` the scale drop for "rescale", `logq2`
    the target modulus for "mod_down". Plaintext-operand ops carry their
    encoded operand in `pt` ((N, qlimbs) mod-q limbs at the ciphertext's
    level) and its scale in `pt_logp`.
    """

    rid: int
    op: str
    cts: Tuple[Ciphertext, ...]
    r: int = 0
    dlogp: int = 0
    logq2: int = 0
    pt: Optional[np.ndarray] = None
    pt_logp: int = 0
    t_submit: float = 0.0

    @property
    def logq(self) -> int:
        return self.cts[0].logq

    @property
    def bucket_key(self) -> BucketKey:
        if self.op == "rotate":
            return (self.op, self.logq, self.r)
        if self.op == "slot_sum":
            return (self.op, self.logq, self.cts[0].n_slots)
        if self.op == "rescale":
            return (self.op, self.logq, self.dlogp)
        if self.op in ("mod_down", "mod_raise"):
            return (self.op, self.logq, self.logq2)
        return (self.op, self.logq, None)     # mul / add / sub / conjugate


@dataclasses.dataclass
class Batch:
    """A fixed-shape, assembly-complete unit of engine work.

    arrays: stacked host (B, N, qlimbs) operands — "ax1"/"bx1" always,
    "ax2"/"bx2" for two-operand ops. Rows past n_valid are zero padding.
    The engine's `_place` is the single host→device transfer.
    """

    key: BucketKey
    requests: List[Request]
    arrays: Dict[str, np.ndarray]
    n_valid: int

    @property
    def op(self) -> str:
        return self.key[0]

    @property
    def logq(self) -> int:
        return self.key[1]

    @property
    def size(self) -> int:
        return next(iter(self.arrays.values())).shape[0]

    @property
    def n_pad(self) -> int:
        return self.size - self.n_valid


class RequestQueue:
    """FIFO-within-bucket request queue keyed by trace signature.

    Besides bucketing, the queue is the flush policy's sensor: it knows
    how long each bucket's head request has waited (`expired_key`) and
    the recent arrival rate (`arrival_rate`), which the server uses to
    size its adaptive bucket target (ROADMAP: continuous batching).

    clock: the time source `submit` stamps `t_submit` with when the
    caller does not pass one. HEServer threads its own (injectable)
    clock here, so direct `queue.submit(...)` calls and server submits
    land on ONE timeline — age deadlines and latency metrics stay
    meaningful under a fake test clock.
    """

    # window of recent submit timestamps used for the arrival-rate
    # estimate; big enough to smooth bursts, small enough to track drift
    _RATE_WINDOW = 64

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._buckets: "OrderedDict[BucketKey, Deque[Request]]" = \
            OrderedDict()
        self._next_rid = 0
        self._submitted = 0
        self._clock = time.perf_counter if clock is None else clock
        self._arrivals: Deque[float] = deque(maxlen=self._RATE_WINDOW)

    def reserve_rid(self) -> int:
        """Allocate a request id without enqueuing anything (used by
        HEServer.submit_circuit so circuit handles share the rid space
        and can never collide with per-op request ids)."""
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def submit(self, op: str, cts: Tuple[Ciphertext, ...], r: int = 0,
               dlogp: int = 0, logq2: int = 0,
               pt: Optional[np.ndarray] = None, pt_logp: int = 0,
               t_submit: Optional[float] = None,
               pt_owned: bool = False) -> int:
        """Enqueue a request; returns its request id.

        t_submit defaults to THIS QUEUE'S clock — never a module-level
        time call — so a server built with an injected clock keeps every
        request on the injected timeline even when the queue is driven
        directly (age-based flush tests skew otherwise).
        """
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; serve one of {set(OPS)}")
        cts = tuple(cts) if isinstance(cts, (tuple, list)) else (cts,)
        if len(cts) != OPS[op]:
            raise ValueError(
                f"op {op!r} takes {OPS[op]} ciphertext(s), got {len(cts)}")
        if any(c.logq != cts[0].logq for c in cts):
            raise ValueError("operands must share a modulus (paper §III-B)")
        if op in ("add", "sub") and cts[0].logp != cts[1].logp:
            raise ValueError(
                f"{op} operands must share a scale: "
                f"logp {cts[0].logp} != {cts[1].logp} (rescale first)")
        if op == "rotate" and r <= 0:
            raise ValueError("rotate needs a positive rotation amount r")
        if op == "rescale":
            if dlogp <= 0:
                raise ValueError("rescale needs a positive dlogp")
            if cts[0].logq - dlogp <= 0:
                raise ValueError(
                    f"rescale by {dlogp} exhausts the ciphertext "
                    f"(logq {cts[0].logq}; needs bootstrapping)")
        if op == "mod_down" and not 0 < logq2 <= cts[0].logq:
            raise ValueError(
                f"mod_down target logq2={logq2} outside (0, "
                f"{cts[0].logq}]")
        if op == "mod_raise" and logq2 <= cts[0].logq:
            raise ValueError(
                f"mod_raise target logq2={logq2} must exceed the "
                f"ciphertext's logq {cts[0].logq}")
        if op in PLAIN_OPS:
            if pt is None:
                raise ValueError(f"{op} needs an encoded plaintext operand "
                                 "(core.heaan.encode_plain)")
            pt = np.asarray(pt)
            ct_shape = cts[0].ax.shape      # no host copy — shape only
            if pt.ndim != 2 or pt.shape[0] != ct_shape[0] \
                    or pt.shape[1] < ct_shape[-1]:
                raise ValueError(
                    f"{op} plaintext shape {pt.shape} does not cover the "
                    f"ciphertext's {tuple(ct_shape)} limbs")
            # copy, not a view: the queued request must not alias the
            # caller's (mutable) buffer — a client reusing its encode
            # scratch before the bucket flushes would corrupt the batch.
            # Exception: pt_owned marks a server-owned read-only cache
            # resident (HEServer sets it only for hash-resolved
            # operands), which is safe to alias and hot enough to
            # matter. Writeability alone is NOT trusted as an ownership
            # signal — a caller's read-only view can have a writeable
            # base (np.broadcast_to, setflags round-trips).
            sliced = pt[:, :ct_shape[-1]]
            pt = sliced if pt_owned and not sliced.flags.writeable \
                else np.array(sliced)
            if op == "mul_plain" and pt_logp <= 0:
                raise ValueError(
                    "mul_plain needs pt_logp, the plaintext's scale "
                    "(HEServer.submit defaults it to params.log_delta)")
            if op == "add_plain":
                pt_logp = pt_logp or cts[0].logp
                if pt_logp != cts[0].logp:
                    raise ValueError(
                        f"add_plain operand scales differ: plaintext logp "
                        f"{pt_logp} != ciphertext {cts[0].logp}")
        req = Request(rid=self._next_rid, op=op, cts=cts, r=r, dlogp=dlogp,
                      logq2=logq2, pt=pt, pt_logp=pt_logp,
                      t_submit=self._clock()
                      if t_submit is None else t_submit)
        self._next_rid += 1
        self._submitted += 1
        self._arrivals.append(req.t_submit)
        self._buckets.setdefault(req.bucket_key, deque()).append(req)
        return req.rid

    @property
    def depth(self) -> int:
        return sum(len(d) for d in self._buckets.values())

    @property
    def submitted(self) -> int:
        return self._submitted

    def bucket_depths(self) -> Dict[BucketKey, int]:
        return {k: len(d) for k, d in self._buckets.items() if d}

    def ready_key(self, batch: int) -> Optional[BucketKey]:
        """Oldest bucket holding at least a full batch, else None."""
        for k, d in self._buckets.items():
            if len(d) >= batch:
                return k
        return None

    def any_key(self) -> Optional[BucketKey]:
        """Oldest non-empty bucket (for flush/drain with padding)."""
        for k, d in self._buckets.items():
            if d:
                return k
        return None

    def expired_key(self, max_age_s: float, now: float
                    ) -> Optional[BucketKey]:
        """The bucket whose HEAD request has waited longest past the age
        deadline (None when nothing has expired). The head is always the
        bucket's oldest request (FIFO), so this is exactly the per-bucket
        oldest-request deadline of the continuous-batching policy."""
        best, best_t = None, None
        for k, d in self._buckets.items():
            if d and now - d[0].t_submit >= max_age_s:
                if best_t is None or d[0].t_submit < best_t:
                    best, best_t = k, d[0].t_submit
        return best

    def arrival_rate(self, now: Optional[float] = None,
                     window_s: Optional[float] = None) -> Optional[float]:
        """Requests/second over the recent submit window.

        With `now` and `window_s`, arrivals older than ``now - window_s``
        are DECAYED OUT of the estimate (and dropped from the window):
        after an idle gap the rate reflects current traffic, not the last
        burst — otherwise the adaptive bucket target stays inflated and a
        post-idle trickle waits the full age deadline per request instead
        of flushing at the adapted target (the flush-stall regression in
        tests/test_hserve.py). A single in-window arrival reports the
        sparse-traffic floor ``1 / window_s`` so a lone post-idle request
        still shrinks the target. Without `now`, the legacy whole-window
        span estimate is returned (None until two distinct timestamps).
        """
        if now is not None and window_s is not None and window_s > 0:
            cutoff = now - window_s
            while self._arrivals and self._arrivals[0] < cutoff:
                self._arrivals.popleft()          # stale: decay the window
            if not self._arrivals:
                return None
            span = self._arrivals[-1] - self._arrivals[0]
            if span <= 0:
                # one arrival — or several sharing a (coarse/fake) clock
                # tick: count over the window, never None, so the target
                # keeps tracking sparse post-idle traffic
                return len(self._arrivals) / window_s
            return (len(self._arrivals) - 1) / span
        if len(self._arrivals) < 2:
            return None
        span = self._arrivals[-1] - self._arrivals[0]
        if span <= 0:
            return None
        return (len(self._arrivals) - 1) / span

    def requeue(self, requests: List[Request]) -> None:
        """Put already-validated requests back into their buckets (the
        frontend's worker-death path: a dead worker's in-flight batch
        returns whole). rids, t_submit, and the submitted/arrival
        bookkeeping are all preserved — the requests were already
        counted once, and circuit routing keys on the original rids.
        Requeued requests append in batch order, so a re-served batch
        pops in the order it originally flushed."""
        for r in requests:
            self._buckets.setdefault(r.bucket_key, deque()).append(r)

    def pop_bucket(self, key: BucketKey, max_n: int) -> List[Request]:
        """Dequeue up to max_n requests from one bucket, FIFO."""
        d = self._buckets.get(key)
        if not d:
            return []
        out = [d.popleft() for _ in range(min(max_n, len(d)))]
        if not d:
            del self._buckets[key]
        return out


class BatchAssembler:
    """Stack + zero-pad a same-bucket request list to the fixed shape."""

    def __init__(self, batch: int):
        if batch < 1:                   # not assert: gone under python -O
            raise ValueError(f"batch size must be >= 1, got {batch}")
        self.batch = batch

    def assemble(self, requests: List[Request]) -> Batch:
        if not requests:
            raise ValueError("cannot assemble an empty batch")
        if len(requests) > self.batch:
            raise ValueError(
                f"{len(requests)} requests exceed batch size {self.batch}")
        key = requests[0].bucket_key
        if any(r.bucket_key != key for r in requests):
            raise ValueError("mixed buckets in one batch: "
                             f"{ {r.bucket_key for r in requests} }")
        n_valid = len(requests)
        pad = self.batch - n_valid

        def stack(attr: str, operand: int) -> np.ndarray:
            rows = [np.asarray(getattr(r.cts[operand], attr))
                    for r in requests]
            if pad:
                z = np.zeros_like(rows[0])
                rows = rows + [z] * pad
            return np.stack(rows)

        arrays = {"ax1": stack("ax", 0), "bx1": stack("bx", 0)}
        if OPS[key[0]] == 2:
            arrays["ax2"] = stack("ax", 1)
            arrays["bx2"] = stack("bx", 1)
        if key[0] in PLAIN_OPS:
            rows = [np.asarray(r.pt) for r in requests]
            if pad:
                rows = rows + [np.zeros_like(rows[0])] * pad
            arrays["pt"] = np.stack(rows)
        return Batch(key=key, requests=list(requests), arrays=arrays,
                     n_valid=n_valid)
