"""Worker engine process for the multi-host serving tier.

A worker owns exactly the device-side half of the old monolithic
``HEServer``: a mesh, a resident level-sliced :class:`TableCache`, and
the jit-once :class:`OpEngine` steps.  Everything queue/scheduler/cache
shaped stays on the frontend (``repro.hserve.frontend``); the worker
only sees fully-assembled fixed-shape batches arriving as transport
frames, executes them, and frames the stacked results back.

Requests cross the wire as metadata only (rid + per-operand
(logq, logp, n_slots) + op parameters) — the engine reads nothing else
off a ``Request`` once the batch arrays are assembled, so
:class:`_CtMeta` stands in for operand ciphertexts and no limb data is
duplicated outside the batch arrays.

Health: each worker publishes a ``runtime.monitor.Heartbeat`` file
embedding its :class:`MetricsRegistry` snapshot (``worker.*`` counters
plus engine/cache sources).  The frontend's ``check_workers`` reads
these; a stale heartbeat marks the worker dead and its in-flight batch
is requeued.

``python -m repro.hserve.worker`` runs the subprocess loop: read an
``init`` frame from stdin (params, mesh shape, key material), then
serve ``batch``/``add_key``/``stats`` frames until ``shutdown``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.params import HEParams
from repro.hserve.queue import Batch, Request
from repro.hserve.tables import TableCache
from repro.obs.registry import MetricsRegistry
from repro.runtime.monitor import Heartbeat

__all__ = ["WorkerEngine", "main"]


@dataclasses.dataclass(frozen=True)
class _CtMeta:
    """Operand stand-in: the level metadata the engine's output-wrap
    reads (`OpEngine._wrap` touches cts[i].logq/.logp/.n_slots only —
    the limb arrays already ride the batch's stacked arrays)."""

    logq: int
    logp: int
    n_slots: int


def _batch_from_frame(head: Dict[str, Any],
                      arrays: Dict[str, np.ndarray]) -> Batch:
    """Rebuild an assembly-complete Batch from a "batch" frame."""
    op, logq, extra = head["key"]
    key = (op, int(logq), None if extra is None else int(extra))
    reqs = []
    for m in head["reqs"]:
        cts = tuple(_CtMeta(logq=int(logq), logp=int(lp),
                            n_slots=int(m["n_slots"]))
                    for lp in m["logps"])
        reqs.append(Request(
            rid=int(m["rid"]), op=op, cts=cts, r=int(m.get("r", 0)),
            dlogp=int(m.get("dlogp", 0)), logq2=int(m.get("logq2", 0)),
            pt=None, pt_logp=int(m.get("pt_logp", 0))))
    return Batch(key=key, requests=reqs,
                 arrays=dict(arrays), n_valid=int(head["n_valid"]))


class WorkerEngine:
    """One worker: mesh + TableCache + OpEngine behind a frame handler.

    Constructed directly by the frontend for the in-process transport,
    or from an ``init`` frame by :func:`main` for the subprocess one.
    Either way the message surface is :meth:`handle`.
    """

    def __init__(self, params: HEParams, evk=None, rot_keys=None,
                 conj_key=None, *, mesh=None, wid: int = 0,
                 clock: Callable[[], float] = time.perf_counter,
                 heartbeat_path=None, heartbeat_interval: float = 0.0,
                 heartbeat_clock: Optional[Callable[[], float]] = None,
                 **engine_knobs):
        import jax
        from repro.hserve.engine import OpEngine

        self.params = params
        self.wid = wid
        self.mesh = mesh if mesh is not None else \
            jax.make_mesh((1, 1), ("data", "model"))
        self.cache = TableCache(params, evk, rot_keys, conj_key)
        self.engine = OpEngine(params, self.mesh, self.cache,
                               **engine_knobs)
        self._clock = clock
        self.batches = 0
        self.registry = MetricsRegistry()
        self._c_batches = self.registry.counter("worker.batches")
        self._c_requests = self.registry.counter("worker.requests")
        self._h_wall = self.registry.histogram("worker.batch.wall_s")
        self.registry.add_source("cache", self.cache.stats)
        self.registry.add_source(
            "engine", lambda: {"steps_compiled": self.engine.n_compiled,
                               "compile_s": self.engine.compile_s})
        self.heartbeat = None
        if heartbeat_path is not None:
            # the heartbeat timestamp must live on the FRONTEND's
            # death-detection timeline (wall time.time for subprocess
            # workers, the injected fake clock for in-process tests) —
            # not on the perf_counter batch-wall clock.
            hb_clock = heartbeat_clock if heartbeat_clock is not None \
                else time.time
            self.heartbeat = Heartbeat(heartbeat_path,
                                       interval=heartbeat_interval,
                                       metrics=self.registry,
                                       clock=hb_clock)
            self.heartbeat.beat(step=0, payload={"wid": wid})

    def _beat(self) -> None:
        if self.heartbeat is not None:
            self.heartbeat.beat(step=self.batches,
                                payload={"wid": self.wid})

    def handle(self, head: Dict[str, Any], arrays: Dict[str, np.ndarray]
               ) -> Optional[Tuple[Dict[str, Any], Dict[str, np.ndarray]]]:
        """Dispatch one frontend frame; returns the reply frame parts."""
        t = head["type"]
        if t == "batch":
            reply = self.serve_batch(head, arrays)
        elif t == "add_key":
            from repro.core.cipher import EvalKey
            ek = EvalKey(ax_ev=arrays["ax_ev"],
                         ax_ev_shoup=arrays["ax_ev_shoup"],
                         bx_ev=arrays["bx_ev"],
                         bx_ev_shoup=arrays["bx_ev_shoup"])
            if head["kind"] == "rot":
                self.cache.add_rot_key(int(head["r"]), ek)
            elif head["kind"] == "conj":
                self.cache.add_conj_key(ek)
            else:
                raise ValueError(f"unknown key kind {head['kind']!r}")
            reply = ({"type": "ok"}, {})
        elif t == "stats":
            reply = ({"type": "stats",
                      "snapshot": self.registry.snapshot()}, {})
        elif t == "shutdown":
            reply = ({"type": "ok"}, {})
        else:
            raise ValueError(f"unknown message type {t!r}")
        self._beat()
        return reply

    def serve_batch(self, head: Dict[str, Any],
                    arrays: Dict[str, np.ndarray]
                    ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        b = _batch_from_frame(head, arrays)
        t0 = self._clock()
        outs, _ = self.engine.wait(self.engine.dispatch(b))
        wall = self._clock() - t0
        self.batches += 1
        self._c_batches.inc()
        self._c_requests.inc(b.n_valid)
        self._h_wall.add(wall)
        rhead = {"type": "result", "seq": head["seq"], "wall": wall,
                 "outs": [{"logq": c.logq, "logp": c.logp,
                           "n_slots": c.n_slots} for c in outs]}
        rarrays = {"ax": np.stack([np.asarray(c.ax) for c in outs]),
                   "bx": np.stack([np.asarray(c.bx) for c in outs])}
        return rhead, rarrays


def _keys_from_init(head: Dict[str, Any], arrays: Dict[str, np.ndarray]):
    """Rebuild (evk, rot_keys, conj_key) from an init frame's arrays
    (named ``evk.<f>`` / ``rot.<r>.<f>`` / ``conj.<f>``)."""
    from repro.core.cipher import EvalKey

    def ek(prefix: str) -> EvalKey:
        return EvalKey(ax_ev=arrays[f"{prefix}.ax_ev"],
                       ax_ev_shoup=arrays[f"{prefix}.ax_ev_shoup"],
                       bx_ev=arrays[f"{prefix}.bx_ev"],
                       bx_ev_shoup=arrays[f"{prefix}.bx_ev_shoup"])

    evk = ek("evk") if head.get("has_evk") else None
    rot_keys = {int(r): ek(f"rot.{r}") for r in head.get("rot_rs", [])}
    conj_key = ek("conj") if head.get("has_conj") else None
    return evk, rot_keys or None, conj_key


def main() -> None:
    """Subprocess entry: frames over stdin/stdout.

    stdout is reserved for frames — any stray print() from imported
    code is rerouted to stderr so it cannot corrupt the stream.
    """
    import sys

    out = sys.stdout.buffer
    inp = sys.stdin.buffer
    sys.stdout = sys.stderr

    from repro.hserve.transport import encode_frame, read_frame

    head, arrays = read_frame(inp)
    if head["type"] != "init":
        raise SystemExit(f"expected init frame, got {head['type']!r}")
    import jax

    params = HEParams(**head["params"])
    evk, rot_keys, conj_key = _keys_from_init(head, arrays)
    mesh = jax.make_mesh(tuple(head["mesh"]), ("data", "model"))
    hb = head.get("heartbeat") or {}
    worker = WorkerEngine(
        params, evk, rot_keys, conj_key, mesh=mesh,
        wid=int(head.get("wid", 0)),
        heartbeat_path=hb.get("path"),
        heartbeat_interval=float(hb.get("interval", 0.0)),
        **head.get("knobs", {}))
    out.write(encode_frame({"type": "ok", "wid": worker.wid}))
    out.flush()
    while True:
        head, arrays = read_frame(inp)
        reply = worker.handle(head, arrays)
        if reply is not None:
            out.write(encode_frame(*reply))
            out.flush()
        if head["type"] == "shutdown":
            break


if __name__ == "__main__":
    main()
