"""HEServer: the composed serving runtime (queue → engine → metrics).

Glues the subsystem pieces into the request loop `launch.serve --he`
and `benchmarks/serve_he.py` drive:

  submit(op, cts, ...)   →  RequestQueue buckets by (op, level, extra)
  submit_circuit(ops, inputs)
                         →  walk an op-DAG server-side with level
                            tracking; nodes enter the same queue and
                            batch with everyone else's requests
  poll()                 →  release at most one batch, chosen by the
                            flush policy: a bucket at the adaptive
                            target ("full"), else — under an SLO — the
                            bucket whose oldest request hit the age
                            deadline ("age"), else, when flushing, the
                            oldest non-empty bucket ("drain"); run it on
                            the mesh (optionally double-buffered),
                            record metrics, return (rid, Ciphertext)
                            results
  drain()                →  serve until queue + circuits + the in-flight
                            step are all empty

One HEServer owns one resident TableCache (tables built once at logQ,
every level served as slices) and one OpEngine (one compiled step per
(op, level) signature) — the serving design HEAX/Medha argue for: keys
and tables stay resident, work streams through them, and the WHOLE
ciphertext op set (mul, add/sub, rotate, conjugate, slot-sum, rescale,
mod-down) runs server-side so a client submits an encrypted circuit once
and gets one ciphertext back.

Continuous batching (ROADMAP → this PR): with ``max_age_s`` set, a
trickle of requests (arrival rate below the batch size) still meets the
latency SLO — poll() releases a bucket the moment its oldest request has
waited max_age_s, padding the batch. The bucket target itself adapts:
it is sized to the arrivals one deadline-window is expected to gather
(rate × max_age_s, clamped to [1, batch]), so at low rates the server
stops waiting for a full batch it will never see. Without ``max_age_s``
the old drain-only behavior is preserved (and so is its bug: a
sub-batch trickle never flushes — tests/test_hserve.py keeps a
regression test on both behaviors).

Double buffering (``overlap=True``): poll() dispatches the new batch
BEFORE blocking on the previous one, so host-side batch assembly +
device_put overlap the in-flight device step and the engine never waits
on the frontend. Results then arrive one poll late — submit→result
still runs front-to-back in drain(), and benchmarks/serve_he.py reports
the overlap-on/off drain-wall comparison.

Circuit-aware scheduling (``schedule=True``): submitted circuits'
validated level schedules are registered with a
:class:`repro.hserve.scheduler.CircuitScheduler`, which (a) defers an
under-full drain flush when a same-key sibling node from another
circuit is within the lookahead horizon — so concurrent circuits
co-batch even out of lockstep — and (b) prefetches the NEXT levels'
table slices while the current batch is in flight (riding the same
dispatch/wait double buffer). Scheduling never changes a result bit;
it only reorders drain flushes and warms caches.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cipher import Ciphertext, EvalKey
from repro.core.params import HEParams
from repro.hserve.circuit import CircuitOp, circuit_schedule
from repro.hserve.engine import Inflight, OpEngine, slot_sum_rotations
from repro.hserve.metrics import ServeMetrics
from repro.hserve.queue import Batch, BatchAssembler, PLAIN_OPS, \
    RequestQueue
from repro.hserve.scheduler import CircuitScheduler
from repro.hserve.tables import TableCache
from repro.obs.registry import MetricsRegistry

__all__ = ["HEServer"]


class _CircuitState:
    """One in-progress circuit: resolved values + submission bookkeeping.
    (The per-node bucket-key schedule lives in the scheduler, which is
    the only consumer — one copy, no drift.)"""

    def __init__(self, cid: int, ops: List[CircuitOp],
                 inputs: Dict[str, Ciphertext]):
        self.cid = cid
        self.ops = ops
        self.values: Dict[Union[int, str], Ciphertext] = dict(inputs)
        self.submitted: set = set()
        # per-node plaintext operands resolved from the server's
        # (hash, level) cache at submit_circuit time (nodes are frozen)
        self.pts: Dict[int, object] = {}


class HEServer:
    """Batched multi-level HE serving over a device mesh.

    params: the HEAAN parameter set every request must use.
    evk:    evaluation key (required to serve "mul").
    rot_keys: {r: rotation key} (required for "rotate" r and for the
              doubling amounts of any "slot_sum").
    conj_key: conjugation key (required to serve "conjugate").
    mesh:   device mesh (defaults to the host mesh); batch rides "data",
            CRT primes ride "model".
    batch:  fixed engine batch size — every trace is (batch, N, qlimbs).
    max_age_s: latency SLO — flush a bucket once its oldest request has
            waited this long (None keeps drain-only flushing).
    adaptive_target: size the full-bucket target from the observed
            arrival rate (rate × max_age_s, clamped to [1, batch]) so a
            trickle flushes promptly; only active under max_age_s.
    overlap: double-buffer batch assembly + device_put against the
            in-flight engine step (results arrive one poll late).
    schedule: circuit-aware scheduling — defer under-full drain flushes
            for same-key sibling nodes within `lookahead` engine batches
            (cross-circuit co-batching) and prefetch next-level table
            slices behind the in-flight batch. Mutable attribute, so
            benchmarks can A/B it on one warm server.
    lookahead: the scheduler's sibling horizon in engine batches.
    cost_model: optional `repro.analysis.cost.CostModel` — gates the
            scheduler's deferrals on estimated padded-batch device
            time (limb-cheap buckets flush immediately instead of
            waiting on siblings). Mutable attribute via
            ``server.scheduler.cost_model``, so benchmarks can A/B it
            on one warm server. None = pure lookahead policy.
    prefetch: table-slice prefetch on/off (only active under schedule).
    plain_cache_mib: LRU budget for the (hash, level) plaintext-operand
            cache (None = unbounded) — one-shot per-request operands
            must not accumulate forever on a long-running server.
    clock:  time source for ages/latencies (injectable for deterministic
            tests; defaults to time.perf_counter). Threaded into the
            RequestQueue so direct queue submits share the timeline.
    tracer: optional `repro.obs.Tracer` — request-lifecycle spans
            (submit → enqueue → bucket_wait → flush → batch_assemble →
            dispatch → device_wall → complete) and engine spans land in
            it; export with tracer.write(path) (Chrome trace-event
            JSON). None (default) records nothing and allocates nothing
            per request. Mutable via the `tracer` property (propagates
            to the engine and table cache), so benchmarks toggle it on
            a warm server.
    profile_stages: run engine steps EAGERLY with per-stage device
            fences so `engine.stage_timer` attributes mul wall to the
            paper's Fig. 3 CRT/NTT/modmul/iCRT buckets. Same bits,
            slower — a measurement mode, not a serving mode.
    registry: optional `repro.obs.MetricsRegistry` to publish into
            (one is created when absent). ServeMetrics, TableCache,
            CircuitScheduler, and the engine register as pull sources;
            `registry.snapshot()` is the live-telemetry JSON heartbeats
            embed.
    """

    # the arrival-rate estimate decays over this many deadline windows,
    # so a post-idle trickle sees its own rate, not the last burst's
    _RATE_DECAY_WINDOWS = 8

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None,
                 conj_key: Optional[EvalKey] = None, *,
                 mesh=None, batch: int = 8, use_kernels: bool = False,
                 max_age_s: Optional[float] = None,
                 adaptive_target: bool = True,
                 overlap: bool = False,
                 schedule: bool = False,
                 lookahead: int = 2,
                 cost_model=None,
                 prefetch: bool = True,
                 plain_cache_mib: Optional[float] = 256.0,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer=None, profile_stages: bool = False,
                 registry=None,
                 **engine_knobs):
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.cache = TableCache(params, evk, rot_keys, conj_key,
                                plain_cache_mib=plain_cache_mib)
        self.engine = OpEngine(params, mesh, self.cache,
                               use_kernels=use_kernels, tracer=tracer,
                               profile_stages=profile_stages,
                               **engine_knobs)
        self._init_core(params, mesh=mesh, batch=batch,
                        max_age_s=max_age_s,
                        adaptive_target=adaptive_target, overlap=overlap,
                        schedule=schedule, lookahead=lookahead,
                        cost_model=cost_model, prefetch=prefetch,
                        clock=clock, tracer=tracer, registry=registry)
        self.registry.add_source("cache", self.cache.stats)
        self.registry.add_source(
            "engine", lambda: {"steps_compiled": self.engine.n_compiled,
                               "compile_s": round(self.engine.compile_s,
                                                  3)})

    def _init_core(self, params: HEParams, *, mesh, batch: int,
                   max_age_s: Optional[float], adaptive_target: bool,
                   overlap: bool, schedule: bool, lookahead: int,
                   cost_model, prefetch: bool,
                   clock: Callable[[], float], tracer, registry) -> None:
        """The engine-free serving core: queue + scheduler + circuit
        state + metrics plane. Shared verbatim by the monolithic server
        (which adds a local TableCache/OpEngine) and the multi-host
        frontend (`repro.hserve.frontend.HEFrontend`, which routes
        batches to worker engines instead). Expects `self.cache` to be
        set already (a TableCache or the frontend's key catalog)."""
        self.params = params
        self.mesh = mesh
        self.batch = batch
        self.max_age_s = max_age_s
        self.adaptive_target = adaptive_target
        self.overlap = overlap
        self.schedule = schedule
        self.prefetch = prefetch
        self._clock = clock
        self.queue = RequestQueue(clock=clock)
        self.assembler = BatchAssembler(batch)
        self.metrics = ServeMetrics()
        # always constructed (registration is cheap bookkeeping), so
        # `schedule` can be toggled on a warm server without losing the
        # in-progress circuits' schedules
        self.scheduler = CircuitScheduler(lookahead=lookahead,
                                          cost_model=cost_model)
        self._inflight: Optional[Inflight] = None
        self._circuits: Dict[int, _CircuitState] = {}
        self._node_of_rid: Dict[int, Tuple[int, int]] = {}
        # cid -> per-node pipeline-stage labels for in-flight bootstrap
        # circuits (submit_bootstrap): drives the boot.* trace lane
        self._boot_stages: Dict[int, List[str]] = {}
        self._tracer = tracer
        self.cache.tracer = tracer
        # telemetry plane: every subsystem publishes into ONE registry.
        # Sources read through `self.metrics` (a lambda, not the bound
        # method) so reset_metrics()'s window swap stays published.
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.registry.add_source("serve", lambda: self.metrics.summary())
        self.registry.add_source("scheduler", self.scheduler.stats)
        self._c_polls = self.registry.counter("serve.polls")
        self._c_batches = self.registry.counter("serve.batches")
        self._c_requests = self.registry.counter("serve.requests")
        self._g_depth = self.registry.gauge("serve.queue.depth")
        self._h_wall = self.registry.histogram("serve.batch.wall_s")

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        """Re-point the trace sink everywhere at once (engine + table
        cache + the profile-mode stage timer follow the server's)."""
        self._tracer = t
        if self.engine is not None:
            self.engine.tracer = t
        self.cache.tracer = t

    # ---- request intake --------------------------------------------------

    def submit(self, op: str, cts, r: int = 0, dlogp: int = 0,
               logq2: int = 0, pt=None, pt_logp: int = 0,
               pt_hash: Optional[str] = None,
               pt_owned: bool = False) -> int:
        """Enqueue one request; returns its rid (used to match results).

        Lifecycle trace: a traced submit lands two instants — "submit"
        (intake, before validation) and "enqueue" (accepted into its
        bucket) — on the "requests" lane; the untraced path takes no
        clock reads and allocates nothing.

        Key availability is checked HERE, not at execution: a request
        the engine cannot serve must never enter the queue (it would
        fail mid-drain, after being popped, taking the batch's other
        requests down with it). rescale's dlogp defaults to params.logp;
        mul_plain's pt_logp to params.log_delta. The plaintext ops need
        NO key material — that is their point; with a pt_hash their
        encoded operand is registered in (pt given) or resolved from
        (pt None) the server's (hash, level) plaintext cache, so a
        reused operand ships and encodes ONCE. pt_owned marks pt as a
        server-owned resident buffer (a cache entry) the queue may
        alias instead of defensively copying; hash-resolved operands
        set it themselves. t_submit comes from the queue's clock (the
        server's injected one).
        """
        tr = self._tracer
        t_in = self._clock() if tr is not None else 0.0
        register = None
        if op in PLAIN_OPS and pt_hash is not None:
            first = cts[0] if isinstance(cts, (tuple, list)) else cts
            if pt is None:
                pt = self.cache.get_plain(pt_hash, first.logq)
                pt_owned = True
            else:
                # registration happens AFTER queue validation below — a
                # rejected operand must never poison the cache (a later
                # hash-only circuit would resolve it and fail mid-drain).
                # ONE owned read-only copy up front: the queue aliases
                # it (pt_owned) and put_plain adopts it — not three
                # copies of an (N, qlimbs) buffer for one registration.
                pt = np.array(pt)
                pt.setflags(write=False)
                pt_owned = True
                register = (pt_hash, first.logq)
        if op == "mul":
            self.cache.evk()                  # raises when absent
        elif op == "rotate":
            self.cache.rot_key(r)             # raises when absent
        elif op == "conjugate":
            self.cache.conj_key()             # raises when absent
        elif op == "slot_sum":
            first = cts[0] if isinstance(cts, (tuple, list)) else cts
            missing = [rr for rr in slot_sum_rotations(first.n_slots)
                       if rr not in self.cache.rotation_amounts]
            if missing:
                raise KeyError(
                    f"slot_sum over {first.n_slots} slots needs rotation "
                    f"keys {missing}; loaded: {self.cache.rotation_amounts}")
        elif op == "rescale" and dlogp == 0:
            dlogp = self.params.logp          # negative falls through to
                                              # the queue's ValueError
        elif op == "mul_plain" and pt_logp == 0:
            pt_logp = self.params.log_delta
        rid = self.queue.submit(op, cts, r=r, dlogp=dlogp, logq2=logq2,
                                pt=pt, pt_logp=pt_logp, pt_owned=pt_owned)
        if register is not None:
            self.cache.put_plain(register[0], register[1], pt)
        self._c_requests.inc()
        if tr is not None:
            tr.event("submit", cat="lifecycle", lane="requests", ts=t_in,
                     args={"rid": rid, "op": op})
            tr.event("enqueue", cat="lifecycle", lane="requests",
                     ts=self._clock(), args={"rid": rid, "op": op})
        return rid

    def submit_mul(self, c1: Ciphertext, c2: Ciphertext) -> int:
        return self.submit("mul", (c1, c2))

    def submit_add(self, c1: Ciphertext, c2: Ciphertext) -> int:
        return self.submit("add", (c1, c2))

    def submit_sub(self, c1: Ciphertext, c2: Ciphertext) -> int:
        return self.submit("sub", (c1, c2))

    def submit_rotate(self, ct: Ciphertext, r: int) -> int:
        return self.submit("rotate", (ct,), r=r)

    def submit_conjugate(self, ct: Ciphertext) -> int:
        return self.submit("conjugate", (ct,))

    def submit_slot_sum(self, ct: Ciphertext) -> int:
        return self.submit("slot_sum", (ct,))

    def submit_rescale(self, ct: Ciphertext,
                       dlogp: Optional[int] = None) -> int:
        return self.submit("rescale", (ct,), dlogp=dlogp or 0)

    def submit_mod_down(self, ct: Ciphertext, logq2: int) -> int:
        return self.submit("mod_down", (ct,), logq2=logq2)

    def submit_mod_raise(self, ct: Ciphertext, logq2: int) -> int:
        """Raise ct to a wider modulus logq2 > ct.logq (the exact
        centered lift — bootstrap stage 1; see `repro.boot`)."""
        return self.submit("mod_raise", (ct,), logq2=logq2)

    def submit_mul_plain(self, ct: Ciphertext, pt=None,
                         pt_logp: Optional[int] = None,
                         pt_hash: Optional[str] = None) -> int:
        """Ciphertext × encoded plaintext (region 1 only — no key
        switch). pt: (N, qlimbs) mod-q limbs at ct's level
        (core.heaan.encode_plain); pt_logp defaults to params.log_delta.
        pt_hash registers/references the server's plaintext cache —
        pt=None resolves a previously registered operand by hash."""
        return self.submit("mul_plain", (ct,), pt=pt, pt_logp=pt_logp or 0,
                           pt_hash=pt_hash)

    def submit_add_plain(self, ct: Ciphertext, pt=None,
                         pt_logp: Optional[int] = None,
                         pt_hash: Optional[str] = None) -> int:
        """Ciphertext + encoded plaintext (bx-only limb add; the
        plaintext must be encoded at ct's scale). pt_hash as in
        :meth:`submit_mul_plain`."""
        return self.submit("add_plain", (ct,), pt=pt, pt_logp=pt_logp or 0,
                           pt_hash=pt_hash)

    # ---- circuits --------------------------------------------------------

    def submit_circuit(self, ops: Sequence[CircuitOp],
                       inputs: Dict[str, Ciphertext]) -> int:
        """Submit a whole encrypted circuit; returns a cid whose result
        (the LAST node's ciphertext) appears in poll()/drain() output
        exactly like a plain request's.

        The DAG is validated up front — (logq, logp) propagated through
        every node from the input ciphertexts' metadata, key
        availability checked per op — so an ill-formed circuit raises
        here, before anything is enqueued. Nodes are then submitted as
        their operands resolve: source nodes immediately, the rest as
        batches complete, so concurrent circuits (and plain requests)
        batch together whenever their (op, level) signatures align.
        """
        ops = list(ops)
        meta = {name: (ct.logq, ct.logp) for name, ct in inputs.items()}
        in_slots = {name: ct.n_slots for name, ct in inputs.items()}
        # the validated level schedule: per-node (logq, logp), per-node
        # queue bucket key (what the scheduler looks ahead at), per-node
        # slot count (every op preserves its first operand's n_slots)
        _, keys, nslots = circuit_schedule(ops, meta, in_slots, self.params)
        # key availability, up front — a node the engine cannot serve
        # must never let ANY of the circuit enter the queue (it would
        # fail mid-drain with siblings already submitted).
        for i, node in enumerate(ops):
            if node.op == "mul":
                self.cache.evk()
            elif node.op == "rotate":
                self.cache.rot_key(node.r)
            elif node.op == "conjugate":
                self.cache.conj_key()
            elif node.op == "slot_sum":
                missing = [rr for rr in slot_sum_rotations(nslots[i])
                           if rr not in self.cache.rotation_amounts]
                if missing:
                    raise KeyError(
                        f"circuit slot_sum over {nslots[i]} slots needs "
                        f"rotation keys {missing}; loaded: "
                        f"{self.cache.rotation_amounts}")
        # plaintext operands, resolved against the (hash, level) cache up
        # front: a hash the server never saw must reject the WHOLE
        # circuit here (never mid-drain); a provided pt with a hash is
        # registered so later circuits reference it without re-shipping
        pts: Dict[int, object] = {}
        for i, node in enumerate(ops):
            if node.op in PLAIN_OPS and node.pt_hash is not None:
                in_logq = keys[i][1]
                if node.pt is None:
                    try:
                        pts[i] = self.cache.get_plain(node.pt_hash, in_logq)
                    except KeyError as e:
                        raise ValueError(f"circuit node {i}: {e.args[0]}") \
                            from None
                else:
                    pts[i] = self.cache.put_plain(node.pt_hash, in_logq,
                                                  node.pt)
        cid = self.queue.reserve_rid()
        circ = _CircuitState(cid, ops, inputs)
        circ.pts = pts
        self._circuits[cid] = circ
        self.scheduler.register(
            cid, keys, [tuple(a for a in node.args if isinstance(a, int))
                        for node in ops])
        self._submit_ready(circ)
        return cid

    def submit_bootstrap(self, ct: Ciphertext, *, config=None,
                         plan=None) -> int:
        """Submit a full bootstrap pipeline (see `repro.boot`) for one
        level-exhausted ciphertext; returns a cid whose result — the
        REFRESHED ciphertext at plan.out_logq — arrives like any other
        circuit's. Every stage rides submit_circuit, so concurrent
        bootstraps co-batch their aligned rotation/mul nodes, and the
        CtS/StC diagonals land in the plaintext cache (hash-only on
        every repeat shape). Pass a prebuilt `BootstrapPlan` to skip
        plan construction (sessions cache plans per input shape)."""
        from repro.boot.pipeline import bootstrap_circuit
        if plan is None:
            plan = bootstrap_circuit(
                self.params, logq_in=ct.logq, logp=ct.logp,
                n_slots=ct.n_slots, config=config,
                plain_lookup=self.cache.has_plain)
        if (ct.logq, ct.logp, ct.n_slots) != (plan.logq_in, plan.logp,
                                              plan.n_slots):
            raise ValueError(
                f"plan was built for (logq={plan.logq_in}, "
                f"logp={plan.logp}, n={plan.n_slots}), got ciphertext "
                f"at (logq={ct.logq}, logp={ct.logp}, n={ct.n_slots})")
        cid = self.submit_circuit(plan.ops, {plan.in_name: ct})
        self._boot_stages[cid] = list(plan.stages)
        return cid

    def _submit_ready(self, circ: _CircuitState) -> None:
        """Enqueue every not-yet-submitted node whose operands are all
        resolved (inputs or completed earlier nodes)."""
        for i, node in enumerate(circ.ops):
            if i in circ.submitted:
                continue
            try:
                cts = tuple(circ.values[a] for a in node.args)
            except KeyError:
                continue                      # operands not ready yet
            rid = self.submit(node.op, cts, r=node.r, dlogp=node.dlogp,
                              logq2=node.logq2,
                              pt=circ.pts.get(i, node.pt),
                              pt_logp=node.pt_logp,
                              pt_owned=i in circ.pts)
            circ.submitted.add(i)
            self._node_of_rid[rid] = (circ.cid, i)
            self.scheduler.on_enqueued(circ.cid, i)

    def _feed_circuit(self, cid: int, node_idx: int, ct: Ciphertext
                      ) -> List[Tuple[int, Ciphertext]]:
        """Route one completed node result back into its circuit; returns
        the client-visible (cid, result) pair when the circuit finishes."""
        self.scheduler.on_completed(cid, node_idx)
        circ = self._circuits.get(cid)
        if circ is None:                      # finished via its last node
            return []                         # while a dangling node ran
        circ.values[node_idx] = ct
        if node_idx == len(circ.ops) - 1:
            del self._circuits[cid]
            self._boot_stages.pop(cid, None)
            self.scheduler.on_finished(cid)
            return [(cid, ct)]
        self._submit_ready(circ)
        return []

    # ---- the serving loop ------------------------------------------------

    def _bucket_target(self, now: Optional[float] = None) -> int:
        """Full-bucket release threshold. Fixed at `batch` without an
        SLO; under one, sized to the arrivals a deadline window is
        expected to gather so a trickle stops waiting for a full batch.
        The rate estimate decays over _RATE_DECAY_WINDOWS deadline
        windows — after an idle gap the target shrinks back to current
        traffic instead of staying inflated from the last burst (the
        post-idle flush-stall regression)."""
        if self.max_age_s is None or not self.adaptive_target:
            return self.batch
        now = self._clock() if now is None else now
        rate = self.queue.arrival_rate(
            now, self._RATE_DECAY_WINDOWS * self.max_age_s)
        if not rate:
            return self.batch
        return max(1, min(self.batch, math.ceil(rate * self.max_age_s)))

    def poll(self, flush: bool = False) -> List[Tuple[int, Ciphertext]]:
        """Release + run at most one batch per the flush policy (full →
        age → drain); returns completed (rid, Ciphertext) pairs (empty
        if no work ran). With overlap, the dispatched batch's results
        return on the NEXT poll; a poll with no new work retires the
        in-flight batch instead of returning nothing.

        The drain cause is scheduler-aware under ``schedule=True``: an
        under-full bucket expecting a same-key sibling node within the
        lookahead horizon is deferred so the sibling co-batches — but
        SOME non-empty bucket is always released (the scheduler's
        progress guarantee), so a flush-poll on a non-empty queue can
        never return without running work.
        """
        self._c_polls.inc()
        self._g_depth.set(self.queue.depth)
        self.metrics.record_depth(self.queue.depth)
        now = self._clock()
        key, cause = self._choose_flush(flush, now)
        if key is None:
            return self._retire(self._take_inflight())
        b = self._pop_assemble(key, cause)
        if self.overlap:
            prev = self._take_inflight()
            self._inflight = self._dispatch(b)
            self._prefetch_next(b)            # rides the in-flight step
            return self._retire(prev)
        inf = self._dispatch(b)
        if self.engine.profile_stages:
            # profiling dispatch is synchronous (fenced stage blocks):
            # there is no in-flight step to hide the prefetch behind,
            # and running it before wait() would book its host-side
            # table-build time into this batch's device wall — sinking
            # the Fig. 3 stage-coverage attribution.
            outs, wall = self.engine.wait(inf)
            self._prefetch_next(b)
            return self._complete(b, outs, wall)
        self._prefetch_next(b)                # host work while b runs
        outs, wall = self.engine.wait(inf)
        return self._complete(b, outs, wall)

    def _choose_flush(self, flush: bool, now: float
                      ) -> Tuple[Optional[Tuple], str]:
        """The flush policy: (bucket key, cause) per full → age → drain
        precedence, or (None, ...) when nothing should release."""
        key, cause = self.queue.ready_key(self._bucket_target(now)), "full"
        if key is None and self.max_age_s is not None:
            key, cause = self.queue.expired_key(self.max_age_s, now), "age"
        if key is None and flush:
            key = (self.scheduler.drain_key(self.queue, self.batch)
                   if self.schedule else self.queue.any_key())
            cause = "drain"
        return key, cause

    def _pop_assemble(self, key: Tuple, cause: str) -> Batch:
        """Pop one bucket and assemble the fixed-shape batch, with the
        bucket_wait / flush / batch_assemble lifecycle tracing and flush
        accounting."""
        reqs = self.queue.pop_bucket(key, self.batch)
        tr = self._tracer
        if tr is not None:
            # bucket_wait per request: submit → popped from its bucket
            t_pop = self._clock()
            for r in reqs:
                tr.event("bucket_wait", cat="lifecycle", lane="requests",
                         ts=r.t_submit, dur=t_pop - r.t_submit,
                         args={"rid": r.rid, "op": r.op})
            tr.event("flush", cat="lifecycle", lane="server", ts=t_pop,
                     args={"cause": cause, "op": key[0], "logq": key[1],
                           "n": len(reqs)})
            with tr.span("batch_assemble", cat="lifecycle", lane="server",
                         args={"op": key[0], "n": len(reqs)}):
                b = self.assembler.assemble(reqs)
        else:
            b = self.assembler.assemble(reqs)
        self.metrics.record_flush(cause)
        self._c_batches.inc()
        return b

    def _work_pending(self) -> bool:
        """Is anything dispatched but not yet completed? (The frontend
        overrides this with its per-worker in-flight view.)"""
        return self._inflight is not None

    def _dispatch(self, b: Batch) -> Inflight:
        """engine.dispatch under a "dispatch" lifecycle span (place +
        async launch; the device wall lands separately at wait)."""
        if self._tracer is None:
            return self.engine.dispatch(b)
        with self._tracer.span("dispatch", cat="lifecycle", lane="server",
                               args={"op": b.op, "batch": b.size}):
            return self.engine.dispatch(b)

    def _prefetch_next(self, b: Batch) -> None:
        """Materialize the table slices the NEXT levels need while `b`
        is in flight: the successor nodes' input levels from the
        registered circuit schedules, plus this batch's own output level
        for the level-changing ops (rescale / mod-down). The per-np iCRT
        entries are the only host-side build; hiding it behind the
        running batch is the prefetch win."""
        if not (self.schedule and self.prefetch):
            return
        tags = [t for t in (self._node_of_rid.get(r.rid)
                            for r in b.requests) if t is not None]
        levels = self.scheduler.next_levels(tags)
        levels |= self.scheduler.levels_for_key(b.key)
        self.scheduler.prefetch_levels(self.cache, levels)

    def _take_inflight(self) -> Optional[Inflight]:
        inf, self._inflight = self._inflight, None
        return inf

    def _retire(self, inf: Optional[Inflight]
                ) -> List[Tuple[int, Ciphertext]]:
        if inf is None:
            return []
        outs, wall = self.engine.wait(inf)
        return self._complete(inf.batch, outs, wall)

    def _complete(self, b: Batch, outs: List[Ciphertext], wall: float
                  ) -> List[Tuple[int, Ciphertext]]:
        """Account one finished batch and route results: circuit-node
        rids feed their circuits (possibly enqueueing successor nodes);
        everything else goes straight back to the client."""
        done = self._clock()
        self.metrics.record_batch(
            b.op, b.logq, b.n_valid, b.n_pad, wall,
            [done - r.t_submit for r in b.requests])
        self._h_wall.add(wall)
        if self._tracer is not None:
            for r in b.requests:
                self._tracer.event(
                    "complete", cat="lifecycle", lane="requests",
                    ts=done, args={"rid": r.rid, "op": r.op,
                                   "latency_s": done - r.t_submit})
        tags = [self._node_of_rid.get(r.rid) for r in b.requests]
        n_nodes = sum(1 for t in tags if t is not None)
        if n_nodes:
            self.metrics.record_circuit_batch(
                len({t[0] for t in tags if t is not None}), n_nodes)
        if self._tracer is not None and self._boot_stages:
            # boot.* lane: attribute this batch's wall to the bootstrap
            # pipeline stages it served, proportionally by node count —
            # one span per (circuit, stage) present in the batch
            by_stage: Dict[Tuple[int, str], int] = {}
            for t in tags:
                if t is not None and t[0] in self._boot_stages:
                    stage = self._boot_stages[t[0]][t[1]]
                    by_stage[(t[0], stage)] = \
                        by_stage.get((t[0], stage), 0) + 1
            for (cid, stage), count in sorted(by_stage.items()):
                self._tracer.event(
                    f"boot.{stage}", cat="boot", lane="boot",
                    ts=done - wall, dur=wall * count / b.n_valid,
                    args={"cid": cid, "nodes": count, "op": b.op,
                          "logq": b.logq})
        client: List[Tuple[int, Ciphertext]] = []
        for req, ct in zip(b.requests, outs):
            tag = self._node_of_rid.pop(req.rid, None)
            if tag is None:
                client.append((req.rid, ct))
            else:
                client.extend(self._feed_circuit(*tag, ct))
        return client

    def drain(self) -> Dict[int, Ciphertext]:
        """Serve until the queue, EVERY in-flight circuit, and the
        in-flight step are all empty (padding the stragglers); returns
        {rid: result} (circuit results under their cid).

        The loop iterates on all three states because a circuit node's
        parent can complete during the FINAL drain pass — its children
        are enqueued inside poll(), after this iteration's flush choice
        was made, and only the next iteration serves them. A flush-poll
        on a non-empty queue always runs a batch (the scheduler's
        deferral keeps a progress guarantee), so the loop terminates; if
        a circuit nevertheless ends up with no node queued or in flight,
        its ready nodes are re-armed once before giving up."""
        results: Dict[int, Ciphertext] = {}
        while (self.queue.depth or self._work_pending()
               or self._circuits):
            served = self.poll(flush=True)
            for rid, ct in served:
                results[rid] = ct
            if (not served and not self.queue.depth
                    and not self._work_pending()):
                if self._circuits:
                    # defensive self-heal: re-run readiness over the
                    # stragglers; anything enqueued keeps the loop alive
                    for circ in list(self._circuits.values()):
                        self._submit_ready(circ)
                    if self.queue.depth:
                        continue
                    raise RuntimeError(
                        f"circuit(s) {sorted(self._circuits)} stalled "
                        "with no pending requests")
                break
        return results

    # ---- accounting ------------------------------------------------------

    def reset_metrics(self) -> None:
        """Start a fresh measurement window (compiled steps and resident
        tables are kept — use after a warm-up pass so reported latencies
        are steady state). The scheduler's deferral/prefetch counters
        reset with it, so stats()["scheduler"] reads per-window too;
        in-progress circuit schedules are untouched."""
        self.metrics = ServeMetrics()
        self.scheduler.reset_counters()

    def stats(self) -> dict:
        st = self.engine.stage_timer
        return {
            **self.metrics.summary(),
            **({"stages": st.summary()} if st is not None else {}),
            "cache": self.cache.stats(),
            "engine": {"steps_compiled": self.engine.n_compiled,
                       "compile_s": round(self.engine.compile_s, 3)},
            "mesh": dict(self.mesh.shape),
            "batch": self.batch,
            "flush_policy": {
                "max_age_s": self.max_age_s,
                "adaptive_target": self.adaptive_target,
                "bucket_target": self._bucket_target(),
                "overlap": self.overlap,
            },
            "scheduler": {"enabled": self.schedule,
                          "prefetch_tables": self.prefetch,
                          **self.scheduler.stats()},
            "submitted": self.queue.submitted,
        }
