"""HEServer: the composed serving runtime (queue → engine → metrics).

Glues the four subsystem pieces into the request loop `launch.serve --he`
and `benchmarks/serve_he.py` drive:

  submit(op, cts[, r])  →  RequestQueue buckets by (op, level)
  poll()                →  assemble the oldest full bucket, run it on the
                           mesh, record throughput/latency, return
                           (rid, Ciphertext) results
  drain()               →  flush remaining partial buckets with padding

One HEServer owns one resident TableCache (tables built once at logQ,
every level served as slices) and one OpEngine (one compiled step per
(op, level) signature) — the serving design HEAX/Medha argue for: keys
and tables stay resident, work streams through them.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.core.cipher import Ciphertext, EvalKey
from repro.core.params import HEParams
from repro.hserve.engine import OpEngine
from repro.hserve.metrics import ServeMetrics
from repro.hserve.queue import BatchAssembler, RequestQueue
from repro.hserve.tables import TableCache

__all__ = ["HEServer"]


class HEServer:
    """Batched multi-level HE serving over a device mesh.

    params: the HEAAN parameter set every request must use.
    evk:    evaluation key (required to serve "mul").
    rot_keys: {r: rotation key} (required for "rotate" r and for the
              doubling amounts of any "slot_sum").
    mesh:   device mesh (defaults to the host mesh); batch rides "data",
            CRT primes ride "model".
    batch:  fixed engine batch size — every trace is (batch, N, qlimbs).
    """

    def __init__(self, params: HEParams, evk: Optional[EvalKey] = None,
                 rot_keys: Optional[Dict[int, EvalKey]] = None, *,
                 mesh=None, batch: int = 8, use_kernels: bool = False,
                 **engine_knobs):
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.params = params
        self.mesh = mesh
        self.batch = batch
        self.cache = TableCache(params, evk, rot_keys)
        self.engine = OpEngine(params, mesh, self.cache,
                               use_kernels=use_kernels, **engine_knobs)
        self.queue = RequestQueue()
        self.assembler = BatchAssembler(batch)
        self.metrics = ServeMetrics()

    # ---- request intake --------------------------------------------------

    def submit(self, op: str, cts, r: int = 0) -> int:
        """Enqueue one request; returns its rid (used to match results).

        Key availability is checked HERE, not at execution: a request
        the engine cannot serve must never enter the queue (it would
        fail mid-drain, after being popped, taking the batch's other
        requests down with it).
        """
        if op == "mul":
            self.cache.evk()                  # raises when absent
        elif op == "rotate":
            self.cache.rot_key(r)             # raises when absent
        elif op == "slot_sum":
            from repro.hserve.engine import slot_sum_rotations
            first = cts[0] if isinstance(cts, (tuple, list)) else cts
            missing = [rr for rr in slot_sum_rotations(first.n_slots)
                       if rr not in self.cache.rotation_amounts]
            if missing:
                raise KeyError(
                    f"slot_sum over {first.n_slots} slots needs rotation "
                    f"keys {missing}; loaded: {self.cache.rotation_amounts}")
        return self.queue.submit(op, cts, r=r)

    def submit_mul(self, c1: Ciphertext, c2: Ciphertext) -> int:
        return self.submit("mul", (c1, c2))

    def submit_rotate(self, ct: Ciphertext, r: int) -> int:
        return self.submit("rotate", (ct,), r=r)

    def submit_slot_sum(self, ct: Ciphertext) -> int:
        return self.submit("slot_sum", (ct,))

    # ---- the serving loop ------------------------------------------------

    def poll(self, flush: bool = False) -> List[Tuple[int, Ciphertext]]:
        """Run at most one batch. Takes the oldest bucket holding a full
        batch; with `flush`, takes the oldest non-empty bucket and pads.
        Returns completed (rid, Ciphertext) pairs (empty if no work ran).
        """
        self.metrics.record_depth(self.queue.depth)
        key = self.queue.ready_key(self.batch)
        if key is None and flush:
            key = self.queue.any_key()
        if key is None:
            return []
        reqs = self.queue.pop_bucket(key, self.batch)
        b = self.assembler.assemble(reqs)
        self.engine.warm_batch(b)        # keep compile out of steady state
        t0 = time.perf_counter()
        outs = self.engine.run(b)
        done = time.perf_counter()
        self.metrics.record_batch(
            b.op, b.logq, b.n_valid, b.n_pad, done - t0,
            [done - r.t_submit for r in b.requests])
        return [(r.rid, ct) for r, ct in zip(b.requests, outs)]

    def drain(self) -> Dict[int, Ciphertext]:
        """Serve until the queue is empty (padding the stragglers);
        returns {rid: result}."""
        results: Dict[int, Ciphertext] = {}
        while self.queue.depth:
            for rid, ct in self.poll(flush=True):
                results[rid] = ct
        return results

    # ---- accounting ------------------------------------------------------

    def reset_metrics(self) -> None:
        """Start a fresh measurement window (compiled steps and resident
        tables are kept — use after a warm-up pass so reported latencies
        are steady state)."""
        self.metrics = ServeMetrics()

    def stats(self) -> dict:
        return {
            **self.metrics.summary(),
            "cache": self.cache.stats(),
            "engine": {"steps_compiled": self.engine.n_compiled,
                       "compile_s": round(self.engine.compile_s, 3)},
            "mesh": dict(self.mesh.shape),
            "batch": self.batch,
            "submitted": self.queue.submitted,
        }
