"""Jit-once sharded op engine: HE Mul, Galois rotate, slot-sum reduction.

One compiled step per trace signature ``(op, logq[, extra])``, each built
from `dist.he_pipeline`'s stage bundle so every op shares the same mesh
placement (batch → "data", CRT primes → "model") and the same table
pytrees out of :class:`repro.hserve.tables.TableCache`:

  - ``mul``     — `dist.he_pipeline.make_he_mul_step` unchanged.
  - ``rotate``  — σ_k as a baked coefficient permutation + the SAME
    region-2 key switch HE Mul uses (`make_keyswitch_step`), so sharded
    rotations ride the pipeline for free (paper Fig. 2; HEAX lanes).
  - ``slot_sum``— the log₂(n)-rotation all-slots sum (the primitive
    encrypted dot products need), fused into one step: each round
    rotates by doubling powers and he_adds in place.

Every step is bitwise identical to its single-device `core` reference
(`core.heaan.he_mul`, `core.rotate.he_rotate`, and the he_add/he_rotate
composition) — integer limb arithmetic partitions exactly across the
mesh, so sharding and batching never change a bit.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import bigint
from repro.core.cipher import Ciphertext
from repro.core.params import HEParams
from repro.core.rotate import automorphism_poly, rotation_k
from repro.dist.he_pipeline import (
    HEStatic, he_static, make_he_mul_step, make_keyswitch_step,
    make_stage_fns,
)
from repro.dist.sharding import he_limb_sharding
from repro.hserve.queue import Batch
from repro.hserve.tables import TableCache

__all__ = ["slot_sum_rotations", "make_he_rotate_step",
           "make_slot_sum_step", "OpEngine"]


def slot_sum_rotations(n_slots: int) -> Tuple[int, ...]:
    """Doubling rotation amounts (1, 2, 4, …) that sum n_slots slots."""
    out, r = [], 1
    while r < n_slots:
        out.append(r)
        r *= 2
    return tuple(out)


def _make_automorphism_b(st: HEStatic, k: int) -> Callable:
    """Batched σ_k on (B, N, qlimbs) mod-q limb polynomials — exactly
    core.rotate.automorphism_poly, vmapped over the batch axis (one
    source of truth for the permute+negate semantics)."""
    params, logq = st.params, st.logq

    def auto_b(x: jnp.ndarray) -> jnp.ndarray:
        return jax.vmap(
            lambda p: automorphism_poly(p, params, k, logq))(x)

    return auto_b


def make_he_rotate_step(st: HEStatic, mesh, k: int, **knobs):
    """Build step(t2, rk, ax, bx) -> (ax', bx') for the automorphism σ_k.

    Batched/sharded `core.rotate._apply_galois`: permute coefficients,
    then region-2 key-switch against the rotation key (same table pytree
    shape as the evk). knobs are make_stage_fns' (use_kernels, …).
    """
    sf = make_stage_fns(st, mesh, **knobs)
    keyswitch = make_keyswitch_step(st, sf)
    auto_b = _make_automorphism_b(st, k)
    logq = st.logq

    def step(t2, rk, ax, bx):
        ax_r = auto_b(ax)
        bx_r = auto_b(bx)
        ks_ax, ks_bx = keyswitch(t2, rk, ax_r)
        ax3 = bigint.mask_bits(ks_ax, logq)
        bx3 = bigint.mask_bits(bigint.add(bx_r, ks_bx), logq)
        return sf.out(ax3), sf.out(bx3)

    return step


def make_slot_sum_step(st: HEStatic, mesh, n_slots: int, **knobs):
    """Build step(t2, rks, ax, bx) summing all n_slots slots into every
    slot: acc ← acc + rotate(acc, r) for r = 1, 2, 4, … — log₂(n) fused
    rotate+add rounds, one key switch each. `rks` is a tuple of rotation
    key pytrees in slot_sum_rotations(n_slots) order."""
    sf = make_stage_fns(st, mesh, **knobs)
    keyswitch = make_keyswitch_step(st, sf)
    params = st.params
    autos = [_make_automorphism_b(st, rotation_k(params, r))
             for r in slot_sum_rotations(n_slots)]
    logq = st.logq

    def step(t2, rks, ax, bx):
        for auto_b, rk in zip(autos, rks):
            ax_r = auto_b(ax)
            bx_r = auto_b(bx)
            ks_ax, ks_bx = keyswitch(t2, rk, ax_r)
            rot_ax = bigint.mask_bits(ks_ax, logq)
            rot_bx = bigint.mask_bits(bigint.add(bx_r, ks_bx), logq)
            ax = bigint.mask_bits(bigint.add(ax, rot_ax), logq)
            bx = bigint.mask_bits(bigint.add(bx, rot_bx), logq)
        return sf.out(ax), sf.out(bx)

    return step


class OpEngine:
    """Compile-once executor for assembled batches.

    Steps are cached by batch bucket key; tables come from the level-aware
    TableCache, so a new level costs one trace + slice views, never a
    table rebuild. `run` places operands on the mesh's data axis, executes
    the step, and re-wraps the valid rows as Ciphertexts.
    """

    def __init__(self, params: HEParams, mesh, cache: TableCache, *,
                 use_kernels: bool = False, crt_strategy: str = "matmul",
                 icrt_strategy: str = "matmul",
                 modified_shoup: bool = False):
        self.params = params
        self.mesh = mesh
        self.cache = cache
        self._knobs = dict(use_kernels=use_kernels,
                           crt_strategy=crt_strategy,
                           icrt_strategy=icrt_strategy,
                           modified_shoup=modified_shoup)
        self._steps: Dict[Tuple, Callable] = {}
        self._static: Dict[int, HEStatic] = {}
        self._warmed: set = set()
        self.compile_s = 0.0

    def _st(self, logq: int) -> HEStatic:
        if logq not in self._static:
            self._static[logq] = he_static(self.params, logq)
        return self._static[logq]

    def _step_for(self, key: Tuple) -> Callable:
        """step caches compile once per (op, logq, extra); returns a
        runner(arrays) -> (ax, bx) closing over the right tables."""
        if key in self._steps:
            return self._steps[key]
        op, logq, extra = key
        st = self._st(logq)
        t1, t2 = self.cache.level_tables(logq)
        if op == "mul":
            step = jax.jit(make_he_mul_step(st, self.mesh, **self._knobs))
            ek = self.cache.evk()

            def runner(a):
                return step(t1, t2, ek, a["ax1"], a["bx1"],
                            a["ax2"], a["bx2"])
        elif op == "rotate":
            k = rotation_k(self.params, extra)
            step = jax.jit(
                make_he_rotate_step(st, self.mesh, k, **self._knobs))
            rk = self.cache.rot_key(extra)

            def runner(a):
                return step(t2, rk, a["ax1"], a["bx1"])
        elif op == "slot_sum":
            step = jax.jit(
                make_slot_sum_step(st, self.mesh, extra, **self._knobs))
            rks = tuple(self.cache.rot_key(r)
                        for r in slot_sum_rotations(extra))

            def runner(a):
                return step(t2, rks, a["ax1"], a["bx1"])
        else:
            raise ValueError(f"unknown op {op!r}")
        self._steps[key] = runner
        return runner

    @property
    def n_compiled(self) -> int:
        return len(self._steps)

    def _place(self, batch: Batch) -> Dict[str, jnp.ndarray]:
        sh = he_limb_sharding(self.mesh, batch=batch.size)
        return {k: jax.device_put(v, sh) for k, v in batch.arrays.items()}

    def warm_batch(self, batch: Batch) -> None:
        """Trace + compile + one throwaway run for the batch's signature
        (no-op once warm); the elapsed time lands in `compile_s` so
        callers can time steady state cleanly.

        Deliberate trade-off: the first batch of a signature executes
        twice (once here, once timed in `run`) — one extra batch per
        (op, level) over the server's lifetime, amortized to nothing in
        steady-state serving. Reusing the warm outputs instead would
        record a ~0s wall for that batch and inflate reported
        throughput; AOT lower().compile() would avoid the re-run but is
        brittle against input-sharding commitment on this jax version.
        """
        if batch.key in self._warmed:
            return
        runner = self._step_for(batch.key)
        t0 = time.perf_counter()
        jax.block_until_ready(runner(self._place(batch)))
        self.compile_s += time.perf_counter() - t0
        self._warmed.add(batch.key)

    def run(self, batch: Batch) -> List[Ciphertext]:
        """Execute one assembled batch; returns the n_valid outputs in
        request order (padded lanes computed and discarded).

        A cold (op, level) signature is warmed first (`warm_batch`), so
        steady-state metrics never include compilation.
        """
        self.warm_batch(batch)
        runner = self._step_for(batch.key)
        arrays = self._place(batch)
        ax, bx = jax.block_until_ready(runner(arrays))
        out = []
        for i, req in enumerate(batch.requests):
            c0 = req.cts[0]
            logp = (c0.logp + req.cts[1].logp if batch.op == "mul"
                    else c0.logp)
            out.append(Ciphertext(ax=ax[i], bx=bx[i], logq=batch.logq,
                                  logp=logp, n_slots=c0.n_slots))
        return out
