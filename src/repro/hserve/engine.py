"""Jit-once sharded op engine: the full ciphertext-level op set.

One compiled step per trace signature ``(op, logq[, extra])``, each built
from `dist.he_pipeline`'s stage bundle so every op shares the same mesh
placement (batch → "data", CRT primes → "model") and the same table
pytrees out of :class:`repro.hserve.tables.TableCache`:

  - ``mul``      — `dist.he_pipeline.make_he_mul_step` unchanged
    (paper Fig. 2, both regions).
  - ``rotate``   — σ_{5^r} as a baked coefficient permutation + the SAME
    region-2 key switch HE Mul uses (`make_keyswitch_step`), so sharded
    rotations ride the pipeline for free (paper Fig. 2; HEAX lanes).
  - ``conjugate``— σ₋₁ (k = 2N−1) through the identical rotate step with
    the conjugation key; the automorphism index is the only difference.
  - ``slot_sum`` — the log₂(n)-rotation all-slots sum (the primitive
    encrypted dot products need), fused into one step: each round
    rotates by doubling powers and he_adds in place.
  - ``rescale`` / ``mod_down`` — the paper §III-A level-management ops.
    Because q is a power of two, both are batched shift/slice steps over
    the limb axis (no NTT, no key switch): rescale is a centered
    rounding shift by dlogp, mod-down a mask + limb slice. They reuse
    `core.heaan.rescale_poly` / `mod_down_poly` verbatim — the core and
    served paths share one implementation.
  - ``add`` / ``sub`` — §III-B limb adds with mod-q masking; cheap, but
    served so an entire encrypted circuit runs without a client
    round-trip between levels (the HEAX/Medha argument).
  - ``mul_plain`` / ``add_plain`` — the plaintext-operand ops encrypted
    inference's affine layers want: the operand is an ENCODED polynomial
    riding the batch (the "pt" array), so mul_plain is Fig. 2's region 1
    alone — CRT→NTT, one pointwise product per component, iNTT→iCRT —
    and add_plain a bare limb add into bx. NO region-2 key switch, no
    key material, no key-switch collectives: `launch.dryrun` lowers both
    and the HLO analysis shows zero collective bytes where mul pays the
    full region-2 traffic.

Every step is bitwise identical to its single-device `core` reference
(`core.heaan.he_mul`/`he_add`/`rescale`/`he_mod_down`,
`core.rotate.he_rotate`/`he_conjugate`, and the he_add/he_rotate
composition) — integer limb arithmetic partitions exactly across the
mesh, so sharding and batching never change a bit (tests/test_hserve.py,
including the 8-device mesh harness).

Double buffering: :meth:`OpEngine.dispatch` launches a step WITHOUT
blocking (JAX dispatch is async; `device_put` of the next batch and the
in-flight step overlap), returning an :class:`Inflight` handle that
:meth:`OpEngine.wait` later blocks on. `HEServer` uses the pair to
assemble batch n+1 while batch n runs, so the engine never waits on the
frontend; :meth:`OpEngine.run` is the synchronous dispatch→wait
composition.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import bigint
from repro.core.cipher import Ciphertext
from repro.core.heaan import mod_down_poly, mod_raise_poly, rescale_poly
from repro.core.params import HEParams
from repro.core.rotate import automorphism_poly, conjugation_k, rotation_k
from repro.dist.he_pipeline import (
    HEStatic, _glue_jit, he_static, make_he_mul_step, make_keyswitch_step,
    make_stage_fns,
)
from repro.dist.sharding import he_limb_sharding
from repro.hserve.queue import Batch
from repro.hserve.tables import TableCache
from repro.obs.stages import StageTimer

__all__ = ["STAGE_OPS", "slot_sum_rotations", "make_he_rotate_step",
           "make_slot_sum_step", "make_rescale_step", "make_mod_down_step",
           "make_mod_raise_step", "make_addsub_step", "make_mul_plain_step",
           "make_add_plain_step",
           "Inflight", "OpEngine"]


# Ops whose steps run the Fig. 3 stage chain (CRT/NTT/modmul/iCRT) and
# therefore must execute stage-by-stage under --profile-stages. The
# rest (limb shifts/slices/adds) have no stages to attribute and stay
# fully jitted even while profiling.
STAGE_OPS = frozenset(
    {"mul", "rotate", "conjugate", "slot_sum", "mul_plain"})


def slot_sum_rotations(n_slots: int) -> Tuple[int, ...]:
    """Doubling rotation amounts (1, 2, 4, …) that sum n_slots slots."""
    out, r = [], 1
    while r < n_slots:
        out.append(r)
        r *= 2
    return tuple(out)


def _make_automorphism_b(st: HEStatic, k: int) -> Callable:
    """Batched σ_k on (B, N, qlimbs) mod-q limb polynomials — exactly
    core.rotate.automorphism_poly, vmapped over the batch axis (one
    source of truth for the permute+negate semantics)."""
    params, logq = st.params, st.logq

    def auto_b(x: jnp.ndarray) -> jnp.ndarray:
        return jax.vmap(
            lambda p: automorphism_poly(p, params, k, logq))(x)

    return auto_b


def make_he_rotate_step(st: HEStatic, mesh, k: int, **knobs):
    """Build step(t2, rk, ax, bx) -> (ax', bx') for the automorphism σ_k.

    Batched/sharded `core.rotate._apply_galois`: permute coefficients,
    then region-2 key-switch against the Galois key (same table pytree
    shape as the evk). Serves both "rotate" (k = 5^r) and "conjugate"
    (k = 2N−1) — the step is automorphism-index-generic. knobs are
    make_stage_fns' (use_kernels, …).
    """
    sf = make_stage_fns(st, mesh, **knobs)
    keyswitch = make_keyswitch_step(st, sf)
    gj = _glue_jit(sf)
    auto_b = gj(_make_automorphism_b(st, k))
    logq = st.logq
    mask_f = gj(lambda x: bigint.mask_bits(x, logq))
    addmask_f = gj(lambda a, b: bigint.mask_bits(bigint.add(a, b), logq))

    def step(t2, rk, ax, bx):
        ax_r = auto_b(ax)
        bx_r = auto_b(bx)
        ks_ax, ks_bx = keyswitch(t2, rk, ax_r)
        ax3 = mask_f(ks_ax)
        bx3 = addmask_f(bx_r, ks_bx)
        return sf.out(ax3), sf.out(bx3)

    return step


def make_slot_sum_step(st: HEStatic, mesh, n_slots: int, **knobs):
    """Build step(t2, rks, ax, bx) summing all n_slots slots into every
    slot: acc ← acc + rotate(acc, r) for r = 1, 2, 4, … — log₂(n) fused
    rotate+add rounds, one key switch each. `rks` is a tuple of rotation
    key pytrees in slot_sum_rotations(n_slots) order."""
    sf = make_stage_fns(st, mesh, **knobs)
    keyswitch = make_keyswitch_step(st, sf)
    gj = _glue_jit(sf)
    params = st.params
    autos = [gj(_make_automorphism_b(st, rotation_k(params, r)))
             for r in slot_sum_rotations(n_slots)]
    logq = st.logq
    mask_f = gj(lambda x: bigint.mask_bits(x, logq))
    addmask_f = gj(lambda a, b: bigint.mask_bits(bigint.add(a, b), logq))

    def step(t2, rks, ax, bx):
        for auto_b, rk in zip(autos, rks):
            ax_r = auto_b(ax)
            bx_r = auto_b(bx)
            ks_ax, ks_bx = keyswitch(t2, rk, ax_r)
            rot_ax = mask_f(ks_ax)
            rot_bx = addmask_f(bx_r, ks_bx)
            ax = addmask_f(ax, rot_ax)
            bx = addmask_f(bx, rot_bx)
        return sf.out(ax), sf.out(bx)

    return step


def make_rescale_step(st: HEStatic, mesh, dlogp: int, **knobs):
    """Build step(ax, bx) -> (ax', bx') dividing by 2^dlogp (§III-A).

    A pure batched shift/slice over the limb axis — q is a power of two,
    so rescaling never touches the RNS side. Output arrays are
    (B, N, qlimbs') at logq' = logq − dlogp. The body IS
    `core.heaan.rescale_poly` (batch axes pass through), so served
    rescale is bitwise `core.rescale` by construction.
    """
    sf = make_stage_fns(st, mesh, **knobs)
    params, logq = st.params, st.logq

    def step(ax, bx):
        return (sf.out(rescale_poly(ax, params, logq, dlogp)),
                sf.out(rescale_poly(bx, params, logq, dlogp)))

    return step


def make_mod_down_step(st: HEStatic, mesh, logq2: int, **knobs):
    """Build step(ax, bx) -> (ax', bx') switching to modulus 2^logq2:
    mask + slice to qlimbs(logq2) limbs (`core.heaan.mod_down_poly`
    batched; level alignment before add/mul across depths)."""
    sf = make_stage_fns(st, mesh, **knobs)
    params = st.params

    def step(ax, bx):
        return (sf.out(mod_down_poly(ax, params, logq2)),
                sf.out(mod_down_poly(bx, params, logq2)))

    return step


def make_mod_raise_step(st: HEStatic, mesh, logq2: int, **knobs):
    """Build step(ax, bx) -> (ax', bx') raising to modulus 2^logq2 —
    the bootstrap's first stage (`core.heaan.mod_raise_poly` batched):
    zero-pad the limb axis to qlimbs(logq2), center at the OLD logq
    boundary (sign extension), re-mask at logq2. Pure limb arithmetic,
    no NTT and no key switch, so like rescale/mod_down it predicts zero
    key-switch collectives (shardlint pins this on HLO)."""
    sf = make_stage_fns(st, mesh, **knobs)
    params, logq = st.params, st.logq

    def step(ax, bx):
        return (sf.out(mod_raise_poly(ax, params, logq, logq2)),
                sf.out(mod_raise_poly(bx, params, logq, logq2)))

    return step


def make_addsub_step(st: HEStatic, mesh, op: str, **knobs):
    """Build step(ax1, bx1, ax2, bx2) for "add"/"sub" — §III-B limb
    arithmetic + mod-q masking, batched and placed on the mesh."""
    if op not in ("add", "sub"):             # not assert: gone under -O
        raise ValueError(f"addsub step takes op 'add' or 'sub', "
                         f"got {op!r}")
    sf = make_stage_fns(st, mesh, **knobs)
    fn = bigint.add if op == "add" else bigint.sub
    logq = st.logq

    def step(ax1, bx1, ax2, bx2):
        return (sf.out(bigint.mask_bits(fn(ax1, ax2), logq)),
                sf.out(bigint.mask_bits(fn(bx1, bx2), logq)))

    return step


def make_mul_plain_step(st: HEStatic, mesh, **knobs):
    """Build step(t1, ax, bx, pt) -> (ax', bx') for ciphertext ×
    plaintext — paper Fig. 2's region 1 ONLY, no key switch.

    The encoded operand pt is batch data ((B, N, qlimbs) mod-q limbs),
    lifted to the region-1 eval domain once and multiplied pointwise
    into both components. np₁ covers 2N·q² (region1_target_bits), the
    same bound `core.heaan.he_mul_plain` uses, and iCRT reconstructs the
    exact integer product — so the served step is bitwise the core
    reference. The absence of region 2 is the op's whole point: affine
    layers of encrypted inference skip the key-switch collectives
    entirely (launch.dryrun lowers this cell to prove it on HLO).
    """
    sf = make_stage_fns(st, mesh, **knobs)
    logq, qlimbs = st.logq, st.qlimbs
    mask_f = _glue_jit(sf)(lambda x: bigint.mask_bits(x, logq))

    def step(t1, ax, bx, pt):
        ept = sf.to_eval(pt, t1)
        da = sf.from_eval(sf.mont_mul(sf.to_eval(ax, t1), ept, t1),
                          t1, st.icrt1, qlimbs)
        db = sf.from_eval(sf.mont_mul(sf.to_eval(bx, t1), ept, t1),
                          t1, st.icrt1, qlimbs)
        return sf.out(mask_f(da)), sf.out(mask_f(db))

    return step


def make_add_plain_step(st: HEStatic, mesh, **knobs):
    """Build step(ax, bx, pt) -> (ax, bx') adding an encoded plaintext
    into bx (mask at logq); ax passes through untouched — no NTT, no key
    switch, no collectives (`core.heaan.he_add_plain` batched)."""
    sf = make_stage_fns(st, mesh, **knobs)
    logq = st.logq

    def step(ax, bx, pt):
        return (sf.out(ax),
                sf.out(bigint.mask_bits(bigint.add(bx, pt), logq)))

    return step


@dataclasses.dataclass
class Inflight:
    """A dispatched-but-not-awaited engine step (double-buffer handle).

    ax/bx are the step's async output arrays; the host is free to
    assemble and `device_put` the next batch while the device works.
    """

    batch: Batch
    ax: jnp.ndarray
    bx: jnp.ndarray
    t0: float


class OpEngine:
    """Compile-once executor for assembled batches.

    Steps are cached by batch bucket key; tables come from the level-aware
    TableCache, so a new level costs one trace + slice views, never a
    table rebuild. `dispatch` places operands on the mesh's data axis and
    launches the step asynchronously; `wait` blocks, re-wraps the valid
    rows as Ciphertexts with the op's output level metadata, and returns
    the measured device wall time. `run` = wait(dispatch(batch)).
    """

    def __init__(self, params: HEParams, mesh, cache: TableCache, *,
                 use_kernels: bool = False, crt_strategy: str = "matmul",
                 icrt_strategy: str = "matmul",
                 modified_shoup: bool = False, tracer=None,
                 profile_stages: bool = False):
        self.params = params
        self.mesh = mesh
        self.cache = cache
        self.profile_stages = profile_stages
        # Fig. 3 attribution (repro.obs.StageTimer) needs per-stage
        # host-side fences, which jit tracing cannot express — so
        # profiling swaps jit for eager execution (same math, same
        # bits, slower) and threads the timer through make_stage_fns.
        self.stage_timer = StageTimer(tracer=tracer) if profile_stages \
            else None
        self._tracer = tracer
        self._knobs = dict(use_kernels=use_kernels,
                           crt_strategy=crt_strategy,
                           icrt_strategy=icrt_strategy,
                           modified_shoup=modified_shoup)
        if profile_stages:
            self._knobs["stage_timer"] = self.stage_timer
        self._steps: Dict[Tuple, Callable] = {}
        self._static: Dict[int, HEStatic] = {}
        self._warmed: set = set()
        self.compile_s = 0.0

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        """Re-pointable post-construction (benchmarks toggle tracing on
        a warm server); the stage timer follows the engine's tracer."""
        self._tracer = t
        if self.stage_timer is not None:
            self.stage_timer.tracer = t

    def _jit(self, fn: Callable, op: str) -> Callable:
        """jax.jit normally; identity under --profile-stages for ops in
        STAGE_OPS, whose stage fences must observe each stage's device
        completion (the stage/glue blocks inside are jitted
        individually). Stage-less limb ops have nothing to attribute
        and keep the fused jit either way."""
        if self.profile_stages and op in STAGE_OPS:
            return fn
        return jax.jit(fn)

    def _st(self, logq: int) -> HEStatic:
        if logq not in self._static:
            self._static[logq] = he_static(self.params, logq)
        return self._static[logq]

    def _step_for(self, key: Tuple) -> Callable:
        """step caches compile once per (op, logq, extra); returns a
        runner(arrays) -> (ax, bx) closing over the right tables."""
        if key in self._steps:
            return self._steps[key]
        op, logq, extra = key
        st = self._st(logq)
        t1, t2 = self.cache.level_tables(logq)
        if op == "mul":
            step = self._jit(make_he_mul_step(st, self.mesh, **self._knobs),
                             op)
            ek = self.cache.evk()

            def runner(a):
                return step(t1, t2, ek, a["ax1"], a["bx1"],
                            a["ax2"], a["bx2"])
        elif op == "rotate":
            k = rotation_k(self.params, extra)
            step = self._jit(
                make_he_rotate_step(st, self.mesh, k, **self._knobs), op)
            rk = self.cache.rot_key(extra)

            def runner(a):
                return step(t2, rk, a["ax1"], a["bx1"])
        elif op == "conjugate":
            step = self._jit(make_he_rotate_step(
                st, self.mesh, conjugation_k(self.params),
                **self._knobs), op)
            ck = self.cache.conj_key()

            def runner(a):
                return step(t2, ck, a["ax1"], a["bx1"])
        elif op == "slot_sum":
            step = self._jit(
                make_slot_sum_step(st, self.mesh, extra, **self._knobs),
                op)
            rks = tuple(self.cache.rot_key(r)
                        for r in slot_sum_rotations(extra))

            def runner(a):
                return step(t2, rks, a["ax1"], a["bx1"])
        elif op == "rescale":
            step = self._jit(
                make_rescale_step(st, self.mesh, extra, **self._knobs),
                op)

            def runner(a):
                return step(a["ax1"], a["bx1"])
        elif op == "mod_down":
            step = self._jit(
                make_mod_down_step(st, self.mesh, extra, **self._knobs),
                op)

            def runner(a):
                return step(a["ax1"], a["bx1"])
        elif op == "mod_raise":
            step = self._jit(
                make_mod_raise_step(st, self.mesh, extra, **self._knobs),
                op)

            def runner(a):
                return step(a["ax1"], a["bx1"])
        elif op in ("add", "sub"):
            step = self._jit(
                make_addsub_step(st, self.mesh, op, **self._knobs), op)

            def runner(a):
                return step(a["ax1"], a["bx1"], a["ax2"], a["bx2"])
        elif op == "mul_plain":
            step = self._jit(
                make_mul_plain_step(st, self.mesh, **self._knobs), op)

            def runner(a):
                return step(t1, a["ax1"], a["bx1"], a["pt"])
        elif op == "add_plain":
            step = self._jit(
                make_add_plain_step(st, self.mesh, **self._knobs), op)

            def runner(a):
                return step(a["ax1"], a["bx1"], a["pt"])
        else:
            raise ValueError(f"unknown op {op!r}")
        self._steps[key] = runner
        return runner

    @property
    def n_compiled(self) -> int:
        return len(self._steps)

    def _place(self, batch: Batch) -> Dict[str, jnp.ndarray]:
        sh = he_limb_sharding(self.mesh, batch=batch.size)
        if self._tracer is None:
            return {k: jax.device_put(v, sh)
                    for k, v in batch.arrays.items()}
        # H2D span: device_put is async, so this measures enqueue — on
        # the overlap path that is exactly the host-side transfer work
        # hidden behind the in-flight batch.
        with self._tracer.span("h2d", cat="engine", lane="engine",
                               args={"op": batch.op,
                                     "batch": batch.size}):
            return {k: jax.device_put(v, sh)
                    for k, v in batch.arrays.items()}

    def warm_batch(self, batch: Batch) -> None:
        """Trace + compile + one throwaway run for the batch's signature
        (no-op once warm); the elapsed time lands in `compile_s` so
        callers can time steady state cleanly.

        Deliberate trade-off: the first batch of a signature executes
        twice (once here, once timed in `run`) — one extra batch per
        (op, level) over the server's lifetime, amortized to nothing in
        steady-state serving. Reusing the warm outputs instead would
        record a ~0s wall for that batch and inflate reported
        throughput; AOT lower().compile() would avoid the re-run but is
        brittle against input-sharding commitment on this jax version.
        """
        if batch.key in self._warmed:
            return
        runner = self._step_for(batch.key)
        span = self._tracer.span(
            "warm_compile", cat="engine", lane="engine",
            args={"op": batch.op, "logq": batch.logq}) \
            if self._tracer is not None else None
        t0 = time.perf_counter()
        if self.stage_timer is not None:
            # warm runs must not pollute the Fig. 3 attribution: the
            # coverage gate compares stage sums against METERED wall.
            with self.stage_timer.pause():
                jax.block_until_ready(runner(self._place(batch)))
        else:
            jax.block_until_ready(runner(self._place(batch)))
        self.compile_s += time.perf_counter() - t0
        if span is not None:
            span.end()
        self._warmed.add(batch.key)

    # ---- async execution (double buffering) ------------------------------

    def dispatch(self, batch: Batch) -> Inflight:
        """Place + launch one batch WITHOUT blocking on the result.

        A cold (op, level) signature is warmed first (`warm_batch`), so
        steady-state metrics never include compilation. The returned
        handle's arrays are async — the caller overlaps the next batch's
        assembly and `device_put` against this step, then `wait`s.
        """
        self.warm_batch(batch)
        runner = self._step_for(batch.key)
        arrays = self._place(batch)
        t0 = time.perf_counter()
        if self.stage_timer is not None:
            with self.stage_timer.op(batch.op):
                ax, bx = runner(arrays)
        else:
            ax, bx = runner(arrays)
        return Inflight(batch=batch, ax=ax, bx=bx, t0=t0)

    def wait(self, inflight: Inflight
             ) -> Tuple[List[Ciphertext], float]:
        """Block on a dispatched batch; returns (outputs, wall_s) with
        the n_valid outputs in request order (padded lanes computed and
        discarded) and the dispatch→ready wall time AS OBSERVED BY THE
        HOST. On the synchronous run() path that is the device wall; on
        the overlapped path it additionally includes any host time
        between dispatch and this wait (an upper bound on device time —
        HEServer.poll retires an idle in-flight batch eagerly, so the
        slack is bounded by the caller's poll cadence). Per-op ops_per_s
        under overlap is therefore host-observed; use drain wall clocks
        (benchmarks/serve_he.py "overlap") to quantify the overlap win."""
        jax.block_until_ready((inflight.ax, inflight.bx))
        wall = time.perf_counter() - inflight.t0
        if self._tracer is not None:
            b = inflight.batch
            self._tracer.event(
                "device_wall", cat="lifecycle", lane="engine",
                ts=inflight.t0, dur=wall,
                args={"op": b.op, "logq": b.logq, "batch": b.size,
                      "n_valid": b.n_valid})
        return self._wrap(inflight.batch, inflight.ax, inflight.bx), wall

    def run(self, batch: Batch) -> List[Ciphertext]:
        """Synchronous dispatch→wait (kept for callers that don't
        pipeline); returns the n_valid outputs in request order."""
        outs, _ = self.wait(self.dispatch(batch))
        return outs

    def _wrap(self, batch: Batch, ax, bx) -> List[Ciphertext]:
        """Re-wrap step outputs as Ciphertexts with each op's output
        level metadata (the server-side level tracking contract):

          mul          logq,          logp₁ + logp₂
          mul_plain    logq,          logp + pt_logp
          add/sub/add_plain           logq, logp (equality checked at
                                      submit)
          rotate/conjugate/slot_sum   unchanged
          rescale      logq − dlogp,  logp − dlogp
          mod_down     logq2,         logp
          mod_raise    logq2,         logp
        """
        op = batch.op
        out = []
        for i, req in enumerate(batch.requests):
            c0 = req.cts[0]
            logq, logp = batch.logq, c0.logp
            if op == "mul":
                logp = c0.logp + req.cts[1].logp
            elif op == "mul_plain":
                logp = c0.logp + req.pt_logp
            elif op == "rescale":
                logq -= req.dlogp
                logp -= req.dlogp
            elif op in ("mod_down", "mod_raise"):
                logq = req.logq2
            out.append(Ciphertext(ax=ax[i], bx=bx[i], logq=logq,
                                  logp=logp, n_slots=c0.n_slots))
        return out
