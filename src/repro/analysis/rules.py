"""The hslint rule registry: stable IDs, severities, and checkers.

Every diagnostic the analyzer emits carries a stable rule ID (HS001…)
so CI greps, suppressions, and docs can reference findings precisely.
Severity semantics:

  error    the circuit cannot run (admission would reject it);
  warning  it runs but almost certainly not as intended;
  info     it runs correctly but leaves performance on the table.

The catalog (docs/ANALYSIS.md has the long-form version):

  HS001  modulus-exhaustion      error    dataflow violation — the
         shared engine rejected the circuit (exhausted modulus, level/
         scale mismatch, malformed node).
  HS002  precision-below-waterline warning  estimated output precision
         below the waterline (default 8 fractional bits).
  HS003  dead-node               warning  a node's output is never
         consumed (and it is not the circuit output) — wasted device
         time every submission.
  HS004  redundant/composite-rotation warning/info  rotate by a
         multiple of n_slots is a no-op; a non-power-of-two r needs a
         dedicated key where a pow2 decomposition (r = Σ 2^i) reuses
         provisioned hoisting keys.
  HS005  eager-rescale           info     a rescale with no downstream
         (plain-)mul — the scale discipline gains nothing, the limb
         drop could be deferred or dropped (lazy rescaling, cf.
         ROADMAP's EVA item).
  HS006  depth-headroom          info     the output retains ≥ 2 unused
         levels — a smaller logQ would shrink every limb array the
         device touches (the paper's §II point that q sizing is THE
         throughput lever).
  HS007  bootstrappable-exhaustion info   companion to an exhaustion
         HS001: names the node whose level-exhausted output a
         `repro.boot` bootstrap would refresh (run(bootstrap="auto")
         inserts it there automatically).

The HS1xx series is shardlint (`repro.analysis.xla`): findings about
the COMPILED serving engines' HLO, not about circuits — emitted by the
xla pass directly (check=None here, like HS001), against the analytic
collective/memory expectations `dist.sharding` exports:

  HS101  unexpected-collective   error    a collective kind the
         sharding rules never predict for that (op, level, mesh) cell —
         an implicit resharding crept into the lowered program.
  HS102  collective-bytes-drift  error    measured all-reduce wire
         bytes off the analytic ring-model prediction beyond tolerance.
  HS103  layout-churn            error    replica groups over the wrong
         mesh axis, or a collective count off the predicted schedule.
  HS104  peak-memory-over-budget error    the backend's peak-live-
         buffer estimate exceeds the per-device HBM budget.
  HS105  fusion-break            warning  fused-kernel count drifted
         from the committed SHARD_MANIFEST.json baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.analysis.dataflow import Meta, OpNode
from repro.analysis.noise import NodeNoise
from repro.core.params import HEParams

__all__ = ["Diagnostic", "Rule", "RULES", "RuleContext", "run_rules",
           "DEFAULT_WATERLINE_BITS"]

DEFAULT_WATERLINE_BITS = 8.0    # fractional bits the output must keep

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: rule ID, severity, human message, node index (None
    for whole-circuit findings)."""

    rule: str
    severity: str
    message: str
    node: Optional[int] = None

    def format(self) -> str:
        where = f"node {self.node}: " if self.node is not None else ""
        return f"{self.severity.upper():7s} {self.rule} {where}{self.message}"


@dataclasses.dataclass(frozen=True)
class RuleContext:
    """Everything a rule may inspect — computed once by the analyzer."""

    ops: Sequence[OpNode]
    input_meta: Dict[str, Meta]
    params: HEParams
    meta: Sequence[Meta]
    noise: Sequence[NodeNoise]
    # rotation amounts with provisioned keys; None = unknown (don't
    # flag missing keys, only structural rotation smells)
    provisioned_rotations: Optional[Set[int]] = None
    waterline_bits: float = DEFAULT_WATERLINE_BITS


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: str          # the DEFAULT severity; checkers may demote
    title: str
    check: Optional[Callable[[RuleContext], List[Diagnostic]]]


def _check_waterline(ctx: RuleContext) -> List[Diagnostic]:
    out = ctx.noise[-1]
    if out.precision_bits < ctx.waterline_bits:
        return [Diagnostic(
            "HS002", "warning",
            f"estimated output precision {out.precision_bits:.1f} bits "
            f"is below the {ctx.waterline_bits:.0f}-bit waterline "
            f"(predicted |slot error| 2^{out.error_bits:.1f} at "
            f"logp={out.logp}); shrink the circuit depth or raise logp",
            node=len(ctx.ops) - 1)]
    return []


def _check_dead_nodes(ctx: RuleContext) -> List[Diagnostic]:
    used = [False] * len(ctx.ops)
    used[len(ctx.ops) - 1] = True                   # the output
    for node in ctx.ops:
        for a in node.args:
            if isinstance(a, int):
                used[a] = True
    return [Diagnostic(
        "HS003", "warning",
        f"{ctx.ops[i].op} result is never consumed and is not the "
        f"circuit output — dead device work every submission",
        node=i) for i, u in enumerate(used) if not u]


def _pow2_terms(r: int) -> List[int]:
    return [1 << b for b in range(r.bit_length()) if r >> b & 1]


def _check_rotations(ctx: RuleContext) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for i, node in enumerate(ctx.ops):
        if node.op != "rotate":
            continue
        n = ctx.noise[i].n_slots
        if node.r % n == 0:
            diags.append(Diagnostic(
                "HS004", "warning",
                f"rotate by {node.r} is a no-op on {n} slots "
                f"(r ≡ 0 mod n_slots) — drop the node", node=i))
            continue
        r = node.r % n
        terms = _pow2_terms(r)
        if len(terms) > 1:
            have = ctx.provisioned_rotations
            missing = have is not None and r not in have
            covered = have is None or all(t in have for t in terms)
            diags.append(Diagnostic(
                "HS004", "warning" if (missing and covered) else "info",
                f"rotate by {r} is composite: " + (
                    f"no key is provisioned for r={r} but the pow2 "
                    if missing else "a pow2 ") +
                f"decomposition {'+'.join(map(str, terms))} reuses "
                f"{len(terms)} hoisting keys", node=i))
    return diags


def _check_eager_rescale(ctx: RuleContext) -> List[Diagnostic]:
    # transitive "feeds a future mul" reachability, computed backwards
    feeds_mul = [False] * len(ctx.ops)
    for i in range(len(ctx.ops) - 1, -1, -1):
        node = ctx.ops[i]
        hot = node.op in ("mul", "mul_plain") or feeds_mul[i]
        if hot:
            for a in node.args:
                if isinstance(a, int):
                    feeds_mul[a] = True
    return [Diagnostic(
        "HS005", "info",
        "rescale feeds no later (plain-)mul — the scale drop buys "
        "nothing here; defer it (lazy rescaling) or drop it if the "
        "consumer accepts the higher scale",
        node=i) for i, node in enumerate(ctx.ops)
        if node.op == "rescale" and not feeds_mul[i]]


def _check_depth_headroom(ctx: RuleContext) -> List[Diagnostic]:
    out_logq = ctx.meta[-1][0]
    spare = max(0, (out_logq - 1) // ctx.params.logp)
    if spare >= 2:
        return [Diagnostic(
            "HS006", "info",
            f"output sits at logq={out_logq}: {spare} unused levels of "
            f"headroom — a smaller logQ (≈{ctx.params.logQ - spare * ctx.params.logp}) "
            f"would shrink every limb array the device touches "
            f"(paper §II)", node=len(ctx.ops) - 1)]
    return []


RULES: Dict[str, Rule] = {r.id: r for r in (
    Rule("HS001", "error", "modulus-exhaustion / dataflow violation",
         None),                       # emitted by the analyzer itself
    Rule("HS002", "warning", "precision-below-waterline",
         _check_waterline),
    Rule("HS003", "warning", "dead-node", _check_dead_nodes),
    Rule("HS004", "warning", "redundant/composite-rotation",
         _check_rotations),
    Rule("HS005", "info", "eager-rescale", _check_eager_rescale),
    Rule("HS006", "info", "depth-headroom", _check_depth_headroom),
    # companion to a modulus-exhaustion HS001: names the node whose
    # output is the level-exhausted — and bootstrappable — ciphertext
    # (emitted by the analyzer itself, alongside the HS001)
    Rule("HS007", "info", "bootstrappable-exhaustion", None),
    # HS1xx: shardlint (repro.analysis.xla) emits these directly over
    # compiled-HLO cells; registered here so IDs/severities/titles stay
    # one catalog with stable references for CI greps and docs
    Rule("HS101", "error", "unexpected-collective", None),
    Rule("HS102", "error", "collective-bytes-drift", None),
    Rule("HS103", "error", "layout-churn", None),
    Rule("HS104", "error", "peak-memory-over-budget", None),
    Rule("HS105", "warning", "fusion-break", None),
)}


def run_rules(ctx: RuleContext) -> List[Diagnostic]:
    """Run every registered checker; diagnostics sorted by severity
    (errors first), then node order."""
    diags: List[Diagnostic] = []
    for rule in RULES.values():
        if rule.check is not None:
            diags.extend(rule.check(ctx))
    rank = {s: i for i, s in enumerate(SEVERITIES)}
    diags.sort(key=lambda d: (rank[d.severity],
                              -1 if d.node is None else d.node))
    return diags
