"""SHARD_MANIFEST.json schema + drift diff (stdlib only, importable
without numpy/jax).

The manifest is shardlint's checked-in measurement: for every served
(op, level, mesh) cell, the collective schedule (per-kind counts and
ring-model wire bytes), the replica-group axis classification, the
fused-kernel count, and the backend memory estimate of the compiled
HLO, next to the `dist.sharding.he_expected_collectives` prediction it
was verified against. `tools/check_docs.py` diffs a freshly measured
manifest against the committed one in CI — so a PR that changes a
collective count, wire bytes, or the fusion structure of a serving
engine must regenerate the manifest (`tools/shardlint.py --write`) and
explain the diff in review.

This module must stay stdlib-only: the docs CI job runs before any
dependency install, so check_docs loads it by file path (bypassing
`repro.analysis.__init__`, which imports numpy).
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, List, Optional, Union

import json

__all__ = ["SCHEMA_VERSION", "MANIFEST_NAME", "DEFAULT_TOLERANCES",
           "cell_key", "load_manifest", "validate_manifest",
           "diff_manifests"]

SCHEMA_VERSION = 1
MANIFEST_NAME = "SHARD_MANIFEST.json"

# bytes_rtol: committed-vs-fresh wire bytes (the ring model is exact on
#   a fixed XLA version, so drift means the partitioner changed — tight);
# expected_rtol: measured-vs-analytic all-reduce bytes (same model on
#   both sides: any drift is a real schedule change);
# fusion_rtol: fused-kernel count (fusion decisions wobble across XLA
#   minor versions — loose, and only ever a warning, HS105).
DEFAULT_TOLERANCES: Dict[str, float] = {
    "bytes_rtol": 0.01, "expected_rtol": 0.01, "fusion_rtol": 0.25,
}

_NUM = (int, float)

_TOP_SCHEMA: Dict[str, Any] = {
    "schema": int, "params": dict, "batch": int, "levels": list,
    "meshes": dict, "tolerances": dict, "hbm_budget_bytes": int,
    "cells": dict,
}
_PARAMS_KEYS = ("logN", "logQ", "logp", "beta_bits")
_CELL_SCHEMA: Dict[str, Any] = {
    "collectives": dict, "expected": dict, "group_axes": list,
    "fusions": int, "memory": dict,
}
_COLL_SCHEMA: Dict[str, Any] = {"counts": dict, "total_bytes": _NUM}
_EXPECTED_SCHEMA: Dict[str, Any] = {"counts": dict, "wire_bytes": _NUM}


def cell_key(op: str, logq: int, mesh_name: str) -> str:
    return f"{op}/{logq}/{mesh_name}"


def load_manifest(path: Union[str, Path]) -> Dict[str, Any]:
    obj = json.loads(Path(path).read_text())
    if not isinstance(obj, dict):
        raise ValueError(f"{path}: manifest is not a JSON object")
    return obj


def _check_block(obj: Dict[str, Any], schema: Dict[str, Any],
                 where: str) -> List[str]:
    errors = []
    for key, typ in schema.items():
        if key not in obj:
            errors.append(f"{where}: missing key {key!r}")
        elif not isinstance(obj[key], typ) or (
                typ is not bool and isinstance(obj[key], bool)):
            errors.append(
                f"{where}.{key}: expected "
                f"{getattr(typ, '__name__', typ)}, got "
                f"{type(obj[key]).__name__}")
    return errors


def validate_manifest(obj: Dict[str, Any],
                      name: str = MANIFEST_NAME) -> List[str]:
    """Schema errors (empty list = valid)."""
    errors = _check_block(obj, _TOP_SCHEMA, name)
    if obj.get("schema") not in (None, SCHEMA_VERSION):
        errors.append(f"{name}: schema version {obj['schema']!r} != "
                      f"{SCHEMA_VERSION}")
    if isinstance(obj.get("params"), dict):
        for k in _PARAMS_KEYS:
            if k not in obj["params"]:
                errors.append(f"{name}.params: missing key {k!r}")
    cells = obj.get("cells")
    if isinstance(cells, dict):
        if not cells:
            errors.append(f"{name}.cells: empty — shardlint measured "
                          "nothing")
        for key, cell in sorted(cells.items()):
            if not isinstance(cell, dict):
                errors.append(f"{name}.cells[{key}]: not an object")
                continue
            where = f"{name}.cells[{key}]"
            errors += _check_block(cell, _CELL_SCHEMA, where)
            if isinstance(cell.get("collectives"), dict):
                errors += _check_block(cell["collectives"], _COLL_SCHEMA,
                                       f"{where}.collectives")
            if isinstance(cell.get("expected"), dict):
                errors += _check_block(cell["expected"], _EXPECTED_SCHEMA,
                                       f"{where}.expected")
    return errors


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1.0)


def diff_manifests(committed: Dict[str, Any], fresh: Dict[str, Any],
                   tolerances: Optional[Dict[str, float]] = None
                   ) -> List[str]:
    """Drift between the checked-in manifest and a fresh measurement.

    Exact on cell coverage and per-kind collective counts; wire bytes
    within `bytes_rtol`; fusion counts within `fusion_rtol`. Tolerances
    come from the COMMITTED manifest (the reviewed contract), falling
    back to DEFAULT_TOLERANCES.
    """
    tol = dict(DEFAULT_TOLERANCES)
    tol.update(committed.get("tolerances") or {})
    tol.update(tolerances or {})
    errors = []
    old_cells = committed.get("cells") or {}
    new_cells = fresh.get("cells") or {}
    for key in sorted(set(old_cells) | set(new_cells)):
        if key not in new_cells:
            errors.append(f"cells[{key}]: in the committed manifest but "
                          "not measured — a served op/level/mesh "
                          "disappeared")
            continue
        if key not in old_cells:
            errors.append(f"cells[{key}]: measured but not in the "
                          "committed manifest — regenerate it "
                          "(tools/shardlint.py --write)")
            continue
        old, new = old_cells[key], new_cells[key]
        oc = (old.get("collectives") or {}).get("counts") or {}
        nc = (new.get("collectives") or {}).get("counts") or {}
        for kind in sorted(set(oc) | set(nc)):
            if oc.get(kind, 0) != nc.get(kind, 0):
                errors.append(
                    f"cells[{key}]: {kind} count {oc.get(kind, 0)} -> "
                    f"{nc.get(kind, 0)} — the collective schedule "
                    "changed")
        ob = (old.get("collectives") or {}).get("total_bytes", 0.0)
        nb = (new.get("collectives") or {}).get("total_bytes", 0.0)
        if _rel(float(ob), float(nb)) > tol["bytes_rtol"]:
            errors.append(
                f"cells[{key}]: wire bytes {ob:.0f} -> {nb:.0f} "
                f"(drift {_rel(float(ob), float(nb)):.1%} > "
                f"{tol['bytes_rtol']:.1%})")
        of, nf = old.get("fusions"), new.get("fusions")
        if isinstance(of, int) and isinstance(nf, int) \
                and _rel(of, nf) > tol["fusion_rtol"]:
            errors.append(
                f"cells[{key}]: fused-kernel count {of} -> {nf} "
                f"(drift > {tol['fusion_rtol']:.0%} — XLA broke or "
                "merged fusions)")
        if old.get("group_axes") != new.get("group_axes"):
            errors.append(
                f"cells[{key}]: replica-group axes "
                f"{old.get('group_axes')} -> {new.get('group_axes')}")
    return errors
