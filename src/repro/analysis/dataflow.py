"""The single shared (logq, logp) dataflow framework.

Before this module existed the repo tracked CKKS metadata twice: once in
`hserve.circuit.validate_circuit` (server-side admission) and once in
the `repro.client` compile pass (trace lowering) — two hand-maintained
copies of the same §III-A level-management rules. Both now delegate
here: :func:`transfer` is THE per-op (logq, logp) transfer function and
:func:`propagate` is the forward abstract interpretation over a
topologically ordered `CircuitOp` list. Any violation raises
:class:`CircuitError`, a `ValueError` subclass that cites the offending
node index, its op, and the computed (logq, logp) at the failure point
— no more bisecting a trace by hand.

The op tables live here too (``OPS`` maps op → ciphertext arity;
``PLAIN_OPS`` are the ops whose second operand is an encoded plaintext
riding the request — paper Fig. 2 region 1 only, no key switch);
`hserve.queue` re-exports them so the analyzer stays import-light
(params + numpy only, no jax).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence, Tuple, Union

import numpy as np

from repro.core.params import HEParams

__all__ = ["OPS", "PLAIN_OPS", "LEVEL_OPS", "CircuitError", "Meta",
           "OpNode", "transfer", "propagate"]

# op -> number of ciphertext operands
OPS: Dict[str, int] = {
    "mul": 2, "add": 2, "sub": 2, "rotate": 1, "conjugate": 1,
    "slot_sum": 1, "rescale": 1, "mod_down": 1, "mod_raise": 1,
    "mul_plain": 1, "add_plain": 1}

# ops whose second operand is an ENCODED PLAINTEXT riding the request
# (no key material, no region-2 key switch — paper Fig. 2 region 1 only)
PLAIN_OPS: Tuple[str, ...] = ("mul_plain", "add_plain")

# ops that exist purely for the paper's §III-A modulus-chain discipline
LEVEL_OPS: Tuple[str, ...] = ("rescale", "mod_down", "mod_raise")

NodeRef = Union[int, str]
Meta = Tuple[int, int]                               # (logq, logp)


class OpNode(Protocol):
    """Structural view of a circuit node — `hserve.circuit.CircuitOp`
    satisfies it, and so would any other frontend IR."""

    op: str
    args: Tuple[NodeRef, ...]
    r: int
    dlogp: int
    logq2: int
    pt: Optional[np.ndarray]
    pt_logp: int
    pt_hash: Optional[str]


class CircuitError(ValueError):
    """A dataflow violation, citing where in the circuit it happened.

    Attributes ``node`` (int index, or None for trace-time errors with
    no node yet), ``op``, ``logq``/``logp`` (the computed input metadata
    at the failure point, when known) let tools consume the location
    without parsing the message; the message itself leads with
    ``node {i} ({op}) at (logq=…, logp=…):`` for humans.
    """

    def __init__(self, msg: str, *, node: Optional[int] = None,
                 op: Optional[str] = None, logq: Optional[int] = None,
                 logp: Optional[int] = None):
        self.node = node
        self.op = op
        self.logq = logq
        self.logp = logp
        where = "trace" if node is None else f"node {node}"
        if op is not None:
            where += f" ({op})"
        if logq is not None:
            where += f" at (logq={logq}, logp={logp})"
        super().__init__(f"{where}: {msg}")


def transfer(op: str, metas: Sequence[Meta], params: HEParams, *,
             r: int = 0, dlogp: int = 0, logq2: int = 0,
             pt_logp: int = 0, node: Optional[int] = None) -> Meta:
    """The per-op (logq, logp) transfer function: input metadata in,
    output metadata out, :class:`CircuitError` on any §III-A violation.
    `metas` is one (logq, logp) pair per CIPHERTEXT operand.

    This is the only place in the repo where the level/scale rules are
    written down; `validate_circuit`, the compile pass, and the noise
    estimator all call it.
    """
    logq, logp = metas[0]

    def err(msg: str) -> CircuitError:
        return CircuitError(msg, node=node, op=op, logq=logq, logp=logp)

    if any(m[0] != logq for m in metas):
        raise err(f"operand levels differ ({[m[0] for m in metas]}); "
                  f"mod_down first (paper §III-B)")
    if op == "mul":
        logp = metas[0][1] + metas[1][1]
    elif op == "mul_plain":
        if pt_logp < 0:
            raise err(f"negative mul_plain pt_logp {pt_logp} "
                      f"(0 means params.log_delta)")
        logp += pt_logp or params.log_delta
    elif op == "add_plain":
        if pt_logp and pt_logp != logp:
            raise err(f"add_plain operand scales differ "
                      f"(plaintext logp {pt_logp} != {logp})")
    elif op in ("add", "sub"):
        if metas[0][1] != metas[1][1]:
            raise err(f"{op} operand scales differ "
                      f"(logp {metas[0][1]} != {metas[1][1]}); "
                      f"rescale first")
    elif op == "rotate":
        if r <= 0:
            raise err("rotate needs a positive rotation amount r")
    elif op == "rescale":
        if dlogp < 0:
            raise err(f"negative rescale dlogp {dlogp} "
                      f"(0 means params.logp)")
        d = dlogp or params.logp
        if logq - d <= 0:
            raise err(f"rescale by {d} exhausts the modulus "
                      f"(logq {logq}: the circuit is deeper than "
                      f"L={params.L} supports; needs bootstrapping)")
        logq -= d
        logp -= d
    elif op == "mod_down":
        if not 0 < logq2 <= logq:
            raise err(f"mod_down target logq2={logq2} "
                      f"outside (0, {logq}]")
        logq = logq2
    elif op == "mod_raise":
        if not logq < logq2 <= params.logQ:
            raise err(f"mod_raise target logq2={logq2} outside "
                      f"({logq}, {params.logQ}]")
        logq = logq2
    return (logq, logp)


def propagate(ops: Sequence[OpNode],
              input_meta: Dict[str, Meta],
              params: HEParams) -> List[Meta]:
    """Forward abstract interpretation over a topologically ordered op
    list: propagate (logq, logp) from the input ciphertexts' metadata
    through every node; raise :class:`CircuitError` — BEFORE anything
    is enqueued — on any ill-formed node. Returns the per-node output
    (logq, logp) list: the level schedule the server will serve.
    """
    if not ops:
        raise CircuitError("empty circuit")
    meta: List[Meta] = []
    for i, node in enumerate(ops):
        if node.op not in OPS:
            raise CircuitError(
                f"unknown op {node.op!r}; serve one of {set(OPS)}",
                node=i)
        if len(node.args) != OPS[node.op]:
            raise CircuitError(
                f"op {node.op!r} takes {OPS[node.op]} operand(s), "
                f"got {len(node.args)}", node=i, op=node.op)

        def resolve(a: NodeRef) -> Meta:
            if isinstance(a, str):
                if a not in input_meta:
                    raise CircuitError(
                        f"unknown input {a!r}; inputs: "
                        f"{sorted(input_meta)}", node=i, op=node.op)
                return input_meta[a]
            if not 0 <= a < i:
                raise CircuitError(
                    f"arg {a} is not an earlier node (circuits are "
                    f"topologically ordered lists)", node=i, op=node.op)
            return meta[a]

        ms = [resolve(a) for a in node.args]
        if node.op in PLAIN_OPS:
            logq, logp = ms[0]
            if node.pt is None and node.pt_hash is None:
                raise CircuitError(
                    f"{node.op} needs an encoded plaintext operand "
                    f"(core.heaan.encode_plain) or a pt_hash "
                    f"referencing the server's plaintext cache",
                    node=i, op=node.op, logq=logq, logp=logp)
            if node.pt is not None:
                shape = np.asarray(node.pt).shape
                if len(shape) != 2 or shape[0] != params.N \
                        or shape[1] < params.qlimbs(logq):
                    raise CircuitError(
                        f"{node.op} plaintext shape {shape} does not "
                        f"cover ({params.N}, {params.qlimbs(logq)}) — "
                        f"encode at the node's input level 2^{logq}",
                        node=i, op=node.op, logq=logq, logp=logp)
        meta.append(transfer(node.op, ms, params, r=node.r,
                             dlogp=node.dlogp, logq2=node.logq2,
                             pt_logp=node.pt_logp, node=i))
    return meta
