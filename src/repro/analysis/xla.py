"""shardlint — static HLO/collective analysis of the compiled serving
engines (`python -m repro.analysis.xla`, or `tools/shardlint.py` which
forces an 8-device host so both meshes exist).

hslint (HS001–HS006) checks circuits before they run; this pass checks
what the COMPILED programs will do on the wire. For every served op in
`analysis.dataflow.OPS`/`PLAIN_OPS`, at each level, on the 1-dev and
(2,4) meshes, it lowers the exact engine step via
`launch.cells.lower_he_serving_cell` (abstract `he_table_specs` tables —
no twiddle build, milliseconds per cell), statically parses the
optimized HLO with `launch.hlo_analysis`, and compares against the
analytic prediction `dist.sharding.he_expected_collectives` derives
from the paper's Fig. 2 dataflow (only iCRT's cross-prime accumulation
communicates: 3 all-reduces over model-axis groups per reduction).

Findings ship as the HS1xx rule series through the hslint Diagnostic
machinery:

  HS101  unexpected-collective   error   a collective kind the sharding
         rules never predict for this cell (implicit resharding);
  HS102  collective-bytes-drift  error   measured all-reduce wire bytes
         off the analytic ring-model prediction beyond tolerance;
  HS103  layout-churn            error   replica groups on the wrong
         mesh axis, or a collective count off the predicted schedule;
  HS104  peak-memory-over-budget error   backend peak-live-buffer
         estimate above the per-device HBM budget;
  HS105  fusion-break            warning fused-kernel count drifted
         from the committed SHARD_MANIFEST.json baseline.

Measured-vs-expected numbers are written to SHARD_MANIFEST.json
(`--write`); `tools/check_docs.py --shard-manifest` drift-gates a fresh
measurement against the committed file in CI. jax is imported lazily so
`import repro.analysis` stays light.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.manifest import (
    DEFAULT_TOLERANCES, MANIFEST_NAME, SCHEMA_VERSION, cell_key,
    load_manifest, validate_manifest,
)
from repro.analysis.rules import Diagnostic

__all__ = ["DEFAULT_HBM_BUDGET", "DEFAULT_MESHES", "measure_cell",
           "check_cell", "run_shardlint", "main"]

# per-device budget the peak-live-buffer estimate is gated against; the
# manifest params are tiny, so the default only catches runaway
# materialization (a real deployment passes its device's HBM)
DEFAULT_HBM_BUDGET = 1 << 30

DEFAULT_MESHES: Dict[str, Tuple[int, int]] = {"1x1": (1, 1), "2x4": (2, 4)}
DEFAULT_LEVELS = (120, 72, 24)
_INJECTIONS = ("bogus-ct-sharding",)


def _make_mesh(shape: Tuple[int, int]) -> Any:
    import jax
    import numpy as np
    from jax.sharding import Mesh
    n = shape[0] * shape[1]
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run via "
            "tools/shardlint.py (it forces an 8-device host before jax "
            "loads) or set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=8")
    return Mesh(np.array(devs[:n]).reshape(shape), ("data", "model"))


def _bogus_ct_sharding(mesh: Any) -> Any:
    """A deliberately wrong ciphertext placement — the ring dimension N
    on "data" with the batch replicated, violating every rule in
    `dist.sharding` (batch-on-data, N local) — used by the injected-
    regression test to prove HS101 (unpredicted all-gathers) and HS103
    (replica groups over the wrong mesh axis) actually fire."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(None, "data"))


def _classify_groups(ops: List[Dict[str, Any]],
                     axis_groups: Dict[str, List[Tuple[int, ...]]]
                     ) -> List[str]:
    """Mesh-axis names the measured replica groups run over ("?" for a
    group set matching no single axis — the layout-churn signal)."""
    axes = set()
    for op in ops:
        if op["op"] == "collective-permute" or op["group_size"] <= 1:
            continue
        groups = op.get("groups")
        if groups is None:
            axes.add("?")
            continue
        gg = sorted(tuple(g) for g in groups)
        for name, agroups in axis_groups.items():
            if gg == agroups:
                axes.add(name)
                break
        else:
            axes.add("?")
    return sorted(axes)


def measure_cell(op: str, logq: int, mesh: Any, params: Any, batch: int, *,
                 n_slots: Optional[int] = None,
                 ct_sharding: Optional[Any] = None) -> Dict[str, Any]:
    """Lower + compile one serving cell and statically analyze its HLO.

    Returns the manifest cell record: collective schedule (per-kind
    counts / ring-model wire bytes / per-instruction detail), replica-
    group axis classification, analytic expectation, fused-kernel count,
    and the backend memory estimate.
    """
    import time
    from repro.dist.sharding import (
        he_expected_collectives, mesh_collective_groups,
    )
    from repro.launch.cells import lower_he_serving_cell
    from repro.launch.hlo_analysis import analyze_compiled
    t0 = time.time()
    lowered = lower_he_serving_cell(op, batch, mesh, logq=logq,
                                    params=params, n_slots=n_slots,
                                    ct_sharding=ct_sharding)
    rec: Dict[str, Any] = analyze_compiled(lowered, lowered.compile(),
                                           time.time() - t0)
    coll = rec["collectives"]
    expected = he_expected_collectives(op, mesh, params, logq, batch=batch,
                                       n_slots=n_slots)
    axis_groups = {str(k): [tuple(g) for g in v]
                   for k, v in mesh_collective_groups(mesh).items()}
    return {
        "collectives": {
            "counts": {k: v for k, v in coll["counts"].items() if v},
            "bytes": {k: round(v, 1) for k, v in coll["bytes"].items()
                      if v},
            "total_bytes": round(float(coll["total_bytes"]), 1),
            "ops": coll["ops"],
        },
        "expected": {
            "counts": dict(expected["counts"]),
            "wire_bytes": round(float(expected["wire_bytes"]), 1),
            "axis": expected["axis"],
            "allowed": expected["allowed"],
        },
        "group_axes": _classify_groups(coll["ops"], axis_groups),
        "fusions": int(rec["fusions"]),
        "memory": rec["memory"],
        "flops": rec["flops"],
    }


def _peak_estimate(memory: Dict[str, Any]) -> Optional[int]:
    """Backend peak bytes, falling back to arguments+output+temps where
    the backend reports no peak (CPU)."""
    peak = memory.get("peak_bytes")
    if isinstance(peak, int):
        return peak
    parts = [memory.get(k) for k in
             ("argument_bytes", "output_bytes", "temp_bytes")]
    known = [p for p in parts if isinstance(p, int)]
    return sum(known) if known else None


def check_cell(key: str, cell: Dict[str, Any], *,
               tolerances: Optional[Dict[str, float]] = None,
               hbm_budget: int = DEFAULT_HBM_BUDGET,
               baseline_fusions: Optional[int] = None
               ) -> List[Diagnostic]:
    """HS1xx findings for one measured cell vs its analytic expectation
    (and, for HS105, the committed manifest's fusion baseline)."""
    tol = dict(DEFAULT_TOLERANCES)
    tol.update(tolerances or {})
    diags: List[Diagnostic] = []
    meas = cell["collectives"]
    exp = cell["expected"]
    allowed = exp.get("allowed") or {}

    # HS101 — collective kinds the sharding rules never predict here
    for kind, count in sorted(meas["counts"].items()):
        if not count or kind in exp["counts"]:
            continue
        allow = allowed.get(kind)
        if allow is not None:
            over = [o for o in meas["ops"] if o["op"] == kind
                    and o["size_bytes"] > allow["max_bytes_each"]]
            if count <= allow["max_count"] and not over:
                continue            # the tolerated evk-slice permutes
        diags.append(Diagnostic(
            "HS101", "error",
            f"{key}: {count} {kind} instruction(s) the sharding rules "
            f"never predict for this cell — an implicit resharding "
            f"crept into the lowered HLO"))

    # HS102 — all-reduce wire bytes off the analytic ring model
    meas_ar = float(meas["bytes"].get("all-reduce", 0.0))
    exp_b = float(exp["wire_bytes"])
    drift = abs(meas_ar - exp_b) / max(meas_ar, exp_b, 1.0)
    if drift > tol["expected_rtol"]:
        diags.append(Diagnostic(
            "HS102", "error",
            f"{key}: all-reduce wire bytes {meas_ar:.0f} vs analytic "
            f"{exp_b:.0f} (drift {drift:.1%} > "
            f"{tol['expected_rtol']:.1%}) — the iCRT reduction "
            f"schedule no longer matches Fig. 2"))

    # HS103 — groups on the wrong mesh axis / schedule shape changed
    bad_axes = [a for a in cell["group_axes"] if a != exp["axis"]]
    if bad_axes:
        diags.append(Diagnostic(
            "HS103", "error",
            f"{key}: replica groups run over {bad_axes} where the "
            f"sharding rules predict only {exp['axis']!r}-axis "
            f"reductions — layout churn"))
    for kind, want in sorted(exp["counts"].items()):
        got = meas["counts"].get(kind, 0)
        if got != want:
            diags.append(Diagnostic(
                "HS103", "error",
                f"{key}: {got} {kind}(s) where the dataflow predicts "
                f"exactly {want} — the collective schedule changed "
                f"shape"))

    # HS104 — peak live buffers vs the HBM budget
    peak = _peak_estimate(cell["memory"])
    if peak is not None and peak > hbm_budget:
        diags.append(Diagnostic(
            "HS104", "error",
            f"{key}: peak-live-buffer estimate {peak} bytes exceeds "
            f"the {hbm_budget}-byte per-device HBM budget"))

    # HS105 — fused-kernel count drifted from the committed baseline
    if baseline_fusions is not None:
        got_f = int(cell["fusions"])
        fdrift = abs(got_f - baseline_fusions) / max(
            got_f, baseline_fusions, 1)
        if fdrift > tol["fusion_rtol"]:
            diags.append(Diagnostic(
                "HS105", "warning",
                f"{key}: fused-kernel count {got_f} vs the committed "
                f"baseline {baseline_fusions} (drift {fdrift:.0%} > "
                f"{tol['fusion_rtol']:.0%}) — XLA broke or merged "
                f"fusions; regenerate SHARD_MANIFEST.json if intended"))
    return diags


def run_shardlint(*, params: Any = None, batch: int = 2,
                  levels: Tuple[int, ...] = DEFAULT_LEVELS,
                  meshes: Optional[Dict[str, Tuple[int, int]]] = None,
                  ops: Optional[Tuple[str, ...]] = None,
                  hbm_budget: int = DEFAULT_HBM_BUDGET,
                  tolerances: Optional[Dict[str, float]] = None,
                  manifest: Optional[Dict[str, Any]] = None,
                  inject: Optional[str] = None) -> Dict[str, Any]:
    """Measure + check every (op, level, mesh) cell.

    Returns {"manifest": fresh manifest dict, "diagnostics": [...],
    "errors": n}. `manifest` (the committed one) supplies the HS105
    fusion baselines; `ops` restricts to a subset of the served table
    (a focused run — the resulting manifest is partial and must not be
    committed); `inject` forces a named regression (`bogus-ct-sharding`)
    for the CI self-test.
    """
    from repro.core.params import test_params
    from repro.launch.cells import HE_SERVING_OPS, serving_op_levels
    if params is None:
        params = test_params(logN=6, beta_bits=32, logQ=120, logp=24)
    if meshes is None:
        meshes = dict(DEFAULT_MESHES)
    if ops is None:
        ops = HE_SERVING_OPS
    else:
        unknown = sorted(set(ops) - set(HE_SERVING_OPS))
        if unknown:
            raise ValueError(f"unknown serving op(s) {unknown}; "
                             f"the served table is {HE_SERVING_OPS}")
    if inject is not None and inject not in _INJECTIONS:
        raise ValueError(f"unknown injection {inject!r}; "
                         f"one of {_INJECTIONS}")
    tol = dict(DEFAULT_TOLERANCES)
    tol.update(tolerances or {})
    # HS105 fusion baselines only make sense when the committed manifest
    # was measured at the SAME parameters (cell keys carry op/level/mesh
    # but not logN/batch)
    base_cells: Dict[str, Any] = {}
    if manifest and manifest.get("batch") == batch \
            and manifest.get("params") == {
                "logN": params.logN, "logQ": params.logQ,
                "logp": params.logp, "beta_bits": params.beta_bits}:
        base_cells = manifest.get("cells") or {}
    cells: Dict[str, Dict[str, Any]] = {}
    diags: List[Diagnostic] = []
    for mesh_name, shape in meshes.items():
        mesh = _make_mesh(shape)
        ct_sh = _bogus_ct_sharding(mesh) \
            if inject == "bogus-ct-sharding" else None
        for op in ops:
            for logq in serving_op_levels(op, list(levels), params):
                key = cell_key(op, int(logq), mesh_name)
                cell = measure_cell(op, int(logq), mesh, params, batch,
                                    ct_sharding=ct_sh)
                base = base_cells.get(key) or {}
                baseline_f = base.get("fusions") \
                    if isinstance(base.get("fusions"), int) else None
                diags += check_cell(key, cell, tolerances=tol,
                                    hbm_budget=hbm_budget,
                                    baseline_fusions=baseline_f)
                cell = dict(cell)
                coll = dict(cell["collectives"])
                coll.pop("ops", None)      # per-instruction detail is
                cell["collectives"] = coll  # too volatile to commit
                cell["expected"] = {
                    "counts": cell["expected"]["counts"],
                    "wire_bytes": cell["expected"]["wire_bytes"],
                }
                cells[key] = cell
    fresh: Dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "params": {"logN": params.logN, "logQ": params.logQ,
                   "logp": params.logp, "beta_bits": params.beta_bits},
        "batch": batch,
        "levels": sorted(set(int(x) for x in levels), reverse=True),
        "meshes": {k: list(v) for k, v in meshes.items()},
        "tolerances": tol,
        "hbm_budget_bytes": hbm_budget,
        "cells": cells,
    }
    return {"manifest": fresh, "diagnostics": diags,
            "errors": sum(1 for d in diags if d.severity == "error")}


def _parse_meshes(text: str) -> Dict[str, Tuple[int, int]]:
    out: Dict[str, Tuple[int, int]] = {}
    for part in text.split(","):
        part = part.strip()
        d, m = part.split("x")
        out[part] = (int(d), int(m))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    import os
    if "jax" not in sys.modules:        # both meshes need 8 host devices
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser(
        prog="shardlint", description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--write", action="store_true",
                    help="(re)generate the manifest at --manifest")
    ap.add_argument("--out", default=None, type=Path,
                    help="also write the fresh measurement JSON here "
                         "(check_docs --shard-manifest compares it "
                         "against the committed manifest)")
    ap.add_argument("--manifest", default=None, type=Path,
                    help=f"committed manifest path (default: "
                         f"{MANIFEST_NAME} next to the repo's "
                         f"tools/ dir, else cwd)")
    ap.add_argument("--levels", default=None,
                    help="comma-separated logq levels (default "
                         f"{','.join(map(str, DEFAULT_LEVELS))})")
    ap.add_argument("--meshes", default=None,
                    help="comma-separated DxM meshes (default 1x1,2x4)")
    ap.add_argument("--ops", default=None,
                    help="comma-separated subset of served ops (default: "
                         "the full table; a subset run's manifest is "
                         "partial — don't commit it)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--logn", type=int, default=6)
    ap.add_argument("--logq-max", type=int, default=120,
                    help="logQ of the parameter set")
    ap.add_argument("--logp", type=int, default=24)
    ap.add_argument("--hbm-budget", type=int, default=DEFAULT_HBM_BUDGET,
                    help="per-device peak-live-buffer budget in bytes "
                         "(HS104)")
    ap.add_argument("--inject", default=None, choices=_INJECTIONS,
                    help="force a named regression (CI self-test: "
                         "shardlint must exit 1 on it)")
    args = ap.parse_args(argv)

    from repro.core.params import test_params
    params = test_params(logN=args.logn, beta_bits=32,
                         logQ=args.logq_max, logp=args.logp)
    levels = tuple(int(x) for x in args.levels.split(",")) \
        if args.levels else DEFAULT_LEVELS
    meshes = _parse_meshes(args.meshes) if args.meshes else None

    manifest_path = args.manifest
    if manifest_path is None:
        for cand in (Path(__file__).resolve().parents[3] / MANIFEST_NAME,
                     Path.cwd() / MANIFEST_NAME):
            if cand.exists():
                manifest_path = cand
                break
        else:
            manifest_path = Path.cwd() / MANIFEST_NAME
    committed: Optional[Dict[str, Any]] = None
    if manifest_path.exists() and not args.write:
        committed = load_manifest(manifest_path)
        for err in validate_manifest(committed, manifest_path.name):
            print(f"shardlint: {err}", file=sys.stderr)

    ops = tuple(x.strip() for x in args.ops.split(",") if x.strip()) \
        if args.ops else None
    report = run_shardlint(params=params, batch=args.batch, levels=levels,
                           meshes=meshes, ops=ops,
                           hbm_budget=args.hbm_budget,
                           manifest=committed, inject=args.inject)
    fresh, diags = report["manifest"], report["diagnostics"]

    if args.write:
        manifest_path.write_text(json.dumps(fresh, indent=1,
                                            sort_keys=True) + "\n")
        print(f"shardlint: wrote {len(fresh['cells'])} cells to "
              f"{manifest_path}", file=sys.stderr)
    if args.out is not None:
        args.out.write_text(json.dumps(fresh, indent=1, sort_keys=True)
                            + "\n")

    if args.json:
        print(json.dumps({
            "cells": fresh["cells"],
            "diagnostics": [vars(d) for d in diags],
            "errors": report["errors"],
        }, sort_keys=True))
    else:
        for d in diags:
            print(d.format())
        print(f"shardlint: {len(fresh['cells'])} cells, "
              f"{report['errors']} error(s), "
              f"{sum(1 for d in diags if d.severity == 'warning')} "
              f"warning(s)")
    return 1 if report["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
