"""Named example circuits for the hslint CLI (and its CI job).

Each builder returns ``(kwargs, note)`` where kwargs feed
:func:`repro.analysis.analyzer.analyze_circuit` directly. The registry
deliberately spans both frontends — hand-built `CircuitOp` lists AND a
traced `CipherHandle` expression lowered through the client compile
pass — because the analyzer's contract is that the two meet the same
dataflow engine.

Builders lazy-import the heavier repro modules (the traced example
pulls in the encoder) so `import repro.analysis` stays numpy-only.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

__all__ = ["EXAMPLES", "build"]


def _degree4():
    """The repo's acceptance circuit conj(x⁴)+x at test params —
    exercises mul/rescale/mod_down/conjugate and the full §III-A level
    discipline."""
    from repro.core.params import test_params
    from repro.hserve.circuit import degree4_demo_circuit
    params = test_params()
    ops, _ = degree4_demo_circuit(params)
    return dict(ops=ops, input_meta={"x": (params.logQ, params.logp)},
                params=params, input_bounds=1.0,
                input_nslots={"x": params.n_slots_max}), \
        "hand-built degree-4 demo (conj(x^4) + x)"


def _affine_sigmoid():
    """The examples/he_inference.py workload as a TRACE: encrypted
    logistic-regression scoring — affine Σ wⱼ·ctⱼ + b, then the
    degree-3 sigmoid 0.5 + 0.197·x − 0.004·x³."""
    import numpy as np

    from repro.client.compile import compile_handle
    from repro.client.handles import CipherHandle
    from repro.core.cipher import Ciphertext
    from repro.core.params import test_params

    params = test_params(logN=7, logQ=144, logp=24)
    session = object()                 # trace-only: never submitted
    n = params.n_slots_max

    def leaf():
        z = np.zeros((params.N, params.qlimbs(params.logQ)), np.uint32)
        ct = Ciphertext(ax=z, bx=z, logq=params.logQ,
                        logp=params.logp, n_slots=n)
        return CipherHandle(session, "input", ct=ct)

    rng = np.random.default_rng(0)
    feats = [leaf() for _ in range(3)]
    weights = rng.uniform(-0.5, 0.5, size=3)
    x = feats[0] * weights[0]
    for ct, w in zip(feats[1:], weights[1:]):
        x = x + ct * w
    x = x + 0.25                       # bias
    score = x * x * x * (-0.004) + x * 0.197 + 0.5
    cc = compile_handle(score, params)
    return dict(ops=cc.ops, params=params,
                input_meta={k: (c.logq, c.logp)
                            for k, c in cc.inputs.items()},
                input_nslots={k: c.n_slots
                              for k, c in cc.inputs.items()},
                input_bounds=1.0, pt_bounds=cc.pt_bounds), \
        "traced logistic-regression scoring (he_inference.py)"


def _rotation_average():
    """A neighborhood average over 5 offsets at a generous logQ —
    a composite rotation (r=5 → 1+4) and depth headroom, the
    performance-smell rules' bread and butter."""
    from repro.core.params import test_params
    from repro.hserve.circuit import CircuitOp
    params = test_params(logN=6, logQ=120, logp=24)
    ops = [
        CircuitOp("rotate", ("x",), r=1),
        CircuitOp("rotate", ("x",), r=5),
        CircuitOp("add", (0, 1)),
        CircuitOp("add", (2, "x")),
    ]
    return dict(ops=ops, params=params,
                input_meta={"x": (params.logQ, params.logp)},
                input_nslots={"x": params.n_slots_max},
                input_bounds=1.0,
                provisioned_rotations={1, 2, 4, 8, 16}), \
        "rotation neighborhood sum (composite r=5, pow2 keys only)"


def _bootstrap():
    """The full `repro.boot` pipeline at the reference small-param
    bootstrap config, as the analyzer sees it: a mod_raise head, two
    BSGS DFT stages, and the complex-exponential EvalMod between them —
    the deepest circuit in the registry, linted like any other."""
    from repro.boot.pipeline import boot_params, bootstrap_circuit

    params = boot_params()
    plan = bootstrap_circuit(params, logq_in=params.logp)
    return dict(ops=plan.ops, params=params,
                input_meta={plan.in_name: (plan.logq_in, plan.logp)},
                input_nslots={plan.in_name: plan.n_slots},
                input_bounds=plan.msg_bound,
                pt_bounds=plan.pt_bounds), \
        "CKKS bootstrap pipeline (mod_raise + CtS + EvalMod + StC)"


EXAMPLES: Dict[str, Callable[[], Tuple[dict, str]]] = {
    "degree4": _degree4,
    "affine_sigmoid": _affine_sigmoid,
    "rotation_average": _rotation_average,
    "bootstrap": _bootstrap,
}


def build(name: str) -> Tuple[dict, str]:
    if name not in EXAMPLES:
        raise ValueError(f"unknown example {name!r}; one of "
                         f"{sorted(EXAMPLES)}")
    return EXAMPLES[name]()
