"""CKKS noise-budget estimation: per-op worst-case growth bounds.

The paper's §II modulus-chain accounting tracks WHERE in the chain a
ciphertext sits; this module tracks what that position costs in message
precision. We follow the standard CKKS canonical-embedding heuristic
(Cheon-Kim-Kim-Song 2017, "noise estimates"): every error polynomial e
is bounded by its canonical-embedding ∞-norm ν = ‖e‖^can_∞, which for a
random polynomial with i.i.d. coefficients of variance v concentrates
around √(N·v) per embedding value — we take the high-probability bound

    ν ≈ _C · √(N · v),      _C = 6  (erfc(6/√2) ≈ 2e-9 per value)

The canonical norm is sub-multiplicative (‖a·b‖ ≤ ‖a‖·‖b‖ — no extra
×N factor on mul, unlike coefficient-norm accounting; this is what
keeps the bounds non-vacuous), and a slot's decoded error is directly
ν / Δ at scale Δ = 2^logp. The repo's gap-subsampled decode (n < N/2
slots) reads a trace-folded subset of embedding values, so the same
per-value bound applies.

Contract (validated by a property test on ≥100 seeded random traced
circuits, documented in docs/ANALYSIS.md): the predicted slot error
2^error_bits UPPER-BOUNDS the measured decrypt error with high
probability. It is worst-case over message magnitudes — the bound is
tight only when every slot sits at its magnitude bound simultaneously —
so expect a documented slack factor, not equality.

Key material (core.keys): s ternary with exactly h nonzeros; e, e0, e1
discrete Gaussian σ; u ∼ ZO(1/2) (±1 w.p. ¼ each, coeff variance ½);
evk/rot/conj keys live at modulus Q² (special modulus P = Q), so the
region-2 key-switch term scales by 2^(logq − logQ) ≤ 1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Union

from repro.analysis.dataflow import Meta, OpNode, propagate
from repro.core.params import HEParams

__all__ = ["NodeNoise", "estimate_noise", "fresh_noise",
           "keyswitch_noise", "rescale_noise", "encode_noise"]

_C = 6.0          # high-probability constant: P(|err| > C·std) ≈ 2e-9


def _embed(coeff_var: float, params: HEParams) -> float:
    """whp canonical-embedding bound for a random poly with i.i.d.
    coefficients of the given variance."""
    return _C * math.sqrt(params.N * coeff_var)


def encode_noise(n_slots: int) -> float:
    """Rounding error of encoding a message: ⌊Δ·z⌉ leaves a uniform
    [-½, ½] error on each of the 2·n_slots populated coefficients."""
    return 0.5 * _C * math.sqrt(2 * n_slots)


def fresh_noise(params: HEParams, n_slots: int) -> float:
    """ν of a fresh encryption: decrypt(Enc(m)) = m + u·e_pk + e0 +
    e1·s, plus the encode rounding of m itself."""
    b_u = _embed(0.5, params)                        # u ~ ZO(1/2)
    b_e = _embed(params.sigma ** 2, params)          # Gaussian errors
    b_s = _C * math.sqrt(params.h)                   # ternary secret
    return b_u * b_e + b_e + b_s * b_e + encode_noise(n_slots)


def rescale_noise(params: HEParams) -> float:
    """ν added by one rescale (also the key-switch mod-switch term):
    the rounding polys δ0 + δ1·s with δ coeffs uniform in [-½, ½]."""
    b_round = _embed(1.0 / 12.0, params)
    return b_round * (1.0 + _C * math.sqrt(params.h))


def keyswitch_noise(logq: int, params: HEParams) -> float:
    """ν added by one region-2 key switch (mul relinearization, rotate,
    conjugate): the key's Gaussian error times the switched part's
    rounding spread, scaled down by the special modulus (P = Q here:
    ×2^(logq − logQ)), plus the mod-switch rounding."""
    b_e = _embed(params.sigma ** 2, params)
    b_round = _embed(1.0 / 12.0, params)
    return (b_e * b_round * 2.0 ** (logq - params.logQ)
            + rescale_noise(params))


@dataclasses.dataclass(frozen=True)
class NodeNoise:
    """Noise state after one node: ν (canonical ∞-norm bound of the
    error polynomial), msg (bound on the SCALED message magnitude
    |Δ·z| in the embedding — needed because mul's cross terms are
    message × noise), and the node's (logq, logp, n_slots)."""

    nu: float
    msg: float
    logq: int
    logp: int
    n_slots: int

    @property
    def error_bits(self) -> float:
        """log2 of the predicted |slot error| = ν / 2^logp."""
        if self.nu <= 0.0:
            return float("-inf")
        return math.log2(self.nu) - self.logp

    @property
    def precision_bits(self) -> float:
        """Fractional bits of the decoded slot still trustworthy."""
        return -self.error_bits


def estimate_noise(ops: Sequence[OpNode],
                   input_meta: Dict[str, Meta],
                   params: HEParams, *,
                   input_bounds: Union[float, Dict[str, float]] = 1.0,
                   pt_bounds: Optional[Dict[int, float]] = None,
                   input_nslots: Optional[Dict[str, int]] = None,
                   meta: Optional[List[Meta]] = None
                   ) -> List[NodeNoise]:
    """Propagate noise bounds through a (level-valid) circuit.

    input_bounds: max |slot value| per input (one float for all inputs,
    or a per-name dict) — inputs are assumed FRESH encryptions at their
    (logq, logp). pt_bounds maps plain-op node index → max |slot| of
    its plaintext operand (``CompiledCircuit.pt_bounds``; defaults to
    1.0 per operand). Returns one :class:`NodeNoise` per node; the last
    entry is the circuit output's budget.
    """
    if meta is None:
        meta = propagate(ops, input_meta, params)
    pt_bounds = pt_bounds or {}
    input_nslots = input_nslots or {}

    def in_bound(name: str) -> float:
        if isinstance(input_bounds, dict):
            return float(input_bounds.get(name, 1.0))
        return float(input_bounds)

    state: Dict[Union[int, str], NodeNoise] = {}

    def resolve(a) -> NodeNoise:
        if isinstance(a, str) and a not in state:
            lq, lp = input_meta[a]
            ns = input_nslots.get(a, params.n_slots_max)
            state[a] = NodeNoise(nu=fresh_noise(params, ns),
                                 msg=in_bound(a) * 2.0 ** lp,
                                 logq=lq, logp=lp, n_slots=ns)
        return state[a]

    out: List[NodeNoise] = []
    for i, node in enumerate(ops):
        xs = [resolve(a) for a in node.args]
        x = xs[0]
        lq, lp = meta[i]
        ns = x.n_slots
        if node.op == "mul":
            y = xs[1]
            nu = x.msg * y.nu + y.msg * x.nu + x.nu * y.nu \
                + keyswitch_noise(lq, params)
            msg = x.msg * y.msg
        elif node.op == "mul_plain":
            pt_msg = pt_bounds.get(i, 1.0) \
                * 2.0 ** (node.pt_logp or params.log_delta)
            e_enc = encode_noise(ns)
            nu = (pt_msg + e_enc) * x.nu + e_enc * x.msg
            msg = x.msg * pt_msg
        elif node.op in ("add", "sub"):
            y = xs[1]
            nu = x.nu + y.nu
            msg = x.msg + y.msg
        elif node.op == "add_plain":
            nu = x.nu + encode_noise(ns)
            msg = x.msg + pt_bounds.get(i, 1.0) * 2.0 ** lp
        elif node.op in ("rotate", "conjugate"):
            nu = x.nu + keyswitch_noise(lq, params)
            msg = x.msg
        elif node.op == "slot_sum":
            nu = ns * x.nu + max(0, ns - 1) * keyswitch_noise(lq, params)
            msg = x.msg * ns
        elif node.op == "rescale":
            d = node.dlogp or params.logp
            nu = x.nu / 2.0 ** d + rescale_noise(params)
            msg = x.msg / 2.0 ** d
        elif node.op == "mod_raise":
            # the centered lift is exact in the decoded view: the q·I(X)
            # term it introduces is removed by the bootstrap's EvalMod
            # stage, whose approximation error is the pipeline's
            # documented error contract (docs/BOOTSTRAP.md), not a
            # per-op noise term — so message and noise carry through
            nu, msg = x.nu, x.msg
        else:                                        # mod_down
            # power-of-two modulus masking is exact: no rounding term
            nu, msg = x.nu, x.msg
        nn = NodeNoise(nu=nu, msg=msg, logq=lq, logp=lp, n_slots=ns)
        state[i] = nn
        out.append(nn)
    return out
