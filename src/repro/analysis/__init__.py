"""`repro.analysis` — static analysis over encrypted circuits (hslint).

The paper's core contribution is a *disciplined static analysis* of HE
Mul — op counts, modulus/level budgets, and data-access characteristics
(§II–IV). This package is that discipline applied to whole circuits,
BEFORE anything is enqueued:

  - :mod:`repro.analysis.dataflow` — the single shared (logq, logp)
    dataflow framework: forward abstract interpretation over
    `CircuitOp` DAGs. Both `hserve.circuit.validate_circuit` and the
    `repro.client` compile pass delegate to it (one set of transfer
    functions, no drift), and every violation raises a
    :class:`CircuitError` citing the offending node.
  - :mod:`repro.analysis.noise` — a CKKS noise-budget estimator:
    per-op worst-case (high-probability) noise growth in the canonical
    embedding, following the paper's §II modulus-chain accounting.
  - :mod:`repro.analysis.rules` — the lint rule registry (stable IDs
    HS001–HS006 for circuit lints, HS101–HS105 for the compiled-HLO
    shard lints, each with a severity).
  - :mod:`repro.analysis.cost` — a bench-calibrated cost model
    (device-seconds per (op, level), constants fitted from
    BENCH_serve_he.json) consulted by the circuit-aware scheduler.
  - :mod:`repro.analysis.analyzer` — ties it together into an
    :class:`AnalysisReport`; `python -m repro.analysis` /
    `tools/hslint.py` is the CLI over the example circuits.
  - :mod:`repro.analysis.xla` — shardlint: lowers every served op on
    the 1-dev and (2,4) meshes and statically checks the optimized
    HLO's collective schedule, layouts, peak memory, and fusion count
    against the `dist.sharding` analytic expectations (HS101–HS105);
    `python -m repro.analysis.xla` / `tools/shardlint.py` is the CLI.
    Imports jax lazily — NOT re-exported here.
  - :mod:`repro.analysis.manifest` — stdlib-only schema + drift diff
    for the checked-in SHARD_MANIFEST.json (loaded by
    `tools/check_docs.py` in CI without numpy/jax).

See docs/ANALYSIS.md for the rule catalog, the noise model's
upper-bound contract, and the cost-model calibration.
"""

from repro.analysis.analyzer import (AnalysisReport, analyze_circuit,
                                     analyze_handle)
from repro.analysis.cost import CostModel, op_units
from repro.analysis.dataflow import (OPS, PLAIN_OPS, CircuitError,
                                     propagate, transfer)
from repro.analysis.noise import NodeNoise, estimate_noise
from repro.analysis.rules import RULES, Diagnostic, Rule

__all__ = [
    "AnalysisReport", "analyze_circuit", "analyze_handle",
    "CostModel", "op_units",
    "OPS", "PLAIN_OPS", "CircuitError", "propagate", "transfer",
    "NodeNoise", "estimate_noise",
    "RULES", "Diagnostic", "Rule",
]
