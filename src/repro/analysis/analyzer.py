"""The analyzer facade: circuit (or traced handle) in, report out.

`analyze_circuit` is the one entry point everything shares: the CLI
(`python -m repro.analysis`), `HESession.run(check=...)`, CI, and
tests. It never raises on a bad circuit — dataflow violations become
HS001 diagnostics — so callers decide policy (the CLI exits 1 on
errors; `check="error"` raises; `check="warn"` warns).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Union

from repro.analysis.cost import CostModel
from repro.analysis.dataflow import CircuitError, Meta, OpNode, propagate
from repro.analysis.noise import NodeNoise, estimate_noise
from repro.analysis.rules import (DEFAULT_WATERLINE_BITS, Diagnostic,
                                  RuleContext, run_rules)
from repro.core.params import HEParams

__all__ = ["AnalysisReport", "analyze_circuit", "analyze_handle"]


@dataclasses.dataclass
class AnalysisReport:
    """Everything the static analyzer learned about one circuit."""

    diagnostics: List[Diagnostic]
    n_ops: int
    meta: List[Meta] = dataclasses.field(default_factory=list)
    noise: List[NodeNoise] = dataclasses.field(default_factory=list)
    cost_s: Optional[float] = None
    cost_per_node: List[float] = dataclasses.field(default_factory=list)
    calibrated_from: Optional[str] = None

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def out_precision_bits(self) -> Optional[float]:
        return self.noise[-1].precision_bits if self.noise else None

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "ok": self.ok,
            "n_ops": self.n_ops,
            "diagnostics": [dataclasses.asdict(x)
                            for x in self.diagnostics],
        }
        if self.meta:
            nn = self.noise[-1]
            d["out"] = {"logq": self.meta[-1][0],
                        "logp": self.meta[-1][1],
                        "error_bits": round(nn.error_bits, 2),
                        "precision_bits": round(nn.precision_bits, 2)}
        if self.cost_s is not None:
            d["cost"] = {"est_device_s": self.cost_s,
                         "calibrated_from": self.calibrated_from}
        return d

    def render(self, name: str = "circuit") -> str:
        """Pretty multi-line report for terminals."""
        lines = [f"{name}: {self.n_ops} op(s), "
                 + ("OK" if self.ok else
                    f"{len(self.errors)} error(s)")]
        if self.meta:
            nn = self.noise[-1]
            lines.append(
                f"  out (logq={self.meta[-1][0]}, "
                f"logp={self.meta[-1][1]}), predicted |slot error| "
                f"2^{nn.error_bits:.1f} "
                f"({nn.precision_bits:.1f} bits of precision)")
        if self.cost_s is not None:
            us = self.cost_s * 1e6
            lines.append(f"  est. device time {us:,.0f} µs "
                         f"(κ from {self.calibrated_from})")
        for diag in self.diagnostics:
            lines.append("  " + diag.format())
        if not self.diagnostics:
            lines.append("  no findings")
        return "\n".join(lines)


def analyze_circuit(ops: Sequence[OpNode],
                    input_meta: Dict[str, Meta],
                    params: HEParams, *,
                    input_bounds: Union[float, Dict[str, float]] = 1.0,
                    pt_bounds: Optional[Dict[int, float]] = None,
                    input_nslots: Optional[Dict[str, int]] = None,
                    provisioned_rotations: Optional[Set[int]] = None,
                    waterline_bits: float = DEFAULT_WATERLINE_BITS,
                    cost_model: Optional[CostModel] = None
                    ) -> AnalysisReport:
    """Run the full static analysis over one circuit.

    Dataflow violations do NOT raise: they come back as a single HS001
    error diagnostic citing the offending node (the same CircuitError
    admission would have raised).
    """
    try:
        meta = propagate(ops, input_meta, params)
    except CircuitError as e:
        diags = [Diagnostic("HS001", "error", str(e), node=e.node)]
        if "needs bootstrapping" in str(e) and e.node is not None:
            # the exhausted ciphertext is the offending node's operand:
            # a bootstrap spliced in front of it would refresh the
            # level and let the rest of the circuit proceed
            args = [a for a in ops[e.node].args if isinstance(a, int)]
            at = args[0] if args else e.node
            diags.append(Diagnostic(
                "HS007", "info",
                f"the level-exhausted ciphertext (node {at}'s output) "
                f"is bootstrappable: insert the repro.boot pipeline "
                f"there — run(bootstrap=\"auto\") does this "
                f"automatically (docs/BOOTSTRAP.md)", node=at))
        return AnalysisReport(diagnostics=diags, n_ops=len(ops))
    noise = estimate_noise(ops, input_meta, params,
                           input_bounds=input_bounds,
                           pt_bounds=pt_bounds,
                           input_nslots=input_nslots, meta=meta)
    ctx = RuleContext(ops=ops, input_meta=input_meta, params=params,
                      meta=meta, noise=noise,
                      provisioned_rotations=provisioned_rotations,
                      waterline_bits=waterline_bits)
    report = AnalysisReport(diagnostics=run_rules(ctx), n_ops=len(ops),
                            meta=list(meta), noise=list(noise))
    if cost_model is not None:
        total, per = cost_model.estimate_circuit(ops, input_meta, meta)
        report.cost_s = total
        report.cost_per_node = per
        report.calibrated_from = cost_model.calibrated_from
    return report


def analyze_handle(root, params: HEParams, *, compiled=None,
                   input_bounds: Union[float, Dict[str, float], None]
                   = None, **kw) -> AnalysisReport:
    """Analyze a traced `CipherHandle` expression: lower it with the
    client compile pass (or reuse a pre-compiled circuit via
    ``compiled=``), then run :func:`analyze_circuit` with the lowered
    circuit's own input metadata, slot counts, and recorded plaintext
    bounds.

    input_bounds defaults to the conservative 1.0 per input; pass the
    real max |slot value| per input name ("in0", "in1", … in trace
    order) for tight noise predictions.
    """
    if compiled is None:
        from repro.client.compile import compile_handle
        compiled = compile_handle(root, params)
    cc = compiled
    if not cc.ops:                       # a bare input: nothing to run
        return AnalysisReport(diagnostics=[], n_ops=0)
    input_meta = {n: (ct.logq, ct.logp) for n, ct in cc.inputs.items()}
    input_nslots = {n: ct.n_slots for n, ct in cc.inputs.items()}
    return analyze_circuit(
        ops=cc.ops, input_meta=input_meta, params=params,
        input_bounds=1.0 if input_bounds is None else input_bounds,
        pt_bounds=cc.pt_bounds, input_nslots=input_nslots, **kw)
