"""hslint CLI: ``python -m repro.analysis [names...] [--json]``.

Runs the static analyzer over the named example circuits (default:
all of them — the CI job does exactly this) and prints either pretty
per-circuit reports or one JSON object keyed by circuit name.

Exit status 1 IFF any circuit has an error-severity finding (HS001):
warnings and infos report but do not fail the build — the performance
rules are advisory by design.

    python -m repro.analysis                     # all examples, pretty
    python -m repro.analysis degree4 --json      # one circuit, JSON
    python -m repro.analysis --bench BENCH_serve_he.json
                                                 # + calibrated costs
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional

from repro.analysis.analyzer import analyze_circuit
from repro.analysis.cost import CostModel
from repro.analysis.examples import EXAMPLES, build


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*", default=None,
                    help=f"example circuits (default: all of "
                         f"{sorted(EXAMPLES)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="one JSON object keyed by circuit name")
    ap.add_argument("--bench", type=Path, default=None,
                    help="BENCH_serve_he.json to calibrate the cost "
                         "model from (adds est. device-seconds; the "
                         "bench's params need not match the "
                         "circuit's)")
    args = ap.parse_args(argv)
    names = args.names or sorted(EXAMPLES)

    reports = {}
    failed = False
    for name in names:
        kwargs, note = build(name)
        cost_model: Optional[CostModel] = None
        if args.bench is not None:
            # refit per circuit: κ transfers, unit counts use the
            # CIRCUIT's params
            bench = json.loads(args.bench.read_text())
            cost_model = CostModel.from_bench(bench)
            cost_model = CostModel(cost_model.kappa,
                                   cost_model.default_kappa,
                                   kwargs["params"],
                                   calibrated_from=str(args.bench))
        report = analyze_circuit(cost_model=cost_model, **kwargs)
        failed |= not report.ok
        if args.as_json:
            d = report.to_dict()
            d["note"] = note
            reports[name] = d
        else:
            print(report.render(f"{name} ({note})"))
            print()
    if args.as_json:
        print(json.dumps(reports, indent=2))
    if failed:
        print("hslint: error-severity findings above", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
