"""Bench-calibrated cost model: device-seconds per (op, level).

The paper's Table III observation is that HE op cost is dominated by a
small set of (N log N)-shaped transform passes whose COUNT per op is
known statically and whose per-unit cost is a device constant. We
exploit exactly that separation:

  analytic units  u(op, logq)   — how many weighted transform/limb
                                  units the op performs at that level
                                  (paper Fig. 2's region-1/region-2
                                  decomposition, counted below);
  fitted constant κ_op          — measured seconds per unit, fitted
                                  from BENCH_serve_he.json throughputs
                                  (so κ absorbs batching efficiency,
                                  device FLOPs, and runtime overheads).

Estimated device-seconds for an op is then κ_op · u(op, logq); for a
circuit, the sum over nodes. The model is intentionally coarse — its
two consumers need only ORDERING, not absolute accuracy:

  - `CircuitScheduler` asks "is deferring this bucket worth a batching
    win?" (a bucket of add at 2 limbs costs ~µs — flush it; a bucket
    of mul at full depth costs ~ms — wait for co-batching);
  - `python -m repro.analysis` reports per-circuit cost so regressions
    in circuit STRUCTURE show up in review, before any benchmark runs.

Unit counts (paper Fig. 2 / §III: HE Mul = 4 forward + 3 inverse
region-1 transforms at np1 primes plus 1 forward + 2 inverse region-2
transforms at np2 primes; rotate/conjugate = the region-2 key switch
only; mul_plain = region-1 products only, no key switch; add-likes and
level ops are per-limb linear passes):

  mul         (7·np1 + 3·np2) · N·logN
  rotate      3·np2 · N·logN          (also conjugate)
  slot_sum    log2(n) · (rotate + add)
  mul_plain   5·np1 · N·logN
  add/sub     qlimbs · N               (also add_plain, rescale,
                                        mod_down — limb-linear)
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis.dataflow import Meta, OpNode, propagate
from repro.core.params import HEParams

__all__ = ["op_units", "CostModel"]


def op_units(op: str, logq: int, params: HEParams, *,
             n_slots: Optional[int] = None) -> float:
    """Analytic work units for one (unbatched) op at level logq."""
    N = params.N
    nlogn = N * max(1, params.logN)
    np1 = params.np_region1(logq)
    np2 = params.np_region2(logq)
    limb = params.qlimbs(logq) * N
    if op == "mul":
        return (7 * np1 + 3 * np2) * nlogn
    if op in ("rotate", "conjugate"):
        return 3 * np2 * nlogn
    if op == "slot_sum":
        n = n_slots if n_slots else params.n_slots_max
        rounds = max(1, int(round(math.log2(max(2, n)))))
        return rounds * (3 * np2 * nlogn + limb)
    if op == "mul_plain":
        return 5 * np1 * nlogn
    # add, sub, add_plain, rescale, mod_down: limb-linear passes
    return limb


class CostModel:
    """κ_op constants fitted from a serve_he bench result.

    The bench reports batched throughput (ops/s at batch B); κ_op is
    fitted as mean over the measured levels of
    ``(1 / ops_per_s) / op_units(op, logq)`` — i.e. κ includes the
    bench's batching amortization, so estimates answer "what does one
    more of these cost the device IN the served configuration".
    Ops the bench doesn't measure fall back to the mean fitted κ
    (transform-dominated ops are within ~2× of each other per unit;
    the limb-linear ops have their own tiny unit counts).
    """

    def __init__(self, kappa: Dict[str, float], default_kappa: float,
                 params: HEParams, calibrated_from: str = "<dict>"):
        self.kappa = dict(kappa)
        self.default_kappa = float(default_kappa)
        self.params = params
        self.calibrated_from = calibrated_from

    @classmethod
    def from_bench(cls, bench: Union[str, Path, dict],
                   params: Optional[HEParams] = None) -> "CostModel":
        """Fit from BENCH_serve_he.json (path or already-loaded dict).

        Uses mul_per_s / rotate_per_s over the bench's measured levels
        and the plain block's throughputs at logQ; params default to
        the bench's own (logN, logQ, logp, beta_bits).
        """
        name = "<dict>"
        if not isinstance(bench, dict):
            name = str(bench)
            bench = json.loads(Path(bench).read_text())
        p = bench.get("params", {})
        if params is None:
            params = HEParams(logN=p["logN"], logQ=p["logQ"],
                              logp=p["logp"],
                              log_delta=p.get("log_delta", p["logp"]),
                              beta_bits=p["beta_bits"])
        levels = [int(x) for x in bench.get("levels", [params.logQ])]
        kappa: Dict[str, float] = {}

        def fit(op: str, per_s: Optional[float],
                at_levels: Sequence[int]):
            if per_s and per_s > 0:
                ks = [(1.0 / per_s) / op_units(op, lq, params)
                      for lq in at_levels]
                kappa[op] = sum(ks) / len(ks)

        fit("mul", bench.get("mul_per_s"), levels)
        fit("rotate", bench.get("rotate_per_s"), levels)
        plain = bench.get("plain", {})
        fit("mul_plain", plain.get("mul_plain_per_s"), [params.logQ])
        fit("add_plain", plain.get("add_plain_per_s"), [params.logQ])
        if not kappa:
            raise ValueError(
                f"cost model: no usable throughputs in {name} "
                f"(need mul_per_s / rotate_per_s / plain.*_per_s)")
        default = sum(kappa.values()) / len(kappa)
        return cls(kappa, default, params, calibrated_from=name)

    def op_seconds(self, op: str, logq: int, *,
                   n_slots: Optional[int] = None) -> float:
        """Estimated device-seconds for ONE op at this level, in the
        calibrated serving configuration."""
        k = self.kappa.get(op)
        if k is None and op == "conjugate":
            k = self.kappa.get("rotate")     # same key-switch machinery
        if k is None and op == "slot_sum":
            k = self.kappa.get("rotate")     # a ladder of rotates
        if k is None:
            k = self.default_kappa
        return k * op_units(op, logq, self.params, n_slots=n_slots)

    def estimate_circuit(self, ops: Sequence[OpNode],
                         input_meta: Dict[str, Meta],
                         meta: Optional[Sequence[Meta]] = None
                         ) -> Tuple[float, List[float]]:
        """(total device-seconds, per-node seconds) for one pass of the
        circuit. Each node is costed at its INPUT level — the level the
        batched step actually runs at."""
        if meta is None:
            meta = propagate(ops, input_meta, params=self.params)
        per: List[float] = []
        for i, node in enumerate(ops):
            a = node.args[0]
            in_logq = (input_meta[a][0] if isinstance(a, str)
                       else meta[a][0])
            per.append(self.op_seconds(node.op, in_logq))
        return sum(per), per
