"""Deterministic random traced expressions with plaintext shadows.

Shared by the hypothesis property tests, the 8-device mesh harness, and
benchmarks: grow a random expression over `CipherHandle`s while
evaluating the SAME ops on the plaintext slot values (the "shadow"), so
a decrypted result can be checked against what the arithmetic should
have produced — independently of how the compiler chose to lower it.

The generator tracks each subexpression's multiplicative depth and stops
spending levels at `max_depth`, so every generated trace compiles within
the parameter set's modulus budget by construction.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.client.handles import CipherHandle, PlainHandle

__all__ = ["random_expr", "OP_KINDS"]

# depth-spending kinds consume one rescale level each
OP_KINDS = ("mul", "mul_plain", "add", "sub", "add_plain", "rotate",
            "conjugate", "slot_sum")
_DEPTH_KINDS = ("mul", "mul_plain")


def random_expr(rng: np.random.Generator,
                leaves: List[Tuple[CipherHandle, np.ndarray]], *,
                n_ops: int = 4, max_depth: int = 2,
                rotations: Tuple[int, ...] = (1, 2)):
    """Grow a random traced expression chain over (handle, slots) leaves.

    Returns (handle, shadow): the traced root and the numpy slot values
    the decrypted result must approximate. Every op kind in
    :data:`OP_KINDS` can appear; multiplicative depth along any path is
    capped at `max_depth` (the mul kinds are withheld once the chain
    reaches it).
    """
    pool = [(h, np.asarray(z, dtype=np.complex128), 0)
            for h, z in leaves]
    n = pool[0][0].n_slots
    cur, cur_z, cur_d = pool[int(rng.integers(len(pool)))]
    for _ in range(n_ops):
        kinds = [k for k in OP_KINDS
                 if cur_d < max_depth or k not in _DEPTH_KINDS]
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "mul":
            o, oz, od = pool[int(rng.integers(len(pool)))]
            if od >= max_depth:        # operand already at the cap
                kind = "add"
            else:
                cur, cur_z = cur * o, cur_z * oz
                cur_d = max(cur_d, od) + 1
        if kind == "mul_plain":
            w = _rand_plain(rng, n)
            cur, cur_z, cur_d = cur * w, cur_z * w.broadcast(n), cur_d + 1
        elif kind in ("add", "sub"):
            o, oz, od = pool[int(rng.integers(len(pool)))]
            if kind == "add":
                cur, cur_z = cur + o, cur_z + oz
            else:
                cur, cur_z = cur - o, cur_z - oz
            cur_d = max(cur_d, od)
        elif kind == "add_plain":
            w = _rand_plain(rng, n)
            cur, cur_z = cur + w, cur_z + w.broadcast(n)
        elif kind == "rotate":
            r = int(rotations[int(rng.integers(len(rotations)))])
            cur, cur_z = cur.rotate(r), np.roll(cur_z, -r)
        elif kind == "conjugate":
            cur, cur_z = cur.conj(), np.conj(cur_z)
        elif kind == "slot_sum":
            cur, cur_z = cur.slot_sum(), np.full(n, cur_z.sum())
        pool.append((cur, cur_z, cur_d))
    return cur, cur_z


def _rand_plain(rng: np.random.Generator, n: int) -> PlainHandle:
    """A small random plain operand — scalar half the time (exercising
    broadcast), vector otherwise; magnitudes kept ≤ ~0.5 so chained
    products and slot sums stay well inside the scale budget."""
    if rng.integers(2):
        return PlainHandle(0.5 * complex(rng.normal(), rng.normal())
                           / np.sqrt(2))
    z = 0.5 * (rng.normal(size=n) + 1j * rng.normal(size=n)) / np.sqrt(2)
    return PlainHandle(z)
