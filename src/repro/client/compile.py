"""The `repro.client` compile pass: traced handle DAG → validated
`CircuitOp` list.

What the user writes is arithmetic; what the server batches is a
topologically ordered, level-aligned encrypted circuit. This pass closes
the gap (the Evaluator-frontend design of SEAL / the graph compilation
of nGraph-HE, cf. PAPERS.md):

  1. **Auto level alignment** — the handle API has no rescale/mod_down;
     the compiler inserts them using the same (logq, logp) rules as
     `hserve.circuit.validate_circuit`:
       - after every `mul` / `mul_plain`, a `rescale` by params.logp
         brings the scale back to Δ (one level consumed — §III-A's
         discipline; assumes the repo-wide log_delta == logp convention);
       - binary-op operands at different moduli get a `mod_down` on the
         higher one; `add`/`sub` operands at different scales get a
         `rescale` on the higher-scale one first.
     A trace deeper than the modulus budget raises ValueError at
     compile — nothing reaches the queue.
  2. **Constant folding** — plain–plain arithmetic folded eagerly by
     `PlainHandle` never appears here; every emitted node touches a
     ciphertext.
  3. **Common-subexpression elimination** — nodes are hash-consed on
     (op, operand refs, parameters, plaintext hash); `x*x` written twice
     costs one HE Mul. Symmetric ops (mul, add) canonicalize operand
     order first.
  4. **Plaintext operand caching** — each plain operand is broadcast,
     content-hashed (`core.encoding.message_hash`), and encoded at its
     use site's level — UNLESS the server-side (hash, level) cache
     already holds it (`plain_lookup`), in which case the node ships
     hash-only and the client-side encode is skipped entirely.

The result is a :class:`CompiledCircuit`: ops ready for
``HEServer.submit_circuit`` (the LAST node is the output), the input
ciphertexts keyed by generated names, the output metadata, and the key
material the trace needs (so ``HESession`` can auto-provision rotation /
conjugation keys).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple, Union

import numpy as np

from repro.analysis.dataflow import transfer
from repro.client.handles import CipherHandle
from repro.core import heaan as H
from repro.core.cipher import Ciphertext
from repro.core.encoding import message_hash
from repro.core.params import HEParams
from repro.hserve.circuit import CircuitOp
from repro.hserve.engine import slot_sum_rotations

__all__ = ["CompiledCircuit", "compile_handle"]

NodeRef = Union[int, str]

# a requirement is ("evk",), ("conj",), or ("rot", r)
Requirement = Tuple


@dataclasses.dataclass
class CompiledCircuit:
    """A lowered trace: everything ``HEServer.submit_circuit`` needs.

    plain_registers: the (hash, logq) plaintext operands this circuit
    carries materialized — i.e. what its submission will REGISTER in
    the server's cache. ``HESession.run`` feeds these into the lookup
    of later compiles in the same call, so sibling circuits ship
    hash-only even though nothing has been submitted yet.

    pt_bounds: per plain-op node index, the max |slot value| of that
    node's plaintext operand — recorded at lowering (where the message
    is still in hand, including for hash-only nodes whose encoding was
    skipped) so `repro.analysis.noise` can bound plaintext products
    without re-materializing operands.
    """

    ops: List[CircuitOp]
    inputs: Dict[str, Ciphertext]
    out_logq: int
    out_logp: int
    n_slots: int
    requires: Set[Requirement]
    plain_registers: Set[Tuple[str, int]] = \
        dataclasses.field(default_factory=set)
    pt_bounds: Dict[int, float] = dataclasses.field(default_factory=dict)
    # node index of each auto-inserted bootstrap's mod_raise head
    # (compile_handle(bootstrap="auto")); empty when none fired
    bootstraps: List[int] = dataclasses.field(default_factory=list)


def _ref_key(ref: NodeRef):
    """Total order over node refs (ints before input names) — the
    canonical operand order for symmetric ops, so CSE sees x*y and y*x
    as one node."""
    return (1, ref) if isinstance(ref, str) else (0, ref)


class _Lowering:
    def __init__(self, params: HEParams,
                 plain_lookup: Optional[Callable[[str, int], bool]],
                 bootstrap: bool = False):
        self.params = params
        self.lookup = plain_lookup
        self.bootstrap = bootstrap
        self.ops: List[CircuitOp] = []
        self.meta: List[Tuple[int, int]] = []      # per-op (logq, logp)
        self.inputs: Dict[str, Ciphertext] = {}
        self.in_meta: Dict[str, Tuple[int, int]] = {}
        self.memo: Dict[CipherHandle, NodeRef] = {}
        self.cse: Dict[tuple, int] = {}
        self.requires: Set[Requirement] = set()
        self.plain_registers: Set[Tuple[str, int]] = set()
        self.pt_bounds: Dict[int, float] = {}
        self.bootstraps: List[int] = []
        self._boot_memo: Dict[NodeRef, NodeRef] = {}

    def m(self, ref: NodeRef) -> Tuple[int, int]:
        return self.in_meta[ref] if isinstance(ref, str) else self.meta[ref]

    def out(self, op: str, refs, **kw) -> Tuple[int, int]:
        """Output (logq, logp) for a node — THE shared transfer function
        (`repro.analysis.dataflow.transfer`), the same rules
        `validate_circuit` applies at admission, so a circuit this pass
        emits can never be rejected by the server for level/scale
        errors. Raises trace-cited CircuitError (a ValueError)."""
        return transfer(op, [self.m(r) for r in refs], self.params, **kw)

    def emit(self, op: str, args: Tuple[NodeRef, ...], *, r: int = 0,
             dlogp: int = 0, logq2: int = 0, pt=None, pt_logp: int = 0,
             pt_hash: Optional[str] = None,
             out: Tuple[int, int]) -> int:
        sig = (op, args, r, dlogp, logq2, pt_hash, pt_logp)
        if sig in self.cse:
            return self.cse[sig]
        self.ops.append(CircuitOp(op, args, r=r, dlogp=dlogp, logq2=logq2,
                                  pt=pt, pt_logp=pt_logp, pt_hash=pt_hash))
        self.meta.append(out)
        self.cse[sig] = len(self.ops) - 1
        return self.cse[sig]

    # ---- level management (the compiler-owned part) ---------------------

    def mod_down(self, ref: NodeRef, logq2: int) -> NodeRef:
        if self.m(ref)[0] == logq2:
            return ref
        return self.emit("mod_down", (ref,), logq2=logq2,
                         out=self.out("mod_down", (ref,), logq2=logq2))

    def rescale(self, ref: NodeRef, dlogp: int) -> NodeRef:
        if dlogp == 0:
            return ref
        return self.emit("rescale", (ref,), dlogp=dlogp,
                         out=self.out("rescale", (ref,), dlogp=dlogp))

    def align_levels(self, a: NodeRef, b: NodeRef):
        la, lb = self.m(a)[0], self.m(b)[0]
        if la > lb:
            a = self.mod_down(a, lb)
        elif lb > la:
            b = self.mod_down(b, la)
        return a, b

    def align_scales_and_levels(self, a: NodeRef, b: NodeRef):
        pa, pb = self.m(a)[1], self.m(b)[1]
        if pa > pb:
            a = self.rescale(a, pa - pb)
        elif pb > pa:
            b = self.rescale(b, pb - pa)
        return self.align_levels(a, b)

    # ---- bootstrap insertion --------------------------------------------

    def maybe_bootstrap(self, ref: NodeRef, n_slots: int) -> NodeRef:
        """Auto-insertion (compile_handle(bootstrap="auto")): when a mul
        operand has no level left for the post-mul rescale — exactly
        where the dataflow pass would raise "needs bootstrapping" — the
        full `repro.boot` pipeline is spliced in front of it, and the
        mul proceeds at the refreshed level. Per-ref memo: an exhausted
        value feeding several muls (x*x, or a shared subexpression)
        bootstraps ONCE."""
        if not self.bootstrap:
            return ref
        if self.m(ref)[0] - self.params.logp >= self.params.logp:
            return ref
        if ref in self._boot_memo:
            return self._boot_memo[ref]
        from repro.boot.pipeline import bootstrap_circuit
        lq, lp = self.m(ref)
        plan = bootstrap_circuit(
            self.params, logq_in=lq, logp=lp, n_slots=n_slots,
            plain_lookup=lambda hs, q: (hs, q) in self.plain_registers
            or (self.lookup is not None and self.lookup(hs, q)))
        off = len(self.ops)
        for node, m in zip(plan.ops, plan.meta):
            args = tuple(ref if isinstance(a, str) else a + off
                         for a in node.args)
            self.ops.append(dataclasses.replace(node, args=args))
            self.meta.append(m)
        for i, bnd in plan.pt_bounds.items():
            self.pt_bounds[i + off] = bnd
        self.requires |= plan.requires
        self.plain_registers |= plan.plain_registers
        self.bootstraps.append(off)
        out = len(self.ops) - 1
        self._boot_memo[ref] = out
        return out

    # ---- plaintext operands ---------------------------------------------

    def plain_operand(self, h: CipherHandle, log_delta: int, logq: int):
        """(pt, hash, bound) for a plain operand at a use site: hash
        (and the max-|slot| bound the noise estimator reads) always;
        the encode is SKIPPED when the server already caches
        (hash, logq) — or when an earlier node of THIS circuit already
        carries it (the lower-index node registers the operand at
        submission, before later nodes resolve it), so one weight
        vector applied to k ciphertexts in one trace encodes once."""
        z = h.plain.broadcast(h.n_slots)
        hsh = message_hash(z, log_delta)
        bound = float(np.max(np.abs(z))) if np.size(z) else 0.0
        if (hsh, logq) in self.plain_registers or (
                self.lookup is not None and self.lookup(hsh, logq)):
            return None, hsh, bound
        self.plain_registers.add((hsh, logq))
        return np.asarray(H.encode_plain(z, self.params, logq,
                                         log_delta=log_delta)), hsh, bound

    # ---- the lowering walk ----------------------------------------------

    def visit(self, h: CipherHandle) -> NodeRef:
        if h in self.memo:
            return self.memo[h]
        p = self.params
        if h.op == "input":
            name = f"in{len(self.inputs)}"
            self.inputs[name] = h.ct
            self.in_meta[name] = (h.ct.logq, h.ct.logp)
            self.memo[h] = name
            return name
        refs = [self.visit(a) for a in h.args]
        if h.op == "mul":
            a = self.maybe_bootstrap(refs[0], h.n_slots)
            b = self.maybe_bootstrap(refs[1], h.n_slots)
            a, b = self.align_levels(a, b)
            a, b = sorted((a, b), key=_ref_key)
            i = self.emit("mul", (a, b), out=self.out("mul", (a, b)))
            i = self.rescale(i, p.logp)
            self.requires.add(("evk",))
        elif h.op == "mul_plain":
            a, = refs
            a = self.maybe_bootstrap(a, h.n_slots)
            lq = self.m(a)[0]
            pt, hsh, bound = self.plain_operand(h, p.log_delta, lq)
            i = self.emit("mul_plain", (a,), pt=pt, pt_logp=p.log_delta,
                          pt_hash=hsh,
                          out=self.out("mul_plain", (a,),
                                       pt_logp=p.log_delta))
            self.pt_bounds[i] = bound
            i = self.rescale(i, p.logp)
        elif h.op in ("add", "sub"):
            a, b = self.align_scales_and_levels(*refs)
            if h.op == "add":
                a, b = sorted((a, b), key=_ref_key)
            i = self.emit(h.op, (a, b), out=self.out(h.op, (a, b)))
        elif h.op == "add_plain":
            a, = refs
            lq, lp = self.m(a)
            pt, hsh, bound = self.plain_operand(h, lp, lq)
            i = self.emit("add_plain", (a,), pt=pt, pt_logp=lp,
                          pt_hash=hsh,
                          out=self.out("add_plain", (a,), pt_logp=lp))
            self.pt_bounds[i] = bound
        elif h.op == "rotate":
            a, = refs
            i = self.emit("rotate", (a,), r=h.r,
                          out=self.out("rotate", (a,), r=h.r))
            self.requires.add(("rot", h.r))
        elif h.op == "conjugate":
            a, = refs
            i = self.emit("conjugate", (a,),
                          out=self.out("conjugate", (a,)))
            self.requires.add(("conj",))
        else:                          # slot_sum (TRACE_OPS is closed)
            a, = refs
            i = self.emit("slot_sum", (a,),
                          out=self.out("slot_sum", (a,)))
            self.requires.update(
                ("rot", r) for r in slot_sum_rotations(h.n_slots))
        self.memo[h] = i
        return i


def compile_handle(root: CipherHandle, params: HEParams, *,
                   plain_lookup: Optional[Callable[[str, int], bool]]
                   = None,
                   bootstrap: Union[bool, str] = False) -> CompiledCircuit:
    """Lower one traced expression to a served circuit.

    plain_lookup(hash, logq) → bool: whether the server's plaintext
    cache already holds an operand (``TableCache.has_plain``); matching
    operands ship hash-only, skipping the client-side encode.

    bootstrap: "auto" (or True) splices the `repro.boot` pipeline in
    front of any mul operand too exhausted for its post-mul rescale —
    the trace may then exceed the native depth budget; the indices of
    inserted pipelines land in ``CompiledCircuit.bootstraps``. The
    default False keeps today's behavior: a too-deep trace raises
    "needs bootstrapping" at compile.
    """
    if bootstrap not in (False, True, "auto", "off"):
        raise ValueError(f"bootstrap must be 'auto' or 'off', "
                         f"got {bootstrap!r}")
    if root.op == "input":
        # a bare input needs no server round trip at all
        return CompiledCircuit(ops=[], inputs={"in0": root.ct},
                               out_logq=root.ct.logq,
                               out_logp=root.ct.logp,
                               n_slots=root.n_slots, requires=set())
    lw = _Lowering(params, plain_lookup,
                   bootstrap=bootstrap in (True, "auto"))
    out = lw.visit(root)
    if isinstance(out, str) or out != len(lw.ops) - 1:
        # defensive: the server returns the LAST node's ciphertext, so a
        # root that hash-consed onto an interior node gets an identity
        # mod_down tail (same modulus — a served no-op)
        lq, lp = lw.m(out)
        lw.ops.append(CircuitOp("mod_down", (out,), logq2=lq))
        lw.meta.append((lq, lp))
        out = len(lw.ops) - 1
    out_logq, out_logp = lw.meta[out]
    return CompiledCircuit(ops=lw.ops, inputs=lw.inputs,
                           out_logq=out_logq, out_logp=out_logp,
                           n_slots=root.n_slots, requires=lw.requires,
                           plain_registers=lw.plain_registers,
                           pt_bounds=lw.pt_bounds,
                           bootstraps=lw.bootstraps)
