"""HESession: the canonical user entry point to the serving stack.

One session owns the parameter set, the key material, and an
:class:`repro.hserve.HEServer` (or wraps one you built yourself). The
workflow is paper §I's application shape — encrypt once, run a chained
encrypted computation server-side, decrypt once:

    session = HESession(params, seed=0, batch=8)
    x = session.encrypt(z)                       # CipherHandle (traced)
    y = ((x * x) * w + x).rotate(1).conj().slot_sum()
    prob = session.decrypt(y)                    # compile → serve → dec

``run`` submits many traced expressions WITHOUT draining between them,
so independent circuits co-batch through the circuit-aware scheduler —
the client-side mirror of the server's cross-circuit co-batching. Each
submission returns a :class:`CipherFuture`; the first ``result()`` call
drains the server and resolves every pending future at once.

Key provisioning: with the secret key in the session (the default —
``HESession(params, seed=...)`` runs keygen), rotation and conjugation
keys the trace needs are generated on demand and loaded into the
server's resident cache (``auto_keys=False`` to disable). A session can
also be built pk-only (no decrypt, no auto keys) around a shared server.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.client.compile import CompiledCircuit, compile_handle
from repro.client.handles import CipherHandle, PlainHandle
from repro.core import heaan as H
from repro.core.cipher import Ciphertext
from repro.core.keys import keygen
from repro.core.params import HEParams
from repro.core.rotate import conj_keygen, rot_keygen

__all__ = ["CipherFuture", "HESession"]


class CipherFuture:
    """The pending result of one submitted traced circuit."""

    def __init__(self, session: "HESession", cid: Optional[int],
                 ct: Optional[Ciphertext] = None):
        self._session = session
        self.cid = cid
        self._ct = ct

    def done(self) -> bool:
        return self._ct is not None

    def result(self) -> Ciphertext:
        """The circuit's output ciphertext (drains the session's server
        on first call; every other pending future resolves with it).
        Raw server-submit results completed by this drain stay buffered
        for the next ``HESession.drain()`` call."""
        if self._ct is None:
            self._session._drain_server()
            if self._ct is None:
                raise RuntimeError(
                    f"circuit {self.cid} did not complete in drain()")
        return self._ct

    def decrypt(self) -> np.ndarray:
        """result() decrypted to complex slots (needs the session's sk)."""
        return self._session.decrypt(self.result())


class HESession:
    """Encrypt/decrypt boundary + traced-expression executor.

    params: the HEAAN parameter set.
    sk/pk/evk: key triple; omit ALL of them to run keygen(seed).
    rot_keys/conj_key: preloaded Galois keys for a freshly built server
        (with auto_keys and sk, traces provision their own on demand).
    seed: keygen seed when no keys are passed (default 0).
    server: wrap an existing HEServer instead of building one (mesh /
        batch / server knobs then live on that server).
    mesh, batch, **server_kwargs: forwarded to the built HEServer
        (max_age_s, overlap, schedule, use_kernels, ...).
    auto_keys: generate + load missing rotation/conjugation keys at run
        time from the session's sk (ignored without an sk).
    """

    def __init__(self, params: HEParams, sk=None, pk=None, evk=None,
                 rot_keys=None, conj_key=None, *,
                 seed: Optional[int] = None, server=None, mesh=None,
                 batch: int = 8, auto_keys: bool = True, **server_kwargs):
        self.params = params
        if pk is None:
            if sk is not None or evk is not None:
                raise ValueError(
                    "pass all of (sk, pk, evk) or none of them")
            sk, pk, evk = keygen(params, seed=0 if seed is None else seed)
        self.sk, self.pk, self.evk = sk, pk, evk
        if server is None:
            from repro.hserve import HEServer
            server = HEServer(params, evk, rot_keys, conj_key, mesh=mesh,
                              batch=batch, **server_kwargs)
        elif mesh is not None or server_kwargs:
            raise ValueError(
                "mesh/server knobs conflict with an explicit server; "
                "configure the HEServer you pass in")
        else:
            # Galois keys passed alongside an explicit server load into
            # its resident cache (dropping them silently would strand a
            # pk-only session that cannot regenerate them)
            for r, rk in (rot_keys or {}).items():
                server.cache.add_rot_key(r, rk)
            if conj_key is not None:
                server.cache.add_conj_key(conj_key)
        self.server = server
        # client-plane telemetry rides the server's registry so one
        # snapshot (and one heartbeat) carries the whole stack
        reg = getattr(server, "registry", None)
        self._c_runs = reg.counter("client.runs") \
            if reg is not None else None
        self._c_circuits = reg.counter("client.circuits") \
            if reg is not None else None
        self._c_bootstraps = reg.counter("client.bootstraps") \
            if reg is not None else None
        self.auto_keys = auto_keys
        self._futures: Dict[int, CipherFuture] = {}
        # bootstrap plans keyed by (logq, logp, n_slots, config):
        # construction (stage lowering + DFT matrices) happens once per
        # input shape; repeats also ship their diagonals hash-only
        self._boot_plans: Dict[tuple, object] = {}
        # raw server-submit results completed by a future-triggered
        # drain, buffered until the next explicit drain() claims them
        self._raw: Dict[int, Ciphertext] = {}
        # AnalysisReports from the latest run(check=...), one per
        # handle (None for bare inputs)
        self.last_reports: list = []
        # per-session counter for default encryption seeds: every
        # default-seeded encrypt gets FRESH randomness (reusing one seed
        # across messages leaks their difference — c1.bx − c2.bx would
        # cancel the identical noise and mask)
        self._enc_seed = 1

    # ---- data boundary ---------------------------------------------------

    def encrypt(self, z, seed: Optional[int] = None) -> CipherHandle:
        """Encrypt a complex slot message into a traced input handle.

        seed: encryption randomness. Default: a fresh per-session
        counter value — never reused, so two default-seeded ciphertexts
        never share their (u, e0, e1) randomness. Pass explicit seeds
        only for reproducibility, and never the same one twice.
        """
        if seed is None:
            seed = self._enc_seed
            self._enc_seed += 1
        z = np.asarray(z, dtype=np.complex128)
        return self.input(
            H.encrypt_message(z, self.pk, self.params, seed=seed))

    def input(self, ct: Ciphertext) -> CipherHandle:
        """Wrap an existing ciphertext as a traced input handle."""
        return CipherHandle(self, "input", ct=ct)

    def plain(self, z) -> PlainHandle:
        """Wrap a plaintext message/scalar (raw scalars and arrays in
        handle arithmetic wrap themselves; this is for explicitness)."""
        return PlainHandle(z)

    def decrypt(self, x: Union[Ciphertext, CipherHandle, CipherFuture]
                ) -> np.ndarray:
        """Decrypt a ciphertext / future / traced handle (running the
        trace first when needed). Needs the session's secret key."""
        if isinstance(x, CipherHandle):
            x = self.run([x])[0]
        if isinstance(x, CipherFuture):
            x = x.result()
        if self.sk is None:
            raise ValueError("this session holds no secret key")
        return H.decrypt_message(x, self.sk, self.params)

    # ---- execution -------------------------------------------------------

    def compile(self, handle: CipherHandle,
                bootstrap: Union[bool, str] = False) -> CompiledCircuit:
        """Lower one traced expression (auto level alignment, CSE,
        plaintext-cache-aware operand encoding) without submitting it.
        bootstrap: as in :meth:`run`."""
        return compile_handle(handle, self.params,
                              plain_lookup=self.server.cache.has_plain,
                              bootstrap=bootstrap)

    def bootstrap(self, x: Union[Ciphertext, CipherHandle, CipherFuture],
                  *, config=None) -> CipherFuture:
        """Refresh a level-exhausted ciphertext through the served
        `repro.boot` pipeline; returns a future whose result is the
        SAME message at a higher level (within the plan's documented
        error bound — bootstrap is approximate, see docs/BOOTSTRAP.md).

        x: a ciphertext, input handle, traced handle (run first), or
        future (drained first). Plans are cached per input shape, so
        repeat bootstraps skip plan construction AND ship their
        CoeffToSlot/SlotToCoeff diagonals hash-only. Needed rotation /
        conjugation keys auto-provision like :meth:`run`'s.
        """
        from repro.boot.pipeline import BootConfig, bootstrap_circuit
        if isinstance(x, CipherHandle):
            x = x.ct if x.op == "input" else self.run([x])[0]
        if isinstance(x, CipherFuture):
            x = x.result()
        key = (x.logq, x.logp, x.n_slots, config or BootConfig())
        plan = self._boot_plans.get(key)
        if plan is None:
            plan = bootstrap_circuit(
                self.params, logq_in=x.logq, logp=x.logp,
                n_slots=x.n_slots, config=config,
                plain_lookup=self.server.cache.has_plain)
            self._boot_plans[key] = plan
        if self.auto_keys and self.sk is not None:
            self.ensure_keys(plan.requires)
        cid = self.server.submit_bootstrap(x, plan=plan)
        fut = CipherFuture(self, cid)
        self._futures[cid] = fut
        if self._c_bootstraps is not None:
            self._c_bootstraps.inc()
        return fut

    def run(self, handles: Sequence[CipherHandle], *,
            check: str = "off",
            bootstrap: Union[bool, str] = False) -> List[CipherFuture]:
        """Compile + submit traced expressions; returns one future per
        handle. Nothing executes until a future's result() drains the
        server — so everything submitted here (and any raw server
        traffic) co-batches.

        Compilation of EVERY handle happens before anything is
        submitted: a compile error (trace too deep, bad slots) raises
        with zero circuits enqueued, never orphaning earlier handles'
        futures. Cache-aware lowering still sees siblings: operands an
        earlier handle in this call will register compile to hash-only
        nodes in later ones (they resolve at submit time, in order).
        Futures register only after EVERY submit succeeds — if a later
        submit raises (e.g. a missing Galois key on a pk-only session),
        the already-enqueued circuits' results come back as raw
        {cid: ct} entries from the next :meth:`drain` instead of
        vanishing into unreachable futures.

        check: run the static analyzer (`repro.analysis`) over every
        compiled circuit BEFORE submitting anything. "error" raises
        ValueError on any error- or warning-severity finding (noise
        below the waterline, dead nodes, rotation smells); "warn"
        issues a `UserWarning` per finding instead; "off" (default)
        skips analysis entirely. The reports of the latest checked run
        are kept on ``self.last_reports`` (one per handle, None for
        bare inputs) either way.

        bootstrap: "auto" (or True) lets the compile pass splice the
        served `repro.boot` pipeline in front of level-exhausted mul
        operands, so a trace deeper than the native modulus budget
        still runs (approximately — see docs/BOOTSTRAP.md). Default
        off: such traces raise "needs bootstrapping" at compile.
        """
        if check not in ("off", "warn", "error"):
            raise ValueError(f"check must be 'off', 'warn', or "
                             f"'error', got {check!r}")
        pending: set = set()           # (hash, logq) earlier handles
                                       # in THIS call will register
        cache = self.server.cache
        compiled = []
        for h in handles:
            if not isinstance(h, CipherHandle):
                raise TypeError(f"run() takes CipherHandles, got "
                                f"{type(h).__name__}")
            if h.session is not self:
                raise ValueError("handle belongs to a different session")
            if h.op == "input":        # bare input: already a ciphertext
                compiled.append((h, None))
                continue
            cc = compile_handle(
                h, self.params,
                plain_lookup=lambda hs, lq: cache.has_plain(hs, lq)
                or (hs, lq) in pending,
                bootstrap=bootstrap)
            pending |= cc.plain_registers
            compiled.append((h, cc))
        if check != "off":
            self._check_compiled(compiled, check)
        futures: List[CipherFuture] = []
        to_register: List[CipherFuture] = []
        for h, cc in compiled:
            if cc is None:
                futures.append(CipherFuture(self, None, ct=h.ct))
                continue
            if self.auto_keys and self.sk is not None:
                self.ensure_keys(cc.requires)
            try:
                cid = self.server.submit_circuit(cc.ops, cc.inputs)
            except ValueError as e:
                if "no cached plaintext" not in str(e):
                    raise
                # the compile-time has_plain answer raced LRU eviction
                # (a sibling's registration in this very call can evict
                # the entry): re-lower with every operand materialized
                cc = compile_handle(h, self.params, plain_lookup=None,
                                    bootstrap=bootstrap)
                cid = self.server.submit_circuit(cc.ops, cc.inputs)
            to_register.append(CipherFuture(self, cid))
            futures.append(to_register[-1])
        self._futures.update((f.cid, f) for f in to_register)
        if self._c_runs is not None:
            self._c_runs.inc()
            self._c_circuits.inc(len(to_register))
        return futures

    def _check_compiled(self, compiled, check: str) -> None:
        """The ``run(check=...)`` analysis pass: analyze every lowered
        circuit (bare inputs skip), escalate per policy. Rotation keys
        resident on the server count as provisioned for the HS004
        rotation rule; an auto-keys session with a secret key reports
        None (it can mint any key, so nothing is 'missing')."""
        import warnings

        from repro.analysis import analyze_handle

        provisioned = None if (self.auto_keys and self.sk is not None) \
            else set(self.server.cache.rotation_amounts())
        self.last_reports = []
        findings = []
        for h, cc in compiled:
            if cc is None:
                self.last_reports.append(None)
                continue
            report = analyze_handle(h, self.params, compiled=cc,
                                    provisioned_rotations=provisioned)
            self.last_reports.append(report)
            k = len(self.last_reports) - 1
            findings += [(k, d) for d in report.diagnostics
                         if d.severity in ("error", "warning")]
        if not findings:
            return
        msgs = [f"handle {k}: {d.format()}" for k, d in findings]
        if check == "error":
            raise ValueError(
                "static analysis rejected the run (check='error'): "
                + "; ".join(msgs))
        for m in msgs:
            warnings.warn(m, stacklevel=3)

    def _drain_server(self) -> None:
        """Drain the server, routing results: future-owned cids resolve
        their futures, everything else is buffered in ``_raw`` until an
        explicit :meth:`drain` claims it (so a future-triggered drain
        never loses raw server-submit results)."""
        for rid, ct in self.server.drain().items():
            fut = self._futures.pop(rid, None)
            if fut is not None:
                fut._ct = ct
            else:
                self._raw[rid] = ct

    def drain(self) -> Dict[int, Ciphertext]:
        """Serve everything queued on the server. Resolves this
        session's pending futures; results of RAW server submits (ops
        or circuits submitted directly on ``session.server``) are
        returned as {rid: Ciphertext}, including any completed earlier
        by a future-triggered drain — use this instead of
        ``server.drain()`` when mixing the two, so futures are not
        starved of their results."""
        self._drain_server()
        out, self._raw = self._raw, {}
        return out

    # ---- key provisioning ------------------------------------------------

    def ensure_keys(self, requires) -> None:
        """Generate + load any missing Galois keys a compiled trace
        needs (("rot", r) / ("conj",) requirements). Needs the sk."""
        cache = self.server.cache
        for req in sorted(requires):
            if req[0] == "rot" and req[1] not in cache.rotation_amounts:
                cache.add_rot_key(
                    req[1], rot_keygen(self.params, self.sk, req[1]))
            elif req[0] == "conj" and not cache.has_conj_key:
                cache.add_conj_key(conj_keygen(self.params, self.sk))

    def ensure_rotation_keys(self, rs) -> None:
        """Convenience for raw-op callers: load rotation keys for the
        given amounts."""
        self.ensure_keys({("rot", int(r)) for r in rs})

    def ensure_conj_key(self) -> None:
        self.ensure_keys({("conj",)})

    # ---- accounting ------------------------------------------------------

    def stats(self) -> dict:
        return self.server.stats()
