"""repro.client — the traced CipherHandle/HESession user API.

The paper's workloads (§III–V) are chained op-DAGs at descending levels,
and PR 3–4 taught the server to evaluate whole circuits with
cross-circuit co-batching — but writing `CircuitOp` lists with integer
node refs and manual (logq, logp) bookkeeping is evaluator assembly.
This package is the compiler-style frontend production HE stacks put on
top (SEAL's Evaluator object model, nGraph-HE's graph-compiled
inference; PAPERS.md):

  - :mod:`repro.client.handles` — `CipherHandle` / `PlainHandle`:
    overloaded `* + - conj() rotate(r) slot_sum()` lazily trace an
    op-DAG; plain–plain arithmetic constant-folds eagerly.
  - :mod:`repro.client.compile` — the lowering pass: auto
    rescale/mod_down level alignment, CSE, plaintext-cache-aware
    operand encoding; emits a validated `CircuitOp` list.
  - :mod:`repro.client.session` — `HESession` owns keys +
    encrypt/decrypt and an `HEServer`; `run()` returns `CipherFuture`s
    so many traced circuits co-batch through one drain.
  - :mod:`repro.client.testing` — deterministic random traced
    expressions with plaintext shadows (property tests, mesh harnesses,
    benchmarks).

Quickstart (see docs/API.md for the full contract)::

    from repro.client import HESession
    from repro.core.params import test_params

    session = HESession(test_params(logN=5, beta_bits=32), seed=0)
    x = session.encrypt(z)                    # traced input handle
    y = ((x * x) * w + x).rotate(1).conj().slot_sum()
    vals = session.decrypt(y)                 # compile → serve → decrypt

The old per-op helpers (``HEServer.submit_mul`` et al.) remain as thin
wrappers over the same queue for benchmarks and tests.
"""

from repro.client.compile import CompiledCircuit, compile_handle  # noqa: F401
from repro.client.handles import (  # noqa: F401
    CipherHandle, PlainHandle, as_plain,
)
from repro.client.session import CipherFuture, HESession  # noqa: F401

__all__ = [
    "HESession", "CipherHandle", "PlainHandle", "CipherFuture",
    "CompiledCircuit", "compile_handle", "as_plain",
]
