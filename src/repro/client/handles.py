"""Traced ciphertext/plaintext handles — the `repro.client` expression
frontend.

A :class:`CipherHandle` is a NODE in a lazily traced op-DAG, not a
ciphertext: `* + - conj() rotate(r) slot_sum()` build more nodes and
nothing touches the server until :meth:`CipherHandle.result` /
``HESession.run`` lowers the trace through the compile pass
(`repro.client.compile`). The traced vocabulary is exactly the
ciphertext-level op set the server batches (mul, mul_plain, add,
add_plain, sub, rotate, conjugate, slot_sum) — level management
(rescale / mod-down) is deliberately ABSENT from the handle API: the
compiler owns it (paper §III-A's discipline, inserted automatically).

A :class:`PlainHandle` wraps a plaintext slot message (a complex vector
or a scalar broadcast at compile time). Plain–plain arithmetic never
reaches a trace: it constant-folds eagerly in numpy, so only
cipher-touching ops are ever served. At compile time each plain operand
is content-hashed (`core.encoding.message_hash`) so the server can cache
its encoding by (hash, level) — reused weights encode and ship once.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import numpy as np

from repro.core.cipher import Ciphertext

__all__ = ["CipherHandle", "PlainHandle", "as_plain"]

Plainable = Union["PlainHandle", int, float, complex, np.ndarray, list,
                  tuple]

# every traced node kind ("input" wraps a real Ciphertext leaf)
TRACE_OPS = ("input", "mul", "mul_plain", "add", "add_plain", "sub",
             "rotate", "conjugate", "slot_sum")


def as_plain(v: Plainable) -> "PlainHandle":
    """Coerce a scalar / array / PlainHandle to a PlainHandle."""
    return v if isinstance(v, PlainHandle) else PlainHandle(v)


class PlainHandle:
    """A plaintext operand of a traced expression.

    Holds the slot MESSAGE (complex vector, or a scalar broadcast to the
    ciphertext's slot count at compile time) — never an encoding: the
    compile pass encodes at each use site's (level, scale), and skips
    even that when the server's plaintext cache already holds the
    operand's (hash, level) entry.

    Arithmetic between plain values folds eagerly (numpy); only ops
    with a :class:`CipherHandle` operand extend a trace.
    """

    __slots__ = ("z",)
    __array_ufunc__ = None        # numpy defers to our reflected ops

    def __init__(self, z: Plainable):
        if isinstance(z, PlainHandle):
            self.z = z.z
            return
        if isinstance(z, (int, float, complex, np.integer, np.floating,
                          np.complexfloating)):
            self.z = complex(z)
            return
        z = np.asarray(z, dtype=np.complex128)
        if z.ndim != 1:
            raise ValueError(
                f"plaintext message must be a scalar or 1-D slot vector, "
                f"got shape {z.shape}")
        self.z = z

    @property
    def is_scalar(self) -> bool:
        return not isinstance(self.z, np.ndarray)

    def broadcast(self, n_slots: int) -> np.ndarray:
        """The message as an (n_slots,) complex vector."""
        if self.is_scalar:
            return np.full(n_slots, self.z, dtype=np.complex128)
        if len(self.z) != n_slots:
            raise ValueError(
                f"plaintext has {len(self.z)} slots; ciphertext has "
                f"{n_slots}")
        return self.z

    # ---- eager constant folding -----------------------------------------

    def __mul__(self, other):
        if isinstance(other, CipherHandle):
            return other * self
        return PlainHandle(self.z * as_plain(other).z)

    __rmul__ = __mul__

    def __add__(self, other):
        if isinstance(other, CipherHandle):
            return other + self
        return PlainHandle(self.z + as_plain(other).z)

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, CipherHandle):
            raise TypeError(
                "plain - cipher needs a ciphertext negation, which is "
                "not a served op; rewrite the expression so the "
                "ciphertext comes first (e.g. cipher * -1 + plain)")
        return PlainHandle(self.z - as_plain(other).z)

    def __rsub__(self, other):
        return PlainHandle(as_plain(other).z - self.z)

    def __neg__(self):
        return PlainHandle(-self.z)

    def conj(self) -> "PlainHandle":
        return PlainHandle(np.conj(self.z))

    def rotate(self, r: int) -> "PlainHandle":
        if self.is_scalar:
            return self                # a constant is rotation-invariant
        return PlainHandle(np.roll(self.z, -int(r)))

    def slot_sum(self) -> "PlainHandle":
        if self.is_scalar:
            raise ValueError(
                "slot_sum of a scalar plaintext needs a slot count; "
                "pass the full slot vector instead")
        return PlainHandle(np.full(len(self.z), self.z.sum()))

    def __repr__(self):
        return f"PlainHandle({self.z!r})"


class CipherHandle:
    """A lazily traced ciphertext expression node.

    Never holds an intermediate ciphertext: only "input" nodes wrap a
    real :class:`Ciphertext` (via ``HESession.encrypt`` /
    ``HESession.input``); every operator builds a new node. Compile +
    submit happen in ``HESession.run`` (or the :meth:`result`
    shorthand), which returns futures so many traced circuits co-batch
    through one server drain.
    """

    __slots__ = ("session", "op", "args", "plain", "r", "ct", "n_slots")
    __array_ufunc__ = None        # numpy defers to our reflected ops

    def __init__(self, session, op: str, args: Tuple["CipherHandle", ...]
                 = (), *, plain: Optional[PlainHandle] = None, r: int = 0,
                 ct: Optional[Ciphertext] = None):
        if op not in TRACE_OPS:
            raise ValueError(f"unknown traced op {op!r}; one of "
                             f"{TRACE_OPS}")
        self.session = session
        self.op = op
        self.args = tuple(args)
        self.plain = plain
        self.r = r
        self.ct = ct
        if op == "input":
            if ct is None:
                raise ValueError("input handles wrap a Ciphertext")
            self.n_slots = ct.n_slots
        else:
            self.n_slots = self.args[0].n_slots
        # slot-count mismatches fail at TRACE time, not at submit
        if plain is not None and not plain.is_scalar \
                and len(plain.z) != self.n_slots:
            raise ValueError(
                f"plaintext operand has {len(plain.z)} slots; the "
                f"ciphertext expression has {self.n_slots}")
        for a in self.args:
            if a.session is not self.session:
                raise ValueError(
                    "cannot mix handles from different sessions")
            if a.n_slots != self.n_slots:
                raise ValueError(
                    f"operand slot counts differ "
                    f"({a.n_slots} != {self.n_slots})")

    @property
    def ciphertext(self) -> Ciphertext:
        """The wrapped ciphertext — input handles only (traced nodes
        have no value until run)."""
        if self.op != "input":
            raise ValueError(
                "only input handles hold a ciphertext; call .result() "
                "to run the trace")
        return self.ct

    # ---- trace-building operators ---------------------------------------

    def __mul__(self, other):
        if isinstance(other, CipherHandle):
            return CipherHandle(self.session, "mul", (self, other))
        return CipherHandle(self.session, "mul_plain", (self,),
                            plain=as_plain(other))

    __rmul__ = __mul__            # mul and mul_plain both commute

    def __add__(self, other):
        if isinstance(other, CipherHandle):
            return CipherHandle(self.session, "add", (self, other))
        return CipherHandle(self.session, "add_plain", (self,),
                            plain=as_plain(other))

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, CipherHandle):
            return CipherHandle(self.session, "sub", (self, other))
        return CipherHandle(self.session, "add_plain", (self,),
                            plain=-as_plain(other))

    def __rsub__(self, other):
        raise TypeError(
            "plain - cipher needs a ciphertext negation, which is not a "
            "served op; rewrite the expression so the ciphertext comes "
            "first (e.g. cipher * -1 + plain)")

    def rotate(self, r: int) -> "CipherHandle":
        """Left-rotate slots by r (slot i+r moves to slot i)."""
        r = int(r)
        if r <= 0:
            raise ValueError("rotate needs a positive left-rotation "
                             "amount r")
        return CipherHandle(self.session, "rotate", (self,), r=r)

    def conj(self) -> "CipherHandle":
        """Slotwise complex conjugation (σ₋₁)."""
        return CipherHandle(self.session, "conjugate", (self,))

    def slot_sum(self) -> "CipherHandle":
        """Every slot becomes the sum of all slots (log₂ n rotate+add
        rounds server-side)."""
        return CipherHandle(self.session, "slot_sum", (self,))

    # ---- execution shorthand --------------------------------------------

    def result(self) -> Ciphertext:
        """Compile, submit, and wait for this expression's ciphertext
        (co-batches with everything else pending on the session's
        server)."""
        return self.session.run([self])[0].result()

    def __repr__(self):
        if self.op == "input":
            return (f"CipherHandle(input, logq={self.ct.logq}, "
                    f"n_slots={self.n_slots})")
        return f"CipherHandle({self.op}, {len(self.args)} arg(s))"
