"""Checkpoint manager: per-leaf npz + JSON manifest, built for restarts.

Properties required at pod scale and implemented here:
  - **atomic**: writes land in ``step_XXXX.tmp`` and are renamed only after
    the manifest (with per-leaf checksums) is fsynced — a crash mid-save
    never corrupts the latest checkpoint.
  - **async**: ``save()`` snapshots device arrays to host then hands the
    file I/O to a worker thread; training continues.
  - **keep-k**: older checkpoints are garbage-collected.
  - **reshard-on-restore**: leaves are stored as full (unsharded) host
    arrays plus the pytree structure; ``restore(..., sharding_fn=...)``
    re-places them under ANY mesh — elastic restarts across different pod
    counts (DESIGN.md §9). At extreme scale a per-shard format would be
    swapped in behind the same interface.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

SEP = "::"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _as_dtype(arr: np.ndarray, name: str) -> np.ndarray:
    """Recover extended dtypes (bfloat16, ...) that .npy stores as void."""
    if str(arr.dtype) == name:
        return arr
    try:
        dt = np.dtype(name)
    except TypeError:
        import ml_dtypes
        dt = np.dtype(getattr(ml_dtypes, name))
    return arr.view(dt) if arr.dtype.kind == "V" else arr.astype(dt)


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, block: bool = False) -> None:
        host = jax.tree.map(lambda a: np.asarray(a), tree)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for key, arr in leaves.items():
            arr = np.asarray(arr)
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                sharding_fn: Optional[Callable[[str, Any], Any]] = None
                ) -> Any:
        """Restore into `template`'s structure.

        sharding_fn(path_key, host_array) -> device array; defaults to plain
        jnp placement. Passing a mesh-aware function implements elastic
        resharding.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys, treedef = _flatten_with_paths(template)
        leaves = []
        for key, tmpl in keys.items():
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            arr = _as_dtype(arr, meta["dtype"])
            assert list(arr.shape) == list(np.shape(tmpl)), \
                f"shape mismatch at {key}: ckpt {arr.shape} vs {np.shape(tmpl)}"
            if sharding_fn is not None:
                leaves.append(sharding_fn(key, arr))
            else:
                import jax.numpy as jnp
                leaves.append(jnp.asarray(arr))
        return treedef.unflatten(leaves)
