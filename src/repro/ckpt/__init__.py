"""Checkpointing: atomic, async, keep-k, reshard-on-restore."""

from repro.ckpt.manager import CheckpointManager

__all__ = ["CheckpointManager"]
