"""Stages 2 & 4: CoeffToSlot / SlotToCoeff as BSGS linear transforms.

Both stages are the same object: a dense n×n complex matrix applied
homomorphically to the slot vector, built from rotate + mul_plain + add
(one multiplicative level). The matrices come straight from the
encoding (`core.encoding.emb` / `emb_inv`, HEAAN's rot-group special
FFT), evaluated on unit vectors — so the homomorphic transform and the
client-side codec can never disagree about slot layout:

  - a FULL-slot ciphertext (n = N/2, gap = 1) decodes to
    w = emb(u) where u_i = (t_i + i·t_{N/2+i}) / Δ pairs up ALL N
    polynomial coefficients as n complex values;
  - CoeffToSlot is therefore emb⁻¹ as a matrix (slots become u — the
    raw coefficients), and SlotToCoeff is emb (u back to slot view).

Full slots are REQUIRED: with n < N/2 the gap coefficients are
invisible to decode but NOT to ring multiplication, so the q·I(X) junk
mod-raise leaves there would poison every post-bootstrap mul. The
pipeline rejects sparse ciphertexts up front.

The baby-step/giant-step split evaluates M·w = Σ_j rot_{j·g}(Σ_i
rot_{-j·g}(diag_{j·g+i}) ⊙ rot_i(w)) with g ≈ √n babies — O(√n)
rotations instead of n, all through resident rotation keys, and every
pre-rotated diagonal is a plain operand that lands in the server's
(hash, level) cache: repeat bootstraps ship the whole DFT hash-only.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.core.encoding import emb, emb_inv

__all__ = ["coeff_to_slot_matrix", "slot_to_coeff_matrix", "bsgs_matvec",
           "default_giant_step"]


@lru_cache(maxsize=8)
def slot_to_coeff_matrix(n: int, N: int) -> np.ndarray:
    """emb as an n×n matrix (decode direction): w = E·u."""
    E = np.empty((n, n), dtype=np.complex128)
    for j in range(n):
        e = np.zeros(n, dtype=np.complex128)
        e[j] = 1.0
        E[:, j] = emb(e, 2 * N)
    return E


@lru_cache(maxsize=8)
def coeff_to_slot_matrix(n: int, N: int) -> np.ndarray:
    """emb⁻¹ as an n×n matrix (encode direction): u = E⁻¹·w."""
    Ei = np.empty((n, n), dtype=np.complex128)
    for j in range(n):
        e = np.zeros(n, dtype=np.complex128)
        e[j] = 1.0
        Ei[:, j] = emb_inv(e, 2 * N)
    return Ei


def default_giant_step(n: int) -> int:
    """Baby-step count g ≈ √n, rounded to a power of two so the giant
    rotations j·g stay few and key-shareable across stages."""
    g = 1
    while g * g < n:
        g <<= 1
    return g


def bsgs_matvec(x, M: np.ndarray, *, giant_step: int = 0, tol: float =
                1e-12):
    """Apply a dense complex matrix to a traced slot vector.

    x: `repro.client.handles.CipherHandle` with n slots.
    M: (n, n) complex matrix.
    giant_step: baby-step count g (0 → :func:`default_giant_step`).
    tol: diagonals with max |entry| below this are skipped.

    Costs one multiplicative level (every term is one mul_plain, auto-
    rescaled by the compile pass) and {1..g−1} ∪ {g, 2g, ...} rotation
    keys. Returns the traced result handle.
    """
    M = np.asarray(M, dtype=np.complex128)
    n = M.shape[0]
    if M.shape != (n, n) or n != x.n_slots:
        raise ValueError(f"matrix {M.shape} does not match the "
                         f"handle's {x.n_slots} slots")
    g = giant_step or default_giant_step(n)
    idx = np.arange(n)
    babies = {0: x}
    out = None
    for j in range((n + g - 1) // g):
        inner = None
        for i in range(g):
            k = j * g + i
            if k >= n:
                break
            d = M[idx, (idx + k) % n]            # k-th diagonal
            if not np.any(np.abs(d) > tol):
                continue
            if i not in babies:
                babies[i] = x.rotate(i)
            # pre-rotate the diagonal by the giant step so one rotation
            # of the inner sum restores alignment: rot_{-jg}(d)
            term = babies[i] * np.roll(d, j * g)
            inner = term if inner is None else inner + term
        if inner is None:
            continue
        if j:
            inner = inner.rotate(j * g)
        out = inner if out is None else out + inner
    if out is None:
        raise ValueError("matrix is numerically zero")
    return out
