"""Stage 3: EvalMod — approximate t mod q via the scaled sine.

After CoeffToSlot the slots hold u = (m + e + q·I)/Δ as complex values
(real/imag = paired coefficients). In slot units with q_s = q/Δ the
target map is

    f(x) = (q_s / 2π) · sin(2π x / q_s)        (elementwise, x real)

— periodic in q_s (so the q·I term vanishes) and ≈ x near 0 (so the
message survives, up to the cubic deviation (2π/q_s)²·x³/6 that the
pipeline's error contract documents).

The evaluation is HEAAN's complex-exponential method: a short Taylor
series for exp(iθ/2^r) where |θ/2^r| ≤ 1, then r repeated squarings
(each one served mul / one level) to reach exp(iθ), then
sin θ = Im = (v − v̄)/2i via one conjugation. Both the real and the
imaginary coefficient streams need the map, so the pipeline splits
u into u ± ū, runs two evaluations, and recombines — the ±1/2 and ±i
bookkeeping constants are folded into the surrounding mul_plain
scalars so the split itself costs no extra level.

Everything here builds TRACED handles (`repro.client`): level
alignment, rescales, CSE (the two shared powers of w), and plain-scalar
encoding all come from the compile pass.
"""

from __future__ import annotations

import math

__all__ = ["exp_taylor_coeffs", "poly_eval", "eval_mod"]


def exp_taylor_coeffs(degree: int):
    """[1/k! for k ≤ degree] — exp's Taylor coefficients, precomputed
    host-side (floats; encoding quantizes them at the use level)."""
    if degree < 1:
        raise ValueError(f"need degree >= 1, got {degree}")
    return [1.0 / math.factorial(k) for k in range(degree + 1)]


def poly_eval(w, coeffs):
    """Evaluate Σ coeffs[k]·w^k over a traced handle in
    ⌈log₂(deg+1)⌉ multiplicative levels (balanced power-of-two split,
    Paterson–Stockmeyer-style), not Horner's deg levels.

    The power ladder w, w², w⁴, … is shared across both split halves —
    handle identity (plus compile-pass CSE) keeps each squaring a
    single served mul.
    """
    if len(coeffs) < 2:
        raise ValueError("need a degree >= 1 polynomial")
    pows = {1: w}
    m = 1
    while 2 * m < len(coeffs):
        pows[2 * m] = pows[m] * pows[m]
        m *= 2

    def ev(cs):
        # returns a handle when any non-constant term survives,
        # else the bare constant (folded into the parent's add)
        if len(cs) == 1:
            return cs[0]
        m = 1
        while 2 * m < len(cs):
            m *= 2
        hi = ev(cs[m:])
        lo = ev(cs[:m])
        term = pows[m] * hi                   # mul_plain or mul
        return term + lo

    return ev(list(coeffs))


def eval_mod(u, *, q_s_bits: int, degree: int, r: int):
    """The full modular-reduction stage on a complex slot vector.

    u: traced handle whose slots hold x_re + i·x_im with each part to be
       reduced mod q_s = 2^q_s_bits independently.
    degree: Taylor degree for exp(iθ/2^r).
    r: squaring count — requires |θ|/2^r ≲ 1 (the pipeline sizes r from
       the mod-raise interval bound).

    Level cost: 1 (argument scaling) + ⌈log₂(degree+1)⌉ (Taylor)
    + r (squarings) + 1 (Im extraction) — the split/recombine adds and
    conjugations are free.
    """
    q_s = 2.0 ** q_s_bits
    coeffs = exp_taylor_coeffs(degree)

    def branch(doubled, c_arg, c_out):
        # doubled = 2x (or 2i·x); w = c_arg·doubled = iθ/2^r
        w = doubled * c_arg
        v = poly_eval(w, coeffs)              # ≈ exp(iθ/2^r)
        for _ in range(r):
            v = v * v                         # ≈ exp(iθ)
        # (v − v̄) = 2i·sin θ; c_out folds 1/2i and q_s/2π (and, for the
        # imaginary branch, the recombination factor i)
        return (v - v.conj()) * c_out

    uc = u.conj()
    s_re = branch(u + uc,                     # 2·Re u
                  1j * math.pi / (q_s * 2.0 ** r),
                  -1j * q_s / (4.0 * math.pi))
    s_im = branch(u - uc,                     # 2i·Im u
                  math.pi / (q_s * 2.0 ** r),
                  q_s / (4.0 * math.pi))
    return s_re + s_im                        # f(x_re) + i·f(x_im)
